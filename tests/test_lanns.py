"""End-to-end LannsIndex: recall vs brute force, persistence, resume, spill."""


import numpy as np
import pytest

from repro.core import (
    LannsConfig,
    LannsIndex,
    brute_force_topk,
    recall_at_k,
)
from repro.data.synthetic import clustered_vectors


@pytest.fixture(scope="module")
def corpus():
    data = clustered_vectors(6000, 24, n_clusters=64, seed=0)
    queries = clustered_vectors(100, 24, n_clusters=64, seed=1)
    truth = brute_force_topk(queries, data, 20)
    return data, queries, truth


@pytest.mark.parametrize("segmenter", ["rs", "rh", "apd"])
def test_recall_bands(corpus, segmenter):
    """Paper Table 1 qualitative ordering at small scale: RS ~ APD > RH,
    all within a bounded drop of brute force."""
    data, queries, (td, ti) = corpus
    cfg = LannsConfig(
        num_shards=1, num_segments=8, segmenter=segmenter, engine="scan",
        alpha=0.15,
    )
    idx = LannsIndex(cfg).build(data)
    d, i = idx.query(queries, 20)
    r = recall_at_k(i, ti, 10)
    floor = {"rs": 0.95, "rh": 0.55, "apd": 0.7}[segmenter]
    assert r > floor, (segmenter, r)


def test_rs_exact_with_full_pstk(corpus):
    """RS + scan engine + perShardTopK disabled == exact brute force."""
    data, queries, (td, ti) = corpus
    cfg = LannsConfig(num_shards=2, num_segments=2, segmenter="rs",
                      engine="scan", topk_confidence=0.999999)
    idx = LannsIndex(cfg).build(data)
    d, i = idx.query(queries, 10)
    assert recall_at_k(i, ti, 10) > 0.999


def test_hnsw_engine(corpus):
    data, queries, (td, ti) = corpus
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="hnsw", hnsw_m=8, ef_construction=60,
                      ef_search=60)
    idx = LannsIndex(cfg).build(data)
    d, i = idx.query(queries, 10)
    assert recall_at_k(i, ti, 10) > 0.6


def test_physical_vs_virtual_spill(corpus):
    """Table 7: physical spill stores more points, similar recall."""
    data, queries, (td, ti) = corpus
    rv, rp, dup = {}, {}, {}
    for spill in ("virtual", "physical"):
        cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                          spill=spill, engine="scan")
        idx = LannsIndex(cfg).build(data)
        d, i = idx.query(queries, 20)
        rv[spill] = recall_at_k(i, ti, 15)
        dup[spill] = idx.build_stats["duplication_factor"]
    assert dup["physical"] > 1.05 > dup["virtual"] == 1.0
    assert abs(rv["physical"] - rv["virtual"]) < 0.1


def _two_level_reference(idx, queries, topk):
    """Replay the PRE-FLIP fp32 scan merge: per-partition top-pstk candidates
    into compact route slots, then the two-level lexsort-dedup merge
    (merge_topk_vec) — the path the disjoint flip replaced for virtual
    spill.  Kept here as the parity oracle (ROADMAP deprecation-window
    item)."""
    from repro.core.merge import merge_topk_vec, per_shard_topk

    cfg = idx.config
    queries = np.asarray(queries, np.float32)
    B, S = queries.shape[0], cfg.num_shards
    pstk = per_shard_topk(topk, S, cfg.topk_confidence)
    seg_mask = idx.partitioner.route_queries(queries)
    slot = np.cumsum(seg_mask, axis=1) - 1
    max_routes = max(int(seg_mask.sum(axis=1).max()), 1)
    cand_d = np.full((B, S, max_routes, pstk), np.inf, np.float32)
    cand_i = np.full((B, S, max_routes, pstk), -1, np.int64)
    for g in range(cfg.num_segments):
        sel = np.nonzero(seg_mask[:, g])[0]
        if sel.size == 0:
            continue
        for s in range(S):
            part = idx.partitions.get((s, g))
            if part is None or part.size == 0:
                continue
            d, i = part.search(queries[sel], pstk)
            cand_d[sel, s, slot[sel, g]] = d
            cand_i[sel, s, slot[sel, g]] = i
    shard_d, shard_i = merge_topk_vec(
        cand_d.reshape(B * S, max_routes * pstk),
        cand_i.reshape(B * S, max_routes * pstk), pstk,
    )
    return merge_topk_vec(
        shard_d.reshape(B, S * pstk), shard_i.reshape(B, S * pstk), topk
    )


def test_scan_disjoint_merge_parity_single_shard(corpus):
    """S=1: perShardTopK never trims, so the dedup-free disjoint merge must
    reproduce the old two-lexsort merge bit-for-bit."""
    data, queries, _ = corpus
    cfg = LannsConfig(num_shards=1, num_segments=8, segmenter="apd",
                      engine="scan", alpha=0.15)
    idx = LannsIndex(cfg).build(data)
    d_new, i_new, stats = idx.query(queries, 20, return_stats=True)
    assert stats["merge_path"] == "disjoint"
    d_old, i_old = _two_level_reference(idx, queries, 20)
    assert np.array_equal(i_new, i_old)
    assert np.array_equal(d_new, d_old)


def test_scan_disjoint_merge_parity_multi_shard(corpus):
    """S=2: the flat merge forwards MORE than perShardTopK would, so
    distances can only improve (element-wise <=), and agree wherever the
    trim didn't bind."""
    data, queries, (td, ti) = corpus
    cfg = LannsConfig(num_shards=2, num_segments=4, segmenter="apd",
                      engine="scan", alpha=0.15)
    idx = LannsIndex(cfg).build(data)
    d_new, i_new = idx.query(queries, 20)
    d_old, i_old = _two_level_reference(idx, queries, 20)
    finite = np.isfinite(d_old)
    assert (d_new[finite] <= d_old[finite] + 1e-6).all()
    same = d_new == d_old
    assert same.mean() > 0.9  # the trim binds rarely at this scale
    assert np.array_equal(i_new[same], i_old[same])
    assert recall_at_k(i_new, ti, 15) >= recall_at_k(i_old, ti, 15) - 1e-9


def test_physical_spill_keeps_dedup_merge(corpus):
    """Physical spill duplicates points across segments — the dedup-free
    path must NOT serve it, and duplicate ids must still collapse."""
    data, queries, _ = corpus
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      spill="physical", engine="scan")
    idx = LannsIndex(cfg).build(data)
    d, i, stats = idx.query(queries, 20, return_stats=True)
    assert stats["merge_path"] == "two_level"
    for row in i:
        real = row[row >= 0]
        assert len(np.unique(real)) == len(real)


def test_hnsw_keeps_two_level_merge(corpus):
    data, queries, _ = corpus
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="hnsw", hnsw_m=8, ef_construction=40,
                      ef_search=40)
    idx = LannsIndex(cfg).build(data)
    _, _, stats = idx.query(queries[:8], 10, return_stats=True)
    assert stats["merge_path"] == "two_level"


def test_warm_traces_covers_live_batches(corpus):
    """After warm_traces(max_batch, k) — non-pow2 max_batch included — live
    queries at any batch size <= max_batch add NO new scan traces (the
    compile-in-timed-window failure mode of p99 sweeps)."""
    data, queries, _ = corpus
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="scan", alpha=0.15)
    idx = LannsIndex(cfg).build(data)
    idx.warm_traces(12, 10)  # non-pow2: must still warm the 16 bucket
    _, _, stats0 = idx.query(queries[:1], 10, return_stats=True)
    for b in (1, 3, 7, 12):
        idx.query(queries[:b], 10)
    _, _, stats1 = idx.query(queries[:1], 10, return_stats=True)
    assert stats1["scan_traces"] == stats0["scan_traces"]


def test_partition_sizes_balanced(corpus):
    data, _, _ = corpus
    cfg = LannsConfig(num_shards=2, num_segments=4, segmenter="rh", engine="scan")
    idx = LannsIndex(cfg).build(data)
    sizes = np.array(idx.build_stats["partition_sizes"])
    assert sizes.sum() == len(data)
    assert sizes.max() < 3 * max(sizes.min(), 1)


def test_save_load_roundtrip(tmp_path, corpus):
    data, queries, _ = corpus
    cfg = LannsConfig(num_shards=2, num_segments=2, segmenter="rh",
                      engine="hnsw", hnsw_m=8, ef_construction=40)
    idx = LannsIndex(cfg).build(data[:2000])
    d1, i1 = idx.query(queries, 5)
    idx.save(str(tmp_path / "idx"))
    idx2 = LannsIndex.load(str(tmp_path / "idx"))
    d2, i2 = idx2.query(queries, 5)
    assert np.array_equal(i1, i2)
    assert np.allclose(d1, d2, rtol=1e-6)


def test_resumable_build(tmp_path, corpus):
    """Fault tolerance: kill the build midway, restart, finish — partitions
    already persisted are not rebuilt (paper §5.3.1 adapted)."""
    data, queries, _ = corpus
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="rh", engine="scan")
    rdir = str(tmp_path / "resume")

    idx = LannsIndex(cfg)
    idx.fit(data[:2000])
    assignment = idx.partitioner.assign(data[:2000], np.arange(2000))
    # simulate a partial build: persist only segments 0 and 1
    from repro.core.lanns import _build_one_partition

    for g in (0, 1):
        rows = assignment.rows[0][g]
        s, gg, payload, _ = _build_one_partition(
            (0, g, data[rows], np.arange(2000)[rows], "scan",
             cfg.hnsw_config(), 256)
        )
        idx._save_partition(rdir, s, gg, payload)

    idx2 = LannsIndex(cfg)
    idx2.fit(data[:2000])
    idx2.build(data[:2000], resume_dir=rdir)
    assert len(idx2.partitions) == 4
    # query works after resume
    d, i = idx2.query(queries, 5)
    assert (i >= 0).all()


@pytest.mark.parametrize("engine", ["scan", "hnsw"])
def test_empty_query_batch(corpus, engine):
    """Regression: B == 0 raised ValueError on segments_visited.max() (and
    warned on .mean()); it must return well-formed (0, topk) outputs."""
    data, _, _ = corpus
    cfg = LannsConfig(num_shards=2, num_segments=2, segmenter="rh",
                      engine=engine, hnsw_m=8, ef_construction=40,
                      ef_search=40)
    idx = LannsIndex(cfg).build(data[:1500])
    empty = np.zeros((0, data.shape[1]), np.float32)
    d, i, stats = idx.query(empty, 7, return_stats=True)
    assert d.shape == (0, 7) and i.shape == (0, 7)
    assert d.dtype == np.float32 and i.dtype == np.int64
    assert stats["mean_segments_visited"] == 0.0
    assert stats["max_segments_visited"] == 0
    assert stats["per_shard_topk"] <= 7
    # same stats schema as a non-empty batch (dashboards index these keys)
    _, _, full_stats = idx.query(data[:3], 7, return_stats=True)
    assert set(stats) == set(full_stats)
    d2, i2 = idx.query(empty, 7)
    assert d2.shape == (0, 7) and i2.shape == (0, 7)
    with pytest.raises(ValueError, match="hnsw_mode"):
        idx.query(data[:2], 7, hnsw_mode="staked")


def test_query_stats(corpus):
    data, queries, _ = corpus
    cfg = LannsConfig(num_shards=2, num_segments=4, segmenter="rh", engine="scan")
    idx = LannsIndex(cfg).build(data)
    _, _, stats = idx.query(queries, 10, return_stats=True)
    assert 1.0 <= stats["mean_segments_visited"] <= 4.0
    assert stats["per_shard_topk"] <= 10


def test_mips_metric_beats_raw_ip_routing():
    """Beyond-paper: the augmented-vector MIPS->L2 reduction routes far
    better than raw inner-product (which ignores the norm component)."""
    from repro.data.synthetic import clustered_vectors

    rng = np.random.default_rng(1)
    items = clustered_vectors(4000, 24, n_clusters=48, seed=0,
                              spectrum_decay=1.0)
    items = items * rng.uniform(0.5, 2.0, (4000, 1)).astype(np.float32)
    qs = clustered_vectors(100, 24, n_clusters=48, seed=2, center_seed=0,
                           spectrum_decay=1.0)
    td, ti = brute_force_topk(qs, items, 20, metric="ip")
    recalls = {}
    for metric in ("ip", "mips"):
        cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                          engine="scan", metric=metric)
        d, i = LannsIndex(cfg).build(items).query(qs, 20)
        recalls[metric] = recall_at_k(i, ti, 20)
        if metric == "mips":
            # converted distances must equal -<q, x> exactly
            fin = np.isfinite(d) & (i >= 0)
            ips = np.einsum("bd,bkd->bk", qs, items[np.clip(i, 0, None)])
            assert np.abs(d[fin] + ips[fin]).max() < 1e-4
    assert recalls["mips"] > recalls["ip"] + 0.1, recalls

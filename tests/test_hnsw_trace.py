"""Device-resident HNSW serving: trace stability + stacked-search parity.

The serving contract this file locks in:

* the frozen graph uploads host->device once (cached pytree), never per call;
* ``beam_search`` compilations are bounded by the power-of-two bucket count —
  independent of how many partitions exist and which routed-subset sizes the
  router produces;
* the stacked multi-partition path is BIT-identical to the per-partition and
  legacy (pre-device-resident) paths.
"""

import numpy as np
import pytest

from repro.common.utils import next_pow2_quarter
from repro.core import LannsConfig, LannsIndex
from repro.core.hnsw import (
    HNSWConfig,
    HNSWIndex,
    beam_search,
    beam_search_flat,
    beam_search_stacked,
)
from repro.data.synthetic import clustered_vectors


@pytest.fixture(scope="module")
def hnsw_index():
    data = clustered_vectors(3000, 16, n_clusters=32, seed=0)
    queries = clustered_vectors(80, 16, n_clusters=32, seed=1)
    cfg = LannsConfig(num_shards=2, num_segments=4, segmenter="apd",
                      engine="hnsw", hnsw_m=8, ef_construction=50,
                      ef_search=50)
    return LannsIndex(cfg).build(data), queries


def test_stacked_bit_identical_to_legacy_and_partition(hnsw_index):
    idx, queries = hnsw_index
    for B in (1, 7, 32, 80):
        d_s, i_s = idx.query(queries[:B], 10)
        d_l, i_l = idx.query(queries[:B], 10, hnsw_mode="legacy")
        d_p, i_p = idx.query(queries[:B], 10, hnsw_mode="partition")
        assert np.array_equal(i_s, i_l) and np.array_equal(d_s, d_l)
        assert np.array_equal(i_s, i_p) and np.array_equal(d_s, d_p)


def test_stacked_traces_bounded_in_batch_and_partitions(hnsw_index):
    idx, queries = hnsw_index
    idx.query(queries[:4], 10)  # warm the stack
    before = beam_search_flat._cache_size()
    sizes = (1, 2, 3, 5, 6, 7, 9, 11, 13, 30, 41, 63, 80)
    for B in sizes:
        idx.query(queries[:B], 10)
    new = beam_search_flat._cache_size() - before
    # routed-pair lane counts fold into quarter-pow2 buckets; the total
    # routed count T <= B * m varies with B, so bound by the bucket count of
    # the reachable lane range (T in [1, 80 * 4]) rather than per-B buckets.
    max_lane_buckets = len(
        {next_pow2_quarter(t) for t in range(1, 80 * 4 + 1)}
    )
    assert new <= max_lane_buckets, (new, max_lane_buckets)
    # an index with a different partition count reuses the SAME flat traces
    # when its lane counts fold into already-seen buckets — compilations
    # never scale with partitions * sizes.
    data = clustered_vectors(1200, 16, n_clusters=16, seed=3)
    cfg2 = LannsConfig(num_shards=1, num_segments=2, segmenter="apd",
                       engine="hnsw", hnsw_m=8, ef_construction=50,
                       ef_search=50)
    idx2 = LannsIndex(cfg2).build(data)
    before2 = beam_search_flat._cache_size()
    sizes2 = (1, 2, 3, 5, 9)
    for B in sizes2:
        idx2.query(queries[:B], 10)
    assert beam_search_flat._cache_size() - before2 <= len(
        {next_pow2_quarter(t) for t in range(1, 9 * 2 + 1)}
    )


def test_partition_mode_traces_shared_across_partitions(hnsw_index):
    """Per-partition fallback: shared (n, L) corpus buckets + quarter-pow2
    routed-batch buckets mean beam_search compiles once per DISTINCT bucket,
    never once per (partition, window) pair."""
    idx, queries = hnsw_index
    windows = [(0, 64), (7, 64), (16, 64), (5, 48), (11, 48), (30, 50)]
    idx.query(queries[:64], 10, hnsw_mode="partition")  # warm corpus upload
    before = beam_search._cache_size()
    buckets = set()
    for lo, B in windows:
        q = queries[lo: lo + B]
        mask = idx.partitioner.route_queries(q)
        for g in range(idx.config.num_segments):
            c = int(mask[:, g].sum())
            if c:
                buckets.add(next_pow2_quarter(c))
        idx.query(q, 10, hnsw_mode="partition")
    new = beam_search._cache_size() - before
    n_parts = len(idx.partitions)
    assert new <= len(buckets), (new, buckets)
    assert new < len(windows) * n_parts / 2, "traces must not scale with " \
        "(windows x partitions)"


def test_device_pytree_cached_across_calls(hnsw_index):
    idx, _ = hnsw_index
    part = next(p for p in idx.partitions.values() if p.kind == "hnsw")
    a1 = part.frozen.device_arrays(2048, 4)
    a2 = part.frozen.device_arrays(2048, 4)
    assert a1 is a2, "device pytree must upload once, not per call"


def test_padding_is_result_transparent():
    """(n, L) padding must not change a single bit of the search output."""
    data = clustered_vectors(700, 12, n_clusters=8, seed=5)
    idx = HNSWIndex(HNSWConfig(M=8, ef_construction=50, ef_search=50), 12)
    idx.add_batch(data)
    fr = idx.freeze()
    qs = clustered_vectors(9, 12, n_clusters=8, seed=6)
    d0, i0 = fr.search(qs, 5)
    d1, i1 = fr.search(qs, 5, n_pad=1024, l_pad=fr.num_upper_levels + 3)
    assert np.array_equal(d0, d1) and np.array_equal(i0, i1)


def test_hnsw_serving_zero_retrace_on_repeat_workload(hnsw_index,
                                                      retrace_sentinel):
    """HNSW warm_traces is best-effort (lane buckets depend on routing), so
    the sentinel contract is run-identical-workload-twice: the second pass
    over the same batch sizes must compile NOTHING — beam, merge or
    otherwise."""
    idx, queries = hnsw_index
    sizes = (1, 3, 7, 13, 41, 80)
    for B in sizes:
        idx.query(queries[:B], 10)
    with retrace_sentinel.expect_no_retrace("repeated hnsw workload"):
        for B in sizes:
            idx.query(queries[:B], 10)


def test_q8_hnsw_serving_zero_retrace_on_repeat_workload(retrace_sentinel):
    """Quantized beam + exact re-rank: the full q8 x hnsw serving pipeline
    (stacked int8 beam, rerank gather, merge) reuses every trace on an
    identical second pass."""
    data = clustered_vectors(1500, 16, n_clusters=16, seed=9)
    queries = clustered_vectors(48, 16, n_clusters=16, seed=10)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="hnsw", hnsw_m=8, ef_construction=50,
                      ef_search=50, quantized="q8")
    idx = LannsIndex(cfg).build(data)
    sizes = (1, 5, 17, 48)
    for B in sizes:
        idx.query(queries[:B], 10)
    with retrace_sentinel.expect_no_retrace("repeated q8 hnsw workload"):
        for B in sizes:
            idx.query(queries[:B], 10)


def test_stacked_standalone_matches_single():
    """beam_search_stacked over P copies == P independent beam_search runs."""
    data = clustered_vectors(500, 12, n_clusters=8, seed=7)
    qs = clustered_vectors(8, 12, n_clusters=8, seed=8)
    frs = []
    for half in (data[:250], data[250:]):
        h = HNSWIndex(HNSWConfig(M=8, ef_construction=40, ef_search=40), 12)
        h.add_batch(half)
        frs.append(h.freeze())
    n_pad = 512
    l_pad = max(f.num_upper_levels for f in frs)
    import jax.numpy as jnp

    stacked = {
        key: jnp.stack([f.device_arrays(n_pad, l_pad)[key] for f in frs])
        for key in ("vectors", "adj0", "upper_adj", "entry")
    }
    qj = jnp.asarray(np.stack([qs, qs]))
    d_all, i_all = beam_search_stacked(
        stacked, qj, k=4, ef=40, max_iters=56, metric="l2"
    )
    for pi, f in enumerate(frs):
        d1, i1 = f.search(qs, 4, n_pad=n_pad, l_pad=l_pad)
        assert np.array_equal(np.asarray(d_all)[pi], d1)
        assert np.array_equal(np.asarray(i_all)[pi], i1)

"""distance_topk metric handling — the cos single-normalization fix.

The cos path in ops.distance_topk normalizes q/x once and must hand the jnp
fallback (and the k_pad>256 path) metric='ip'; passing 'cos' through used to
re-normalize inside ref.distance_matrix.  Idempotent up to fp error, so these
pin parity between the fixed path, the oracle, and the double-normalized
legacy behaviour."""

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _rand(B, N, D, seed=0):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, D)).astype(np.float32) * 3.0
    x = rng.standard_normal((N, D)).astype(np.float32) * 0.5
    return q, x


def test_cos_jnp_matches_oracle():
    q, x = _rand(16, 300, 24)
    d, i = ops.distance_topk(q, x, 10, "cos", backend="jnp")
    d_r, i_r = ref.distance_topk_ref(jnp.asarray(q), jnp.asarray(x), 10, "cos")
    assert np.array_equal(np.asarray(i), np.asarray(i_r))
    assert np.allclose(np.asarray(d), np.asarray(d_r), atol=1e-5)


def test_cos_jnp_matches_double_normalized_legacy():
    q, x = _rand(8, 200, 16, seed=1)
    d, i = ops.distance_topk(q, x, 8, "cos", backend="jnp")
    # legacy behaviour: normalize, then score with metric='cos' (normalizes
    # again inside distance_matrix)
    qn = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    xn = x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    d_l, i_l = ref.distance_topk_blocked(
        jnp.asarray(qn), jnp.asarray(xn), 8, "cos"
    )
    assert np.array_equal(np.asarray(i), np.asarray(i_l))
    assert np.allclose(np.asarray(d), np.asarray(d_l), atol=1e-5)


def test_cos_large_k_fallback_single_normalizes():
    # k_pad > 256 streams through the blocked jnp merge even with
    # backend='pallas_interpret' requested; ids must match the oracle.
    q, x = _rand(4, 600, 16, seed=2)
    d, i = ops.distance_topk(q, x, 300, "cos", backend="pallas_interpret")
    d_r, i_r = ref.distance_topk_ref(jnp.asarray(q), jnp.asarray(x), 300, "cos")
    assert np.array_equal(np.asarray(i), np.asarray(i_r))
    assert np.allclose(np.asarray(d), np.asarray(d_r), atol=1e-5)


def test_ip_on_prenormalized_equals_cos():
    q, x = _rand(8, 150, 16, seed=3)
    qn = q / np.linalg.norm(q, axis=-1, keepdims=True)
    xn = x / np.linalg.norm(x, axis=-1, keepdims=True)
    d_ip, i_ip = ops.distance_topk(qn, xn, 6, "ip", backend="jnp")
    d_cos, i_cos = ops.distance_topk(q, x, 6, "cos", backend="jnp")
    assert np.array_equal(np.asarray(i_ip), np.asarray(i_cos))
    assert np.allclose(np.asarray(d_ip), np.asarray(d_cos), atol=1e-5)


# ---------------------------------------------------------------------------
# empty-corpus handling (N == 0 used to recurse into the blocked scan k=0)
# ---------------------------------------------------------------------------


def test_empty_corpus_jnp():
    q = np.zeros((3, 8), np.float32)
    x = np.zeros((0, 8), np.float32)
    d, i = ops.distance_topk(q, x, 5, "l2", backend="jnp")
    assert np.asarray(d).shape == (3, 5) and np.asarray(i).shape == (3, 5)
    assert np.all(np.isinf(np.asarray(d)))
    assert np.all(np.asarray(i) == -1)


def test_empty_corpus_pallas_interpret():
    q = np.zeros((2, 16), np.float32)
    x = np.zeros((0, 16), np.float32)
    d, i = ops.distance_topk(q, x, 7, "ip", backend="pallas_interpret")
    assert np.all(np.isinf(np.asarray(d))) and np.all(np.asarray(i) == -1)


def test_empty_partition_search_both_engines():
    """An empty (shard, segment) partition serves (inf, -1) for any batch,
    whichever engine the config names."""
    from repro.core.lanns import LannsConfig, _Partition

    for engine in ("scan", "hnsw"):
        cfg = LannsConfig(engine=engine)
        part = _Partition(
            {"kind": "scan", "vectors": np.zeros((0, 8), np.float32),
             "keys": np.zeros((0,), np.int64)},
            cfg,
        )
        d, i = part.search(np.zeros((4, 8), np.float32), 3)
        assert d.shape == (4, 3) and np.all(np.isinf(d)) and np.all(i == -1)

"""Suppression fixture: justified noqa suppresses, bare noqa is LANNS000."""
import numpy as np
import jax.numpy as jnp


# lanns: hotpath
def justified(x):
    d = jnp.sqrt(x)
    return np.asarray(d)  # lanns: noqa[LANNS003] -- test fixture: the designed sync


# lanns: hotpath
def unjustified(x):
    d = jnp.sqrt(x)
    return np.asarray(d)  # lanns: noqa[LANNS003]


# lanns: hotpath
def wrong_code(x):
    d = jnp.sqrt(x)
    return np.asarray(d)  # lanns: noqa[LANNS001] -- wrong code: does not match

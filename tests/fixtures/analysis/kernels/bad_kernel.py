"""Intentionally-broken kernels fixture: trips LANNS020-024."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bad_kernel(x_ref, o_ref, *, block: int):
    x = x_ref[...].astype(jnp.float64)  # LANNS020: f64 in a kernels module
    idx = jnp.arange(block)  # LANNS022: arange in kernel body
    order = jnp.argsort(x[:, 0])  # LANNS023: sort in kernel body
    w = x @ x.T  # LANNS021: matmul without preferred_element_type
    o_ref[...] = (w + idx[None, :] + order[None, :]).astype(jnp.float32)


def bad_launcher(x, block=128):
    # LANNS024: no divisibility assert before pallas_call
    n = x.shape[0]
    return pl.pallas_call(
        lambda x_ref, o_ref: bad_kernel(x_ref, o_ref, block=block),
        grid=(n // block,),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)

"""Clean twin of bad_kernel.py: same shapes, no findings."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def clean_kernel(x_ref, o_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)  # TPU-legal iota
    w = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = w + idx.astype(jnp.float32)


def clean_launcher(x, block=128):
    n = x.shape[0]
    assert n % block == 0, "corpus must tile the block size"
    return pl.pallas_call(
        lambda x_ref, o_ref: clean_kernel(x_ref, o_ref, block=block),
        grid=(n // block,),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)

"""Clean twin of bad_scalecheck.py — same code shapes, bounds respected."""

import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2

# lanns: dims[P<=4096, n_pad<=33_554_432, n<=200_000_000, d<=2048, k<=200]

_INT32_MAX = np.iinfo(np.int32).max


# int64 offsets, plus the overflow guard that refines P * n_pad below the
# int32 line for the branch that narrows.
def clean_offsets(P, n_pad):  # lanns: hotpath
    off = P * n_pad
    if off > _INT32_MAX:
        raise OverflowError(off)
    return np.full((P,), off, np.int32)


# explicit fp32 scales: no promotion anywhere on the product.
def clean_promotion(x, d):  # lanns: hotpath
    scale = np.zeros((d,), np.float32)
    return x.astype(np.float32) * scale


# rows stay int64 end to end — the slot is sized for the values it holds.
def clean_store(n, n_pad):  # lanns: hotpath
    out = np.zeros((16,), np.int64)
    rows = np.arange(n) + n_pad
    out[:] = rows[:16]
    return out


# the device buffer is shaped on the pow2 grid: trace count stays
# logarithmic in the corpus size.
def clean_buckets(q, n):  # lanns: hotpath
    pad = jnp.zeros((next_pow2(n), 8), jnp.float32)
    return pad


# 12.5M x 512 int8 codes are ~6 GiB — inside the declared device budget.
def clean_budget(q8_rows):  # lanns: budget[device<=8GiB]
    m_pad = 12_500_000
    dim = 512
    return jnp.zeros((m_pad, dim), jnp.int8)

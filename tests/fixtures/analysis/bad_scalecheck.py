"""Intentionally-bad scale/dtype snippets — one LANNS03x rule per block.

Paired with clean_scalecheck.py (same shapes of code, bounds respected);
tests/test_scalecheck.py asserts every rule fires here and none fire there.
"""

import jax.numpy as jnp
import numpy as np

# lanns: dims[P<=4096, n_pad<=33_554_432, n<=200_000_000, d<=2048, k<=200]


# LANNS030: P * n_pad reaches 1.37e11 at the declared bounds — the int32
# fill value wraps (the exact pre-fix core/plan.py bug shape).
def bad_offsets(P, n_pad):  # lanns: hotpath
    return np.full((P,), P * n_pad, np.int32)


# LANNS031: np.zeros defaults to float64; multiplying the fp32 corpus by it
# silently promotes the whole hot-path product to float64.
def bad_promotion(x, d):  # lanns: hotpath
    scale = np.zeros((d,))
    return x.astype(np.float32) * scale


# LANNS032: np.arange yields int64 rows; scattering them into an int32 slot
# narrows values that reach n - 1 + n_pad > 2^31 at the declared bounds.
def bad_store(n, n_pad):  # lanns: hotpath
    out = np.zeros((16,), np.int32)
    rows = np.arange(n) + n_pad
    out[:] = rows[:16]
    return out


# LANNS033: a device buffer shaped by a raw declared dim — every distinct
# corpus size compiles a fresh trace (no pow2/quarter-pow2 bucketing).
def bad_buckets(q, n):  # lanns: hotpath
    pad = jnp.zeros((n, 8), jnp.float32)
    return pad


# LANNS034: 33.5M x 2048 fp32 rows are 256 GiB resident — two orders over
# the declared single-device budget.
def bad_budget(n_pad, d):  # lanns: budget[device<=8GiB]
    return jnp.zeros((n_pad, d), jnp.float32)

"""Intentionally-broken fixture: trips LANNS001-006 (one per function)."""
import numpy as np
import jax
import jax.numpy as jnp


# lanns: hotpath
def hot_item_sync(x):
    total = jnp.sum(x)
    return total.item()  # LANNS001


# lanns: hotpath
def hot_float_cast(x):
    s = jnp.sum(x)
    return float(s)  # LANNS002


# lanns: hotpath
def hot_asarray_sync(x):
    d = jnp.sqrt(x)
    return np.asarray(d)  # LANNS003


# lanns: hotpath
def hot_loop_dispatch(parts):
    out = []
    for p in parts:
        out.append(jnp.sum(p))  # LANNS004
    return out


@jax.jit
def jit_dynamic_shape(x, n):
    return jnp.zeros((n, x.shape[1]))  # LANNS005: n not static


# lanns: hotpath
def hot_unordered_feed(parts):
    rows = []
    for key, val in parts.items():  # LANNS006: dict order feeds arrays
        rows.append(np.asarray(val))
    return np.stack(rows)

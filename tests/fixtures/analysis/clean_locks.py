"""Clean twins of bad_locks.py: same shapes, no findings."""
import threading


class Worker:
    _GUARDED_BY = {"stats": "_lock", "queue": "_lock"}
    _LOCK_ORDER = ("_lock", "_stats_lock")

    def __init__(self):
        self._lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.stats = {}
        self.queue = []

    def guarded_touch(self):
        with self._lock:
            self.stats["n"] = 1

    def snapshot_then_block(self):
        with self._lock:
            n = len(self.queue)
        return n  # lock released before anything slow runs

    def declared_order(self):
        with self._lock:
            with self._stats_lock:  # matches _LOCK_ORDER
                return len(self.queue)

    # lanns: holds[_lock]
    def _drain_locked(self):
        self.queue.clear()  # caller holds _lock (see directive)


class Request:
    _PUBLISHED_FIELDS = ("result", "error")

    def __init__(self):
        self.event = threading.Event()
        self.result = None
        self.error = None


def publish_safe(req, value):
    req.result = value  # publish BEFORE waking the waiter
    req.event.set()

"""Clean twins of bad_tracelint.py: same shapes, no findings."""
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp


# lanns: hotpath
def hot_no_sync(x):
    total = jnp.sum(x)
    return total  # stays on device: caller decides when to sync


# lanns: hotpath
def hot_host_cast(x):
    s = np.sum(np.asarray(x, np.float32))  # host value in, host value out
    return float(s)


# lanns: hotpath
def hot_batched_dispatch(parts):
    stacked = jnp.stack(parts)  # ONE dispatch outside any loop
    return jnp.sum(stacked, axis=0)


@partial(jax.jit, static_argnames=("n",))
def jit_static_shape(x, n):
    return jnp.zeros((n, x.shape[1]))  # n static: one trace per bucket


# lanns: hotpath
def hot_sorted_feed(parts):
    rows = []
    for key, val in sorted(parts.items()):  # deterministic order
        rows.append(np.asarray(val))
    return np.stack(rows)

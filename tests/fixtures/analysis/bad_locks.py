"""Intentionally-broken fixture: trips LANNS010-013."""
import threading
import time


class Worker:
    _GUARDED_BY = {"stats": "_lock", "queue": "_lock"}
    _LOCK_ORDER = ("_lock", "_stats_lock")

    def __init__(self):
        self._lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.stats = {}
        self.queue = []

    def unguarded_touch(self):
        self.stats["n"] = 1  # LANNS010: no lock held

    def blocking_under_lock(self):
        with self._lock:
            time.sleep(0.1)  # LANNS011
            return len(self.queue)

    def inverted_order(self):
        with self._stats_lock:
            with self._lock:  # LANNS012: _lock ranks BEFORE _stats_lock
                return len(self.queue)


class Request:
    _PUBLISHED_FIELDS = ("result", "error")

    def __init__(self):
        self.event = threading.Event()
        self.result = None
        self.error = None


def publish_racy(req, value):
    req.event.set()
    req.result = value  # LANNS013: assigned after the waiter may wake

"""AnnFrontend micro-batching semantics (deterministic via injected clock)."""

import numpy as np
import pytest

from repro.core import LannsConfig, LannsIndex
from repro.data.synthetic import clustered_vectors
from repro.serve.engine import AnnFrontend


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def index_and_queries():
    data = clustered_vectors(1500, 16, n_clusters=16, seed=0)
    queries = clustered_vectors(40, 16, n_clusters=16, seed=1)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="scan")
    return LannsIndex(cfg).build(data), queries


def test_no_flush_before_deadline_or_max_batch(index_and_queries):
    idx, queries = index_and_queries
    clock = FakeClock()
    fe = AnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=2.0, clock=clock)
    for q in queries[:3]:
        fe.submit(q)
    assert fe.step() == []
    assert len(fe.pending) == 3


def test_flush_at_max_batch(index_and_queries):
    idx, queries = index_and_queries
    clock = FakeClock()
    fe = AnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=1e9, clock=clock)
    reqs = [fe.submit(q) for q in queries[:17]]
    done = fe.step()
    # two full batches fire; one submission stays pending
    assert len(done) == 16
    assert fe.stats["full_batches"] == 2
    assert len(fe.pending) == 1
    assert all(r.done for r in reqs[:16]) and not reqs[16].done


def test_flush_at_deadline(index_and_queries):
    idx, queries = index_and_queries
    clock = FakeClock()
    fe = AnnFrontend(idx, topk=5, max_batch=64, max_wait_ms=2.0, clock=clock)
    req = fe.submit(queries[0])
    clock.advance(0.001)
    assert fe.step() == []
    clock.advance(0.0015)  # oldest has now waited 2.5ms >= 2ms
    done = fe.step()
    assert done == [req] and req.done
    assert fe.stats["deadline_batches"] == 1


def test_results_match_direct_query(index_and_queries):
    idx, queries = index_and_queries
    clock = FakeClock()
    fe = AnnFrontend(idx, topk=10, max_batch=16, max_wait_ms=1e9, clock=clock)
    reqs = [fe.submit(q) for q in queries[:16]]
    fe.step()
    want_d, want_i = idx.query(queries[:16], 10)
    got_d = np.stack([r.dists for r in reqs])
    got_i = np.stack([r.ids for r in reqs])
    assert np.array_equal(got_i, np.asarray(want_i))
    assert np.allclose(got_d, np.asarray(want_d), equal_nan=True)


def test_collect_stats_surfaces_routing(index_and_queries):
    idx, queries = index_and_queries
    clock = FakeClock()
    fe = AnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=1e9, clock=clock,
                     collect_stats=True)
    for q in queries[:8]:
        fe.submit(q)
    done = fe.step()
    assert len(done) == 8
    assert fe.last_query_stats is not None
    assert fe.last_query_stats["per_shard_topk"] <= 5
    assert "beam_traces" in fe.last_query_stats
    assert 1.0 <= fe.mean_segments_visited <= idx.config.num_segments


def test_flush_drains_everything(index_and_queries):
    idx, queries = index_and_queries
    clock = FakeClock()
    fe = AnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=1e9, clock=clock)
    reqs = [fe.submit(q) for q in queries[:5]]
    done = fe.flush()
    assert len(done) == 5 and all(r.done for r in reqs)
    assert fe.pending == []
    assert fe.stats["forced_batches"] == 1
    assert fe.stats["completed"] == 5
    assert fe.mean_batch_size == 5.0

"""Persistence round-trips: save→load→query == build→query for every metric
and engine, including the mips manifest fix and the resume_dir path."""

import numpy as np
import pytest

from repro.core import LannsConfig, LannsIndex
from repro.data.synthetic import clustered_vectors


@pytest.fixture(scope="module")
def small_world():
    data = clustered_vectors(1200, 16, n_clusters=16, seed=7)
    queries = clustered_vectors(32, 16, n_clusters=16, seed=8)
    return data, queries


@pytest.mark.parametrize("engine", ["scan", "hnsw"])
@pytest.mark.parametrize("metric", ["l2", "ip", "cos", "mips"])
def test_save_load_query_roundtrip(tmp_path, small_world, metric, engine):
    data, queries = small_world
    cfg = LannsConfig(
        num_shards=2, num_segments=2, segmenter="rh", engine=engine,
        metric=metric, hnsw_m=8, ef_construction=40, ef_search=40,
    )
    idx = LannsIndex(cfg).build(data)
    d1, i1 = idx.query(queries, 10)
    root = str(tmp_path / f"{metric}_{engine}")
    idx.save(root)
    idx2 = LannsIndex.load(root)
    d2, i2 = idx2.query(queries, 10)
    assert np.array_equal(i1, i2)
    assert np.allclose(d1, d2, rtol=1e-6, equal_nan=True)


def test_mips_load_restores_m2(tmp_path, small_world):
    """Regression: save() used to drop _mips_M2, so query() on a loaded
    metric='mips' index raised AttributeError."""
    data, queries = small_world
    cfg = LannsConfig(num_shards=1, num_segments=2, segmenter="rh",
                      engine="scan", metric="mips")
    idx = LannsIndex(cfg).build(data)
    root = str(tmp_path / "mips")
    idx.save(root)
    idx2 = LannsIndex.load(root)
    assert idx2._mips_M2 == pytest.approx(idx._mips_M2)
    d, i = idx2.query(queries, 5)
    assert (i >= 0).all()


def test_mips_query_without_build_raises_cleanly(small_world):
    _, queries = small_world
    cfg = LannsConfig(num_shards=1, num_segments=2, segmenter="rh",
                      engine="scan", metric="mips")
    idx = LannsIndex(cfg)
    idx.partitioner._fitted = True  # skip fit; the mips check runs first
    with pytest.raises(RuntimeError, match="mips"):
        idx.query(queries, 5)


def test_legacy_ragged_artifact_loads(tmp_path, small_world):
    """Pre-stacked artifacts stored ragged per-level lists (level_nodes /
    level_adj / level_loc); loading one must rebuild the (L, n, M) stack and
    answer queries identically."""
    data, queries = small_world
    cfg = LannsConfig(num_shards=1, num_segments=2, segmenter="rh",
                      engine="hnsw", hnsw_m=8, ef_construction=40,
                      ef_search=40)
    idx = LannsIndex(cfg).build(data)
    d1, i1 = idx.query(queries, 10)
    root = str(tmp_path / "legacy")
    for (s, g), part in idx.partitions.items():
        fr = part.frozen
        payload = {"kind": "hnsw", "vectors": fr.vectors, "levels": fr.levels,
                   "adj0": fr.adj0, "entry": fr.entry, "keys": fr.keys}
        level_nodes, level_adj, level_loc = [], [], []
        for l in range(fr.num_upper_levels):
            nodes = np.nonzero(fr.levels >= l + 1)[0].astype(np.int32)
            loc = np.full(fr.size, -1, np.int32)
            loc[nodes] = np.arange(len(nodes), dtype=np.int32)
            level_nodes.append(nodes)
            level_adj.append(fr.upper_adj[l][nodes])
            level_loc.append(loc)
        payload.update(level_nodes=level_nodes, level_adj=level_adj,
                       level_loc=level_loc)
        idx._save_partition(root, s, g, payload)
    idx2 = LannsIndex(cfg)
    idx2.partitioner = idx.partitioner
    for (s, g) in idx.partitions:
        idx2.partitions[(s, g)] = idx2._load_partition(root, s, g)
    d2, i2 = idx2.query(queries, 10)
    assert np.array_equal(i1, i2)
    assert np.allclose(d1, d2, rtol=1e-6, equal_nan=True)


@pytest.mark.parametrize("metric", ["l2", "ip", "cos", "mips"])
def test_quantized_save_load_query_roundtrip(tmp_path, small_world, metric):
    """v2 artifacts carry the int8 payload (codes/scales/norms2) next to the
    fp32 re-rank store; load -> query must match build -> query exactly."""
    data, queries = small_world
    cfg = LannsConfig(
        num_shards=2, num_segments=2, segmenter="rh", engine="scan",
        metric=metric, quantized="q8",
    )
    idx = LannsIndex(cfg).build(data)
    d1, i1 = idx.query(queries, 10)
    root = str(tmp_path / f"q8_{metric}")
    idx.save(root)
    import json
    import os

    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format_version"] == 2
    assert manifest["config"]["quantized"] == "q8"
    idx2 = LannsIndex.load(root)
    # the quantized payload is loaded, not re-derived
    part = next(p for p in idx2.partitions.values() if p.size > 0)
    assert part.q8 is not None and part.q8.codes.dtype == np.int8
    d2, i2 = idx2.query(queries, 10)
    assert np.array_equal(i1, i2)
    assert np.allclose(d1, d2, rtol=1e-6, equal_nan=True)


def test_legacy_fp32_artifact_upgrades_to_q8(tmp_path, small_world):
    """A v1 (pre-quantization) artifact loaded under a quantized config
    quantizes on load — deterministically, so results match a fresh q8
    build bit-for-bit."""
    data, queries = small_world
    import json
    import os

    cfg_fp = LannsConfig(num_shards=1, num_segments=2, segmenter="rh",
                         engine="scan")
    idx_fp = LannsIndex(cfg_fp).build(data)
    root = str(tmp_path / "legacy_fp32")
    idx_fp.save(root)
    # rewrite the manifest the way an old writer + new config would look:
    # no format_version, config without the quantized knobs -> turn q8 on
    mpath = os.path.join(root, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["format_version"]
    for key in ("quantized", "rerank_factor", "rerank_store"):
        manifest["config"].pop(key, None)
    manifest["config"]["quantized"] = "q8"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    idx_q8 = LannsIndex.load(root)
    assert idx_q8.config.quantized == "q8"
    cfg_q8 = LannsConfig(num_shards=1, num_segments=2, segmenter="rh",
                         engine="scan", quantized="q8")
    idx_fresh = LannsIndex(cfg_q8).build(data)
    d1, i1 = idx_q8.query(queries, 10)
    d2, i2 = idx_fresh.query(queries, 10)
    assert np.array_equal(i1, i2)
    assert np.allclose(d1, d2, rtol=1e-6, equal_nan=True)


def test_newer_format_version_rejected(tmp_path, small_world):
    data, _ = small_world
    cfg = LannsConfig(num_shards=1, num_segments=2, segmenter="rh",
                      engine="scan")
    idx = LannsIndex(cfg).build(data[:200])
    root = str(tmp_path / "future")
    idx.save(root)
    import json
    import os

    mpath = os.path.join(root, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format_version"] = 99
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="format_version"):
        LannsIndex.load(root)


@pytest.mark.parametrize("engine", ["scan", "hnsw"])
def test_resume_dir_roundtrip(tmp_path, small_world, engine):
    """A build checkpointed into resume_dir resumes to identical results."""
    data, queries = small_world
    cfg = LannsConfig(
        num_shards=1, num_segments=4, segmenter="rh", engine=engine,
        hnsw_m=8, ef_construction=40, ef_search=40,
    )
    rdir = str(tmp_path / "resume")
    idx = LannsIndex(cfg).build(data, resume_dir=rdir)
    d1, i1 = idx.query(queries, 10)
    # second build resumes entirely from persisted partitions
    idx2 = LannsIndex(cfg)
    idx2.fit(data)
    idx2.build(data, resume_dir=rdir)
    d2, i2 = idx2.query(queries, 10)
    assert np.array_equal(i1, i2)
    assert np.allclose(d1, d2, rtol=1e-6, equal_nan=True)

"""repro.analysis: rule fixtures, suppression semantics, CLI exit codes,
and the runtime lock-order/GuardedDict instrumentation.

Static checks run on the intentionally-bad / clean-twin snippet pairs in
tests/fixtures/analysis/ (excluded from ruff and from the repo-wide
``--strict`` CI gate, which covers src/ only via the package default
paths).  Runtime checks drive ``InstrumentedLock``/``GuardedDict`` through
known-bad orderings and a short seeded ``race_stress`` burst.
"""

import subprocess
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.analysis import RULES, analyze_file, analyze_paths
from repro.analysis.runtime import (
    GuardedDict,
    InstrumentedLock,
    LockOrderRegistry,
    race_stress,
)

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"


def codes(findings, *, include_suppressed=False):
    return sorted(
        f.code for f in findings if include_suppressed or not f.suppressed
    )


# ---------------------------------------------------------------------------
# trace-stability lint (LANNS001-006)
# ---------------------------------------------------------------------------


def test_bad_tracelint_trips_every_rule():
    got = codes(analyze_file(str(FIXTURES / "bad_tracelint.py")))
    for code in ("LANNS001", "LANNS002", "LANNS003", "LANNS004",
                 "LANNS005", "LANNS006"):
        assert code in got, (code, got)


def test_clean_tracelint_twin_is_silent():
    assert codes(analyze_file(str(FIXTURES / "clean_tracelint.py"))) == []


# ---------------------------------------------------------------------------
# lock discipline (LANNS010-013)
# ---------------------------------------------------------------------------


def test_bad_locks_trips_every_rule():
    got = codes(analyze_file(str(FIXTURES / "bad_locks.py")))
    for code in ("LANNS010", "LANNS011", "LANNS012", "LANNS013"):
        assert code in got, (code, got)


def test_clean_locks_twin_is_silent():
    assert codes(analyze_file(str(FIXTURES / "clean_locks.py"))) == []


# ---------------------------------------------------------------------------
# kernel constraints (LANNS020-024)
# ---------------------------------------------------------------------------


def test_bad_kernel_trips_every_rule():
    got = codes(analyze_file(str(FIXTURES / "kernels" / "bad_kernel.py")))
    for code in ("LANNS020", "LANNS021", "LANNS022", "LANNS023", "LANNS024"):
        assert code in got, (code, got)


def test_clean_kernel_twin_is_silent():
    assert codes(
        analyze_file(str(FIXTURES / "kernels" / "clean_kernel.py"))
    ) == []


def test_kernel_rules_only_apply_under_kernels_dir():
    """The same f64/arange/sort code OUTSIDE a kernels/ dir is not flagged."""
    got = codes(analyze_file(str(FIXTURES / "bad_tracelint.py")))
    assert not any(c.startswith("LANNS02") for c in got)


# ---------------------------------------------------------------------------
# suppression semantics
# ---------------------------------------------------------------------------


def test_justified_noqa_suppresses_and_is_counted():
    findings = analyze_file(str(FIXTURES / "suppressed.py"))
    sup = [f for f in findings if f.suppressed]
    assert len(sup) == 1 and sup[0].code == "LANNS003"
    assert "designed sync" in sup[0].justification


def test_bare_noqa_is_lanns000_and_does_not_suppress():
    findings = analyze_file(str(FIXTURES / "suppressed.py"))
    active = [f for f in findings if not f.suppressed]
    got = codes(active)
    assert "LANNS000" in got
    # the unjustified and wrong-code noqa lines both stay ACTIVE findings
    assert got.count("LANNS003") == 2


def test_every_rule_has_registry_entry():
    findings = []
    for p in ("bad_tracelint.py", "bad_locks.py", "suppressed.py",
              "kernels/bad_kernel.py"):
        findings += analyze_file(str(FIXTURES / p))
    for f in findings:
        assert f.code in RULES, f.code


# ---------------------------------------------------------------------------
# CLI exit codes (the CI gate)
# ---------------------------------------------------------------------------


def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True,
    )


def test_cli_strict_nonzero_on_violation_fixture():
    r = _cli("--strict", str(FIXTURES / "bad_tracelint.py"))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "LANNS001" in r.stdout


def test_cli_strict_zero_on_clean_fixture():
    r = _cli("--strict", str(FIXTURES / "clean_tracelint.py"),
             str(FIXTURES / "clean_locks.py"))
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_non_strict_always_zero():
    r = _cli(str(FIXTURES / "bad_tracelint.py"))
    assert r.returncode == 0
    assert "LANNS001" in r.stdout


def test_cli_strict_zero_on_repo():
    """The repo itself must stay analyzer-clean: every intentional
    violation carries a justified suppression (acceptance criterion)."""
    r = _cli("--strict")
    assert r.returncode == 0, r.stdout + r.stderr


def test_analyze_paths_walks_directories():
    findings = analyze_paths([str(FIXTURES)])
    got = codes(findings)
    assert "LANNS001" in got and "LANNS010" in got and "LANNS020" in got


# ---------------------------------------------------------------------------
# runtime: lock-order registry + guarded dict
# ---------------------------------------------------------------------------


def test_lock_order_cycle_detected():
    """Two locks acquired in opposite orders on two threads -> cycle, even
    though this schedule never deadlocked."""
    reg = LockOrderRegistry()
    a = InstrumentedLock("a", reg)
    b = InstrumentedLock("b", reg)

    def ab():
        with a, b:
            pass

    def ba():
        with b, a:
            pass

    for fn in (ab, ba):
        t = threading.Thread(target=fn)
        t.start()
        t.join()
    cyc = reg.cycles()
    assert cyc, reg.edges
    with pytest.raises(AssertionError, match="cycle"):
        reg.assert_acyclic()


def test_lock_order_consistent_is_acyclic():
    reg = LockOrderRegistry()
    a = InstrumentedLock("a", reg)
    b = InstrumentedLock("b", reg)
    for _ in range(3):
        with a, b:
            pass
    assert reg.cycles() == []
    reg.assert_acyclic()


def test_reentrant_acquire_records_no_self_edge():
    reg = LockOrderRegistry()
    a = InstrumentedLock("a", reg)
    with a, a:
        pass
    assert ("a", "a") not in reg.edges


def test_guarded_dict_flags_unlocked_mutation():
    reg = LockOrderRegistry()
    lock = InstrumentedLock("m", reg)
    d = GuardedDict({"n": 0}, lock, "stats")
    d["n"] = 1  # unlocked: recorded, not raised (stress keeps running)
    assert len(d.violations) == 1 and "without holding m" in d.violations[0]
    with lock:
        d["n"] = 2
    assert len(d.violations) == 1


def test_instrumented_lock_backs_condition():
    reg = LockOrderRegistry()
    cond = threading.Condition(InstrumentedLock("c", reg))
    hit = []

    def waiter():
        with cond:
            hit.append(cond.wait(timeout=5.0))

    t = threading.Thread(target=waiter)
    t.start()
    while not cond._waiters:  # let the waiter park (releases the lock)
        time.sleep(0.005)
    with cond:
        cond.notify()
    t.join(timeout=5.0)
    assert hit == [True]


# ---------------------------------------------------------------------------
# race stress (short burst; the 30s version runs nightly in CI)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def stress_index():
    from repro.core import LannsConfig, LannsIndex
    from repro.data.synthetic import clustered_vectors

    data = clustered_vectors(400, 8, n_clusters=8, seed=11)
    cfg = LannsConfig(num_shards=1, num_segments=2, segmenter="apd",
                      engine="scan")
    return LannsIndex(cfg).build(data)


def test_race_stress_short_burst_clean(stress_index):
    report = race_stress(threads=4, duration_s=2.0, seed=0,
                         index=stress_index)
    assert report.ok, report.render()
    assert report.cycles_run >= 1
    assert report.submitted > 0 and report.completed > 0


def test_race_stress_is_seed_deterministic_in_structure(stress_index):
    """Same seed, same thread count: the report stays clean and the
    invariant checks hold on every cycle (timing varies, correctness
    must not)."""
    for _ in range(2):
        report = race_stress(threads=2, duration_s=1.0, seed=7,
                             index=stress_index)
        assert report.ok, report.render()

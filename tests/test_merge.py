"""perShardTopK (Eq. 5-6) + two-level merge correctness, with hypothesis."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    merge_topk,
    merge_topk_np,
    merge_topk_scatter,
    merge_topk_vec,
    per_shard_topk,
    two_level_merge_np,
)
from repro.core.merge import _probit


def test_probit_matches_scipy():
    norm = pytest.importorskip("scipy.stats").norm

    for q in (0.01, 0.1, 0.5, 0.9, 0.975, 0.999):
        assert _probit(q) == pytest.approx(norm.ppf(q), abs=1e-6)


def test_per_shard_topk_known_values():
    # S=1 must be exactly topk (cI >= 1)
    assert per_shard_topk(100, 1) == 100
    # paper regime: k=100, many shards => big trim
    v32 = per_shard_topk(100, 32, 0.95)
    assert 5 <= v32 <= 12
    # monotone: more shards => smaller per-shard k
    vals = [per_shard_topk(100, s, 0.95) for s in (2, 4, 8, 16, 32)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
    # monotone in confidence
    assert per_shard_topk(100, 16, 0.99) >= per_shard_topk(100, 16, 0.9)
    # never exceeds topk
    assert all(per_shard_topk(10, s) <= 10 for s in range(1, 40))


def test_per_shard_topk_statistical_validity():
    """Empirical check of the Normal Approximation Interval: Eq. (5) bounds
    the count of global top-k items in ONE uniform shard at confidence p —
    i.e. the PER-SHARD overflow rate is <= 1-p.  (The max over S shards
    overflows more often — multiple testing — which is why the paper reports
    a recall of ~p rather than a hard guarantee.)"""
    rng = np.random.default_rng(0)
    k, S, p = 100, 16, 0.95
    pstk = per_shard_topk(k, S, p)
    overflows = 0
    trials = 400
    for _ in range(trials):
        shard = rng.integers(0, S, size=k)  # shard of each top-k item
        counts = np.bincount(shard, minlength=S)
        overflows += int((counts > pstk).sum())
    per_shard_rate = overflows / (trials * S)
    assert per_shard_rate < (1 - p) * 1.5, per_shard_rate


def test_merge_topk_np_dedups_and_sorts():
    d = np.array([[3.0, 1.0, 2.0, 1.0, np.inf]])
    i = np.array([[7, 3, 9, 3, -1]])
    od, oi = merge_topk_np(d, i, 3)
    assert oi.tolist() == [[3, 9, 7]]
    assert od.tolist() == [[1.0, 2.0, 3.0]]


def test_merge_topk_jit_matches_np(rng):
    d = rng.standard_normal((6, 40)).astype(np.float32)
    i = rng.integers(0, 25, (6, 40)).astype(np.int32)
    od, oi = merge_topk_np(d, i.astype(np.int64), 10)
    jd, ji = merge_topk(d, i, 10)
    assert np.allclose(od, np.asarray(jd), rtol=1e-6)
    assert np.array_equal(oi, np.asarray(ji).astype(np.int64))


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=5), st.integers(min_value=1, max_value=4),
       st.integers(min_value=2, max_value=12))
def test_property_merge_equals_global_topk(S, m, k):
    """When perShardTopK == k (confidence 1-ish via direct k), the two-level
    merge must equal the global top-k over all candidates."""
    rng = np.random.default_rng(S * 100 + m * 10 + k)
    B, c = 3, k + 4
    # unique ids so dedup can't collapse distinct entries
    ids = rng.permutation(S * m * c * B).reshape(S, m, B, c).astype(np.int64)
    dists = rng.standard_normal((S, m, B, c)).astype(np.float32)
    # force pstk == k by confidence=1-1e-12 ... instead use S=1-style merge:
    flat_d = np.moveaxis(dists, 2, 0).reshape(B, -1)
    flat_i = np.moveaxis(ids, 2, 0).reshape(B, -1)
    want_d, want_i = merge_topk_np(flat_d, flat_i, k)
    # two_level_merge with pstk=k: emulate by merging shards with k directly
    shard_d = np.empty((S, B, k), np.float32)
    shard_i = np.empty((S, B, k), np.int64)
    for s in range(S):
        sd = np.moveaxis(dists[s], 1, 0).reshape(B, -1)
        si = np.moveaxis(ids[s], 1, 0).reshape(B, -1)
        shard_d[s], shard_i[s] = merge_topk_np(sd, si, k)
    got_d, got_i = merge_topk_np(
        np.moveaxis(shard_d, 0, 1).reshape(B, -1),
        np.moveaxis(shard_i, 0, 1).reshape(B, -1),
        k,
    )
    assert np.allclose(want_d, got_d)
    assert np.array_equal(want_i, got_i)


@settings(max_examples=60, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**32 - 1),
    st.integers(min_value=1, max_value=48),
    st.integers(min_value=1, max_value=24),
    st.floats(min_value=0.0, max_value=0.6),
    st.floats(min_value=0.0, max_value=0.4),
)
def test_property_merge_vec_parity(seed, C, k, dup_frac, inf_frac):
    """merge_topk_vec == merge_topk_np on adversarial candidate lists:
    duplicate ids (small id range), -1 ids, ±inf distances, tied dists."""
    rng = np.random.default_rng(seed)
    R = 4
    id_hi = max(int(C * (1.0 - dup_frac)), 1)
    ids = rng.integers(-1, id_hi, (R, C)).astype(np.int64)
    # quantized dists force ties; sprinkle ±inf
    d = (rng.integers(0, 8, (R, C)) / 4.0).astype(np.float32)
    d[rng.random((R, C)) < inf_frac] = np.inf
    d[rng.random((R, C)) < inf_frac / 2] = -np.inf
    rd, ri = merge_topk_np(d, ids, k)
    vd, vi = merge_topk_vec(d, ids, k)
    assert np.array_equal(ri, vi)
    assert np.array_equal(rd, vd)


@settings(max_examples=60, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**32 - 1),
    st.integers(min_value=1, max_value=48),
    st.integers(min_value=1, max_value=24),
    st.floats(min_value=0.0, max_value=0.6),
    st.floats(min_value=0.0, max_value=0.4),
)
def test_property_merge_jit_parity(seed, C, k, dup_frac, inf_frac):
    """The jitted two-lexsort merge_topk == merge_topk_np on the same
    adversarial candidate lists as the vec parity test: duplicate ids,
    -1 ids, ±inf distances, tied distances, and k > C."""
    rng = np.random.default_rng(seed)
    R = 4
    id_hi = max(int(C * (1.0 - dup_frac)), 1)
    ids = rng.integers(-1, id_hi, (R, C)).astype(np.int64)
    d = (rng.integers(0, 8, (R, C)) / 4.0).astype(np.float32)
    d[rng.random((R, C)) < inf_frac] = np.inf
    d[rng.random((R, C)) < inf_frac / 2] = -np.inf
    rd, ri = merge_topk_np(d, ids, k)
    jd, ji = merge_topk(d, ids, k)
    assert np.array_equal(ri, np.asarray(ji))
    assert np.array_equal(rd, np.asarray(jd))


def test_merge_scatter_baseline_still_matches():
    """The retired scatter-min form stays a valid oracle on distinct dists
    (it is the benchmark baseline for the lexsort port)."""
    rng = np.random.default_rng(5)
    d = rng.standard_normal((6, 40)).astype(np.float32)
    i = rng.integers(0, 25, (6, 40)).astype(np.int32)
    od, oi = merge_topk_np(d, i.astype(np.int64), 10)
    sd, si = merge_topk_scatter(d, i, 10)
    assert np.allclose(od, np.asarray(sd), rtol=1e-6)
    assert np.array_equal(oi, np.asarray(si).astype(np.int64))


def test_two_level_merge_respects_pstk():
    rng = np.random.default_rng(1)
    S, m, B, c, k = 4, 2, 5, 30, 10
    dists = rng.standard_normal((S, m, B, c)).astype(np.float32)
    ids = rng.permutation(S * m * B * c).reshape(S, m, B, c).astype(np.int64)
    od, oi = two_level_merge_np(dists, ids, k, confidence=0.95)
    assert od.shape == (B, k)
    assert np.all(np.diff(od, axis=1) >= 0)
    # recall vs untrimmed merge is high but can be < 1 (that's the trade)
    fd, fi = merge_topk_np(
        np.moveaxis(dists, 2, 0).reshape(B, -1),
        np.moveaxis(ids, 2, 0).reshape(B, -1), k,
    )
    overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(oi, fi)
    ])
    assert overlap > 0.7

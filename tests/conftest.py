"""Test config.  NOTE: no XLA_FLAGS here — smoke tests must see ONE CPU
device (the dry-run sets its own 512-device flag in its own process)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

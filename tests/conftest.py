"""Test config.  NOTE: no XLA_FLAGS here — smoke tests must see ONE CPU
device (the dry-run sets its own 512-device flag in its own process)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def retrace_sentinel():
    """Snapshot of the serving-path jit compile caches (repro.analysis).

    Usage: warm the traces, ``sentinel.reset()``, run the serving workload,
    ``sentinel.assert_no_retrace(context)``.  Skips if this jax build hides
    the cache counters — the assertion would be vacuous, not green.
    """
    from repro.analysis import RetraceSentinel

    sentinel = RetraceSentinel()
    if not sentinel.available:
        pytest.skip("jit cache-size counters unavailable on this jax build")
    return sentinel

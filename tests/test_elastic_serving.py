"""Elasticity end-to-end: lose a host, replan, reload its shards, keep
serving with identical answers — the composition of elastic.ShardPlacement
with LannsIndex persistence that a real searcher fleet would run."""

import numpy as np

from repro.core import LannsConfig, LannsIndex, recall_at_k, brute_force_topk
from repro.data.synthetic import sift_like
from repro.train.elastic import ShardPlacement, StragglerMonitor, replan_on_failure


class SearcherFleet:
    """Minimal host simulator: hosts serve the shards the placement assigns;
    answers merge at the broker exactly like LannsIndex.query does."""

    def __init__(self, index: LannsIndex, placement: ShardPlacement):
        self.index = index
        self.placement = placement
        self.alive = set(range(placement.num_hosts))

    def kill(self, host: int):
        self.alive.discard(host)
        self.placement = replan_on_failure(self.placement, [host])

    def query(self, qs, topk):
        # every shard must be served by a live host or answers are partial
        for s in range(self.index.config.num_shards):
            assert self.placement.hosts_of(s) in self.alive
        return self.index.query(qs, topk)


def test_fleet_survives_host_loss(tmp_path):
    corpus, queries = sift_like(4000, 32, 64, seed=9)
    cfg = LannsConfig(num_shards=4, num_segments=2, segmenter="apd",
                      engine="scan")
    index = LannsIndex(cfg).build(corpus)
    index.save(str(tmp_path / "prod"))

    placement = ShardPlacement.initial(num_hosts=4, num_shards=4)
    fleet = SearcherFleet(index, placement)
    d0, i0 = fleet.query(queries, 10)

    # host 2 dies: its shard moves; artifacts reload from the store
    fleet.kill(2)
    assert all(h != 2 for h in fleet.placement.assignment)
    reloaded = LannsIndex.load(str(tmp_path / "prod"))
    fleet.index = reloaded  # surviving hosts reload the moved shards
    d1, i1 = fleet.query(queries, 10)
    assert np.array_equal(i0, i1), "answers must be identical after re-shard"

    # cascade: another host dies; still serving
    fleet.kill(0)
    d2, i2 = fleet.query(queries, 10)
    assert np.array_equal(i0, i2)

    td, ti = brute_force_topk(queries, corpus, 10)
    assert recall_at_k(i2, ti, 10) > 0.6


def test_straggler_duplication_is_consistent():
    """Speculatively duplicated shards return the same answers (idempotent
    reads), so racing the straggler is always safe."""
    corpus, queries = sift_like(2000, 16, 16, seed=11)
    cfg = LannsConfig(num_shards=4, num_segments=1, segmenter="rs",
                      engine="scan")
    index = LannsIndex(cfg).build(corpus)
    mon = StragglerMonitor(num_hosts=4, min_samples=2, ratio=1.4)
    for _ in range(3):
        for h, t in enumerate([1.0, 1.0, 1.0, 2.5]):
            mon.observe(h, t)
    placement = ShardPlacement.initial(4, 4)
    dup = mon.speculative_duplicates(placement)
    assert dup, "slow host's shards should be duplicated"
    # primary and speculative answers are identical by construction
    d1, i1 = index.query(queries, 5)
    d2, i2 = index.query(queries, 5)
    assert np.array_equal(i1, i2)

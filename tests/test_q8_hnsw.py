"""Quantized HNSW beam (engine='hnsw' x quantized='q8').

Candidate generation walks the graph over int8 codes; the shared exact
re-rank stage then re-scores the beam's candidates against the fp32
originals, so returned distances are EXACT — quantization can only affect
which candidates reach the re-rank, and at bench scales it costs ~nothing
(recall parity asserted below, the ISSUE's 0.01 acceptance bound with
margin).
"""

import numpy as np
import pytest

from repro.core import (
    LannsConfig,
    LannsIndex,
    brute_force_topk,
    recall_at_k,
)
from repro.data.synthetic import clustered_vectors

D = 24


def _cfg(**kw):
    base = {
        "num_shards": 1, "num_segments": 4, "segmenter": "apd",
        "engine": "hnsw", "hnsw_m": 8, "ef_construction": 60,
        "ef_search": 80, "alpha": 0.15,
    }
    base.update(kw)
    return LannsConfig(**base)


@pytest.fixture(scope="module")
def world():
    data = clustered_vectors(2500, D, n_clusters=32, seed=0)
    queries = clustered_vectors(64, D, n_clusters=32, seed=1)
    return data, queries


@pytest.fixture(scope="module")
def fp32_and_q8(world):
    data, _ = world
    idx_fp = LannsIndex(_cfg()).build(data)
    idx_q8 = LannsIndex(_cfg(quantized="q8")).build(data)
    return idx_fp, idx_q8


def test_recall_parity_vs_fp32_hnsw(world, fp32_and_q8):
    """The acceptance bound: recall@k within 0.01 of the fp32 beam, both
    against ground truth and relative to the fp32 results."""
    data, queries = world
    idx_fp, idx_q8 = fp32_and_q8
    td, ti = brute_force_topk(queries, data, 20)
    d_fp, i_fp = idx_fp.query(queries, 20)
    d_q8, i_q8 = idx_q8.query(queries, 20)
    r_fp = recall_at_k(i_fp, ti, 20)
    r_q8 = recall_at_k(i_q8, ti, 20)
    assert r_q8 >= r_fp - 0.01, (r_fp, r_q8)
    assert recall_at_k(i_q8, i_fp, 20) >= 0.99


def test_distances_are_exact(world, fp32_and_q8):
    """Re-ranked distances must be TRUE squared L2 distances to the
    returned ids — bit-comparable to the fp32 beam wherever ids agree."""
    data, queries = world
    idx_fp, idx_q8 = fp32_and_q8
    d_fp, i_fp = idx_fp.query(queries, 10)
    d_q8, i_q8 = idx_q8.query(queries, 10)
    valid = (i_q8 >= 0) & np.isfinite(d_q8)
    diff = data[np.clip(i_q8, 0, None)] - queries[:, None, :]
    true_d = np.einsum("bkd,bkd->bk", diff, diff)
    np.testing.assert_allclose(
        d_q8[valid], true_d[valid], rtol=1e-4, atol=1e-3
    )
    same = i_q8 == i_fp
    np.testing.assert_allclose(
        d_q8[same & valid], d_fp[same & valid], rtol=1e-4, atol=1e-3
    )


@pytest.mark.parametrize("metric", ["cos", "ip", "mips"])
def test_metrics_recall_parity(metric):
    data = clustered_vectors(1500, 16, n_clusters=16, seed=0)
    if metric == "mips":
        rng = np.random.default_rng(1)
        data = data * rng.uniform(0.5, 2.0, (len(data), 1)).astype(np.float32)
    queries = clustered_vectors(40, 16, n_clusters=16, seed=1)
    kw = {"metric": metric}
    i_res = {}
    for quant in ("none", "q8"):
        idx = LannsIndex(_cfg(quantized=quant, **kw)).build(data)
        _, i_res[quant] = idx.query(queries, 10)
    assert recall_at_k(i_res["q8"], i_res["none"], 10) >= 0.95, metric


def test_resident_codes_are_int8(fp32_and_q8):
    """The q8 stack's device corpus must be the int8 codes (the memory
    win), with norms2 riding along; the fp32 stack is never built."""
    _, idx_q8 = fp32_and_q8
    stack = idx_q8._hnsw_stack(quantized=True)
    assert stack["arrs"]["vectors"].dtype == np.int8
    assert stack["arrs"]["norms2"].dtype == np.float32
    assert idx_q8._stack.get(False) is None  # fp32 vectors never uploaded
    codes_b = stack["arrs"]["vectors"].nbytes + stack["arrs"]["norms2"].nbytes
    fp32_b = 4 * stack["arrs"]["vectors"].size
    assert codes_b < 0.5 * fp32_b


def test_trace_stability(world, fp32_and_q8):
    """Re-running seen batch windows must add no new flat-beam traces: lane
    counts pad to quarter-pow2 buckets, so the trace set is a function of
    the bucket grid, not of which queries arrive."""
    data, queries = world
    _, idx_q8 = fp32_and_q8
    windows = [(0, 16), (8, 24), (16, 32), (24, 40)]
    for lo, hi in windows:  # warm every window's lane bucket once
        idx_q8.query(queries[lo:hi], 10)
    _, _, s0 = idx_q8.query(queries[:16], 10, return_stats=True)
    for lo, hi in windows * 2:
        idx_q8.query(queries[lo:hi], 10)
    _, _, s1 = idx_q8.query(queries[:16], 10, return_stats=True)
    assert s1["beam_traces_flat"] == s0["beam_traces_flat"]


def test_mixed_knobs_on_q8_hnsw(world, fp32_and_q8):
    data, queries = world
    _, idx_q8 = fp32_and_q8
    tk = np.array([5, 10] * 8)
    ef = np.array([0, 96] * 8)
    d, i = idx_q8.query(queries[:16], tk, ef=ef)
    for tkv, efv in ((5, 0), (10, 96)):
        rows = np.nonzero((tk == tkv) & (ef == efv))[0]
        dd, ii = idx_q8.query(queries[rows], tkv, ef=(efv or None))
        assert np.array_equal(i[rows, :tkv], ii)
        assert np.array_equal(d[rows, :tkv], dd)


def test_save_load_roundtrip(tmp_path, world, fp32_and_q8):
    """Quantized hnsw artifacts persist (codes saved next to the graph) and
    reload bit-identically; the loaded index re-serves through the beam."""
    data, queries = world
    _, idx_q8 = fp32_and_q8
    d1, i1 = idx_q8.query(queries, 10)
    root = str(tmp_path / "q8_hnsw")
    idx_q8.save(root)
    idx2 = LannsIndex.load(root)
    assert idx2.config.quantized == "q8" and idx2.config.engine == "hnsw"
    assert all(
        p.q8 is not None for p in idx2.partitions.values() if p.size > 0
    )
    d2, i2 = idx2.query(queries, 10)
    assert np.array_equal(i1, i2)
    np.testing.assert_allclose(d1, d2, rtol=1e-6)


def test_empty_batch_and_stats(fp32_and_q8):
    _, idx_q8 = fp32_and_q8
    empty = np.zeros((0, D), np.float32)
    d, i, stats = idx_q8.query(empty, 7, return_stats=True)
    assert d.shape == (0, 7) and i.shape == (0, 7)
    assert stats["merge_path"] == "two_level"
    _, _, full = idx_q8.query(np.zeros((2, D), np.float32), 7,
                              return_stats=True)
    assert set(stats) == set(full)


def test_rerank_store_host_device_agree(world):
    data, queries = world
    small = data[:1200]
    idx_h = LannsIndex(_cfg(quantized="q8", rerank_store="host")).build(small)
    idx_d = LannsIndex(_cfg(quantized="q8", rerank_store="device")).build(
        small
    )
    dh, ih = idx_h.query(queries, 10)
    dd, id_ = idx_d.query(queries, 10)
    assert np.array_equal(ih, id_)
    np.testing.assert_allclose(dh, dd, rtol=1e-5, atol=1e-5)

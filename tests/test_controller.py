"""Closed-loop SLO controller: degrade determinism, retune policy, lifecycle.

The controller's acceptance contract has three legs, each tested here
deterministically (no wall-clock dependence — the frontend and controller
share an injectable fake clock):

* **degrade is pure policy over the mixed-knob batch path**: a request past
  its ``deadline_ms`` at batch formation gets the ladder rung for how many
  whole budgets it is late, on-time requests in the SAME formed batch keep
  their knobs, and the results are bit-identical to the equivalent
  hand-built per-request ``(topk, ef)`` batch;
* **a controller decision never compiles**: after ``warm_traces(knobs=
  ctrl.warm_knobs())``, controller-driven ef switches reuse existing
  traces (retrace-sentinel assertion);
* **bad budgets fail the SUBMITTER**: a negative/NaN ``deadline_ms``
  raises at ``submit()`` and never reaches the batcher thread (the PR 5
  validation contract extended to the new knob).
"""

import json
import math

import numpy as np
import pytest

from repro.core import LannsConfig, LannsIndex
from repro.core.brute_force import brute_force_topk
from repro.data.synthetic import clustered_vectors
from repro.obs import Telemetry
from repro.serve.controller import SLOController
from repro.serve.engine import AnnFrontend, AnnRequest, AsyncAnnFrontend
from repro.serve.loadgen import run_controller_ab, run_load_point

WAIT_S = 30.0
LADDER = (32, 16)
TOPK = 10


class FakeClock:
    """Deterministic clock shared by frontend + controller in these tests."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt_s: float) -> None:
        self.t += dt_s


@pytest.fixture(scope="module")
def hnsw_setup():
    """Single-segment HNSW index (ef actually matters; one segment keeps
    the routed lane counts a pure function of group sizes, so the
    zero-retrace assertion is deterministic), warmed for the degrade
    ladder."""
    data = clustered_vectors(2000, 16, n_clusters=16, seed=0)
    queries = clustered_vectors(48, 16, n_clusters=16, seed=1)
    cfg = LannsConfig(num_shards=1, num_segments=1, segmenter="apd",
                      engine="hnsw", hnsw_m=8, ef_construction=50,
                      ef_search=64)
    idx = LannsIndex(cfg).build(data)
    ctrl = SLOController(slo_ms=10.0, ef_ladder=LADDER)
    idx.warm_traces(8, TOPK, knobs=ctrl.warm_knobs(topk=TOPK))
    return idx, data, queries


@pytest.fixture(scope="module")
def scan_setup():
    data = clustered_vectors(1200, 16, n_clusters=8, seed=0)
    queries = clustered_vectors(32, 16, n_clusters=8, seed=1)
    cfg = LannsConfig(num_shards=1, num_segments=2, segmenter="apd",
                      engine="scan")
    idx = LannsIndex(cfg).build(data)
    idx.warm_traces(8, TOPK)
    return idx, queries


# ---------------------------------------------------------------------------
# deadline-aware degrade
# ---------------------------------------------------------------------------


def test_degrade_bit_identical_to_handbuilt_mixed_batch(hnsw_setup):
    """Fake-clock determinism: in one formed batch, the request past its
    deadline gets the ladder rung for its lateness, on-time requests keep
    their knobs, and results match the hand-built mixed-knob query bit for
    bit."""
    idx, _, queries = hnsw_setup
    clk = FakeClock()
    ctrl = SLOController(slo_ms=10.0, ef_ladder=LADDER, clock=clk)
    fe = AnnFrontend(idx, topk=TOPK, max_batch=8, max_wait_ms=1e9,
                     clock=clk, controller=ctrl)
    # t=0: 12 ms late at formation vs a 5 ms budget -> 2 whole budgets
    # elapsed -> rung 1 (ladder[1] == 16)
    r0 = fe.submit(queries[0], deadline_ms=5.0)
    clk.advance(4e-3)
    # t=4ms: 8 ms elapsed at formation, within its 20 ms budget
    r1 = fe.submit(queries[1], deadline_ms=20.0)
    # no explicit deadline: default budget mirrors slo_ms=10 -> on time
    r2 = fe.submit(queries[2])
    # already cheaper than any rung: a request's own ef is never RAISED
    r3 = fe.submit(queries[3], ef=8, deadline_ms=1.0)
    clk.advance(8e-3)  # formation at t=12ms
    fe.flush()
    assert [r.degraded for r in (r0, r1, r2, r3)] == [
        True, False, False, False
    ]
    assert r0.ef_used == LADDER[1]
    assert r1.ef_used is None and r2.ef_used is None  # index default ran
    assert r3.ef_used == 8
    assert ctrl.snapshot()["degraded"] == 1
    # bit-identity vs the equivalent hand-built per-request knob batch
    # (0 == index default in the executor's ef encoding)
    q = np.stack([queries[j] for j in range(4)])
    topk_arr = np.full(4, TOPK, np.int64)
    ef_arr = np.array([LADDER[1], 0, 0, 8], np.int64)
    d, i = idx.query(q, topk_arr, ef=ef_arr)
    d, i = np.asarray(d), np.asarray(i)
    for j, r in enumerate((r0, r1, r2, r3)):
        assert np.array_equal(r.ids, i[j])
        assert np.array_equal(r.dists, d[j])


def test_degrade_rung_deepens_with_lateness(hnsw_setup):
    """One rung per whole budget elapsed, clamped to the last rung."""
    idx, _, queries = hnsw_setup
    clk = FakeClock()
    ctrl = SLOController(slo_ms=1e6, ef_ladder=LADDER, clock=clk)
    fe = AnnFrontend(idx, topk=TOPK, max_batch=8, max_wait_ms=1e9,
                     clock=clk, controller=ctrl)
    r_rung0 = fe.submit(queries[0], deadline_ms=10.0)  # 1 budget late
    r_clamp = fe.submit(queries[1], deadline_ms=2.0)  # 7+ budgets late
    clk.advance(15e-3)
    fe.flush()
    assert r_rung0.ef_used == LADDER[0]
    assert r_clamp.ef_used == LADDER[-1]


def test_controller_ef_switch_never_retraces(hnsw_setup, retrace_sentinel):
    """After ``warm_traces(knobs=ctrl.warm_knobs())``, controller-driven
    ef switches (different degrade mixes, same group sizes) reuse existing
    traces — the 'controller never triggers a compile' contract."""
    idx, _, queries = hnsw_setup
    clk = FakeClock()
    ctrl = SLOController(slo_ms=10.0, ef_ladder=LADDER, clock=clk)
    fe = AnnFrontend(idx, topk=TOPK, max_batch=8, max_wait_ms=1e9,
                     clock=clk, controller=ctrl)

    def run_mixed(late: set) -> list:
        reqs = [
            fe.submit(
                queries[j],
                deadline_ms=1.0 if j in late else 1e6,
            )
            for j in range(8)
        ]
        clk.advance(3.5e-3)  # 3 whole budgets late -> deepest rung
        fe.flush()
        return reqs

    # first pass covers any residual best-effort-warming compiles for
    # these exact group sizes (2 degraded / 6 default)
    run_mixed({0, 3})
    retrace_sentinel.reset()
    reqs = run_mixed({2, 7})  # same sizes, different members/ef positions
    assert sum(r.degraded for r in reqs) == 2
    retrace_sentinel.assert_no_retrace("controller-driven ef switch")


def test_degrade_disabled_without_budget(scan_setup):
    """``default_deadline_ms=None`` leaves requests without explicit
    deadlines untouched no matter how late they run."""
    idx, queries = scan_setup
    clk = FakeClock()
    ctrl = SLOController(slo_ms=1.0, ef_ladder=LADDER,
                         default_deadline_ms=None, clock=clk)
    fe = AnnFrontend(idx, topk=TOPK, max_batch=4, max_wait_ms=1e9,
                     clock=clk, controller=ctrl)
    r = fe.submit(queries[0])
    clk.advance(5.0)  # 5000x the SLO
    fe.flush()
    assert not r.degraded and ctrl.snapshot()["degraded"] == 0


# ---------------------------------------------------------------------------
# submit-time validation (PR 5 contract extended to deadline_ms)
# ---------------------------------------------------------------------------


def test_bad_deadline_fails_at_submit_not_in_batcher(scan_setup):
    """A nonsensical deadline (negative, NaN, zero, inf) must raise in the
    SUBMITTER's thread and leave the batcher (and every other request)
    unharmed."""
    idx, queries = scan_setup
    with AsyncAnnFrontend(idx, topk=TOPK, max_batch=4, max_wait_ms=5.0) as fe:
        for bad in (-1.0, float("nan"), 0.0, float("inf")):
            with pytest.raises(ValueError, match="deadline_ms"):
                fe.submit(queries[0], deadline_ms=bad)
        good = fe.submit(queries[1], deadline_ms=50.0)
        assert good.wait(WAIT_S) and good.done
        assert good.deadline_ms == 50.0
        assert fe.error is None
    sync = AnnFrontend(idx, topk=TOPK, max_batch=4)
    with pytest.raises(ValueError, match="deadline_ms"):
        sync.submit(queries[0], deadline_ms=float("nan"))


def test_retune_validation():
    with pytest.raises(ValueError, match="max_batch"):
        AnnFrontend.retune(AnnFrontend.__new__(AnnFrontend), max_batch=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        AnnFrontend.retune(
            AnnFrontend.__new__(AnnFrontend), max_wait_ms=float("nan")
        )


# ---------------------------------------------------------------------------
# auto-tune policy
# ---------------------------------------------------------------------------


def test_retune_tighten_relax_hold_cycle(scan_setup):
    """AIMD over fabricated telemetry: hot windows halve max_wait (floored),
    cold windows relax it back (capped at the configured base), steady
    state holds — and every tick is observable (controller span + labeled
    counter)."""
    idx, _ = scan_setup
    tel = Telemetry()
    ctrl = SLOController(slo_ms=10.0, ef_ladder=LADDER, min_wait_ms=0.5)
    fe = AsyncAnnFrontend(idx, topk=TOPK, max_batch=8, max_wait_ms=4.0,
                          telemetry=tel, controller=ctrl)
    # empty window, empty queue, already at base -> hold
    assert ctrl.retune_once() == "hold"
    # a batch whose worst request blew the SLO -> tighten (4 -> 2 ms)
    tel.spans.emit("batch", batch_kind="full_batches", b=8,
                   exec_s=20e-3, queue_mean_s=1e-3, queue_max_s=5e-3)
    assert ctrl.retune_once() == "tighten"
    assert fe.max_wait_s == pytest.approx(2e-3)
    # quiet windows relax multiplicatively back toward the base, capped
    assert ctrl.retune_once() == "relax"
    assert fe.max_wait_s == pytest.approx(3e-3)
    assert ctrl.retune_once() == "relax"
    assert fe.max_wait_s == pytest.approx(4e-3)  # capped at base
    assert ctrl.retune_once() == "hold"
    snap = ctrl.snapshot()
    assert snap["ticks"] == 5 and snap["tighten"] == 1 and snap["relax"] == 2
    assert len(tel.spans.events(kind="controller")) == 5
    assert 'lanns_controller_retunes_total{action="tighten"} 1' in (
        tel.registry.expose_text()
    )
    # repeated hot windows never push below the floor
    for _ in range(10):
        tel.spans.emit("batch", batch_kind="full_batches", b=8,
                       exec_s=50e-3, queue_mean_s=0.0, queue_max_s=0.0)
        ctrl.retune_once()
    assert fe.max_wait_s == pytest.approx(0.5e-3)


def test_retune_tightens_on_queue_depth_alone(scan_setup):
    """Depth > 2x max_batch is a hot signal even with no batch spans (e.g.
    telemetry-less frontends still get backpressure adaptation)."""
    idx, queries = scan_setup
    ctrl = SLOController(slo_ms=10.0, ef_ladder=LADDER)
    fe = AsyncAnnFrontend(idx, topk=TOPK, max_batch=4, max_wait_ms=4.0,
                          controller=ctrl)
    with fe._cond:  # unstarted frontend: fabricate a deep queue
        fe.pending.extend(
            AnnRequest(j, queries[0], 0.0) for j in range(9)
        )
    assert ctrl.retune_once() == "tighten"
    assert fe.max_wait_s == pytest.approx(2e-3)


# ---------------------------------------------------------------------------
# lifecycle + construction
# ---------------------------------------------------------------------------


def test_constructor_validation():
    good = dict(slo_ms=10.0, ef_ladder=(32, 16))
    SLOController(**good)
    for bad in (
        dict(good, slo_ms=0.0),
        dict(good, slo_ms=float("nan")),
        dict(good, ef_ladder=()),
        dict(good, ef_ladder=(16, 32)),  # ascending
        dict(good, ef_ladder=(16, 16)),  # not strictly descending
        dict(good, ef_ladder=(16, 0)),
        dict(good, default_deadline_ms=-1.0),
        dict(good, interval_s=0.0),
        dict(good, min_wait_ms=0.0),
        dict(good, tighten_factor=1.0),
        dict(good, relax_factor=1.0),
        dict(good, relax_margin=1.5),
    ):
        with pytest.raises(ValueError):
            SLOController(**bad)


def test_warm_knobs_covers_ladder():
    ctrl = SLOController(slo_ms=5.0, ef_ladder=(48, 24, 12))
    assert ctrl.warm_knobs() == [(None, 48), (None, 24), (None, 12)]
    assert ctrl.warm_knobs(topk=20) == [(20, 48), (20, 24), (20, 12)]


def test_lifecycle_and_binding(scan_setup):
    idx, queries = scan_setup
    ctrl = SLOController(slo_ms=10.0, ef_ladder=LADDER, interval_s=0.01)
    with pytest.raises(RuntimeError, match="bind"):
        ctrl.start()
    assert ctrl.retune_once() == "unbound"  # tick before bind: a no-op
    fe = AsyncAnnFrontend(idx, topk=TOPK, max_batch=4, max_wait_ms=1.0,
                          controller=ctrl)
    assert fe.controller is ctrl and ctrl.frontend is fe
    # one controller binds one frontend
    with pytest.raises(RuntimeError, match="already bound"):
        AsyncAnnFrontend(idx, topk=TOPK, controller=ctrl)
    ctrl.bind(fe)  # re-binding the same frontend is a no-op
    with fe, ctrl:
        with pytest.raises(RuntimeError, match="already started"):
            ctrl.start()
        req = fe.submit(queries[0], deadline_ms=100.0)
        assert req.wait(WAIT_S)
    assert not ctrl.running
    ctrl.stop()  # idempotent
    assert ctrl.snapshot()["ticks"] >= 0
    # restart after stop works
    ctrl.start()
    ctrl.stop()


# ---------------------------------------------------------------------------
# loadgen integration: the A/B harness
# ---------------------------------------------------------------------------


def test_run_controller_ab_smoke(hnsw_setup):
    """Paired off/on points: same seeded schedule, SLO accounting and
    recall populated on both, controller decisions observable, rows
    strict-JSON clean."""
    idx, data, queries = hnsw_setup
    gt_ids = np.asarray(brute_force_topk(queries, data, TOPK)[1])
    tel = Telemetry()
    off, on, ctrl = run_controller_ab(
        idx, queries, rate_qps=200.0, slo_ms=8.0, ef_ladder=LADDER,
        duration_s=0.3, seed=3, topk=TOPK, max_batch=8, max_wait_ms=2.0,
        gt_ids=gt_ids, telemetry=tel,
    )
    for res in (off, on):
        assert res.completed > 0 and res.completed == res.submitted
        assert res.slo_ms == 8.0
        assert 0.0 <= res.slo_attainment <= 1.0
        assert 0.0 <= res.mean_recall <= 1.0
    assert not off.controller_on and on.controller_on
    assert off.degraded == 0  # no controller bound -> deadlines inert
    snap = ctrl.snapshot()
    assert snap["ticks"] > 0
    assert snap["degraded"] == on.degraded
    json.dumps(off.row())  # nan-cleaning holds for the new fields
    json.dumps(on.row())


def test_run_load_point_slo_accounting_without_controller(scan_setup):
    """slo_ms alone adds attainment accounting; deadline_ms alone changes
    nothing about the results."""
    idx, queries = scan_setup
    res = run_load_point(
        idx, queries, process="poisson", rate_qps=200.0, duration_s=0.2,
        topk=TOPK, max_batch=8, max_wait_ms=1.0, seed=7,
        deadline_ms=1e6, slo_ms=1e6,
    )
    assert res.completed > 0
    assert res.slo_attainment == 1.0  # a 1000 s SLO is always met
    assert res.degraded == 0 and not res.controller_on
    assert math.isnan(res.mean_recall)  # no gt supplied

"""Loop-aware HLO cost analyzer: exactness on known-flop programs."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_flat_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    r = analyze(_hlo(lambda a, b: a @ b, a, b))
    assert r["flops"] == 2 * 128 * 256 * 64


def test_scan_multiplies_trip_count():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=12)[0]

    r = analyze(_hlo(scanned, w, w))
    assert r["flops"] == 12 * 2 * 128**3


def test_nested_scan_multiplies_both_levels():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            inner = jax.lax.scan(
                lambda c2, _: (c2 @ w, None), c, None, length=5
            )[0]
            return inner, None

        return jax.lax.scan(outer, x, None, length=3)[0]

    r = analyze(_hlo(nested, w, w))
    assert r["flops"] == 15 * 2 * 64**3


def test_scan_equals_unrolled():
    w = jax.ShapeDtypeStruct((96, 96), jnp.float32)

    def unrolled(x, w):
        for _ in range(6):
            x = x @ w
        return x

    def scanned(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=6)[0]

    ru = analyze(_hlo(unrolled, w, w))
    rs = analyze(_hlo(scanned, w, w))
    assert ru["flops"] == rs["flops"] == 6 * 2 * 96**3


def test_elementwise_costs_no_flops_or_bytes():
    """Converts/elementwise are treated as fused (free) — the TPU model."""
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.bfloat16)
    r = analyze(_hlo(lambda a: jnp.tanh(a.astype(jnp.float32)) * 2.0, a))
    assert r["flops"] == 0
    # only the final output copy-ish traffic may appear; no 4 MiB f32 blowup
    assert r["bytes"] < 4 * 1024 * 1024


def test_grad_flops_roughly_triple():
    """Backward of a matmul chain costs ~2x the forward dots (dgrad+wgrad)."""
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def fwd(x, w):
        return jnp.sum(jnp.tanh(x @ w) @ w)

    r_f = analyze(_hlo(fwd, w, w))
    r_g = analyze(_hlo(jax.grad(fwd, argnums=(0, 1)), w, w))
    assert 2.2 * r_f["flops"] <= r_g["flops"] <= 3.8 * r_f["flops"]

"""Per-architecture smoke tests: reduced config, one forward + train steps on
CPU, asserting output shapes, finiteness, and that the loss moves."""

import pytest

from repro.configs import ARCH_IDS, get_arch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke(arch_id):
    metrics = get_arch(arch_id).smoke()
    assert all(
        v == v and abs(v) < 1e9 for v in metrics.values()
    ), metrics  # finite


def test_all_archs_have_cells():
    for arch_id in ARCH_IDS:
        arch = get_arch(arch_id)
        assert len(arch.cells) == 4, arch_id
        assert arch.family in ("lm", "gnn", "recsys")


def test_lm_param_counts_match_published():
    """num_params() should land near the published sizes (the exact configs
    are the point of the exercise)."""
    expected = {
        "codeqwen1.5-7b": 7.3e9,
        "qwen2-72b": 72.7e9,
        "smollm-360m": 0.36e9,
        "deepseek-moe-16b": 16.4e9,
        "deepseek-v2-lite-16b": 15.7e9,
    }
    for arch_id, want in expected.items():
        cfg = get_arch(arch_id).model_config()
        got = cfg.num_params()
        assert abs(got - want) / want < 0.15, (arch_id, got, want)


def test_moe_active_params():
    cfg = get_arch("deepseek-moe-16b").model_config()
    active = cfg.num_active_params()
    total = cfg.num_params()
    # DeepSeekMoE-16B: ~2.8B activated of ~16B
    assert 1.5e9 < active < 4e9, active
    assert active < total / 4

"""Bench-regression gate logic (benchmarks/check_regression.py).

CI trusts this checker to block QPS regressions — so the checker itself is
tier-1 tested: drop detection on relative (qps/speedup) and absolute
(recall) metrics, improvement tolerance, schema-drift failures, and the
--update refresh path.
"""

import json

import pytest

from benchmarks.check_regression import (
    check,
    load_bench_files,
    main,
    update_baselines,
)
from benchmarks.common import BENCH_SCHEMA_VERSION, bench_payload


def _payload(metrics, bench="online_qps"):
    return bench_payload(bench, metrics=metrics, smoke=True)


def _baseline(metrics, bench="online_qps"):
    return {bench: {"smoke": True, "metrics": metrics}}


def test_pass_within_tolerance():
    cur = {"online_qps": _payload({"qps_offline_b64": 800.0})}
    base = _baseline({"qps_offline_b64": 1000.0})
    failures, lines = check(cur, base, tolerance=0.25)
    assert failures == []
    assert any("ok" in ln for ln in lines)


def test_fail_beyond_qps_tolerance():
    cur = {"online_qps": _payload({"qps_offline_b64": 700.0})}
    base = _baseline({"qps_offline_b64": 1000.0})
    failures, _ = check(cur, base, tolerance=0.25)
    assert len(failures) == 1
    assert "qps_offline_b64" in failures[0]


def test_improvement_never_fails():
    cur = {"online_qps": _payload(
        {"qps_offline_b64": 5000.0, "recall_q8": 0.99}
    )}
    base = _baseline({"qps_offline_b64": 1000.0, "recall_q8": 0.80})
    failures, _ = check(cur, base)
    assert failures == []


def test_recall_absolute_tolerance():
    base = _baseline({"recall_q8": 0.80})
    ok = {"online_qps": _payload({"recall_q8": 0.79})}
    bad = {"online_qps": _payload({"recall_q8": 0.75})}
    assert check(ok, base, recall_tolerance=0.02)[0] == []
    failures, _ = check(bad, base, recall_tolerance=0.02)
    assert len(failures) == 1 and "recall_q8" in failures[0]


def test_smoke_flag_mismatch_fails():
    """A full-scale run must not be gated against smoke-calibrated
    baselines (different corpus sizes/windows)."""
    cur = {"online_qps": bench_payload(
        "online_qps", metrics={"qps_offline_b64": 1e6}, smoke=False,
    )}
    base = _baseline({"qps_offline_b64": 1000.0})  # calibrated smoke=True
    failures, _ = check(cur, base)
    assert len(failures) == 1 and "smoke" in failures[0]


def test_missing_metric_fails():
    cur = {"online_qps": _payload({"qps_other": 1.0})}
    base = _baseline({"qps_offline_b64": 1000.0})
    failures, _ = check(cur, base)
    assert len(failures) == 1 and "missing" in failures[0]


def test_missing_bench_file_fails():
    base = _baseline({"qps_offline_b64": 1000.0}, bench="latency_load")
    failures, _ = check({}, base)
    assert len(failures) == 1 and "latency_load" in failures[0]


def test_info_metrics_not_gated():
    """Latency/bytes metrics report but never fail (runner variance)."""
    cur = {"online_qps": _payload({"p99_ms_half_load": 100.0})}
    base = _baseline({"p99_ms_half_load": 1.0})
    failures, lines = check(cur, base)
    assert failures == []
    assert any("not gated" in ln for ln in lines)


def test_missing_info_metric_reports_not_fails():
    """An info metric absent from the run is drift worth showing, never a
    gate failure — it had no gate to drift from."""
    cur = {"online_qps": _payload(
        {"qps_offline_b64": 1000.0}
    )}
    base = _baseline({
        "qps_offline_b64": 1000.0,
        "footprint_q8_scan_device_bytes": 5.4e9,
    })
    failures, lines = check(cur, base)
    assert failures == []
    assert any("footprint_q8_scan_device_bytes" in ln and "missing" in ln
               for ln in lines)


def test_info_only_bench_file_absent_not_fails():
    """A baseline bench with only info metrics (the footprint report) whose
    file didn't get produced this run must not fail the gate."""
    base = _baseline(
        {"footprint_q8_scan_device_bytes": 5.4e9}, bench="footprint"
    )
    failures, lines = check({}, base)
    assert failures == []
    assert any("footprint" in ln and "info-only" in ln for ln in lines)


def test_footprint_metrics_classify_as_info():
    """Footprint bytes must never gate even though they are stable: the
    committed values move with deliberate dim/codec changes."""
    cur = {"footprint": _payload(
        {"footprint_fp32_scan_device_bytes": 2e12}, bench="footprint"
    )}
    base = _baseline(
        {"footprint_fp32_scan_device_bytes": 1.0}, bench="footprint"
    )  # 2e12 x drift: still info
    failures, lines = check(cur, base)
    assert failures == []
    assert any("not gated" in ln for ln in lines)


def test_newer_schema_rejected(tmp_path):
    path = tmp_path / "BENCH_x.json"
    payload = _payload({"qps_a": 1.0})
    payload["schema_version"] = BENCH_SCHEMA_VERSION + 1
    path.write_text(json.dumps(payload))
    with pytest.raises(ValueError, match="schema_version"):
        load_bench_files([str(path)])


def test_update_roundtrip(tmp_path):
    """--update writes only gated metrics; a fresh check then passes."""
    cur = {"online_qps": _payload(
        {"qps_offline_b64": 1234.5, "recall_q8": 0.9, "p99_ms": 3.0}
    )}
    bpath = tmp_path / "baselines.json"
    written = update_baselines(cur, str(bpath))
    assert "p99_ms" not in written["online_qps"]["metrics"]
    reloaded = json.loads(bpath.read_text())
    assert reloaded["online_qps"]["metrics"]["qps_offline_b64"] == 1234.5
    failures, _ = check(cur, reloaded)
    assert failures == []


def test_update_merges_existing_baselines(tmp_path):
    """--update with a subset of benches must not erase the other benches'
    entries (that would silently disable their gates)."""
    bpath = tmp_path / "baselines.json"
    bpath.write_text(json.dumps({
        "recall": {"smoke": True, "metrics": {"recall_q8": 0.8}},
        "online_qps": {"smoke": True, "metrics": {"qps_offline_b64": 1.0}},
    }))
    update_baselines(
        {"online_qps": _payload({"qps_offline_b64": 2000.0})}, str(bpath)
    )
    reloaded = json.loads(bpath.read_text())
    assert reloaded["online_qps"]["metrics"]["qps_offline_b64"] == 2000.0
    assert reloaded["recall"]["metrics"]["recall_q8"] == 0.8  # preserved


def test_main_end_to_end(tmp_path, capsys):
    """CLI: pass -> 0, regression -> 1, no files -> 2."""
    bench = tmp_path / "BENCH_online_qps.json"
    bench.write_text(json.dumps(_payload({"qps_offline_b64": 1000.0})))
    bpath = tmp_path / "baselines.json"
    bpath.write_text(json.dumps(_baseline({"qps_offline_b64": 1000.0})))
    assert main([str(bench), "--baseline", str(bpath)]) == 0
    assert "PASS" in capsys.readouterr().out

    bench.write_text(json.dumps(_payload({"qps_offline_b64": 10.0})))
    assert main([str(bench), "--baseline", str(bpath)]) == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_main_no_files_is_usage_error(tmp_path, monkeypatch, capsys):
    empty = tmp_path / "empty"
    empty.mkdir()
    monkeypatch.chdir(empty)
    assert main([]) == 2
    assert "no BENCH" in capsys.readouterr().err


def test_main_unreadable_bench_file_is_usage_error(tmp_path, capsys):
    """Malformed/newer-schema files exit 2 (usage), not a traceback."""
    bad = tmp_path / "BENCH_x.json"
    bad.write_text("{not json")
    assert main([str(bad)]) == 2
    assert "cannot load" in capsys.readouterr().err

    newer = _payload({"qps_a": 1.0})
    newer["schema_version"] = BENCH_SCHEMA_VERSION + 1
    bad.write_text(json.dumps(newer))
    assert main([str(bad)]) == 2
    assert "schema_version" in capsys.readouterr().err


def test_update_keeps_metrics_for_info_only_bench(tmp_path):
    """--update must not hollow out the footprint baseline entry: with no
    gated keys, the info metrics ARE the committed reference."""
    cur = {
        "footprint": _payload(
            {"footprint_q8_scan_device_bytes": 5.4e9}, bench="footprint"
        ),
        "online_qps": _payload({"qps_offline_b64": 1.0, "p99_ms": 2.0}),
    }
    bpath = tmp_path / "baselines.json"
    written = update_baselines(cur, str(bpath))
    assert written["footprint"]["metrics"] == {
        "footprint_q8_scan_device_bytes": 5.4e9
    }
    # gated benches still store gated keys only
    assert written["online_qps"]["metrics"] == {"qps_offline_b64": 1.0}

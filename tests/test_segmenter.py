"""Segmenter invariants (paper §4.3), including hypothesis property tests."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SegmenterConfig, make_segmenter, expected_spill_fraction
from repro.core.segmenter import failure_probability
from repro.data.synthetic import clustered_vectors


def _fit(kind, m, alpha=0.15, spill="virtual", n=4000, d=16, seed=0):
    data = clustered_vectors(n, d, n_clusters=32, seed=seed)
    seg = make_segmenter(
        SegmenterConfig(kind=kind, num_segments=m, alpha=alpha, spill=spill,
                        seed=seed)
    ).fit(data)
    return seg, data


@pytest.mark.parametrize("kind", ["rs", "rh", "apd"])
@pytest.mark.parametrize("m", [2, 4, 8])
def test_every_point_routed_exactly_once_virtual(kind, m):
    seg, data = _fit(kind, m)
    mask = seg.route_points(data)
    assert mask.shape == (len(data), m)
    assert np.all(mask.sum(axis=1) == 1), "virtual spill: one segment per point"


@pytest.mark.parametrize("kind", ["rh", "apd"])
def test_physical_spill_duplicates_points(kind):
    seg, data = _fit(kind, 4, spill="physical")
    mask = seg.route_points(data)
    counts = mask.sum(axis=1)
    assert np.all(counts >= 1)
    dup_frac = (counts > 1).mean()
    # alpha=0.15 => ~30% band per level; 2 levels compound
    assert 0.15 < dup_frac < 0.8


@pytest.mark.parametrize("kind", ["rh", "apd"])
def test_query_spill_fraction_matches_alpha(kind):
    seg, data = _fit(kind, 2, alpha=0.15)
    q = clustered_vectors(5000, 16, n_clusters=32, seed=99)
    mask = seg.route_queries(q)
    frac_both = (mask.sum(axis=1) > 1).mean()
    # one level: P(band) ~ 2*alpha = 0.3 on in-distribution queries
    assert 0.1 < frac_both < 0.55


def test_rs_queries_go_everywhere():
    seg, data = _fit("rs", 8)
    q = data[:100]
    assert np.all(seg.route_queries(q))


def test_balanced_split_rh():
    seg, data = _fit("rh", 8)
    mask = seg.route_points(data)
    sizes = mask.sum(axis=0)
    assert sizes.max() < 2.0 * sizes.min() + 10  # median splits ~balance


def test_apd_direction_is_informative():
    """APD should split along a high-variance direction: the projections'
    variance should exceed the average coordinate variance."""
    seg, data = _fit("apd", 2)
    h = seg.hyperplanes[0]
    proj_var = np.var(data @ h)
    mean_var = np.var(data, axis=0).mean()
    assert proj_var > mean_var


def test_segment_assignment_deterministic():
    seg1, data = _fit("rh", 4, seed=5)
    seg2, _ = _fit("rh", 4, seed=5)
    assert np.array_equal(seg1.route_points(data), seg2.route_points(data))


def test_expected_spill_fraction_formula():
    assert expected_spill_fraction(0.15, 1) == pytest.approx(0.3)
    assert expected_spill_fraction(0.15, 3) == pytest.approx(1 - 0.7**3)


def test_failure_probability_monotone():
    p = failure_probability(np.arange(1, 9), alpha=0.15, n=10_000)
    assert np.all(np.diff(p) > 0), "more levels => more failure (Fig. 4)"
    assert p[-1] < 0.01  # paper's plotted range is small


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=3),
    st.floats(min_value=0.05, max_value=0.3),
)
def test_property_virtual_routing_covers_median_route(levels, alpha):
    """Property: the no-spill (median) route of any query is always included
    in its spill route set — spill only ADDS segments."""
    m = 2**levels
    data = clustered_vectors(1000, 8, n_clusters=16, seed=3)
    seg = make_segmenter(
        SegmenterConfig(kind="rh", num_segments=m, alpha=alpha, seed=1)
    ).fit(data)
    q = data[:200]
    spill_mask = seg._route(q, spill_band=True)
    median_mask = seg._route(q, spill_band=False)
    assert np.all(spill_mask | ~median_mask), "median leaf must be in spill set"

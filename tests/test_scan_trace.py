"""Scan-engine trace stability (ROADMAP item closed by this PR).

Scan partition corpora pad to shared pow2 size buckets and routed batches
to pow2 query buckets, so ``distance_topk`` (blocked-jnp on CPU) compiles
once per DISTINCT (query bucket, corpus bucket) pair — never once per
(partition, routed-subset) pair.  Mirrors tests/test_hnsw_trace.py, using
the same jit-cache counters; the q8 stage-1 jit is bounded the same way
(quarter-pow2 lane buckets x corpus buckets).
"""

import numpy as np
import pytest

from repro.common.utils import jit_cache_size, next_pow2, next_pow2_quarter
from repro.core import LannsConfig, LannsIndex
from repro.data.synthetic import clustered_vectors
from repro.kernels import ref
from repro.quant import twostage


@pytest.fixture(scope="module")
def scan_index():
    data = clustered_vectors(3000, 16, n_clusters=32, seed=0)
    queries = clustered_vectors(80, 16, n_clusters=32, seed=1)
    cfg = LannsConfig(num_shards=2, num_segments=4, segmenter="apd",
                      engine="scan", alpha=0.15)
    return LannsIndex(cfg).build(data), queries


def test_scan_traces_bounded_across_partitions_and_batches(scan_index):
    idx, queries = scan_index
    idx.query(queries[:4], 10)  # warm
    before = jit_cache_size(ref.distance_topk_blocked)
    sizes = (1, 2, 3, 5, 7, 9, 13, 30, 41, 63, 80)
    qbuckets, nbuckets = set(), set()
    for B in sizes:
        q = queries[:B]
        mask = idx.partitioner.route_queries(q)
        for g in range(idx.config.num_segments):
            c = int(mask[:, g].sum())
            if c:
                qbuckets.add(next_pow2(c))
        idx.query(q, 10)
    for p in idx.partitions.values():
        if p.size:
            nbuckets.add(next_pow2_quarter(p.size))
    new = jit_cache_size(ref.distance_topk_blocked) - before
    assert new <= len(qbuckets) * len(nbuckets), (
        new, qbuckets, nbuckets
    )
    # and strictly fewer than one trace per (batch, partition) combination
    assert new < len(sizes) * len(idx.partitions) / 2


def test_q8_stage1_traces_bounded():
    data = clustered_vectors(2500, 16, n_clusters=16, seed=2)
    queries = clustered_vectors(64, 16, n_clusters=16, seed=3)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="scan", alpha=0.15, quantized="q8")
    idx = LannsIndex(cfg).build(data)
    idx.query(queries[:4], 10)  # warm
    before = jit_cache_size(twostage._stage1_scores)
    lbuckets, nbuckets = set(), set()
    for B in (1, 3, 6, 11, 17, 33, 64):
        q = queries[:B]
        mask = idx.partitioner.route_queries(q)
        for g in range(idx.config.num_segments):
            c = int(mask[:, g].sum())
            if c:
                lbuckets.add(next_pow2_quarter(c))
        idx.query(q, 10)
    for p in idx.partitions.values():
        if p.size:
            nbuckets.add(next_pow2_quarter(p.size))
    new = jit_cache_size(twostage._stage1_scores) - before
    assert new <= len(lbuckets) * len(nbuckets), (new, lbuckets, nbuckets)


def test_scan_padding_is_result_transparent(scan_index):
    """Bucketed corpora + n_valid masking change ZERO bits of any result."""
    idx, queries = scan_index
    from repro.kernels import ops

    for p in idx.partitions.values():
        if p.size == 0 or p.scan_corpus() is p.vectors:
            continue
        d0, i0 = ops.distance_topk(queries[:8], p.vectors, 7, "l2")
        d1, i1 = ops.distance_topk(
            queries[:8], p.scan_corpus(), 7, "l2", n_valid=p.size
        )
        assert np.array_equal(np.asarray(d0), np.asarray(d1))
        assert np.array_equal(np.asarray(i0), np.asarray(i1))


def test_scan_trace_counter_in_stats(scan_index):
    idx, queries = scan_index
    _, _, stats = idx.query(queries[:8], 10, return_stats=True)
    assert stats["scan_traces"] != 0  # -1 (unavailable) or a real count


def test_scan_serving_zero_retrace_after_warm(scan_index, retrace_sentinel):
    """fp32 scan warm_traces is exhaustive over (batch bucket x corpus
    bucket): after it, NO watched serving jit may recompile — not the scan
    kernel, not the merge, nothing."""
    idx, queries = scan_index
    idx.warm_traces(len(queries), 10)
    idx.query(queries[:5], 10)  # settle any non-scan residuals (merge path)
    with retrace_sentinel.expect_no_retrace("warmed scan serving"):
        for B in (1, 2, 5, 13, 41, 80):
            idx.query(queries[:B], 10)


def test_q8_scan_zero_retrace_on_repeat_workload(retrace_sentinel):
    """q8 warm_traces is best-effort (stage-1 lane buckets depend on the
    router), so the sentinel contract is run-the-identical-workload-twice:
    the second pass must hit only cached traces."""
    data = clustered_vectors(2500, 16, n_clusters=16, seed=4)
    queries = clustered_vectors(64, 16, n_clusters=16, seed=5)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="scan", alpha=0.15, quantized="q8")
    idx = LannsIndex(cfg).build(data)
    sizes = (1, 3, 11, 33, 64)
    for B in sizes:  # first pass compiles whatever the workload needs
        idx.query(queries[:B], 10)
    with retrace_sentinel.expect_no_retrace("repeated q8 scan workload"):
        for B in sizes:
            idx.query(queries[:B], 10)

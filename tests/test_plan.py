"""Query-plan executor: knob grouping, mixed-batch parity, merge decision.

The tentpole contract: a heterogeneous per-request (topk, ef) batch must be
BIT-IDENTICAL to issuing each knob group as its own homogeneous query —
grouping and reassembly may not perturb a single value.  Plus the merge
deprecation-window endpoint: ``choose_merge_path`` is the ONE place the
disjoint/two-level decision lives.
"""

import numpy as np
import pytest

from repro.core import LannsConfig, LannsIndex
from repro.core.plan import choose_merge_path, knob_groups
from repro.data.synthetic import clustered_vectors


@pytest.fixture(scope="module")
def world():
    data = clustered_vectors(3000, 16, n_clusters=24, seed=0)
    queries = clustered_vectors(48, 16, n_clusters=24, seed=1)
    return data, queries


def _index(data, engine, **kw):
    cfg = LannsConfig(
        num_shards=1, num_segments=4, segmenter="apd", engine=engine,
        hnsw_m=8, ef_construction=40, ef_search=40, **kw,
    )
    return LannsIndex(cfg).build(data)


# ---------------------------------------------------------------------------
# knob_groups normalization
# ---------------------------------------------------------------------------


def test_knob_groups_scalar_and_collapse():
    scalar, groups = knob_groups(10, None, 4)
    assert scalar and groups == [(10, None, None)]
    scalar, groups = knob_groups(10, 64, 4)
    assert scalar and groups == [(10, 64, None)]
    # a homogeneous ARRAY collapses to the scalar fast path
    scalar, groups = knob_groups(np.full(4, 10), np.zeros(4, int), 4)
    assert scalar and groups == [(10, None, None)]
    scalar, groups = knob_groups(np.full(4, 10), np.full(4, 32), 4)
    assert scalar and groups == [(10, 32, None)]


def test_knob_groups_mixed_deterministic():
    tk = np.array([5, 10, 5, 10, 20])
    ef = np.array([0, 0, 64, 0, 0])
    scalar, groups = knob_groups(tk, ef, 5)
    assert not scalar
    # sorted by (topk, ef); rows ascending; every row exactly once
    assert [(t, e) for t, e, _ in groups] == [
        (5, None), (5, 64), (10, None), (20, None)
    ]
    rows = np.concatenate([r for _, _, r in groups])
    assert sorted(rows.tolist()) == list(range(5))
    np.testing.assert_array_equal(groups[0][2], [0])
    np.testing.assert_array_equal(groups[1][2], [2])
    np.testing.assert_array_equal(groups[2][2], [1, 3])


def test_knob_groups_validation():
    with pytest.raises(ValueError, match="topk"):
        knob_groups(0, None, 2)
    with pytest.raises(ValueError, match="topk"):
        knob_groups(np.array([5, 0]), None, 2)
    with pytest.raises(ValueError, match="shape"):
        knob_groups(np.array([5, 5, 5]), None, 2)
    with pytest.raises(ValueError, match="ef"):
        knob_groups(5, np.array([1, 2, 3]), 2)
    # empty batch with array knobs: no groups
    scalar, groups = knob_groups(np.zeros(0, int), None, 0)
    assert not scalar and groups == []


# ---------------------------------------------------------------------------
# mixed-batch bit-identity (the tentpole acceptance test)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["scan", "hnsw"])
def test_mixed_knobs_bit_identical_to_homogeneous(world, engine):
    data, queries = world
    idx = _index(data, engine)
    B = len(queries)
    rng = np.random.default_rng(3)
    tk = rng.choice([5, 10, 20], B)
    ef = rng.choice([0, 48, 64], B)
    d, i, stats = idx.query(queries, tk, ef=ef, return_stats=True)
    assert d.shape == (B, tk.max()) and i.shape == (B, tk.max())
    # ef is an hnsw-only knob: the scan engine must NOT fragment its
    # batches on it (groups = distinct topk values only)
    want_groups = (
        len({(a, b) for a, b in zip(tk, ef)}) if engine == "hnsw"
        else len(set(tk))
    )
    assert stats["knob_groups"] == want_groups
    for tkv, efv in sorted({(a, b) for a, b in zip(tk, ef)}):
        rows = np.nonzero((tk == tkv) & (ef == efv))[0]
        dd, ii = idx.query(
            queries[rows], int(tkv), ef=(int(efv) if efv > 0 else None)
        )
        assert np.array_equal(i[rows, :tkv], ii), (engine, tkv, efv)
        assert np.array_equal(d[rows, :tkv], dd), (engine, tkv, efv)
        # rows narrower than the widest topk carry (+inf, -1) padding
        assert (i[rows, tkv:] == -1).all()
        assert np.isinf(d[rows, tkv:]).all()


def test_mixed_knobs_single_request_groups(world):
    """Every request its own knob group — the B=1-per-group worst case."""
    data, queries = world
    idx = _index(data, "scan")
    tk = np.array([3, 7, 11, 15])
    d, i = idx.query(queries[:4], tk)
    for j, tkv in enumerate(tk):
        dd, ii = idx.query(queries[j: j + 1], int(tkv))
        assert np.array_equal(i[j, :tkv], ii[0])
        assert np.array_equal(d[j, :tkv], dd[0])


def test_mixed_knobs_empty_batch(world):
    data, _ = world
    idx = _index(data, "scan")
    empty = np.zeros((0, data.shape[1]), np.float32)
    d, i, stats = idx.query(
        empty, np.zeros(0, np.int64), ef=np.zeros(0, np.int64),
        return_stats=True,
    )
    assert d.shape == (0, 0) and i.shape == (0, 0)
    assert stats["knob_groups"] == 0
    # merge_path report is configuration state — same as the scalar B==0
    # path (scan + virtual spill here)
    assert stats["merge_path"] == "disjoint"
    # same schema as scalar-knob stats (dashboards index unconditionally)
    _, _, full = idx.query(data[:2], 5, return_stats=True)
    assert set(stats) == set(full)
    assert full["knob_groups"] == 1


def test_homogeneous_array_matches_scalar(world):
    data, queries = world
    idx = _index(data, "scan")
    d1, i1 = idx.query(queries, np.full(len(queries), 10), ef=None)
    d2, i2 = idx.query(queries, 10)
    assert np.array_equal(i1, i2) and np.array_equal(d1, d2)


def test_scalar_ef_nonpositive_means_default(world):
    """Scalar ef <= 0 must follow the same 'index default' contract as
    array entries — a scalar 0 and a homogeneous array of 0 agree with
    ef=None bit-for-bit."""
    data, queries = world
    idx = _index(data, "hnsw")
    d_none, i_none = idx.query(queries[:8], 10, ef=None)
    d_zero, i_zero = idx.query(queries[:8], 10, ef=0)
    d_arr, i_arr = idx.query(queries[:8], 10, ef=np.zeros(8, np.int64))
    assert np.array_equal(i_none, i_zero) and np.array_equal(d_none, d_zero)
    assert np.array_equal(i_none, i_arr) and np.array_equal(d_none, d_arr)
    scalar, groups = knob_groups(10, -1, 4)
    assert scalar and groups == [(10, None, None)]


def test_warm_traces_covers_knob_mix(world):
    """warm_traces(knobs=...) pre-compiles every (topk, ef) pair's trace
    grid, so a mixed-knob workload adds NO scan traces at serve time (topk
    is a static jit arg — each distinct value is its own trace set)."""
    data, queries = world
    idx = _index(data, "scan")
    idx.warm_traces(8, 10, knobs=[(5, None), (20, 64)])
    _, _, s0 = idx.query(queries[:1], 10, return_stats=True)
    tk = np.array([5, 10, 20, 5, 10, 20, 5, 10])
    for b in (1, 3, 8):
        idx.query(queries[:b], tk[:b])
    _, _, s1 = idx.query(queries[:1], 10, return_stats=True)
    assert s1["scan_traces"] == s0["scan_traces"]


def test_mixed_knob_serving_zero_retrace(world, retrace_sentinel):
    """The sentinel twin of the stats-counter test above, over EVERY watched
    serving jit (scan, merge, rerank, ...) instead of just the scan kernel:
    a warmed mixed-knob workload recompiles nothing."""
    data, queries = world
    idx = _index(data, "scan")
    idx.warm_traces(8, 10, knobs=[(5, None), (20, 64)])
    tk = np.array([5, 10, 20, 5, 10, 20, 5, 10])
    for b in (1, 3, 8):  # warm pass fills any best-effort residual traces
        idx.query(queries[:b], tk[:b])
    with retrace_sentinel.expect_no_retrace("mixed-knob serving"):
        for b in (1, 3, 8):
            idx.query(queries[:b], tk[:b])


def test_mixed_knobs_quantized_scan(world):
    data, queries = world
    idx = _index(data, "scan", quantized="q8")
    tk = np.array([5, 15] * (len(queries) // 2))
    d, i = idx.query(queries, tk)
    for tkv in (5, 15):
        rows = np.nonzero(tk == tkv)[0]
        dd, ii = idx.query(queries[rows], tkv)
        assert np.array_equal(i[rows, :tkv], ii)
        assert np.array_equal(d[rows, :tkv], dd)


# ---------------------------------------------------------------------------
# the ONE merge-path decision point
# ---------------------------------------------------------------------------


def test_choose_merge_path_decision_table():
    mk = lambda **kw: LannsConfig(
        num_shards=1, num_segments=4, segmenter="apd", **kw
    )
    assert choose_merge_path(mk(engine="scan", spill="virtual")) == "disjoint"
    assert choose_merge_path(mk(engine="scan", spill="physical")) == "two_level"
    assert choose_merge_path(mk(engine="hnsw", spill="virtual")) == "two_level"
    assert choose_merge_path(mk(engine="hnsw", spill="physical")) == "two_level"
    assert (
        choose_merge_path(mk(engine="hnsw", quantized="q8")) == "two_level"
    )
    # q8 scan: disjoint only when the two-stage executor served EVERY
    # non-empty partition
    cfg = mk(engine="scan", quantized="q8")

    class _P:
        size = 1

    parts = {(0, 0): _P(), (0, 1): _P()}
    assert choose_merge_path(cfg, {(0, 0), (0, 1)}, parts) == "disjoint"
    assert choose_merge_path(cfg, {(0, 0)}, parts) == "two_level"


def test_merge_path_reported_consistently(world):
    """The stats field and the decision function must agree per mode."""
    data, queries = world
    for engine, spill, want in (
        ("scan", "virtual", "disjoint"),
        ("scan", "physical", "two_level"),
        ("hnsw", "virtual", "two_level"),
    ):
        idx = _index(data[:1200], engine, spill=spill)
        _, _, stats = idx.query(queries[:4], 5, return_stats=True)
        assert stats["merge_path"] == want == choose_merge_path(idx.config)

"""ServeEngine prompt-length bucketing: bounded prefill traces, exact
numerics (the causal mask makes right padding invisible to the last real
token), and clean decode continuation over the padded cache rows."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine, make_prefill_fn


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = tf.TransformerConfig(n_layers=2, d_model=32, n_heads=2,
                               n_kv_heads=2, head_dim=16, d_ff=64, vocab=128)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_prefill_traces_bounded_by_buckets(tiny_lm):
    cfg, params = tiny_lm
    eng = ServeEngine(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    lengths = [2, 3, 5, 7, 9, 11, 13, 17, 19, 23, 29, 31, 33, 40]
    for uid, L in enumerate(lengths):
        eng.submit(Request(uid, rng.integers(0, 128, L).astype(np.int32),
                           max_new_tokens=2))
    eng.run()
    assert eng.stats["completed"] == len(lengths)
    # 14 distinct prompt lengths -> at most 3 buckets (16, 32, 64)
    assert eng.stats["prefill_traces"] <= 3, eng.stats


def test_bucketed_prefill_matches_exact(tiny_lm):
    """Greedy continuation from the bucketed engine == greedy continuation
    computed with an exact-length prefill + per-token decode."""
    cfg, params = tiny_lm
    rng = np.random.default_rng(1)
    prefill_exact = jax.jit(make_prefill_fn(cfg))
    for L in (3, 9, 14, 16, 21):
        prompt = rng.integers(0, 128, L).astype(np.int32)
        n_new = 4

        # reference: exact-length prefill, then greedy decode
        cache = tf.make_cache(cfg, 1, 64, dtype=jnp.float32)
        logits, cache = prefill_exact(params, jnp.asarray(prompt[None]), cache)
        want = [int(np.argmax(np.asarray(logits)[0]))]
        offset = L
        for _ in range(n_new - 1):
            tok = jnp.asarray([[want[-1]]], jnp.int32)
            logits, cache = tf.apply(
                params, cfg, tok, cache=cache,
                cache_offset=jnp.asarray([offset], jnp.int32),
            )[:2]
            want.append(int(np.argmax(np.asarray(logits)[0, -1])))
            offset += 1

        eng = ServeEngine(cfg, params, slots=1, max_seq=64)
        req = Request(0, prompt, max_new_tokens=n_new)
        eng.submit(req)
        eng.run()
        assert req.tokens_out == want, (L, req.tokens_out, want)

"""repro.analysis.scalecheck: symbolic dim propagation, the LANNS030-034
rules on their fixture twins, guard refinement, the footprint report, and
the CLI surfaces that CI consumes.

Snippet tests write one-function modules to tmp_path and run the full
analyzer over them — the same entry point CI uses — so every assertion
covers directive parsing, roster selection, and rule logic end to end.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    DEFAULT_FOOTPRINT_DIMS,
    RULES,
    analyze_file,
    footprint_report,
)
from repro.analysis.symdims import (
    Sym,
    fmt_bytes,
    next_pow2_bound,
    parse_budget,
    parse_dims,
    quarter_pow2_bound,
)

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"

SCALE_RULES = ("LANNS030", "LANNS031", "LANNS032", "LANNS033", "LANNS034")


def codes(findings, *, include_suppressed=False):
    return sorted(
        f.code for f in findings if include_suppressed or not f.suppressed
    )


def analyze_snippet(tmp_path, body: str):
    p = tmp_path / "snippet.py"
    p.write_text(body)
    return analyze_file(str(p))


# ---------------------------------------------------------------------------
# fixture twins
# ---------------------------------------------------------------------------


def test_bad_scalecheck_trips_every_rule():
    got = codes(analyze_file(str(FIXTURES / "bad_scalecheck.py")))
    for code in SCALE_RULES:
        assert code in got, (code, got)


def test_clean_scalecheck_twin_is_silent():
    assert codes(analyze_file(str(FIXTURES / "clean_scalecheck.py"))) == []


def test_scale_rules_have_registry_entries():
    for code in SCALE_RULES:
        assert code in RULES
    for f in analyze_file(str(FIXTURES / "bad_scalecheck.py")):
        assert f.code in RULES, f.code


def test_unannotated_module_is_skipped(tmp_path):
    """No dims/budget directive -> the pass must not touch the file (the
    whole repo minus the annotated hot modules takes this path)."""
    findings = analyze_snippet(tmp_path, (
        "import numpy as np\n"
        "def f(n, d):  # lanns: hotpath\n"
        "    return np.full((4,), n * d, np.int32)\n"
    ))
    assert not any(f.code in SCALE_RULES for f in findings)


# ---------------------------------------------------------------------------
# symbolic interval algebra
# ---------------------------------------------------------------------------


def test_sym_product_bounds():
    n = Sym("n", 180_000_000)
    d = Sym("d", 2048)
    p = n * d
    assert p.hi == 180_000_000 * 2048 and p.lo == 0
    assert "n" in p.expr and "d" in p.expr


def test_sym_sub_and_neg_cross_bounds():
    a, b = Sym("a", 10, 2), Sym("b", 7, 3)
    s = a - b
    assert (s.lo, s.hi) == (2 - 7, 10 - 3)
    assert ((-a).lo, (-a).hi) == (-10, -2)


def test_sym_floordiv_conservative_on_zero_divisor():
    total = Sym("t", 1000, 0)
    c = Sym("c", 10, 0)  # lo == 0: division can't tighten anything
    q = total // c
    assert q.hi >= 1000 and q.lo <= -1000 or (q.lo, q.hi) == (-1000, 1000)
    safe = total // Sym("k", 10, 2)
    assert safe.hi == 500 and safe.lo == 0


def test_sym_mod_bounded_by_divisor():
    m = Sym("x", 10 ** 12) % Sym("m", 128, 1)
    assert m.hi == 127 and m.lo == 0


def test_sym_hull_and_clamp():
    h = Sym("a", 10, 5).hull(Sym("b", 20, 1))
    assert (h.lo, h.hi) == (1, 20)
    c = Sym("a", 10, 5).clamp_hi(7)
    assert (c.lo, c.hi) == (5, 7)


def test_bucket_bounds_cover_real_pads():
    from repro.common.utils import next_pow2, next_pow2_quarter

    for v in (1, 2, 3, 7, 100, 1000, 12_345_678):
        assert next_pow2(v) <= next_pow2_bound(Sym("x", v, v)).hi
        assert next_pow2_quarter(v) <= quarter_pow2_bound(Sym("x", v, v)).hi


def test_parse_dims_and_budget_grammar():
    assert parse_dims("n<=180_000_000, d <= 2048") == {
        "n": 180_000_000, "d": 2048,
    }
    assert parse_budget("device<=8GiB") == {"device": 8 * 2 ** 30}
    assert parse_budget("host<=1.5GB") == {"host": 1_500_000_000}
    with pytest.raises(ValueError, match="malformed"):
        parse_dims("n=10")
    with pytest.raises(ValueError, match="malformed"):
        parse_budget("device<=8XiB")
    assert fmt_bytes(8 * 2 ** 30) == "8GiB"


# ---------------------------------------------------------------------------
# propagation through numpy shape/index arithmetic (end to end)
# ---------------------------------------------------------------------------

_HDR = (
    "import numpy as np\n"
    "# lanns: dims[n<=200_000_000, d<=2048, P<=4096, "
    "n_pad<=33_554_432, C<=1024]\n"
)


def scale_codes(tmp_path, body):
    return [f.code for f in analyze_snippet(tmp_path, _HDR + body)
            if f.code in SCALE_RULES and not f.suppressed]


def test_product_overflow_fires(tmp_path):
    got = scale_codes(tmp_path, (
        "def f(n, d):  # lanns: hotpath\n"
        "    return np.full((4,), n * d, np.int32)\n"
    ))
    assert got == ["LANNS030"]


def test_assert_guard_refines_product(tmp_path):
    got = scale_codes(tmp_path, (
        "def f(n, d):  # lanns: hotpath\n"
        "    total = n * d\n"
        "    assert total <= 2_000_000_000\n"
        "    return np.full((4,), total, np.int32)\n"
    ))
    assert got == []


def test_raise_guard_refines_product(tmp_path):
    got = scale_codes(tmp_path, (
        "def f(P, n_pad):  # lanns: hotpath\n"
        "    off = P * n_pad\n"
        "    if off > 2_147_483_647:\n"
        "        raise OverflowError(off)\n"
        "    return np.full((4,), off, np.int32)\n"
    ))
    assert got == []


def test_cumsum_range_is_total_times_magnitude(tmp_path):
    got = scale_codes(tmp_path, (
        "def f(n):  # lanns: hotpath\n"
        "    counts = np.full((n,), 32, np.int32)\n"
        "    return np.cumsum(counts)\n"
    ))
    assert got == ["LANNS030"]
    # int64 accumulation is the fix — and must satisfy the checker
    got = scale_codes(tmp_path, (
        "def f(n):  # lanns: hotpath\n"
        "    counts = np.full((n,), 32, np.int32)\n"
        "    return np.cumsum(counts.astype(np.int64))\n"
    ))
    assert got == []


def test_reshape_wildcard_infers_total(tmp_path):
    got = scale_codes(tmp_path, (
        "def f(n, d):  # lanns: hotpath\n"
        "    y = np.zeros((n, d), np.int8)\n"
        "    flat = y.reshape(-1)\n"
        "    return np.full((2,), flat.size, np.int32)\n"
    ))
    assert got == ["LANNS030"]


def test_broadcast_to_propagates_shape(tmp_path):
    got = scale_codes(tmp_path, (
        "def f(x, P, n_pad):  # lanns: hotpath\n"
        "    y = np.broadcast_to(x, (P, n_pad))\n"
        "    return np.full((2,), y.size, np.int32)\n"
    ))
    assert got == ["LANNS030"]


def test_int64_store_into_int32_slot_fires(tmp_path):
    got = scale_codes(tmp_path, (
        "def f(n, n_pad):  # lanns: hotpath\n"
        "    out = np.zeros((16,), np.int32)\n"
        "    out[:] = np.arange(n) + n_pad\n"
        "    return out\n"
    ))
    assert "LANNS032" in got


def test_conservatism_unknown_values_never_flag(tmp_path):
    """Anything the interpreter can't bound must stay silent — the
    contract that makes repo-wide --strict viable."""
    got = scale_codes(tmp_path, (
        "def helper(x):\n"
        "    return x\n"
        "def f(n, q):  # lanns: hotpath\n"
        "    m = helper(n)\n"
        "    return np.full((4,), m, np.int32)\n"
    ))
    assert got == []


# ---------------------------------------------------------------------------
# the footprint report
# ---------------------------------------------------------------------------

MODES = ("fp32_scan", "q8_scan", "fp32_hnsw", "q8_hnsw")


def test_footprint_covers_every_mode_and_placement():
    rep = footprint_report()
    assert rep["dims"] == DEFAULT_FOOTPRINT_DIMS
    for mode in MODES:
        for placement in ("device", "host"):
            key = f"footprint_{mode}_{placement}_bytes"
            assert key in rep["metrics"], key
            assert rep["metrics"][key] > 0
    # per-component rows carry the auditable closed forms and sum exactly
    # to the per-(mode, placement) metrics
    for r in rep["rows"]:
        assert r["formula"] and r["bytes"] > 0
    for mode in MODES:
        for placement in ("device", "host"):
            total = sum(
                r["bytes"] for r in rep["rows"]
                if r["mode"] == mode and r["placement"] == placement
            )
            assert total == \
                rep["metrics"][f"footprint_{mode}_{placement}_bytes"]


def test_footprint_quantization_saves_device_bytes():
    m = footprint_report()["metrics"]
    assert m["footprint_q8_scan_device_bytes"] < \
        m["footprint_fp32_scan_device_bytes"] / 3
    assert m["footprint_q8_hnsw_device_bytes"] < \
        m["footprint_fp32_hnsw_device_bytes"]


def test_footprint_scales_with_dims():
    small = footprint_report({"n": 10_000_000, "d": 512, "P": 64, "M": 16})
    big = footprint_report()
    for key in small["metrics"]:
        assert small["metrics"][key] < big["metrics"][key]
    # q8@10M x 512d — the committed-artifact deployment point — fits a
    # single 8 GiB device per the ROADMAP byte budget
    assert small["metrics"]["footprint_q8_scan_device_bytes"] < 8 * 2 ** 30


# ---------------------------------------------------------------------------
# CLI (the CI gate surfaces)
# ---------------------------------------------------------------------------


def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True,
    )


def test_cli_strict_fires_on_bad_fixture():
    r = _cli("--strict", str(FIXTURES / "bad_scalecheck.py"))
    assert r.returncode == 1, r.stdout + r.stderr
    for code in SCALE_RULES:
        assert code in r.stdout, code


def test_cli_strict_zero_on_clean_twin():
    r = _cli("--strict", str(FIXTURES / "clean_scalecheck.py"))
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_repo_stays_scale_clean():
    """The annotated hot modules must hold their declared envelopes with
    every remaining violation justified (acceptance criterion)."""
    r = _cli("--strict")
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_footprint_report_round_trips(tmp_path):
    out = tmp_path / "BENCH_footprint.json"
    r = _cli("--footprint-report", str(out),
             "--footprint-dims", "n<=10_000_000, d<=512, P<=64, M<=16")
    assert r.returncode == 0, r.stdout + r.stderr
    payload = json.loads(out.read_text())
    assert payload["schema_version"] == 1
    assert payload["bench"] == "footprint"
    assert payload["smoke"] is False
    assert payload["config"]["dims"]["n"] == 10_000_000
    for mode in MODES:
        assert f"footprint_{mode}_device_bytes" in payload["metrics"]
    assert all(r["formula"] for r in payload["rows"])


def test_cli_footprint_rejects_malformed_dims(tmp_path):
    out = tmp_path / "x.json"
    r = _cli("--footprint-report", str(out), "--footprint-dims", "n=10")
    assert r.returncode != 0

"""Pallas flash-attention kernel vs the dense oracle (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.models.layers import chunked_attention, dot_attention

CASES = [
    (2, 128, 2, 64, True),
    (1, 200, 3, 32, True),   # unaligned seq (padding path)
    (2, 96, 2, 64, False),   # bidirectional
    (1, 256, 1, 128, True),  # single head, lane-width head dim
]


@pytest.mark.parametrize("B,S,H,D,causal", CASES)
def test_flash_matches_dense(B, S, H, D, causal):
    rng = np.random.default_rng(B * 1000 + S)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    out = flash_attention_bhsd(q, k, v, causal=causal, interpret=True)
    ref = dot_attention(q, k, v, causal=causal)
    assert float(jnp.abs(out - ref).max()) < 3e-5


def test_flash_matches_chunked_jnp():
    """All three attention implementations agree (flash == chunked == dense)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 160, 2, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 160, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 160, 2, 64)).astype(np.float32))
    fl = flash_attention_bhsd(q, k, v, causal=True, interpret=True)
    ch = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    assert float(jnp.abs(fl - ch).max()) < 3e-5


def test_flash_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((2, 128, 1, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 128, 1, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 128, 1, 64)), jnp.bfloat16)
    out = flash_attention_bhsd(q, k, v, causal=True, interpret=True)
    ref = dot_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=True,
    )
    assert float(jnp.abs(out.astype(jnp.float32) - ref).max()) < 3e-2

"""Distributed-path tests.  These need >1 device, so each test runs a child
python with XLA_FLAGS=--xla_force_host_platform_device_count set BEFORE jax
imports (the parent test process keeps its single CPU device)."""

import subprocess
import sys
import textwrap



def run_child(code: str, devices: int = 8, timeout: int = 900):
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": "src",
        "PATH": "/usr/bin:/bin",
        "HOME": "/root",
    }
    import os

    env.update({k: v for k, v in os.environ.items()
                if k.startswith(("JAX", "XDG")) and k != "XLA_FLAGS"})
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd="/root/repo",
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_distributed_lanns_full_scan_recall():
    """Full-scan distributed serving == brute force up to perShardTopK."""
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.lanns import LannsConfig
        from repro.core.brute_force import brute_force_topk
        from repro.core.recall import recall_at_k
        from repro.serve.retrieval import build_device_index, make_serve_fn
        from repro.data.synthetic import clustered_vectors

        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        # confidence chosen so perShardTopK == k: full scan is then exact
        cfg = LannsConfig(num_shards=4, num_segments=4, segmenter="apd",
                          engine="scan", topk_confidence=1 - 1e-9)
        data = clustered_vectors(4000, 24, n_clusters=64, seed=0)
        qs = clustered_vectors(64, 24, n_clusters=64, seed=1)
        idx = build_device_index(data, cfg)
        serve_fn, sh = make_serve_fn(mesh, cfg, topk=10, mode="full",
                                     batch_per_device=32)
        d, i, ovf = serve_fn(jnp.asarray(qs), jnp.asarray(idx.corpus),
                             jnp.asarray(idx.ids), jnp.asarray(idx.norms),
                             idx.tree)
        td, ti = brute_force_topk(qs, data, 10)
        r = recall_at_k(np.asarray(i), ti, 10)
        assert r > 0.98, r
        print("RECALL", r)
    """)
    assert "RECALL" in out


def test_distributed_lanns_routed_beats_nothing():
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.lanns import LannsConfig
        from repro.core.brute_force import brute_force_topk
        from repro.core.recall import recall_at_k
        from repro.serve.retrieval import build_device_index, make_serve_fn
        from repro.data.synthetic import clustered_vectors

        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        cfg = LannsConfig(num_shards=4, num_segments=4, segmenter="apd",
                          engine="scan", alpha=0.15)
        data = clustered_vectors(4000, 24, n_clusters=64, seed=3)
        qs = clustered_vectors(64, 24, n_clusters=64, seed=4)
        idx = build_device_index(data, cfg)
        serve_fn, sh = make_serve_fn(mesh, cfg, topk=10, mode="routed",
                                     batch_per_device=32, capacity_factor=2.0)
        d, i, ovf = serve_fn(jnp.asarray(qs), jnp.asarray(idx.corpus),
                             jnp.asarray(idx.ids), jnp.asarray(idx.norms),
                             idx.tree)
        td, ti = brute_force_topk(qs, data, 10)
        r = recall_at_k(np.asarray(i), ti, 10)
        assert r > 0.5, r
        assert int(ovf) == 0
        print("ROUTED_RECALL", r)
    """)
    assert "ROUTED_RECALL" in out


def test_gnn_shard_map_loss_matches_local():
    """The shard_map partitioned GNN loss (and its grads) must equal the
    single-device computation on the same partitions."""
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.models import dimenet as dn
        from repro.data.synthetic import random_molecule_batch

        cfg = dn.DimeNetConfig(n_blocks=2, d_hidden=16, n_bilinear=2,
                               n_spherical=3, n_radial=3)
        params = dn.init(jax.random.PRNGKey(0), cfg)
        mols = random_molecule_batch(4, n_nodes=10, n_edges=20, seed=0)
        t_in = np.full((4, 64), -1, np.int32); t_out = np.full((4, 64), -1, np.int32)
        for b in range(4):
            ti_, to_ = dn.build_triplets(mols["edge_index"][b], 10)
            m = min(64, len(ti_)); t_in[b, :m] = ti_[:m]; t_out[b, :m] = to_[:m]
        batch = dict(positions=jnp.asarray(mols["positions"]),
                     edge_index=jnp.asarray(mols["edge_index"]),
                     t_in=jnp.asarray(t_in), t_out=jnp.asarray(t_out),
                     z=jnp.asarray(mols["z"]), y=jnp.asarray(mols["y"]))

        def local_loss(p, batch):
            def one(pos, ei, ti, to, z):
                _, g = dn.apply(p, cfg, positions=pos, edge_index=ei,
                                t_in=ti, t_out=to, z=z)
                return g[0]
            pred = jax.vmap(one)(batch["positions"], batch["edge_index"],
                                 batch["t_in"], batch["t_out"], batch["z"])
            return jnp.mean((pred - batch["y"]) ** 2)

        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((4,), ("lanes",))
        def lane_loss(p, b):
            bb = jax.tree.map(lambda a: a[0], b)
            _, g = dn.apply(p, cfg, positions=bb["positions"],
                            edge_index=bb["edge_index"], t_in=bb["t_in"],
                            t_out=bb["t_out"], z=bb["z"])
            se = (g[0] - bb["y"]) ** 2
            return jax.lax.psum(se, "lanes") / 4.0
        sm_loss = shard_map(
            lane_loss, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params),
                      jax.tree.map(lambda _: P("lanes"), batch)),
            out_specs=P(), check_rep=False)

        l0 = float(local_loss(params, batch))
        l1 = float(sm_loss(params, batch))
        assert abs(l0 - l1) < 1e-4 * max(abs(l0), 1), (l0, l1)
        g0 = jax.grad(local_loss)(params, batch)
        g1 = jax.grad(lambda p, b: sm_loss(p, b).sum())(params, batch)
        # psum reassociates f32 sums; compare RELATIVE to grad magnitude
        scale = max(float(jnp.abs(x).max()) for x in jax.tree.leaves(g0))
        errs = jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()), g0, g1)
        m = max(jax.tree.leaves(errs)) / max(scale, 1e-9)
        assert m < 1e-3, m
        print("GRAD_MATCH", m)
    """, devices=4)
    assert "GRAD_MATCH" in out


def test_hierarchical_grad_sync_equals_global_mean():
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import hierarchical_grad_sync

        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 4), ("pod", "data"))
        g = jnp.arange(8 * 33, dtype=jnp.float32).reshape(8, 33)

        def local(gl):
            synced = hierarchical_grad_sync({"w": gl[0]},
                                            pod_axis="pod", local_axis="data")
            return synced["w"][None]

        out = shard_map(local, mesh=mesh,
                        in_specs=(P(("pod", "data"), None),),
                        out_specs=P(("pod", "data"), None),
                        check_rep=False)(g)
        want = g.mean(axis=0)
        for row in np.asarray(out):
            assert np.allclose(row, np.asarray(want), rtol=1e-5), "mismatch"
        print("SYNC_OK")
    """, devices=8)
    assert "SYNC_OK" in out


def test_ring_topk_merge_matches_allgather():
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import ring_topk_merge

        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((4,), ("s",))
        rng = np.random.default_rng(0)
        d = jnp.asarray(rng.standard_normal((4, 3, 8)).astype(np.float32))
        ids = jnp.asarray(rng.permutation(4 * 3 * 8).reshape(4, 3, 8).astype(np.int32))

        def local(dl, il):
            md, mi = ring_topk_merge(dl[0], il[0], 5, "s")
            return md[None], mi[None]

        od, oi = shard_map(local, mesh=mesh,
                           in_specs=(P("s"), P("s")),
                           out_specs=(P("s"), P("s")),
                           check_rep=False)(d, ids)
        od, oi = np.asarray(od), np.asarray(oi)
        # reference: global top-5 per row
        flat_d = np.moveaxis(np.asarray(d), 0, -1).reshape(3, 32)
        flat_i = np.moveaxis(np.asarray(ids), 0, -1).reshape(3, 32)
        for r in range(3):
            order = np.argsort(flat_d[r])[:5]
            want = set(flat_i[r][order].tolist())
            for s in range(4):
                assert set(oi[s, r].tolist()) == want
        print("RING_OK")
    """, devices=4)
    assert "RING_OK" in out


def test_debug_mesh_dryrun_smoke():
    """A reduced-config LM cell lowers and compiles on a small debug mesh —
    the CI-scale version of the 512-device dry-run."""
    out = run_child("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.launch.mesh import make_debug_mesh
        from repro.configs import get_arch

        mesh = make_debug_mesh(2, 4)
        arch = get_arch("deepseek-moe-16b")
        # shrink the model but keep the cell machinery
        arch._config = dataclasses.replace(
            arch.model_config(reduced=True), n_layers=3,
            param_dtype="bfloat16", compute_dtype="bfloat16")
        cell = dataclasses.replace(arch.cells["train_4k"], global_batch=8,
                                   seq_len=64)
        arch.num_micro = 2
        spec = arch.build_cell(cell, mesh)
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=spec.donate_argnums)
        compiled = jitted.lower(*spec.args).compile()
        assert compiled.memory_analysis().temp_size_in_bytes > 0
        print("DEBUG_DRYRUN_OK")
    """, devices=8)
    assert "DEBUG_DRYRUN_OK" in out


def test_distributed_lanns_int8_corpus():
    """SQ8 corpus: 4x smaller, recall within a point of f32 full scan."""
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.lanns import LannsConfig
        from repro.core.brute_force import brute_force_topk
        from repro.core.recall import recall_at_k
        from repro.serve.retrieval import build_device_index, make_serve_fn
        from repro.data.synthetic import sift_like

        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        cfg = LannsConfig(num_shards=4, num_segments=4, segmenter="apd",
                          engine="scan", topk_confidence=1 - 1e-9)
        data, qs = sift_like(4000, 24, 64, seed=0)
        idx8 = build_device_index(data, cfg, corpus_dtype="int8")
        assert idx8.corpus.dtype == np.int8 and idx8.scale is not None
        serve_fn, sh = make_serve_fn(mesh, cfg, topk=10, mode="full",
                                     batch_per_device=32)
        d, i, ovf = serve_fn(jnp.asarray(qs), jnp.asarray(idx8.corpus),
                             jnp.asarray(idx8.ids), jnp.asarray(idx8.norms),
                             idx8.tree, jnp.asarray(idx8.scale))
        td, ti = brute_force_topk(qs, data, 10)
        r = recall_at_k(np.asarray(i), ti, 10)
        assert r > 0.9, r
        print("INT8_RECALL", r)
    """)
    assert "INT8_RECALL" in out


def test_pod_sharded_corpus_two_stage_merge():
    """corpus_axes=('pod','model'): 2x shards, hierarchical gather, exact
    at pstk==k."""
    out = run_child("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.lanns import LannsConfig
        from repro.core.brute_force import brute_force_topk
        from repro.core.recall import recall_at_k
        from repro.serve.retrieval import build_device_index, make_serve_fn
        from repro.data.synthetic import sift_like

        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = LannsConfig(num_shards=4, num_segments=2, segmenter="apd",
                          engine="scan", topk_confidence=1 - 1e-9)
        data, qs = sift_like(3000, 16, 32, seed=0)
        idx = build_device_index(data, cfg)
        serve_fn, sh = make_serve_fn(
            mesh, cfg, topk=10, mode="full", batch_per_device=16,
            corpus_axes=("pod", "model"), query_axes=("data",),
        )
        d, i, ovf = serve_fn(jnp.asarray(qs), jnp.asarray(idx.corpus),
                             jnp.asarray(idx.ids), jnp.asarray(idx.norms),
                             idx.tree)
        td, ti = brute_force_topk(qs, data, 10)
        r = recall_at_k(np.asarray(i), ti, 10)
        assert r > 0.98, r
        print("POD_SHARDED_RECALL", r)
    """)
    assert "POD_SHARDED_RECALL" in out

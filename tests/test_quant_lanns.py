"""Two-stage quantized serving through LannsIndex.

Contracts:

* recall parity — the q8 two-stage path recovers the fp32 scan path's
  recall (>= 0.99 relative) on l2/ip/cos/mips, and its returned distances
  are EXACT (stage 2 re-ranks against fp32 originals);
* the rerank_factor * k > segment-size clamp degrades gracefully (the
  satellite bugfix): candidates clamp to the segment, -1 padding survives
  re-rank and merge, and with full-segment candidate cover the results
  match the fp32 path exactly;
* rerank_store='host' and 'device' agree;
* physical spill routes through the dedup merge (no duplicate ids);
* config validation and the B == 0 edge hold.
"""

import numpy as np
import pytest

from repro.core import (
    LannsConfig,
    LannsIndex,
    brute_force_topk,
    recall_at_k,
)
from repro.data.synthetic import clustered_vectors


def _cfg(metric="l2", quantized="q8", **kw):
    base = {
        "num_shards": 1, "num_segments": 4, "segmenter": "apd",
        "engine": "scan", "alpha": 0.15, "metric": metric,
        "quantized": quantized,
    }
    base.update(kw)
    return LannsConfig(**base)


@pytest.fixture(scope="module")
def world():
    data = clustered_vectors(4000, 24, n_clusters=32, seed=0)
    queries = clustered_vectors(64, 24, n_clusters=32, seed=1)
    return data, queries


@pytest.mark.parametrize("metric", ["l2", "ip", "cos", "mips"])
def test_q8_recall_parity_vs_fp32(world, metric):
    data, queries = world
    k = 100
    idx_fp = LannsIndex(_cfg(metric, quantized="none")).build(data)
    idx_q8 = LannsIndex(_cfg(metric)).build(data)
    d_fp, i_fp = idx_fp.query(queries, k)
    d_q8, i_q8 = idx_q8.query(queries, k)
    rel = recall_at_k(i_q8, i_fp, k)
    assert rel >= 0.99, (metric, rel)
    # absolute recall: within a point of the fp32 path against brute force
    bf_metric = "ip" if metric == "mips" else metric
    _, ti = brute_force_topk(queries, data, k, metric=bf_metric)
    r_fp = recall_at_k(i_fp, ti, k)
    r_q8 = recall_at_k(i_q8, ti, k)
    assert r_q8 >= r_fp - 0.01, (metric, r_fp, r_q8)


@pytest.mark.parametrize("metric", ["l2", "ip", "cos"])
def test_q8_distances_are_exact(world, metric):
    """Stage 2 re-ranks against fp32 originals, so every returned distance
    equals the true metric distance of (query, returned id)."""
    data, queries = world
    idx = LannsIndex(_cfg(metric)).build(data)
    d, i = idx.query(queries, 10)
    fin = np.isfinite(d) & (i >= 0)
    got = data[np.clip(i, 0, None)]
    if metric == "l2":
        exact = ((queries[:, None, :] - got) ** 2).sum(-1)
    elif metric == "ip":
        exact = -np.einsum("bd,bkd->bk", queries, got)
    else:
        qn = queries / np.linalg.norm(queries, axis=1, keepdims=True)
        gn = got / np.maximum(
            np.linalg.norm(got, axis=-1, keepdims=True), 1e-12
        )
        exact = -np.einsum("bd,bkd->bk", qn, gn)
    assert np.allclose(d[fin], exact[fin], rtol=1e-4, atol=1e-4)


def test_rerank_factor_exceeding_segment_clamps(world):
    """Satellite bugfix: rerank_factor * k > segment size must clamp (no
    out-of-range gathers) and — since the clamp covers the whole segment —
    match the fp32 path exactly."""
    data, queries = world
    small = data[:300]  # 4 segments of ~75 rows; C = 4 * 100 >> 75
    k = 100
    idx_q8 = LannsIndex(_cfg(rerank_factor=4)).build(small)
    idx_fp = LannsIndex(_cfg(quantized="none")).build(small)
    d_q8, i_q8 = idx_q8.query(queries, k)
    d_fp, i_fp = idx_fp.query(queries, k)
    assert d_q8.shape == (len(queries), k)
    # -1 padding is preserved through re-rank and merge
    assert np.array_equal(i_q8 == -1, ~np.isfinite(d_q8))
    assert (i_q8 == -1).any(), "expected padding (segments < k rows)"
    # full-segment candidate cover -> exact == fp32 results per query
    for r in range(len(queries)):
        fin = np.isfinite(d_fp[r])
        assert set(i_q8[r][fin]) == set(i_fp[r][fin])
        assert np.allclose(np.sort(d_q8[r][fin]), np.sort(d_fp[r][fin]),
                           rtol=1e-5)


def test_rerank_store_host_device_agree(world):
    data, queries = world
    idx_h = LannsIndex(_cfg(rerank_store="host")).build(data)
    idx_d = LannsIndex(_cfg(rerank_store="device")).build(data)
    d_h, i_h = idx_h.query(queries, 20)
    d_d, i_d = idx_d.query(queries, 20)
    # both stores compute exact fp32 distances (accumulation order may
    # differ): distances agree tightly, ids up to fp ties
    assert np.allclose(d_h, d_d, rtol=1e-4, atol=1e-4, equal_nan=True)
    assert recall_at_k(i_d, i_h, 20) > 0.995


def test_physical_spill_uses_dedup_merge(world):
    data, queries = world
    cfg = _cfg(spill="physical")
    idx = LannsIndex(cfg).build(data)
    assert idx.build_stats["duplication_factor"] > 1.0
    d, i = idx.query(queries, 20)
    for row in i:
        real = row[row >= 0]
        assert len(set(real.tolist())) == len(real), "duplicate ids"
    _, ti = brute_force_topk(queries, data, 20)
    assert recall_at_k(i, ti, 15) > 0.6


def test_multi_shard_q8(world):
    data, queries = world
    idx = LannsIndex(_cfg(num_shards=2, num_segments=2)).build(data)
    idx_fp = LannsIndex(
        _cfg(num_shards=2, num_segments=2, quantized="none")
    ).build(data)
    _, i_q8 = idx.query(queries, 20)
    _, i_fp = idx_fp.query(queries, 20)
    assert recall_at_k(i_q8, i_fp, 20) >= 0.99


def test_q8_empty_batch_and_stats(world):
    data, _ = world
    idx = LannsIndex(_cfg()).build(data[:500])
    empty = np.zeros((0, data.shape[1]), np.float32)
    d, i, stats = idx.query(empty, 7, return_stats=True)
    assert d.shape == (0, 7) and i.shape == (0, 7)
    assert "scan_traces_q8" in stats and "scan_traces" in stats
    _, _, full = idx.query(data[:3], 7, return_stats=True)
    assert set(stats) == set(full)


def test_config_validation():
    with pytest.raises(ValueError, match="quantized"):
        LannsIndex(LannsConfig(quantized="int4"))
    with pytest.raises(ValueError, match="rerank_store"):
        LannsIndex(LannsConfig(engine="scan", quantized="q8",
                               rerank_store="gpu"))
    # q8 + hnsw is a supported composition now (the quantized beam); only
    # the flat stacked dispatch serves it.
    idx = LannsIndex(LannsConfig(engine="hnsw", quantized="q8"))
    with pytest.raises(ValueError, match="hnsw_mode='stacked'"):
        idx.query(np.zeros((1, 8), np.float32), 5, hnsw_mode="legacy")


def test_fp32_path_untouched_when_quantized_off(world):
    """quantized='none' must not allocate any quantized state — the fp32
    executor and its results are byte-for-byte the pre-quantization path."""
    data, queries = world
    idx = LannsIndex(_cfg(quantized="none")).build(data[:1000])
    assert all(p.q8 is None for p in idx.partitions.values())
    d, i = idx.query(queries, 10)
    # scan padding is result-transparent: compare against the unpadded scan
    from repro.kernels import ops

    part = next(iter(idx.partitions.values()))
    d0, i0 = ops.distance_topk(queries, part.vectors, 5, "l2")
    d1, i1 = ops.distance_topk(
        queries, part.scan_corpus(), 5, "l2", n_valid=part.size
    )
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    assert np.array_equal(np.asarray(i0), np.asarray(i1))

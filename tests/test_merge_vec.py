"""Vectorized merge parity vs the Python-loop reference.

These run in the minimal env (no hypothesis): seeded randomized sweeps over
the adversarial cases the offline executor actually produces — duplicate ids
from spill, -1 / +inf padding from empty partitions, ±inf distances, ties.
"""

import numpy as np
import pytest

from repro.core.merge import merge_topk_np, merge_topk_vec


def _assert_parity(d, i, k):
    rd, ri = merge_topk_np(d, i, k)
    vd, vi = merge_topk_vec(d, i, k)
    assert vd.shape == rd.shape and vi.shape == ri.shape
    assert np.array_equal(ri, vi), (ri, vi)
    assert np.array_equal(rd, vd), (rd, vd)


def test_dedups_and_sorts():
    d = np.array([[3.0, 1.0, 2.0, 1.0, np.inf]])
    i = np.array([[7, 3, 9, 3, -1]])
    vd, vi = merge_topk_vec(d, i, 3)
    assert vi.tolist() == [[3, 9, 7]]
    assert vd.tolist() == [[1.0, 2.0, 3.0]]


def test_duplicate_keeps_best_copy():
    d = np.array([[5.0, 2.0, 9.0, 4.0]], np.float32)
    i = np.array([[11, 11, 11, 3]], np.int64)
    vd, vi = merge_topk_vec(d, i, 4)
    assert vi[0, :2].tolist() == [11, 3]
    assert vd[0, :2].tolist() == [2.0, 4.0]
    assert (vi[0, 2:] == -1).all() and np.isinf(vd[0, 2:]).all()


def test_all_invalid_pads():
    d = np.full((2, 6), np.inf, np.float32)
    i = np.full((2, 6), -1, np.int64)
    vd, vi = merge_topk_vec(d, i, 3)
    assert (vi == -1).all() and np.isinf(vd).all()


def test_neg_inf_dropped_like_reference():
    # merge_topk_np skips ±inf distances; the vectorized path must agree.
    d = np.array([[-np.inf, 1.0, np.inf, 0.5]], np.float32)
    i = np.array([[4, 5, 6, 7]], np.int64)
    _assert_parity(d, i, 3)
    vd, vi = merge_topk_vec(d, i, 3)
    assert vi.tolist() == [[7, 5, -1]]


def test_k_larger_than_candidates():
    d = np.array([[2.0, 1.0]], np.float32)
    i = np.array([[5, 9]], np.int64)
    vd, vi = merge_topk_vec(d, i, 5)
    assert vi.tolist() == [[9, 5, -1, -1, -1]]
    assert np.isinf(vd[0, 2:]).all()


def test_leading_axes_preserved():
    rng = np.random.default_rng(0)
    d = rng.standard_normal((3, 4, 20)).astype(np.float32)
    i = rng.integers(0, 15, (3, 4, 20)).astype(np.int64)
    vd, vi = merge_topk_vec(d, i, 6)
    assert vd.shape == (3, 4, 6) and vi.shape == (3, 4, 6)
    _assert_parity(d, i, 6)


@pytest.mark.parametrize("seed", range(8))
def test_randomized_parity_sweep(seed):
    rng = np.random.default_rng(seed)
    for _ in range(40):
        R = int(rng.integers(1, 5))
        C = int(rng.integers(1, 60))
        k = int(rng.integers(1, 25))
        # small id range => heavy duplication; -1 sprinkled in
        ids = rng.integers(-1, max(C // 2, 2), (R, C)).astype(np.int64)
        # quantized distances => ties; ±inf sprinkled in
        d = (rng.integers(0, 10, (R, C)) / 4.0).astype(np.float32)
        d[rng.random((R, C)) < 0.15] = np.inf
        d[rng.random((R, C)) < 0.05] = -np.inf
        _assert_parity(d, ids, k)


def test_valid_id_equal_to_sentinel_survives():
    """A valid candidate whose id equals iinfo(dtype).max (the internal
    invalid-id sentinel) must not be dropped."""
    imax = np.iinfo(np.int32).max
    d = np.array([[0.5, 1.0, np.inf]], np.float32)
    i = np.array([[imax, 5, -1]], np.int32)
    _assert_parity(d, i, 3)
    vd, vi = merge_topk_vec(d, i, 2)
    assert vi.tolist() == [[imax, 5]]
    assert vd.tolist() == [[0.5, 1.0]]
    # and a duplicated sentinel-valued id still dedups to its best copy
    d = np.array([[2.0, 0.25]], np.float32)
    i = np.array([[imax, imax]], np.int32)
    _assert_parity(d, i, 2)


def test_float_ids_parity():
    """two_level_merge_np historically accepted float id arrays."""
    rng = np.random.default_rng(5)
    d = rng.standard_normal((3, 16)).astype(np.float32)
    i = rng.integers(-1, 9, (3, 16)).astype(np.float64)
    _assert_parity(d, i, 5)
    _, vi = merge_topk_vec(d, i, 5)
    assert vi.dtype == np.float64


def test_int32_ids_dtype_preserved():
    rng = np.random.default_rng(3)
    d = rng.standard_normal((2, 12)).astype(np.float32)
    i = rng.integers(-1, 8, (2, 12)).astype(np.int32)
    vd, vi = merge_topk_vec(d, i, 4)
    assert vi.dtype == np.int32 and vd.dtype == np.float32
    _assert_parity(d, i, 4)


# ---------------------------------------------------------------------------
# jitted (jnp) merge_topk — same two-lexsort formulation, same parity bar
# ---------------------------------------------------------------------------


def _assert_jit_parity(d, i, k):
    from repro.core.merge import merge_topk

    rd, ri = merge_topk_np(d, i, k)
    jd, ji = merge_topk(d, i, k)
    assert np.array_equal(ri, np.asarray(ji).astype(i.dtype)), (ri, ji)
    assert np.array_equal(rd, np.asarray(jd)), (rd, jd)


def test_jit_dedups_sorts_and_pads():
    d = np.array([[3.0, 1.0, 2.0, 1.0, np.inf]], np.float32)
    i = np.array([[7, 3, 9, 3, -1]], np.int64)
    _assert_jit_parity(d, i, 3)
    _assert_jit_parity(d, i, 8)  # k > C pads with (inf, -1)


def test_jit_randomized_adversarial_sweep():
    rng = np.random.default_rng(123)
    for _ in range(40):
        C = int(rng.integers(1, 48))
        k = int(rng.integers(1, 24))
        R = 4
        ids = rng.integers(-1, max(int(C * 0.7), 1), (R, C)).astype(np.int64)
        d = (rng.integers(0, 8, (R, C)) / 4.0).astype(np.float32)
        d[rng.random((R, C)) < 0.2] = np.inf
        d[rng.random((R, C)) < 0.1] = -np.inf
        _assert_jit_parity(d, ids, k)


def test_jit_matches_vec_on_executor_shapes():
    """The exact shapes LannsIndex.query feeds the merges."""
    rng = np.random.default_rng(7)
    B, S, routes, pstk = 6, 2, 3, 5
    d = rng.standard_normal((B * S, routes * pstk)).astype(np.float32)
    i = rng.integers(0, 40, (B * S, routes * pstk)).astype(np.int64)
    _assert_jit_parity(d, i, pstk)

"""Async host loop: determinism vs sync step(), loadgen seeding, shutdown.

The acceptance contract of the threaded front end is that threading changes
WHEN work happens, never WHAT is computed: for identical formed batches the
results are bit-identical to the synchronous ``step()`` path (same
``_execute``), arrival schedules are pure functions of their seed, and
shutdown either drains (every in-flight query answered) or cancels (every
waiter released) — nothing blocks forever.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core import LannsConfig, LannsIndex
from repro.data.synthetic import clustered_vectors
from repro.serve.engine import AnnFrontend, AsyncAnnFrontend
from repro.serve.loadgen import (
    arrival_gaps,
    measure_saturation_qps,
    run_load_point,
)

# generous CI margin: every wait in this file bounds a thread the test has
# already made runnable, so the timeout only matters on a wedged box
WAIT_S = 30.0


@pytest.fixture(scope="module")
def index_and_queries():
    data = clustered_vectors(1500, 16, n_clusters=16, seed=0)
    queries = clustered_vectors(48, 16, n_clusters=16, seed=1)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="scan")
    idx = LannsIndex(cfg).build(data)
    idx.warm_traces(8, 10)
    return idx, queries


def test_bit_identical_to_sync_step(index_and_queries):
    """Same formed batches (FIFO slices of max_batch) => bit-identical
    results, per request, against both the sync frontend and direct query."""
    idx, queries = index_and_queries
    sync = AnnFrontend(idx, topk=10, max_batch=8, max_wait_ms=1e9)
    sreqs = [sync.submit(q) for q in queries[:40]]
    sync.step()  # five full batches
    with AsyncAnnFrontend(idx, topk=10, max_batch=8, max_wait_ms=1e9) as fe:
        areqs = [fe.submit(q) for q in queries[:40]]
        assert all(r.wait(WAIT_S) for r in areqs)
    assert all(r.done for r in areqs)
    for a, s in zip(areqs, sreqs):
        assert np.array_equal(a.ids, s.ids)
        assert np.array_equal(a.dists, s.dists)
    # and against the raw executor on the same formed batches
    for lo in range(0, 40, 8):
        d, i = idx.query(queries[lo: lo + 8], 10)
        got_d = np.stack([r.dists for r in areqs[lo: lo + 8]])
        got_i = np.stack([r.ids for r in areqs[lo: lo + 8]])
        assert np.array_equal(got_i, np.asarray(i))
        assert np.array_equal(got_d, np.asarray(d))


def test_deadline_flush_without_new_submits(index_and_queries):
    """The batcher thread wakes ITSELF at the max_wait deadline — a partial
    batch completes with no further submissions and no step() calls."""
    idx, queries = index_and_queries
    fe = AsyncAnnFrontend(idx, topk=5, max_batch=64, max_wait_ms=20.0)
    fe.start()
    try:
        reqs = [fe.submit(q) for q in queries[:3]]
        assert all(r.wait(WAIT_S) for r in reqs)
        assert all(r.done for r in reqs)
        assert fe.stats["deadline_batches"] >= 1
        assert reqs[0].batch_size == 3
    finally:
        fe.stop()


def test_timestamps_ordered(index_and_queries):
    idx, queries = index_and_queries
    with AsyncAnnFrontend(idx, topk=5, max_batch=4, max_wait_ms=5.0) as fe:
        reqs = [fe.submit(q) for q in queries[:4]]
        assert all(r.wait(WAIT_S) for r in reqs)
    for r in reqs:
        assert r.t_submit <= r.t_start <= r.t_done
        assert r.latency_s >= r.queue_s >= 0.0


def test_graceful_drain_with_in_flight(index_and_queries):
    """stop(drain=True) answers everything pending — max_wait is effectively
    infinite here, so ONLY the drain path can complete these."""
    idx, queries = index_and_queries
    fe = AsyncAnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=1e9)
    fe.start()
    reqs = [fe.submit(q) for q in queries[:21]]
    completed = fe.stop(drain=True)
    assert all(r.done for r in reqs)
    assert not any(r.cancelled for r in reqs)
    assert len(completed) == 21
    # 21 = 2 full batches of 8 + one forced remainder of 5
    assert fe.batch_hist.get(8) == 2 and fe.batch_hist.get(5) == 1


def test_stop_without_drain_cancels(index_and_queries):
    idx, queries = index_and_queries
    fe = AsyncAnnFrontend(idx, topk=5, max_batch=64, max_wait_ms=1e9)
    fe.start()
    reqs = [fe.submit(q) for q in queries[:3]]
    fe.stop(drain=False)
    assert all(r.wait(WAIT_S) for r in reqs)  # events fire on cancel too
    assert all(r.cancelled and not r.done for r in reqs)
    with pytest.raises(RuntimeError):
        fe.submit(queries[0])


def test_stop_without_drain_beats_full_queue(index_and_queries):
    """Even with >= max_batch pending, stop(drain=False) cancels instead of
    serving full batches (the cancel-stop has priority in the loop)."""
    idx, queries = index_and_queries
    fe = AsyncAnnFrontend(idx, topk=5, max_batch=4, max_wait_ms=1e9)
    fe.start()
    # submit under the lock-free API fast; some may already be served before
    # stop lands, but everything NOT served must be cancelled, never stuck
    reqs = [fe.submit(q) for q in queries[:32]]
    fe.stop(drain=False, timeout=WAIT_S)
    assert all(r.wait(WAIT_S) for r in reqs)
    for r in reqs:
        assert r.done != r.cancelled  # exactly one outcome, none stranded
    assert any(r.cancelled for r in reqs)  # 32 can't all finish pre-stop


def test_lifecycle_errors(index_and_queries):
    idx, queries = index_and_queries
    fe = AsyncAnnFrontend(idx, topk=5, max_batch=8)
    with pytest.raises(RuntimeError):  # not started
        fe.submit(queries[0])
    fe.start()
    with pytest.raises(RuntimeError):  # double start
        fe.start()
    with pytest.raises(RuntimeError):  # driven by its own thread
        fe.step()
    with pytest.raises(RuntimeError):
        fe.flush()
    fe.stop()
    # restartable after a clean stop
    fe.start()
    req = fe.submit(queries[0])
    fe.stop(drain=True)
    assert req.done


def test_batcher_crash_releases_all_waiters(index_and_queries):
    """A query() crash must cancel the in-flight batch AND everything still
    pending (waiters wake), surface on the next submit, and never hang."""
    idx, queries = index_and_queries

    class Boom:
        def query(self, *a, **kw):
            # linger before raising so the OTHER submissions are pending
            # when the crash lands (deterministic regardless of scheduling)
            time.sleep(0.2)
            raise ValueError("boom")

    fe = AsyncAnnFrontend(Boom(), topk=5, max_batch=2, max_wait_ms=1e9)
    fe.start()
    # 5 submissions, max_batch=2: the first full batch crashes; the other 3
    # are still pending at crash time and must be cancelled, not stranded
    reqs = [fe.submit(q) for q in queries[:5]]
    assert all(r.wait(WAIT_S) for r in reqs)
    assert all(r.cancelled and not r.done for r in reqs)
    with pytest.raises(RuntimeError, match="batcher thread died"):
        fe.submit(queries[0])
    fe.stop()


def test_restart_after_crash_is_clean(index_and_queries):
    """stop() + start() after a crash clears the stale error and completed
    list — the restarted frontend serves normally."""
    idx, queries = index_and_queries

    class Flaky:
        def __init__(self, real):
            self.real, self.broken = real, True

        def query(self, *a, **kw):
            if self.broken:
                raise ValueError("boom")
            return self.real.query(*a, **kw)

    flaky = Flaky(idx)
    fe = AsyncAnnFrontend(flaky, topk=5, max_batch=2, max_wait_ms=1e9)
    fe.start()
    bad = [fe.submit(q) for q in queries[:2]]
    assert all(r.wait(WAIT_S) for r in bad) and fe.error is not None
    fe.stop()
    flaky.broken = False
    fe.start()
    assert fe.error is None and fe.completed == []
    good = fe.submit(queries[0])
    completed = fe.stop(drain=True)
    assert good.done and not good.cancelled
    assert completed == [good]


def test_collect_stats_flow_through(index_and_queries):
    """Routing/trace stats reach the async frontend exactly as in sync mode
    (the signal source for online alpha/capacity auto-tuning)."""
    idx, queries = index_and_queries
    with AsyncAnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=5.0,
                          collect_stats=True) as fe:
        reqs = [fe.submit(q) for q in queries[:8]]
        assert all(r.wait(WAIT_S) for r in reqs)
    qs = fe.last_query_stats
    assert qs is not None
    assert qs["per_shard_topk"] <= 5
    assert qs["merge_path"] == "disjoint"  # scan engine + virtual spill
    assert "beam_traces" in qs and "scan_traces" in qs
    assert 1.0 <= fe.mean_segments_visited <= idx.config.num_segments


def test_concurrent_submitters(index_and_queries):
    """submit() is thread-safe: N producer threads, every request answered
    exactly once, uids unique."""
    idx, queries = index_and_queries
    with AsyncAnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=2.0) as fe:
        out: list = []
        lock = threading.Lock()

        def producer(ci):
            reqs = [fe.submit(queries[(ci * 12 + j) % len(queries)])
                    for j in range(12)]
            with lock:
                out.extend(reqs)

        threads = [threading.Thread(target=producer, args=(ci,))
                   for ci in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert all(r.wait(WAIT_S) for r in out)
    assert len(out) == 48 and all(r.done for r in out)
    assert len({r.uid for r in out}) == 48
    assert fe.stats["completed"] == 48
    assert sum(b * c for b, c in fe.batch_hist.items()) == 48


# ---------------------------------------------------------------------------
# loadgen: arrival-process seeding + end-to-end load points
# ---------------------------------------------------------------------------


def test_arrival_gaps_seeding_reproducible():
    g1 = arrival_gaps("poisson", 100.0, 64, seed=7)
    g2 = arrival_gaps("poisson", 100.0, 64, seed=7)
    g3 = arrival_gaps("poisson", 100.0, 64, seed=8)
    assert np.array_equal(g1, g2)
    assert not np.array_equal(g1, g3)
    assert (g1 > 0).all()
    # mean inter-arrival ~ 1/rate (loose: 64 exponential draws)
    assert 0.3 / 100 < g1.mean() < 3.0 / 100
    fixed = arrival_gaps("fixed", 50.0, 8)
    assert np.allclose(fixed, 1.0 / 50)


def test_arrival_gaps_validation():
    with pytest.raises(ValueError):
        arrival_gaps("closed", 100.0, 8)
    with pytest.raises(ValueError):
        arrival_gaps("poisson", 0.0, 8)
    with pytest.raises(ValueError):
        arrival_gaps("weibull", 100.0, 8)


def test_run_load_point_poisson(index_and_queries):
    idx, queries = index_and_queries
    res = run_load_point(
        idx, queries, process="poisson", rate_qps=300.0, duration_s=0.3,
        topk=5, max_batch=8, max_wait_ms=2.0, seed=3,
    )
    assert res.process == "poisson" and res.offered_qps == 300.0
    assert res.completed > 0 and res.cancelled == 0
    assert res.completed == res.submitted
    assert res.achieved_qps > 0
    assert np.isfinite([res.p50_ms, res.p95_ms, res.p99_ms]).all()
    assert res.p50_ms <= res.p95_ms <= res.p99_ms
    assert sum(b * c for b, c in res.batch_hist.items()) == res.completed
    # row() is JSON-ready (the BENCH_latency_load.json contract)
    encoded = json.dumps(res.row())
    assert "p99_ms" in encoded and "batch_hist" in encoded


def test_run_load_point_closed(index_and_queries):
    idx, queries = index_and_queries
    res = measure_saturation_qps(
        idx, queries, duration_s=0.3, topk=5, max_batch=8, max_wait_ms=2.0,
        concurrency=4,
    )
    assert res.process == "closed" and res.concurrency == 4
    assert np.isnan(res.offered_qps)  # load is implicit in closed loop
    assert res.completed > 0 and res.cancelled == 0
    assert res.mean_batch <= 8


def test_run_load_point_validation(index_and_queries):
    idx, queries = index_and_queries
    with pytest.raises(ValueError):
        run_load_point(idx, queries, process="poisson", rate_qps=None)
    with pytest.raises(ValueError):
        run_load_point(idx, queries, process="uniform", rate_qps=10.0)


# ---------------------------------------------------------------------------
# MMPP bursty arrivals + per-request knobs under load
# ---------------------------------------------------------------------------


def test_mmpp_gaps_seeded_and_bursty():
    """Pure in (process, rate, n, seed); mean rate ~ rate_qps; squared
    coefficient of variation far above Poisson's 1 (the burstiness)."""
    g1 = arrival_gaps("mmpp", 400.0, 3000, seed=11)
    g2 = arrival_gaps("mmpp", 400.0, 3000, seed=11)
    g3 = arrival_gaps("mmpp", 400.0, 3000, seed=12)
    assert np.array_equal(g1, g2)
    assert not np.array_equal(g1, g3)
    assert (g1 >= 0).all()
    # long-run rate ~ rate_qps (loose: ON/OFF cycles inflate the variance)
    assert 0.2 / 400 < g1.mean() < 5.0 / 400
    cv2 = (g1.std() / g1.mean()) ** 2
    gp = arrival_gaps("poisson", 400.0, 3000, seed=11)
    cv2_poisson = (gp.std() / gp.mean()) ** 2
    assert cv2 > 3.0 * cv2_poisson, (cv2, cv2_poisson)
    # on_frac=1 degenerates to plain Poisson statistics (cv2 ~ 1)
    g_on = arrival_gaps("mmpp", 400.0, 3000, seed=11, mmpp_on_frac=1.0)
    assert 0.5 < (g_on.std() / g_on.mean()) ** 2 < 2.0


def test_mmpp_validation():
    with pytest.raises(ValueError, match="mmpp_on_frac"):
        arrival_gaps("mmpp", 100.0, 8, mmpp_on_frac=0.0)
    with pytest.raises(ValueError, match="mmpp_on_frac"):
        arrival_gaps("mmpp", 100.0, 8, mmpp_on_frac=1.5)
    with pytest.raises(ValueError, match="mmpp_cycle_s"):
        arrival_gaps("mmpp", 100.0, 8, mmpp_cycle_s=0.0)


def test_async_per_request_knobs_bit_identical(index_and_queries):
    """Requests with mixed (topk, ef) ride one formed batch; each result is
    bit-identical to the direct mixed query over the same batch."""
    idx, queries = index_and_queries
    with AsyncAnnFrontend(idx, topk=10, max_batch=8, max_wait_ms=1e9) as fe:
        reqs = []
        for j in range(8):
            reqs.append(fe.submit(
                queries[j],
                topk=(5 if j % 2 else None),
                ef=(32 if j in (2, 3) else None),
            ))
        assert all(r.wait(WAIT_S) for r in reqs)
    tk = np.array([10 if r.topk is None else r.topk for r in reqs])
    ef = np.array([0 if r.ef is None else r.ef for r in reqs])
    d, i = idx.query(queries[:8], tk, ef=ef)
    for j, r in enumerate(reqs):
        assert r.dists.shape == (tk[j],) and r.ids.shape == (tk[j],)
        assert np.array_equal(r.ids, i[j, : tk[j]])
        assert np.array_equal(r.dists, d[j, : tk[j]])


def test_invalid_knobs_fail_at_submit_not_in_batcher(index_and_queries):
    """A bad per-request knob must raise in the SUBMITTER's thread and
    leave the batcher (and every other request) unharmed."""
    idx, queries = index_and_queries
    with AsyncAnnFrontend(idx, topk=10, max_batch=4, max_wait_ms=5.0) as fe:
        with pytest.raises(ValueError, match="topk"):
            fe.submit(queries[0], topk=0)
        with pytest.raises(ValueError, match="ef"):
            fe.submit(queries[0], ef=-5)
        good = fe.submit(queries[1], topk=3)
        assert good.wait(WAIT_S) and good.done
        assert fe.error is None
    sync = AnnFrontend(idx, topk=10, max_batch=4)
    with pytest.raises(ValueError, match="topk"):
        sync.submit(queries[0], topk=0)


def test_run_load_point_mmpp_with_knob_mix(index_and_queries):
    """MMPP arrivals + a deterministic (topk, ef) mix: everything submitted
    completes, and per-request result widths follow the mix."""
    idx, queries = index_and_queries
    mix = [(None, None), (5, None), (20, 48)]
    res = run_load_point(
        idx, queries, process="mmpp", rate_qps=300.0, duration_s=0.3,
        topk=10, max_batch=8, max_wait_ms=2.0, seed=5, knob_mix=mix,
    )
    assert res.process == "mmpp" and res.offered_qps == 300.0
    assert res.completed > 0 and res.completed == res.submitted
    assert sum(b * c for b, c in res.batch_hist.items()) == res.completed


# ---------------------------------------------------------------------------
# telemetry: queue-delay decomposition + per-stage breakdown under load
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("process", ["poisson", "fixed", "mmpp"])
def test_queue_decomposition_accounts_for_latency(index_and_queries, process):
    """Per request, the t_submit/t_start/t_done timestamps decompose exactly:
    queue delay + execution time == end-to-end latency (all three read the
    same monotonic clock, so the identity is algebraic, not approximate)."""
    idx, queries = index_and_queries
    fe = AsyncAnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=2.0)
    gaps = arrival_gaps(process, 300.0, 64, seed=7)
    fe.start()
    try:
        reqs = []
        for j, g in enumerate(gaps[:40]):
            time.sleep(min(g, 5e-3))
            reqs.append(fe.submit(queries[j % len(queries)]))
    finally:
        fe.stop(drain=True)
    assert all(r.done for r in reqs)
    for r in reqs:
        exec_s = r.t_done - r.t_start
        assert exec_s >= 0.0 and r.queue_s >= 0.0
        assert r.queue_s + exec_s == pytest.approx(r.latency_s, abs=1e-9)


def test_run_load_point_stage_breakdown(index_and_queries):
    """With a Telemetry attached, the load point reports per-stage
    percentiles covering the whole pipeline, and the queue + exec means
    re-compose the end-to-end mean."""
    from repro.obs import STAGES, Telemetry

    idx, queries = index_and_queries
    tel = Telemetry()
    res = run_load_point(
        idx, queries, process="poisson", rate_qps=300.0, duration_s=0.3,
        topk=5, max_batch=8, max_wait_ms=2.0, seed=9, telemetry=tel,
    )
    assert idx.telemetry is None  # restored after the point
    assert res.completed > 0
    assert set(STAGES) <= set(res.stage_breakdown)
    for st in STAGES:
        pct = res.stage_breakdown[st]
        assert pct["n"] > 0
        assert 0.0 <= pct["p50_ms"] <= pct["p95_ms"] <= pct["p99_ms"]
    # decomposition: mean latency == mean queue + mean exec (same requests)
    assert res.mean_queue_ms + res.mean_exec_ms == pytest.approx(
        res.mean_ms, rel=1e-6
    )
    # the breakdown's queue row is the same per-request queue population
    assert res.stage_breakdown["queue"]["n"] == res.completed
    # and the spans/metrics made it to the shared sinks
    assert len(tel.spans) > 0
    assert "lanns_stage_seconds" in tel.registry.expose_text()
    # without telemetry the result shape degrades gracefully
    res0 = run_load_point(
        idx, queries, process="poisson", rate_qps=300.0, duration_s=0.1,
        topk=5, max_batch=8, max_wait_ms=2.0, seed=9,
    )
    assert res0.stage_breakdown == {}

"""Substrate tests: optimizer, checkpointing, elasticity, sampler, pipeline,
compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ShardedBatchIterator
from repro.data.sampler import CSRGraph, sample_neighbors, sample_subgraph
from repro.data.synthetic import power_law_graph
from repro.distributed.compression import (
    dequantize_int8,
    error_feedback_compress,
    quantize_int8,
    topk_sparsify,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import (
    ShardPlacement,
    StragglerMonitor,
    escalation_plan,
    replan_on_failure,
)
from repro.train.optimizer import AdamWConfig, adamw_update, init_state, lr_schedule


# -- optimizer ---------------------------------------------------------------


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    state = init_state(params)
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert np.allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-3)


def test_grad_clip_applied():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    state = init_state(params)
    _, _, m = adamw_update(cfg, params, {"w": jnp.full(4, 100.0)}, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# -- checkpoint ---------------------------------------------------------------


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(r.standard_normal((4, 5)).astype(np.float32)),
        "b": {"c": jnp.asarray(r.integers(0, 9, 7).astype(np.int32))},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_n=2)
    t = _tree(1)
    mgr.save(3, t, extra={"loss": 1.5})
    step, restored, extra = mgr.restore_latest(t)
    assert step == 3 and extra["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_n=2)
    t = _tree(2)
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(3)
    mgr.save(1, t)
    path = os.path.join(str(tmp_path), "step_0000000001", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(30)
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(IOError, match="integrity"):
        mgr.restore(1, t)


def test_checkpoint_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(4)
    mgr.save(1, t)
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.zeros(7, jnp.int32)}}
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, bad)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    t = _tree(5)
    mgr.save(1, t)
    mgr.wait()
    assert mgr.latest_step() == 1


# -- elastic -------------------------------------------------------------------


def test_replan_minimal_movement():
    p = ShardPlacement.initial(num_hosts=8, num_shards=32)
    p2 = replan_on_failure(p, failed_hosts=[3])
    moved = sum(a != b for a, b in zip(p.assignment, p2.assignment))
    assert moved == 4  # only shards of host 3
    assert all(h != 3 for h in p2.assignment)
    assert p2.generation == 1
    # balanced: max load 5, min 4
    load = p2.load()
    assert load[np.arange(8) != 3].max() <= 5


def test_replan_cascading_failures():
    p = ShardPlacement.initial(num_hosts=4, num_shards=8)
    p = replan_on_failure(p, [0])
    p = replan_on_failure(p, [1])
    assert set(p.assignment) <= {2, 3}
    with pytest.raises(RuntimeError):
        replan_on_failure(p, [2, 3])


def test_escalation_plan():
    fb = escalation_plan(data_axis=16, model_axis=16, lost_devices=16)
    assert fb.data == 8 and fb.model == 16
    assert fb.per_device_batch_scale == 2.0
    fb = escalation_plan(16, 16, lost_devices=1)  # one chip kills a TP group
    assert fb.data == 8
    assert escalation_plan(2, 16, lost_devices=32) is None


def test_straggler_detection_and_duplicates():
    mon = StragglerMonitor(num_hosts=4, min_samples=3, ratio=1.5)
    for _ in range(5):
        for h, t in enumerate([1.0, 1.0, 1.0, 3.0]):
            mon.observe(h, t)
    assert mon.stragglers() == [3]
    p = ShardPlacement.initial(num_hosts=4, num_shards=8)
    dup = mon.speculative_duplicates(p)
    assert set(dup.keys()) == set(p.shards_of(3))
    assert all(v != 3 for v in dup.values())


# -- sampler -------------------------------------------------------------------


def test_csr_and_neighbor_sampling():
    g = power_law_graph(200, 2000, seed=0)
    csr = CSRGraph.from_edge_index(g["edge_index"], 200)
    assert csr.n_nodes == 200
    rng = np.random.default_rng(0)
    nodes = np.array([0, 1, 2, 3])
    nbrs = sample_neighbors(csr, nodes, 8, rng)
    assert nbrs.shape == (4, 8)
    for r, n in zip(nbrs, nodes):
        deg = csr.degree(np.array([n]))[0]
        if deg > 0:
            row_nbrs = csr.indices[csr.indptr[n]: csr.indptr[n + 1]]
            assert set(r.tolist()) <= set(row_nbrs.tolist())
        else:
            assert np.all(r == -1)


def test_subgraph_sampling_shapes_and_locality():
    g = power_law_graph(500, 5000, seed=1)
    csr = CSRGraph.from_edge_index(g["edge_index"], 500)
    rng = np.random.default_rng(1)
    sub = sample_subgraph(
        csr, np.arange(16), (5, 3), rng=rng, n_max=512, e_max=1024
    )
    assert sub["nodes"].shape == (512,)
    assert sub["edge_index"].shape == (2, 1024)
    assert sub["seed_mask"][:16].all()
    ei = sub["edge_index"]
    valid = ei[0] >= 0
    assert np.all(ei[:, valid] < 512)
    # every edge endpoint is a real node of the subgraph
    assert np.all(sub["nodes"][ei[0][valid]] >= 0)


# -- pipeline ------------------------------------------------------------------


def test_sharded_batch_iterator_determinism_and_slicing():
    def batch_fn(seed, step):
        r = np.random.default_rng(seed * 1000 + step)
        return {"x": r.standard_normal((8, 3)).astype(np.float32)}

    it0 = ShardedBatchIterator(batch_fn, seed=7, host_index=0, num_hosts=2)
    it1 = ShardedBatchIterator(batch_fn, seed=7, host_index=1, num_hosts=2)
    s0, b0 = next(it0)
    s1, b1 = next(it1)
    assert s0 == s1 == 0
    full = batch_fn(7, 0)["x"]
    assert np.array_equal(b0["x"], full[:4])
    assert np.array_equal(b1["x"], full[4:])
    it0.close()
    it1.close()


def test_pipeline_resume_from_step():
    def batch_fn(seed, step):
        return {"x": np.full((2, 1), step, dtype=np.float32)}

    it = ShardedBatchIterator(batch_fn, seed=0, start_step=5)
    s, b = next(it)
    assert s == 5 and b["x"][0, 0] == 5
    it.close()


# -- compression -----------------------------------------------------------------


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000) * 3)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x)).max()
    assert err <= float(scale) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    res = jnp.zeros(512)
    total_naive = jnp.zeros(512)
    total_ef = jnp.zeros(512)
    for _ in range(50):
        q, s = quantize_int8(g)
        total_naive = total_naive + dequantize_int8(q, s)
        qs, res_tree = error_feedback_compress({"g": g}, {"g": res})
        res = res_tree["g"]
        qe, se = qs["g"]
        total_ef = total_ef + dequantize_int8(qe, se)
    want = np.asarray(g) * 50
    err_naive = np.abs(np.asarray(total_naive) - want).max()
    err_ef = np.abs(np.asarray(total_ef) - want).max()
    assert err_ef <= err_naive + 1e-5


def test_topk_sparsify():
    x = jnp.asarray([1.0, -5.0, 0.1, 3.0])
    y, mask = topk_sparsify(x, 0.5)
    assert np.asarray(mask).sum() == 2
    assert np.asarray(y)[1] == -5.0 and np.asarray(y)[3] == 3.0

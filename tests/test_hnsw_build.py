"""Bulk-builder determinism and build-provenance tests.

The wavefront builder's contract (core/hnsw.py): for a fixed seed the frozen
graph is BIT-IDENTICAL regardless of the wavefront chunk size, of how the
points were split across add_batch calls, and of the process-pool worker
count — chunking and workers are throughput knobs only.  These tests pin
that contract, plus the amortized-growth behaviour of incremental adds and
the compact per-partition build-cost summary persisted in manifests.
"""

import json
import os

import numpy as np
import pytest

from repro.core import (
    HNSWConfig,
    HNSWIndex,
    HNSWIndexLegacy,
    LannsConfig,
    LannsIndex,
    brute_force_topk,
    recall_at_k,
)
from repro.core.lanns import (
    _build_one_partition,
    _merge_seconds_summary,
    _summarize_seconds,
)


def _corpus(n=800, d=16, seed=3):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _assert_frozen_identical(a, b):
    assert a.entry == b.entry
    np.testing.assert_array_equal(a.levels, b.levels)
    np.testing.assert_array_equal(a.adj0, b.adj0)
    np.testing.assert_array_equal(a.upper_adj, b.upper_adj)
    np.testing.assert_array_equal(a.vectors, b.vectors)
    if a.keys is not None or b.keys is not None:
        np.testing.assert_array_equal(a.keys, b.keys)


@pytest.fixture(scope="module")
def reference():
    data = _corpus()
    cfg = HNSWConfig(seed=7)
    frozen = HNSWIndex(cfg, data.shape[1]).add_batch(data).freeze()
    return data, cfg, frozen


@pytest.mark.parametrize("chunk", [1, 7, 64, 1000])
def test_chunk_invariance(reference, chunk):
    data, cfg, ref = reference
    frozen = (
        HNSWIndex(cfg, data.shape[1]).add_batch(data, chunk=chunk).freeze()
    )
    _assert_frozen_identical(frozen, ref)


@pytest.mark.parametrize("splits", [[800], [100, 700], [1, 399, 400],
                                    [37] * 21 + [23]])
def test_add_batch_split_invariance(reference, splits):
    """The RNG consumes one uniform per point in order, so splitting the
    ingest across calls cannot change level draws or insertion order."""
    data, cfg, ref = reference
    assert sum(splits) == len(data)
    idx = HNSWIndex(cfg, data.shape[1])
    lo = 0
    for sz in splits:
        idx.add_batch(data[lo: lo + sz])
        lo += sz
    _assert_frozen_identical(idx.freeze(), ref)


def test_incremental_adds_amortized(reference):
    """Re-ingest is amortized doubling: O(log n) buffer reallocations, not
    one per add_batch call (the seed reconcatenated everything each call)."""
    data, cfg, _ = reference
    idx = HNSWIndex(cfg, data.shape[1])
    reallocs = 0
    prev = id(idx._vstack)
    for lo in range(0, len(data), 50):
        idx.add_batch(data[lo: lo + 50])
        if id(idx._vstack) != prev:
            reallocs += 1
            prev = id(idx._vstack)
    assert idx._cap >= len(data)
    # 16 adds of 50 points: growth from the initial capacity to >=800 takes
    # at most a handful of doublings, never one realloc per call
    assert reallocs <= int(np.log2(len(data))) + 1


def test_worker_count_invariance():
    """workers=0 (in-process) and workers=2 (real ProcessPoolExecutor)
    produce bit-identical per-partition graphs: partitions are isolated,
    each seeded from the same config."""
    data = _corpus(n=1200, d=12, seed=5)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="rh",
                      engine="hnsw", hnsw_m=8, ef_construction=40,
                      ef_search=40)
    a = LannsIndex(cfg).build(data, workers=0)
    b = LannsIndex(cfg).build(data, workers=2)
    assert a.build_stats["build_workers"] == 0
    assert b.build_stats["build_workers"] == 2
    assert set(a.partitions) == set(b.partitions)
    for sg in a.partitions:
        _assert_frozen_identical(a.partitions[sg].frozen,
                                 b.partitions[sg].frozen)


@pytest.mark.parametrize("chunk", [32, 512])
def test_lanns_chunk_invariance(chunk):
    """The chunk knob threads through LannsIndex.build to every partition
    without changing the built graphs."""
    data = _corpus(n=1000, d=12, seed=9)
    cfg = LannsConfig(num_shards=1, num_segments=2, segmenter="rh",
                      engine="hnsw", hnsw_m=8, ef_construction=40,
                      ef_search=40)
    a = LannsIndex(cfg).build(data)
    b = LannsIndex(cfg).build(data, chunk=chunk)
    assert b.build_stats["build_chunk"] == chunk
    for sg in a.partitions:
        _assert_frozen_identical(a.partitions[sg].frozen,
                                 b.partitions[sg].frozen)


def test_resume_round_trip(tmp_path):
    """A build killed midway and resumed yields the same frozen graphs as
    an uninterrupted build, and keeps merged build-cost provenance."""
    data = _corpus(n=1200, d=12, seed=5)
    keys = np.arange(len(data), dtype=np.int64)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="rh",
                      engine="hnsw", hnsw_m=8, ef_construction=40,
                      ef_search=40)
    full = LannsIndex(cfg).build(data, keys)

    rdir = str(tmp_path / "resume")
    idx = LannsIndex(cfg)
    idx.fit(data)
    assignment = idx.partitioner.assign(data, keys)
    for g in (0, 1):
        rows = assignment.rows[0][g]
        s, gg, payload, _ = _build_one_partition(
            (0, g, data[rows], keys[rows], "hnsw", cfg.hnsw_config(), 256)
        )
        idx._save_partition(rdir, s, gg, payload)

    resumed = LannsIndex(cfg)
    resumed.fit(data)
    resumed.build(data, keys, resume_dir=rdir)
    assert set(resumed.partitions) == set(full.partitions)
    for sg in full.partitions:
        _assert_frozen_identical(resumed.partitions[sg].frozen,
                                 full.partitions[sg].frozen)
    # the resumed run only rebuilt segments 2 and 3 but its summary merged
    # the manifest-persisted provenance of the earlier run (none here: the
    # partial build above wrote partitions without a manifest, so the
    # summary covers the two partitions this run actually built)
    summary = resumed.build_stats["per_partition_seconds_summary"]
    assert summary["count"] == 2


def test_recall_parity_bulk_vs_legacy():
    """The wavefront builder's graphs must search as well as the seed's
    sequential builder — same frozen-search path, so recall isolates the
    build: gap bounded at 0.03 on this corpus (acceptance at bench scale
    is 0.01, checked in bench_build_query_scaling)."""
    data = _corpus(n=1500, d=24, seed=1)
    rng = np.random.default_rng(2)
    queries = rng.standard_normal((64, 24)).astype(np.float32)
    cfg = HNSWConfig(seed=7)
    _, gt = brute_force_topk(queries, data, 10)
    recalls = {}
    for name, cls in (("bulk", HNSWIndex), ("legacy", HNSWIndexLegacy)):
        frozen = cls(cfg, 24).add_batch(data).freeze()
        _, ids = frozen.search(queries, 10, ef=120)
        recalls[name] = recall_at_k(np.asarray(ids), np.asarray(gt), 10)
    assert recalls["bulk"] >= 0.85
    assert abs(recalls["bulk"] - recalls["legacy"]) <= 0.03


def test_seconds_summary_helpers():
    assert _summarize_seconds([]) == {}
    s = _summarize_seconds([3.0, 1.0, 2.0])
    assert s == {"min": 1.0, "median": 2.0, "max": 3.0, "total": 6.0,
                 "count": 3}
    # identity on empty sides
    assert _merge_seconds_summary({}, s) == s
    assert _merge_seconds_summary(s, {}) == s
    m = _merge_seconds_summary(s, _summarize_seconds([5.0]))
    assert m["min"] == 1.0 and m["max"] == 5.0
    assert m["total"] == 11.0 and m["count"] == 4
    # merged median is count-weighted, bounded by the inputs
    assert 2.0 <= m["median"] <= 5.0


def test_manifest_persists_summary_not_raw_seconds(tmp_path):
    """save() drops the per-partition timing dict (it scales with partition
    count) but keeps the compact summary, so resumed builds retain
    build-cost provenance."""
    data = _corpus(n=600, d=12, seed=4)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="rh",
                      engine="hnsw", hnsw_m=8, ef_construction=40,
                      ef_search=40)
    idx = LannsIndex(cfg).build(data)
    root = str(tmp_path / "saved")
    idx.save(root)
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    stats = manifest["build_stats"]
    assert "per_partition_seconds" not in stats
    summary = stats["per_partition_seconds_summary"]
    assert summary["count"] == 4
    assert summary["min"] <= summary["median"] <= summary["max"]
    assert summary["total"] >= summary["max"]

"""End-to-end behaviour tests for the LANNS platform (paper-level claims).

Each test pins one of the paper's system-level claims at CPU scale:
segmented builds beat monolithic; APD > RH in recall; perShardTopK trims the
merge payload at bounded recall cost; the whole pipeline survives a restart.
"""

import time

import numpy as np
import pytest

from repro.core import (
    HNSWConfig,
    HNSWIndex,
    LannsConfig,
    LannsIndex,
    brute_force_topk,
    per_shard_topk,
    recall_at_k,
)
from repro.data.synthetic import sift_like


@pytest.fixture(scope="module")
def world():
    corpus, queries = sift_like(8000, 48, 200, seed=5)
    truth = brute_force_topk(queries, corpus, 100)
    return corpus, queries, truth


def test_segmented_build_is_faster_per_partition(world):
    """Paper Tables 2/5: the build speedup comes from partition independence
    + superlinear per-index cost; per-partition build must be << monolithic
    and partitions must be parallelizable (no shared state)."""
    corpus, _, _ = world
    t0 = time.perf_counter()
    mono = HNSWIndex(HNSWConfig(M=8, ef_construction=60), corpus.shape[1])
    mono.add_batch(corpus)
    t_mono = time.perf_counter() - t0

    cfg = LannsConfig(num_shards=1, num_segments=8, segmenter="rs",
                      engine="hnsw", hnsw_m=8, ef_construction=60)
    idx = LannsIndex(cfg).build(corpus)
    per_part = list(idx.build_stats["per_partition_seconds"].values())
    assert len(per_part) == 8
    # 8-executor makespan ~ max partition time; paper reports ~10x at e=8
    assert max(per_part) < t_mono / 3.0
    assert sum(per_part) < t_mono * 1.2  # total work doesn't blow up


def test_apd_beats_rh_recall(world):
    """Paper Tables 1/4: APD (data-dependent) > RH (random) in recall at the
    same partitioning — the reason the smarter segmenter exists."""
    corpus, queries, (td, ti) = world
    recalls = {}
    for seg in ("rh", "apd"):
        cfg = LannsConfig(num_shards=1, num_segments=8, segmenter=seg,
                          engine="scan", alpha=0.15)
        idx = LannsIndex(cfg).build(corpus)
        _, ids = idx.query(queries, 100)
        recalls[seg] = recall_at_k(ids, ti, 100)
    assert recalls["apd"] > recalls["rh"], recalls


def test_pershard_topk_bounded_recall_cost(world):
    """§5.3.2: trimming to perShardTopK keeps R@100 within a few points of
    the untrimmed merge while cutting payload ~5-10x."""
    corpus, queries, (td, ti) = world
    base = LannsConfig(num_shards=8, num_segments=1, segmenter="rs",
                       engine="scan", topk_confidence=0.999999)
    trim = LannsConfig(num_shards=8, num_segments=1, segmenter="rs",
                       engine="scan", topk_confidence=0.95)
    _, ids_full = LannsIndex(base).build(corpus).query(queries, 100)
    _, ids_trim = LannsIndex(trim).build(corpus).query(queries, 100)
    r_full = recall_at_k(ids_full, ti, 100)
    r_trim = recall_at_k(ids_trim, ti, 100)
    pstk = per_shard_topk(100, 8, 0.95)
    assert pstk <= 25  # >= 4x payload saving
    assert r_full > 0.999
    assert r_trim > r_full - 0.05, (r_trim, r_full)


def test_full_pipeline_restart(tmp_path, world):
    """Build, save, 'lose the process', reload, same answers (§5.3.1 /
    online-serving deserialization §7)."""
    corpus, queries, _ = world
    cfg = LannsConfig(num_shards=2, num_segments=4, segmenter="apd",
                      engine="scan")
    idx = LannsIndex(cfg).build(corpus[:4000])
    d1, i1 = idx.query(queries, 50)
    idx.save(str(tmp_path / "prod"))
    del idx
    idx2 = LannsIndex.load(str(tmp_path / "prod"))
    d2, i2 = idx2.query(queries, 50)
    assert np.array_equal(i1, i2)


def test_scan_and_hnsw_engines_agree(world):
    """The TPU-native dense engine and the paper's HNSW engine answer the
    same routed queries with consistent results (scan is exact within a
    segment, so it should dominate)."""
    corpus, queries, (td, ti) = world
    out = {}
    for engine in ("scan", "hnsw"):
        cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                          engine=engine, hnsw_m=12, ef_construction=80,
                          ef_search=150)
        idx = LannsIndex(cfg).build(corpus)
        _, ids = idx.query(queries, 100)
        out[engine] = recall_at_k(ids, ti, 100)
    assert out["scan"] >= out["hnsw"] - 0.01
    assert out["hnsw"] > out["scan"] - 0.15

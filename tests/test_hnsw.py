"""HNSW: build/search correctness, numpy/JAX parity, freeze round-trip."""

import numpy as np
import pytest

from repro.core import HNSWConfig, HNSWIndex, brute_force_topk, recall_at_k
from repro.data.synthetic import clustered_vectors


@pytest.fixture(scope="module")
def small_index():
    data = clustered_vectors(3000, 24, n_clusters=40, seed=1)
    idx = HNSWIndex(HNSWConfig(M=8, ef_construction=80, ef_search=80), 24)
    idx.add_batch(data)
    return idx, data


def test_recall_vs_brute_force(small_index):
    idx, data = small_index
    qs = clustered_vectors(64, 24, n_clusters=40, seed=2)
    td, ti = brute_force_topk(qs, data, 10)
    d, i = idx.search_np(qs, 10)
    assert recall_at_k(i, ti, 10) > 0.9


def test_jax_search_matches_numpy(small_index):
    idx, data = small_index
    qs = clustered_vectors(32, 24, n_clusters=40, seed=3)
    d_np, i_np = idx.search_np(qs, 5)
    d_j, i_j = idx.freeze().search(qs, 5)
    # identical beams modulo tie-breaks: compare distances
    assert np.allclose(np.sort(d_np, 1), np.sort(d_j, 1), rtol=1e-4, atol=1e-4)
    same = (i_np == i_j).mean()
    assert same > 0.95


def test_distances_sorted_and_unique(small_index):
    idx, data = small_index
    qs = clustered_vectors(16, 24, n_clusters=40, seed=4)
    d, i = idx.freeze().search(qs, 8)
    assert np.all(np.diff(d, axis=1) >= -1e-6), "distances must be ascending"
    for row in i:
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid), "ids must be unique"


def test_true_squared_distances(small_index):
    idx, data = small_index
    qs = clustered_vectors(8, 24, n_clusters=40, seed=5)
    d, i = idx.search_np(qs, 3)
    for qi in range(len(qs)):
        for c in range(3):
            if i[qi, c] >= 0:
                ref = np.sum((qs[qi] - data[i[qi, c]]) ** 2)
                assert abs(ref - d[qi, c]) < 1e-2 * max(ref, 1.0)


def test_ip_metric():
    data = clustered_vectors(1000, 16, n_clusters=10, seed=6)
    idx = HNSWIndex(HNSWConfig(M=8, ef_construction=60, metric="ip"), 16)
    idx.add_batch(data)
    qs = clustered_vectors(16, 16, n_clusters=10, seed=7)
    d, i = idx.search_np(qs, 5)
    td, ti = brute_force_topk(qs, data, 5, metric="ip")
    assert recall_at_k(i, ti, 5) > 0.85


def test_keys_remap():
    data = clustered_vectors(500, 8, seed=8)
    keys = np.arange(500) * 7 + 3
    idx = HNSWIndex(HNSWConfig(M=8, ef_construction=50), 8)
    idx.add_batch(data, keys)
    d, i = idx.search_np(data[:4], 1)
    assert np.array_equal(i[:, 0], keys[:4])  # self is its own NN


def test_incremental_add():
    d1 = clustered_vectors(400, 8, seed=9)
    d2 = clustered_vectors(400, 8, seed=10)
    idx = HNSWIndex(HNSWConfig(M=8, ef_construction=50), 8)
    idx.add_batch(d1)
    idx.add_batch(d2)
    assert idx.size == 800
    data = np.concatenate([d1, d2])
    qs = data[::97]
    d, i = idx.search_np(qs, 1)
    assert (i[:, 0] == np.arange(0, 800, 97)).mean() > 0.9

"""Telemetry subsystem: metrics registry, span sink, pipeline hooks.

The acceptance contract (mirrored from the serving stack's): telemetry
OBSERVES, never participates — attaching it must not change a single
result bit, and every aggregate it keeps is bounded (fixed-bucket
histograms, a capacity-capped span ring, a fixed-depth query-stats
ring) so a long-lived server cannot leak through its own instruments.
"""

import json
import threading

import numpy as np
import pytest

from repro.core import LannsConfig, LannsIndex
from repro.data.synthetic import clustered_vectors
from repro.obs import (
    Histogram,
    MetricsRegistry,
    SpanSink,
    Telemetry,
    format_stage_table,
    percentiles_ms,
    stage_breakdown,
)
from repro.serve.engine import AnnFrontend


@pytest.fixture(scope="module")
def small_index():
    data = clustered_vectors(1200, 16, n_clusters=8, seed=0)
    cfg = LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                      engine="scan")
    return LannsIndex(cfg).build(data)


@pytest.fixture(scope="module")
def queries():
    return clustered_vectors(32, 16, n_clusters=8, seed=1)


# ---------------------------------------------------------------------------
# histograms: bucket-boundary edge cases (the satellite's explicit ask)
# ---------------------------------------------------------------------------


def test_histogram_exact_boundary_lands_in_bucket():
    """Prometheus `le` semantics: a value EXACTLY on a bound counts in
    that bound's bucket (upper-inclusive), not the next one."""
    h = Histogram(buckets=(1.0, 2.0, 5.0))
    h.observe(1.0)   # on the first bound
    h.observe(2.0)   # on the second
    h.observe(1.5)   # strictly inside the second
    counts, total, count = h.snapshot()
    assert counts.tolist() == [1, 2, 0, 0]
    assert count == 3 and total == pytest.approx(4.5)


def test_histogram_overflow_bucket():
    h = Histogram(buckets=(1.0, 2.0))
    h.observe(2.0000001)  # just past the last bound
    h.observe(1e9)
    counts, _, count = h.snapshot()
    assert counts.tolist() == [0, 0, 2]  # both in the +Inf overflow slot
    assert count == 2
    # quantiles from an all-overflow population clamp to the last bound
    assert h.quantile(0.5) == 2.0


def test_histogram_observe_many_matches_loop():
    vals = [0.0003, 0.0005, 0.001, 0.0011, 0.049, 0.05, 0.051, 7.0]
    h1, h2 = Histogram(), Histogram()
    h1.observe_many(vals)
    for v in vals:
        h2.observe(v)
    c1, s1, n1 = h1.snapshot()
    c2, s2, n2 = h2.snapshot()
    assert np.array_equal(c1, c2) and n1 == n2 == len(vals)
    assert s1 == pytest.approx(s2)  # summation order differs (pairwise sum)
    h1.observe_many([])  # empty batch is a no-op
    assert h1.snapshot()[2] == len(vals)


def test_histogram_quantile_interpolates():
    h = Histogram(buckets=(1.0, 2.0, 4.0))
    h.observe_many([0.5] * 50 + [3.0] * 50)
    assert h.quantile(0.25) == pytest.approx(0.5)
    assert 2.0 <= h.quantile(0.9) <= 4.0
    assert np.isnan(Histogram().quantile(0.5))
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_validates_bounds():
    for bad in ((), (1.0, 1.0), (2.0, 1.0), (1.0, float("inf"))):
        with pytest.raises(ValueError):
            Histogram(buckets=bad)


# ---------------------------------------------------------------------------
# registry: idempotent registration, counters, pull gauges, exposition
# ---------------------------------------------------------------------------


def test_registry_idempotent_and_mismatch_raises():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", "help", ("a",))
    c2 = reg.counter("x_total", "other help", ("a",))
    assert c1 is c2  # same (name, kind, labels) -> the existing family
    with pytest.raises(ValueError):  # kind mismatch
        reg.gauge("x_total")
    with pytest.raises(ValueError):  # label-schema mismatch
        reg.counter("x_total", labelnames=("a", "b"))
    with pytest.raises(ValueError):  # invalid name
        reg.counter("9bad-name")


def test_counter_monotonic():
    reg = MetricsRegistry()
    c = reg.counter("ops_total")
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    with pytest.raises(ValueError):
        c.inc(-1.0)


def test_gauge_set_function_pull_mode():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(3.0)
    assert g.value == 3.0
    state = {"v": 7}
    g.set_function(lambda: state["v"])
    assert g.value == 7.0
    state["v"] = 9
    assert g.value == 9.0  # read at collection time, not registration
    g.set(1.0)  # a set() drops back to push mode
    assert g.value == 1.0


def test_labels_validation():
    reg = MetricsRegistry()
    fam = reg.counter("req_total", labelnames=("kind", "engine"))
    fam.labels("full", "scan").inc()
    fam.labels(kind="full", engine="scan").inc(2)
    assert fam.labels("full", "scan").value == 3.0
    with pytest.raises(ValueError):
        fam.labels("full")  # arity mismatch
    with pytest.raises(ValueError):
        fam.labels(kind="full")  # missing keyword


def test_expose_text_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", ("kind",)).labels("full").inc(4)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe_many([0.05, 0.5, 2.0])
    text = reg.expose_text()
    assert "# HELP req_total requests" in text
    assert "# TYPE req_total counter" in text
    assert 'req_total{kind="full"} 4' in text
    # cumulative buckets + the +Inf total
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text
    assert text.endswith("\n")
    # the JSON snapshot round-trips
    snap = json.loads(reg.to_json())
    assert snap["lat_seconds"]["series"][""]["count"] == 3


def test_registry_concurrent_updates_are_exact():
    reg = MetricsRegistry()
    c = reg.counter("n_total")
    h = reg.histogram("v_seconds", buckets=(0.5,))

    def work():
        for _ in range(500):
            c.inc()
            h.observe(0.1)

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 2000.0
    assert h._default().snapshot()[2] == 2000


# ---------------------------------------------------------------------------
# span sink: bounded ring, watermark filtering, JSONL round trip
# ---------------------------------------------------------------------------


def test_span_sink_bounded_and_dropped():
    sink = SpanSink(capacity=4, clock=lambda: 123.0)
    for i in range(7):
        sink.emit("plan", i=i)
    assert len(sink) == 4
    assert sink.dropped == 3
    evs = sink.events()
    assert [e["i"] for e in evs] == [3, 4, 5, 6]  # oldest fell off
    assert all(e["ts"] == 123.0 for e in evs)
    with pytest.raises(ValueError):
        SpanSink(capacity=0)


def test_span_sink_kind_and_since_filters():
    sink = SpanSink(capacity=16)
    sink.emit("plan", x=1)
    mark = sink.next_seq
    sink.emit("batch", x=2)
    sink.emit("plan", x=3)
    assert [e["x"] for e in sink.events(kind="plan")] == [1, 3]
    assert [e["x"] for e in sink.events(since=mark)] == [2, 3]
    assert [e["x"] for e in sink.events(kind="plan", since=mark)] == [3]
    sink.clear()
    assert len(sink) == 0
    assert sink.next_seq == 3  # seq survives a clear (still a watermark)


def test_span_sink_jsonl_round_trip(tmp_path):
    sink = SpanSink(capacity=8)
    sink.emit("retrace", fn="scan", count=2)
    sink.emit("plan", stage_s={"route": 0.001})
    path = tmp_path / "spans.jsonl"
    assert sink.dump_jsonl(str(path)) == 2
    lines = [json.loads(li) for li in path.read_text().splitlines()]
    assert lines[0]["kind"] == "retrace" and lines[0]["count"] == 2
    assert lines[1]["stage_s"]["route"] == 0.001


def test_stage_breakdown_and_table():
    events = [
        {"kind": "plan", "stage_s": {"route": 0.001, "merge": 0.002}},
        {"kind": "plan", "stage_s": {"route": 0.003, "merge": 0.004}},
        {"kind": "batch", "b": 4},  # ignored: not a plan event
    ]
    bd = stage_breakdown(events, extra={"queue": [0.01, 0.02]})
    assert list(bd) == ["queue", "route", "merge"]  # canonical order
    assert bd["route"]["n"] == 2
    assert bd["queue"]["mean_ms"] == pytest.approx(15.0)
    table = format_stage_table(bd)
    assert "queue" in table and "p99_ms" in table
    empty = percentiles_ms([])
    assert empty["n"] == 0 and np.isnan(empty["p50_ms"])


# ---------------------------------------------------------------------------
# Telemetry bundle: pipeline hooks, bit-identity, retrace plumbing
# ---------------------------------------------------------------------------


def test_attach_telemetry_bit_identical(small_index, queries):
    """The tentpole invariant: instrumentation-off and -on return the same
    bits (the hooks only observe)."""
    idx = small_index
    d0, i0 = idx.query(queries, 10)
    tel = Telemetry()
    idx.attach_telemetry(tel)
    try:
        d1, i1 = idx.query(queries, 10)
    finally:
        idx.attach_telemetry(None)
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    # and the executor recorded a plan span with the full stage split
    plans = tel.spans.events(kind="plan")
    assert plans, "no plan span recorded"
    assert set(plans[0]["stage_s"]) == {"route", "candidates", "rerank",
                                        "merge"}
    assert plans[0]["engine"] == "scan"
    assert "lanns_stage_seconds" in tel.registry.expose_text()


def test_frontend_on_batch_counters(small_index, queries):
    idx = small_index
    tel = Telemetry()
    fe = AnnFrontend(idx, topk=5, max_batch=8, max_wait_ms=1e9,
                     telemetry=tel)
    idx.attach_telemetry(tel)
    try:
        for q in queries[:16]:
            fe.submit(q)
        fe.step()  # two full batches
    finally:
        idx.attach_telemetry(None)
    assert tel.requests_total.labels("full_batches").value == 16.0
    assert tel.batches_total.labels("full_batches").value == 2.0
    batch_evs = tel.spans.events(kind="batch")
    assert [e["b"] for e in batch_evs] == [8, 8]
    for e in batch_evs:
        assert e["queue_max_s"] >= e["queue_mean_s"] >= 0.0
    # the batched histograms saw every request exactly once
    assert tel.queue_seconds._default().snapshot()[2] == 16
    assert tel.latency_seconds._default().snapshot()[2] == 16


class _FakeSentinel:
    """retraced()/reset() stub: one pending retrace, then quiet."""

    def __init__(self):
        self.hot = {"beam_search": 2}
        self.resets = 0

    def retraced(self):
        return dict(self.hot)

    def reset(self):
        self.hot = {}
        self.resets += 1


def test_retrace_poll_plumbing():
    sent = _FakeSentinel()
    tel = Telemetry(sentinel=sent)
    hot = tel.poll_retraces()
    assert hot == {"beam_search": 2}
    assert sent.resets == 1
    assert tel.poll_retraces() == {}  # drained: counts fresh compiles only
    assert sent.resets == 1  # no reset when nothing retraced
    assert tel.retraces_total.labels("beam_search").value == 2.0
    evs = tel.spans.events(kind="retrace")
    assert len(evs) == 1 and evs[0]["fn"] == "beam_search"


def test_register_serve_engine_pull_gauges():
    class Stub:
        def __init__(self):
            self.stats = {"served": 5, "rejected": 0}

    eng = Stub()
    tel = Telemetry(sentinel=_FakeSentinel())
    tel.register_serve_engine(eng, prefix="stub")
    text = tel.registry.expose_text()
    assert "stub_served 5" in text
    eng.stats["served"] = 11  # pull mode: next collection sees the update
    assert "stub_served 11" in tel.registry.expose_text()


def test_serve_engine_registers_on_shared_registry():
    """One exposition covers both engines: the LM ServeEngine's stats dict
    registers as serve_engine_* pull gauges on the shared registry."""
    import jax

    from repro.models import transformer as tf
    from repro.serve.engine import Request, ServeEngine

    cfg = tf.TransformerConfig(n_layers=1, d_model=32, n_heads=2,
                               n_kv_heads=2, head_dim=16, d_ff=64, vocab=128)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    tel = Telemetry(sentinel=_FakeSentinel())
    eng = ServeEngine(cfg, params, slots=2, max_seq=32, telemetry=tel)
    text = tel.registry.expose_text()
    for key in eng.stats:
        assert f"serve_engine_{key} " in text
    eng.submit(Request(0, np.arange(4, dtype=np.int32), max_new_tokens=2))
    eng.run()
    # pull mode: the next collection reads the live dict, no push needed
    assert "serve_engine_completed 1" in tel.registry.expose_text()


def test_recent_query_stats_ring(small_index, queries):
    idx = small_index
    fe = AnnFrontend(idx, topk=5, max_batch=4, max_wait_ms=1e9,
                     collect_stats=True, recent_stats_depth=3)
    for q in queries[:20]:
        fe.submit(q)
    fe.step()  # five batches of 4 -> ring keeps the newest 3
    recent = fe.recent_query_stats()
    assert len(recent) == 3
    assert fe.last_query_stats is recent[-1]
    assert fe.recent_query_stats(2) == recent[-2:]
    assert fe.recent_query_stats(99) == recent  # over-ask clamps
    assert fe.recent_query_stats(0) == []
    with pytest.raises(ValueError):
        AnnFrontend(idx, recent_stats_depth=0)
    # without collect_stats the ring stays empty and last is None
    fe2 = AnnFrontend(idx, topk=5, max_batch=4)
    fe2.submit(queries[0])
    fe2.flush()
    assert fe2.last_query_stats is None
    assert fe2.recent_query_stats() == []

"""Property tests for the transformer primitives."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import (
    apply_rope, chunked_attention, dot_attention, rms_norm, rms_norm_init,
)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6).map(lambda e: 2**e))
def test_rope_preserves_norms(seed, hd):
    """Rotations are orthogonal: per-pair (and total) norms are invariant."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 8, 2, hd)).astype(np.float32))
    pos = jnp.arange(8)
    y = apply_rope(x, pos)
    assert np.allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        rtol=1e-5, atol=1e-5,
    )


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j (the point of RoPE)."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 64)).astype(np.float32))

    def score(i, j):
        qi = apply_rope(q, jnp.array([i]))
        kj = apply_rope(k, jnp.array([j]))
        return float(jnp.sum(qi * kj))

    assert abs(score(3, 1) - score(10, 8)) < 1e-4
    assert abs(score(5, 5) - score(0, 0)) < 1e-4


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_rms_norm_unit_rms(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32) * 7)
    p = rms_norm_init(32)
    y = np.asarray(rms_norm(p, x))
    rms = np.sqrt((y**2).mean(axis=-1))
    assert np.allclose(rms, 1.0, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([16, 48, 96]),
       st.sampled_from([16, 32]))
def test_chunked_attention_exactness(seed, S, chunk):
    """Online-softmax chunking is EXACT for any chunking of any length."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, S, 2, 16)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, S, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, S, 2, 16)).astype(np.float32))
    ref = dot_attention(q, k, v, causal=True)
    out = chunked_attention(q, k, v, causal=True, q_chunk=chunk, kv_chunk=chunk)
    assert np.allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps in interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.distance_topk import bitonic_sort_pairs


def _check(B, N, D, k, metric, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, D)).astype(dtype)
    x = rng.standard_normal((N, D)).astype(dtype)
    d_k, i_k = ops.distance_topk(q, x, k, metric, backend="pallas_interpret")
    k_eff = min(k, N)
    d_r, i_r = ref.distance_topk_ref(jnp.asarray(q), jnp.asarray(x), k_eff, metric)
    if k_eff < k:  # oracle padded to k with (inf, -1)
        d_r = jnp.concatenate(
            [d_r, jnp.full((B, k - k_eff), jnp.inf, d_r.dtype)], 1
        )
        i_r = jnp.concatenate(
            [i_r, jnp.full((B, k - k_eff), -1, i_r.dtype)], 1
        )
    d_k, i_k, d_r, i_r = map(np.asarray, (d_k, i_k, d_r, i_r))
    fin = np.isfinite(d_r)
    assert np.allclose(d_k[fin], d_r[fin], rtol=3e-4, atol=3e-4), (
        metric, np.abs(d_k - d_r)[fin].max()
    )
    # discrete-boundary metric: ids compared as sets per row (ties may swap)
    for rk, rr, f in zip(i_k, i_r, fin):
        sk, sr = set(rk[f].tolist()), set(rr[f].tolist())
        assert len(sk & sr) >= len(sr) - 1  # allow one tie swap


# sweep: dims from tiny/odd to SIFT/GIST-like, k below/at/above lane width
SWEEP = [
    (1, 100, 8, 5, "l2"),
    (5, 1000, 32, 10, "l2"),
    (8, 700, 50, 100, "l2"),     # People-dataset dims
    (3, 513, 128, 7, "ip"),      # SIFT dims, odd N
    (4, 300, 20, 5, "cos"),
    (2, 2048, 960, 64, "l2"),    # GIST dims
    (2, 64, 8, 100, "l2"),       # k > N
    (9, 255, 2048, 128, "ip"),   # NearDupe dims, k == lane width
]


@pytest.mark.parametrize("B,N,D,k,metric", SWEEP)
def test_kernel_matches_oracle(B, N, D, k, metric):
    _check(B, N, D, k, metric)


def test_kernel_bf16_inputs():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((4, 64)), jnp.bfloat16)
    x = jnp.asarray(rng.standard_normal((500, 64)), jnp.bfloat16)
    d_k, i_k = ops.distance_topk(q, x, 10, "l2", backend="pallas_interpret")
    d_r, i_r = ref.distance_topk_ref(
        q.astype(jnp.float32), x.astype(jnp.float32), 10, "l2"
    )
    # bf16 inputs upcast in-kernel: distances close at bf16 resolution
    assert np.allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-2, atol=2e-2)


def test_blocked_jnp_path_matches_oracle():
    rng = np.random.default_rng(4)
    q = rng.standard_normal((16, 48)).astype(np.float32)
    x = rng.standard_normal((5000, 48)).astype(np.float32)
    d_b, i_b = ops.distance_topk(q, x, 20, "l2", backend="jnp")
    d_r, i_r = ref.distance_topk_ref(jnp.asarray(q), jnp.asarray(x), 20, "l2")
    assert np.allclose(np.asarray(d_b), np.asarray(d_r), rtol=1e-5)
    assert np.array_equal(np.asarray(i_b), np.asarray(i_r))


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=7).map(lambda e: 2 ** (e + 2)),  # P: 4..512
    st.integers(min_value=0, max_value=10_000),
)
def test_property_bitonic_sorts(P, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.standard_normal((2, P)).astype(np.float32))
    i = jnp.asarray(rng.integers(0, 10 * P, (2, P)).astype(np.int32))
    sd, si = bitonic_sort_pairs(d, i)
    sd, si = np.asarray(sd), np.asarray(si)
    assert np.all(np.diff(sd, axis=1) >= 0), "ascending"
    # permutation check: same multiset of (dist, id) pairs
    for r in range(2):
        got = sorted(zip(sd[r].tolist(), si[r].tolist()))
        want = sorted(zip(np.asarray(d)[r].tolist(), np.asarray(i)[r].tolist()))
        assert got == want


def test_bitonic_with_inf_padding():
    d = jnp.asarray([[2.0, np.inf, 1.0, np.inf]])
    i = jnp.asarray([[5, -1, 9, -1]], dtype=jnp.int32)
    sd, si = bitonic_sort_pairs(d, i)
    assert np.asarray(si)[0, :2].tolist() == [9, 5]

"""q8 codec + int8 kernel: round-trip bounds, backend bit-parity, oracles.

The contracts locked in here:

* the codec's round-trip error is bounded by half a quantization step per
  coordinate (symmetric round-to-nearest, no clipping);
* the int8 Pallas kernel (interpret mode) and the blocked-jnp fallback
  produce BIT-IDENTICAL distances (the int8 dot is exact int32 either way
  and the fp32 rescale is the same expression);
* both match the numpy reference scoring in ``repro.quant.codec``;
* quantized scores track exact fp32 distances within codec error, which is
  what makes a small rerank_factor sufficient downstream.
"""

import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant import (
    Q8Corpus,
    dequantize_q8,
    distance_topk_q8_np,
    q8_bytes_per_vector,
    q8_scores_np,
    quantize_q8,
    quantize_queries_q8,
)


def _rand(B, N, D, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, D)).astype(np.float32) * scale
    x = rng.standard_normal((N, D)).astype(np.float32) * scale
    return q, x


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------


def test_roundtrip_error_bound():
    _, x = _rand(1, 700, 48, seed=1, scale=3.0)
    qc = quantize_q8(x)
    deq = dequantize_q8(qc)
    assert qc.codes.dtype == np.int8 and qc.scales.shape == (48,)
    assert np.abs(qc.codes).max() <= 127
    # round-to-nearest: at most half a step per coordinate
    assert np.all(np.abs(x - deq) <= qc.scales[None, :] / 2 + 1e-7)
    # norms2 is the dequantized norm, exactly
    assert np.allclose(qc.norms2, (deq * deq).sum(1), rtol=1e-6)


def test_cos_rows_normalized_before_encoding():
    _, x = _rand(1, 300, 16, seed=2, scale=5.0)
    qc = quantize_q8(x, metric="cos")
    deq = dequantize_q8(qc)
    norms = np.linalg.norm(deq, axis=1)
    # dequantized rows are unit up to codec error
    assert np.abs(norms - 1.0).max() < 0.01


def test_query_quantization_bound():
    q, x = _rand(32, 10, 24, seed=3)
    qc = quantize_q8(x)
    q_codes, q_scale = quantize_queries_q8(q, qc.scales)
    assert q_codes.dtype == np.int8
    # reconstructing the folded query: error <= half a step per coordinate
    back = q_codes.astype(np.float32) * q_scale[:, None]
    assert np.all(np.abs(back - q * qc.scales[None, :]) <= q_scale[:, None] / 2 + 1e-7)


def test_accumulator_dim_guard():
    """Rows wider than Q8_ACCUM_MAX_D must be refused at ENCODE time: the
    int8 dot's worst case d * 127^2 would wrap the int32 accumulator the
    kernels (and q8_scores_np) contract on."""
    from repro.quant.codec import Q8_ACCUM_MAX_D

    assert Q8_ACCUM_MAX_D * 127 * 127 <= 2 ** 31 - 1
    assert (Q8_ACCUM_MAX_D + 1) * 127 * 127 > 2 ** 31 - 1
    wide = np.zeros((2, Q8_ACCUM_MAX_D + 1), np.float32)
    with pytest.raises(ValueError, match="accumulator"):
        quantize_q8(wide)
    with pytest.raises(ValueError, match="accumulator"):
        quantize_queries_q8(wide, np.ones((wide.shape[1],), np.float32))
    # the widest legal dim encodes (and the reference scorer accepts it)
    ok = quantize_q8(np.ones((2, 8), np.float32))
    assert ok.codes.shape == (2, 8)


def test_empty_corpus_codec():
    qc = quantize_q8(np.zeros((0, 8), np.float32))
    assert qc.size == 0 and qc.dim == 8
    d, i = ops.distance_topk_q8(np.zeros((3, 8), np.float32), qc, 5)
    assert np.all(np.isinf(np.asarray(d))) and np.all(np.asarray(i) == -1)


def test_bytes_per_vector_under_fp32():
    _, x = _rand(1, 2000, 64, seed=4)
    qc = quantize_q8(x)
    bpv = q8_bytes_per_vector(qc)
    # codes d bytes + 4-byte norm + amortized scales << 4d fp32 bytes
    assert bpv <= 64 + 4 + 1
    assert bpv < 64 * 4 / 3.5  # ~4x smaller than fp32


# ---------------------------------------------------------------------------
# kernel: interpret mode vs jnp fallback vs numpy reference
# ---------------------------------------------------------------------------

SWEEP = [
    (4, 300, 24, 10, "l2"),
    (3, 513, 128, 7, "ip"),      # SIFT dims, odd N
    (5, 200, 20, 5, "cos"),
    (2, 64, 8, 100, "l2"),       # k > N
    (2, 150, 960, 16, "l2"),     # GIST dims
    (9, 255, 2048, 128, "ip"),   # k == lane width, D > exact-cast bound
]


def _ids_match_up_to_ties(i_a, i_b, fin):
    for ra, rb, f in zip(i_a, i_b, fin):
        sa, sb = set(ra[f].tolist()), set(rb[f].tolist())
        assert len(sa & sb) >= len(sb) - 1  # allow one tie swap


@pytest.mark.parametrize("B,N,D,k,metric", SWEEP)
def test_interpret_vs_jnp_bit_parity(B, N, D, k, metric):
    q, x = _rand(B, N, D, seed=B + N)
    qc = quantize_q8(x, metric)
    d_i, i_i = ops.distance_topk_q8(q, qc, k, metric, backend="pallas_interpret")
    d_j, i_j = ops.distance_topk_q8(q, qc, k, metric, backend="jnp")
    d_i, i_i, d_j, i_j = map(np.asarray, (d_i, i_i, d_j, i_j))
    assert np.array_equal(d_i, d_j), (metric, np.abs(d_i - d_j).max())
    _ids_match_up_to_ties(i_i, i_j, np.isfinite(d_j))


@pytest.mark.parametrize("B,N,D,k,metric", SWEEP[:4])
def test_kernel_matches_numpy_reference(B, N, D, k, metric):
    q, x = _rand(B, N, D, seed=2 * B + N)
    qc = quantize_q8(x, metric)
    d_k, i_k = map(
        np.asarray,
        ops.distance_topk_q8(q, qc, k, metric, backend="pallas_interpret"),
    )
    d_r, i_r = distance_topk_q8_np(q, qc, k, metric)
    fin = np.isfinite(d_r)
    assert np.allclose(d_k[fin], d_r[fin], rtol=1e-5, atol=1e-5)
    _ids_match_up_to_ties(i_k, i_r, fin)


def test_quantized_scores_track_exact():
    """Stage-1 scores deviate from exact fp32 distances only by codec error
    — the property that lets a small rerank_factor recover full recall."""
    q, x = _rand(16, 400, 32, seed=7)
    qc = quantize_q8(x)
    s = q8_scores_np(q, qc, "l2")
    exact = (
        (q * q).sum(1)[:, None]
        - 2.0 * q @ x.T
        + (x * x).sum(1)[None, :]
    )
    # analytic-ish bound: per-coordinate step errors accumulate ~sqrt(D)
    denom = np.maximum(np.abs(exact), 1.0)
    rel = np.abs(s - exact) / denom
    assert rel.max() < 0.05, rel.max()
    # quantized-only ranking is already close; re-rank closes the rest
    order_q = np.argsort(s, axis=1)[:, :10]
    order_e = np.argsort(exact, axis=1)[:, :10]
    overlap = np.mean([
        len(set(a) & set(b)) / 10 for a, b in zip(order_q, order_e)
    ])
    assert overlap > 0.9


def test_n_valid_masks_padding_rows():
    """A corpus padded to a shape bucket + n_valid == the raw corpus."""
    q, x = _rand(4, 100, 16, seed=8)
    qc = quantize_q8(x)
    pad = 128
    qc_pad = Q8Corpus(
        codes=np.vstack([qc.codes, np.full((pad - 100, 16), 7, np.int8)]),
        scales=qc.scales,
        norms2=np.concatenate(
            [qc.norms2, np.full((pad - 100,), np.inf, np.float32)]
        ),
        metric=qc.metric,
    )
    for backend in ("jnp", "pallas_interpret"):
        d0, i0 = map(
            np.asarray, ops.distance_topk_q8(q, qc, 9, backend=backend)
        )
        d1, i1 = map(
            np.asarray,
            ops.distance_topk_q8(q, qc_pad, 9, backend=backend, n_valid=100),
        )
        assert np.array_equal(d0, d1) and np.array_equal(i0, i1)


def test_blocked_q8_streams_blocks():
    """Multi-block streaming merge == single-block result."""
    q, x = _rand(3, 900, 24, seed=9)
    qc = quantize_q8(x)
    from repro.quant.codec import quantize_queries_q8 as qq
    import jax.numpy as jnp

    q_codes, q_scale = qq(q, qc.scales)
    d0, i0 = ref.distance_topk_q8_blocked(
        jnp.asarray(q_codes), jnp.asarray(qc.codes), jnp.asarray(q_scale),
        jnp.asarray(qc.norms2), 8, "l2", block_n=256,
    )
    d1, i1 = ref.distance_topk_q8_blocked(
        jnp.asarray(q_codes), jnp.asarray(qc.codes), jnp.asarray(q_scale),
        jnp.asarray(qc.norms2), 8, "l2", block_n=4096,
    )
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    assert np.array_equal(np.asarray(i0), np.asarray(i1))

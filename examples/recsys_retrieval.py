"""RecSys retrieval serving: SASRec user tower + LANNS candidate index.

The paper's PYMK use case shape: a sequential recommender encodes the user,
and LANNS retrieves top-K candidates from a large item-embedding corpus
(here: the retrieval_cand cell at CPU scale).

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import LannsConfig, LannsIndex, brute_force_topk, recall_at_k
from repro.models import recsys as rs

arch = get_arch("sasrec")
cfg = arch.model_config(reduced=True)  # small item vocab for CPU
params = rs.sasrec_init(jax.random.PRNGKey(0), cfg)

# a *trained* item space is clustered (items of a taste cluster co-embed);
# random-init tables are the known worst case for hyperplane segmenters, so
# simulate the trained structure the way ANN benchmarks do:
from repro.data.synthetic import clustered_vectors

item_embs = clustered_vectors(cfg.n_items, cfg.embed_dim, n_clusters=16,
                              cluster_std=0.2, seed=3)
params["item_table"] = jnp.asarray(item_embs)

# user histories -> user vectors.  An untrained SASRec tower emits
# arbitrary vectors (out-of-distribution queries — nothing retrieves well);
# production would plug the TRAINED tower here.  For the demo we use the
# standard mean-of-history tower (YouTube-DNN style), which is in-distribution
# by construction:
rng = np.random.default_rng(0)
histories = rng.integers(0, cfg.n_items, size=(64, cfg.seq_len)).astype(np.int32)
user_vecs = item_embs[histories].mean(axis=1)
# (the SASRec tower path, identical plumbing:)
_ = rs.sasrec_encode(params, cfg, jnp.asarray(histories))[:, -1]

# candidate corpus = the item embedding table; index it with LANNS.
# cosine metric: production two-towers serve on normalized embeddings, and
# spherical clusters are what hyperplane segmenters route well.
index = LannsIndex(
    LannsConfig(num_shards=1, num_segments=4, segmenter="apd",
                engine="scan", metric="cos")
).build(item_embs)

t0 = time.time()
d, ids = index.query(user_vecs, topk=50)
dt = time.time() - t0

# ground truth: exact max-inner-product
td, ti = brute_force_topk(user_vecs, item_embs, 50, metric="cos")
print(f"retrieval: {1e3 * dt / len(user_vecs):.2f} ms/user, "
      f"R@50 vs exact cosine retrieval = {recall_at_k(ids, ti, 50):.3f}")

"""Online serving (paper §7): LM decode engine + LANNS retrieval serving.

Two services in one example, mirroring the paper's production setup where
embedding models feed the ANN index:

  A. a SmolLM-reduced language model served with continuous batching
     (prefill + per-slot decode against a shared KV cache);
  B. its hidden states indexed by LANNS and served as an embedding-retrieval
     endpoint (the kNN-LM-flavored integration from DESIGN.md §7).

    PYTHONPATH=src python examples/online_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import LannsConfig, LannsIndex
from repro.models import transformer as tf
from repro.serve.engine import AnnFrontend, Request, ServeEngine

# ---- A. LM serving with continuous batching ---------------------------------
arch = get_arch("smollm-360m")
cfg = arch.model_config(reduced=True)
params = tf.init(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(cfg, params, slots=4, max_seq=64)

rng = np.random.default_rng(0)
for uid in range(10):
    prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12))
    engine.submit(Request(uid=uid, prompt=prompt.astype(np.int64),
                          max_new_tokens=8))
t0 = time.time()
stats = engine.run()
dt = time.time() - t0
print(f"LM engine: {stats} in {dt:.1f}s "
      f"({stats['decode_steps'] * engine.slots / dt:.0f} slot-steps/s)")

# ---- B. embedding retrieval over the LM's hidden states ---------------------
# index the final hidden state of a corpus of token sequences
corpus_tokens = rng.integers(0, cfg.vocab, size=(2000, 16)).astype(np.int32)


@jax.jit
def embed(tokens):
    logits, _, _ = tf.apply(params, cfg, tokens)
    return logits[:, -1, :64]  # cheap fixed-width embedding head

embs = np.asarray(jax.vmap(lambda t: embed(t[None])[0])(jnp.asarray(corpus_tokens)))
index = LannsIndex(
    LannsConfig(num_shards=1, num_segments=4, segmenter="apd", engine="scan")
).build(embs)

q_tokens = corpus_tokens[:8]  # queries = known corpus items -> should self-match
q_embs = np.asarray(embed(jnp.asarray(q_tokens)))
d, i = index.query(q_embs, topk=5)
self_hit = float((i[:, 0] == np.arange(8)).mean())
print(f"retrieval: self-match@1 = {self_hit:.2f} (expect 1.0)")

# ---- C. the micro-batching front end (single-query arrivals) -----------------
# production serving coalesces single-query arrivals into one batched query
# (up to max_batch queries or max_wait_ms of queueing, whichever first)
frontend = AnnFrontend(index, topk=5, max_batch=4, max_wait_ms=1.0)
for q in q_embs:
    frontend.submit(q)
done = frontend.step() + frontend.flush()
fe_hit = float(np.mean([r.ids[0] == r.uid for r in done]))
print(f"frontend: {len(done)} served in {frontend.stats['batches']} "
      f"micro-batches (mean {frontend.mean_batch_size:.1f}/batch), "
      f"self-match@1 = {fe_hit:.2f}")

# ---- D. async host loop under a live arrival process ------------------------
# the threaded frontend serves while a Poisson load generator submits;
# latencies are end-to-end (submit -> results visible), the raw material of
# the paper's Table 8 p99-vs-load curve (benchmarks/bench_latency_load.py
# runs the full sweep).
from repro.serve import run_load_point  # noqa: E402

index.warm_traces(max_batch=4, topk=5)  # compile serving traces up front
res = run_load_point(index, q_embs, process="poisson", rate_qps=200.0,
                     duration_s=0.5, topk=5, max_batch=4, max_wait_ms=1.0)
print(f"async loop: {res.completed} served at {res.achieved_qps:.0f} QPS "
      f"(offered {res.offered_qps:.0f}), p50={res.p50_ms:.1f}ms "
      f"p99={res.p99_ms:.1f}ms, mean batch {res.mean_batch:.1f}")

"""Quickstart: build a LANNS index, query it, check recall vs brute force.

    PYTHONPATH=src python examples/quickstart.py
"""


from repro.core import LannsConfig, LannsIndex, brute_force_topk, recall_table
from repro.data.synthetic import sift_like

# 1. a corpus and held-out same-distribution queries (SIFT-like synthetic)
corpus, queries = sift_like(10_000, 64, n_queries=200, seed=0)

# 2. a (2 shards x 4 segments) LANNS index with the APD segmenter —
#    the paper's recommended configuration family
cfg = LannsConfig(
    num_shards=2,
    num_segments=4,
    segmenter="apd",      # 'rs' | 'rh' | 'apd'
    alpha=0.15,           # virtual-spill band (~30% of queries spill/level)
    engine="scan",        # 'hnsw' (paper) | 'scan' (TPU-native dense)
)
index = LannsIndex(cfg).build(corpus)
print("partition sizes:", index.build_stats["partition_sizes"])

# 3. query with two-level merge + perShardTopK
dists, ids, stats = index.query(queries, topk=100, return_stats=True)
print("routing stats:", stats)

# 4. recall vs exact brute force
true_d, true_i = brute_force_topk(queries, corpus, 100)
print("recall:", {k: round(v, 4) for k, v in recall_table(ids, true_i).items()})

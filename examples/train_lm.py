"""End-to-end training driver example: train a small LM for a few hundred
steps with the full substrate (pipeline, optimizer, checkpoints, resume).

    PYTHONPATH=src python examples/train_lm.py            # ~200 steps on CPU
    PYTHONPATH=src python examples/train_lm.py --steps 50 # quicker
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:] or [
        "--arch", "smollm-360m", "--steps", "200", "--batch", "8",
        "--seq", "128", "--lr", "3e-3", "--ckpt-dir", "/tmp/lm_ckpt",
        "--ckpt-every", "100",
    ]
    raise SystemExit(main(args))

"""End-to-end offline framework (paper §5): the full production pipeline.

1. learn a shared segmenter on a subsample      (paper Fig. 5)
2. two-level partition + parallel index build   (paper Fig. 6)
3. fault-injected resume (kill + restart)       (paper §5.3.1)
4. distributed batched querying + 2-level merge (paper Fig. 7)
5. brute-force ground truth + recall report     (paper §5.4)

    PYTHONPATH=src python examples/offline_pipeline.py
"""

import shutil
import tempfile
import time

import numpy as np

from repro.core import (
    LannsConfig, LannsIndex, brute_force_topk, per_shard_topk, recall_table,
)
from repro.core.lanns import _build_one_partition
from repro.data.synthetic import clustered_vectors

N, D, NQ, TOPK = 15_000, 64, 400, 100
corpus = clustered_vectors(N, D, n_clusters=128, seed=0)
queries = clustered_vectors(NQ, D, n_clusters=128, seed=1)
workdir = tempfile.mkdtemp(prefix="lanns_")

cfg = LannsConfig(num_shards=2, num_segments=4, segmenter="apd",
                  alpha=0.15, engine="hnsw", hnsw_m=12, ef_construction=80,
                  ef_search=120)

# -- 1+2: learn segmenter, partition, build (with persistence) ---------------
print("== building with checkpointed partitions ==")
t0 = time.time()
index = LannsIndex(cfg)
index.fit(corpus)  # pre-learned segmenter, shared across shards (§5.1)

# -- 3: fault injection — build only half the partitions, "crash", resume ----
assignment = index.partitioner.assign(corpus, np.arange(N))
built = 0
for s in range(cfg.num_shards):
    for g in range(cfg.num_segments):
        if built >= 4:  # "crash" after 4 of 8 partitions
            break
        rows = assignment.rows[s][g]
        _, _, payload, secs = _build_one_partition(
            (s, g, corpus[rows], np.arange(N)[rows], cfg.engine,
             cfg.hnsw_config())
        )
        index._save_partition(workdir, s, g, payload)
        built += 1
print(f"   simulated crash after {built} partitions "
      f"({time.time() - t0:.1f}s); resuming ...")

index2 = LannsIndex(cfg)
index2.fit(corpus)
index2.build(corpus, resume_dir=workdir)  # skips the 4 persisted partitions
print(f"   resume completed: {len(index2.partitions)} partitions, "
      f"build wall {index2.build_stats['build_wall_seconds']:.1f}s")

# -- 4: batched querying with the two-level merge -----------------------------
pstk = per_shard_topk(TOPK, cfg.num_shards, cfg.topk_confidence)
print(f"== querying (perShardTopK={pstk} of topK={TOPK}) ==")
t0 = time.time()
d, i, stats = index2.query(queries, TOPK, return_stats=True)
print(f"   {1e3 * (time.time() - t0) / NQ:.2f} ms/query, {stats}")

# -- 5: ground truth + recall table -------------------------------------------
print("== brute-force ground truth (partitioned, merged by queryId) ==")
td, ti = brute_force_topk(queries, corpus, TOPK, num_partitions=4)
print("   recall:", {k: round(v, 4) for k, v in recall_table(i, ti).items()})
shutil.rmtree(workdir)

"""Pallas TPU kernel: fused int8 distance + streaming top-k.

The quantized twin of ``distance_topk.py`` — stage 1 of the two-stage
(quantized scan -> exact re-rank) serving path.  Per grid step:

  1. dots = q_codes @ x_codes^T          (int8 x int8 -> int32 on the MXU)
  2. scores = n2 - 2 * q_scale * dots    (one fp32 rescale; 'ip' drops n2)
  3. merge(running_topk, block scores)   (same bitonic network as fp32)

Inputs are the artifacts of ``repro.quant.codec``: the corpus as int8
``codes`` with the per-dimension scales already FOLDED INTO THE QUERY
(``quantize_queries_q8``), so the kernel sees one fp32 scale per query row
plus a per-row fp32 norm correction for l2.  Int8 halves-again the VMEM/HBM
traffic of the bf16 path and runs the contraction at the MXU's int8 rate;
the fp32 work is one rank-1 rescale per (TQ, TN) tile.

The int32 -> fp32 rescale is exact for D <= 1040 (sums stay under 2^24), so
the blocked-jnp fallback in ``ref.distance_topk_q8_blocked`` reproduces
these scores bit-for-bit — asserted by tests/test_quant.py.

Constraints: identical to the fp32 kernel (k <= K_PAD, block sizes lane
multiples, D padded to a lane multiple by ops.py — zero padding is exact
for the integer dot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.distance_topk import bitonic_sort_pairs


def _distance_topk_q8_kernel(
    q_ref,  # (TQ, D)      int8  VMEM
    x_ref,  # (TN, D)      int8  VMEM
    qs_ref,  # (TQ, 1)     f32   VMEM — per-query rescale
    n2_ref,  # (1, TN)     f32   VMEM — per-row dequantized ||x||^2
    out_d_ref,  # (TQ, K_PAD)
    out_i_ref,  # (TQ, K_PAD)
    run_d,  # scratch (TQ, K_PAD) f32
    run_i,  # scratch (TQ, K_PAD) i32
    *,
    k_pad: int,
    block_n: int,
    n_valid: int,
    metric: str,
):
    in_ = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(in_ == 0)
    def _init():
        run_d[...] = jnp.full(run_d.shape, jnp.inf, run_d.dtype)
        run_i[...] = jnp.full(run_i.shape, -1, run_i.dtype)

    # int8 x int8 -> int32: the MXU-native contraction; fp32 enters only in
    # the rank-1 rescale below.
    dots = jax.lax.dot_general(
        q_ref[...],
        x_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # (TQ, TN) exact
    qx = dots.astype(jnp.float32) * qs_ref[...]  # (TQ, TN) * (TQ, 1)
    if metric == "l2":
        scores = n2_ref[...] - 2.0 * qx  # ||q||^2 added by the wrapper
    else:  # ip (cos is ip over pre-normalized inputs)
        scores = -qx

    gid = in_ * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_n), 1
    )
    valid = gid < n_valid
    scores = jnp.where(valid, scores, jnp.inf)
    gids = jnp.broadcast_to(gid, scores.shape)
    gids = jnp.where(valid, gids, -1)

    cat_d = jnp.concatenate([run_d[...], scores], axis=-1)
    cat_i = jnp.concatenate([run_i[...], gids], axis=-1)
    P = cat_d.shape[-1]
    P2 = 1 << (P - 1).bit_length()
    if P2 != P:
        pad = ((0, 0), (0, P2 - P))
        cat_d = jnp.pad(cat_d, pad, constant_values=jnp.inf)
        cat_i = jnp.pad(cat_i, pad, constant_values=-1)
    sd, si = bitonic_sort_pairs(cat_d, cat_i)
    run_d[...] = sd[:, :k_pad]
    run_i[...] = si[:, :k_pad]

    @pl.when(in_ == nn - 1)
    def _flush():
        out_d_ref[...] = run_d[...]
        out_i_ref[...] = run_i[...]


@functools.partial(
    jax.jit,
    static_argnames=("k_pad", "block_q", "block_n", "n_valid", "metric", "interpret"),
)
def distance_topk_q8_pallas(
    q_codes: jnp.ndarray,  # (B, D) int8 — scales folded, per-query quantized
    x_codes: jnp.ndarray,  # (N, D) int8
    q_scale: jnp.ndarray,  # (B, 1) f32
    norms2: jnp.ndarray,  # (1, N) f32 (+inf on padding rows)
    *,
    k_pad: int,
    block_q: int,
    block_n: int,
    n_valid: int,
    metric: str,
    interpret: bool = False,
):
    """Raw kernel launch; same shape contract as ``distance_topk_pallas``
    (B % block_q == 0, N % block_n == 0, D a lane multiple, k_pad a power
    of two).  Returns (B, k_pad) ascending quantized scores + global ids."""
    B, D = q_codes.shape
    N = x_codes.shape[0]
    assert B % block_q == 0 and N % block_n == 0
    nq, nn = B // block_q, N // block_n
    kernel = functools.partial(
        _distance_topk_q8_kernel,
        k_pad=k_pad,
        block_n=block_n,
        n_valid=n_valid,
        metric=metric,
    )
    out_shape = (
        jax.ShapeDtypeStruct((B, k_pad), jnp.float32),
        jax.ShapeDtypeStruct((B, k_pad), jnp.int32),
    )
    return pl.pallas_call(
        kernel,
        grid=(nq, nn),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda iq, in_: (iq, 0)),
            pl.BlockSpec((block_n, D), lambda iq, in_: (in_, 0)),
            pl.BlockSpec((block_q, 1), lambda iq, in_: (iq, 0)),
            pl.BlockSpec((1, block_n), lambda iq, in_: (0, in_)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k_pad), lambda iq, in_: (iq, 0)),
            pl.BlockSpec((block_q, k_pad), lambda iq, in_: (iq, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, k_pad), jnp.float32),
            pltpu.VMEM((block_q, k_pad), jnp.int32),
        ],
        interpret=interpret,
    )(q_codes, x_codes, q_scale, norms2)

"""Pallas TPU kernel: fused blocked distance + streaming top-k.

This is the compute hot spot of LANNS serving (DESIGN.md §2, §6): scoring a
query tile against a corpus segment is a (TQ, d) x (d, TN) matmul on the MXU,
and the top-k selection is fused into the same kernel so candidate scores
never round-trip to HBM.  The kernel is the TPU-native replacement for the
"<query, document> distance comparisons" that the paper identifies as where
"most of the search time is spent" (§7).

Grid/tiling
-----------
grid = (num_q_tiles, num_n_blocks); the N axis is the innermost (sequential on
TPU) grid dimension, and a VMEM scratch carries the running per-query top-k
(dists + global ids) across N blocks — the same accumulator pattern as
flash-attention.  Per grid step:

  1. scores = x_norm - 2 * q @ x_blk^T           (MXU matmul, f32 accum)
  2. merge(running_topk, block scores)           (bitonic network, VPU)
  3. last block: write (TQ, K_PAD) results out

The merge sorts the concatenated [K_PAD running | TN block] row of each query
with a bitonic network expressed ONLY as reshapes + elementwise select (bit
``t`` of the lane index becomes an explicit axis of a reshape), because Mosaic
does not lower lax.top_k/sort inside kernels; this form maps to vector
shuffles on TPU and is exactly emulated in interpret mode on CPU.

Constraints: k <= K_PAD (=256 default); d padded to a lane multiple by ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_LANES = 128  # TPU lane width; block sizes are multiples of this


def _log2(n: int) -> int:
    l = n.bit_length() - 1
    if (1 << l) != n:
        raise ValueError(f"{n} is not a power of two")
    return l


def bitonic_sort_pairs(d: jnp.ndarray, i: jnp.ndarray):
    """Ascending bitonic sort of (dist, id) pairs along the last axis.

    Last axis length must be a power of two.  Implemented with reshape +
    min/max/select only (no gather, no sort primitive) so it lowers inside a
    Pallas TPU kernel.  O(P log^2 P) compare-exchanges.
    """
    P = d.shape[-1]
    LP = _log2(P)
    lead = d.shape[:-1]
    for s in range(1, LP + 1):  # stage: sorted runs of length 2**s
        for t in range(s - 1, -1, -1):  # substage: partner distance 2**t
            blk = 1 << (t + 1)
            half = 1 << t
            nb = P // blk
            dv = d.reshape(*lead, nb, 2, half)
            iv = i.reshape(*lead, nb, 2, half)
            a_d, b_d = dv[..., 0, :], dv[..., 1, :]
            a_i, b_i = iv[..., 0, :], iv[..., 1, :]
            # ascending iff bit ``s`` of the flat index is 0; bits >= t+1 of
            # the flat index live in the ``nb`` axis.
            base = jax.lax.broadcasted_iota(jnp.int32, (nb, 1), 0) * blk
            asc = (base & (1 << s)) == 0
            if s == LP:
                asc = jnp.ones_like(asc)  # final merge: fully ascending
            swap = jnp.where(asc, a_d > b_d, a_d < b_d)
            new_a_d = jnp.where(swap, b_d, a_d)
            new_b_d = jnp.where(swap, a_d, b_d)
            new_a_i = jnp.where(swap, b_i, a_i)
            new_b_i = jnp.where(swap, a_i, b_i)
            d = jnp.stack([new_a_d, new_b_d], axis=-2).reshape(*lead, P)
            i = jnp.stack([new_a_i, new_b_i], axis=-2).reshape(*lead, P)
    return d, i


def _distance_topk_kernel(
    q_ref,  # (TQ, D)       VMEM
    x_ref,  # (TN, D)       VMEM
    out_d_ref,  # (TQ, K_PAD)
    out_i_ref,  # (TQ, K_PAD)
    run_d,  # scratch (TQ, K_PAD) f32
    run_i,  # scratch (TQ, K_PAD) i32
    *,
    k_pad: int,
    block_n: int,
    n_valid: int,
    metric: str,
):
    in_ = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(in_ == 0)
    def _init():
        run_d[...] = jnp.full(run_d.shape, jnp.inf, run_d.dtype)
        run_i[...] = jnp.full(run_i.shape, -1, run_i.dtype)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    # scores: lower is better.  l2 drops the per-query ||q||^2 constant
    # (added back by the ops.py wrapper) so the MXU does one matmul + one
    # rank-1 broadcast add.
    qx = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TQ, TN)
    if metric == "l2":
        x_norm = jnp.sum(x * x, axis=-1)  # (TN,)
        scores = x_norm[None, :] - 2.0 * qx
    else:  # ip (cos is ip over pre-normalized inputs)
        scores = -qx

    gid = in_ * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_n), 1
    )  # (1, TN)
    valid = gid < n_valid
    scores = jnp.where(valid, scores, jnp.inf)
    gids = jnp.broadcast_to(gid, scores.shape)
    gids = jnp.where(valid, gids, -1)

    cat_d = jnp.concatenate([run_d[...], scores], axis=-1)  # (TQ, K_PAD+TN)
    cat_i = jnp.concatenate([run_i[...], gids], axis=-1)
    P = cat_d.shape[-1]
    P2 = 1 << (P - 1).bit_length()
    if P2 != P:  # bitonic needs a power of two; pad with +inf sentinels
        pad = ((0, 0), (0, P2 - P))
        cat_d = jnp.pad(cat_d, pad, constant_values=jnp.inf)
        cat_i = jnp.pad(cat_i, pad, constant_values=-1)
    sd, si = bitonic_sort_pairs(cat_d, cat_i)
    run_d[...] = sd[:, :k_pad]
    run_i[...] = si[:, :k_pad]

    @pl.when(in_ == nn - 1)
    def _flush():
        out_d_ref[...] = run_d[...]
        out_i_ref[...] = run_i[...]


@functools.partial(
    jax.jit,
    static_argnames=("k_pad", "block_q", "block_n", "n_valid", "metric", "interpret"),
)
def distance_topk_pallas(
    q: jnp.ndarray,
    x: jnp.ndarray,
    *,
    k_pad: int,
    block_q: int,
    block_n: int,
    n_valid: int,
    metric: str,
    interpret: bool = False,
):
    """Raw kernel launch. q (B, D) with B % block_q == 0; x (N, D) with
    N % block_n == 0; D a lane multiple; k_pad a power of two; block sizes
    lane multiples.  Returns (B, k_pad) dists (ascending) + global ids."""
    B, D = q.shape
    N = x.shape[0]
    assert B % block_q == 0 and N % block_n == 0
    nq, nn = B // block_q, N // block_n
    kernel = functools.partial(
        _distance_topk_kernel,
        k_pad=k_pad,
        block_n=block_n,
        n_valid=n_valid,
        metric=metric,
    )
    out_shape = (
        jax.ShapeDtypeStruct((B, k_pad), jnp.float32),
        jax.ShapeDtypeStruct((B, k_pad), jnp.int32),
    )
    return pl.pallas_call(
        kernel,
        grid=(nq, nn),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda iq, in_: (iq, 0)),
            pl.BlockSpec((block_n, D), lambda iq, in_: (in_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k_pad), lambda iq, in_: (iq, 0)),
            pl.BlockSpec((block_q, k_pad), lambda iq, in_: (iq, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, k_pad), jnp.float32),
            pltpu.VMEM((block_q, k_pad), jnp.int32),
        ],
        interpret=interpret,
    )(q, x)

"""Jit'd public wrappers around the Pallas kernels.

``distance_topk`` is the one entry point the rest of the system uses; it
handles padding (queries to the q-tile, corpus to the n-block, feature dim to
the lane width, k to the kernel's power-of-two buffer), metric normalization,
and backend selection:

* on TPU: the fused Pallas kernel (distance_topk_pallas);
* elsewhere (this CPU container): the blocked-scan jnp path, which is
  semantically identical (same streaming merge) and XLA-fused;
* interpret=True forces the Pallas kernel through the interpreter — used by
  the kernel tests to validate the TPU code path on CPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.common.utils import next_pow2, round_up
from repro.kernels import ref
from repro.kernels.distance_topk import distance_topk_pallas

LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def distance_topk(
    q,
    x,
    k: int,
    metric: str = "l2",
    *,
    block_q: int = 8,
    block_n: int = 256,
    backend: str = "auto",  # 'auto' | 'pallas' | 'pallas_interpret' | 'jnp'
):
    """Top-k nearest rows of ``x`` for each row of ``q``.

    Returns (dists (B, k) ascending, ids (B, k) int32; id -1 where fewer than
    k valid rows exist).  For metric='l2' distances are true squared L2; for
    'ip'/'cos' they are negative (inner product / cosine similarity).
    """
    q = jnp.asarray(q)
    x = jnp.asarray(x)
    B, D = q.shape
    N = x.shape[0]
    if N == 0:
        # empty corpus: nothing to rank.  The k > N recursion below would
        # otherwise bottom out calling the blocked scan with k=0 — return the
        # (inf, -1) padding directly.
        return (
            jnp.full((B, k), jnp.inf, jnp.float32),
            jnp.full((B, k), -1, jnp.int32),
        )
    if k > N:  # fewer corpus rows than requested: pad with (inf, -1)
        d, i = distance_topk(
            q, x, N, metric, block_q=block_q, block_n=block_n, backend=backend
        )
        pad_d = jnp.full((B, k - N), jnp.inf, d.dtype)
        pad_i = jnp.full((B, k - N), -1, i.dtype)
        return jnp.concatenate([d, pad_d], 1), jnp.concatenate([i, pad_i], 1)
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"

    if metric == "cos":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        metric_k = "ip"
    else:
        metric_k = metric

    # q/x are already normalized above for 'cos', so the fallbacks must score
    # with metric_k ('ip') — passing 'cos' through would normalize a second
    # time inside ref.distance_matrix (redundant work, not a result change).
    if backend == "jnp":
        return ref.distance_topk_blocked(
            q.astype(jnp.float32), x.astype(jnp.float32), k, metric_k
        )

    k_pad = max(next_pow2(k), LANE)
    if k_pad > 256:
        # the in-kernel buffer tops out at 256; larger k streams through the
        # blocked jnp merge instead (rare: paper's k is 100-200).
        return ref.distance_topk_blocked(
            q.astype(jnp.float32), x.astype(jnp.float32), k, metric_k
        )
    # pick block_n so the in-kernel merge length k_pad + block_n is a power
    # of two (bitonic network) and a lane multiple.
    block_n = max(block_n, k_pad)
    block_n = next_pow2(k_pad + block_n) - k_pad

    D_pad = round_up(D, LANE)
    B_pad = round_up(B, block_q)
    N_pad = round_up(N, block_n)
    qp = jnp.zeros((B_pad, D_pad), jnp.float32).at[:B, :D].set(q.astype(jnp.float32))
    xp = jnp.zeros((N_pad, D_pad), jnp.float32).at[:N, :D].set(x.astype(jnp.float32))

    out_d, out_i = distance_topk_pallas(
        qp,
        xp,
        k_pad=k_pad,
        block_q=block_q,
        block_n=block_n,
        n_valid=N,
        metric=metric_k,
        interpret=(backend == "pallas_interpret") or not _on_tpu(),
    )
    out_d, out_i = out_d[:B, :k], out_i[:B, :k]
    if metric == "l2":
        qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
        out_d = jnp.where(jnp.isinf(out_d), out_d, out_d + qn)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_d, out_i


@partial(jax.jit, static_argnames=("k", "metric"))
def distance_topk_jit(q, x, k: int, metric: str = "l2"):
    """Pre-jitted jnp path (stable signature for serving loops)."""
    return ref.distance_topk_blocked(q, x, k, metric)

"""Jit'd public wrappers around the Pallas kernels.

``distance_topk`` is the one entry point the rest of the system uses; it
handles padding (queries to the q-tile, corpus to the n-block, feature dim to
the lane width, k to the kernel's power-of-two buffer), metric normalization,
and backend selection:

* on TPU: the fused Pallas kernel (distance_topk_pallas);
* elsewhere (this CPU container): the blocked-scan jnp path, which is
  semantically identical (same streaming merge) and XLA-fused;
* interpret=True forces the Pallas kernel through the interpreter — used by
  the kernel tests to validate the TPU code path on CPU.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2, round_up
from repro.kernels import ref
from repro.kernels.distance_topk import distance_topk_pallas
from repro.kernels.distance_topk_q8 import distance_topk_q8_pallas

LANE = 128

# Scale-safety contract (repro.analysis.scalecheck): corpora arrive padded
# to shared pow2/quarter-pow2 buckets of up to 2^25 rows; feature dims to
# 2048.  B and k are intentionally NOT declared here: the batch is bucketed
# by the callers and k ranges over the per-request knob set (bounded in
# core/lanns.py / core/plan.py where those knobs are formed).
# lanns: dims[N<=33_554_432, D<=2048]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# lanns: hotpath
def distance_topk(
    q,
    x,
    k: int,
    metric: str = "l2",
    *,
    block_q: int = 8,
    block_n: int = 256,
    backend: str = "auto",  # 'auto' | 'pallas' | 'pallas_interpret' | 'jnp'
    n_valid: int | None = None,
):
    """Top-k nearest rows of ``x`` for each row of ``q``.

    Returns (dists (B, k) ascending, ids (B, k) int32; id -1 where fewer than
    k valid rows exist).  For metric='l2' distances are true squared L2; for
    'ip'/'cos' they are negative (inner product / cosine similarity).

    ``n_valid``: number of real corpus rows when ``x`` is padded to a shared
    shape bucket (rows >= n_valid are ignored).  On the jnp path it is a
    traced scalar, so every partition padded to the same bucket reuses ONE
    compiled trace — the point of the scan-engine pow2 bucketing.  (The
    Pallas kernel bakes it statically; folding it into SMEM is a ROADMAP
    follow-on.)
    """
    q = jnp.asarray(q)
    x = jnp.asarray(x)
    B, D = q.shape
    N = x.shape[0]
    nv = N if n_valid is None else min(int(n_valid), N)
    if N == 0 or nv == 0:
        # empty corpus: nothing to rank.  The k > N recursion below would
        # otherwise bottom out calling the blocked scan with k=0 — return the
        # (inf, -1) padding directly.
        return (
            jnp.full((B, k), jnp.inf, jnp.float32),
            jnp.full((B, k), -1, jnp.int32),
        )
    if k > N:  # fewer corpus rows than requested: pad with (inf, -1)
        d, i = distance_topk(  # lanns: noqa[LANNS033] -- degenerate k > N tail: k snaps to the corpus size, which callers pre-bucket (quarter-pow2 scan corpora) — one trace per size bucket
            q, x, N, metric, block_q=block_q, block_n=block_n,
            backend=backend, n_valid=nv,
        )
        pad_d = jnp.full((B, k - N), jnp.inf, d.dtype)
        pad_i = jnp.full((B, k - N), -1, i.dtype)
        return jnp.concatenate([d, pad_d], 1), jnp.concatenate([i, pad_i], 1)
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"

    if metric == "cos":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        metric_k = "ip"
    else:
        metric_k = metric

    # q/x are already normalized above for 'cos', so the fallbacks must score
    # with metric_k ('ip') — passing 'cos' through would normalize a second
    # time inside ref.distance_matrix (redundant work, not a result change).
    if backend == "jnp":
        return ref.distance_topk_blocked(
            q.astype(jnp.float32), x.astype(jnp.float32), k, metric_k,
            n_valid=nv,
        )

    k_pad = max(next_pow2(k), LANE)
    if k_pad > 256:
        # the in-kernel buffer tops out at 256; larger k streams through the
        # blocked jnp merge instead (rare: paper's k is 100-200).
        return ref.distance_topk_blocked(
            q.astype(jnp.float32), x.astype(jnp.float32), k, metric_k,
            n_valid=nv,
        )
    # pick block_n so the in-kernel merge length k_pad + block_n is a power
    # of two (bitonic network) and a lane multiple.
    block_n = max(block_n, k_pad)
    block_n = next_pow2(k_pad + block_n) - k_pad

    D_pad = round_up(D, LANE)
    B_pad = round_up(B, block_q)
    N_pad = round_up(N, block_n)
    qp = jnp.zeros((B_pad, D_pad), jnp.float32).at[:B, :D].set(q.astype(jnp.float32))  # lanns: noqa[LANNS033] -- D is a deployment constant (one trace per corpus layout); round_up only re-rounds it to the lane width
    xp = jnp.zeros((N_pad, D_pad), jnp.float32).at[:N, :D].set(x.astype(jnp.float32))  # lanns: noqa[LANNS033] -- N arrives pre-bucketed (quarter-pow2 scan corpora); round_up to the kernel block multiple preserves the finite bucket set

    out_d, out_i = distance_topk_pallas(
        qp,
        xp,
        k_pad=k_pad,
        block_q=block_q,
        block_n=block_n,
        n_valid=nv,
        metric=metric_k,
        interpret=(backend == "pallas_interpret") or not _on_tpu(),
    )
    out_d, out_i = out_d[:B, :k], out_i[:B, :k]
    if metric == "l2":
        qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
        out_d = jnp.where(jnp.isinf(out_d), out_d, out_d + qn)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_d, out_i


# lanns: hotpath
def distance_topk_q8(
    q,
    qc,
    k: int,
    metric: str = "l2",
    *,
    block_q: int = 8,
    block_n: int = 256,
    backend: str = "auto",
    n_valid: int | None = None,
):
    """Quantized top-k: rank the int8 corpus ``qc`` for each row of ``q``.

    ``qc`` is a ``repro.quant.codec.Q8Corpus`` (or any object with
    ``codes``/``scales``/``norms2``).  Returns (dists, ids) in the same
    convention as :func:`distance_topk`, except distances are the QUANTIZED
    scores — the distance to the dequantized corpus point, with the query
    itself quantized for the integer contraction.  These rank candidates for
    the exact re-rank stage; they are within codec error of the fp32
    distances, not equal to them.

    Backends mirror :func:`distance_topk`: the fused int8 Pallas kernel on
    TPU (or ``pallas_interpret``), and the blocked int8 jnp scan elsewhere —
    both produce bit-identical scores (the dot is exact int32 either way).
    """
    codes = jnp.asarray(qc.codes)
    scales = np.asarray(qc.scales, np.float32)
    norms2 = jnp.asarray(qc.norms2)
    q = np.asarray(q, np.float32)
    B, D = q.shape
    N = codes.shape[0]
    nv = N if n_valid is None else min(int(n_valid), N)
    if N == 0 or nv == 0:
        return (
            jnp.full((B, k), jnp.inf, jnp.float32),
            jnp.full((B, k), -1, jnp.int32),
        )
    if k > N:
        d, i = distance_topk_q8(  # lanns: noqa[LANNS033] -- degenerate k > N tail: k snaps to the corpus size, which callers pre-bucket (quarter-pow2 q8 corpora) — one trace per size bucket
            q, qc, N, metric, block_q=block_q, block_n=block_n,
            backend=backend, n_valid=nv,
        )
        pad_d = jnp.full((B, k - N), jnp.inf, d.dtype)
        pad_i = jnp.full((B, k - N), -1, i.dtype)
        return jnp.concatenate([d, pad_d], 1), jnp.concatenate([i, pad_i], 1)
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    qc_metric = getattr(qc, "metric", None)
    if qc_metric is not None and qc_metric != metric:
        # 'cos' codes are built from normalized rows; scoring them as 'ip'
        # (or vice versa) would silently return wrong rankings.
        raise ValueError(
            f"corpus was quantized for metric={qc_metric!r} but scoring "
            f"requested metric={metric!r}"
        )

    from repro.quant.codec import quantize_queries_q8

    q_eff = q
    if metric == "cos":
        q_eff = q / np.maximum(
            np.linalg.norm(q, axis=-1, keepdims=True), 1e-12
        )
        metric_k = "ip"
    else:
        metric_k = metric
    q_codes, q_scale = quantize_queries_q8(q_eff, scales)

    k_pad = max(next_pow2(k), LANE)
    if backend == "jnp" or k_pad > 256:
        out_d, out_i = ref.distance_topk_q8_blocked(
            jnp.asarray(q_codes), codes, jnp.asarray(q_scale), norms2,
            k, metric_k, n_valid=nv,
        )
    else:
        D_pad = round_up(D, LANE)
        B_pad = round_up(B, block_q)
        block_n = max(block_n, k_pad)
        block_n = next_pow2(k_pad + block_n) - k_pad
        N_pad = round_up(N, block_n)
        qp = np.zeros((B_pad, D_pad), np.int8)
        qp[:B, :D] = q_codes
        xp = jnp.zeros((N_pad, D_pad), jnp.int8).at[:N, :D].set(codes)  # lanns: noqa[LANNS033] -- N arrives pre-bucketed (quarter-pow2 q8 corpora); round_up to the kernel block multiple preserves the finite bucket set
        qsp = np.zeros((B_pad, 1), np.float32)
        qsp[:B, 0] = q_scale
        n2p = jnp.full((1, N_pad), jnp.inf, jnp.float32).at[0, :N].set(norms2)  # lanns: noqa[LANNS033] -- same pre-bucketed N as the codes pad above
        out_d, out_i = distance_topk_q8_pallas(
            jnp.asarray(qp),  # lanns: noqa[LANNS033] -- D is a deployment constant (one trace per corpus layout); round_up only re-rounds it to the lane width
            xp,
            jnp.asarray(qsp),
            n2p,
            k_pad=k_pad,
            block_q=block_q,
            block_n=block_n,
            n_valid=nv,
            metric=metric_k,
            interpret=(backend == "pallas_interpret") or not _on_tpu(),
        )
        out_d, out_i = out_d[:B, :k], out_i[:B, :k]
    if metric == "l2":
        qn = jnp.sum(jnp.asarray(q) ** 2, axis=-1, keepdims=True)
        out_d = jnp.where(jnp.isinf(out_d), out_d, out_d + qn)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_d, out_i


@partial(jax.jit, static_argnames=("k", "metric"))
def distance_topk_jit(q, x, k: int, metric: str = "l2"):
    """Pre-jitted jnp path (stable signature for serving loops)."""
    return ref.distance_topk_blocked(q, x, k, metric)

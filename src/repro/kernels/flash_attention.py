"""Pallas TPU kernel: causal flash attention (forward).

The LM cells currently use a pure-jnp chunked attention (exact, memory-
bounded) — this kernel is the TPU-native version of the same online-softmax
algorithm with explicit VMEM tiling: one (Bq) query block stays resident
while the kernel streams kv blocks, carrying (m, l, acc) in VMEM scratch so
the (S, S) score matrix never exists in HBM.

Layout: q (B*H, S, d), k/v (B*H, S, d) — the wrapper folds batch and heads
into the grid's first dimension.  Causal masking is done blockwise: kv blocks
strictly above the diagonal are skipped via the block index map (their loads
are masked), diagonal blocks apply the triangular mask.

Validated in interpret mode against models/layers.dot_attention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_fwd_kernel(
    q_ref,  # (1, BQ, D)
    k_ref,  # (1, BK, D)
    v_ref,  # (1, BK, D)
    o_ref,  # (1, BQ, D)
    m_scr,  # (BQ, 1) f32
    l_scr,  # (BQ, 1) f32
    acc_scr,  # (BQ, D) f32
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_len: int,
    causal: bool,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, -jnp.inf, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    def compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        valid = k_pos < seq_len
        if causal:
            valid = valid & (k_pos <= q_pos)
        s = jnp.where(valid, s, -jnp.inf)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.where(
            jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0
        )
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        # diagonal/below blocks only; above-diagonal blocks are no-ops
        @pl.when(ik * block_k <= iq * block_q + block_q - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention(
    q: jnp.ndarray,  # (BH, S, D)
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """Forward flash attention over (batch*heads, seq, head_dim) arrays.

    seq is padded to the block size internally; padded kv positions are
    masked, padded q rows are sliced away.
    """
    BH, S, D = q.shape
    scale = scale or (1.0 / np.sqrt(D))
    S_pad = -(-S // max(block_q, block_k)) * max(block_q, block_k)
    assert S_pad % block_q == 0 and S_pad % block_k == 0, (
        "padded seq must tile both block sizes"
    )
    if S_pad != S:
        pad = ((0, 0), (0, S_pad - S), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nq = S_pad // block_q
    nk = S_pad // block_k
    kernel = functools.partial(
        _flash_fwd_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        seq_len=S,
        causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]


def flash_attention_bhsd(q, k, v, *, causal=True, interpret=False):
    """(B, S, H, D) convenience wrapper matching models/layers layouts."""
    B, S, H, D = q.shape
    fold = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * H, S, D)
    out = flash_attention(
        fold(q), fold(k), fold(v), causal=causal, interpret=interpret
    )
    return jnp.moveaxis(out.reshape(B, H, S, D), 1, 2)

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth that kernel tests assert against (interpret mode),
and the CPU execution path for benchmarks (interpret-mode Pallas is a Python
loop and not representative of anything).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def distance_matrix(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """(B, d) x (N, d) -> (B, N) distances, lower is better.

    l2:  true squared euclidean distance.
    ip:  negative inner product.
    cos: negative cosine similarity (inputs need not be normalized).
    """
    if metric == "l2":
        qn = jnp.sum(q * q, axis=-1, keepdims=True)
        xn = jnp.sum(x * x, axis=-1)
        return qn - 2.0 * (q @ x.T) + xn[None, :]
    if metric == "ip":
        return -(q @ x.T)
    if metric == "cos":
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        return -(qn @ xn.T)
    raise ValueError(metric)


@partial(jax.jit, static_argnames=("k", "metric"))
def distance_topk_ref(q: jnp.ndarray, x: jnp.ndarray, k: int, metric: str = "l2"):
    """Oracle: full (B, N) distance matrix + lax.top_k.

    Returns (dists (B, k) ascending, ids (B, k) int32).
    """
    d = distance_matrix(q, x, metric)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx.astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "metric", "block_n"))
def distance_topk_blocked(
    q: jnp.ndarray, x: jnp.ndarray, k: int, metric: str = "l2", block_n: int = 4096
):
    """Memory-bounded oracle: scan over N blocks carrying a running top-k.

    Semantically identical to distance_topk_ref but never materializes the
    full (B, N) matrix — this is the production CPU/brute-force path and the
    reference for the streaming behaviour of the Pallas kernel.
    """
    B, dim = q.shape
    N = x.shape[0]
    nb = -(-N // block_n)
    n_pad = nb * block_n
    x_pad = jnp.pad(x, ((0, n_pad - N), (0, 0)))
    x_blocks = x_pad.reshape(nb, block_n, dim)

    init_d = jnp.full((B, k), jnp.inf, dtype=jnp.float32)
    init_i = jnp.full((B, k), -1, dtype=jnp.int32)

    def step(carry, inp):
        run_d, run_i = carry
        blk_idx, xb = inp
        d = distance_matrix(q, xb, metric).astype(jnp.float32)
        gid = blk_idx * block_n + jnp.arange(block_n, dtype=jnp.int32)
        valid = gid < N
        d = jnp.where(valid[None, :], d, jnp.inf)
        cat_d = jnp.concatenate([run_d, d], axis=1)
        cat_i = jnp.concatenate(
            [run_i, jnp.broadcast_to(gid[None, :], (B, block_n))], axis=1
        )
        neg, idx = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, idx, axis=1)), None

    (out_d, out_i), _ = jax.lax.scan(
        step, (init_d, init_i), (jnp.arange(nb, dtype=jnp.int32), x_blocks)
    )
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_d, out_i


def bitonic_topk_ref(d: jnp.ndarray, i: jnp.ndarray, k: int):
    """Oracle for the in-kernel bitonic partial sort: ascending-by-distance
    (dist, id) pairs, first k returned."""
    order = jnp.argsort(d, axis=-1)
    return (
        jnp.take_along_axis(d, order, axis=-1)[..., :k],
        jnp.take_along_axis(i, order, axis=-1)[..., :k],
    )

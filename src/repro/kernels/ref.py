"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth that kernel tests assert against (interpret mode),
and the CPU execution path for benchmarks (interpret-mode Pallas is a Python
loop and not representative of anything).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def distance_matrix(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """(B, d) x (N, d) -> (B, N) distances, lower is better.

    l2:  true squared euclidean distance.
    ip:  negative inner product.
    cos: negative cosine similarity (inputs need not be normalized).
    """
    if metric == "l2":
        qn = jnp.sum(q * q, axis=-1, keepdims=True)
        xn = jnp.sum(x * x, axis=-1)
        return qn - 2.0 * (q @ x.T) + xn[None, :]
    if metric == "ip":
        return -(q @ x.T)
    if metric == "cos":
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        return -(qn @ xn.T)
    raise ValueError(metric)


@partial(jax.jit, static_argnames=("k", "metric"))
def distance_topk_ref(q: jnp.ndarray, x: jnp.ndarray, k: int, metric: str = "l2"):
    """Oracle: full (B, N) distance matrix + lax.top_k.

    Returns (dists (B, k) ascending, ids (B, k) int32).
    """
    d = distance_matrix(q, x, metric)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx.astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "metric", "block_n"))
def distance_topk_blocked(
    q: jnp.ndarray, x: jnp.ndarray, k: int, metric: str = "l2",
    block_n: int = 4096, n_valid=None,
):
    """Memory-bounded oracle: scan over N blocks carrying a running top-k.

    Semantically identical to distance_topk_ref but never materializes the
    full (B, N) matrix — this is the production CPU/brute-force path and the
    reference for the streaming behaviour of the Pallas kernel.

    ``n_valid`` (traced scalar) masks rows >= n_valid as padding, so corpora
    padded to shared pow2 size buckets share ONE compiled trace; results are
    bit-identical to scanning the unpadded corpus (padding rows score +inf
    and valid entries are untouched — matmul rows are independent).
    """
    B, dim = q.shape
    N = x.shape[0]
    nb = -(-N // block_n)
    n_pad = nb * block_n
    x_pad = jnp.pad(x, ((0, n_pad - N), (0, 0)))
    x_blocks = x_pad.reshape(nb, block_n, dim)
    nv = jnp.asarray(N if n_valid is None else n_valid, jnp.int32)

    init_d = jnp.full((B, k), jnp.inf, dtype=jnp.float32)
    init_i = jnp.full((B, k), -1, dtype=jnp.int32)

    def step(carry, inp):
        run_d, run_i = carry
        blk_idx, xb = inp
        d = distance_matrix(q, xb, metric).astype(jnp.float32)
        gid = blk_idx * block_n + jnp.arange(block_n, dtype=jnp.int32)
        valid = gid < nv
        d = jnp.where(valid[None, :], d, jnp.inf)
        cat_d = jnp.concatenate([run_d, d], axis=1)
        cat_i = jnp.concatenate(
            [run_i, jnp.broadcast_to(gid[None, :], (B, block_n))], axis=1
        )
        neg, idx = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, idx, axis=1)), None

    (out_d, out_i), _ = jax.lax.scan(
        step, (init_d, init_i), (jnp.arange(nb, dtype=jnp.int32), x_blocks)
    )
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_d, out_i


def q8_score_matrix(
    q_codes: jnp.ndarray,  # (B, D) int8
    x_codes: jnp.ndarray,  # (N, D) int8
    q_scale: jnp.ndarray,  # (B,) f32
    norms2: jnp.ndarray,  # (N,) f32
    metric: str,
) -> jnp.ndarray:
    """(B, N) stage-1 quantized scores, lower is better — the jnp twin of the
    int8 Pallas kernel's per-tile math.  The dot runs int8 x int8 -> int32
    (exact), then ONE fp32 rescale — identical value and operation order to
    the kernel, so scores match bit-for-bit."""
    dots = jax.lax.dot_general(
        q_codes, x_codes, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    qx = dots.astype(jnp.float32) * q_scale[:, None]
    if metric == "l2":
        return norms2[None, :] - 2.0 * qx
    if metric == "ip":
        return -qx
    raise ValueError(metric)


@partial(jax.jit, static_argnames=("k", "metric", "block_n"))
def distance_topk_q8_blocked(
    q_codes: jnp.ndarray,
    x_codes: jnp.ndarray,
    q_scale: jnp.ndarray,
    norms2: jnp.ndarray,
    k: int,
    metric: str = "l2",
    block_n: int = 4096,
    n_valid=None,
):
    """Memory-bounded int8 scan: N blocks carrying a running top-k.

    Semantically identical to the streaming merge inside
    ``distance_topk_q8_pallas`` (scores are bit-equal; ties at the k
    boundary may order differently between lax.top_k and the bitonic
    network).  ``n_valid`` masks padding rows so corpora padded to shared
    shape buckets reuse one trace."""
    B = q_codes.shape[0]
    N = x_codes.shape[0]
    nb = -(-N // block_n)
    n_pad = nb * block_n
    x_pad = jnp.pad(x_codes, ((0, n_pad - N), (0, 0)))
    n2_pad = jnp.pad(norms2, (0, n_pad - N), constant_values=jnp.inf)
    x_blocks = x_pad.reshape(nb, block_n, -1)
    n2_blocks = n2_pad.reshape(nb, block_n)
    nv = jnp.asarray(N if n_valid is None else n_valid, jnp.int32)

    init_d = jnp.full((B, k), jnp.inf, dtype=jnp.float32)
    init_i = jnp.full((B, k), -1, dtype=jnp.int32)

    def step(carry, inp):
        run_d, run_i = carry
        blk_idx, xb, n2b = inp
        d = q8_score_matrix(q_codes, xb, q_scale, n2b, metric)
        gid = blk_idx * block_n + jnp.arange(block_n, dtype=jnp.int32)
        valid = gid < nv
        d = jnp.where(valid[None, :], d, jnp.inf)
        cat_d = jnp.concatenate([run_d, d], axis=1)
        cat_i = jnp.concatenate(
            [run_i, jnp.broadcast_to(gid[None, :], (B, block_n))], axis=1
        )
        neg, idx = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, idx, axis=1)), None

    (out_d, out_i), _ = jax.lax.scan(
        step,
        (init_d, init_i),
        (jnp.arange(nb, dtype=jnp.int32), x_blocks, n2_blocks),
    )
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_d, out_i


def bitonic_topk_ref(d: jnp.ndarray, i: jnp.ndarray, k: int):
    """Oracle for the in-kernel bitonic partial sort: ascending-by-distance
    (dist, id) pairs, first k returned."""
    order = jnp.argsort(d, axis=-1)
    return (
        jnp.take_along_axis(d, order, axis=-1)[..., :k],
        jnp.take_along_axis(i, order, axis=-1)[..., :k],
    )

"""Trace-stability lint (LANNS001-006).

Scope: functions marked ``# lanns: hotpath`` plus everything reachable from
them through same-module calls (``foo(...)`` to a module-level def,
``self.meth(...)`` to a method of the enclosing class).  Hot functions that
are themselves jit-wrapped (or Pallas kernel bodies, detected by ``*_ref``
parameters) run under trace, where Python loops unroll at compile time —
LANNS001-004 do not apply inside them; LANNS005/006 still do.

Device-value inference is a single forward pass per function: a name is
"device-valued" after being assigned from a ``jnp.``/``jax.`` call, from a
call to a known jitted callable, or from an expression over device values.
``np.asarray(x)`` re-hosts it.  The tracking is deliberately local and
conservative — it exists to catch the syncs that matter (hot loops, hot
returns), not to be a type system.
"""

from __future__ import annotations

import ast

from .rules import Finding, SourceFile, attr_chain

# jitted callables living in other modules: calls to these produce device
# values even though the decorator is out of scope for a per-module pass.
KNOWN_JITTED = {
    "beam_search", "beam_search_flat", "beam_search_stacked",
    "distance_topk", "distance_topk_q8", "distance_topk_jit",
    "distance_topk_blocked", "distance_topk_q8_blocked",
    "merge_topk", "_stage1_scores", "_rerank_gather_dev",
}

_HOST_CAST = {"float", "int", "bool"}
_NP_SYNC = {"np.asarray", "np.array", "np.from_dlpack", "np.copy",
            "numpy.asarray", "numpy.array", "numpy.from_dlpack"}
_SHAPE_CTORS = {"zeros", "ones", "full", "empty", "arange", "linspace",
                "eye", "broadcast_to", "tile", "repeat", "iota"}


def _is_kernel_body(fn: ast.FunctionDef) -> bool:
    return any(a.arg.endswith("_ref") for a in fn.args.args)


def _jit_static_names(fn: ast.FunctionDef) -> tuple[bool, set[str]]:
    """(is_jitted, static param names) from @jax.jit / @partial(jax.jit,...)
    decorators."""
    params = [a.arg for a in fn.args.args + fn.args.kwonlyargs]
    for dec in fn.decorator_list:
        chain = attr_chain(dec)
        if chain in ("jax.jit", "jit"):
            return True, set()
        if isinstance(dec, ast.Call):
            cchain = attr_chain(dec.func)
            target = dec.args[0] if dec.args else None
            is_partial_jit = (
                cchain in ("partial", "functools.partial")
                and target is not None
                and attr_chain(target) in ("jax.jit", "jit")
            )
            if not (is_partial_jit or cchain in ("jax.jit", "jit")):
                continue
            static: set[str] = set()
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    for el in ast.walk(kw.value):
                        if isinstance(el, ast.Constant) and \
                                isinstance(el.value, str):
                            static.add(el.value)
                elif kw.arg == "static_argnums":
                    for el in ast.walk(kw.value):
                        if isinstance(el, ast.Constant) and \
                                isinstance(el.value, int):
                            if el.value < len(params):
                                static.add(params[el.value])
            return True, static
    return False, set()


class _FunctionIndex(ast.NodeVisitor):
    """qualname -> def node, plus per-function metadata."""

    def __init__(self) -> None:
        self.funcs: dict[str, ast.FunctionDef] = {}
        self._class: list[str] = []

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class.append(node.name)
        self.generic_visit(node)
        self._class.pop()

    def _def(self, node: ast.FunctionDef) -> None:
        qual = f"{self._class[-1]}.{node.name}" if self._class else node.name
        self.funcs.setdefault(qual, node)
        # nested defs are not independently indexed on purpose: they run as
        # part of their parent and are walked with it.

    visit_FunctionDef = _def
    visit_AsyncFunctionDef = _def


def _callees(qual: str, fn: ast.FunctionDef,
             funcs: dict[str, ast.FunctionDef]) -> set[str]:
    cls = qual.split(".")[0] if "." in qual else None
    out: set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func)
        if chain in funcs:
            out.add(chain)
        elif cls and chain.startswith("self."):
            meth = f"{cls}.{chain[5:]}"
            if meth in funcs:
                out.add(meth)
    return out


def hot_roster(src: SourceFile) -> dict[str, ast.FunctionDef]:
    """Marked functions plus their same-module call closure."""
    idx = _FunctionIndex()
    idx.visit(src.tree)
    seeds = [q for q, fn in idx.funcs.items() if src.func_is_hot(fn)]
    seen: dict[str, ast.FunctionDef] = {}
    work = list(seeds)
    while work:
        qual = work.pop()
        if qual in seen:
            continue
        fn = idx.funcs[qual]
        seen[qual] = fn
        # Closure stops at jitted defs and kernel bodies: everything THEY
        # call runs at trace time, not per-query on host, so host-sync
        # rules don't apply beyond this boundary.
        if _jit_static_names(fn)[0] or _is_kernel_body(fn):
            continue
        work.extend(_callees(qual, fn, idx.funcs))
    return seen


class _DeviceTracker(ast.NodeVisitor):
    """Forward pass over one function; flags LANNS001-004 as it walks."""

    def __init__(self, src: SourceFile, qual: str, traced: bool) -> None:
        self.src = src
        self.qual = qual
        self.traced = traced  # jit-wrapped or kernel body: loops unroll
        self.device: set[str] = set()
        self.loop_depth = 0
        self.findings: list[Finding] = []

    # -- device-value expression test -------------------------------------

    def is_device(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.device
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            root = chain.split(".")[0] if chain else ""
            if root in ("jnp", "jax"):
                return True
            if chain in KNOWN_JITTED or chain.split(".")[-1] in KNOWN_JITTED:
                return True
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "block_until_ready":
                return self.is_device(node.func.value)
            return False
        if isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
            return self.is_device(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_device(node.left) or self.is_device(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_device(node.operand)
        if isinstance(node, ast.IfExp):
            return self.is_device(node.body) or self.is_device(node.orelse)
        return False

    def _bind(self, target: ast.AST, device: bool) -> None:
        if isinstance(target, ast.Name):
            (self.device.add if device else self.device.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind(el, device)

    # -- statements --------------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        dev = self.is_device(node.value)
        for t in node.targets:
            self._bind(t, dev)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.visit(node.value)
        if self.is_device(node.value):
            self._bind(node.target, True)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self.visit(node.value)
            self._bind(node.target, self.is_device(node.value))

    def _loop(self, node: ast.AST) -> None:
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = _loop
    visit_While = _loop

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested def: walk it with the same tracker (closures run inline on
        # the hot path often enough to deserve the same rules)
        self.generic_visit(node)

    # -- the rules ---------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        if self.traced:
            return
        chain = attr_chain(node.func)
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args:
            self.findings.append(Finding(
                "LANNS001", self.src.path, node.lineno,
                f"`.item()` in hot function `{self.qual}` forces a "
                "device->host sync",
            ))
        if isinstance(node.func, ast.Name) and node.func.id in _HOST_CAST \
                and len(node.args) == 1 and self.is_device(node.args[0]):
            self.findings.append(Finding(
                "LANNS002", self.src.path, node.lineno,
                f"`{node.func.id}()` of a device value in hot function "
                f"`{self.qual}` blocks on the device",
            ))
        if chain in _NP_SYNC and node.args and self.is_device(node.args[0]):
            where = "inside a host loop" if self.loop_depth else \
                "in hot function"
            self.findings.append(Finding(
                "LANNS003", self.src.path, node.lineno,
                f"`{chain}` of a device value {where} `{self.qual}` is a "
                "host sync",
            ))
        root = chain.split(".")[0] if chain else ""
        if self.loop_depth and root in ("jnp", "jax"):
            self.findings.append(Finding(
                "LANNS004", self.src.path, node.lineno,
                f"`{chain}` inside a host-side loop in `{self.qual}` "
                "dispatches per-iteration",
            ))


_STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}


def _names_outside_shape_attrs(expr: ast.AST) -> list[ast.Name]:
    """Name nodes in expr, pruning `x.shape`/`x.dtype`-style subtrees: the
    shape of a TRACED argument is static, so `jnp.ones(q.shape[0])` is
    trace-stable even when `q` itself is not a static arg."""
    out: list[ast.Name] = []

    def walk(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return
        if isinstance(node, ast.Name):
            out.append(node)
        for child in ast.iter_child_nodes(node):
            walk(child)

    walk(expr)
    return out


def _check_static_shapes(src: SourceFile, qual: str, fn: ast.FunctionDef,
                         findings: list[Finding]) -> None:
    """LANNS005 on a jit-wrapped def: non-static params in shape positions."""
    jitted, static = _jit_static_names(fn)
    if not jitted:
        return
    params = {a.arg for a in fn.args.args + fn.args.kwonlyargs} - static

    def flag(name_node: ast.Name, what: str) -> None:
        findings.append(Finding(
            "LANNS005", src.path, name_node.lineno,
            f"jit param `{name_node.id}` of `{qual}` used as {what} but not "
            "in static_argnums/static_argnames — every distinct value "
            "retraces",
        ))

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            tail = chain.split(".")[-1] if chain else ""
            shapeish = (
                tail in _SHAPE_CTORS
                and chain.split(".")[0] in ("jnp", "np", "jax", "lax")
            ) or tail == "reshape" or (
                isinstance(node.func, ast.Name) and node.func.id == "range"
            )
            if not shapeish:
                continue
            args = list(node.args) + [
                kw.value for kw in node.keywords
                if kw.arg in ("shape", "axis", "new_sizes")
            ]
            for a in args:
                for el in _names_outside_shape_attrs(a):
                    if el.id in params:
                        flag(el, f"a shape argument of `{chain}`")
        elif isinstance(node, ast.Slice):
            for bound in (node.lower, node.upper, node.step):
                if isinstance(bound, ast.Name) and bound.id in params:
                    flag(bound, "a static slice bound")


def _iter_is_unordered(it: ast.AST) -> str | None:
    """Human tag if the iterable has nondeterministic / insertion order that
    a sorted() wrapper would fix; None if it is fine."""
    if isinstance(it, (ast.Set, ast.SetComp)):
        return "a set"
    if isinstance(it, ast.Call):
        chain = attr_chain(it.func)
        if chain == "set":
            return "a set"
        if isinstance(it.func, ast.Attribute) and \
                it.func.attr in ("items", "keys", "values"):
            return f"dict .{it.func.attr}()"
    return None


_ARRAY_FEED = {"asarray", "array", "stack", "concatenate", "vstack",
               "hstack", "column_stack", "append", "full", "zeros", "ones"}


def _feeds_arrays(body: list[ast.stmt]) -> bool:
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                chain = attr_chain(node.func)
                tail = chain.split(".")[-1] if chain else ""
                if tail in _ARRAY_FEED:
                    return True
    return False


def _check_unordered_iteration(src: SourceFile, qual: str,
                               fn: ast.FunctionDef,
                               findings: list[Finding]) -> None:
    for node in ast.walk(fn):
        if isinstance(node, ast.For):
            tag = _iter_is_unordered(node.iter)
            if tag and _feeds_arrays(node.body):
                findings.append(Finding(
                    "LANNS006", src.path, node.lineno,
                    f"iteration over {tag} feeds array construction in "
                    f"`{qual}` — wrap in sorted() for deterministic "
                    "trace/layout order",
                ))
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for comp in node.generators:
                tag = _iter_is_unordered(comp.iter)
                if tag and isinstance(node, (ast.ListComp, ast.GeneratorExp)):
                    wrapped = ast.Expr(value=getattr(node, "elt", node))
                    if _feeds_arrays([wrapped]):
                        findings.append(Finding(
                            "LANNS006", src.path, node.lineno,
                            f"comprehension over {tag} feeds array "
                            f"construction in `{qual}`",
                        ))


def run(src: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    hot = hot_roster(src)
    for qual, fn in sorted(hot.items()):
        traced = _jit_static_names(fn)[0] or _is_kernel_body(fn)
        tracker = _DeviceTracker(src, qual, traced)
        for stmt in fn.body:
            tracker.visit(stmt)
        findings.extend(tracker.findings)
        _check_unordered_iteration(src, qual, fn, findings)
    # LANNS005 applies to every jitted def, hot-marked or not: a retracing
    # jit is a latency bug wherever it lives.
    idx = _FunctionIndex()
    idx.visit(src.tree)
    for qual, fn in sorted(idx.funcs.items()):
        _check_static_shapes(src, qual, fn, findings)
    return findings

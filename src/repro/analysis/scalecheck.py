"""Scale-safety pass (LANNS030-034): symbolic shape/dtype abstract
interpretation at declared dimension bounds.

The repo has only ever *run* at <= 1M points, but the paper serves 180M —
and index arithmetic that is fine at 1M silently wraps int32 long before
paper scale.  This pass proves (or refutes) scale safety statically: a
module declares bounds with ``# lanns: dims[n<=180_000_000, d<=2048]`` and
the interpreter threads those bounds through numpy/jnp shape-producing ops
(``arange``/``full``/``zeros``/``broadcast_to``/``cumsum``/``reshape`` and
index arithmetic) over the ``# lanns: hotpath`` roster plus any function
carrying its own ``dims``/``budget`` directive.

Conservatism contract: a rule fires only on a PROVABLE violation at the
declared bounds — unknown values never flag.  This keeps honestly-annotated
code clean while making every overflow the bounds imply undeniable.

Name binding: any name (assignment target, loop variable, parameter,
attribute tail like ``plan.pstk``, or string dict key like
``stack["n_pad"]``) matching a declared dim is tracked at that dim's bound.
Runtime guards refine bounds: ``assert x <= C`` (and the equivalent
``if x > C: raise``) clamps ``x`` — the *proven-bounded cast* idiom the
LANNS030 fixes use.

Rules:

* LANNS030 — int32/uint32 value-range overflow at the bounds (flattened-id
  products like ``pi * n_pad`` landing in int32 storage).
* LANNS031 — implicit dtype promotion on a hot path: fp64 leaking into
  fp32 math, int64/fp64 silently narrowed by ``jnp.asarray`` (x64
  disabled), int8 arithmetic outside an explicit ``astype`` rescale.
* LANNS032 — int64 values stored into int32 array slots without an
  explicit cast.
* LANNS033 — a jit static/shape argument ranging over a declared dim
  without pow2/quarter-pow2 bucketing (unbounded trace cardinality);
  hot-roster functions only.
* LANNS034 — the static device-resident footprint of a
  ``# lanns: budget[device<=8GiB]`` function, summed in closed form at the
  bounds, exceeds its declaration.

``footprint_report`` emits the closed-form resident-bytes model per
engine x quantization mode (the ``--footprint-report`` CLI artifact).
"""

from __future__ import annotations

import ast
import dataclasses
from dataclasses import dataclass

from .rules import Finding, SourceFile, attr_chain
from .symdims import (
    DTYPE_BYTES,
    INT_RANGES,
    Sym,
    canon_dtype,
    fmt_bytes,
    is_float_dtype,
    next_pow2_bound,
    quarter_pow2_bound,
    sym_max,
    sym_min,
)
from .tracelint import KNOWN_JITTED, _FunctionIndex, hot_roster

BUCKET_FUNCS = {"next_pow2", "next_pow2_quarter"}
#: kwarg names that are static (shape-burning) in the known-jitted entry
#: points; other kwargs (e.g. ``n_valid``) are traced operands and MUST NOT
#: trip LANNS033 — tracing them is exactly how the bucketing contract keeps
#: the trace set finite.
STATIC_KWARG_NAMES = {"k", "k_pad", "ef", "max_iters", "topk",
                      "block_q", "block_n"}
_SHAPE_CTORS = {"zeros", "ones", "empty", "full"}
_ARRAY_MODS = {"np", "numpy", "jnp"}
_ARITH_OPS = (ast.Add, ast.Sub, ast.Mult, ast.Div, ast.FloorDiv, ast.Mod,
              ast.Pow, ast.MatMult)


@dataclass
class AV:
    """Abstract value: dtype + value interval + (symbolic) shape.

    ``dtype`` is a canonical numpy dtype name, or the pseudo-dtypes
    "pyint"/"pyfloat"/"pybool" for Python scalars; None when unknown.
    ``shape`` is a tuple of scalar AVs (one per dim; None elements for
    unknown dims).  ``elts`` carries Python tuple/list literals (shape
    arguments, ``x.shape`` results).  ``bucketed`` marks values produced by
    ``next_pow2``/``next_pow2_quarter`` — the finite-trace-set certificate
    LANNS033 looks for.
    """

    dtype: str | None = None
    rng: Sym | None = None
    shape: tuple | None = None
    elts: tuple | None = None
    bucketed: bool = False
    dtype_ref: str | None = None  # this AV *names* a dtype (np.int32 arg)

    @property
    def is_const(self) -> bool:
        return self.rng is not None and self.rng.is_const


UNKNOWN = AV()


def _promote(da: str | None, db: str | None) -> str | None:
    if da == db:
        return da
    if da is None or db is None:
        # a known array dtype absorbs a Python scalar; anything else: unknown
        known, other = (da, db) if da is not None else (db, da)
        del other
        return known if known not in ("pyint", "pyfloat", "pybool") else None
    for weak in ("pybool", "pyint"):
        if da == weak:
            return db
        if db == weak:
            return da
    if "pyfloat" in (da, db):
        other = db if da == "pyfloat" else da
        return other if is_float_dtype(other) else None
    if is_float_dtype(da) or is_float_dtype(db):
        fa = da if is_float_dtype(da) else "float32"
        fb = db if is_float_dtype(db) else "float32"
        return fa if DTYPE_BYTES.get(fa, 0) >= DTYPE_BYTES.get(fb, 0) else fb
    if da in INT_RANGES and db in INT_RANGES:
        return da if DTYPE_BYTES[da] >= DTYPE_BYTES[db] else db
    return None


def _clamp_to_dtype(rng: Sym | None, dtype: str | None) -> Sym | None:
    if rng is None or dtype not in INT_RANGES:
        return rng
    lo, hi = INT_RANGES[dtype]
    return Sym(rng.expr, min(rng.hi, hi), max(rng.lo, lo))


class _FnInterp:
    """One forward pass over a function body at the declared bounds."""

    def __init__(self, src: SourceFile, qual: str, fn: ast.FunctionDef, *,
                 dims: dict[str, int], budget: dict[str, int], hot: bool,
                 consts: dict[str, AV], findings: list[Finding]) -> None:
        self.src = src
        self.qual = qual
        self.fn = fn
        self.dims = dims
        self.budget = budget
        self.hot = hot
        self.findings = findings
        self.env: dict[str, AV] = dict(consts)
        self.refined: dict[str, int] = {}  # proven `expr <= C` facts
        self.allocs: list[tuple[int, Sym]] = []  # (line, device bytes)

    # -- plumbing ----------------------------------------------------------

    def _dim_av(self, name: str, cap: int | None = None) -> AV:
        hi = self.dims[name]
        if cap is not None:
            hi = min(hi, cap)
        return AV(dtype="pyint", rng=Sym(name, hi, 0))

    def _flag(self, code: str, lineno: int, msg: str) -> None:
        self.findings.append(Finding(code, self.src.path, lineno, msg))

    def _mentions_dim(self, expr: str) -> bool:
        import re

        return any(
            re.search(rf"\b{re.escape(d)}\b", expr) for d in self.dims
        )

    def _symbolic_unbucketed(self, av: AV | None) -> bool:
        return (
            av is not None and av.rng is not None and not av.bucketed
            and not av.is_const and self._mentions_dim(av.rng.expr)
        )

    # -- entry -------------------------------------------------------------

    def run(self) -> None:
        args = self.fn.args
        for a in args.args + args.kwonlyargs + args.posonlyargs:
            if a.arg in self.dims:
                self.env[a.arg] = self._dim_av(a.arg)
        for stmt in self.fn.body:
            self.stmt(stmt)
        self._check_budget()

    def _check_budget(self) -> None:
        limit = self.budget.get("device")
        if limit is None:
            return
        if not self.allocs:
            return
        total = sum(b.hi for _, b in self.allocs)
        if total <= limit:
            return
        formula = " + ".join(b.expr for _, b in self.allocs)
        self._flag(
            "LANNS034", self.fn.lineno,
            f"`{self.qual}` device-resident footprint at declared bounds is "
            f"{fmt_bytes(total)} ({formula}) > budget[device<="
            f"{fmt_bytes(limit)}]",
        )

    # -- statements --------------------------------------------------------

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, ast.Assign):
            av = self.eval(node.value)
            for t in node.targets:
                self.assign(t, av)
        elif isinstance(node, ast.AnnAssign):
            if node.value is not None:
                self.assign(node.target, self.eval(node.value))
        elif isinstance(node, ast.AugAssign):
            self.eval(node.value)
            if isinstance(node.target, ast.Name):
                # value changes across iterations: drop to unknown unless
                # the name is a declared dim (then the bound still holds)
                name = node.target.id
                self.env[name] = (
                    self._dim_av(name) if name in self.dims else UNKNOWN
                )
        elif isinstance(node, ast.Assert):
            self._refine_from_test(node.test)
        elif isinstance(node, ast.If):
            self.eval(node.test)
            raises = node.body and all(
                isinstance(s, ast.Raise) for s in node.body
            )
            if raises:
                # `if X > C: raise` proves X <= C on the fall-through
                self._refine_from_guard(node.test)
            else:
                for s in node.body:
                    self.stmt(s)
            for s in node.orelse:
                self.stmt(s)
        elif isinstance(node, ast.For):
            self._bind_loop(node.target, node.iter)
            for s in node.body + node.orelse:
                self.stmt(s)
        elif isinstance(node, ast.While):
            self.eval(node.test)
            for s in node.body + node.orelse:
                self.stmt(s)
        elif isinstance(node, ast.With):
            for s in node.body:
                self.stmt(s)
        elif isinstance(node, ast.Try):
            for s in node.body + node.finalbody:
                self.stmt(s)
            for h in node.handlers:
                for s in h.body:
                    self.stmt(s)
        elif isinstance(node, (ast.Return, ast.Expr)):
            if node.value is not None:
                self.eval(node.value)
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.env.pop(t.id, None)
        # nested defs/classes: separate scopes, skipped on purpose

    def _bind_loop(self, target: ast.AST, it: ast.AST) -> None:
        if isinstance(it, ast.Call) and isinstance(target, ast.Name):
            chain = attr_chain(it.func)
            if chain == "range" and it.args:
                stop = self.eval(it.args[0 if len(it.args) == 1 else 1])
                if stop.rng is not None:
                    self.env[target.id] = AV(
                        dtype="pyint",
                        rng=Sym(target.id, max(stop.rng.hi - 1, 0), 0),
                    )
                    return
        self._bind_names(target)

    def _bind_names(self, target: ast.AST) -> None:
        """Fallback loop-target binding: declared dims keep their bound."""
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                self.env[n.id] = (
                    self._dim_av(n.id) if n.id in self.dims else UNKNOWN
                )

    def assign(self, target: ast.AST, av: AV) -> None:
        if isinstance(target, ast.Name):
            name = target.id
            if name in self.dims and av.shape is None and av.elts is None:
                cap = av.rng.hi if av.rng is not None else None
                bound = self._dim_av(name, cap)
                self.env[name] = dataclasses.replace(
                    bound, bucketed=av.bucketed
                )
            else:
                self.env[name] = av
        elif isinstance(target, (ast.Tuple, ast.List)):
            vals = av.elts
            if vals is not None and len(vals) == len(target.elts):
                for t, v in zip(target.elts, vals):
                    self.assign(t, v if v is not None else UNKNOWN)
            else:
                self._bind_names(target)
        elif isinstance(target, ast.Subscript):
            self._check_store(target, av)
        elif isinstance(target, ast.Starred):
            self.assign(target.value, UNKNOWN)
        # attribute targets (self.x = ...) are not tracked

    def _check_store(self, target: ast.Subscript, val: AV) -> None:
        base = self.eval(target.value)
        self.eval(target.slice)
        if base.dtype not in ("int32", "uint32"):
            return
        if val.dtype in ("int64", "uint64"):
            self._flag(
                "LANNS032", target.lineno,
                f"{val.dtype} value stored into {base.dtype} slots of "
                f"`{ast.unparse(target.value)}` in `{self.qual}` — cast "
                "explicitly after a bounds assert",
            )
            return
        lo, hi = INT_RANGES[base.dtype]
        if val.rng is not None and (val.rng.hi > hi or val.rng.lo < lo):
            self._flag(
                "LANNS030", target.lineno,
                f"store into {base.dtype} `{ast.unparse(target.value)}` in "
                f"`{self.qual}`: value {val.rng.expr} reaches "
                f"{val.rng.hi:_} at declared bounds (> {hi:_})",
            )

    # -- guard refinement --------------------------------------------------

    def _const_of(self, node: ast.AST) -> int | None:
        av = self.eval(node)
        if av.rng is not None and av.rng.is_const:
            return av.rng.hi
        return None

    def _refine_from_test(self, test: ast.AST) -> None:
        """assert X <= C / X < C: clamp X (Name or Name+Y) and memoize."""
        if not (isinstance(test, ast.Compare) and len(test.ops) == 1):
            return
        op = test.ops[0]
        if not isinstance(op, (ast.LtE, ast.Lt)):
            return
        bound = self._const_of(test.comparators[0])
        if bound is None:
            return
        if isinstance(op, ast.Lt):
            bound -= 1
        self._refine_le(test.left, bound)

    def _refine_from_guard(self, test: ast.AST) -> None:
        """`if X > C: raise` / `if X >= C: raise`: fall-through has X <= C."""
        if not (isinstance(test, ast.Compare) and len(test.ops) == 1):
            return
        op = test.ops[0]
        if not isinstance(op, (ast.Gt, ast.GtE)):
            return
        bound = self._const_of(test.comparators[0])
        if bound is None:
            return
        if isinstance(op, ast.GtE):
            bound -= 1
        self._refine_le(test.left, bound)

    def _refine_le(self, left: ast.AST, bound: int) -> None:
        av = self.eval(left)
        if av.rng is not None:
            self.refined[av.rng.expr] = min(
                self.refined.get(av.rng.expr, bound), bound
            )
        if isinstance(left, ast.Name) and left.id in self.env:
            cur = self.env[left.id]
            if cur.rng is not None:
                self.env[left.id] = dataclasses.replace(
                    cur, rng=cur.rng.clamp_hi(bound)
                )
        elif isinstance(left, ast.BinOp) and isinstance(left.op, ast.Add) \
                and isinstance(left.left, ast.Name):
            other = self.eval(left.right)
            slack = other.rng.lo if other.rng is not None else 0
            cur = self.env.get(left.left.id)
            if cur is not None and cur.rng is not None:
                self.env[left.left.id] = dataclasses.replace(
                    cur, rng=cur.rng.clamp_hi(bound - slack)
                )

    # -- expressions -------------------------------------------------------

    def eval(self, node: ast.AST) -> AV:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool):
                return AV(dtype="pybool")
            if isinstance(node.value, int):
                return AV(dtype="pyint", rng=Sym.lit(node.value))
            if isinstance(node.value, float):
                return AV(dtype="pyfloat")
            if isinstance(node.value, str):
                dt = canon_dtype(node.value)
                return AV(dtype_ref=dt) if dt else UNKNOWN
            return UNKNOWN
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            if node.id in self.dims:
                return self._dim_av(node.id)
            return UNKNOWN
        if isinstance(node, (ast.Tuple, ast.List)):
            return AV(elts=tuple(self.eval(e) for e in node.elts))
        if isinstance(node, ast.BinOp):
            return self._binop(node)
        if isinstance(node, ast.UnaryOp):
            inner = self.eval(node.operand)
            if isinstance(node.op, ast.USub) and inner.rng is not None:
                return dataclasses.replace(inner, rng=-inner.rng)
            if isinstance(node.op, ast.Not):
                return AV(dtype="pybool")
            return dataclasses.replace(inner, rng=None)
        if isinstance(node, ast.Compare):
            self.eval(node.left)
            for c in node.comparators:
                self.eval(c)
            return AV(dtype="pybool")
        if isinstance(node, ast.BoolOp):
            for v in node.values:
                self.eval(v)
            return UNKNOWN
        if isinstance(node, ast.IfExp):
            self.eval(node.test)
            a, b = self.eval(node.body), self.eval(node.orelse)
            if a.dtype == b.dtype and a.rng is not None and b.rng is not None:
                return AV(dtype=a.dtype, rng=a.rng.hull(b.rng),
                          bucketed=a.bucketed and b.bucketed)
            return UNKNOWN
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.Attribute):
            return self._attr(node)
        if isinstance(node, ast.Subscript):
            return self._subscript(node)
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            for gen in node.generators:
                self.eval(gen.iter)
            return UNKNOWN
        if isinstance(node, ast.Slice):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self.eval(part)
            return UNKNOWN
        return UNKNOWN

    def _binop(self, node: ast.BinOp) -> AV:
        lt, rt = self.eval(node.left), self.eval(node.right)
        if isinstance(node.op, _ARITH_OPS):
            self._check_promotion(node, lt, rt)
        dtype = _promote(lt.dtype, rt.dtype)
        rng: Sym | None = None
        if lt.rng is not None and rt.rng is not None:
            if isinstance(node.op, ast.Add):
                rng = lt.rng + rt.rng
            elif isinstance(node.op, ast.Sub):
                rng = lt.rng - rt.rng
            elif isinstance(node.op, ast.Mult):
                rng = lt.rng * rt.rng
            elif isinstance(node.op, ast.FloorDiv):
                rng = lt.rng // rt.rng
            elif isinstance(node.op, ast.Mod):
                rng = lt.rng % rt.rng
            elif isinstance(node.op, ast.MatMult):
                rng = self._matmul_rng(lt, rt)
        if isinstance(node.op, ast.Div):
            dtype = dtype if is_float_dtype(dtype) else "pyfloat"
            rng = None
        if rng is not None and rng.expr in self.refined:
            rng = rng.clamp_hi(self.refined[rng.expr])
        shape = lt.shape if lt.shape is not None else rt.shape
        if isinstance(node.op, ast.MatMult):
            shape = None
        self._check_int_range(node.lineno, dtype, rng,
                              f"`{ast.unparse(node)}`")
        return AV(dtype=dtype, rng=rng, shape=shape)

    def _matmul_rng(self, lt: AV, rt: AV) -> Sym | None:
        """int matmul accumulator bound: contraction length x |a| x |b|."""
        if lt.shape is None or not lt.shape or lt.shape[-1] is None:
            return None
        contraction = lt.shape[-1]
        if contraction.rng is None or lt.rng is None or rt.rng is None:
            return None
        mags = (abs(lt.rng.hi), abs(lt.rng.lo), abs(rt.rng.hi),
                abs(rt.rng.lo))
        a = Sym(lt.rng.expr, max(mags[:2]), -max(mags[:2]))
        b = Sym(rt.rng.expr, max(mags[2:]), -max(mags[2:]))
        return contraction.rng * a * b

    def _check_promotion(self, node: ast.BinOp, lt: AV, rt: AV) -> None:
        dts = {lt.dtype, rt.dtype}
        if "float64" in dts and "float32" in dts:
            self._flag(
                "LANNS031", node.lineno,
                f"float64 x float32 arithmetic in `{self.qual}` "
                f"(`{ast.unparse(node)}`): fp64 weak-type leak on a hot "
                "path — pin float32",
            )
        if "int8" in dts:
            self._flag(
                "LANNS031", node.lineno,
                f"int8 arithmetic without an explicit astype in "
                f"`{self.qual}` (`{ast.unparse(node)}`): the int8 "
                "accumulator wraps at +-127 products — rescale via "
                ".astype(...) first",
            )

    def _check_int_range(self, lineno: int, dtype: str | None,
                         rng: Sym | None, what: str) -> None:
        if dtype not in ("int32", "uint32") or rng is None:
            return
        lo, hi = INT_RANGES[dtype]
        if rng.hi > hi or rng.lo < lo:
            self._flag(
                "LANNS030", lineno,
                f"{what} is {dtype} but reaches {rng.hi:_} at declared "
                f"bounds ({rng.expr}) — exceeds {dtype} "
                f"[{lo:_}, {hi:_}] in `{self.qual}`",
            )

    # -- attribute / subscript --------------------------------------------

    def _attr(self, node: ast.Attribute) -> AV:
        chain = attr_chain(node)
        dt = canon_dtype(chain) if chain else None
        if dt and chain.split(".")[0] in ("np", "numpy", "jnp", "jax"):
            return AV(dtype_ref=dt)
        # np.iinfo(np.int32).max / .min
        if node.attr in ("max", "min") and isinstance(node.value, ast.Call):
            ichain = attr_chain(node.value.func)
            if ichain and ichain.split(".")[-1] == "iinfo" \
                    and node.value.args:
                ref = self.eval(node.value.args[0]).dtype_ref
                if ref in INT_RANGES:
                    lo, hi = INT_RANGES[ref]
                    v = hi if node.attr == "max" else lo
                    return AV(dtype="pyint", rng=Sym.lit(v))
        base = self.eval(node.value)
        if node.attr == "shape":
            if base.shape is not None:
                return AV(elts=base.shape)
            return UNKNOWN
        if node.attr == "size" and base.shape is not None:
            rng = None
            if all(d is not None and d.rng is not None for d in base.shape):
                rng = Sym.lit(1)
                for d in base.shape:
                    rng = rng * d.rng
            return AV(dtype="pyint", rng=rng)
        if node.attr == "T":
            shape = None
            if base.shape is not None:
                shape = tuple(reversed(base.shape))
            return dataclasses.replace(base, shape=shape, elts=None)
        if node.attr in self.dims:
            return self._dim_av(node.attr)
        return UNKNOWN

    def _subscript(self, node: ast.Subscript) -> AV:
        base = self.eval(node.value)
        idx = node.slice
        if base.elts is not None and isinstance(idx, ast.Constant) \
                and isinstance(idx.value, int) \
                and -len(base.elts) <= idx.value < len(base.elts):
            got = base.elts[idx.value]
            return got if got is not None else UNKNOWN
        if isinstance(idx, ast.Constant) and isinstance(idx.value, str) \
                and idx.value in self.dims:
            self.eval(idx)
            return self._dim_av(idx.value)
        self.eval(idx)
        if base.dtype in (None, "pyint", "pyfloat", "pybool") \
                and base.elts is None and base.shape is None:
            return UNKNOWN
        return AV(dtype=base.dtype, rng=base.rng)

    # -- calls -------------------------------------------------------------

    def _kw(self, node: ast.Call, name: str) -> ast.AST | None:
        for kw in node.keywords:
            if kw.arg == name:
                return kw.value
        return None

    def _dtype_arg(self, node: ast.AST | None) -> str | None:
        if node is None:
            return None
        return self.eval(node).dtype_ref

    def _shape_of(self, av: AV) -> tuple | None:
        if av.elts is not None:
            return tuple(
                e if e is not None and e.rng is not None else None
                for e in av.elts
            )
        if av.rng is not None:
            return (av,)
        return None

    def _infer_reshape(self, shape: tuple | None,
                       base_shape: tuple | None) -> tuple | None:
        """Resolve a single -1 wildcard dim from the source total.

        ``x.reshape(-1, C)`` keeps x's element count: the wildcard is
        total // prod(other dims).  With an unknown source shape (or more
        than one wildcard) the -1 stays, which downstream checks treat as
        an unknown dim — conservative, never flagged.
        """
        if shape is None or base_shape is None:
            return shape
        wild = [j for j, s in enumerate(shape)
                if s is not None and s.rng is not None
                and s.rng.is_const and s.rng.hi == -1]
        if len(wild) != 1 or any(
            s is None or s.rng is None for s in base_shape
        ):
            return shape
        total = Sym.lit(1)
        for s in base_shape:
            total = total * s.rng
        inferred = total
        for j, s in enumerate(shape):
            if j != wild[0] and s is not None and s.rng is not None:
                inferred = inferred // s.rng
            elif j != wild[0]:
                return shape  # a sibling dim is unknown: keep the -1
        return tuple(
            AV(dtype="pyint", rng=inferred) if j == wild[0] else s
            for j, s in enumerate(shape)
        )

    def _device_alloc(self, lineno: int, shape: tuple | None,
                      dtype: str | None, label: str) -> None:
        if not self.budget or shape is None or dtype not in DTYPE_BYTES:
            return
        if any(d is None or d.rng is None for d in shape):
            return
        nbytes = Sym.lit(DTYPE_BYTES[dtype])
        for d in shape:
            nbytes = nbytes * d.rng
        self.allocs.append(
            (lineno, Sym(f"{label}:{nbytes.expr}", nbytes.hi, nbytes.lo))
        )

    def _check_shape_buckets(self, lineno: int, shape: tuple | None,
                             what: str) -> None:
        if not self.hot or shape is None:
            return
        for d in shape:
            if d is not None and self._symbolic_unbucketed(d):
                self._flag(
                    "LANNS033", lineno,
                    f"{what} in `{self.qual}` has a shape dim "
                    f"`{d.rng.expr}` ranging over a declared dim without "
                    "pow2/quarter-pow2 bucketing — every distinct value "
                    "compiles a new trace",
                )

    def _call(self, node: ast.Call) -> AV:
        arg_avs = [self.eval(a) for a in node.args]
        for kw in node.keywords:
            if kw.arg is None:
                self.eval(kw.value)
        chain = attr_chain(node.func)
        tail = chain.split(".")[-1] if chain else ""
        root = chain.split(".")[0] if chain else ""

        # method: x.astype(dt) / x.reshape(...) / x.copy() ...
        if isinstance(node.func, ast.Attribute):
            base = self.eval(node.func.value)
            if node.func.attr == "astype" and node.args:
                dt = self._dtype_arg(node.args[0])
                self._check_int_range(
                    node.lineno, dt, base.rng,
                    f"`.astype` of `{ast.unparse(node.func.value)}`",
                )
                return AV(dtype=dt, rng=_clamp_to_dtype(base.rng, dt),
                          shape=base.shape, bucketed=base.bucketed)
            if node.func.attr == "reshape":
                shape_av = (
                    arg_avs[0] if len(arg_avs) == 1 and
                    arg_avs[0].elts is not None else AV(elts=tuple(arg_avs))
                )
                shape = self._infer_reshape(
                    self._shape_of(shape_av), base.shape
                )
                return dataclasses.replace(base, shape=shape, elts=None)
            if node.func.attr in ("copy", "ravel", "flatten", "squeeze"):
                return dataclasses.replace(base, shape=None, elts=None)
            if node.func.attr in ("sum", "max", "min", "mean", "item"):
                return AV(dtype=base.dtype)

        if root in _ARRAY_MODS:
            return self._array_call(node, root, tail, arg_avs)

        if tail in BUCKET_FUNCS and arg_avs:
            x = arg_avs[0]
            bound = next_pow2_bound if tail == "next_pow2" \
                else quarter_pow2_bound
            rng = bound(x.rng) if x.rng is not None else None
            return AV(dtype="pyint", rng=rng, bucketed=True)
        if tail == "round_up" and arg_avs:
            x = arg_avs[0]
            if x.rng is None:
                return AV(dtype="pyint")
            m = arg_avs[1].rng if len(arg_avs) > 1 and \
                arg_avs[1].rng is not None else x.rng
            return AV(dtype="pyint",
                      rng=Sym(f"round_up({x.rng.expr})",
                              x.rng.hi + max(m.hi - 1, 0), x.rng.lo))
        if isinstance(node.func, ast.Name):
            builtin = self._builtin(node, arg_avs)
            if builtin is not None:
                return builtin
        if tail in KNOWN_JITTED:
            self._check_jit_call(node, arg_avs)
            return UNKNOWN
        return UNKNOWN

    def _builtin(self, node: ast.Call, arg_avs: list[AV]) -> AV | None:
        name = node.func.id
        if name == "len" and arg_avs:
            x = arg_avs[0]
            if x.shape is not None and x.shape[0] is not None:
                return x.shape[0]
            if x.elts is not None:
                return AV(dtype="pyint", rng=Sym.lit(len(x.elts)))
            return AV(dtype="pyint")
        if name in ("min", "max") and len(arg_avs) >= 2:
            known = [a.rng for a in arg_avs if a.rng is not None]
            fn = sym_min if name == "min" else sym_max
            rng = None
            if name == "min" and known:
                rng = fn(*known)  # any known arg upper-bounds a min
            elif name == "max" and len(known) == len(arg_avs):
                rng = fn(*known)
            bucketed = all(a.bucketed or a.is_const for a in arg_avs) and \
                any(a.bucketed for a in arg_avs)
            return AV(dtype="pyint", rng=rng, bucketed=bucketed)
        if name == "int" and arg_avs:
            return AV(dtype="pyint", rng=arg_avs[0].rng)
        if name == "abs" and arg_avs:
            x = arg_avs[0]
            if x.rng is not None:
                m = max(abs(x.rng.hi), abs(x.rng.lo))
                return dataclasses.replace(
                    x, rng=Sym(f"abs({x.rng.expr})", m, 0)
                )
            return x
        return None

    def _array_call(self, node: ast.Call, root: str, tail: str,
                    arg_avs: list[AV]) -> AV:
        device = root == "jnp"
        if tail in _SHAPE_CTORS and arg_avs:
            shape = self._shape_of(arg_avs[0])
            if tail == "full":
                dt = self._dtype_arg(
                    node.args[2] if len(node.args) > 2
                    else self._kw(node, "dtype")
                )
                fill = arg_avs[1] if len(arg_avs) > 1 else UNKNOWN
                if dt is None:
                    dt = {"pyint": "int64", "pyfloat": "float64"}.get(
                        fill.dtype
                    )
                    if device and dt:
                        dt = {"int64": "int32", "float64": "float32"}[dt]
                self._check_int_range(
                    node.lineno, dt, fill.rng,
                    f"fill value of `{ast.unparse(node)}`",
                )
                rng = _clamp_to_dtype(fill.rng, dt)
            else:
                dt = self._dtype_arg(
                    node.args[1] if len(node.args) > 1
                    else self._kw(node, "dtype")
                )
                if dt is None:
                    dt = "float32" if device else "float64"
                rng = Sym.lit(0) if tail == "zeros" else (
                    Sym.lit(1) if tail == "ones" else None
                )
            if device:
                self._device_alloc(node.lineno, shape, dt,
                                   f"jnp.{tail}")
                self._check_shape_buckets(
                    node.lineno, shape, f"`jnp.{tail}`"
                )
            return AV(dtype=dt, rng=rng, shape=shape)
        if tail == "arange" and arg_avs:
            stop = arg_avs[-1] if len(node.args) >= 2 else arg_avs[0]
            dt = self._dtype_arg(self._kw(node, "dtype"))
            if dt is None:
                dt = "int32" if device else "int64"
            rng = None
            if stop.rng is not None:
                rng = Sym(f"{stop.rng.expr} - 1", max(stop.rng.hi - 1, 0), 0)
            self._check_int_range(
                node.lineno, dt, rng, f"`{ast.unparse(node)}`"
            )
            shape = (stop,) if stop.rng is not None else None
            if device:
                self._device_alloc(node.lineno, shape, dt, "jnp.arange")
                self._check_shape_buckets(node.lineno, shape,
                                          "`jnp.arange`")
            return AV(dtype=dt, rng=_clamp_to_dtype(rng, dt), shape=shape)
        if tail in ("asarray", "array") and arg_avs:
            x = arg_avs[0]
            dt = self._dtype_arg(
                node.args[1] if len(node.args) > 1
                else self._kw(node, "dtype")
            )
            if not device:
                if dt is not None:
                    self._check_int_range(
                        node.lineno, dt, x.rng,
                        f"`{ast.unparse(node)}`",
                    )
                    return dataclasses.replace(
                        x, dtype=dt, rng=_clamp_to_dtype(x.rng, dt),
                        elts=None,
                    )
                return dataclasses.replace(x, elts=None)
            # jnp.asarray: the device boundary.  x64 is disabled in this
            # repo, so 64-bit hosts arrays narrow SILENTLY here.
            out_dt = dt
            if dt is None:
                narrowed = {"int64": "int32", "uint64": "uint32",
                            "float64": "float32"}.get(x.dtype or "")
                if narrowed:
                    proven = (
                        x.dtype == "int64" and x.rng is not None
                        and x.rng.hi <= INT_RANGES["int32"][1]
                        and x.rng.lo >= INT_RANGES["int32"][0]
                    )
                    if not proven:
                        self._flag(
                            "LANNS031", node.lineno,
                            f"`jnp.asarray` of a {x.dtype} value in "
                            f"`{self.qual}` silently narrows to {narrowed} "
                            "(x64 disabled) — cast explicitly after a "
                            "bounds check",
                        )
                    out_dt = narrowed
                else:
                    out_dt = x.dtype
            self._device_alloc(node.lineno, x.shape, out_dt, "jnp.asarray")
            self._check_shape_buckets(
                node.lineno, x.shape, "`jnp.asarray` upload"
            )
            return AV(dtype=out_dt, rng=_clamp_to_dtype(x.rng, out_dt),
                      shape=x.shape)
        if tail == "broadcast_to" and len(arg_avs) >= 2:
            x = arg_avs[0]
            return AV(dtype=x.dtype, rng=x.rng,
                      shape=self._shape_of(arg_avs[1]),
                      bucketed=x.bucketed)
        if tail == "reshape" and len(arg_avs) >= 2:
            x = arg_avs[0]
            return AV(dtype=x.dtype, rng=x.rng,
                      shape=self._shape_of(arg_avs[1]))
        if tail == "cumsum" and arg_avs:
            x = arg_avs[0]
            rng = None
            if x.rng is not None and x.shape is not None and \
                    all(d is not None and d.rng is not None
                        for d in x.shape):
                total = Sym.lit(1)
                for d in x.shape:
                    total = total * d.rng
                m = max(abs(x.rng.hi), abs(x.rng.lo))
                rng = total * Sym(f"|{x.rng.expr}|", m, -m)
            self._check_int_range(
                node.lineno, x.dtype, rng,
                f"`{ast.unparse(node)}` (running sum keeps the input "
                "dtype)",
            )
            return AV(dtype=x.dtype, rng=rng, shape=x.shape)
        if tail == "clip" and len(arg_avs) >= 3:
            x, lo, hi = arg_avs[0], arg_avs[1], arg_avs[2]
            rng = None
            if lo.rng is not None and hi.rng is not None:
                rng = Sym(f"clip({x.rng.expr if x.rng else '?'})",
                          hi.rng.hi, lo.rng.lo)
            return AV(dtype=x.dtype, rng=rng, shape=x.shape)
        if tail in ("concatenate", "stack", "vstack", "hstack"):
            parts = arg_avs[0].elts if arg_avs and arg_avs[0].elts else ()
            dt = None
            rng = None
            for p in parts:
                if p is None:
                    return UNKNOWN
                dt = _promote(dt, p.dtype) if dt is not None else p.dtype
                if p.rng is not None:
                    rng = rng.hull(p.rng) if rng is not None else p.rng
                else:
                    rng = None
            return AV(dtype=dt, rng=rng)
        if tail == "where" and len(arg_avs) >= 3:
            a, b = arg_avs[1], arg_avs[2]
            dt = _promote(a.dtype, b.dtype)
            rng = a.rng.hull(b.rng) \
                if a.rng is not None and b.rng is not None else None
            return AV(dtype=dt, rng=rng)
        if tail in ("argpartition", "argsort", "argmax", "argmin"):
            return AV(dtype="int64")
        if tail in ("take_along_axis", "rint", "maximum", "minimum",
                    "abs"):
            x = arg_avs[0] if arg_avs else UNKNOWN
            return AV(dtype=x.dtype, rng=x.rng)
        if tail in ("int8", "int16", "int32", "int64", "uint32",
                    "float32", "float64"):
            x = arg_avs[0] if arg_avs else UNKNOWN
            self._check_int_range(
                node.lineno, tail, x.rng, f"`{ast.unparse(node)}`"
            )
            return AV(dtype=tail, rng=_clamp_to_dtype(x.rng, tail))
        if tail in KNOWN_JITTED:
            self._check_jit_call(node, arg_avs)
        return UNKNOWN

    def _check_jit_call(self, node: ast.Call, arg_avs: list[AV]) -> None:
        """LANNS033 on calls into the known-jitted serving entry points."""
        if not self.hot:
            return
        name = attr_chain(node.func).split(".")[-1]
        for i, av in enumerate(arg_avs):
            if av.shape is not None:
                self._check_shape_buckets(
                    node.lineno, av.shape, f"arg {i} of `{name}`"
                )
            elif av.elts is None and self._symbolic_unbucketed(av):
                self._flag(
                    "LANNS033", node.lineno,
                    f"scalar arg {i} of jitted `{name}` in `{self.qual}` "
                    f"ranges over `{av.rng.expr}` without bucketing — "
                    "unbounded trace cardinality",
                )
        for kw in node.keywords:
            if kw.arg is None or kw.arg not in STATIC_KWARG_NAMES:
                continue
            av = self.eval(kw.value)
            if self._symbolic_unbucketed(av):
                self._flag(
                    "LANNS033", node.lineno,
                    f"static arg `{kw.arg}={av.rng.expr}` of jitted "
                    f"`{name}` in `{self.qual}` is not quantized to a "
                    "finite bucket set — every distinct value retraces",
                )


# ---------------------------------------------------------------------------
# module pass
# ---------------------------------------------------------------------------


def _module_consts(src: SourceFile) -> dict[str, AV]:
    """Constant-fold simple module-level ``NAME = <int expr>`` bindings."""
    probe = _FnInterp(
        src, "<module>", ast.parse("def _probe(): pass").body[0],
        dims={}, budget={}, hot=False, consts={}, findings=[],
    )
    consts: dict[str, AV] = {}
    for stmt in src.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            probe.env = dict(consts)
            probe.findings = []
            av = probe.eval(stmt.value)
            if av.rng is not None and av.rng.is_const:
                consts[stmt.targets[0].id] = av
    return consts


def run(src: SourceFile) -> list[Finding]:
    if not src.dims and not src.budget:
        return []
    idx = _FunctionIndex()
    idx.visit(src.tree)
    claimed: set[int] = set()
    for fn in idx.funcs.values():
        claimed |= src._anchor_lines(fn) & (set(src.dims) | set(src.budget))
    mod_dims = src.module_dims(claimed)
    hot = hot_roster(src)
    consts = _module_consts(src)
    findings: list[Finding] = []
    for qual, fn in sorted(idx.funcs.items()):
        fdims = src.func_dims(fn)
        fbudget = src.func_budget(fn)
        is_hot = qual in hot
        dims = {**mod_dims, **fdims}
        if not dims and not fbudget:
            continue
        if not (is_hot or fdims or fbudget):
            continue
        _FnInterp(src, qual, fn, dims=dims, budget=fbudget, hot=is_hot,
                  consts=consts, findings=findings).run()
    return findings


# ---------------------------------------------------------------------------
# footprint report (closed-form resident-bytes model)
# ---------------------------------------------------------------------------

# Worst-case padding factors of the two shared shape-bucket grids:
# quarter-pow2 rows pad <= 1.25x; the HNSW stack's pow2 per-partition rows
# give P * next_pow2(max_part) <= 2n under balanced partitioning.
DEFAULT_FOOTPRINT_DIMS = {
    "n": 180_000_000, "d": 2048, "P": 4096, "M": 32, "L": 4,
}


def footprint_report(dims: dict[str, int] | None = None) -> dict:
    """Closed-form device/host resident bytes per engine x quantized mode.

    Formulas mirror the actual allocations: ``scan_corpus`` (quarter-pow2
    fp32 rows), ``_Q8Partition`` (quarter-pow2 int8 codes + scale/bias +
    host exact store), and ``LannsIndex._hnsw_stack`` (pow2-padded flat
    rows: vectors/adj0/upper_adj [+ norms2, scales, stores for q8]).
    """
    dd = {**DEFAULT_FOOTPRINT_DIMS, **(dims or {})}
    n = Sym("n", dd["n"])
    d = Sym("d", dd["d"])
    P = Sym("P", dd["P"])
    M = Sym("M", dd["M"])
    L = Sym("L", dd["L"])
    nq = Sym("1.25*n", (5 * dd["n"] + 3) // 4)  # quarter-pow2 row bound
    rows = Sym("2*n", 2 * dd["n"])  # P*n_pad bound (pow2, balanced parts)

    modes = {
        "fp32_scan": {
            "device": [
                ("vectors", nq * d * 4),
            ],
            "host": [("keys", n * 8)],
        },
        "q8_scan": {
            "device": [
                ("codes", nq * d * 1),
                ("scale_bias", (d + nq) * 4),
            ],
            "host": [
                ("rerank_store.vectors", n * d * 4),
                ("rerank_store.norms2", n * 4),
                ("keys", n * 8),
            ],
        },
        "fp32_hnsw": {
            "device": [
                ("vectors", rows * d * 4),
                ("adj0", rows * (2 * M) * 4),
                ("upper_adj", rows * L * M * 4),
            ],
            "host": [("keys", rows * 8), ("entry", P * 4)],
        },
        "q8_hnsw": {
            "device": [
                ("codes", rows * d * 1),
                ("norms2", rows * 4),
                ("adj0", rows * (2 * M) * 4),
                ("upper_adj", rows * L * M * 4),
            ],
            "host": [
                ("scales", P * d * 4),
                ("rerank_store.vectors", n * d * 4),
                ("rerank_store.norms2", n * 4),
                ("keys", rows * 8),
            ],
        },
    }

    metrics: dict[str, float] = {}
    rows_out: list[dict] = []
    for mode, placements in modes.items():
        for placement, comps in placements.items():
            total = 0
            for cname, sym in comps:
                total += sym.hi
                rows_out.append({
                    "mode": mode, "placement": placement,
                    "component": cname, "formula": f"{sym.expr} bytes",
                    "bytes": int(sym.hi),
                })
            metrics[f"footprint_{mode}_{placement}_bytes"] = float(total)
    return {
        "dims": {k: dd[k] for k in ("n", "d", "P", "M", "L")},
        "pad_model": {
            "scan_rows": "1.25*n (quarter-pow2 bucket worst case)",
            "hnsw_rows": "2*n (P * next_pow2(max partition), balanced)",
        },
        "metrics": metrics,
        "rows": rows_out,
    }

"""Retrace sentinel: assert zero recompiles on warmed serving paths.

Wraps the jit compile-cache counters (``repro.common.utils.jit_cache_size``)
of every jitted callable on the serving hot path — scan, HNSW beam, q8
stage-1, rerank gather, merge — behind one snapshot/delta API, replacing
the ad-hoc ``._cache_size()`` arithmetic the trace tests used to hand-roll.

Usage (the ``retrace_sentinel`` pytest fixture in tests/conftest.py):

    idx.warm_traces(...)
    idx.query(warmup_workload)        # fill any best-effort residual traces
    sentinel.reset()
    idx.query(serving_workload)
    sentinel.assert_no_retrace("mixed-knob serving")

On jax builds without the private cache-size API ``available`` is False and
the assertions pass vacuously (callers should skip instead if the counter
is the point of the test).
"""

from __future__ import annotations

from importlib import import_module

from repro.common.utils import jit_cache_size

# (module, attr) for every jitted callable a warmed serving path may hit.
WATCHED_JITS: tuple[tuple[str, str], ...] = (
    ("repro.core.hnsw", "beam_search"),
    ("repro.core.hnsw", "beam_search_flat"),
    ("repro.core.hnsw", "beam_search_stacked"),
    ("repro.core.merge", "merge_topk"),
    ("repro.core.merge", "merge_topk_scatter"),
    ("repro.kernels.ref", "distance_topk_blocked"),
    ("repro.kernels.ref", "distance_topk_q8_blocked"),
    ("repro.kernels.ops", "distance_topk_jit"),
    ("repro.quant.twostage", "_stage1_scores"),
    ("repro.quant.rerank", "_rerank_gather_dev"),
)


def _resolve() -> dict[str, object]:
    fns: dict[str, object] = {}
    for mod, attr in WATCHED_JITS:
        try:
            fn = getattr(import_module(mod), attr)
        except (ImportError, AttributeError):
            continue
        fns[f"{mod.rsplit('.', 1)[-1]}.{attr}"] = fn
    return fns


class RetraceSentinel:
    """Snapshot/delta view over the watched jit compile caches."""

    def __init__(self, extra: dict[str, object] | None = None) -> None:
        self._fns = _resolve()
        if extra:
            self._fns.update(extra)
        self._base: dict[str, int] = {}
        self.reset()

    @property
    def available(self) -> bool:
        """True if at least one watched fn exposes a real cache counter."""
        return any(v >= 0 for v in self.snapshot().values())

    def snapshot(self) -> dict[str, int]:
        return {name: jit_cache_size(fn) for name, fn in self._fns.items()}

    def reset(self) -> dict[str, int]:
        self._base = self.snapshot()
        return self._base

    def deltas(self) -> dict[str, int]:
        """New compiles per watched fn since reset(); unavailable counters
        (-1) report 0."""
        now = self.snapshot()
        return {
            name: max(now[name] - self._base.get(name, 0), 0)
            if now[name] >= 0 and self._base.get(name, -1) >= 0 else 0
            for name in now
        }

    def retraced(self) -> dict[str, int]:
        return {k: v for k, v in self.deltas().items() if v > 0}

    def assert_no_retrace(self, context: str = "") -> None:
        hot = self.retraced()
        if hot:
            where = f" during {context}" if context else ""
            raise AssertionError(
                f"unexpected jit recompiles{where}: {hot} — a warmed "
                "serving path must reuse existing traces"
            )

    # `with sentinel.expect_no_retrace("mixed-knob"):` asserts on exit
    def expect_no_retrace(self, context: str = "") -> "_NoRetrace":
        return _NoRetrace(self, context)


class _NoRetrace:
    def __init__(self, sentinel: RetraceSentinel, context: str) -> None:
        self._s = sentinel
        self._ctx = context

    def __enter__(self) -> RetraceSentinel:
        self._s.reset()
        return self._s

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self._s.assert_no_retrace(self._ctx)

"""Rule registry, findings, and inline directive parsing for repro.analysis.

Directives are comments of the form ``# lanns: <directive>``:

* ``# lanns: hotpath`` — marks the function defined on (or directly below)
  this line as a serving hot-path root.  The trace lint checks the marked
  function plus everything reachable from it inside the same module.
* ``# lanns: noqa[LANNS001] -- justification`` — suppress the named rule(s)
  on this line.  The justification after ``--`` is REQUIRED: a bare noqa is
  itself a finding (LANNS000) and cannot be suppressed.  Multiple codes:
  ``noqa[LANNS001,LANNS003]``.
* ``# lanns: holds[_cond]`` — declares that the function defined on this
  line must only be called with ``self._cond`` held; the lock checker then
  treats guarded-attribute accesses inside it as covered.
* ``# lanns: dims[n<=180_000_000, d<=2048]`` — declares symbolic dimension
  bounds for the scale-safety pass (scalecheck).  On/above a def it scopes
  to that function (merged over module-level declarations); anywhere else
  it scopes to the whole module.  Any name bound in an annotated function
  that MATCHES a declared dim is tracked at that bound.
* ``# lanns: budget[device<=8GiB]`` — declares a device-resident byte
  budget for the function defined on/below this line; scalecheck sums the
  static footprint of its device allocations at the declared dim bounds
  (LANNS034).
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

from .symdims import parse_budget, parse_dims

_DIRECTIVE_RE = re.compile(r"#\s*lanns:\s*(?P<body>.+?)\s*$")
_NOQA_RE = re.compile(
    r"noqa\[(?P<codes>[A-Z0-9,\s]+)\](?:\s*--\s*(?P<just>.+))?$"
)
_HOLDS_RE = re.compile(r"holds\[(?P<lock>\w+)\]$")
_DIMS_RE = re.compile(r"dims\[(?P<body>[^\]]*)\]$")
_BUDGET_RE = re.compile(r"budget\[(?P<body>[^\]]*)\]$")


@dataclass(frozen=True)
class Rule:
    code: str
    name: str
    summary: str


RULES: dict[str, Rule] = {
    r.code: r
    for r in (
        # -- meta ----------------------------------------------------------
        Rule("LANNS000", "bare-noqa",
             "`# lanns: noqa[...]` without a `-- justification` tail"),
        # -- trace stability (hot-path functions) --------------------------
        Rule("LANNS001", "item-sync",
             ".item() on a hot path forces a device->host sync per element"),
        Rule("LANNS002", "scalar-sync",
             "float()/int()/bool() of a device value blocks on the device"),
        Rule("LANNS003", "asarray-sync",
             "np.asarray/np.array/np.from_dlpack of a device value is a "
             "host sync; hoist to one designed sync point per batch"),
        Rule("LANNS004", "jnp-in-host-loop",
             "jnp/jax op inside a host-side Python loop dispatches "
             "per-iteration instead of batching"),
        Rule("LANNS005", "dynamic-shape-arg",
             "jit parameter used in a shape/axis position without being "
             "declared in static_argnums/static_argnames"),
        Rule("LANNS006", "unordered-iteration",
             "set or unsorted-dict iteration feeding array/pytree "
             "construction makes trace/layout order nondeterministic"),
        # -- lock discipline -----------------------------------------------
        Rule("LANNS010", "guarded-attr-unlocked",
             "attribute declared in _GUARDED_BY touched outside `with "
             "self.<lock>:`"),
        Rule("LANNS011", "blocking-under-lock",
             "blocking call (join/sleep/execute/query) while holding a "
             "lock"),
        Rule("LANNS012", "lock-order-inversion",
             "nested lock acquisition contradicts the class _LOCK_ORDER"),
        Rule("LANNS013", "publish-after-set",
             "request result field assigned after event.set() — waiters "
             "can observe a half-published result"),
        # -- Pallas kernel constraints --------------------------------------
        Rule("LANNS020", "kernel-f64",
             "float64 dtype in a kernels/ module (TPU Pallas has no f64)"),
        Rule("LANNS021", "dot-no-preferred-type",
             "dot/dot_general in a kernel body without "
             "preferred_element_type pins the MXU accumulator dtype"),
        Rule("LANNS022", "kernel-1d-iota",
             "1D iota/arange in a kernel body — Mosaic requires "
             "broadcasted_iota (>= 2D)"),
        Rule("LANNS023", "kernel-sort",
             "sort/argsort/top_k in a kernel body — Mosaic cannot lower "
             "them; use a compare/select network"),
        Rule("LANNS024", "launcher-no-divisibility-guard",
             "pallas_call launcher without a block-divisibility assert on "
             "its padded operand shapes"),
        # -- scale safety (symbolic dims; scalecheck) ------------------------
        Rule("LANNS030", "int32-range-overflow",
             "index arithmetic provably exceeds the int32/uint32 value "
             "range at the declared `dims[...]` bounds (silent wraparound "
             "at scale)"),
        Rule("LANNS031", "implicit-promotion",
             "implicit dtype promotion on a hot path: fp64 leaking into "
             "fp32 math, int64/fp64 silently narrowed at a jnp boundary "
             "(x64 disabled), or int8 arithmetic outside an explicit "
             "astype rescale"),
        Rule("LANNS032", "mixed-width-store",
             "int64 value stored into an int32-dtyped array slot without "
             "an explicit bounds-asserted cast"),
        Rule("LANNS033", "unbounded-trace-bucket",
             "jit static/shape argument ranging over a declared symbolic "
             "dim without pow2/quarter-pow2 bucketing — trace cardinality "
             "is unbounded in the dim"),
        Rule("LANNS034", "device-budget-exceeded",
             "static device-resident footprint at the declared dim bounds "
             "exceeds the `# lanns: budget[device<=...]` declaration"),
    )
}


@dataclass
class Finding:
    code: str
    path: str
    line: int
    message: str
    suppressed: bool = False
    justification: str = ""

    def render(self) -> str:
        tag = " (suppressed: %s)" % self.justification if self.suppressed \
            else ""
        return f"{self.path}:{self.line}: {self.code} {self.message}{tag}"


@dataclass
class Noqa:
    codes: tuple[str, ...]
    justification: str
    used: bool = False


@dataclass
class SourceFile:
    """A parsed module plus its ``# lanns:`` directive maps."""

    path: str
    text: str
    tree: ast.AST
    noqa: dict[int, Noqa] = field(default_factory=dict)
    hotpath_lines: set[int] = field(default_factory=set)
    holds: dict[int, str] = field(default_factory=dict)
    dims: dict[int, dict[str, int]] = field(default_factory=dict)
    budget: dict[int, dict[str, int]] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: str, text: str | None = None) -> "SourceFile":
        if text is None:
            with open(path, encoding="utf-8") as f:
                text = f.read()
        src = cls(path=path, text=text, tree=ast.parse(text, filename=path))
        for lineno, line in enumerate(text.splitlines(), start=1):
            m = _DIRECTIVE_RE.search(line)
            if not m:
                continue
            body = m.group("body")
            nq = _NOQA_RE.match(body)
            if nq:
                codes = tuple(
                    c.strip() for c in nq.group("codes").split(",")
                    if c.strip()
                )
                src.noqa[lineno] = Noqa(codes, (nq.group("just") or "").strip())
                continue
            hl = _HOLDS_RE.match(body)
            if hl:
                src.holds[lineno] = hl.group("lock")
                continue
            dm = _DIMS_RE.match(body)
            if dm:
                src.dims[lineno] = parse_dims(
                    dm.group("body"), where=f"{path}:{lineno}"
                )
                continue
            bg = _BUDGET_RE.match(body)
            if bg:
                src.budget[lineno] = parse_budget(
                    bg.group("body"), where=f"{path}:{lineno}"
                )
                continue
            if body == "hotpath":
                src.hotpath_lines.add(lineno)
        return src

    # -- directive lookups -------------------------------------------------

    def _anchor_lines(self, node: ast.FunctionDef) -> set[int]:
        """Lines a function-scoped directive may sit on: the def line, the
        line directly above, any decorator line, or the line above the
        first decorator."""
        lines = {node.lineno, node.lineno - 1}
        lines.update(d.lineno for d in node.decorator_list)
        if node.decorator_list:
            lines.add(min(d.lineno for d in node.decorator_list) - 1)
        return lines

    def func_is_hot(self, node: ast.FunctionDef) -> bool:
        """A def is hot-marked if the directive sits on the def line, on a
        decorator line, or on the line directly above the def."""
        return bool(self._anchor_lines(node) & self.hotpath_lines)

    def func_dims(self, node: ast.FunctionDef) -> dict[str, int]:
        """Function-scoped ``dims[...]`` declarations (unmerged)."""
        out: dict[str, int] = {}
        for ln in sorted(self._anchor_lines(node) & set(self.dims)):
            out.update(self.dims[ln])
        return out

    def func_budget(self, node: ast.FunctionDef) -> dict[str, int]:
        out: dict[str, int] = {}
        for ln in sorted(self._anchor_lines(node) & set(self.budget)):
            out.update(self.budget[ln])
        return out

    def module_dims(self, claimed: set[int]) -> dict[str, int]:
        """Module-scoped dims: every dims line not anchored to a def."""
        out: dict[str, int] = {}
        for ln in sorted(set(self.dims) - claimed):
            out.update(self.dims[ln])
        return out

    def func_holds(self, node: ast.FunctionDef) -> str | None:
        lines = [node.lineno, node.lineno - 1]
        lines += [d.lineno for d in node.decorator_list]
        for ln in lines:
            if ln in self.holds:
                return self.holds[ln]
        return None

    # -- suppression -------------------------------------------------------

    def meta_findings(self) -> list[Finding]:
        """LANNS000 for every noqa directive missing a justification."""
        return [
            Finding("LANNS000", self.path, ln,
                    RULES["LANNS000"].summary)
            for ln, nq in sorted(self.noqa.items())
            if not nq.justification
        ]

    def apply_suppressions(self, findings: list[Finding]) -> list[Finding]:
        """Mark findings suppressed where a justified noqa names their code
        on the same line.  LANNS000 is never suppressible."""
        for f in findings:
            if f.code == "LANNS000":
                continue
            nq = self.noqa.get(f.line)
            if nq and f.code in nq.codes and nq.justification:
                f.suppressed = True
                f.justification = nq.justification
                nq.used = True
        return findings


def attr_chain(node: ast.AST) -> str:
    """Dotted name for Name/Attribute chains ('jnp.asarray', 'self._cond');
    '' for anything unresolvable."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""

"""Runtime concurrency instrumentation: lock-order recording + race stress.

``InstrumentedLock`` wraps an RLock and reports every acquisition to a
process-wide ``LockOrderRegistry``, which maintains the directed
held-before graph across threads; a cycle in that graph is a potential
deadlock even if the schedule that would deadlock never ran.  The wrapper
implements ``_release_save``/``_acquire_restore``/``_is_owned`` so it can
back a ``threading.Condition`` (``wait()`` keeps the held-stack honest).

``instrument_frontend`` swaps an ``AsyncAnnFrontend``'s locks for
instrumented ones (BEFORE ``start()``) and wraps its guarded dicts in
``GuardedDict``, which asserts the declared lock is held on every mutation
— the runtime twin of the static LANNS010 pass.

``race_stress`` is the seeded multi-submitter churn driver used by the
nightly CI job and tests/test_analysis.py: repeated
submit/stop(drain)/restart cycles under N submitter threads, with lock
orders recorded and invariants checked after every cycle.

None of this is imported by serving code: production frontends run plain
``threading`` primitives with zero analysis overhead.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field


class LockOrderRegistry:
    """Held-before edges across all instrumented locks, per process."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._tls = threading.local()
        self.edges: dict[tuple[str, str], int] = {}

    def _held(self) -> list[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = []
            self._tls.held = held
        return held

    def on_acquire_attempt(self, name: str) -> None:
        held = self._held()
        if name in held:  # re-entrant re-acquire: no new ordering fact
            return
        if held:
            with self._mu:
                for h in set(held):
                    self.edges[(h, name)] = self.edges.get((h, name), 0) + 1

    def on_acquired(self, name: str) -> None:
        self._held().append(name)

    def on_released(self, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                return

    def cycles(self) -> list[list[str]]:
        """Every elementary cycle in the held-before graph (DFS)."""
        with self._mu:
            adj: dict[str, set[str]] = {}
            for a, b in self.edges:
                adj.setdefault(a, set()).add(b)
        out: list[list[str]] = []
        state: dict[str, int] = {}  # 0 unvisited / 1 on stack / 2 done
        stack: list[str] = []

        def dfs(node: str) -> None:
            state[node] = 1
            stack.append(node)
            for nxt in sorted(adj.get(node, ())):
                if state.get(nxt, 0) == 1:
                    out.append(stack[stack.index(nxt):] + [nxt])
                elif state.get(nxt, 0) == 0:
                    dfs(nxt)
            stack.pop()
            state[node] = 2

        for node in sorted(adj):
            if state.get(node, 0) == 0:
                dfs(node)
        return out

    def assert_acyclic(self) -> None:
        cyc = self.cycles()
        if cyc:
            raise AssertionError(
                f"lock-order cycles detected: {cyc} (edges={self.edges})"
            )


class InstrumentedLock:
    """RLock wrapper reporting to a LockOrderRegistry; Condition-capable."""

    def __init__(self, name: str, registry: LockOrderRegistry) -> None:
        self.name = name
        self.registry = registry
        self._lock = threading.RLock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self.registry.on_acquire_attempt(self.name)
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            self.registry.on_acquired(self.name)
        return ok

    def release(self) -> None:
        self._lock.release()
        self.registry.on_released(self.name)

    __enter__ = acquire

    def __exit__(self, *exc) -> None:
        self.release()

    # Condition integration: wait() parks via _release_save and re-enters
    # via _acquire_restore; both must keep the registry's held-stack honest.
    def _release_save(self):
        state = self._lock._release_save()
        self.registry.on_released(self.name)
        return state

    def _acquire_restore(self, state) -> None:
        self.registry.on_acquire_attempt(self.name)
        self._lock._acquire_restore(state)
        self.registry.on_acquired(self.name)

    def _is_owned(self) -> bool:
        return self._lock._is_owned()


class GuardedDict(dict):
    """Dict that asserts its lock is held on every mutation."""

    def __init__(self, data: dict, lock: InstrumentedLock, name: str) -> None:
        super().__init__(data)
        self._lock = lock
        self._name = name
        self.violations: list[str] = []

    def _check(self, op: str) -> None:
        if not self._lock._is_owned():
            self.violations.append(
                f"{self._name}.{op} without holding {self._lock.name} "
                f"(thread {threading.current_thread().name})"
            )

    def __setitem__(self, k, v) -> None:
        self._check(f"__setitem__[{k!r}]")
        super().__setitem__(k, v)

    def __delitem__(self, k) -> None:
        self._check(f"__delitem__[{k!r}]")
        super().__delitem__(k)


def instrument_frontend(fe, registry: LockOrderRegistry):
    """Swap an (unstarted) AsyncAnnFrontend's locks for instrumented ones
    and wrap its guarded dicts.  Returns the list the guarded-mutation
    violations accumulate into."""
    if getattr(fe, "_thread", None) is not None:
        raise RuntimeError("instrument before start(): the batcher thread "
                           "must only ever see the instrumented locks")
    fe._cond = threading.Condition(InstrumentedLock("_cond", registry))
    fe._stats_lock = InstrumentedLock("_stats_lock", registry)
    stats = GuardedDict(fe.stats, fe._stats_lock, "stats")
    hist = GuardedDict(fe.batch_hist, fe._stats_lock, "batch_hist")
    fe.stats, fe.batch_hist = stats, hist
    violations = stats.violations
    hist.violations = violations  # shared sink
    return violations


def instrument_controller(ctrl, registry: LockOrderRegistry):
    """Same treatment for an (unstarted) ``SLOController``: its ``_lock``
    condition becomes instrumented (named ``ctrl_lock`` so the held-before
    graph separates it from the frontend's ``_cond``) and its ``stats``
    dict asserts the lock on every mutation.  Must run before
    ``ctrl.start()`` AND before the bound frontend ``start()``s — both
    threads must only ever see the instrumented lock."""
    if getattr(ctrl, "_thread", None) is not None:
        raise RuntimeError("instrument before start(): the controller "
                           "thread must only ever see the instrumented lock")
    inner = InstrumentedLock("ctrl_lock", registry)
    ctrl._lock = threading.Condition(inner)
    stats = GuardedDict(ctrl.stats, inner, "ctrl.stats")
    ctrl.stats = stats
    return stats.violations


@dataclass
class StressReport:
    cycles_run: int = 0
    submitted: int = 0
    completed: int = 0
    cancelled: int = 0
    retunes: int = 0
    degraded: int = 0
    lock_edges: dict = field(default_factory=dict)
    lock_cycles: list = field(default_factory=list)
    violations: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.lock_cycles and not self.violations

    def render(self) -> str:
        lines = [
            f"race-stress: {self.cycles_run} lifecycle cycles, "
            f"{self.submitted} submitted, {self.completed} completed, "
            f"{self.cancelled} cancelled, {self.retunes} controller ticks, "
            f"{self.degraded} deadline degrades",
            f"lock-order edges observed: "
            f"{sorted(self.lock_edges) or '(none)'}",
        ]
        if self.lock_cycles:
            lines.append(f"LOCK-ORDER CYCLES: {self.lock_cycles}")
        lines.extend(f"VIOLATION: {v}" for v in self.violations)
        if self.ok:
            lines.append("no lock-order cycles, no guarded-attribute "
                         "violations")
        return "\n".join(lines)


def _check_invariants(fe, report: StressReport) -> None:
    """Counter consistency that torn (unlocked) updates would break."""
    stats = fe.stats
    if sum(fe.batch_hist.values()) != stats["batches"]:
        report.violations.append(
            f"batch_hist total {sum(fe.batch_hist.values())} != "
            f"stats['batches'] {stats['batches']}"
        )
    if sum(b * n for b, n in fe.batch_hist.items()) != stats["completed"]:
        report.violations.append(
            "batch_hist-weighted completion count != stats['completed']"
        )
    if len(fe.completed) != stats["completed"]:
        report.violations.append(
            f"completed list {len(fe.completed)} != stats['completed'] "
            f"{stats['completed']}"
        )
    for r in fe.completed:
        if r.ids is None or r.dists is None or r.batch_size < 1:
            report.violations.append(
                f"request {r.uid} completed but half-published"
            )


def race_stress(threads: int = 8, duration_s: float = 30.0, seed: int = 0,
                index=None, progress=None) -> StressReport:
    """Seeded submit/stop/drain churn over an instrumented frontend
    + bound SLO controller.

    Each lifecycle cycle builds a fresh ``AsyncAnnFrontend`` with a bound
    ``SLOController`` over a shared small index, instruments both, runs
    ``threads`` seeded submitters (some requests carrying tight
    ``deadline_ms`` budgets, so the degrade path runs concurrently with
    submission) for a slice of the budget, churns the controller thread
    mid-slice (stop / manual retune / live ``fe.retune`` / restart), then
    stops everything — alternating drain=True/False AND controller-stop
    before/after frontend-stop — and checks counter invariants plus
    request publication integrity.  Lock orders accumulate in one registry
    across all cycles.
    """
    import numpy as np

    from repro.data.synthetic import clustered_vectors
    from repro.obs.telemetry import Telemetry
    from repro.serve.controller import SLOController
    from repro.serve.engine import AsyncAnnFrontend

    if index is None:
        from repro.core import LannsConfig, LannsIndex

        data = clustered_vectors(600, 8, n_clusters=8, seed=seed)
        cfg = LannsConfig(num_shards=1, num_segments=2, segmenter="apd",
                          engine="scan")
        index = LannsIndex(cfg).build(data)
    queries = clustered_vectors(256, 8, n_clusters=8, seed=seed + 1)

    registry = LockOrderRegistry()
    report = StressReport()
    telemetry = Telemetry()  # shared: the span ring is bounded by design
    deadline = time.monotonic() + duration_s
    cycle = 0
    while time.monotonic() < deadline:
        drain = cycle % 2 == 0
        ctrl = SLOController(slo_ms=3.0, ef_ladder=(12, 6),
                             interval_s=0.01, min_wait_ms=0.05)
        fe = AsyncAnnFrontend(index, topk=10, max_batch=8, max_wait_ms=1.0,
                              telemetry=telemetry, controller=ctrl)
        violations = instrument_frontend(fe, registry)
        ctrl_violations = instrument_controller(ctrl, registry)
        fe.start()
        ctrl.start()
        stop_flag = threading.Event()
        counts = [0] * threads

        def submitter(tid: int, fe=fe, stop_flag=stop_flag, counts=counts,
                      cycle=cycle):
            rng = np.random.default_rng(seed * 1000 + cycle * 100 + tid)
            while not stop_flag.is_set():
                q = queries[rng.integers(len(queries))]
                # half the requests carry a budget; 0.5 ms is already blown
                # at formation, so degrades happen under live churn
                ddl = (
                    float(rng.choice([0.5, 3.0, 20.0]))
                    if rng.random() < 0.5 else None
                )
                try:
                    req = fe.submit(q, topk=int(rng.choice([5, 10])),
                                    deadline_ms=ddl)
                except RuntimeError:
                    return  # frontend stopping/stopped: expected during churn
                counts[tid] += 1
                if rng.random() < 0.3:
                    req.wait(timeout=5.0)

        workers = [
            threading.Thread(target=submitter, args=(t,), daemon=True)
            for t in range(threads)
        ]
        for w in workers:
            w.start()
        slice_s = min(1.0, max(0.2, deadline - time.monotonic()))
        time.sleep(slice_s / 2)
        # controller churn under live traffic: thread restart, a manual
        # tick while it is down, and an operator-style live retune
        ctrl.stop()
        ctrl.retune_once()
        fe.retune(max_wait_ms=0.8)
        ctrl.start()
        time.sleep(slice_s / 2)
        stop_flag.set()
        if cycle % 2 == 0:  # alternate controller-stop vs frontend-stop order
            ctrl.stop()
            completed = fe.stop(drain=drain)
        else:
            completed = fe.stop(drain=drain)
            ctrl.stop()
        for w in workers:
            w.join(timeout=10.0)
            if w.is_alive():
                report.violations.append("submitter thread failed to exit")
        if fe.error is not None:
            report.violations.append(f"batcher died: {fe.error!r}")
        _check_invariants(fe, report)
        snap = ctrl.snapshot()
        report.cycles_run += 1
        report.submitted += sum(counts)
        report.completed += len(completed)
        report.cancelled += sum(counts) - len(completed)
        report.retunes += snap["ticks"]
        report.degraded += snap["degraded"]
        report.violations.extend(violations)
        report.violations.extend(ctrl_violations)
        if progress is not None:
            progress(report)
        cycle += 1
    report.lock_edges = dict(registry.edges)
    report.lock_cycles = registry.cycles()
    return report

"""Static lock-discipline checker (LANNS010-013).

A class opts in by declaring a literal registry of guarded attributes:

    class AsyncAnnFrontend(AnnFrontend):
        _GUARDED_BY = {"pending": "_cond", "completed": "_cond"}
        _LOCK_ORDER = ("_cond", "_stats_lock")   # optional

The pass then proves every ``self.<attr>`` touch of a guarded attribute is
lexically inside ``with self.<lock>:`` (or inside a function annotated
``# lanns: holds[<lock>]``, whose callers take the lock — see
analysis/README.md).  ``__init__``/``__post_init__`` are exempt: nothing
else can hold a reference yet.

Inheritance: a subclass's effective registry is the union of its bases'
registries (within the module) with its own; methods inherited from a base
are checked against the subclass registry unless the subclass overrides
them (the override is what actually runs).

LANNS013 guards the publish protocol of request objects: inside a single
statement list, once ``x.event.set()`` has run, later assignments to
``x.<field>`` race with the woken waiter.
"""

from __future__ import annotations

import ast

from .rules import Finding, SourceFile, attr_chain

_BLOCKING_ATTRS = {"join", "sleep"}
_BLOCKING_CHAINS = {"self.index.query", "self._execute", "time.sleep"}
_CONSTRUCTORS = {"__init__", "__post_init__", "__init_subclass__"}


def _literal_str_dict(node: ast.AST) -> dict[str, str] | None:
    if not isinstance(node, ast.Dict):
        return None
    out: dict[str, str] = {}
    for k, v in zip(node.keys, node.values):
        if not (isinstance(k, ast.Constant) and isinstance(k.value, str)
                and isinstance(v, ast.Constant) and isinstance(v.value, str)):
            return None
        out[k.value] = v.value
    return out


def _literal_str_seq(node: ast.AST) -> tuple[str, ...] | None:
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    vals: list[str] = []
    for el in node.elts:
        if not (isinstance(el, ast.Constant) and isinstance(el.value, str)):
            return None
        vals.append(el.value)
    return tuple(vals)


class _ClassInfo:
    def __init__(self, node: ast.ClassDef) -> None:
        self.node = node
        self.name = node.name
        self.bases = [attr_chain(b).split(".")[-1]
                      for b in node.bases if attr_chain(b)]
        self.guards: dict[str, str] = {}
        self.lock_order: tuple[str, ...] = ()
        self.published: tuple[str, ...] = ()
        self.methods: dict[str, ast.FunctionDef] = {}
        self.aliases: dict[str, str] = {}  # flush = step -> {"flush": "step"}
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                    isinstance(stmt.targets[0], ast.Name):
                tgt = stmt.targets[0].id
                if tgt == "_GUARDED_BY":
                    self.guards = _literal_str_dict(stmt.value) or {}
                elif tgt == "_LOCK_ORDER":
                    self.lock_order = _literal_str_seq(stmt.value) or ()
                elif tgt == "_PUBLISHED_FIELDS":
                    self.published = _literal_str_seq(stmt.value) or ()
                elif isinstance(stmt.value, ast.Name):
                    self.aliases[tgt] = stmt.value.id
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.methods[stmt.name] = stmt


def _collect_classes(src: SourceFile) -> dict[str, _ClassInfo]:
    return {
        node.name: _ClassInfo(node)
        for node in ast.walk(src.tree)
        if isinstance(node, ast.ClassDef)
    }


def _effective(cls: _ClassInfo, classes: dict[str, _ClassInfo],
               attr: str) -> dict:
    """Merge a dict/tuple attribute down the (single-module) base chain."""
    merged: dict = {}
    chain: list[_ClassInfo] = []
    cur: _ClassInfo | None = cls
    seen = set()
    while cur is not None and cur.name not in seen:
        seen.add(cur.name)
        chain.append(cur)
        nxt = None
        for b in cur.bases:
            if b in classes:
                nxt = classes[b]
                break
        cur = nxt
    for ci in reversed(chain):
        merged.update(getattr(ci, attr))
    return merged


def _resolved_methods(cls: _ClassInfo, classes: dict[str, _ClassInfo],
                      ) -> dict[str, tuple[_ClassInfo, ast.FunctionDef]]:
    """name -> (defining class, def) after override resolution."""
    out: dict[str, tuple[_ClassInfo, ast.FunctionDef]] = {}
    cur: _ClassInfo | None = cls
    seen = set()
    while cur is not None and cur.name not in seen:
        seen.add(cur.name)
        for name, fn in cur.methods.items():
            out.setdefault(name, (cur, fn))
        for alias, target in cur.aliases.items():
            # `flush = step`: the alias shadows any inherited def of that
            # name; the aliased method is checked under its own name.
            if target in cur.methods:
                out.setdefault(alias, (cur, cur.methods[target]))
        nxt = None
        for b in cur.bases:
            if b in classes:
                nxt = classes[b]
                break
        cur = nxt
    return out


class _LockWalk(ast.NodeVisitor):
    """One method body; tracks the stack of self.<lock> With contexts."""

    def __init__(self, src: SourceFile, cls: str, meth: str,
                 guards: dict[str, str], order: tuple[str, ...],
                 held_at_entry: str | None) -> None:
        self.src = src
        self.cls = cls
        self.meth = meth
        self.guards = guards
        self.order = order
        self.held: list[str] = [held_at_entry] if held_at_entry else []
        self.findings: list[Finding] = []

    def _lock_names(self, item: ast.withitem) -> str | None:
        chain = attr_chain(item.context_expr)
        if chain.startswith("self.") and chain.count(".") == 1:
            return chain[5:]
        return None

    def visit_With(self, node: ast.With) -> None:
        acquired = [n for n in
                    (self._lock_names(it) for it in node.items) if n]
        for name in acquired:
            if self.order and self.held:
                try:
                    prev = max(self.order.index(h) for h in self.held
                               if h in self.order)
                    if name in self.order and self.order.index(name) < prev:
                        self.findings.append(Finding(
                            "LANNS012", self.src.path, node.lineno,
                            f"`{self.cls}.{self.meth}` acquires "
                            f"`self.{name}` while holding a later lock in "
                            f"_LOCK_ORDER {self.order}",
                        ))
                except ValueError:
                    pass
            self.held.append(name)
        self.generic_visit(node)
        for _ in acquired:
            self.held.pop()

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if isinstance(node.value, ast.Name) and node.value.id == "self" and \
                node.attr in self.guards:
            lock = self.guards[node.attr]
            if lock not in self.held:
                self.findings.append(Finding(
                    "LANNS010", self.src.path, node.lineno,
                    f"`self.{node.attr}` (guarded by `{lock}`) touched in "
                    f"`{self.cls}.{self.meth}` without holding it",
                ))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if self.held:
            chain = attr_chain(node.func)
            attr = node.func.attr if isinstance(node.func, ast.Attribute) \
                else ""
            if chain in _BLOCKING_CHAINS or attr in _BLOCKING_ATTRS:
                self.findings.append(Finding(
                    "LANNS011", self.src.path, node.lineno,
                    f"blocking call `{chain or attr}` in "
                    f"`{self.cls}.{self.meth}` while holding "
                    f"`self.{self.held[-1]}`",
                ))
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass  # nested defs (worker closures) are separate execution contexts

    visit_AsyncFunctionDef = visit_FunctionDef


def _check_publish_order(src: SourceFile, published: tuple[str, ...],
                         findings: list[Finding]) -> None:
    """Module-wide LANNS013: fields in any class's _PUBLISHED_FIELDS must
    never be assigned after `<obj>.event.set()` in the same statement list
    (the publisher is usually a DIFFERENT class than the request)."""
    for fn in ast.walk(src.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        meth_name = fn.name
        for node in ast.walk(fn):
            body = getattr(node, "body", None)
            if not isinstance(body, list):
                continue
            set_done: set[str] = set()
            for stmt in body:
                if not isinstance(stmt, ast.stmt):
                    continue
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Assign):
                        for tgt in sub.targets:
                            if isinstance(tgt, ast.Attribute) and \
                                    tgt.attr in published:
                                base = attr_chain(tgt.value)
                                if base in set_done:
                                    findings.append(Finding(
                                        "LANNS013", src.path, sub.lineno,
                                        f"`{base}.{tgt.attr}` assigned "
                                        "after `event.set()` in "
                                        f"`{meth_name}` — waiters may "
                                        "read a half-published result",
                                    ))
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call):
                        chain = attr_chain(sub.func)
                        if chain.endswith(".event.set"):
                            set_done.add(chain[: -len(".event.set")])


def run(src: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    classes = _collect_classes(src)
    for cls in classes.values():
        guards = _effective(cls, classes, "guards")
        order: tuple[str, ...] = ()
        cur: _ClassInfo | None = cls
        seen: set[str] = set()
        while cur is not None and cur.name not in seen:
            seen.add(cur.name)
            if cur.lock_order:
                order = cur.lock_order
                break
            cur = next((classes[b] for b in cur.bases if b in classes), None)
        if guards:
            for name, (owner, fn) in sorted(
                    _resolved_methods(cls, classes).items()):
                if name in _CONSTRUCTORS:
                    continue
                if owner is not cls and owner.guards and owner is not None:
                    # base method already checked against its own class if
                    # the base declares guards; re-checking against every
                    # subclass only duplicates findings.
                    if set(guards) == set(_effective(
                            owner, classes, "guards")):
                        continue
                walk = _LockWalk(src, cls.name, name, guards, order,
                                 src.func_holds(fn))
                for stmt in fn.body:
                    walk.visit(stmt)
                findings.extend(walk.findings)
    published = tuple(sorted({
        f for c in classes.values() for f in c.published
    }))
    if published:
        _check_publish_order(src, published, findings)
    return findings

"""repro.analysis — static + runtime invariant checks for the serving stack.

Three coordinated passes (see analysis/README.md for the rule catalog):

* ``tracelint``  — jit trace-stability lint over hot-path functions
  (LANNS001-006);
* ``locks``      — lock-discipline proof over ``_GUARDED_BY`` registries
  (LANNS010-013), with a runtime twin in ``runtime``
  (InstrumentedLock / race_stress);
* ``kernelcheck``— Pallas/Mosaic constraint check over kernels/
  (LANNS020-024);
* ``scalecheck`` — symbolic shape/dtype abstract interpretation at
  declared ``dims[...]`` bounds (LANNS030-034) plus the closed-form
  device-footprint report.

CLI: ``python -m repro.analysis [--strict] [paths...]``,
``python -m repro.analysis --footprint-report OUT.json``, and
``python -m repro.analysis --race-stress --threads 8 --duration 30``.
"""

from __future__ import annotations

import os

from . import kernelcheck, locks, scalecheck, tracelint
from .rules import RULES, Finding, SourceFile
from .scalecheck import DEFAULT_FOOTPRINT_DIMS, footprint_report
from .sentinels import RetraceSentinel

__all__ = [
    "RULES", "Finding", "SourceFile", "RetraceSentinel",
    "analyze_file", "analyze_paths",
    "footprint_report", "DEFAULT_FOOTPRINT_DIMS",
]

_PASSES = (tracelint.run, locks.run, kernelcheck.run, scalecheck.run)


def analyze_file(path: str, text: str | None = None) -> list[Finding]:
    """All findings for one module, suppressions applied, deduped."""
    src = SourceFile.parse(path, text)
    findings: list[Finding] = src.meta_findings()
    for run in _PASSES:
        findings.extend(run(src))
    src.apply_suppressions(findings)
    seen: set[tuple[str, str, int]] = set()
    out: list[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
        key = (f.code, f.path, f.line)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def _py_files(paths: list[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if not d.startswith((".", "__")))
            out.extend(
                os.path.join(root, f) for f in sorted(files)
                if f.endswith(".py")
            )
    return out


def analyze_paths(paths: list[str]) -> list[Finding]:
    findings: list[Finding] = []
    for path in _py_files(paths):
        findings.extend(analyze_file(path))
    return findings

"""Static Pallas-kernel constraint check (LANNS020-024).

Applies to modules living under a ``kernels/`` directory.  Kernel BODIES are
detected structurally: any function with a ``*_ref`` parameter (the Ref
calling convention of ``pl.pallas_call``).  Launchers are functions that
call ``pl.pallas_call``.

The rules encode the Mosaic/TPU lowering constraints this repo already
relies on (see /opt/skills guides and kernels/README commentary):

* no float64 anywhere in a kernels module (TPU has no f64; x64 is globally
  disabled but a literal would silently truncate);
* MXU dots must pin ``preferred_element_type`` (f32 accumulation for int8
  codes is the q8 contract);
* iota must be >= 2D (``broadcasted_iota``), never 1D ``jnp.arange``;
* no sort/argsort/top_k inside a kernel body — Mosaic cannot lower them,
  which is why the bitonic compare/select network exists;
* every launcher asserts block divisibility of its padded operand shapes
  before ``pallas_call`` (grids silently drop the ragged tail otherwise).
"""

from __future__ import annotations

import ast
import os

from .rules import Finding, SourceFile, attr_chain

_F64_NAMES = {"float64", "f64", "double"}
_SORT_TAILS = {"sort", "argsort", "top_k", "sort_key_val"}
_DOT_TAILS = {"dot_general", "dot", "matmul"}


def is_kernels_module(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return "kernels" in parts[:-1]


def _is_kernel_body(fn: ast.FunctionDef) -> bool:
    return any(a.arg.endswith("_ref") for a in fn.args.args)


def _calls_pallas_call(fn: ast.FunctionDef) -> bool:
    return any(
        isinstance(node, ast.Call)
        and attr_chain(node.func).split(".")[-1] == "pallas_call"
        for node in ast.walk(fn)
    )


def _has_divisibility_assert(fn: ast.FunctionDef) -> bool:
    return any(
        isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod)
        for node in ast.walk(fn)
        if isinstance(node, ast.Assert)
        for sub in ast.walk(node.test)
    )


def run(src: SourceFile) -> list[Finding]:
    if not is_kernels_module(src.path):
        return []
    findings: list[Finding] = []

    # LANNS020: module-wide f64 ban (dtype literals or attribute refs)
    for node in ast.walk(src.tree):
        name = None
        if isinstance(node, ast.Attribute) and node.attr in _F64_NAMES:
            name = attr_chain(node)
        elif isinstance(node, ast.Constant) and node.value == "float64":
            name = "'float64'"
        if name:
            findings.append(Finding(
                "LANNS020", src.path, node.lineno,
                f"float64 reference `{name}` in a kernels module — TPU "
                "Pallas has no f64",
            ))

    for fn in ast.walk(src.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _is_kernel_body(fn):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = attr_chain(node.func)
                tail = chain.split(".")[-1] if chain else ""
                if tail in _DOT_TAILS:
                    kws = {kw.arg for kw in node.keywords}
                    if "preferred_element_type" not in kws:
                        findings.append(Finding(
                            "LANNS021", src.path, node.lineno,
                            f"`{chain}` in kernel body `{fn.name}` without "
                            "preferred_element_type — MXU accumulator "
                            "dtype is left to the lowering",
                        ))
                if tail in ("arange", "iota"):
                    findings.append(Finding(
                        "LANNS022", src.path, node.lineno,
                        f"1D `{chain}` in kernel body `{fn.name}` — Mosaic "
                        "requires broadcasted_iota (>= 2D)",
                    ))
                if tail in _SORT_TAILS:
                    findings.append(Finding(
                        "LANNS023", src.path, node.lineno,
                        f"`{chain}` in kernel body `{fn.name}` — Mosaic "
                        "cannot lower sorts; use a compare/select network",
                    ))
            for node in ast.walk(fn):
                if isinstance(node, ast.BinOp) and \
                        isinstance(node.op, ast.MatMult):
                    findings.append(Finding(
                        "LANNS021", src.path, node.lineno,
                        f"`@` matmul in kernel body `{fn.name}` cannot pin "
                        "preferred_element_type — use lax.dot_general",
                    ))
        elif _calls_pallas_call(fn) and not _has_divisibility_assert(fn):
            findings.append(Finding(
                "LANNS024", src.path, fn.lineno,
                f"launcher `{fn.name}` calls pallas_call without a block "
                "divisibility assert on its padded shapes",
            ))
    return findings

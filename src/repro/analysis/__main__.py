"""CLI: ``python -m repro.analysis [--strict] [paths...]``.

Default mode lints the given paths (default: the installed ``repro``
package sources) with all three static passes and prints a per-rule
summary including counted, justified suppressions.  ``--strict`` exits
non-zero when any UNSUPPRESSED finding remains — the CI gate.

``--footprint-report OUT.json`` writes the closed-form device/host
resident-bytes model (per engine x quantized mode, at the declared dim
bounds) in the BENCH_*.json schema so ``benchmarks/check_regression.py``
can track it as an info-only metric.  ``--footprint-dims`` overrides the
default 180M x 2048d bounds with the same ``name<=value`` grammar as the
``dims[...]`` directive.

``--race-stress`` runs the seeded multi-submitter lifecycle churn with
``InstrumentedLock`` lock-order recording instead (the nightly CI job):
exits non-zero on any lock-order cycle or guarded-attribute violation.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from collections import Counter

from . import analyze_paths
from .rules import RULES
from .scalecheck import footprint_report
from .symdims import fmt_bytes, parse_dims


def _default_paths() -> list[str]:
    import repro

    if getattr(repro, "__file__", None):
        return [os.path.dirname(os.path.abspath(repro.__file__))]
    return [os.path.abspath(p) for p in repro.__path__]  # namespace package


def _lint(args: argparse.Namespace) -> int:
    paths = args.paths or _default_paths()
    findings = analyze_paths(paths)
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    for f in findings:
        print(f.render())
    print()
    print(f"repro.analysis: {len(active)} finding(s), "
          f"{len(suppressed)} suppressed, over {len(paths)} path(s)")
    for code, n in sorted(Counter(f.code for f in active).items()):
        print(f"  {code} ({RULES[code].name}): {n}")
    if suppressed:
        print("suppressions (justification required and counted):")
        for f in suppressed:
            print(f"  {f.path}:{f.line}: {f.code} -- {f.justification}")
    return 1 if active and (args.strict or args.exit_nonzero) else 0


def _footprint(args: argparse.Namespace) -> int:
    dims = parse_dims(args.footprint_dims, where="--footprint-dims") \
        if args.footprint_dims else None
    report = footprint_report(dims)
    payload = {
        # mirrors benchmarks.common.bench_payload (kept import-free so the
        # analyzer works without the benchmarks package on sys.path)
        "schema_version": 1,
        "bench": "footprint",
        "smoke": False,
        "created_unix": time.time(),
        "config": {"dims": report["dims"], "pad_model": report["pad_model"]},
        "metrics": report["metrics"],
        "rows": report["rows"],
    }
    with open(args.footprint_report, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    d = report["dims"]
    print(f"footprint report ({', '.join(f'{k}={v:_}' for k, v in d.items())})"
          f" -> {args.footprint_report}")
    for key, val in sorted(report["metrics"].items()):
        print(f"  {key}: {fmt_bytes(val)}")
    return 0


def _race_stress(args: argparse.Namespace) -> int:
    from .runtime import race_stress

    def progress(report):
        print(f"  cycle {report.cycles_run}: {report.submitted} submitted, "
              f"{report.completed} completed", flush=True)

    print(f"race-stress: threads={args.threads} duration={args.duration}s "
          f"seed={args.seed}", flush=True)
    report = race_stress(threads=args.threads, duration_s=args.duration,
                         seed=args.seed, progress=progress)
    print(report.render())
    return 0 if report.ok else 1


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Trace-stability, lock-discipline, and Pallas-kernel "
                    "invariant checks.",
    )
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: the repro "
                         "package)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any unsuppressed finding remains")
    ap.add_argument("--exit-nonzero", action="store_true",
                    help=argparse.SUPPRESS)  # legacy alias for --strict
    ap.add_argument("--footprint-report", metavar="OUT.json",
                    help="write the closed-form resident-bytes report "
                         "(BENCH schema) instead of linting")
    ap.add_argument("--footprint-dims", metavar="DIMS",
                    help="override footprint bounds, e.g. "
                         "'n<=10_000_000, d<=512, P<=64, M<=16'")
    ap.add_argument("--race-stress", action="store_true",
                    help="run the seeded multi-submitter lock-order stress "
                         "instead of linting")
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--duration", type=float, default=30.0,
                    help="race-stress wall-clock budget in seconds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.race_stress:
        return _race_stress(args)
    if args.footprint_report:
        return _footprint(args)
    return _lint(args)


if __name__ == "__main__":
    sys.exit(main())

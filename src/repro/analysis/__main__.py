"""CLI: ``python -m repro.analysis [--strict] [paths...]``.

Default mode lints the given paths (default: the installed ``repro``
package sources) with all three static passes and prints a per-rule
summary including counted, justified suppressions.  ``--strict`` exits
non-zero when any UNSUPPRESSED finding remains — the CI gate.

``--race-stress`` runs the seeded multi-submitter lifecycle churn with
``InstrumentedLock`` lock-order recording instead (the nightly CI job):
exits non-zero on any lock-order cycle or guarded-attribute violation.
"""

from __future__ import annotations

import argparse
import os
import sys
from collections import Counter

from . import analyze_paths
from .rules import RULES


def _default_paths() -> list[str]:
    import repro

    if getattr(repro, "__file__", None):
        return [os.path.dirname(os.path.abspath(repro.__file__))]
    return [os.path.abspath(p) for p in repro.__path__]  # namespace package


def _lint(args: argparse.Namespace) -> int:
    paths = args.paths or _default_paths()
    findings = analyze_paths(paths)
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    for f in findings:
        print(f.render())
    print()
    print(f"repro.analysis: {len(active)} finding(s), "
          f"{len(suppressed)} suppressed, over {len(paths)} path(s)")
    for code, n in sorted(Counter(f.code for f in active).items()):
        print(f"  {code} ({RULES[code].name}): {n}")
    if suppressed:
        print("suppressions (justification required and counted):")
        for f in suppressed:
            print(f"  {f.path}:{f.line}: {f.code} -- {f.justification}")
    return 1 if active and (args.strict or args.exit_nonzero) else 0


def _race_stress(args: argparse.Namespace) -> int:
    from .runtime import race_stress

    def progress(report):
        print(f"  cycle {report.cycles_run}: {report.submitted} submitted, "
              f"{report.completed} completed", flush=True)

    print(f"race-stress: threads={args.threads} duration={args.duration}s "
          f"seed={args.seed}", flush=True)
    report = race_stress(threads=args.threads, duration_s=args.duration,
                         seed=args.seed, progress=progress)
    print(report.render())
    return 0 if report.ok else 1


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Trace-stability, lock-discipline, and Pallas-kernel "
                    "invariant checks.",
    )
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: the repro "
                         "package)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any unsuppressed finding remains")
    ap.add_argument("--exit-nonzero", action="store_true",
                    help=argparse.SUPPRESS)  # legacy alias for --strict
    ap.add_argument("--race-stress", action="store_true",
                    help="run the seeded multi-submitter lock-order stress "
                         "instead of linting")
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--duration", type=float, default=30.0,
                    help="race-stress wall-clock budget in seconds")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.race_stress:
        return _race_stress(args)
    return _lint(args)


if __name__ == "__main__":
    sys.exit(main())

"""Symbolic dimension algebra for the scale-safety pass (scalecheck).

A ``Sym`` is a closed-form expression over declared dimension names plus a
conservative integer interval ``[lo, hi]`` — the value range the expression
can take when every declared dim sits at its bound.  The abstract
interpreter in ``scalecheck.py`` threads Syms through numpy/jnp shape and
index arithmetic; the interval is what the LANNS03x rules test, the
expression string is what their messages (and the footprint report) print.

Also home to the ``dims[...]`` / ``budget[...]`` directive grammars and the
dtype width/range tables shared by the rules.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

_DIM_ITEM_RE = re.compile(r"^(?P<name>[A-Za-z_]\w*)\s*<=\s*(?P<val>[\d_]+)$")
_BUDGET_ITEM_RE = re.compile(
    r"^(?P<name>[A-Za-z_]\w*)\s*<=\s*(?P<val>[\d_]+(?:\.\d+)?)\s*"
    r"(?P<unit>[KMGT]i?B|B)?$"
)

_UNIT_BYTES = {
    None: 1, "B": 1,
    "KiB": 2 ** 10, "MiB": 2 ** 20, "GiB": 2 ** 30, "TiB": 2 ** 40,
    "KB": 10 ** 3, "MB": 10 ** 6, "GB": 10 ** 9, "TB": 10 ** 12,
}


def parse_dims(body: str, *, where: str = "?") -> dict[str, int]:
    """``"n<=180_000_000, d<=2048"`` -> ``{"n": 180000000, "d": 2048}``."""
    out: dict[str, int] = {}
    for item in body.split(","):
        item = item.strip()
        if not item:
            continue
        m = _DIM_ITEM_RE.match(item)
        if not m:
            raise ValueError(
                f"{where}: malformed dims[...] item {item!r} "
                "(expected name<=integer)"
            )
        out[m.group("name")] = int(m.group("val"))
    return out


def parse_budget(body: str, *, where: str = "?") -> dict[str, int]:
    """``"device<=8GiB"`` -> ``{"device": 8589934592}`` (bytes)."""
    out: dict[str, int] = {}
    for item in body.split(","):
        item = item.strip()
        if not item:
            continue
        m = _BUDGET_ITEM_RE.match(item)
        if not m:
            raise ValueError(
                f"{where}: malformed budget[...] item {item!r} "
                "(expected name<=<number><unit>, unit in B/KiB/MiB/GiB/TiB)"
            )
        out[m.group("name")] = int(
            float(m.group("val")) * _UNIT_BYTES[m.group("unit")]
        )
    return out


def fmt_bytes(n: int | float) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024 or unit == "TiB":
            return f"{n:.4g}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.4g}TiB"


# ---------------------------------------------------------------------------
# dtype tables
# ---------------------------------------------------------------------------

DTYPE_BYTES = {
    "bool": 1, "int8": 1, "uint8": 1, "int16": 2, "uint16": 2,
    "int32": 4, "uint32": 4, "int64": 8, "uint64": 8,
    "float16": 2, "bfloat16": 2, "float32": 4, "float64": 8,
}

INT_RANGES = {
    "int8": (-(2 ** 7), 2 ** 7 - 1),
    "uint8": (0, 2 ** 8 - 1),
    "int16": (-(2 ** 15), 2 ** 15 - 1),
    "uint16": (0, 2 ** 16 - 1),
    "int32": (-(2 ** 31), 2 ** 31 - 1),
    "uint32": (0, 2 ** 32 - 1),
    "int64": (-(2 ** 63), 2 ** 63 - 1),
    "uint64": (0, 2 ** 64 - 1),
}

_DTYPE_NAMES = set(DTYPE_BYTES)


def canon_dtype(name: str | None) -> str | None:
    """'np.int32' / 'jnp.int32' / 'int32' / 'float' -> canonical name."""
    if not name:
        return None
    tail = name.split(".")[-1]
    if tail in _DTYPE_NAMES:
        return tail
    if tail == "float":
        return "float64"
    if tail == "int":
        return "int64"
    return None


def is_int_dtype(dtype: str | None) -> bool:
    return dtype in INT_RANGES


def is_float_dtype(dtype: str | None) -> bool:
    return dtype in ("float16", "bfloat16", "float32", "float64")


# ---------------------------------------------------------------------------
# the symbolic interval
# ---------------------------------------------------------------------------


def _atom(expr: str) -> str:
    """True-ish when ``expr`` needs no parens as a product operand."""
    return expr if re.fullmatch(r"[\w.]+|\([^()]*\)", expr) \
        else f"({expr})"


@dataclass(frozen=True)
class Sym:
    """Closed-form expression + conservative value interval [lo, hi]."""

    expr: str
    hi: int
    lo: int = 0

    @staticmethod
    def lit(v: int) -> "Sym":
        return Sym(str(v), v, v)

    @property
    def is_const(self) -> bool:
        return self.lo == self.hi

    def _coerce(self, o) -> "Sym | None":
        if isinstance(o, Sym):
            return o
        if isinstance(o, int):
            return Sym.lit(o)
        return None

    def __add__(self, o) -> "Sym":
        o = self._coerce(o)
        return Sym(f"{self.expr} + {o.expr}", self.hi + o.hi,
                   self.lo + o.lo)

    __radd__ = __add__

    def __sub__(self, o) -> "Sym":
        o = self._coerce(o)
        return Sym(f"{self.expr} - {_atom(o.expr)}", self.hi - o.lo,
                   self.lo - o.hi)

    def __mul__(self, o) -> "Sym":
        o = self._coerce(o)
        ps = (self.hi * o.hi, self.hi * o.lo, self.lo * o.hi,
              self.lo * o.lo)
        return Sym(f"{_atom(self.expr)}*{_atom(o.expr)}", max(ps), min(ps))

    __rmul__ = __mul__

    def __floordiv__(self, o) -> "Sym":
        o = self._coerce(o)
        if o.lo <= 0:  # dividing by a possibly-nonpositive bound: give up
            return Sym(f"{_atom(self.expr)}//{_atom(o.expr)}",
                       abs(self.hi), -abs(self.hi))
        return Sym(f"{_atom(self.expr)}//{_atom(o.expr)}",
                   self.hi // o.lo, self.lo // o.hi)

    def __mod__(self, o) -> "Sym":
        o = self._coerce(o)
        return Sym(f"{_atom(self.expr)} % {_atom(o.expr)}",
                   max(o.hi - 1, 0), min(self.lo, 0))

    def __neg__(self) -> "Sym":
        return Sym(f"-{_atom(self.expr)}", -self.lo, -self.hi)

    def clamp_hi(self, hi: int) -> "Sym":
        return Sym(self.expr, min(self.hi, hi), min(self.lo, hi))

    def hull(self, o: "Sym") -> "Sym":
        """Interval union (for joins across branches / where)."""
        return Sym(f"{self.expr}|{o.expr}", max(self.hi, o.hi),
                   min(self.lo, o.lo))


def sym_min(*syms: Sym) -> Sym:
    """min() over intervals; any arg is a valid upper bound."""
    hi = min(s.hi for s in syms)
    lo = min(s.lo for s in syms)
    expr = f"min({', '.join(s.expr for s in syms)})"
    return Sym(expr, hi, lo)


def sym_max(*syms: Sym) -> Sym:
    hi = max(s.hi for s in syms)
    lo = max(s.lo for s in syms)
    expr = f"max({', '.join(s.expr for s in syms)})"
    return Sym(expr, hi, lo)


def next_pow2_bound(x: Sym) -> Sym:
    """Worst-case bound of next_pow2(x): <= 2*(x-1) for x >= 2; use 2x."""
    return Sym(f"next_pow2({x.expr})", max(2 * x.hi, 1), max(x.lo, 0))


def quarter_pow2_bound(x: Sym) -> Sym:
    """next_pow2_quarter pads on a {2^k, 1.25*2^k, 1.5*2^k, 1.75*2^k}
    grid: worst-case padded size < ceil(8/7 * x); 1.25x is a safe cover."""
    return Sym(f"next_pow2_quarter({x.expr})", (5 * x.hi + 3) // 4,
               max(x.lo, 0))

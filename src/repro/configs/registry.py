"""Arch registry: the 10 assigned architectures with exact published configs.

Sources as assigned:
  codeqwen1.5-7b        [hf:Qwen/CodeQwen1.5-7B]
  qwen2-72b             [arXiv:2407.10671]
  smollm-360m           [hf:HuggingFaceTB/SmolLM-360M]
  deepseek-moe-16b      [arXiv:2401.06066]
  deepseek-v2-lite-16b  [arXiv:2405.04434]
  dimenet               [arXiv:2003.03123]
  autoint               [arXiv:1810.11921]
  din                   [arXiv:1706.06978]
  sasrec                [arXiv:1808.09781]
  xdeepfm               [arXiv:1803.05170]
"""

from __future__ import annotations

from functools import lru_cache

from repro.configs.families import Arch, GNNArch, LMArch, RecsysArch

ARCH_IDS = (
    "codeqwen1.5-7b",
    "qwen2-72b",
    "smollm-360m",
    "deepseek-moe-16b",
    "deepseek-v2-lite-16b",
    "dimenet",
    "autoint",
    "din",
    "sasrec",
    "xdeepfm",
)

# Criteo-style vocab mix for the 39-field archs: 13 integer-bucket fields
# (small vocab) + 26 categorical fields (large, hash-bucketed).  Totals ~27M
# rows — a realistic "huge sparse table" without being gratuitous.
CRITEO39_VOCABS = tuple([1000] * 13 + [1_000_000] * 26)


@lru_cache(maxsize=None)
def get_arch(arch_id: str) -> Arch:
    from repro.models.moe import MoEConfig
    from repro.models.recsys import (
        AutoIntConfig,
        DINConfig,
        SASRecConfig,
        XDeepFMConfig,
    )
    from repro.models.transformer import TransformerConfig
    from repro.models.dimenet import DimeNetConfig

    if arch_id == "codeqwen1.5-7b":
        # 32L d=4096 32H (GQA kv=32 => MHA-style kv) d_ff=13440 vocab=92416,
        # QKV bias (qwen1.5 arch)
        return LMArch(
            arch_id,
            TransformerConfig(
                name=arch_id, n_layers=32, d_model=4096, n_heads=32,
                n_kv_heads=32, head_dim=128, d_ff=13440, vocab=92416,
                qkv_bias=True, rope_theta=1_000_000.0,
            ),
            num_micro=4,
        )
    if arch_id == "qwen2-72b":
        # 80L d=8192 64H GQA kv=8 d_ff=29568 vocab=152064, QKV bias
        return LMArch(
            arch_id,
            TransformerConfig(
                name=arch_id, n_layers=80, d_model=8192, n_heads=64,
                n_kv_heads=8, head_dim=128, d_ff=29568, vocab=152064,
                qkv_bias=True, rope_theta=1_000_000.0,
            ),
            num_micro=16,
            remat_group=5,  # sqrt-L remat: 16 groups x 5 layers
        )
    if arch_id == "smollm-360m":
        # 32L d=960 15H GQA kv=5 d_ff=2560 vocab=49152 (llama-arch small,
        # tied embeddings)
        return LMArch(
            arch_id,
            TransformerConfig(
                name=arch_id, n_layers=32, d_model=960, n_heads=15,
                n_kv_heads=5, head_dim=64, d_ff=2560, vocab=49152,
                tie_embeddings=True, rope_theta=10_000.0,
            ),
            num_micro=1,
            tp=False,  # 15 heads don't divide any TP width; FSDP-only
        )
    if arch_id == "deepseek-moe-16b":
        # 28L d=2048 16H (kv=16) expert d_ff=1408 vocab=102400,
        # 2 shared + 64 routed top-6, first layer dense (dense d_ff=10944)
        return LMArch(
            arch_id,
            TransformerConfig(
                name=arch_id, n_layers=28, d_model=2048, n_heads=16,
                n_kv_heads=16, head_dim=128, d_ff=10944, vocab=102400,
                rope_theta=10_000.0,
                moe=MoEConfig(
                    num_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                    first_k_dense=1, capacity_factor=1.25,
                ),
            ),
            num_micro=4,
        )
    if arch_id == "deepseek-v2-lite-16b":
        # 27L d=2048 16H MLA kv_lora=512 rope_dim=64, expert d_ff=1408
        # vocab=102400, 2 shared + 64 routed top-6, first layer dense.
        # (The assignment sheet says both "64e top-6" and "160 routed"; the
        # HF/paper V2-Lite config is 64 routed + 2 shared — we follow it and
        # note the discrepancy here.)
        return LMArch(
            arch_id,
            TransformerConfig(
                name=arch_id, n_layers=27, d_model=2048, n_heads=16,
                n_kv_heads=16, head_dim=128, d_ff=10944, vocab=102400,
                attention="mla", mla_kv_lora_rank=512,
                mla_qk_nope_head_dim=128, mla_qk_rope_head_dim=64,
                mla_v_head_dim=128, rope_theta=10_000.0,
                moe=MoEConfig(
                    num_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                    first_k_dense=1, capacity_factor=1.25,
                ),
            ),
            num_micro=4,
        )
    if arch_id == "dimenet":
        return GNNArch(
            arch_id,
            DimeNetConfig(
                name=arch_id, n_blocks=6, d_hidden=128, n_bilinear=8,
                n_spherical=7, n_radial=6,
            ),
        )
    if arch_id == "autoint":
        return RecsysArch(
            arch_id,
            AutoIntConfig(
                name=arch_id, n_sparse=39, embed_dim=16, n_attn_layers=3,
                n_heads=2, d_attn=32, vocab_sizes=CRITEO39_VOCABS,
            ),
        )
    if arch_id == "din":
        return RecsysArch(
            arch_id,
            DINConfig(
                name=arch_id, embed_dim=18, seq_len=100, attn_mlp=(80, 40),
                mlp=(200, 80), n_items=10_000_000, n_context=8,
                context_vocab=100_000,
            ),
            embed_dim_retrieval=18,
        )
    if arch_id == "sasrec":
        return RecsysArch(
            arch_id,
            SASRecConfig(
                name=arch_id, embed_dim=50, n_blocks=2, n_heads=1,
                seq_len=50, n_items=10_000_000,
            ),
            embed_dim_retrieval=50,
        )
    if arch_id == "xdeepfm":
        return RecsysArch(
            arch_id,
            XDeepFMConfig(
                name=arch_id, n_sparse=39, embed_dim=10,
                cin_layers=(200, 200, 200), mlp=(400, 400),
                vocab_sizes=CRITEO39_VOCABS,
            ),
        )
    raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")

"""Assigned-architecture configs.  ``registry.get_arch(id)`` is the entry."""

from repro.configs.registry import ARCH_IDS, get_arch

__all__ = ["ARCH_IDS", "get_arch"]

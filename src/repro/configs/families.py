"""Arch families: everything needed to smoke-test and dry-run one cell.

An ``Arch`` bundles:
  * the exact published model config (+ a reduced smoke twin),
  * its shape cells (name -> Cell),
  * ``build_cell(cell, mesh, ctx)`` -> ``LoweredSpec``: the step function,
    abstract inputs (ShapeDtypeStruct — never allocated), and in/out
    shardings for ``jit(...).lower(...)``.

Dtype policy: dry-run cells use bf16 params/compute with f32 optimizer
moments (production mixed precision); smoke tests run f32 on CPU.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding_rules import ShardingCtx
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import (
    dimenet_loss_fn,
    lm_loss_fn,
    make_train_step,
    recsys_loss_fn,
)


@dataclasses.dataclass(frozen=True)
class Cell:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode' | 'forward' | 'retrieval'
    global_batch: int = 1
    seq_len: int = 0
    extra: tuple = ()  # extra (key, value) pairs

    def get(self, key, default=None):
        return dict(self.extra).get(key, default)


@dataclasses.dataclass
class LoweredSpec:
    """What launch/dryrun.py feeds to jit(...).lower()."""

    fn: Callable
    args: tuple  # ShapeDtypeStructs (abstract) or concrete arrays
    in_shardings: Any
    out_shardings: Any
    note: str = ""
    model_flops_per_step: float = 0.0  # 6*N*D (dense) / 6*N_active*D (MoE)
    donate_argnums: tuple = ()  # in-place buffers (params/opt/KV cache)
    aux_info: dict = dataclasses.field(default_factory=dict)


def _sds(tree):
    """pytree of arrays/structs -> ShapeDtypeStructs."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def make_ctx(mesh: Optional[Mesh], *, pod_dp: bool = True) -> ShardingCtx:
    ctx = ShardingCtx(mesh=mesh)
    if mesh is not None and "pod" in mesh.shape and pod_dp:
        rules = dict(ctx.rules)
        rules["batch"] = ("pod", "data")
        ctx = dataclasses.replace(ctx, rules=rules)
    return ctx


class Arch:
    arch_id: str = ""
    family: str = ""
    cells: dict = {}

    # -- interface -----------------------------------------------------------
    def model_config(self, reduced: bool = False):
        raise NotImplementedError

    def build_cell(self, cell: Cell, mesh: Mesh) -> LoweredSpec:
        raise NotImplementedError

    def smoke(self, seed: int = 0) -> dict:
        """Reduced-config forward+train step on CPU; returns metrics."""
        raise NotImplementedError

    def cell_names(self):
        return list(self.cells)


# ===========================================================================
# LM family
# ===========================================================================

LM_CELLS = {
    "train_4k": Cell("train_4k", "train", global_batch=256, seq_len=4096),
    "prefill_32k": Cell("prefill_32k", "prefill", global_batch=32, seq_len=32_768),
    "decode_32k": Cell("decode_32k", "decode", global_batch=128, seq_len=32_768),
    "long_500k": Cell("long_500k", "decode", global_batch=1, seq_len=524_288),
}


class LMArch(Arch):
    family = "lm"

    def __init__(self, arch_id: str, config, *, num_micro: int = 16,
                 tp: bool = True, remat_group: int = 0,
                 smoke_overrides: Optional[dict] = None):
        """tp=False: pure FSDP/DP — the 'model' axis joins the batch/FSDP
        axes instead of tensor-parallelism.  The right layout for small
        models (smollm: 15 heads don't divide any TP width; TP would
        replicate attention scores on every chip)."""
        self.arch_id = arch_id
        self._config = config
        self.cells = dict(LM_CELLS)
        self.num_micro = num_micro
        self.tp = tp
        self.remat_group = remat_group
        self.smoke_overrides = smoke_overrides or {}

    def model_config(self, reduced: bool = False):
        if not reduced:
            return self._config
        cfg = self._config
        moe = cfg.moe
        if moe is not None:
            # generous capacity so smoke decode-parity is exact (capacity
            # drops are the one legitimate prefill/train divergence)
            moe = dataclasses.replace(
                moe, num_experts=8, top_k=2, d_ff_expert=64, n_shared=1,
                capacity_factor=8.0,
            )
        return dataclasses.replace(
            cfg,
            n_layers=2 + (moe.first_k_dense if moe else 0),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=512,
            moe=moe,
            mla_kv_lora_rank=32,
            mla_qk_nope_head_dim=16,
            mla_qk_rope_head_dim=8,
            mla_v_head_dim=16,
            q_chunk=0,
            remat=False,
            param_dtype="float32",
            compute_dtype="float32",
            **self.smoke_overrides,
        )

    # -- dry-run construction --------------------------------------------------
    def _abstract_params(self, cfg, ctx):
        from repro.models import transformer as tf

        params = jax.eval_shape(lambda k: tf.init(k, cfg), jax.random.PRNGKey(0))
        specs = tf.param_specs(params, cfg, ctx)
        return params, specs

    def _dryrun_model_cfg(self, cell: Cell):
        # chunked (flash-style) attention everywhere except decode: bounds
        # the live f32 score buffer to q_chunk x kv_chunk even when the head
        # count doesn't divide the TP width (smollm: 15 heads on 16-way TP
        # replicates scores — 7.5 GiB/layer unchunked).
        cfg = dataclasses.replace(
            self._config,
            param_dtype="bfloat16",
            compute_dtype="bfloat16",
            remat=cell.kind == "train",
            remat_group=self.remat_group if cell.kind == "train" else 0,
            q_chunk=0 if cell.kind == "decode" else 1024,
            kv_chunk=2048,
        )
        return cfg

    def build_cell(self, cell: Cell, mesh: Mesh) -> LoweredSpec:
        from repro.models import transformer as tf
        from repro.serve.engine import make_decode_fn, make_prefill_fn

        ctx = make_ctx(mesh)
        if not self.tp:
            # nothing model-sharded; for training the model axis joins the
            # batch/FSDP axes (serving keeps batch on 'data' so the KV cache
            # can use 'model' for its sequence dim).
            rules = dict(ctx.rules)
            rules["model"] = ()
            rules["vocab"] = ()
            rules["expert"] = ()
            if cell.kind == "train":
                if "pod" in mesh.shape:
                    # 512 lanes would exceed global_batch=256: batch over
                    # (pod, data) = 32 lanes, weights FSDP over 'model'
                    rules["batch"] = ("pod", "data")
                    rules["fsdp"] = ("model",)
                else:
                    rules["batch"] = ("data", "model")
            ctx = dataclasses.replace(ctx, rules=rules)
        cfg = self._dryrun_model_cfg(cell)
        params, pspecs = self._abstract_params(cfg, ctx)
        B = cell.global_batch
        S = cell.seq_len
        tokens_per_step = B * S
        n_active = cfg.num_active_params()
        batch_spec = ctx.spec("batch")

        if cell.kind == "train":
            mf = 6.0 * n_active * tokens_per_step
            opt_cfg = AdamWConfig(lr=3e-4, total_steps=10_000)
            loss = lm_loss_fn(cfg, ctx)
            num_micro = self.num_micro
            if not self.tp and "pod" in mesh.shape:
                # 32 batch lanes instead of 256: microbatch to keep the
                # unsharded-vocab logits buffer at 1 seq/lane
                num_micro = max(num_micro, 8)
            step = make_train_step(loss, opt_cfg, num_micro=num_micro)
            opt_specs = {
                "step": P(),
                "m": pspecs,
                "v": pspecs,
            }
            opt_abs = {
                "step": jax.ShapeDtypeStruct((), jnp.int32),
                "m": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params
                ),
                "v": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params
                ),
            }
            batch_abs = {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            }
            batch_specs = {
                "tokens": P(*batch_spec, None),
                "labels": P(*batch_spec, None),
            }
            in_sh = (
                _named(mesh, pspecs),
                _named(mesh, opt_specs),
                _named(mesh, batch_specs),
            )
            out_sh = (
                _named(mesh, pspecs),
                _named(mesh, opt_specs),
                None,
            )
            return LoweredSpec(
                fn=step,
                args=(params, opt_abs, batch_abs),
                in_shardings=in_sh,
                out_shardings=out_sh,
                model_flops_per_step=mf,
                note=f"microbatch={num_micro}, remat, fsdp+tp",
                donate_argnums=(0, 1),
            )

        # serving cells share bf16 cache; sharding of the cache seq dim is
        # the per-cell decision (DESIGN.md §4)
        if cfg.attention == "mla":
            cache_abs = {
                "latent": jax.ShapeDtypeStruct(
                    (cfg.n_layers, B, S, cfg.mla_kv_lora_rank), jnp.bfloat16
                ),
                "k_rope": jax.ShapeDtypeStruct(
                    (cfg.n_layers, B, S, cfg.mla_qk_rope_head_dim), jnp.bfloat16
                ),
            }
        else:
            kvh = cfg.n_kv_heads
            cache_abs = {
                "k": jax.ShapeDtypeStruct(
                    (cfg.n_layers, B, S, kvh, cfg.head_dim), jnp.bfloat16
                ),
                "v": jax.ShapeDtypeStruct(
                    (cfg.n_layers, B, S, kvh, cfg.head_dim), jnp.bfloat16
                ),
            }
        batch_axes_mesh = tuple(
            a for a in ctx.rules["batch"] if a in mesh.shape
        )
        if cell.name == "long_500k":
            # whole mesh serves one stream: KV seq sharded over data x model
            seq_axes = ("data", "model")
            cache_batch = ()
        elif cell.kind == "decode":
            seq_axes = ("model",)
            cache_batch = batch_axes_mesh  # must match token batch axes —
            # a (pod,data)-sharded batch writing a (data,)-sharded cache made
            # GSPMD gather k/v across pods (+75 GiB temp on moe prefill)
        else:  # prefill: batch-sharded cache, seq sharded on model
            seq_axes = ("model",)
            cache_batch = batch_axes_mesh
        cache_specs = jax.tree.map(
            lambda s: P(None, cache_batch if cache_batch else None, seq_axes)
            if s.ndim >= 3
            else P(),
            cache_abs,
        )
        # serving params: TP only (no fsdp gather per token step? keep fsdp
        # for memory; decode weights gathered per layer like prefill)
        serve_pspecs = pspecs
        n_dev = int(np.prod(list(mesh.shape.values())))
        n_dev_cache = 256 if "pod" in mesh.shape else n_dev  # pods replicate
        cache_bytes_device = sum(
            int(np.prod(c.shape)) * c.dtype.itemsize for c in jax.tree.leaves(cache_abs)
        ) // n_dev_cache

        if cell.kind == "prefill":
            fn = make_prefill_fn(cfg, ctx)
            tokens_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)
            in_sh = (
                _named(mesh, serve_pspecs),
                NamedSharding(mesh, P(*batch_spec, None)),
                _named(mesh, cache_specs),
            )
            out_sh = (
                NamedSharding(mesh, P(*batch_spec, None)),
                _named(mesh, cache_specs),
            )
            mf = 2.0 * n_active * tokens_per_step
            return LoweredSpec(
                fn=fn,
                args=(params, tokens_abs, cache_abs),
                in_shardings=in_sh,
                out_shardings=out_sh,
                model_flops_per_step=mf,
                note="chunked attention, bf16 cache",
                donate_argnums=(2,),
                aux_info={"cache_bytes_device": cache_bytes_device},
            )

        # decode
        fn = make_decode_fn(cfg, ctx)
        tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        off_abs = jax.ShapeDtypeStruct((), jnp.int32)
        tok_spec = P(*batch_spec, None) if B > 1 else P(None, None)
        in_sh = (
            _named(mesh, serve_pspecs),
            NamedSharding(mesh, tok_spec),
            _named(mesh, cache_specs),
            NamedSharding(mesh, P()),
        )
        out_sh = (
            NamedSharding(mesh, tok_spec),
            _named(mesh, cache_specs),
        )
        mf = 2.0 * n_active * B  # one token per slot
        return LoweredSpec(
            fn=fn,
            args=(params, tok_abs, cache_abs, off_abs),
            in_shardings=in_sh,
            out_shardings=out_sh,
            model_flops_per_step=mf,
            note=f"kv seq axes={seq_axes}",
            donate_argnums=(2,),
            aux_info={"cache_bytes_device": cache_bytes_device},
        )

    # -- smoke ------------------------------------------------------------------
    def smoke(self, seed: int = 0) -> dict:
        from repro.data.synthetic import token_batch
        from repro.models import transformer as tf
        from repro.train.optimizer import init_state

        cfg = self.model_config(reduced=True)
        key = jax.random.PRNGKey(seed)
        params = tf.init(key, cfg)
        toks, labels = token_batch(4, 16, cfg.vocab, seed=seed)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        logits, _, _ = tf.apply(params, cfg, batch["tokens"])
        assert logits.shape == (4, 16, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
        step = jax.jit(make_train_step(lm_loss_fn(cfg), opt_cfg, num_micro=2))
        st = init_state(params)
        p2, st, m1 = step(params, st, batch)
        _, _, m2 = step(p2, st, batch)
        assert np.isfinite(float(m1["loss"])) and float(m2["loss"]) < float(m1["loss"]) * 1.5
        # decode parity with cache
        cache = tf.make_cache(cfg, 2, 20, dtype=jnp.float32)
        lg_p, cache, _ = tf.apply(params, cfg, batch["tokens"][:2, :8], cache=cache, cache_offset=0)
        lg_d, cache, _ = tf.apply(params, cfg, batch["tokens"][:2, 8:9], cache=cache, cache_offset=8)
        lg_full, _, _ = tf.apply(params, cfg, batch["tokens"][:2, :9])
        err = float(jnp.abs(lg_d[:, 0] - lg_full[:, 8]).max())
        assert err < 1e-3, err
        return {"loss0": float(m1["loss"]), "loss1": float(m2["loss"]),
                "decode_err": err}


# ===========================================================================
# GNN family (DimeNet)
# ===========================================================================

GNN_CELLS = {
    # full-batch small graph (cora-scale): fits replicated, single step.
    "full_graph_sm": Cell(
        "full_graph_sm", "train",
        extra=(("n_nodes", 2708), ("n_edges", 10556), ("d_feat", 1433),
               ("triplet_cap", 8)),
    ),
    # sampled-training on a reddit-scale graph: the real neighbor sampler
    # (data/sampler.py) produces per-lane padded subgraphs.
    "minibatch_lg": Cell(
        "minibatch_lg", "train",
        extra=(("n_nodes", 232_965), ("n_edges", 114_615_892),
               ("batch_nodes", 1024), ("fanout", (15, 10)),
               ("n_max", 16_384), ("e_max", 16_384), ("t_max", 32_768)),
    ),
    # full-batch LARGE graph: halo-partitioned data parallelism (DistDGL
    # style) — each chip owns one locality partition (nodes + halo, local
    # edges + capped triplets); grads psum.  A naive edge-sharded layout
    # would force a 15.8 GB message all-gather per block (see DESIGN.md §4).
    "ogb_products": Cell(
        "ogb_products", "train",
        extra=(("n_nodes", 2_449_029), ("n_edges", 61_859_140), ("d_feat", 100),
               ("triplet_cap", 4), ("n_loc", 16_384), ("e_loc", 262_144)),
    ),
    "molecule": Cell(
        "molecule", "train", global_batch=128,
        extra=(("n_nodes", 30), ("n_edges", 64), ("t_max", 256)),
    ),
}


class GNNArch(Arch):
    family = "gnn"

    def __init__(self, arch_id: str, config):
        self.arch_id = arch_id
        self._config = config
        self.cells = dict(GNN_CELLS)

    def model_config(self, reduced: bool = False):
        if not reduced:
            return self._config
        return dataclasses.replace(
            self._config, n_blocks=2, d_hidden=32, n_bilinear=4,
            n_spherical=4, n_radial=4,
        )

    def _cfg_for_cell(self, cell: Cell):
        d_feat = cell.get("d_feat", 0)
        return dataclasses.replace(
            self._config,
            d_node_feat=d_feat or 0,
            param_dtype="bfloat16",
            compute_dtype="bfloat16",
        )

    def build_cell(self, cell: Cell, mesh: Mesh) -> LoweredSpec:
        from repro.models import dimenet as dn

        ctx = make_ctx(mesh)
        cfg = self._cfg_for_cell(cell)
        params = jax.eval_shape(lambda k: dn.init(k, cfg), jax.random.PRNGKey(0))
        pspecs = jax.tree.map(lambda _: P(), params)  # small model: replicate
        opt_cfg = AdamWConfig(lr=1e-3, total_steps=10_000)
        loss = dimenet_loss_fn(cfg, ctx)

        def shard_mapped_loss(batch_specs_tree, lane_axes):
            """Partition-parallel loss via shard_map: each device runs DimeNet
            on its own halo partition; only the scalar loss (and, via AD, the
            parameter grads) cross devices.  GSPMD propagation through the
            vmapped form replicated the (T, h) triplet tensors instead
            (measured 242 GiB/device of collectives on ogb_products)."""
            from jax.experimental.shard_map import shard_map
            from repro.distributed.sharding_rules import NULL_CTX

            def lane_loss(p, batch):
                b = jax.tree.map(lambda a: a[0], batch)  # local lane
                node_pred, _ = dn.apply(
                    p, cfg, positions=b["positions"],
                    edge_index=b["edge_index"], t_in=b["t_in"],
                    t_out=b["t_out"], z=b.get("z"),
                    node_feat=b.get("features"),
                    node_mask=b.get("node_mask"), ctx=NULL_CTX,
                )
                mask = (
                    b["node_mask"].astype(jnp.float32)
                    if "node_mask" in b
                    else jnp.ones(node_pred.shape[0], jnp.float32)
                )
                se = (node_pred[:, 0] - b["y"]) ** 2 * mask
                s = jax.lax.psum(jnp.sum(se), lane_axes)
                c = jax.lax.psum(jnp.sum(mask), lane_axes)
                return s / jnp.maximum(c, 1.0)

            smapped = shard_map(
                lane_loss,
                mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(), params), batch_specs_tree),
                out_specs=P(),
                check_rep=False,
            )
            return lambda p, batch: (smapped(p, batch), jnp.float32(0.0))

        f32, i32 = jnp.float32, jnp.int32
        lane_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        n_lanes = int(np.prod([mesh.shape[a] for a in lane_axes]))
        bf16 = jnp.bfloat16

        def lane_specs(abs_tree, axes):
            return jax.tree.map(
                lambda s: P(axes, *((None,) * (s.ndim - 1))), abs_tree
            )

        if cell.name == "molecule":
            B = cell.global_batch
            nn_, ne = cell.get("n_nodes"), cell.get("n_edges")
            t_max = cell.get("t_max")
            batch_abs = {
                "positions": jax.ShapeDtypeStruct((B, nn_, 3), f32),
                "edge_index": jax.ShapeDtypeStruct((B, 2, ne), i32),
                "t_in": jax.ShapeDtypeStruct((B, t_max), i32),
                "t_out": jax.ShapeDtypeStruct((B, t_max), i32),
                "z": jax.ShapeDtypeStruct((B, nn_), i32),
                "y": jax.ShapeDtypeStruct((B,), f32),
            }
            batch_specs = lane_specs(batch_abs, ctx.spec("batch")[0])
        elif cell.name == "minibatch_lg":
            # one sampled subgraph per batch lane (data axes); 1024 seeds
            # split over the lanes.
            lanes = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                                 if a in mesh.shape]))
            n_max, e_max, t_max = (
                cell.get("n_max"), cell.get("e_max"), cell.get("t_max")
            )
            batch_abs = {
                "positions": jax.ShapeDtypeStruct((lanes, n_max, 3), f32),
                "edge_index": jax.ShapeDtypeStruct((lanes, 2, e_max), i32),
                "t_in": jax.ShapeDtypeStruct((lanes, t_max), i32),
                "t_out": jax.ShapeDtypeStruct((lanes, t_max), i32),
                "z": jax.ShapeDtypeStruct((lanes, n_max), i32),
                "y": jax.ShapeDtypeStruct((lanes,), f32),
            }
            batch_specs = lane_specs(batch_abs, ctx.spec("batch")[0])
        elif cell.name == "ogb_products":
            # halo partitions: one per chip (over ALL mesh axes)
            n_loc, e_loc = cell.get("n_loc"), cell.get("e_loc")
            t_loc = e_loc * cell.get("triplet_cap")
            d_feat = cell.get("d_feat")
            batch_abs = {
                "positions": jax.ShapeDtypeStruct((n_lanes, n_loc, 3), f32),
                "edge_index": jax.ShapeDtypeStruct((n_lanes, 2, e_loc), i32),
                "t_in": jax.ShapeDtypeStruct((n_lanes, t_loc), i32),
                "t_out": jax.ShapeDtypeStruct((n_lanes, t_loc), i32),
                "features": jax.ShapeDtypeStruct((n_lanes, n_loc, d_feat), bf16),
                "node_mask": jax.ShapeDtypeStruct((n_lanes, n_loc), jnp.bool_),
                "y": jax.ShapeDtypeStruct((n_lanes, n_loc), f32),
            }
            batch_specs = lane_specs(batch_abs, lane_axes)
            loss = shard_mapped_loss(batch_specs, lane_axes)
        else:  # full_graph_sm: replicated single graph
            n, E = cell.get("n_nodes"), cell.get("n_edges")
            cap = cell.get("triplet_cap")
            T = E * cap
            batch_abs = {
                "positions": jax.ShapeDtypeStruct((n, 3), f32),
                "edge_index": jax.ShapeDtypeStruct((2, E), i32),
                "t_in": jax.ShapeDtypeStruct((T,), i32),
                "t_out": jax.ShapeDtypeStruct((T,), i32),
                "features": jax.ShapeDtypeStruct((n, cell.get("d_feat")), f32),
                "y": jax.ShapeDtypeStruct((n,), f32),
                "node_mask": jax.ShapeDtypeStruct((n,), jnp.bool_),
            }
            batch_specs = jax.tree.map(lambda s: P(), batch_abs)

        step = make_train_step(loss, opt_cfg, num_micro=1)
        opt_abs = {
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "m": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params
            ),
            "v": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params
            ),
        }
        opt_specs = {"step": P(), "m": pspecs, "v": pspecs}
        in_sh = (
            _named(mesh, pspecs),
            _named(mesh, opt_specs),
            _named(mesh, batch_specs),
        )
        out_sh = (_named(mesh, pspecs), _named(mesh, opt_specs), None)
        # FLOPs proxy: 6 * params-touched-per-edge * edges processed
        if cell.name == "molecule":
            n_edges_step = cell.global_batch * cell.get("n_edges", 0)
        elif cell.name == "minibatch_lg":
            n_edges_step = batch_abs["edge_index"].shape[0] * cell.get("e_max")
        elif cell.name == "ogb_products":
            n_edges_step = n_lanes * cell.get("e_loc")
        else:
            n_edges_step = cell.get("n_edges", 1)
        per_edge_params = cfg.num_params() / max(cfg.n_blocks, 1)
        mf = 6.0 * per_edge_params * max(n_edges_step, 1)
        return LoweredSpec(
            fn=step,
            args=(params, opt_abs, batch_abs),
            in_shardings=in_sh,
            out_shardings=out_sh,
            model_flops_per_step=mf,
            note=f"layout={cell.name}; triplet cap {cell.get('triplet_cap')}",
            donate_argnums=(0, 1),
        )

    def smoke(self, seed: int = 0) -> dict:
        from repro.data.synthetic import random_molecule_batch
        from repro.models import dimenet as dn
        from repro.train.optimizer import init_state

        cfg = self.model_config(reduced=True)
        key = jax.random.PRNGKey(seed)
        params = dn.init(key, cfg)
        mols = random_molecule_batch(4, n_nodes=12, n_edges=24, seed=seed)
        t_in = np.full((4, 64), -1, np.int32)
        t_out = np.full((4, 64), -1, np.int32)
        for b in range(4):
            ti, to = dn.build_triplets(mols["edge_index"][b], 12)
            m = min(64, len(ti))
            t_in[b, :m], t_out[b, :m] = ti[:m], to[:m]
        batch = {
            "positions": jnp.asarray(mols["positions"]),
            "edge_index": jnp.asarray(mols["edge_index"]),
            "t_in": jnp.asarray(t_in),
            "t_out": jnp.asarray(t_out),
            "z": jnp.asarray(mols["z"]),
            "y": jnp.asarray(mols["y"]),
        }
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        step = jax.jit(make_train_step(dimenet_loss_fn(cfg), opt_cfg))
        st = init_state(params)
        p, st, m1 = step(params, st, batch)
        losses = [float(m1["loss"])]
        for _ in range(5):
            p, st, mm = step(p, st, batch)
            losses.append(float(mm["loss"]))
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0]
        return {"loss0": losses[0], "loss_last": losses[-1]}


# ===========================================================================
# RecSys family
# ===========================================================================

RECSYS_CELLS = {
    "train_batch": Cell("train_batch", "train", global_batch=65_536),
    "serve_p99": Cell("serve_p99", "forward", global_batch=512),
    "serve_bulk": Cell("serve_bulk", "forward", global_batch=262_144),
    "retrieval_cand": Cell(
        "retrieval_cand", "retrieval", global_batch=1,
        extra=(("n_candidates", 1_000_000),),
    ),
}


class RecsysArch(Arch):
    family = "recsys"

    def __init__(self, arch_id: str, config, *, embed_dim_retrieval: int = 0):
        self.arch_id = arch_id
        self._config = config
        self.cells = dict(RECSYS_CELLS)
        self.embed_dim_retrieval = embed_dim_retrieval

    def model_config(self, reduced: bool = False):
        from repro.models import recsys as rs

        cfg = self._config
        if not reduced:
            return cfg
        small = {"param_dtype": "float32", "compute_dtype": "float32"}
        if isinstance(cfg, rs.AutoIntConfig):
            return dataclasses.replace(cfg, vocab_sizes=(64,) * cfg.n_sparse, **small)
        if isinstance(cfg, rs.DINConfig):
            return dataclasses.replace(
                cfg, n_items=256, context_vocab=64, seq_len=16, **small
            )
        if isinstance(cfg, rs.SASRecConfig):
            return dataclasses.replace(cfg, n_items=256, seq_len=16, **small)
        if isinstance(cfg, rs.XDeepFMConfig):
            return dataclasses.replace(
                cfg, vocab_sizes=(64,) * cfg.n_sparse,
                cin_layers=(16, 16), mlp=(32, 32), **small
            )
        raise TypeError(type(cfg))

    # ---- batch spec per arch -------------------------------------------------
    def _batch_abs(self, cfg, B: int, for_loss: bool):
        from repro.models import recsys as rs

        f32, i32 = jnp.float32, jnp.int32
        if isinstance(cfg, (rs.AutoIntConfig, rs.XDeepFMConfig)):
            b = {"sparse_ids": jax.ShapeDtypeStruct((B, cfg.n_sparse), i32)}
        elif isinstance(cfg, rs.DINConfig):
            b = {
                "history": jax.ShapeDtypeStruct((B, cfg.seq_len), i32),
                "hist_len": jax.ShapeDtypeStruct((B,), i32),
                "target_item": jax.ShapeDtypeStruct((B,), i32),
                "context_ids": jax.ShapeDtypeStruct((B, cfg.n_context), i32),
            }
        elif isinstance(cfg, rs.SASRecConfig):
            b = {"item_seq": jax.ShapeDtypeStruct((B, cfg.seq_len), i32)}
            if for_loss:
                b["next_items"] = jax.ShapeDtypeStruct((B, cfg.seq_len), i32)
                b["neg_items"] = jax.ShapeDtypeStruct((B, cfg.seq_len), i32)
        else:
            raise TypeError(type(cfg))
        if for_loss and not isinstance(cfg, rs.SASRecConfig):
            b["label"] = jax.ShapeDtypeStruct((B,), f32)
        return b

    def _param_specs(self, params):
        """Embedding tables row-sharded over 'model' (LANNS level-1 applied
        to tables); small dense layers replicated."""

        def spec_for(path, leaf):
            names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
            if "table" in names[-1] or (len(names) >= 2 and "table" in names[-2]):
                if leaf.ndim == 2 and leaf.shape[0] >= 4096:
                    return P("model", None)
            if names[-1] == "offsets":
                return P()
            return P(*([None] * leaf.ndim))

        return jax.tree_util.tree_map_with_path(spec_for, params)

    def _init_abstract(self, cfg):
        from repro.models import recsys as rs

        if isinstance(cfg, rs.AutoIntConfig):
            init = rs.autoint_init
        elif isinstance(cfg, rs.DINConfig):
            init = rs.din_init
        elif isinstance(cfg, rs.SASRecConfig):
            init = rs.sasrec_init
        else:
            init = rs.xdeepfm_init
        return jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))

    def _forward_fn(self, cfg, ctx):
        from repro.models import recsys as rs

        if isinstance(cfg, rs.AutoIntConfig):
            return lambda p, b: rs.autoint_apply(p, cfg, b["sparse_ids"], ctx)
        if isinstance(cfg, rs.DINConfig):
            return lambda p, b: rs.din_apply(
                p, cfg, history=b["history"], hist_len=b["hist_len"],
                target_item=b["target_item"], context_ids=b["context_ids"], ctx=ctx,
            )
        if isinstance(cfg, rs.SASRecConfig):
            return lambda p, b: rs.sasrec_encode(p, cfg, b["item_seq"], ctx)[:, -1]
        return lambda p, b: rs.xdeepfm_apply(p, cfg, b["sparse_ids"], ctx)

    def build_cell(self, cell: Cell, mesh: Mesh) -> LoweredSpec:
        from repro.models import recsys as rs

        ctx = make_ctx(mesh)
        cfg = dataclasses.replace(
            self._config, param_dtype="bfloat16", compute_dtype="bfloat16"
        )
        params = self._init_abstract(cfg)
        pspecs = self._param_specs(params)
        batch_spec = ctx.spec("batch")
        n_params = cfg.num_params()

        if cell.kind == "train":
            B = cell.global_batch
            arch = cfg.name
            opt_cfg = AdamWConfig(lr=1e-3, total_steps=100_000)
            loss = recsys_loss_fn(arch, cfg, ctx)
            step = make_train_step(loss, opt_cfg, num_micro=1)
            batch_abs = self._batch_abs(cfg, B, for_loss=True)
            batch_specs = jax.tree.map(
                lambda s: P(batch_spec[0] if batch_spec else None,
                            *((None,) * (s.ndim - 1))),
                batch_abs,
            )
            opt_abs = {
                "step": jax.ShapeDtypeStruct((), jnp.int32),
                "m": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params
                ),
                "v": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params
                ),
            }
            opt_specs = {"step": P(), "m": pspecs, "v": pspecs}
            return LoweredSpec(
                fn=step,
                args=(params, opt_abs, batch_abs),
                in_shardings=(
                    _named(mesh, pspecs), _named(mesh, opt_specs),
                    _named(mesh, batch_specs),
                ),
                out_shardings=(
                    _named(mesh, pspecs), _named(mesh, opt_specs), None
                ),
                model_flops_per_step=6.0 * B * self._active_params_per_example(cfg),
                note="tables row-sharded on model",
                donate_argnums=(0, 1),
            )

        if cell.kind == "forward":
            B = cell.global_batch
            fwd = self._forward_fn(cfg, ctx)
            batch_abs = self._batch_abs(cfg, B, for_loss=False)
            batch_specs = jax.tree.map(
                lambda s: P(batch_spec[0] if batch_spec else None,
                            *((None,) * (s.ndim - 1))),
                batch_abs,
            )
            return LoweredSpec(
                fn=fwd,
                args=(params, batch_abs),
                in_shardings=(_named(mesh, pspecs), _named(mesh, batch_specs)),
                out_shardings=None,
                model_flops_per_step=2.0 * B * self._active_params_per_example(cfg),
                note="online inference",
            )

        # retrieval_cand: user-tower forward (batch=1) + LANNS shard scan
        # over 1M candidate embeddings sharded across every chip + top-k
        # merge — the paper's PYMK retrieval served by this framework
        # (DESIGN.md §7).  A learned projection maps the tower output to the
        # candidate embedding space (two-tower serving layout).
        n_cand = cell.get("n_candidates")
        n_cand_pad = -(-n_cand // 512) * 512  # shard evenly over all chips
        d_emb = self.embed_dim_retrieval or 64
        fwd = self._forward_fn(cfg, ctx)
        batch_abs = self._batch_abs(cfg, cell.global_batch, for_loss=False)
        # user tower output dim: probe via eval_shape
        u_shape = jax.eval_shape(fwd, params, batch_abs)
        ud = int(np.prod(u_shape.shape[1:])) if u_shape.ndim > 1 else 1
        cand_abs = jax.ShapeDtypeStruct((n_cand_pad, d_emb), jnp.bfloat16)
        proj_abs = jax.ShapeDtypeStruct((max(ud, 1), d_emb), jnp.bfloat16)
        topk = 100
        lane_axes_r = tuple(
            a for a in ("pod", "data", "model") if a in mesh.shape
        )

        def retrieval_step(params, batch, candidates, user_proj):
            u = fwd(params, batch)
            u = u.reshape(1, -1).astype(jnp.bfloat16)
            u = (u @ user_proj).astype(candidates.dtype)
            scores = (u @ candidates.T).astype(jnp.float32)  # (1, n_cand_pad)
            pad_mask = jnp.arange(scores.shape[-1]) < n_cand
            scores = jnp.where(pad_mask[None, :], scores, -jnp.inf)
            top, idx = jax.lax.top_k(scores, topk)
            return top, idx

        batch_specs = jax.tree.map(lambda s: P(*([None] * s.ndim)), batch_abs)
        return LoweredSpec(
            fn=retrieval_step,
            args=(params, batch_abs, cand_abs, proj_abs),
            in_shardings=(
                _named(mesh, pspecs),
                _named(mesh, batch_specs),
                NamedSharding(mesh, P(lane_axes_r, None)),
                NamedSharding(mesh, P()),
            ),
            out_shardings=None,
            model_flops_per_step=2.0 * n_cand * d_emb,
            note="candidate corpus sharded over all chips (LANNS shard scan)",
        )

    def _active_params_per_example(self, cfg):
        """Params touched per example (embedding rows looked up + MLPs)."""
        from repro.models import recsys as rs

        if isinstance(cfg, rs.AutoIntConfig):
            dh = cfg.d_attn * cfg.n_heads
            mlp = cfg.n_sparse * cfg.embed_dim * dh * 4 * cfg.n_attn_layers
            return cfg.n_sparse * cfg.embed_dim + mlp + cfg.n_sparse * dh
        if isinstance(cfg, rs.DINConfig):
            d = cfg.embed_dim
            att = 4 * d * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1]
            mlp_in = 2 * d + cfg.n_context * d
            mlp = mlp_in * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1]
            return (cfg.seq_len + 1 + cfg.n_context) * d + cfg.seq_len * att + mlp
        if isinstance(cfg, rs.SASRecConfig):
            d = cfg.embed_dim
            per = 6 * d * d
            return cfg.seq_len * d + cfg.n_blocks * cfg.seq_len * per / cfg.seq_len
        if isinstance(cfg, rs.XDeepFMConfig):
            d = cfg.embed_dim
            cin = 0
            hk_prev = cfg.n_sparse
            for hk in cfg.cin_layers:
                cin += hk_prev * cfg.n_sparse * hk * d
                hk_prev = hk
            dims = (cfg.n_sparse * d,) + cfg.mlp + (1,)
            mlp = sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1))
            return cfg.n_sparse * d + cin + mlp
        raise TypeError(type(cfg))

    def smoke(self, seed: int = 0) -> dict:
        from repro.data.synthetic import criteo_like_batch
        from repro.models import recsys as rs
        from repro.train.optimizer import init_state

        cfg = self.model_config(reduced=True)
        key = jax.random.PRNGKey(seed)
        params_init = self._init_abstract  # noqa
        if isinstance(cfg, rs.AutoIntConfig):
            params = rs.autoint_init(key, cfg)
            data = criteo_like_batch(32, n_sparse=cfg.n_sparse,
                                     vocab_sizes=list(cfg.vocab_sizes), seed=seed)
            batch = {"sparse_ids": jnp.asarray(data["sparse_ids"]),
                     "label": jnp.asarray(data["label"])}
        elif isinstance(cfg, rs.XDeepFMConfig):
            params = rs.xdeepfm_init(key, cfg)
            data = criteo_like_batch(32, n_sparse=cfg.n_sparse,
                                     vocab_sizes=list(cfg.vocab_sizes), seed=seed)
            batch = {"sparse_ids": jnp.asarray(data["sparse_ids"]),
                     "label": jnp.asarray(data["label"])}
        elif isinstance(cfg, rs.DINConfig):
            params = rs.din_init(key, cfg)
            data = criteo_like_batch(
                32, n_sparse=cfg.n_context, vocab_sizes=[cfg.context_vocab] * cfg.n_context,
                hist_len=cfg.seq_len, n_items=cfg.n_items, seed=seed,
            )
            batch = {
                "history": jnp.asarray(data["history"]),
                "hist_len": jnp.asarray(data["hist_len"]),
                "target_item": jnp.asarray(data["target_item"]),
                "context_ids": jnp.asarray(data["sparse_ids"]),
                "label": jnp.asarray(data["label"]),
            }
        else:
            params = rs.sasrec_init(key, cfg)
            rng = np.random.default_rng(seed)
            seq = rng.integers(0, cfg.n_items, (32, cfg.seq_len + 1))
            batch = {
                "item_seq": jnp.asarray(seq[:, :-1], jnp.int32),
                "next_items": jnp.asarray(seq[:, 1:], jnp.int32),
            }
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)
        step = jax.jit(
            make_train_step(recsys_loss_fn(cfg.name, cfg), opt_cfg)
        )
        st = init_state(params)
        p, st, m1 = step(params, st, batch)
        losses = [float(m1["loss"])]
        for _ in range(8):
            p, st, mm = step(p, st, batch)
            losses.append(float(mm["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        assert losses[-1] < losses[0], losses
        return {"loss0": losses[0], "loss_last": losses[-1]}

"""DimeNet — Directional Message Passing Neural Network (arXiv:2003.03123).

Config (assigned): n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6.  Messages live on DIRECTED EDGES m_{ji}; each interaction block
updates m_{ji} from all incoming messages m_{kj} (k != i) weighted by a
2D spherical-radial basis of (d_kj, angle(k->j->i)) through a bilinear layer.

Kernel regime (taxonomy §GNN): triplet gather — NOT expressible as SpMM.  We
precompute the triplet index list (t_src = edge k->j, t_dst = edge j->i) on
the host (numpy, with an optional per-edge cap for the web-scale graphs) and
the model does gather -> dense math -> ``jax.ops.segment_sum`` back to edges;
node aggregation is another segment_sum over edge destinations.  All ragged
structures are padded to static shapes with -1 sentinels (masked), so the
whole model jits and shards: edge/triplet tables shard over 'model' (the
LANNS hash-shard idea applied to edge partitions), node tables replicate.

Bases: radial = sin(n pi d / c)/d (the l=0 spherical Bessel family the paper
uses), angular = Legendre polynomials P_l(cos theta) (the m=0 spherical
harmonics up to n_spherical) — both faithful to the reference implementation
up to normalization constants.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding_rules import NULL_CTX, ShardingCtx
from repro.models.layers import _init_dense


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    envelope_p: int = 6
    n_species: int = 95
    d_node_feat: int = 0  # >0: continuous node features instead of species
    out_dim: int = 1
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def num_params(self) -> int:
        h, nb = self.d_hidden, self.n_bilinear
        emb = (self.n_species if not self.d_node_feat else self.d_node_feat) * h
        emb += self.n_radial * h + 3 * h * h
        per_block = (
            self.n_radial * h  # rbf -> edge gate
            + self.n_spherical * self.n_radial * nb  # sbf proj
            + h * nb * h  # bilinear
            + 4 * h * h  # msg MLPs
            + h * self.out_dim
        )
        return emb + self.n_blocks * per_block + 2 * h * self.out_dim


# ---------------------------------------------------------------------------
# host-side graph preprocessing (real substrate, not a stub)
# ---------------------------------------------------------------------------


def build_triplets(
    edge_index: np.ndarray,
    n_nodes: int,
    max_in_per_edge: Optional[int] = None,
    max_triplets: Optional[int] = None,
    seed: int = 0,
):
    """Triplet list for directed edges: pairs (e_kj, e_ji) sharing node j,
    with k != i.  Returns (t_in, t_out) int32 arrays — t_in indexes the
    incoming message edge (k->j), t_out the updated edge (j->i).

    Fully vectorized (no python loop over edges).  ``max_in_per_edge`` caps
    in-degree contributions per outgoing edge (deterministic truncation) and
    ``max_triplets`` uniformly subsamples the rest — the compute-bounding
    trick for web-scale graphs, analogous to LANNS capacity-bounded routing.
    """
    src = np.asarray(edge_index[0], dtype=np.int64)
    dst = np.asarray(edge_index[1], dtype=np.int64)
    valid = (src >= 0) & (dst >= 0)
    E = src.shape[0]
    vsrc, vdst = src[valid], dst[valid]
    vidx = np.nonzero(valid)[0]
    order_d = np.argsort(vdst, kind="stable")  # valid edges grouped by dst
    starts = np.searchsorted(vdst[order_d], np.arange(n_nodes + 1))
    indeg = starts[1:] - starts[:-1]
    counts = indeg[vsrc]  # per valid edge e=(j->i): in-degree of j
    if max_in_per_edge is not None:
        counts = np.minimum(counts, max_in_per_edge)
    total = int(counts.sum())
    t_out_v = np.repeat(np.arange(len(vsrc), dtype=np.int64), counts)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    within = np.arange(total, dtype=np.int64) - offsets[t_out_v]
    t_in_v = order_d[starts[vsrc[t_out_v]] + within]
    # drop degenerate triplets where k == i (message bouncing straight back)
    keep = vsrc[t_in_v] != vdst[t_out_v]
    t_in_v, t_out_v = t_in_v[keep], t_out_v[keep]
    if max_triplets is not None and len(t_in_v) > max_triplets:
        rng = np.random.default_rng(seed)
        sel = rng.choice(len(t_in_v), max_triplets, replace=False)
        t_in_v, t_out_v = t_in_v[sel], t_out_v[sel]
    # map back to original (padded) edge ids
    return vidx[t_in_v].astype(np.int32), vidx[t_out_v].astype(np.int32)


# ---------------------------------------------------------------------------
# bases
# ---------------------------------------------------------------------------


def envelope(d, cutoff: float, p: int):
    """Smooth polynomial cutoff envelope u(d) (DimeNet eq. 8)."""
    x = d / cutoff
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2)
    c = -p * (p + 1) / 2.0
    env = 1.0 / jnp.maximum(x, 1e-9) + a * x ** (p - 1) + b * x**p + c * x ** (p + 1)
    return jnp.where(x < 1.0, env, 0.0)


def radial_basis(d, n_radial: int, cutoff: float, p: int):
    """e_RBF,n(d) = sqrt(2/c) sin(n pi d / c) / d, enveloped.  (E, n_radial)"""
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    dd = jnp.maximum(d[..., None], 1e-9)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * dd / cutoff) / dd
    return basis * envelope(d, cutoff, p)[..., None]


def _legendre(cos_t, l_max: int):
    """P_0..P_{l_max-1}(cos theta) by recurrence.  (..., l_max)"""
    p0 = jnp.ones_like(cos_t)
    if l_max == 1:
        return p0[..., None]
    ps = [p0, cos_t]
    for l in range(2, l_max):
        ps.append(((2 * l - 1) * cos_t * ps[-1] - (l - 1) * ps[-2]) / l)
    return jnp.stack(ps, axis=-1)


def spherical_basis(d, angle, n_spherical: int, n_radial: int, cutoff: float, p: int):
    """a_SBF,(l,n)(d, theta): radial sin-basis x Legendre angular.  Returns
    (T, n_spherical * n_radial)."""
    rb = radial_basis(d, n_radial, cutoff, p)  # (T, n_radial)
    ab = _legendre(jnp.cos(angle), n_spherical)  # (T, n_spherical)
    return (ab[..., :, None] * rb[..., None, :]).reshape(
        *d.shape, n_spherical * n_radial
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _mlp2_init(key, d_in, d_out, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w1": _init_dense(k1, (d_in, d_out), dtype),
        "w2": _init_dense(k2, (d_out, d_out), dtype),
    }


def init(key, cfg: DimeNetConfig):
    dtype = cfg.dtype()
    keys = jax.random.split(key, 6 + cfg.n_blocks)
    h = cfg.d_hidden
    d_in_node = cfg.d_node_feat if cfg.d_node_feat else cfg.n_species
    params = {
        "embed_node": _init_dense(keys[0], (d_in_node, h), dtype, scale=0.02),
        "embed_rbf": _init_dense(keys[1], (cfg.n_radial, h), dtype),
        "embed_msg": _mlp2_init(keys[2], 3 * h, dtype=dtype, d_out=h),
        "out_embed": _mlp2_init(keys[3], h, h, dtype),
        "out_final": _init_dense(keys[4], (h, cfg.out_dim), dtype),
    }
    blocks = []
    for b in range(cfg.n_blocks):
        kb = jax.random.split(keys[5 + b], 8)
        blocks.append(
            {
                "rbf_gate": _init_dense(kb[0], (cfg.n_radial, h), dtype),
                "sbf_proj": _init_dense(
                    kb[1], (cfg.n_spherical * cfg.n_radial, cfg.n_bilinear), dtype
                ),
                "bilinear": (
                    jax.random.normal(kb[2], (h, cfg.n_bilinear, h)) / np.sqrt(h)
                ).astype(dtype),
                "w_src": _init_dense(kb[3], (h, h), dtype),
                "w_msg": _init_dense(kb[4], (h, h), dtype),
                "w_update1": _init_dense(kb[5], (h, h), dtype),
                "w_update2": _init_dense(kb[6], (h, h), dtype),
                "out_proj": _init_dense(kb[7], (h, h), dtype),
            }
        )
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return params


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def apply(
    params,
    cfg: DimeNetConfig,
    *,
    positions,  # (n, 3)
    edge_index,  # (2, E) int32, -1 padded
    t_in,  # (T,) int32 triplet incoming edge, -1 padded
    t_out,  # (T,) int32 triplet outgoing edge, -1 padded
    z=None,  # (n,) species OR None
    node_feat=None,  # (n, d_feat) when cfg.d_node_feat
    node_mask=None,  # (n,) bool
    ctx: ShardingCtx = NULL_CTX,
):
    """Returns (node_out (n, out_dim), graph_out (out_dim,)).

    All index arrays may be -1 padded; contributions are masked to zero.
    """
    act = jax.nn.silu
    n = positions.shape[0]
    E = edge_index.shape[1]
    src, dst = edge_index[0], edge_index[1]
    e_valid = (src >= 0) & (dst >= 0)
    srcc = jnp.clip(src, 0)
    dstc = jnp.clip(dst, 0)

    # geometry
    vec = positions[dstc] - positions[srcc]  # (E, 3)
    dist = jnp.sqrt(jnp.maximum(jnp.sum(vec**2, axis=-1), 1e-12))
    rbf = radial_basis(dist, cfg.n_radial, cfg.cutoff, cfg.envelope_p)
    rbf = jnp.where(e_valid[:, None], rbf, 0.0).astype(positions.dtype)

    t_valid = (t_in >= 0) & (t_out >= 0)
    ti = jnp.clip(t_in, 0)
    to = jnp.clip(t_out, 0)
    # angle at shared node j between edges (k->j) and (j->i)
    v_in = -vec[ti]  # j -> k
    v_out = vec[to]  # j -> i
    cos_a = jnp.sum(v_in * v_out, axis=-1) / (
        jnp.maximum(jnp.linalg.norm(v_in, axis=-1) * jnp.linalg.norm(v_out, axis=-1), 1e-9)
    )
    angle = jnp.arccos(jnp.clip(cos_a, -1.0 + 1e-7, 1.0 - 1e-7))
    sbf = spherical_basis(
        dist[ti], angle, cfg.n_spherical, cfg.n_radial, cfg.cutoff, cfg.envelope_p
    )
    sbf = jnp.where(t_valid[:, None], sbf, 0.0).astype(positions.dtype)

    # node embedding
    if cfg.d_node_feat:
        hN = node_feat @ params["embed_node"]
    else:
        hN = params["embed_node"][jnp.clip(z, 0)]
    if node_mask is not None:
        hN = jnp.where(node_mask[:, None], hN, 0.0)

    # initial edge message: MLP([h_src, h_dst, rbf_embed])
    m = jnp.concatenate(
        [hN[srcc], hN[dstc], rbf @ params["embed_rbf"]], axis=-1
    )
    m = act(m @ params["embed_msg"]["w1"])
    m = act(m @ params["embed_msg"]["w2"])  # (E, h)
    m = jnp.where(e_valid[:, None], m, 0.0)
    m = ctx.constrain(m, "batch", None)

    node_out = jnp.zeros((n, cfg.d_hidden), m.dtype)

    def block(carry, bp):
        m, node_out = carry
        # directional message passing (eq. 9): bilinear(sbf, m_kj) agg to e_ji
        gate = rbf @ bp["rbf_gate"]  # (E, h)
        m_gated = act(m @ bp["w_msg"]) * gate
        m_in = m_gated[ti]  # (T, h) gather incoming messages
        sb = sbf @ bp["sbf_proj"]  # (T, n_bilinear)
        # bilinear as a sum over the (small) bilinear axis — an einsum over
        # "th,hbk,tb->tk" materializes a (T, n_bilinear, h) intermediate
        # (4 GiB/block at 1M triplets); the unrolled form peaks at (T, h).
        h_dim = m_in.shape[-1]
        inter = jnp.zeros((m_in.shape[0], h_dim), m_in.dtype)
        for b in range(bp["bilinear"].shape[-2]):
            inter = inter + (m_in @ bp["bilinear"][:, b, :]) * sb[:, b:b + 1]
        inter = jnp.where(t_valid[:, None], inter, 0.0)
        agg = jax.ops.segment_sum(inter, to, num_segments=E)  # (E, h)
        mm = act(m @ bp["w_src"]) + agg
        mm = act(mm @ bp["w_update1"])
        m_new = m + act(mm @ bp["w_update2"])  # residual
        m_new = jnp.where(e_valid[:, None], m_new, 0.0)
        m_new = ctx.constrain(m_new, "batch", None)
        # per-block output: aggregate messages to destination nodes
        contrib = jax.ops.segment_sum(
            m_new * gate, dstc, num_segments=n
        ) @ bp["out_proj"]
        return (m_new, node_out + contrib), None

    block_fn = jax.checkpoint(
        block, policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False
    )
    (m, node_out), _ = jax.lax.scan(block_fn, (m, node_out), params["blocks"])

    h = act(node_out @ params["out_embed"]["w1"])
    h = act(h @ params["out_embed"]["w2"])
    node_pred = h @ params["out_final"]
    if node_mask is not None:
        node_pred = jnp.where(node_mask[:, None], node_pred, 0.0)
    graph_pred = jnp.sum(node_pred, axis=0)
    return node_pred, graph_pred

"""Transformer building blocks: RMSNorm, RoPE, GQA, MLA, SwiGLU, chunked attn.

Functional style: every layer is (init_fn -> params pytree, apply_fn).  Params
are plain dicts so they stack cleanly for scan-over-layers (models/
transformer.py) and shard with simple PartitionSpec rules (distributed/
sharding_rules.py).  Compute dtype and parameter dtype are decoupled; norms
and softmax always run in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _init_dense(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norm
# ---------------------------------------------------------------------------


def rms_norm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rms_norm(params, x, eps: float = 1e-6):
    """RMSNorm with f32 statistics but NO full-size f32 convert of x.

    A plain ``x.astype(f32)`` creates a convert node that jax.checkpoint
    considers free-to-save; under scan-over-layers that made the backward
    save an f32 copy of the whole (L, B, S, d) carry stack (+10 GiB on
    qwen2-72b train_4k).  The einsum accumulates the sum of squares in f32
    without materializing an f32 copy of x.
    """
    d = x.shape[-1]
    ss = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss / d + eps)[..., None].astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10_000.0):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float = 10_000.0):
    """x (..., S, H, hd); positions (..., S) int32.  Rotates pairs (even, odd)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, with optional QKV bias — Qwen style)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    q_chunk: int = 0  # 0 = unchunked; >0 enables flash-style chunked attn
    kv_chunk: int = 1024


def attention_init(key, cfg: AttentionConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _init_dense(ks[0], (d, H * hd), dtype),
        "wk": _init_dense(ks[1], (d, KV * hd), dtype),
        "wv": _init_dense(ks[2], (d, KV * hd), dtype),
        "wo": _init_dense(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _repeat_kv(x, n_rep: int):
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd) by head repetition (GQA)."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(
        b, s, kv * n_rep, hd
    )


def _causal_mask(sq: int, skv: int, q_offset):
    """Additive causal mask (sq, skv): q position i attends kv <= i+offset."""
    qi = jax.lax.broadcasted_iota(jnp.int32, (sq, skv), 0) + q_offset
    kj = jax.lax.broadcasted_iota(jnp.int32, (sq, skv), 1)
    return jnp.where(kj <= qi, 0.0, -jnp.inf).astype(jnp.float32)


def dot_attention(q, k, v, *, causal: bool, q_offset=0, scale=None):
    """q (B, Sq, H, hd), k/v (B, Skv, H, hd) -> (B, Sq, H, hd).  f32 softmax."""
    hd = q.shape[-1]
    scale = scale or (1.0 / np.sqrt(hd))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        logits = logits + _causal_mask(q.shape[1], k.shape[1], q_offset)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
                      scale=None):
    """Flash-style exact attention: scan over kv chunks with an online
    softmax (running max / normalizer), scanned over q chunks.  Memory is
    O(q_chunk * kv_chunk) instead of O(Sq * Skv) — mandatory for the 32k
    prefill cells (32k^2 scores would be 4 GB/head).  Matches dot_attention
    to float tolerance.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    hd_v = v.shape[-1]  # MLA: v head dim can differ from qk head dim
    scale = scale or (1.0 / np.sqrt(hd))
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    q_pad, kv_pad = nq * q_chunk - Sq, nk * kv_chunk - Skv
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    qs = qp.reshape(B, nq, q_chunk, H, hd)
    ks = kp.reshape(B, nk, kv_chunk, H, hd)
    vs = vp.reshape(B, nk, kv_chunk, H, hd_v)

    def q_step(_, qc_idx):
        qi, qc = qc_idx
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kc_idx):
            m, l, acc = carry
            ki, kc, vc = kc_idx
            kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32) * scale
            valid = kv_pos[None, :] < Skv
            if causal:
                valid = valid & (kv_pos[None, :] <= q_pos[:, None])
            s = jnp.where(valid[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(valid[None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, jnp.moveaxis(out, 1, 2)  # (B, q_chunk, H, hd)

    _, outs = jax.lax.scan(
        q_step, None, (jnp.arange(nq), jnp.moveaxis(qs, 1, 0))
    )  # (nq, B, q_chunk, H, hd_v)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, hd_v)[:, :Sq]
    return out.astype(q.dtype)


def attention_apply(
    params,
    cfg: AttentionConfig,
    x,
    *,
    positions,
    causal: bool = True,
    kv_cache: Optional[dict] = None,
    cache_offset=None,
):
    """GQA attention.  x (B, S, d).

    kv_cache: {"k": (B, S_max, KV, hd), "v": ...} — when provided, new k/v are
    written at cache_offset and attention runs against the full cache
    (decode / incremental prefill).  Returns (out, new_cache).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        off = cache_offset if cache_offset is not None else 0
        if hasattr(off, "ndim") and off.ndim == 1:  # per-row offsets (slots)
            rows = jnp.arange(B)[:, None]
            cols = off[:, None] + jnp.arange(S)[None, :]
            ck = kv_cache["k"].at[rows, cols].set(k.astype(kv_cache["k"].dtype))
            cv = kv_cache["v"].at[rows, cols].set(v.astype(kv_cache["v"].dtype))
            q_pos = off[:, None] + jnp.arange(S)[None, :]  # (B, S)
            full_prefill = False
        else:
            ck = jax.lax.dynamic_update_slice(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, off, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, off, 0, 0)
            )
            q_pos = jnp.broadcast_to(off + jnp.arange(S)[None, :], (B, S))
            # whole-sequence prefill: nothing precedes these tokens, so
            # attention over the fresh k/v is exact — take the (chunked)
            # cacheless path instead of scoring the padded cache (which
            # materialized a (B, H, S, S_max) f32 buffer: 34 GiB at 32k).
            full_prefill = isinstance(off, int) and off == 0 and S > 1
        new_cache = {"k": ck, "v": cv}
        if full_prefill:
            k_full = _repeat_kv(k, H // KV)
            v_full = _repeat_kv(v, H // KV)
            if cfg.q_chunk and S > cfg.q_chunk:
                out = chunked_attention(
                    q, k_full, v_full, causal=causal,
                    q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                )
            else:
                out = dot_attention(q, k_full, v_full, causal=causal)
            out = out.reshape(B, S, H * hd) @ params["wo"]
            return out, new_cache
        S_kv = ck.shape[1]
        kv_pos = jnp.arange(S_kv)
        # valid cache extent + causality, per row: kv <= q position
        ok = kv_pos[None, None, :] <= q_pos[:, :, None]  # (B, S, S_kv)
        if not causal:
            ok = kv_pos[None, None, :] <= q_pos[:, -1:, None]
        # grouped einsum: never materialize the repeated KV (decode at
        # kv=8 -> 64 heads would copy 4 GiB/layer otherwise)
        G = H // KV
        qg = q.reshape(B, S, KV, G, hd)
        logits = jnp.einsum(
            "bskgd,btkd->bkgst", qg, ck.astype(x.dtype)
        ).astype(jnp.float32) / np.sqrt(hd)
        logits = jnp.where(ok[:, None, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", probs, cv.astype(x.dtype))
        out = out.reshape(B, S, H, hd)
    else:
        k_full = _repeat_kv(k, H // KV)
        v_full = _repeat_kv(v, H // KV)
        if cfg.q_chunk and S > cfg.q_chunk:
            out = chunked_attention(
                q, k_full, v_full, causal=causal,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            )
        else:
            out = dot_attention(q, k_full, v_full, causal=causal)
    out = out.reshape(B, S, H * hd) @ params["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10_000.0
    q_chunk: int = 0
    kv_chunk: int = 1024

    @property
    def qk_head_dim(self):
        return self.qk_nope_head_dim + self.qk_rope_head_dim


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    d, H = cfg.d_model, cfg.n_heads
    return {
        # queries: full-rank projection (V2-Lite has no q compression)
        "wq": _init_dense(ks[0], (d, H * cfg.qk_head_dim), dtype),
        # compressed KV path: d -> latent + shared rope key
        "w_dkv": _init_dense(ks[1], (d, cfg.kv_lora_rank), dtype),
        "w_krope": _init_dense(ks[2], (d, cfg.qk_rope_head_dim), dtype),
        "kv_norm": rms_norm_init(cfg.kv_lora_rank, dtype),
        # up-projections from the latent
        "w_uk": _init_dense(ks[3], (cfg.kv_lora_rank, H * cfg.qk_nope_head_dim), dtype),
        "w_uv": _init_dense(ks[4], (cfg.kv_lora_rank, H * cfg.v_head_dim), dtype),
        "wo": _init_dense(ks[5], (H * cfg.v_head_dim, d), dtype),
    }


def mla_apply(
    params,
    cfg: MLAConfig,
    x,
    *,
    positions,
    causal: bool = True,
    latent_cache: Optional[dict] = None,
    cache_offset=None,
):
    """MLA attention.  Cache stores ONLY (latent (B, S, r), k_rope (B, S, dr))
    — 576 dims/token for V2-Lite vs 2 * 16 * 128 = 4096 for the GQA
    equivalent: the 7x KV-byte reduction that makes the long-decode cells
    memory-feasible (see EXPERIMENTS.md §Roofline).

    Decode uses the absorbed form: q_nope is folded through W_uk so scores are
    taken directly against the latent; W_uv output is folded through wo.  This
    never materializes per-head K/V for the whole cache.
    """
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = (
        cfg.qk_nope_head_dim,
        cfg.qk_rope_head_dim,
        cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    q = (x @ params["wq"]).reshape(B, S, H, cfg.qk_head_dim)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent = rms_norm(params["kv_norm"], x @ params["w_dkv"])  # (B, S, r)
    k_rope = apply_rope(
        (x @ params["w_krope"]).reshape(B, S, 1, dr), positions, cfg.rope_theta
    )  # (B, S, 1, dr) — shared across heads

    scale = 1.0 / np.sqrt(cfg.qk_head_dim)

    if latent_cache is not None:
        off = cache_offset if cache_offset is not None else 0
        if hasattr(off, "ndim") and off.ndim == 1:  # per-row offsets (slots)
            rows = jnp.arange(B)[:, None]
            cols = off[:, None] + jnp.arange(S)[None, :]
            cl = latent_cache["latent"].at[rows, cols].set(
                latent.astype(latent_cache["latent"].dtype)
            )
            cr = latent_cache["k_rope"].at[rows, cols].set(
                k_rope[:, :, 0].astype(latent_cache["k_rope"].dtype)
            )
            q_pos = off[:, None] + jnp.arange(S)[None, :]  # (B, S)
        else:
            cl = jax.lax.dynamic_update_slice(
                latent_cache["latent"],
                latent.astype(latent_cache["latent"].dtype), (0, off, 0),
            )
            cr = jax.lax.dynamic_update_slice(
                latent_cache["k_rope"],
                k_rope[:, :, 0].astype(latent_cache["k_rope"].dtype),
                (0, off, 0),
            )
            q_pos = jnp.broadcast_to(off + jnp.arange(S)[None, :], (B, S))
        new_cache = {"latent": cl, "k_rope": cr}
        if isinstance(off, int) and off == 0 and S > 1:
            # whole-sequence prefill: exact over the fresh latent; use the
            # materialized (chunked) path and just persist the cache.
            k_nope = (latent @ params["w_uk"]).reshape(B, S, H, dn)
            v = (latent @ params["w_uv"]).reshape(B, S, H, dv)
            k_rope_b = jnp.broadcast_to(k_rope, (B, S, H, dr))
            qh = jnp.concatenate([q_nope, q_rope], axis=-1)
            kh = jnp.concatenate([k_nope, k_rope_b], axis=-1)
            if cfg.q_chunk and S > cfg.q_chunk:
                out = chunked_attention(
                    qh, kh, v, causal=causal, q_chunk=cfg.q_chunk,
                    kv_chunk=cfg.kv_chunk, scale=scale,
                )
            else:
                out = dot_attention(qh, kh, v, causal=causal, scale=scale)
            out = out.reshape(B, S, H * dv) @ params["wo"]
            return out, new_cache
        S_kv = cl.shape[1]
        # absorbed scores: q_nope' = q_nope @ W_uk  (per head: dn x r)
        w_uk = params["w_uk"].reshape(r, H, dn)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)  # (B, S, H, r)
        s_lat = jnp.einsum("bshr,btr->bhst", q_lat, cl.astype(x.dtype))
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope, cr.astype(x.dtype))
        logits = (s_lat + s_rope).astype(jnp.float32) * scale
        kv_pos = jnp.arange(S_kv)
        ok = kv_pos[None, None, :] <= q_pos[:, :, None]  # (B, S, S_kv)
        if not causal:
            ok = kv_pos[None, None, :] <= q_pos[:, -1:, None]
        logits = jnp.where(ok[:, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        # absorbed values: out_latent = probs @ latent; then through W_uv
        out_lat = jnp.einsum("bhst,btr->bshr", probs, cl.astype(x.dtype))
        w_uv = params["w_uv"].reshape(r, H, dv)
        out = jnp.einsum("bshr,rhd->bshd", out_lat, w_uv)  # (B, S, H, dv)
        out = out.reshape(B, S, H * dv) @ params["wo"]
        return out, new_cache

    # train / prefill: materialize per-head K, V from the latent
    k_nope = (latent @ params["w_uk"]).reshape(B, S, H, dn)
    v = (latent @ params["w_uv"]).reshape(B, S, H, dv)
    k_rope_b = jnp.broadcast_to(k_rope, (B, S, H, dr))
    qh = jnp.concatenate([q_nope, q_rope], axis=-1)
    kh = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    if cfg.q_chunk and S > cfg.q_chunk:
        out = chunked_attention(
            qh, kh, v, causal=causal, q_chunk=cfg.q_chunk,
            kv_chunk=cfg.kv_chunk, scale=scale,
        )
    else:
        out = dot_attention(qh, kh, v, causal=causal, scale=scale)
    out = out.reshape(B, S, H * dv) @ params["wo"]
    return out, None


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init_dense(ks[0], (d_model, d_ff), dtype),
        "w_up": _init_dense(ks[1], (d_model, d_ff), dtype),
        "w_down": _init_dense(ks[2], (d_ff, d_model), dtype),
    }


def mlp_apply(params, x):
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]

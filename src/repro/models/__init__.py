"""Assigned-architecture model zoo (pure-pytree functional JAX models)."""

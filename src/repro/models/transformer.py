"""Decoder-only LM covering the five assigned LM architectures.

One config class spans: dense GQA (CodeQwen/Qwen2/SmolLM — qkv_bias toggles
the Qwen variant), DeepSeekMoE (fine-grained experts + shared + first-k-dense)
and DeepSeek-V2-Lite (MLA attention + MoE).  Layers are scanned (stacked
params) so HLO size — and hence dry-run compile time on 512 fake devices — is
O(1) in depth; remat is a config flag applied to the scanned block.

TP sharding happens through ``ShardingCtx.constrain`` on activations; weight
PartitionSpecs come from ``param_specs`` below (consumed by launch/dryrun.py
and train/train_step.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding_rules import NULL_CTX, ShardingCtx
from repro.models.layers import (
    AttentionConfig,
    MLAConfig,
    _init_dense,
    attention_apply,
    attention_init,
    mla_apply,
    mla_init,
    mlp_apply,
    mlp_init,
    rms_norm,
    rms_norm_init,
)
from repro.models.moe import MoEConfig, moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    head_dim: int = 64
    d_ff: int = 3072
    vocab: int = 32_000
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    attention: str = "gqa"  # 'gqa' | 'mla'
    mla_kv_lora_rank: int = 512
    mla_qk_nope_head_dim: int = 128
    mla_qk_rope_head_dim: int = 64
    mla_v_head_dim: int = 128
    moe: Optional[MoEConfig] = None
    q_chunk: int = 0  # enable chunked (flash-style) attention for long seqs
    kv_chunk: int = 2048
    remat: bool = False
    # two-level (sqrt-L) remat: scan G groups x K layers, saving only group
    # boundaries (K=0 disables).  Cuts the saved carry stack from L x (B,S,d)
    # to (G + K) x (B,S,d) — 5 GiB -> 1.3 GiB on qwen2-72b train_4k.
    remat_group: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def attn_config(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
            q_chunk=self.q_chunk,
            kv_chunk=self.kv_chunk,
        )

    @property
    def mla_config(self) -> MLAConfig:
        return MLAConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            kv_lora_rank=self.mla_kv_lora_rank,
            qk_nope_head_dim=self.mla_qk_nope_head_dim,
            qk_rope_head_dim=self.mla_qk_rope_head_dim,
            v_head_dim=self.mla_v_head_dim,
            rope_theta=self.rope_theta,
            q_chunk=self.q_chunk,
            kv_chunk=self.kv_chunk,
        )

    @property
    def n_dense_layers(self) -> int:
        return self.moe.first_k_dense if self.moe else self.n_layers

    @property
    def n_scan_layers(self) -> int:
        return self.n_layers - (self.moe.first_k_dense if self.moe else 0)

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def num_params(self) -> int:
        """Total parameter count N (used for MODEL_FLOPS = 6 N D)."""
        d, V = self.d_model, self.vocab
        if self.attention == "mla":
            qk = self.mla_qk_nope_head_dim + self.mla_qk_rope_head_dim
            attn = (
                d * self.n_heads * qk
                + d * (self.mla_kv_lora_rank + self.mla_qk_rope_head_dim)
                + self.mla_kv_lora_rank
                * self.n_heads
                * (self.mla_qk_nope_head_dim + self.mla_v_head_dim)
                + self.n_heads * self.mla_v_head_dim * d
            )
        else:
            attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        dense_ff = 3 * d * self.d_ff
        per_dense = attn + dense_ff
        total = V * d * (1 if self.tie_embeddings else 2)
        if self.moe is None:
            return total + self.n_layers * per_dense
        m = self.moe
        moe_ff = 3 * d * m.d_ff_expert * (m.num_experts + m.n_shared) + d * m.num_experts
        total += m.first_k_dense * per_dense
        total += (self.n_layers - m.first_k_dense) * (attn + moe_ff)
        return total

    def num_active_params(self) -> int:
        """Activated parameters per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.num_params()
        d, V = self.d_model, self.vocab
        if self.attention == "mla":
            qk = self.mla_qk_nope_head_dim + self.mla_qk_rope_head_dim
            attn = (
                d * self.n_heads * qk
                + d * (self.mla_kv_lora_rank + self.mla_qk_rope_head_dim)
                + self.mla_kv_lora_rank
                * self.n_heads
                * (self.mla_qk_nope_head_dim + self.mla_v_head_dim)
                + self.n_heads * self.mla_v_head_dim * d
            )
        else:
            attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        m = self.moe
        active_ff = 3 * d * m.d_ff_expert * (m.top_k + m.n_shared) + d * m.num_experts
        total = V * d * (1 if self.tie_embeddings else 2)
        total += m.first_k_dense * (attn + 3 * d * self.d_ff)
        total += (self.n_layers - m.first_k_dense) * (attn + active_ff)
        return total


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(key, cfg: TransformerConfig, moe_layer: bool, dtype):
    ka, km = jax.random.split(key)
    p = {"attn_norm": rms_norm_init(cfg.d_model, dtype),
         "mlp_norm": rms_norm_init(cfg.d_model, dtype)}
    if cfg.attention == "mla":
        p["attn"] = mla_init(ka, cfg.mla_config, dtype)
    else:
        p["attn"] = attention_init(ka, cfg.attn_config, dtype)
    if moe_layer:
        p["moe"] = moe_init(km, cfg.d_model, cfg.moe, dtype)
    else:
        p["mlp"] = mlp_init(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def init(key, cfg: TransformerConfig):
    dtype = cfg.dtype()
    k_embed, k_dense, k_scan, k_head = jax.random.split(key, 4)
    params = {
        "embed": _init_dense(k_embed, (cfg.vocab, cfg.d_model), dtype, scale=0.02),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init_dense(k_head, (cfg.d_model, cfg.vocab), dtype)
    if cfg.n_dense_layers and cfg.moe is not None:
        keys = jax.random.split(k_dense, cfg.n_dense_layers)
        params["dense_blocks"] = jax.vmap(
            lambda k: _block_init(k, cfg, moe_layer=False, dtype=dtype)
        )(keys)
    n_scan = cfg.n_scan_layers
    keys = jax.random.split(k_scan, n_scan)
    params["blocks"] = jax.vmap(
        lambda k: _block_init(k, cfg, moe_layer=cfg.moe is not None, dtype=dtype)
    )(keys)
    return params


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def _block_apply(cfg: TransformerConfig, bp, x, positions, cache, cache_offset,
                 ctx: ShardingCtx):
    """One transformer block.  cache: per-layer slice or None."""
    h = rms_norm(bp["attn_norm"], x)
    if cfg.attention == "mla":
        attn_out, new_cache = mla_apply(
            bp["attn"], cfg.mla_config, h, positions=positions,
            latent_cache=cache, cache_offset=cache_offset,
        )
    else:
        attn_out, new_cache = attention_apply(
            bp["attn"], cfg.attn_config, h, positions=positions,
            kv_cache=cache, cache_offset=cache_offset,
        )
    x = x + attn_out
    x = ctx.constrain(x, "batch", None, None)
    h = rms_norm(bp["mlp_norm"], x)
    if "moe" in bp:
        # decode (serving, one token) runs dropless — capacity drops would
        # make decode diverge from prefill/train numerics.
        dropless = cache is not None and x.shape[1] == 1
        ff, aux = moe_apply(
            bp["moe"], cfg.moe, h, ctx=ctx,
            capacity_factor=-1.0 if dropless else 0.0,
        )
    else:
        ff, aux = mlp_apply(bp["mlp"], h), jnp.float32(0.0)
    x = x + ff
    x = ctx.constrain(x, "batch", None, None)
    return x, new_cache, aux


def apply(
    params,
    cfg: TransformerConfig,
    tokens: jnp.ndarray,
    *,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[dict] = None,
    cache_offset=None,
    ctx: ShardingCtx = NULL_CTX,
):
    """tokens (B, S) int32 -> (logits (B, S, V), new_cache, aux_loss).

    cache: stacked over layers, e.g. {"k": (L, B, Smax, KV, hd), ...}; pass
    ``make_cache`` output.  cache_offset: scalar position of tokens[:, 0].
    """
    B, S = tokens.shape
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    if positions is None:
        start = cache_offset if cache_offset is not None else 0
        if hasattr(start, "ndim") and start.ndim == 1:  # per-row offsets
            positions = start[:, None] + jnp.arange(S)[None, :]
        else:
            positions = start + jnp.arange(S)
    x = params["embed"][tokens].astype(compute_dtype)
    x = ctx.constrain(x, "batch", None, None)
    aux_total = jnp.float32(0.0)

    # unscanned dense head layers (DeepSeek first_k_dense)
    if "dense_blocks" in params:
        n_dense = cfg.n_dense_layers
        for l in range(n_dense):
            bp = jax.tree.map(lambda a, l=l: a[l], params["dense_blocks"])
            layer_cache = (
                jax.tree.map(lambda a, l=l: a[l], cache)
                if cache is not None else None
            )
            x, new_c, aux = _block_apply(
                cfg, bp, x, positions, layer_cache, cache_offset, ctx
            )
            aux_total += aux
            if cache is not None:
                cache = jax.tree.map(
                    lambda full, new, l=l: full.at[l].set(new), cache, new_c
                )

    # scanned stack
    def scan_body(carry, xs):
        x, aux_total = carry
        if cache is not None:
            bp, layer_cache = xs
        else:
            bp, layer_cache = xs, None
        x, new_c, aux = _block_apply(
            cfg, bp, x, positions, layer_cache, cache_offset, ctx
        )
        return (x, aux_total + aux), new_c

    body = scan_body
    if cfg.remat:
        # prevent_cse=False: scan already rules out CSE across iterations;
        # the default barriers add copies of the carry stack.
        body = jax.checkpoint(
            scan_body,
            policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False,
        )
    n_dense = cfg.n_dense_layers if "dense_blocks" in params else 0
    scan_cache = (
        jax.tree.map(lambda a: a[n_dense:], cache) if cache is not None else None
    )
    xs = (params["blocks"], scan_cache) if cache is not None else params["blocks"]
    K = cfg.remat_group
    if cfg.remat and K > 1 and cfg.n_scan_layers % K == 0 and cache is None:
        # two-level scan: outer over G groups (saves boundaries), inner over
        # K layers (rematerialized inside the checkpointed group body).
        G = cfg.n_scan_layers // K
        xs_g = jax.tree.map(
            lambda a: a.reshape(G, K, *a.shape[1:]), params["blocks"]
        )

        def group_body(carry, group_params):
            return jax.lax.scan(body, carry, group_params)

        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False,
        )
        (x, aux_total), _ = jax.lax.scan(group_body, (x, aux_total), xs_g)
        new_scan_cache = None
    else:
        (x, aux_total), new_scan_cache = jax.lax.scan(body, (x, aux_total), xs)

    new_cache = None
    if cache is not None:
        new_cache = jax.tree.map(
            lambda full, new: full.at[n_dense:].set(new), cache, new_scan_cache
        ) if n_dense else new_scan_cache

    x = rms_norm(params["final_norm"], x)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(compute_dtype)
    logits = x @ head
    logits = ctx.constrain(logits, "batch", None, "vocab")
    return logits, new_cache, aux_total


def make_cache(cfg: TransformerConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Stacked-over-layers cache pytree (zeros; dry-run uses shape structs)."""
    L = cfg.n_layers
    if cfg.attention == "mla":
        return {
            "latent": jnp.zeros((L, batch, max_seq, cfg.mla_kv_lora_rank), dtype),
            "k_rope": jnp.zeros((L, batch, max_seq, cfg.mla_qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# parameter PartitionSpecs (consumed by dryrun / train)
# ---------------------------------------------------------------------------


def param_specs(params, cfg: TransformerConfig, ctx: ShardingCtx, *, fsdp: bool = True):
    """PartitionSpec pytree matching ``params``.

    TP (Megatron): wq/wk/wv/w_gate/w_up column-sharded on 'model'; wo/w_down
    row-sharded; embed/lm_head vocab-sharded; experts sharded on E.
    FSDP: the OTHER matrix dim additionally shards over 'data' — required for
    the 72B cells (144 GB of bf16 weights / 256 chips; TP-16 alone leaves
    9 GB/chip of weights and the optimizer would never fit).  GSPMD turns the
    per-layer weight use inside scan into an all-gather per layer = classic
    FSDP prefetch.
    """
    from jax.sharding import PartitionSpec as P

    M = ctx.spec("model")[0]  # mesh axis name (or None off-mesh)
    D = ctx.spec("fsdp")[0] if fsdp else None  # weight-sharding axis

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = names[-1]
        nd = leaf.ndim
        stacked = "blocks" in names[0] if names else False
        lead = (None,) if stacked else ()

        def mk(*tail):
            return P(*(lead + tail))

        if name in ("wq", "wk", "wv", "w_gate", "w_up"):
            if nd - len(lead) == 3:  # expert-stacked (E, d, f)
                return mk(M, D, None)
            return mk(D, M)
        if name in ("wo", "w_down"):
            if nd - len(lead) == 3:  # (E, f, d)
                return mk(M, D, None)
            return mk(M, D)
        if name in ("bq", "bk", "bv"):
            return mk(M)
        if name == "embed":
            # no-TP: shard the vocab rows, not d (a d-sharded gather output
            # trips the SPMD partitioner inside the microbatch scan)
            return P(M, D) if M is not None else P(D, None)
        if name == "lm_head":
            return P(D, M)
        if name == "w_dkv":
            return mk(D, None)  # latent down-proj: small, fsdp only
        if name == "w_krope":
            return mk(None, None)
        if name in ("w_uk", "w_uv"):
            return mk(D, M)  # up-proj column = heads
        if name == "router":
            return mk(None, None)
        return mk(*([None] * (nd - len(lead))))

    return jax.tree_util.tree_map_with_path(spec_for, params)

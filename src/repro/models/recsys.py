"""RecSys architectures: AutoInt, DIN, SASRec, xDeepFM.

The shared substrate is the **embedding layer over huge sparse tables** —
JAX has no nn.EmbeddingBag, so we build it: ``jnp.take`` over row-sharded
tables + ``jax.ops.segment_sum`` for multi-hot bags.  Tables are row-hash-
sharded over the 'model' mesh axis — this IS the LANNS level-1 sharding
applied to embedding tables (DESIGN.md §7): lookup fans out to every shard
and partial rows psum back (GSPMD inserts the collective from the specs).

  AutoInt  (arXiv:1810.11921): field embeddings -> 3 residual self-attention
           layers (2 heads, d=32) -> concat -> logit.
  DIN      (arXiv:1706.06978): target attention over user behaviour history
           with the [h, t, h-t, h*t] MLP scorer -> 200-80 MLP.
  SASRec   (arXiv:1808.09781): causal 2-block transformer over the item
           sequence; next-item logits = hidden @ item_embeddings^T (the
           retrieval_cand cell scores 1M candidates with the LANNS kernel).
  xDeepFM  (arXiv:1803.05170): CIN (outer-product feature maps compressed by
           1x1 conv, 200-200-200) + deep MLP (400-400) + linear.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding_rules import NULL_CTX, ShardingCtx
from repro.models.layers import _init_dense


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------


def embedding_table_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return _init_dense(key, (vocab, dim), dtype, scale=0.01)


def embedding_lookup(table, ids, ctx: ShardingCtx = NULL_CTX):
    """Single-hot lookup: ids (...,) -> (..., dim).  Row-sharded table."""
    out = jnp.take(table, jnp.clip(ids, 0), axis=0)
    return jnp.where((ids >= 0)[..., None], out, 0.0)


def embedding_bag(table, ids, segment_ids, num_segments: int, mode: str = "sum"):
    """EmbeddingBag: gather rows then segment-reduce.

    ids (nnz,) row indices (-1 = padding), segment_ids (nnz,) output bag per
    id, -> (num_segments, dim).  mode in {'sum', 'mean'}.
    """
    rows = jnp.take(table, jnp.clip(ids, 0), axis=0)
    valid = (ids >= 0).astype(rows.dtype)[:, None]
    rows = rows * valid
    seg = jnp.where(ids >= 0, segment_ids, num_segments)  # drop padding
    out = jax.ops.segment_sum(rows, seg, num_segments=num_segments + 1)[:num_segments]
    if mode == "mean":
        cnt = jax.ops.segment_sum(valid[:, 0], seg, num_segments=num_segments + 1)[
            :num_segments
        ]
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out


def field_offsets(vocab_sizes) -> np.ndarray:
    """Per-field row offsets into the fused table (static, config-derived —
    NOT a parameter, so grads stay all-float)."""
    return np.concatenate([[0], np.cumsum(vocab_sizes)[:-1]]).astype(np.int32)


def multi_field_lookup(
    tables, sparse_ids, vocab_sizes, ctx: ShardingCtx = NULL_CTX
):
    """Per-field single-hot lookup: sparse_ids (B, F) against a single fused
    table (sum_vocab, dim) with per-field row offsets — one big gather instead
    of F small ones (the TPU-friendly layout; FBGEMM TBE does the same).

    tables: {"table": (total_rows, dim)}
    """
    offs = jnp.asarray(field_offsets(vocab_sizes))
    flat = sparse_ids + offs[None, :]
    out = jnp.take(tables["table"], jnp.clip(flat, 0), axis=0)  # (B, F, dim)
    return jnp.where((sparse_ids >= 0)[..., None], out, 0.0)


def fused_tables_init(key, vocab_sizes, dim: int, dtype=jnp.float32):
    # rows padded to a multiple of 256 so the table row-shards evenly over
    # any production mesh axis combination (the pad rows are dead weight of
    # < 0.001% — same trick as padded vocab in LM heads).
    total = int(np.sum(vocab_sizes))
    total_pad = -(-total // 512) * 512
    return {"table": embedding_table_init(key, total_pad, dim, dtype)}


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": _init_dense(ks[i], (dims[i], dims[i + 1]), dtype),
         "b": jnp.zeros((dims[i + 1],), dtype)}
        for i in range(len(dims) - 1)
    ]


def _mlp_apply(layers, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# AutoInt
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str = "autoint"
    n_sparse: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    vocab_sizes: tuple = ()
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def num_params(self):
        emb = int(np.sum(self.vocab_sizes)) * self.embed_dim
        d_in = self.embed_dim
        per = 3 * d_in * self.d_attn * self.n_heads + d_in * self.d_attn * self.n_heads
        # layer 0 maps embed_dim; later layers map d_attn*n_heads
        dh = self.d_attn * self.n_heads
        per_rest = 3 * dh * dh + dh * dh
        return emb + per + (self.n_attn_layers - 1) * per_rest + self.n_sparse * dh


def autoint_init(key, cfg: AutoIntConfig):
    dtype = cfg.dtype()
    keys = jax.random.split(key, 2 + cfg.n_attn_layers)
    params = {"tables": fused_tables_init(keys[0], cfg.vocab_sizes, cfg.embed_dim, dtype)}
    d = cfg.embed_dim
    dh = cfg.d_attn * cfg.n_heads
    layers = []
    for i in range(cfg.n_attn_layers):
        kk = jax.random.split(keys[1 + i], 4)
        d_in = d if i == 0 else dh
        layers.append(
            {
                "wq": _init_dense(kk[0], (d_in, dh), dtype),
                "wk": _init_dense(kk[1], (d_in, dh), dtype),
                "wv": _init_dense(kk[2], (d_in, dh), dtype),
                "w_res": _init_dense(kk[3], (d_in, dh), dtype),
            }
        )
    params["attn_layers"] = layers
    params["head"] = _init_dense(keys[-1], (cfg.n_sparse * dh, 1), dtype)
    return params


def autoint_apply(params, cfg: AutoIntConfig, sparse_ids, ctx: ShardingCtx = NULL_CTX):
    """sparse_ids (B, F) -> logits (B,)."""
    x = multi_field_lookup(params["tables"], sparse_ids, cfg.vocab_sizes, ctx)  # (B, F, d)
    x = ctx.constrain(x, "batch", None, None)
    H, da = cfg.n_heads, cfg.d_attn
    for lp in params["attn_layers"]:
        B, F, _ = x.shape
        q = (x @ lp["wq"]).reshape(B, F, H, da)
        k = (x @ lp["wk"]).reshape(B, F, H, da)
        v = (x @ lp["wv"]).reshape(B, F, H, da)
        s = jnp.einsum("bfhd,bghd->bhfg", q, k).astype(jnp.float32) / np.sqrt(da)
        p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhfg,bghd->bfhd", p, v).reshape(B, F, H * da)
        x = jax.nn.relu(o + x @ lp["w_res"])
    B = x.shape[0]
    return (x.reshape(B, -1) @ params["head"])[:, 0]


# ---------------------------------------------------------------------------
# DIN
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    n_items: int = 1_000_000
    n_context: int = 8  # additional context/profile fields
    context_vocab: int = 100_000
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def num_params(self):
        d = self.embed_dim
        emb = self.n_items * d + self.n_context * self.context_vocab * d
        att_in = 4 * d
        att = att_in * self.attn_mlp[0] + self.attn_mlp[0] * self.attn_mlp[1] + self.attn_mlp[1]
        mlp_in = d * 2 + self.n_context * d
        mlp = mlp_in * self.mlp[0] + self.mlp[0] * self.mlp[1] + self.mlp[1]
        return emb + att + mlp


def din_init(key, cfg: DINConfig):
    dtype = cfg.dtype()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.embed_dim
    return {
        "item_table": embedding_table_init(k1, cfg.n_items, d, dtype),
        "ctx_tables": fused_tables_init(
            k2, [cfg.context_vocab] * cfg.n_context, d, dtype
        ),
        "att_mlp": _mlp_init(k3, (4 * d,) + cfg.attn_mlp + (1,), dtype),
        "mlp": _mlp_init(k4, (2 * d + cfg.n_context * d,) + cfg.mlp + (1,), dtype),
    }


def din_apply(
    params, cfg: DINConfig, *, history, hist_len, target_item, context_ids,
    ctx: ShardingCtx = NULL_CTX,
):
    """history (B, T) item ids; target_item (B,); context_ids (B, n_context).
    -> logits (B,).  Target attention: a(h, t) = MLP([h, t, h-t, h*t])."""
    h = embedding_lookup(params["item_table"], history, ctx)  # (B, T, d)
    t = embedding_lookup(params["item_table"], target_item, ctx)  # (B, d)
    h = ctx.constrain(h, "batch", None, None)
    tb = jnp.broadcast_to(t[:, None, :], h.shape)
    feats = jnp.concatenate([h, tb, h - tb, h * tb], axis=-1)
    scores = _mlp_apply(params["att_mlp"], feats, act=jax.nn.sigmoid)[..., 0]
    T = h.shape[1]
    mask = jnp.arange(T)[None, :] < hist_len[:, None]
    scores = jnp.where(mask, scores.astype(jnp.float32), -jnp.inf)
    # DIN uses un-normalized sigmoid weights in the paper; we keep softmax
    # masking for numerical sanity but scale by hist length (sum-pool like).
    w = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    user = jnp.einsum("bt,btd->bd", w, h)
    c = multi_field_lookup(
        params["ctx_tables"], context_ids, [cfg.context_vocab] * cfg.n_context, ctx
    )  # (B, C, d)
    B = user.shape[0]
    feat = jnp.concatenate([user, t, c.reshape(B, -1)], axis=-1)
    return _mlp_apply(params["mlp"], feat)[:, 0]


# ---------------------------------------------------------------------------
# SASRec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    n_items: int = 2_000_000
    dropout: float = 0.0
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def num_params(self):
        d = self.embed_dim
        emb = self.n_items * d + self.seq_len * d
        per = 4 * d * d + 2 * d * d + 4 * d  # attn + pointwise ffn + norms
        return emb + self.n_blocks * per


def sasrec_init(key, cfg: SASRecConfig):
    dtype = cfg.dtype()
    keys = jax.random.split(key, 2 + cfg.n_blocks)
    d = cfg.embed_dim
    params = {
        "item_table": embedding_table_init(keys[0], cfg.n_items, d, dtype),
        "pos_table": embedding_table_init(keys[1], cfg.seq_len, d, dtype),
        "blocks": [],
    }
    blocks = []
    for b in range(cfg.n_blocks):
        kk = jax.random.split(keys[2 + b], 6)
        blocks.append(
            {
                "wq": _init_dense(kk[0], (d, d), dtype),
                "wk": _init_dense(kk[1], (d, d), dtype),
                "wv": _init_dense(kk[2], (d, d), dtype),
                "wo": _init_dense(kk[3], (d, d), dtype),
                "ff1": _init_dense(kk[4], (d, d), dtype),
                "ff2": _init_dense(kk[5], (d, d), dtype),
                "ln1": jnp.ones((d,), dtype),
                "ln2": jnp.ones((d,), dtype),
            }
        )
    params["blocks"] = blocks
    return params


def _ln(x, scale):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)


def sasrec_encode(params, cfg: SASRecConfig, item_seq, ctx: ShardingCtx = NULL_CTX):
    """item_seq (B, T) -> hidden (B, T, d).  Causal self-attention."""
    B, T = item_seq.shape
    x = embedding_lookup(params["item_table"], item_seq, ctx)
    x = x * np.sqrt(cfg.embed_dim) + params["pos_table"][jnp.arange(T)][None]
    x = ctx.constrain(x, "batch", None, None)
    H = cfg.n_heads
    d = cfg.embed_dim
    dh = d // H
    causal = jnp.where(
        jnp.arange(T)[None, :] <= jnp.arange(T)[:, None], 0.0, -jnp.inf
    ).astype(jnp.float32)
    pad = (item_seq >= 0)
    for bp in params["blocks"]:
        h = _ln(x, bp["ln1"])
        q = (h @ bp["wq"]).reshape(B, T, H, dh)
        k = (h @ bp["wk"]).reshape(B, T, H, dh)
        v = (h @ bp["wv"]).reshape(B, T, H, dh)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / np.sqrt(dh)
        s = s + causal[None, None]
        s = jnp.where(pad[:, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, T, d)
        x = x + o @ bp["wo"]
        h = _ln(x, bp["ln2"])
        x = x + jax.nn.relu(h @ bp["ff1"]) @ bp["ff2"]
    return jnp.where(pad[..., None], x, 0.0)


def sasrec_apply(params, cfg: SASRecConfig, item_seq, ctx: ShardingCtx = NULL_CTX):
    """Full-vocab forward: logits over the item vocab for every position.
    (B, T, n_items) — ONLY for small-vocab evaluation; training at 10M items
    uses ``sasrec_sampled_logits`` (full logits would be B*T*10M)."""
    hidden = sasrec_encode(params, cfg, item_seq, ctx)
    logits = hidden @ params["item_table"].T
    return ctx.constrain(logits, "batch", None, "vocab")


def sasrec_sampled_logits(
    params, cfg: SASRecConfig, item_seq, pos_items, neg_items,
    ctx: ShardingCtx = NULL_CTX,
):
    """SASRec's actual training objective (paper eq. 6): BCE on the positive
    next item vs one sampled negative per position.  Returns
    (pos_scores (B, T), neg_scores (B, T))."""
    hidden = sasrec_encode(params, cfg, item_seq, ctx)
    pe = embedding_lookup(params["item_table"], pos_items, ctx)
    ne = embedding_lookup(params["item_table"], neg_items, ctx)
    pos = jnp.sum(hidden * pe, axis=-1)
    neg = jnp.sum(hidden * ne, axis=-1)
    return pos, neg


def sasrec_score_candidates(
    params, cfg: SASRecConfig, item_seq, candidates, ctx: ShardingCtx = NULL_CTX
):
    """Serving: score (B?, n_cand) candidate items against the final hidden
    state — the retrieval_cand cell (batched dot, not a loop; for the 1M-
    candidate cell this routes through the LANNS distance kernel)."""
    hidden = sasrec_encode(params, cfg, item_seq, ctx)
    last = hidden[:, -1]  # (B, d)
    cand = embedding_lookup(params["item_table"], candidates, ctx)  # (C, d) or (B, C, d)
    if cand.ndim == 2:
        return last @ cand.T
    return jnp.einsum("bd,bcd->bc", last, cand)


# ---------------------------------------------------------------------------
# xDeepFM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_sparse: int = 39
    embed_dim: int = 10
    cin_layers: tuple = (200, 200, 200)
    mlp: tuple = (400, 400)
    vocab_sizes: tuple = ()
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def num_params(self):
        emb = int(np.sum(self.vocab_sizes)) * self.embed_dim
        lin = int(np.sum(self.vocab_sizes))
        cin, hk_prev = 0, self.n_sparse
        for hk in self.cin_layers:
            cin += hk_prev * self.n_sparse * hk
            hk_prev = hk
        cin_out = sum(self.cin_layers)
        d_mlp_in = self.n_sparse * self.embed_dim
        mlp = 0
        dims = (d_mlp_in,) + self.mlp + (1,)
        for i in range(len(dims) - 1):
            mlp += dims[i] * dims[i + 1] + dims[i + 1]
        return emb + lin + cin + cin_out + mlp


def xdeepfm_init(key, cfg: XDeepFMConfig):
    dtype = cfg.dtype()
    keys = jax.random.split(key, 4 + len(cfg.cin_layers))
    params = {
        "tables": fused_tables_init(keys[0], cfg.vocab_sizes, cfg.embed_dim, dtype),
        "linear": fused_tables_init(keys[1], cfg.vocab_sizes, 1, dtype),
        "mlp": _mlp_init(
            keys[2], (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp + (1,), dtype
        ),
        "cin_head": _init_dense(keys[3], (sum(cfg.cin_layers), 1), dtype),
    }
    cin = []
    hk_prev = cfg.n_sparse
    for li, hk in enumerate(cfg.cin_layers):
        cin.append(
            _init_dense(keys[4 + li], (hk_prev * cfg.n_sparse, hk), dtype)
        )
        hk_prev = hk
    params["cin"] = cin
    return params


def xdeepfm_apply(params, cfg: XDeepFMConfig, sparse_ids, ctx: ShardingCtx = NULL_CTX):
    """sparse_ids (B, F) -> logits (B,).

    CIN layer k: X^k_{h} = sum_{i,j} W^k_{h,i,j} (X^{k-1}_i * X^0_j) computed
    as an outer product over feature maps contracted against the compress
    weights — einsum form, no explicit (B, H_{k-1}*F, D) materialization."""
    x0 = multi_field_lookup(params["tables"], sparse_ids, cfg.vocab_sizes, ctx)  # (B, F, D)
    x0 = ctx.constrain(x0, "batch", None, None)
    B, F, D = x0.shape
    # linear term
    lin = multi_field_lookup(params["linear"], sparse_ids, cfg.vocab_sizes, ctx)  # (B, F, 1)
    logit = lin.sum(axis=(1, 2))
    # CIN
    xk = x0
    cin_outs = []
    for w in params["cin"]:
        hk_prev = xk.shape[1]
        inter = jnp.einsum("bhd,bfd->bhfd", xk, x0)  # (B, Hk-1, F, D)
        xk = jnp.einsum(
            "bhfd,hfk->bkd", inter, w.reshape(hk_prev, F, -1)
        )  # (B, Hk, D)
        cin_outs.append(xk.sum(-1))  # sum pool over D
    logit = logit + (jnp.concatenate(cin_outs, axis=-1) @ params["cin_head"])[:, 0]
    # deep MLP
    logit = logit + _mlp_apply(params["mlp"], x0.reshape(B, -1))[:, 0]
    return logit

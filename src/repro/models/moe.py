"""DeepSeek-style Mixture-of-Experts FFN (fine-grained + shared experts).

DeepSeekMoE (arXiv:2401.06066): many small routed experts (top-6 of 64 at
expert d_ff 1408) plus always-on shared experts; first ``first_k_dense``
layers stay dense.  Routing is softmax -> top-k (optionally renormalized),
with the standard switch-style load-balance auxiliary loss.

Dispatch is the sort-based capacity implementation (MaxText/GShard "dropping"
style, but without the (T, E) one-hot): ranks-within-expert come from an
argsort + run-start subtraction, tokens scatter into an (E, C, d) buffer that
is sharded over the ``model`` axis (expert parallelism), expert FFNs run as a
batched einsum against E-sharded weights, and the combine scatter-adds back to
token space (GSPMD turns that into a reduce over the expert axis).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding_rules import NULL_CTX, ShardingCtx
from repro.models.layers import _init_dense


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 64
    top_k: int = 6
    d_ff_expert: int = 1408
    n_shared: int = 2
    first_k_dense: int = 1
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001
    normalize_topk: bool = False
    routed_scaling: float = 1.0


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    E, f = cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": _init_dense(ks[0], (d_model, E), jnp.float32, scale=0.02),
        "w_gate": _init_dense(ks[1], (E, d_model, f), dtype),
        "w_up": _init_dense(ks[2], (E, d_model, f), dtype),
        "w_down": _init_dense(ks[3], (E, f, d_model), dtype),
    }
    if cfg.n_shared:
        from repro.models.layers import mlp_init

        p["shared"] = mlp_init(ks[4], d_model, cfg.n_shared * f, dtype)
    return p


def _ranks_within_expert(flat_e: jnp.ndarray, num_experts: int):
    """rank[i] = #earlier assignments with the same expert id.  Sort-based:
    no (T*k, E) one-hot materialization."""
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(tk, dtype=jnp.int32)
    change = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(change, idx, 0))
    rank_sorted = idx - run_start
    rank = jnp.zeros((tk,), jnp.int32).at[order].set(rank_sorted)
    return rank


def moe_apply(
    params,
    cfg: MoEConfig,
    x: jnp.ndarray,
    *,
    ctx: ShardingCtx = NULL_CTX,
    capacity_factor: float = 0.0,
):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    capacity_factor overrides cfg (0 = use config).  Tokens beyond an
    expert's capacity are dropped for that expert (they keep their other
    top-k routes and the shared experts).

    On a mesh, the routed-expert interior runs under shard_map
    (``_moe_routed_shard_map``): GSPMD's handling of the pjit-constrained
    dispatch all-gathered the (E, C, d) token buffers and the routing index
    arrays globally (~2.4 GiB/layer of avoidable collectives on
    deepseek-moe-16b train_4k); the explicit schedule computes routing
    replicated per model column, dispatches only to local experts, and
    combines with ONE psum of (T, d) partials.
    """
    if ctx.mesh is not None and not _JUST_LOCAL:
        routed, aux = _moe_routed_shard_map(
            params, cfg, x, ctx, capacity_factor
        )
        if "shared" in params:
            from repro.models.layers import mlp_apply

            routed = routed + mlp_apply(
                params["shared"], x.reshape(-1, x.shape[-1])
            ).reshape(x.shape)
        return routed, aux
    return _moe_apply_local(params, cfg, x, ctx, capacity_factor)


_JUST_LOCAL = False  # test hook


def _moe_routed_shard_map(params, cfg, x, ctx: ShardingCtx, capacity_factor):
    """Expert-parallel routed experts via an explicit shard_map schedule."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    expert_axes = tuple(
        a for a in ctx.rules.get("expert", ()) if a in mesh.shape
    )
    batch_axes = tuple(
        a for a in ctx.rules.get("batch", ()) if a in mesh.shape
    )
    n_batch_lanes = 1
    for a in batch_axes:
        n_batch_lanes *= mesh.shape[a]
    if x.shape[0] % max(n_batch_lanes, 1):
        batch_axes = ()  # tiny batch (long-decode B=1): replicate tokens
    if not expert_axes:
        return _moe_apply_local(
            params, cfg, x, ctx, capacity_factor, include_shared=False,
        )
    other_axes = tuple(
        a for a in mesh.shape if a not in expert_axes + batch_axes
    )
    ep = 1
    for a in expert_axes:
        ep *= mesh.shape[a]
    E_loc = cfg.num_experts // ep
    routed_params = {
        "router": params["router"],
        "w_gate": params["w_gate"],
        "w_up": params["w_up"],
        "w_down": params["w_down"],
    }
    x_spec = P(batch_axes, None, None) if batch_axes else P(None, None, None)
    in_specs = (
        {
            "router": P(),
            "w_gate": P(expert_axes, None, None),
            "w_up": P(expert_axes, None, None),
            "w_down": P(expert_axes, None, None),
        },
        x_spec,
    )

    def local_moe(p, x_loc):
        e0 = jnp.int32(0)
        stride = E_loc
        for a in reversed(expert_axes):
            e0 = e0 + jax.lax.axis_index(a) * stride
            stride = stride * mesh.shape[a]
        out, aux = _routed_core(
            p, cfg, x_loc, capacity_factor, e0=e0, E_loc=E_loc
        )
        out = jax.lax.psum(out, expert_axes)  # combine expert partials
        if other_axes:
            out = jax.lax.pmean(out, other_axes)
        aux = jax.lax.pmean(aux, batch_axes) if batch_axes else aux
        return out, aux

    out, aux = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(x_spec, P()),
        check_rep=False,
    )(routed_params, x)
    return out, aux


def _routed_core(params, cfg: MoEConfig, x, capacity_factor, *, e0, E_loc):
    """Routing + dispatch + expert FFN for the local expert slice.

    x (B_loc, S, d); params expert weights already sliced (E_loc, ...).
    Returns the PARTIAL output (only local experts' contributions).
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    cf = capacity_factor or cfg.capacity_factor
    C = T if cf < 0 else int(np.ceil(T * K / E * cf))
    C = min(C, T)
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    if cfg.normalize_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    top_p = top_p * cfg.routed_scaling

    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32).mean(axis=0)
    aux = cfg.aux_loss_weight * E * jnp.sum(me * ce)

    flat_e = top_e.reshape(T * K).astype(jnp.int32)
    flat_p = top_p.reshape(T * K)
    flat_tok = jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32)[:, None], (T, K)
    ).reshape(T * K)
    rank = _ranks_within_expert(flat_e, E)
    local_e = flat_e - e0
    keep = (rank < C) & (local_e >= 0) & (local_e < E_loc)
    slot = jnp.where(keep, local_e * C + rank, E_loc * C)
    tok_buf = jnp.full((E_loc * C + 1,), T, jnp.int32).at[slot].set(
        flat_tok, mode="drop"
    )[: E_loc * C]
    prob_buf = jnp.zeros((E_loc * C + 1,), jnp.float32).at[slot].set(
        flat_p, mode="drop"
    )[: E_loc * C]
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = x_pad[tok_buf].reshape(E_loc, C, d)
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    ye = ye * prob_buf.reshape(E_loc, C, 1).astype(ye.dtype)
    y = (
        jnp.zeros((T + 1, d), ye.dtype)
        .at[tok_buf.reshape(E_loc * C)]
        .add(ye.reshape(E_loc * C, d), mode="drop")[:T]
    )
    return y.reshape(B, S, d), aux


def _moe_apply_local(
    params,
    cfg: MoEConfig,
    x: jnp.ndarray,
    ctx: ShardingCtx = NULL_CTX,
    capacity_factor: float = 0.0,
    include_shared: bool = True,
):
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    cf = capacity_factor or cfg.capacity_factor
    # cf < 0 => dropless (decode path): every expert can hold every token.
    C = T if cf < 0 else int(np.ceil(T * K / E * cf))
    C = min(C, T)
    xf = x.reshape(T, d)

    # ---- router (f32 for stability) ----------------------------------------
    logits = (xf.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_p, top_e = jax.lax.top_k(probs, K)  # (T, K)
    if cfg.normalize_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    top_p = top_p * cfg.routed_scaling

    # ---- aux load-balance loss (Switch eq. 4-6) -----------------------------
    me = probs.mean(axis=0)  # mean router prob / expert
    one_hot_top1 = jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)  # fraction routed (top-1) / expert
    aux = cfg.aux_loss_weight * E * jnp.sum(me * ce)

    # ---- dispatch ----------------------------------------------------------
    flat_e = top_e.reshape(T * K).astype(jnp.int32)
    flat_p = top_p.reshape(T * K)
    flat_tok = (
        jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None], (T, K))
    ).reshape(T * K)
    rank = _ranks_within_expert(flat_e, E)
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)  # E*C = dropped
    tok_buf = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        flat_tok, mode="drop"
    )[: E * C]
    prob_buf = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        flat_p, mode="drop"
    )[: E * C]

    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = x_pad[tok_buf].reshape(E, C, d)
    xe = ctx.constrain(xe, "expert", None, None)

    # ---- expert FFN (E-sharded batched einsum) ------------------------------
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    ye = ctx.constrain(ye, "expert", None, None)
    ye = ye * prob_buf.reshape(E, C, 1).astype(ye.dtype)

    # ---- combine: scatter-add back to token space ---------------------------
    y = (
        jnp.zeros((T + 1, d), ye.dtype)
        .at[tok_buf.reshape(E * C)]
        .add(ye.reshape(E * C, d), mode="drop")[:T]
    )
    y = ctx.constrain(y.reshape(B, S, d), "batch", None, None).reshape(T, d)

    # ---- shared experts ------------------------------------------------------
    if include_shared and "shared" in params:
        from repro.models.layers import mlp_apply

        y = y + mlp_apply(params["shared"], xf)
    return y.reshape(B, S, d), aux

"""Sharding rules: logical-axis annotations decoupled from the mesh.

Models never import a mesh.  They call ``ctx.constrain(x, *logical_axes)``
with *logical* names; ShardingCtx maps logical -> mesh axes and inserts
``with_sharding_constraint`` (a no-op off-mesh, so smoke tests run unchanged
on one CPU device).

Logical axes used across the zoo:
  batch    -> ("pod"?, "data")   activations' batch dim (pod only when the
                                  pod axis data-parallelizes)
  model    -> ("model",)          TP: heads / d_ff / vocab / experts
  seq      -> (None)              sequence (sharded only for long-decode KV)
  kv_seq   -> ("model",)          sequence-sharded KV cache (flash-decoding
                                  partial-softmax merge comes from GSPMD)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES = {
    "batch": ("data",),
    "fsdp": ("data",),  # weight-sharding axis (ZeRO-3 style)
    "model": ("model",),
    "expert": ("model",),
    "seq": (),
    "kv_seq": (),
    "vocab": ("model",),
}


@dataclasses.dataclass
class ShardingCtx:
    """Logical-axis -> mesh-axis mapping + constraint insertion."""

    mesh: Optional[Mesh] = None
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))

    def spec(self, *logical) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            axes = self.rules.get(name, ())
            axes = tuple(a for a in axes if self.mesh and a in self.mesh.shape)
            if len(axes) == 0:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return P(*parts)

    def constrain(self, x, *logical):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical))
        )

    def named(self, *logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))


NULL_CTX = ShardingCtx(mesh=None)


def batch_axes_with_pod(ctx: ShardingCtx) -> ShardingCtx:
    """Return a ctx whose 'batch' logical axis also spans the pod axis —
    used when the pod dimension data-parallelizes (default multi-pod mode)."""
    rules = dict(ctx.rules)
    rules["batch"] = ("pod", "data")
    return dataclasses.replace(ctx, rules=rules)

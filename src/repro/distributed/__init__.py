"""Distribution substrate: sharding rules, hierarchical collectives, compression."""

"""Gradient compression for the cross-pod hop: int8 quantization with error
feedback (1-bit-Adam-style residual carrying), and top-k sparsification.

Compression lives OUTSIDE the collective (quantize -> psum in int32 ->
dequantize) so it composes with any reduction schedule.  Error feedback keeps
the quantization residual on-device and re-injects it next step, which is the
standard fix for the bias that naive quantized all-reduce introduces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str):
    """int8-compressed all-reduce: ~4x cross-link byte reduction vs f32.

    Accumulates in int32 (no overflow below ~2^23 summands) and reduces the
    scales separately (max-scale conservative dequant).
    """
    q, scale = quantize_int8(x)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)
    return acc.astype(jnp.float32) * scale_max


def error_feedback_compress(grads, residuals):
    """Apply error feedback: g' = quantize(g + r); r' = (g + r) - dequant(g').

    Returns (quantized_pairs, new_residuals) as pytrees.
    """

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return (q, scale), target - deq

    flat = jax.tree.map(one, grads, residuals)
    qs = jax.tree.map(
        lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
    )
    new_res = jax.tree.map(
        lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2
    )
    return qs, new_res


def topk_sparsify(x: jnp.ndarray, frac: float):
    """Keep the top-|frac| magnitude entries (dense mask form — the collective
    still moves a dense tensor, but zeros compress over the wire; used for
    ablations of sparsified sync)."""
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]  # k-th largest magnitude
    mask = jnp.abs(x) >= thresh
    return jnp.where(mask, x, 0), mask

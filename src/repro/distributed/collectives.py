"""Collective schedules: hierarchical cross-pod reduction, top-k merge trees.

``hierarchical_grad_sync`` implements the multi-pod gradient path from
DESIGN.md §4: pod-local reduce_scatter -> cross-pod all_reduce on the 1/N
shard -> pod-local all_gather.  Cross-pod links are the scarce resource
(data-center interconnect vs intra-pod ICI); this schedule sends exactly
1/pod_local_size of the gradient bytes across pods vs a naive global
all-reduce, and composes with int8 compression (compression.py) applied only
to the cross-pod hop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _axis_size(axis_name: str) -> int:
    """jax.lax.axis_size across jax versions (absent on 0.4.x).

    psum of the Python constant 1 is constant-folded to the axis size as a
    static int on every jax that lacks axis_size, so both branches return a
    concrete value usable in shapes/loop bounds.
    """
    ax_size = getattr(jax.lax, "axis_size", None)
    if ax_size is not None:
        return ax_size(axis_name)
    return jax.lax.psum(1, axis_name)


def hierarchical_grad_sync(grads, *, pod_axis: str = "pod", local_axis: str = "data"):
    """Inside shard_map: grads pytree replicated per (pod, data) lane.

    Returns the mean over the full (pod x data) group, computed as
    reduce_scatter(local) -> all_reduce(pod) -> all_gather(local).
    """

    def sync_leaf(g):
        orig_shape = g.shape
        n_local = _axis_size(local_axis)
        n_pod = _axis_size(pod_axis)
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % n_local
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), g.dtype)])
        # 1. pod-local reduce_scatter (each lane owns 1/n_local of the sum)
        shard = jax.lax.psum_scatter(
            flat.reshape(n_local, -1), local_axis, scatter_dimension=0, tiled=False
        )
        # 2. cross-pod all_reduce on the shard only
        shard = jax.lax.psum(shard, pod_axis)
        # 3. pod-local all_gather to restore the full gradient
        full = jax.lax.all_gather(shard, local_axis, axis=0, tiled=False)
        full = full.reshape(-1)[: g.size].reshape(orig_shape)
        return full / (n_local * n_pod)

    return jax.tree.map(sync_leaf, grads)


def ring_topk_merge(dists, ids, k: int, axis_name: str):
    """Log-depth alternative to all_gather+merge for the LANNS shard merge:
    butterfly exchange via all-to-all pairs is overkill at pstk payloads, but
    for LARGE k the broker all_gather becomes the bottleneck; this merges
    pairwise over a hypercube in log2(S) rounds, each round halving payload
    growth (candidates stay at k instead of S*k).

    dists/ids: (B, k) local candidates; returns merged (B, k) on every lane.
    Requires power-of-two axis size.
    """
    size = _axis_size(axis_name)
    rounds = size.bit_length() - 1
    idx = jax.lax.axis_index(axis_name)
    d, i = dists, ids
    for r in range(rounds):
        partner = idx ^ (1 << r)
        # pairwise exchange via ppermute
        perm = [(s, s ^ (1 << r)) for s in range(size)]
        od = jax.lax.ppermute(d, axis_name, perm)
        oi = jax.lax.ppermute(i, axis_name, perm)
        cd = jnp.concatenate([d, od], axis=-1)
        ci = jnp.concatenate([i, oi], axis=-1)
        neg, sel = jax.lax.top_k(-cd, k)
        d = -neg
        i = jnp.take_along_axis(ci, sel, axis=-1)
    return d, i

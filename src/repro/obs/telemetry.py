"""The ``Telemetry`` bundle: registry + span sink + retrace sentinel.

One object threads through the serving stack:

* ``LannsIndex.attach_telemetry(tel)`` makes the staged plan executor time
  its route/candidates/rerank/merge boundaries into ``tel`` (detached — the
  default — the executor reads no clock at all, so the instrumentation-off
  path is structurally bit-identical to the pre-telemetry pipeline);
* ``AnnFrontend(..., telemetry=tel)`` records the per-request queue/exec/
  end-to-end decomposition of every formed micro-batch, and polls the
  ``RetraceSentinel`` so a jit recompile on warmed traffic becomes a
  counter bump + a ``retrace`` span event;
* ``ServeEngine(..., telemetry=tel)`` registers its ``stats`` dict as pull
  gauges, so ONE ``tel.registry.expose_text()`` call covers both engines.

The hooks hold no locks of their own beyond the metric/sink internals
(each an uncontended leaf lock around a dict/array update — see the
telemetry lock contract in src/repro/analysis/README.md), and they never
call back into the index or frontend, so attaching telemetry cannot
introduce a lock cycle with the serving locks.
"""

from __future__ import annotations

import math
import time
from typing import Callable, Optional

import numpy as np

from repro.common.utils import next_pow2
from repro.obs.metrics import (
    BATCH_SIZE_BUCKETS,
    MetricsRegistry,
)
from repro.obs.spans import SpanSink


class Telemetry:
    """Serving-telemetry bundle; share one instance across components.

    ``clock`` is the duration clock for the executor's stage spans
    (injectable for tests; defaults to ``time.perf_counter`` — the same
    domain as the frontend request timestamps).  ``sentinel`` defaults to
    a fresh ``RetraceSentinel`` over the serving jit set; any object with
    ``retraced()``/``reset()`` substitutes (tests stub it).
    """

    def __init__(
        self,
        registry: Optional[MetricsRegistry] = None,
        spans: Optional[SpanSink] = None,
        sentinel=None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.spans = spans if spans is not None else SpanSink()
        if sentinel is None:
            from repro.analysis.sentinels import RetraceSentinel

            sentinel = RetraceSentinel()
        self.sentinel = sentinel
        self.clock = clock
        reg = self.registry
        # -- metric catalog (documented in README "Observability") ---------
        self.requests_total = reg.counter(
            "lanns_requests_total",
            "ANN requests completed, by micro-batch kind",
            ("kind",),
        )
        self.batches_total = reg.counter(
            "lanns_batches_total",
            "Micro-batches formed, by flush kind",
            ("kind",),
        )
        self.queue_seconds = reg.histogram(
            "lanns_queue_seconds",
            "Per-request batching/queueing delay (t_start - t_submit)",
        )
        self.exec_seconds = reg.histogram(
            "lanns_exec_seconds",
            "Per-request batched execution time (t_done - t_start)",
        )
        self.latency_seconds = reg.histogram(
            "lanns_request_latency_seconds",
            "Per-request end-to-end latency (t_done - t_submit)",
        )
        self.batch_size = reg.histogram(
            "lanns_batch_size",
            "Formed micro-batch sizes",
            buckets=BATCH_SIZE_BUCKETS,
        )
        self.stage_seconds = reg.histogram(
            "lanns_stage_seconds",
            "Query-plan stage wall clock per executed knob group",
            ("stage", "engine", "quantized", "merge_path", "batch_bucket"),
        )
        self.retraces_total = reg.counter(
            "lanns_jit_retraces_total",
            "Watched jit recompiles observed on serving traffic",
            ("fn",),
        )
        # SLO-controller decision metrics (serve/controller.py): the policy
        # is itself observable, so a controller A/B can be judged from one
        # exposition — degrades by ladder rung, retune ticks by decision,
        # and the knob values the controller last applied/saw.
        self.controller_degraded = reg.counter(
            "lanns_controller_degraded_total",
            "Requests served with a deadline-degraded ef, by ladder ef",
            ("ef",),
        )
        self.controller_retunes = reg.counter(
            "lanns_controller_retunes_total",
            "Controller retune ticks, by decision",
            ("action",),
        )
        self.controller_max_wait_ms = reg.gauge(
            "lanns_controller_max_wait_ms",
            "Frontend max_wait_ms as last set by the controller",
        )
        self.controller_max_batch = reg.gauge(
            "lanns_controller_max_batch",
            "Frontend max_batch as last observed by the controller",
        )

    # -- pipeline hooks ----------------------------------------------------

    def on_execute(self, *, engine: str, quantized: str, merge_path: str,
                   batch: int, stage_s: dict) -> None:
        """One executed knob group (called by ``QueryPlanExecutor``)."""
        bucket = str(next_pow2(max(int(batch), 1)))
        for stage, secs in stage_s.items():
            self.stage_seconds.labels(
                stage=stage, engine=engine, quantized=quantized,
                merge_path=merge_path, batch_bucket=bucket,
            ).observe(float(secs))
        self.spans.emit(
            "plan",
            b=int(batch),
            batch_bucket=int(bucket),
            engine=str(engine),
            quantized=str(quantized),
            merge_path=str(merge_path),
            stage_s={k: float(v) for k, v in stage_s.items()},
        )

    def on_batch(self, batch, kind: str) -> None:
        """One formed micro-batch of completed ``AnnRequest``s (called by
        ``AnnFrontend._execute`` AFTER results are published)."""
        b = len(batch)
        if b == 0:
            return
        queue = np.array([r.t_start - r.t_submit for r in batch], np.float64)
        execs = np.array([r.t_done - r.t_start for r in batch], np.float64)
        e2e = np.array([r.t_done - r.t_submit for r in batch], np.float64)
        self.queue_seconds.observe_many(queue)
        self.exec_seconds.observe_many(execs)
        self.latency_seconds.observe_many(e2e)
        self.batch_size.observe(float(b))
        self.batches_total.labels(kind).inc()
        self.requests_total.labels(kind).inc(b)
        self.spans.emit(
            "batch",
            batch_kind=str(kind),
            b=int(b),
            exec_s=float(execs[0]),  # shared by the whole batch
            queue_mean_s=float(queue.mean()),
            queue_max_s=float(queue.max()),
        )
        self.poll_retraces()

    def on_degrade(self, ef: int, n: int = 1) -> None:
        """``n`` requests in a formed batch degraded to ladder rung ``ef``
        (called by ``SLOController.on_batch_formed`` on the batcher
        thread; one labeled counter bump, no span — the batch span that
        follows carries the batch context)."""
        self.controller_degraded.labels(str(int(ef))).inc(int(n))

    def on_retune(self, *, action: str, max_wait_ms: float, max_batch: int,
                  worst_ms: float, depth: int) -> None:
        """One controller tick: decision counter, knob gauges, and a
        ``controller`` span with the signal values the decision saw
        (``worst_ms`` is None in the span when the tick's window held no
        batch events)."""
        self.controller_retunes.labels(str(action)).inc()
        self.controller_max_wait_ms.set(float(max_wait_ms))
        self.controller_max_batch.set(float(max_batch))
        worst = float(worst_ms)
        self.spans.emit(
            "controller",
            action=str(action),
            max_wait_ms=float(max_wait_ms),
            max_batch=int(max_batch),
            worst_ms=worst if math.isfinite(worst) else None,
            depth=int(depth),
        )

    def poll_retraces(self) -> dict:
        """Fold the sentinel's deltas into the retrace counter + events.

        Returns the {fn: new_compiles} dict observed this poll (empty when
        nothing retraced or no sentinel is wired)."""
        sentinel = self.sentinel
        if sentinel is None:
            return {}
        hot = sentinel.retraced()
        if hot:
            for fn, n in sorted(hot.items()):
                self.retraces_total.labels(fn).inc(n)
                self.spans.emit("retrace", fn=str(fn), count=int(n))
            sentinel.reset()  # next poll counts fresh compiles only
        return hot

    # -- component registration -------------------------------------------

    def register_serve_engine(self, engine, prefix: str = "serve_engine"):
        """Register an engine-like object's ``stats`` dict as pull gauges.

        Each key becomes ``<prefix>_<key>`` read at collection time — no
        push call on the engine's loop.  Works for ``ServeEngine`` (and any
        object with a ``stats`` mapping of numbers)."""
        for key in sorted(engine.stats):
            gauge = self.registry.gauge(
                f"{prefix}_{key}", f"{type(engine).__name__}.stats[{key!r}]"
            )
            gauge.set_function(
                lambda e=engine, k=key: float(e.stats.get(k, 0))
            )
        return self

    def attach(self, index) -> "Telemetry":
        """Convenience: ``Telemetry().attach(idx)`` wires the executor."""
        index.attach_telemetry(self)
        return self

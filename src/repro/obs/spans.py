"""Bounded span/event sink + the per-stage latency breakdown helpers.

Spans are plain dicts (``kind`` + payload) appended to a bounded in-memory
ring: the sink NEVER grows past ``capacity`` events — under sustained
traffic old events fall off the front and ``dropped`` counts them, so
attaching telemetry to a long-lived server cannot leak memory.  Three
event kinds flow through it in this repo:

* ``plan``  — one per executed knob group: engine/quantized/merge_path
  labels, the pow2 batch bucket, and ``stage_s`` with the
  route/candidates/rerank/merge wall-clock split (from
  ``QueryPlanExecutor.execute``);
* ``batch`` — one per formed micro-batch: batch kind (full/deadline/
  forced), size, and the queue/exec decomposition of its requests (from
  ``AnnFrontend._execute``);
* ``retrace`` — a watched jit recompiled (from ``RetraceSentinel`` deltas,
  polled on every batch) — the event an operator alerts on, because a
  warmed serving path must reuse existing traces;
* ``controller`` — one per SLO-controller retune tick: the decision
  (tighten/relax/hold), the knob values applied, and the worst-latency /
  queue-depth signals the decision saw (from
  ``serve.controller.SLOController`` via ``Telemetry.on_retune``).

Export surface: ``to_jsonl()`` / ``dump_jsonl(path)`` — one JSON object
per line, the load-sweep artifact format (``BENCH_stage_breakdown.jsonl``).

``stage_breakdown`` reduces plan events to the per-stage p50/p95/p99 table
the load sweeps report; percentiles are EXACT (``np.percentile`` over the
retained per-event durations), unlike the bucket-interpolated quantiles of
the exposition histograms.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

#: canonical pipeline stages, reporting order: queue wait (request-level),
#: then the executor's route -> candidates -> rerank -> merge split.
STAGES: tuple[str, ...] = ("queue", "route", "candidates", "rerank", "merge")


class SpanSink:
    """Bounded ring of event dicts with a monotonic sequence number.

    ``emit`` returns the event's ``seq``; ``events(since=seq)`` filters to
    events emitted at-or-after a watermark, which is how a load sweep
    isolates one offered-load point's spans out of a shared sink.
    """

    _GUARDED_BY = {"_events": "_lock", "_seq": "_lock", "_dropped": "_lock"}

    def __init__(self, capacity: int = 4096,
                 clock: Callable[[], float] = time.time):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self.capacity = capacity
        self.clock = clock  # wall-clock stamp; injectable for tests
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._seq = 0
        self._dropped = 0

    def emit(self, kind: str, **fields) -> int:
        ev = {"kind": kind, "ts": float(self.clock()), **fields}
        with self._lock:
            ev["seq"] = self._seq
            self._seq += 1
            if len(self._events) == self.capacity:
                self._dropped += 1
            self._events.append(ev)
        return ev["seq"]

    @property
    def next_seq(self) -> int:
        """Watermark: the seq the NEXT emitted event will carry."""
        with self._lock:
            return self._seq

    @property
    def dropped(self) -> int:
        """Events evicted from the ring since construction."""
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self, kind: Optional[str] = None,
               since: Optional[int] = None) -> list[dict]:
        """Retained events, oldest first, optionally filtered."""
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        if since is not None:
            evs = [e for e in evs if e["seq"] >= since]
        return evs

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    # -- JSONL export ------------------------------------------------------

    def to_jsonl(self) -> str:
        evs = self.events()
        return "".join(json.dumps(e, sort_keys=True) + "\n" for e in evs)

    def dump_jsonl(self, path: str) -> int:
        """Write the retained events to ``path``; returns lines written."""
        text = self.to_jsonl()
        with open(path, "w") as f:
            f.write(text)
        return text.count("\n")


def percentiles_ms(values) -> dict:
    """{p50_ms, p95_ms, p99_ms, mean_ms, n} of a seconds array."""
    v = np.asarray(values, np.float64).ravel()
    if v.size == 0:
        nan = float("nan")
        return {"p50_ms": nan, "p95_ms": nan, "p99_ms": nan,
                "mean_ms": nan, "n": 0}
    pct = np.percentile(v, (50, 95, 99))
    return {
        "p50_ms": 1e3 * float(pct[0]),
        "p95_ms": 1e3 * float(pct[1]),
        "p99_ms": 1e3 * float(pct[2]),
        "mean_ms": 1e3 * float(v.mean()),
        "n": int(v.size),
    }


def stage_breakdown(events, *, extra: Optional[dict] = None) -> dict:
    """Per-stage percentile table from ``plan`` span events.

    ``events`` is any iterable of event dicts; only ``kind == 'plan'``
    entries with a ``stage_s`` payload contribute — each contributes one
    duration per stage (per executed knob group).  ``extra`` merges
    caller-supplied stages measured elsewhere (the load generator passes
    ``{"queue": per_request_queue_seconds}`` — queue wait is request-level
    and never visible to the executor).  Returns ``{stage:
    percentiles_ms(...)}`` ordered canonically (STAGES first).
    """
    vals: dict[str, list] = {}
    for ev in events:
        st = ev.get("stage_s")
        if ev.get("kind") != "plan" or not st:
            continue
        for stage, secs in st.items():
            vals.setdefault(stage, []).append(float(secs))
    if extra:
        for stage, secs in extra.items():
            vals.setdefault(stage, []).extend(np.asarray(secs).ravel())
    order = [s for s in STAGES if s in vals] + sorted(set(vals) - set(STAGES))
    return {stage: percentiles_ms(vals[stage]) for stage in order}


def format_stage_table(breakdown: dict, indent: str = "  ") -> str:
    """Fixed-width text table of a ``stage_breakdown`` result."""
    cols = ("p50_ms", "p95_ms", "p99_ms", "mean_ms", "n")
    head = f"{indent}{'stage':<12}" + "".join(f"{c:>10}" for c in cols)
    rows = [head]
    for stage, d in breakdown.items():
        cells = []
        for c in cols:
            v = d.get(c, float("nan"))
            cells.append(f"{v:>10d}" if c == "n" else f"{v:>10.3f}")
        rows.append(f"{indent}{stage:<12}" + "".join(cells))
    return "\n".join(rows)

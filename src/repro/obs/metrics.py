"""Metrics registry: counters, gauges, fixed-bucket histograms, exposition.

The serving-telemetry substrate (ROADMAP: the closed-loop SLO controller
"exports the telemetry counters to judge it").  Three metric kinds, each a
FAMILY that fans out into labeled series:

* ``Counter`` — monotonic; ``inc(amount)`` rejects negative amounts.
* ``Gauge`` — last-write-wins value, or a pull callback
  (``set_function``) read at collection time — how ``ServeEngine.stats``
  registers without a push call on its hot loop.
* ``Histogram`` — fixed upper-bound buckets with Prometheus ``le``
  semantics (upper-INCLUSIVE bounds, implicit ``+Inf`` overflow bucket)
  plus ``_sum``/``_count``; ``observe_many`` ingests a whole micro-batch
  of values with ONE ``np.searchsorted`` + ONE lock acquisition, so the
  per-batch instrumentation cost stays microseconds at ``B=1024``.

Export surfaces: ``MetricsRegistry.expose_text()`` renders the standard
Prometheus text format (``# HELP``/``# TYPE``, cumulative ``_bucket{le=}``
lines); ``to_dict()`` is the JSON-friendly snapshot the bench artifacts
embed.

Lock discipline (checked statically by ``repro.analysis`` LANNS010-013 —
see src/repro/analysis/README.md): every mutable aggregate declares its
``_GUARDED_BY`` registry and takes its own uncontended ``threading.Lock``
for the dict/array update only — no metric method ever calls into jax,
the index, or anything blocking while holding a lock, so telemetry can
never participate in a lock cycle with the serving locks.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Callable, Optional, Sequence

import numpy as np

#: default latency buckets (seconds): 0.5 ms .. 5 s, roughly log-spaced —
#: covers micro-batch execution on one node through past-saturation queueing.
DEFAULT_LATENCY_BUCKETS_S: tuple[float, ...] = (
    0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05,
    0.1, 0.2, 0.5, 1.0, 2.0, 5.0,
)

#: pow2 batch-size buckets matching the serving trace buckets (a formed
#: micro-batch pads to the next pow2 before execution).
BATCH_SIZE_BUCKETS: tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096,
)


def _escape_label(value: object) -> str:
    return (
        str(value)
        .replace("\\", "\\\\")
        .replace('"', '\\"')
        .replace("\n", "\\n")
    )


def _fmt_value(v: float) -> str:
    """Prometheus sample-value formatting: integral floats stay integral."""
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if float(v) == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _series_suffix(labelnames: Sequence[str], key: tuple) -> str:
    if not labelnames:
        return ""
    pairs = ",".join(
        f'{n}="{_escape_label(v)}"' for n, v in zip(labelnames, key)
    )
    return "{" + pairs + "}"


class Counter:
    """One monotonic series.  ``inc`` only; negative amounts raise."""

    _GUARDED_BY = {"_value": "_lock"}

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment {amount} must be >= 0")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """One settable series; ``set_function`` switches it to pull mode."""

    _GUARDED_BY = {"_value": "_lock", "_fn": "_lock"}

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)
            self._fn = None

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount
            self._fn = None

    def set_function(self, fn: Callable[[], float]) -> None:
        """Read ``fn()`` at every collection instead of a stored value."""
        with self._lock:
            self._fn = fn

    @property
    def value(self) -> float:
        with self._lock:
            fn = self._fn
            stored = self._value
        # the callback runs OUTSIDE the lock: it is caller code and must
        # not be able to deadlock collection against its own locks
        return float(fn()) if fn is not None else stored


class Histogram:
    """Fixed-bucket histogram with Prometheus ``le`` (upper-incl.) bounds.

    A value exactly on a bound lands IN that bound's bucket; anything past
    the last bound lands in the implicit ``+Inf`` overflow bucket (both
    asserted in tests/test_obs.py).  ``observe_many`` is the batched hot
    path: one vectorized bin + one lock acquisition per call.
    """

    _GUARDED_BY = {"_counts": "_lock", "_sum": "_lock", "_count": "_lock"}

    def __init__(self, buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        bounds = np.asarray(tuple(buckets), np.float64)
        if bounds.size == 0:
            raise ValueError("histogram needs at least one bucket bound")
        if not np.all(np.isfinite(bounds)):
            raise ValueError("bucket bounds must be finite (+Inf is implicit)")
        if np.any(np.diff(bounds) <= 0):
            raise ValueError(f"bucket bounds must be increasing: {buckets}")
        self._bounds = bounds  # immutable after init — read lock-free
        self._lock = threading.Lock()
        self._counts = np.zeros(bounds.size + 1, np.int64)
        self._sum = 0.0
        self._count = 0

    @property
    def bounds(self) -> tuple[float, ...]:
        return tuple(self._bounds)

    def observe(self, value: float) -> None:
        self.observe_many((value,))

    def observe_many(self, values) -> None:
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return
        # side='left': first bound >= v — exactly the upper-inclusive `le`
        # bucket; v past the last bound indexes the overflow slot.
        idx = np.searchsorted(self._bounds, v, side="left")
        add = np.bincount(idx, minlength=self._bounds.size + 1)
        total = float(v.sum())
        n = int(v.size)
        with self._lock:
            self._counts += add
            self._sum += total
            self._count += n

    def snapshot(self) -> tuple[np.ndarray, float, int]:
        """(per-bucket counts incl. overflow, sum, count) — consistent."""
        with self._lock:
            return self._counts.copy(), self._sum, self._count

    def quantile(self, q: float) -> float:
        """Prometheus-style ``histogram_quantile``: linear interpolation
        inside the winning bucket; overflow-bucket answers clamp to the
        last finite bound.  NaN on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile q={q} must be in [0, 1]")
        counts, _, count = self.snapshot()
        if count == 0:
            return float("nan")
        target = q * count
        cum = np.cumsum(counts)
        i = int(np.searchsorted(cum, target, side="left"))
        if i >= self._bounds.size:  # landed in the +Inf overflow bucket
            return float(self._bounds[-1])
        lo = 0.0 if i == 0 else float(self._bounds[i - 1])
        hi = float(self._bounds[i])
        inside = counts[i]
        if inside == 0:
            return hi
        frac = (target - (cum[i] - inside)) / inside
        return lo + (hi - lo) * float(min(max(frac, 0.0), 1.0))


class _Family:
    """One named metric fanning out into labeled child series."""

    kind = "untyped"

    _GUARDED_BY = {"_series": "_lock"}

    def __init__(self, name: str, help: str, labelnames: Sequence[str]):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._series: dict[tuple, object] = {}

    def _make_child(self):
        raise NotImplementedError

    def labels(self, *values, **kv):
        """The child series for one label-value tuple (created on first
        use, cached after).  Positional values follow ``labelnames`` order;
        keywords must cover exactly the declared names."""
        if kv:
            if values or set(kv) != set(self.labelnames):
                raise ValueError(
                    f"{self.name}: labels expect exactly {self.labelnames}, "
                    f"got args={values} kwargs={sorted(kv)}"
                )
            key = tuple(str(kv[n]) for n in self.labelnames)
        else:
            if len(values) != len(self.labelnames):
                raise ValueError(
                    f"{self.name}: expected {len(self.labelnames)} label "
                    f"value(s) {self.labelnames}, got {len(values)}"
                )
            key = tuple(str(v) for v in values)
        with self._lock:
            child = self._series.get(key)
            if child is None:
                child = self._make_child()
                self._series[key] = child
        return child

    def series(self) -> dict[tuple, object]:
        """Stable snapshot of the label -> child map, sorted by labels."""
        with self._lock:
            items = list(self._series.items())
        return dict(sorted(items))

    # unlabeled convenience: family with labelnames=() delegates to the
    # single () child, so `registry.counter("x").inc()` just works.

    def _default(self):
        return self.labels()


class CounterFamily(_Family):
    kind = "counter"

    def _make_child(self):
        return Counter()

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    @property
    def value(self) -> float:
        return self._default().value


class GaugeFamily(_Family):
    kind = "gauge"

    def _make_child(self):
        return Gauge()

    def set(self, value: float) -> None:
        self._default().set(value)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._default().set_function(fn)

    @property
    def value(self) -> float:
        return self._default().value


class HistogramFamily(_Family):
    kind = "histogram"

    def __init__(self, name, help, labelnames,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(buckets)
        Histogram(self.buckets)  # validate bounds once, at registration

    def _make_child(self):
        return Histogram(self.buckets)

    def observe(self, value: float) -> None:
        self._default().observe(value)

    def observe_many(self, values) -> None:
        self._default().observe_many(values)

    def quantile(self, q: float) -> float:
        return self._default().quantile(q)


_NAME_OK = set(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:"
)


def _validate_name(name: str) -> None:
    if not name or name[0].isdigit() or not set(name) <= _NAME_OK:
        raise ValueError(f"invalid metric name {name!r}")


class MetricsRegistry:
    """Named family registry + the two snapshot/exposition surfaces.

    Registration is idempotent: re-registering the same (name, kind,
    labelnames) returns the EXISTING family — so independently constructed
    components (frontend, engine, benches) can all declare their metrics
    against one shared registry without an ownership protocol.  A kind or
    label-schema mismatch on an existing name raises.
    """

    _GUARDED_BY = {"_families": "_lock"}

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _register(self, cls, name: str, help: str,
                  labelnames: Sequence[str], **kw) -> _Family:
        _validate_name(name)
        labelnames = tuple(labelnames)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = cls(name, help, labelnames, **kw)
                self._families[name] = fam
                return fam
        if not isinstance(fam, cls) or fam.labelnames != labelnames:
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.labelnames} — asked for {cls.kind} with "
                f"{labelnames}"
            )
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> CounterFamily:
        return self._register(CounterFamily, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> GaugeFamily:
        return self._register(GaugeFamily, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S,
                  ) -> HistogramFamily:
        return self._register(HistogramFamily, name, help, labelnames,
                              buckets=buckets)

    def families(self) -> list[_Family]:
        with self._lock:
            fams = list(self._families.values())
        return sorted(fams, key=lambda f: f.name)

    # -- exposition --------------------------------------------------------

    def expose_text(self) -> str:
        """Prometheus text exposition (text/plain; version 0.0.4)."""
        out: list[str] = []
        for fam in self.families():
            if fam.help:
                out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in fam.series().items():
                suffix = _series_suffix(fam.labelnames, key)
                if isinstance(child, Histogram):
                    counts, total, count = child.snapshot()
                    cum = 0
                    for bound, c in zip(child.bounds, counts):
                        cum += int(c)
                        le = _series_suffix(
                            fam.labelnames + ("le",),
                            key + (_fmt_value(bound),),
                        )
                        out.append(f"{fam.name}_bucket{le} {cum}")
                    le = _series_suffix(
                        fam.labelnames + ("le",), key + ("+Inf",)
                    )
                    out.append(f"{fam.name}_bucket{le} {count}")
                    out.append(
                        f"{fam.name}_sum{suffix} {_fmt_value(total)}"
                    )
                    out.append(f"{fam.name}_count{suffix} {count}")
                else:
                    out.append(
                        f"{fam.name}{suffix} {_fmt_value(child.value)}"
                    )
        return "\n".join(out) + "\n"

    def to_dict(self) -> dict:
        """JSON-friendly snapshot: {name: {kind, labels, series}}."""
        out: dict = {}
        for fam in self.families():
            series = {}
            for key, child in fam.series().items():
                skey = ",".join(key) if key else ""
                if isinstance(child, Histogram):
                    counts, total, count = child.snapshot()
                    series[skey] = {
                        "buckets": list(child.bounds),
                        "counts": [int(c) for c in counts],
                        "sum": total,
                        "count": int(count),
                    }
                else:
                    series[skey] = child.value
            out[fam.name] = {
                "kind": fam.kind,
                "labels": list(fam.labelnames),
                "series": series,
            }
        return out

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

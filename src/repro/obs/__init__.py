"""Serving telemetry: metrics registry, span sink, pipeline instrumentation.

Quickstart::

    from repro.obs import Telemetry

    tel = Telemetry()
    idx.attach_telemetry(tel)                   # stage spans from the executor
    fe = AnnFrontend(idx, telemetry=tel)        # queue/exec decomposition
    ...serve...
    print(tel.registry.expose_text())           # Prometheus text exposition
    tel.spans.dump_jsonl("events.jsonl")        # bounded JSONL event log

Instrumentation-off (no attach, ``telemetry=None``) and -on paths return
bit-identical results — the hooks only observe; the ≤3% QPS overhead at
B=1024 is measured by ``benchmarks/bench_online_qps.py``.
"""

from repro.obs.metrics import (
    BATCH_SIZE_BUCKETS,
    DEFAULT_LATENCY_BUCKETS_S,
    Counter,
    CounterFamily,
    Gauge,
    GaugeFamily,
    Histogram,
    HistogramFamily,
    MetricsRegistry,
)
from repro.obs.spans import (
    STAGES,
    SpanSink,
    format_stage_table,
    percentiles_ms,
    stage_breakdown,
)
from repro.obs.telemetry import Telemetry

__all__ = [
    "BATCH_SIZE_BUCKETS",
    "DEFAULT_LATENCY_BUCKETS_S",
    "Counter",
    "CounterFamily",
    "Gauge",
    "GaugeFamily",
    "Histogram",
    "HistogramFamily",
    "MetricsRegistry",
    "STAGES",
    "SpanSink",
    "Telemetry",
    "format_stage_table",
    "percentiles_ms",
    "stage_breakdown",
]

"""Two-level merging + perShardTopK (paper §5.3).

The merge mirrors production: segment-level results merge *inside* the shard
(no network), shard-level results merge at the broker (network / collective).
``per_shard_topk`` implements Eq. (5)-(6): the Normal Approximation Interval
[Brown, Cai, DasGupta 2001] on the binomial "how many of the global top-k land
in one of S uniform shards", shrinking what each shard returns from k to
``min(k, ceil(cI * k))`` — the paper's network-I/O / merge-cost optimization.
On the TPU mesh this directly shrinks the all-gather payload of the shard
merge (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _probit(q: float) -> float:
    """Φ^{-1}(q) — Acklam's rational approximation (|err| < 1.15e-9).

    Dependency-free so the serving path never imports scipy.
    """
    if not 0.0 < q < 1.0:
        raise ValueError(q)
    a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
    b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00]
    plow, phigh = 0.02425, 1 - 0.02425
    if q < plow:
        u = math.sqrt(-2 * math.log(q))
        return (((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4]) * u + c[5]) / (
            (((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1
        )
    if q > phigh:
        u = math.sqrt(-2 * math.log(1 - q))
        return -(((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4]) * u + c[5]) / (
            (((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1
        )
    u = q - 0.5
    t = u * u
    return (
        (((((a[0] * t + a[1]) * t + a[2]) * t + a[3]) * t + a[4]) * t + a[5])
        * u
        / (((((b[0] * t + b[1]) * t + b[2]) * t + b[3]) * t + b[4]) * t + 1)
    )


def per_shard_topk(topk: int, num_shards: int, confidence: float = 0.95) -> int:
    """Eq. (5)-(6).  perShardTopK = min(topK, ceil(cI * topK)).

    The paper writes f(p) as "the (1 - p/2) quantile" with p called the
    confidence; read literally with p=0.95 that gives a 0.525-quantile ≈ 0.06
    which contradicts the stated intent (an upper confidence bound).  We take
    the standard reading: f(p) = Φ^{-1}((1+p)/2), so p=0.95 → 1.96. With S=1
    the formula degenerates to cI >= 1 so perShardTopK == topK, as it must.
    """
    if num_shards <= 1:
        return topk
    s_prime = 1.0 / num_shards
    f = _probit((1.0 + confidence) / 2.0)
    ci = s_prime + f * math.sqrt(s_prime * (1.0 - s_prime) / topk)
    return min(topk, int(math.ceil(ci * topk)))


# ---------------------------------------------------------------------------
# Merging.  All merges operate on (B, c, ...) candidate lists with distances
# where LOWER IS BETTER and invalid entries are (+inf dist, id -1).
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("k",))
def merge_topk(dists: jnp.ndarray, ids: jnp.ndarray, k: int):
    """Merge candidate lists along the last candidate axis.

    dists/ids: (..., C).  Returns ((..., k) dists, (..., k) ids) sorted
    ascending by (distance, id).  Duplicate ids (a point returned by several
    segments the query spilled to) are collapsed — keep the best copy.

    Same two-lexsort formulation as ``merge_topk_vec`` (which replaced the
    earlier vmapped scatter-min dedup, kept below as
    ``merge_topk_scatter`` for benchmarking): first group by id with distance
    as tie-break so each id-run's head carries the run minimum, mask the rest
    of the run, then order survivors by (distance, id).  O(C log C) sorts,
    no per-row scatter.
    """
    C = dists.shape[-1]
    sentinel = (
        jnp.iinfo(ids.dtype).max
        if jnp.issubdtype(ids.dtype, jnp.integer) else jnp.inf
    )
    invalid = (ids < 0) | jnp.isinf(dists)
    dk = jnp.where(invalid, jnp.inf, dists)
    ik = jnp.where(invalid, sentinel, ids)
    # lexsort by id, then distance (last key is primary, like np.lexsort)
    order = jnp.lexsort((dk, ik), axis=-1)
    sid = jnp.take_along_axis(ik, order, axis=-1)
    sd = jnp.take_along_axis(dk, order, axis=-1)
    sinv = jnp.take_along_axis(invalid, order, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(sid[..., :1], dtype=bool),
         sid[..., 1:] == sid[..., :-1]], axis=-1,
    )
    sd = jnp.where(dup | sinv, jnp.inf, sd)
    order = jnp.lexsort((sid, sd), axis=-1)  # by distance, then id
    kk = min(k, C)
    take = order[..., :kk]
    out_d = jnp.take_along_axis(sd, take, axis=-1)
    out_i = jnp.where(
        jnp.isinf(out_d), -1, jnp.take_along_axis(sid, take, axis=-1)
    ).astype(ids.dtype)
    if kk < k:
        pad = k - kk
        out_d = jnp.concatenate(
            [out_d, jnp.full((*out_d.shape[:-1], pad), jnp.inf, out_d.dtype)],
            axis=-1,
        )
        out_i = jnp.concatenate(
            [out_i, jnp.full((*out_i.shape[:-1], pad), -1, out_i.dtype)],
            axis=-1,
        )
    return out_d, out_i


@partial(jax.jit, static_argnames=("k",))
def merge_topk_scatter(dists: jnp.ndarray, ids: jnp.ndarray, k: int):
    """The previous ``merge_topk``: vmapped scatter-min dedup + top_k.

    Kept as the benchmark baseline for the two-lexsort form (ROADMAP item;
    see benchmarks/bench_kernels.py) and as a second parity oracle.  Note its
    output order is by distance only (ids tie-break unspecified) — parity
    tests compare against ``merge_topk_np`` on distinct distances.
    """
    order = jnp.argsort(ids, axis=-1)
    sid = jnp.take_along_axis(ids, order, axis=-1)
    sd = jnp.take_along_axis(dists, order, axis=-1)
    same = jnp.concatenate(
        [jnp.zeros_like(sid[..., :1], dtype=bool), sid[..., 1:] == sid[..., :-1]],
        axis=-1,
    ) & (sid >= 0)
    run_start = ~same
    run_id = jnp.cumsum(run_start.astype(jnp.int32), axis=-1) - 1
    # per-run min distance via scatter-min into a (num_runs<=C,) buffer
    C = dists.shape[-1]

    def per_row(sd_row, run_row, sid_row, same_row):
        buf = jnp.full((C,), jnp.inf, dtype=sd_row.dtype)
        buf = buf.at[run_row].min(sd_row)
        best = buf[run_row]
        keep = (~same_row) & (sid_row >= 0)
        return jnp.where(keep, best, jnp.inf)

    flat = lambda a: a.reshape((-1, C))
    dd = jax.vmap(per_row)(flat(sd), flat(run_id), flat(sid), flat(same))
    dd = dd.reshape(sd.shape)
    neg, idx = jax.lax.top_k(-dd, k)
    out_d = -neg
    out_i = jnp.take_along_axis(sid, idx, axis=-1)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    return out_d, out_i


# lanns: dims[C<=16_384, k<=200]
def merge_topk_vec(dists: np.ndarray, ids: np.ndarray, k: int):
    """Vectorized NumPy merge — semantics of ``merge_topk_np``, no Python loop.

    dists/ids: (..., C), lower distance is better.  Entries with id < 0 or a
    non-finite (±inf) distance are dropped; duplicate ids keep their minimum
    distance; output is sorted ascending by (distance, id) and padded with
    (+inf, -1).  Parity with ``merge_topk_np`` is property-tested
    (tests/test_merge_vec.py).

    ids must be integral-VALUED; a float dtype is accepted (and preserved)
    but fractional ids are undefined behaviour — the reference dedups by
    int(i) truncation, this path by exact value.

    Two row-wise lexsorts, O(C log C) per row: first group by id (distance as
    the tie-break so the head of each id-run carries the run minimum), mask
    the rest of each run, then order the survivors by (distance, id).
    """
    *lead, C = dists.shape
    d2 = dists.reshape(-1, C)
    i2 = ids.reshape(-1, C)
    R = d2.shape[0]
    # invalid ids get a sentinel that sorts after every real id (float id
    # arrays are legal in the reference, so pick the sentinel by kind)
    sentinel = (
        np.iinfo(i2.dtype).max
        if np.issubdtype(i2.dtype, np.integer) else np.inf
    )
    invalid = (i2 < 0) | np.isinf(d2)
    dk = np.where(invalid, np.inf, d2)
    ik = np.where(invalid, sentinel, i2)
    order = np.lexsort((dk, ik), axis=-1)  # by id, then distance
    sid = np.take_along_axis(ik, order, axis=-1)
    sd = np.take_along_axis(dk, order, axis=-1)
    # carry the invalid mask through the sort rather than re-deriving it from
    # the sentinel: a VALID candidate whose id happens to equal the sentinel
    # value must survive (it sorts ahead of the invalid run by distance).
    sinv = np.take_along_axis(invalid, order, axis=-1)
    dup = np.concatenate(
        [np.zeros((R, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1
    )
    sd = np.where(dup | sinv, np.inf, sd)
    order = np.lexsort((sid, sd), axis=-1)  # by distance, then id
    kk = min(k, C)
    take = order[:, :kk]
    out_d = np.full((R, k), np.inf, dtype=dists.dtype)
    out_i = np.full((R, k), -1, dtype=ids.dtype)
    out_d[:, :kk] = np.take_along_axis(sd, take, axis=-1)
    out_i[:, :kk] = np.where(
        np.isinf(out_d[:, :kk]), -1, np.take_along_axis(sid, take, axis=-1)
    )
    return out_d.reshape(*lead, k), out_i.reshape(*lead, k)


# lanns: dims[C<=16_384, k<=200]
def merge_topk_disjoint_np(dists: np.ndarray, ids: np.ndarray, k: int):
    """Dedup-FREE top-k merge: one introselect + one partial sort per row.

    Valid only when candidate ids are disjoint across the merged lists — in
    LANNS that is exactly virtual spill, where every point lives in one
    (shard, segment) — so the O(C log C) lexsort-dedup of
    ``merge_topk_vec`` degenerates to selection.  The quantized two-stage
    executor merges its exact per-lane results through this path.  Same
    output contract: ascending by distance, (+inf, -1) padding.  Tie ORDER
    among equal distances may differ from ``merge_topk_vec`` (which
    tie-breaks by id); with distinct distances the outputs are identical
    (asserted in tests/test_merge_vec.py).
    """
    *lead, C = dists.shape
    d2 = dists.reshape(-1, C)
    i2 = np.where(np.isinf(d2), -1, ids.reshape(-1, C))
    kk = min(k, C)
    if kk < C:
        sel = np.argpartition(d2, kk - 1, axis=1)[:, :kk]
        d2 = np.take_along_axis(d2, sel, axis=1)
        i2 = np.take_along_axis(i2, sel, axis=1)
    order = np.argsort(d2, axis=1, kind="stable")
    out_d = np.full((d2.shape[0], k), np.inf, dtype=dists.dtype)
    out_i = np.full((d2.shape[0], k), -1, dtype=ids.dtype)
    out_d[:, :kk] = np.take_along_axis(d2, order, axis=1)
    out_i[:, :kk] = np.take_along_axis(i2, order, axis=1)
    return out_d.reshape(*lead, k), out_i.reshape(*lead, k)


def merge_topk_np(dists: np.ndarray, ids: np.ndarray, k: int):
    """Python-loop reference of merge_topk (ground truth for parity tests)."""
    *lead, C = dists.shape
    dists2 = dists.reshape(-1, C)
    ids2 = ids.reshape(-1, C)
    out_d = np.full((dists2.shape[0], k), np.inf, dtype=dists.dtype)
    out_i = np.full((dists2.shape[0], k), -1, dtype=ids.dtype)
    for r in range(dists2.shape[0]):
        seen: dict[int, float] = {}
        for d, i in zip(dists2[r], ids2[r]):
            if i < 0 or np.isinf(d):
                continue
            if i not in seen or d < seen[i]:
                seen[int(i)] = float(d)
        pairs = sorted((d, i) for i, d in seen.items())[:k]
        for c, (d, i) in enumerate(pairs):
            out_d[r, c] = d
            out_i[r, c] = i
    return out_d.reshape(*lead, k), out_i.reshape(*lead, k)


# lanns: dims[S<=64, m<=64, B<=4096, c<=1024, topk<=200]
def two_level_merge_np(
    seg_dists: np.ndarray,
    seg_ids: np.ndarray,
    topk: int,
    confidence: float = 0.95,
):
    """Full two-level merge (offline path).

    seg_dists/seg_ids: (S, m, B, c) per (shard, segment) candidates.
    Level 1 (inside shard): merge over segments -> (S, B, pstk).
    Level 2 (broker):       merge over shards   -> (B, topk).

    perShardTopK trims level-1 output; the paper propagates the *shard* level
    perShardTopK to segments rather than trimming per-segment (§5.3.2).
    """
    S, m, B, c = seg_dists.shape
    pstk = per_shard_topk(topk, S, confidence)
    shard_d = np.empty((S, B, pstk), dtype=seg_dists.dtype)
    shard_i = np.empty((S, B, pstk), dtype=seg_ids.dtype)
    for s in range(S):
        d = np.moveaxis(seg_dists[s], 0, -1).reshape(B, m * c)
        i = np.moveaxis(seg_ids[s], 0, -1).reshape(B, m * c)
        shard_d[s], shard_i[s] = merge_topk_vec(d, i, pstk)
    d = np.moveaxis(shard_d, 0, -1).reshape(B, S * pstk)
    i = np.moveaxis(shard_i, 0, -1).reshape(B, S * pstk)
    return merge_topk_vec(d, i, topk)

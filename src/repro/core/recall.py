"""Recall@k — the paper's quality metric.

"The recall, measured as the fraction of true k-nearest neighbors returned in
a result set of size k" (§1).  R@j in Tables 1/4 evaluates the top-j of the
returned set against the true top-j (topK is fixed at 100; R@j slices both)."""

from __future__ import annotations

import numpy as np


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray, k: int) -> float:
    """Mean fraction of true top-k found in predicted top-k.

    pred_ids, true_ids: (B, >=k) int arrays; -1 entries are ignored.
    """
    pred = pred_ids[:, :k]
    true = true_ids[:, :k]
    hits = 0
    total = 0
    for p, t in zip(pred, true):
        ts = {int(x) for x in t if x >= 0}
        if not ts:
            continue
        ps = {int(x) for x in p if x >= 0}
        hits += len(ts & ps)
        total += len(ts)
    return hits / max(total, 1)


def recall_table(pred_ids: np.ndarray, true_ids: np.ndarray, ks=(1, 5, 10, 15, 50, 100)):
    """Dict {k: R@k} — the row format of paper Tables 1 and 4."""
    kmax = min(pred_ids.shape[1], true_ids.shape[1])
    return {k: recall_at_k(pred_ids, true_ids, k) for k in ks if k <= kmax}

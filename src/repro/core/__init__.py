"""LANNS core — the paper's primary contribution.

Two-level partitioning (hash sharding + learned segmentation) over
per-partition ANN engines (HNSW or dense Pallas scan), with spill routing,
perShardTopK-trimmed two-level merging, and exact brute-force ground truth.
"""

from repro.core.brute_force import brute_force_topk
from repro.core.hnsw import (
    DEFAULT_BUILD_CHUNK,
    FrozenHNSW,
    HNSWConfig,
    HNSWIndex,
    HNSWIndexLegacy,
)
from repro.core.lanns import LannsConfig, LannsIndex
from repro.core.plan import (
    QueryPlan,
    QueryPlanExecutor,
    choose_merge_path,
    knob_groups,
)
from repro.core.merge import (
    merge_topk,
    merge_topk_disjoint_np,
    merge_topk_np,
    merge_topk_scatter,
    merge_topk_vec,
    per_shard_topk,
    two_level_merge_np,
)
from repro.core.recall import recall_at_k, recall_table
from repro.core.segmenter import (
    SegmenterConfig,
    RandomSegmenter,
    TreeSegmenter,
    expected_spill_fraction,
    failure_probability,
    make_segmenter,
)
from repro.core.sharding import TwoLevelPartitioner, hash_shard

__all__ = [
    "DEFAULT_BUILD_CHUNK",
    "HNSWConfig",
    "HNSWIndex",
    "HNSWIndexLegacy",
    "FrozenHNSW",
    "LannsConfig",
    "LannsIndex",
    "QueryPlan",
    "QueryPlanExecutor",
    "choose_merge_path",
    "knob_groups",
    "SegmenterConfig",
    "RandomSegmenter",
    "TreeSegmenter",
    "TwoLevelPartitioner",
    "brute_force_topk",
    "expected_spill_fraction",
    "failure_probability",
    "hash_shard",
    "make_segmenter",
    "merge_topk",
    "merge_topk_disjoint_np",
    "merge_topk_np",
    "merge_topk_scatter",
    "merge_topk_vec",
    "per_shard_topk",
    "recall_at_k",
    "recall_table",
    "two_level_merge_np",
]

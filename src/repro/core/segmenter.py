"""LANNS segmenters (paper §4.3): RS, RH, APD with virtual/physical spill.

A segmenter maps points to segments at index time, and queries to one-or-few
segments at query time.  The tree segmenters (RH, APD) learn a complete binary
tree of depth L (``2**L`` leaves = segments per shard).  At each internal node:

* a hyperplane direction ``h`` is chosen —
  - RH:  uniformly at random from the unit sphere (Randomized Partition
    Trees, Dasgupta & Sinha 2015);
  - APD: the second-largest right singular vector of the (subsampled) data
    matrix reaching that node — the practical sparsest-cut surrogate of
    McCartin-Lim et al. 2012 / Trevisan 2013 that the paper adopts (§4.3.3);
* the split point is ``median(X @ h)``;
* spill boundaries ``lo/hi`` are the ``0.5 ± alpha`` fractiles of ``X @ h``.

Insertion routes a point to ONE leaf (virtual spill) or to BOTH children
whenever its projection lies in [lo, hi] (physical spill).  A query with
virtual spill is routed to both children when its projection lies in [lo, hi]
(paper Figure 3); with physical spill the query goes to exactly one leaf
because the data was duplicated instead.

The learned tree is stored as flat arrays in binary-heap order (node i has
children 2i+1 / 2i+2), so routing is fully vectorized: a (B, n_nodes)
projection matmul followed by L levels of boolean mask propagation — this is
the form used on-device by the TPU serving path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.common.utils import stable_hash_u64


@dataclasses.dataclass(frozen=True)
class SegmenterConfig:
    kind: str = "rh"  # 'rs' | 'rh' | 'apd'
    num_segments: int = 8  # must be a power of two for tree segmenters
    alpha: float = 0.15  # spill fractile (paper uses 0.15 => ~30% spill/level)
    spill: str = "virtual"  # 'virtual' | 'physical' | 'none'
    seed: int = 0
    apd_power_iters: int = 20  # power-iteration steps for the APD direction
    sample_size: int = 250_000  # subsample for learning (paper uses 250k)

    @property
    def depth(self) -> int:
        d = int(np.log2(self.num_segments))
        if 2**d != self.num_segments:
            raise ValueError("tree segmenters need power-of-two num_segments")
        return d


# ---------------------------------------------------------------------------


class RandomSegmenter:
    """RS (§4.3.1): modulo/hash segmenter. Data-independent.

    Points go to ``hash(key) % m``; queries go to ALL segments (no locality).
    """

    def __init__(self, config: SegmenterConfig):
        self.config = config
        self.kind = "rs"

    def fit(self, data: np.ndarray) -> "RandomSegmenter":
        return self  # nothing to learn

    def route_points(self, x: np.ndarray, keys: Optional[np.ndarray] = None):
        """Returns a (n, m) bool mask (RS: exactly one True per row)."""
        m = self.config.num_segments
        n = x.shape[0]
        if keys is None:
            keys = np.arange(n, dtype=np.uint64)
        seg = (stable_hash_u64(keys, salt=self.config.seed) % np.uint64(m)).astype(
            np.int64
        )
        mask = np.zeros((n, m), dtype=bool)
        mask[np.arange(n), seg] = True
        return mask

    def route_queries(self, q: np.ndarray) -> np.ndarray:
        return np.ones((q.shape[0], self.config.num_segments), dtype=bool)

    def tree_arrays(self):
        return None


# ---------------------------------------------------------------------------


def _rh_direction(rng: np.random.Generator, d: int) -> np.ndarray:
    h = rng.standard_normal(d).astype(np.float32)
    return h / np.linalg.norm(h)


def _apd_direction(x: np.ndarray, iters: int, rng: np.random.Generator) -> np.ndarray:
    """Second-largest right singular vector of x via block power iteration.

    The paper computes the 2nd right singular vector of D (via Spark MLlib
    SVD).  We run subspace iteration on D^T D with a 2-column block, which is
    cheap (O(n d) per iter) and deterministic given the seed.  Falls back to
    the exact SVD for small problems to keep tests tight.
    """
    n, d = x.shape
    if n * d <= 2_000_000 or d <= 64:
        # exact — numpy SVD of the (n, d) block
        _, _, vt = np.linalg.svd(x, full_matrices=False)
        v = vt[1] if vt.shape[0] > 1 else vt[0]
        return (v / np.linalg.norm(v)).astype(np.float32)
    v = rng.standard_normal((d, 2)).astype(np.float64)
    v, _ = np.linalg.qr(v)
    xf = x.astype(np.float64)
    for _ in range(iters):
        w = xf.T @ (xf @ v)  # (d, 2)
        v, _ = np.linalg.qr(w)
    # order columns by Rayleigh quotient, return the 2nd
    scores = np.einsum("dk,dk->k", v, xf.T @ (xf @ v))
    order = np.argsort(-scores)
    v2 = v[:, order[1]]
    return (v2 / np.linalg.norm(v2)).astype(np.float32)


class TreeSegmenter:
    """RH / APD hyperplane-tree segmenter with spill (paper §4.3.2-4.3.3).

    Flat-array tree (heap order). ``n_internal = num_segments - 1``.
      hyperplanes  (n_internal, d) float32
      split        (n_internal,)  — median of projections at that node
      lo, hi       (n_internal,)  — 0.5∓/±alpha fractiles (spill band)
    """

    def __init__(self, config: SegmenterConfig):
        if config.kind not in ("rh", "apd"):
            raise ValueError(config.kind)
        self.config = config
        self.kind = config.kind
        self.hyperplanes: Optional[np.ndarray] = None
        self.split: Optional[np.ndarray] = None
        self.lo: Optional[np.ndarray] = None
        self.hi: Optional[np.ndarray] = None

    # -- learning -----------------------------------------------------------

    def fit(self, data: np.ndarray) -> "TreeSegmenter":
        cfg = self.config
        rng = np.random.default_rng(cfg.seed)
        data = np.asarray(data, dtype=np.float32)
        if data.shape[0] > cfg.sample_size:
            idx = rng.choice(data.shape[0], cfg.sample_size, replace=False)
            data = data[idx]
        d = data.shape[1]
        n_internal = cfg.num_segments - 1
        H = np.zeros((n_internal, d), dtype=np.float32)
        S = np.zeros(n_internal, dtype=np.float32)
        LO = np.zeros(n_internal, dtype=np.float32)
        HI = np.zeros(n_internal, dtype=np.float32)

        # recursive median splits; node 0 is the root.
        def build(node: int, rows: np.ndarray):
            if node >= n_internal:
                return
            x = data[rows]
            if self.kind == "rh":
                h = _rh_direction(rng, d)
            else:
                h = _apd_direction(x, cfg.apd_power_iters, rng)
            u = x @ h
            S[node] = np.median(u)
            LO[node] = np.quantile(u, 0.5 - cfg.alpha)
            HI[node] = np.quantile(u, 0.5 + cfg.alpha)
            H[node] = h
            left = rows[u < S[node]]
            right = rows[u >= S[node]]
            build(2 * node + 1, left)
            build(2 * node + 2, right)

        build(0, np.arange(data.shape[0]))
        self.hyperplanes, self.split, self.lo, self.hi = H, S, LO, HI
        return self

    def _require_fit(self):
        if self.hyperplanes is None:
            raise RuntimeError("segmenter not fitted")

    # -- routing ------------------------------------------------------------

    def _route(self, x: np.ndarray, spill_band: bool) -> np.ndarray:
        """Tree routing, vectorized.  Returns (n, num_segments) bool mask.

        spill_band=True routes a row to BOTH children when its projection is
        inside [lo, hi] at that node; False uses the pure median split.
        """
        self._require_fit()
        cfg = self.config
        n = x.shape[0]
        proj = x.astype(np.float32) @ self.hyperplanes.T  # (n, n_internal)
        # mask over nodes of the implicit complete tree, level by level
        level_nodes = [0]
        mask = {0: np.ones(n, dtype=bool)}
        for _ in range(cfg.depth):
            next_mask = {}
            for node in level_nodes:
                m = mask[node]
                p = proj[:, node]
                if spill_band:
                    go_left = p <= self.hi[node]
                    go_right = p >= self.lo[node]
                else:
                    go_left = p < self.split[node]
                    go_right = ~go_left
                l, r = 2 * node + 1, 2 * node + 2
                next_mask[l] = next_mask.get(l, False) | (m & go_left)
                next_mask[r] = next_mask.get(r, False) | (m & go_right)
            mask = next_mask
            level_nodes = sorted(mask.keys())
        n_internal = cfg.num_segments - 1
        out = np.zeros((n, cfg.num_segments), dtype=bool)
        for node in level_nodes:
            out[:, node - n_internal] = mask[node]
        return out

    def route_points(self, x: np.ndarray, keys: Optional[np.ndarray] = None):
        """(n, m) bool — one leaf per point (virtual) or spill band (physical)."""
        physical = self.config.spill == "physical"
        return self._route(x, spill_band=physical)

    def route_queries(self, q: np.ndarray) -> np.ndarray:
        """(B, m) bool — spill band for virtual spill, single leaf otherwise."""
        virtual = self.config.spill == "virtual"
        return self._route(q, spill_band=virtual)

    def tree_arrays(self):
        """Arrays for the on-device (jit) router in serve/retrieval.py."""
        self._require_fit()
        return {
            "hyperplanes": self.hyperplanes,
            "split": self.split,
            "lo": self.lo,
            "hi": self.hi,
            "depth": self.config.depth,
        }


# ---------------------------------------------------------------------------


def make_segmenter(config: SegmenterConfig):
    if config.kind == "rs":
        return RandomSegmenter(config)
    return TreeSegmenter(config)


def expected_spill_fraction(alpha: float, depth: int) -> float:
    """Expected fraction of queries routed to >1 segment after `depth` levels.

    Per level a query falls in the band with probability ~2*alpha; the paper
    quotes "~30% queries to both partitions at any level" for alpha=0.15.
    """
    return 1.0 - (1.0 - 2.0 * alpha) ** depth


def failure_probability(levels: np.ndarray, alpha: float, n: int) -> np.ndarray:
    """Paper Figure 4: P(L) ≈ sum_{l=1..L} 1 / (2 (0.5+alpha)^l n).

    The paper approximates Φ'_m ≈ 1/(2 alpha) ... and plots
    P(L) ≈ Σ_{l=1}^{L} 1/(2 (0.5+α)^l n) for n = 10_000.  We reproduce that
    exact curve for the Figure-4 benchmark.
    """
    levels = np.asarray(levels)
    out = np.zeros(levels.shape, dtype=np.float64)
    for i, L in np.ndenumerate(levels):
        ls = np.arange(1, int(L) + 1, dtype=np.float64)
        out[i] = np.sum(1.0 / (2.0 * (0.5 + alpha) ** ls * n))
    return out

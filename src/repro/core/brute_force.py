"""Distributed brute-force search (paper §5.4) — exact ground truth at scale.

The paper partitions the dataset over executors, computes partial results for
the whole query set against each partition, and merges by queryId.  Here each
"executor" is a corpus block (offline, host loop for low memory) or a mesh
shard (the distributed path in serve/retrieval.py); the partial top-k merge is
``merge_topk``.  The scoring inner loop is the same fused distance+top-k
kernel as serving, so ground-truth generation exercises the production path.
"""

from __future__ import annotations

import numpy as np

from repro.core.merge import merge_topk_vec
from repro.kernels import ops


def brute_force_topk(
    queries: np.ndarray,
    corpus: np.ndarray,
    k: int,
    metric: str = "l2",
    *,
    num_partitions: int = 1,
    query_block: int = 4096,
    backend: str = "auto",
):
    """Exact top-k via partitioned scan + two-level merge.

    queries (B, d), corpus (N, d) -> (dists (B, k), ids (B, k)).  ids index
    ``corpus`` rows.  num_partitions > 1 exercises the partial-result merge
    exactly as the Spark implementation does (each partition produces its own
    top-k, then results are merged by query id).
    """
    queries = np.asarray(queries, dtype=np.float32)
    corpus = np.asarray(corpus, dtype=np.float32)
    B, _ = queries.shape
    N = corpus.shape[0]
    bounds = np.linspace(0, N, num_partitions + 1).astype(np.int64)
    part_d = np.full((B, num_partitions, k), np.inf, dtype=np.float32)
    part_i = np.full((B, num_partitions, k), -1, dtype=np.int64)
    for p in range(num_partitions):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        if hi <= lo:
            continue
        kk = min(k, hi - lo)
        for qs in range(0, B, query_block):
            qe = min(qs + query_block, B)
            d, i = ops.distance_topk(
                queries[qs:qe], corpus[lo:hi], kk, metric, backend=backend
            )
            d, i = np.asarray(d), np.asarray(i, dtype=np.int64)
            part_d[qs:qe, p, :kk] = d
            part_i[qs:qe, p, :kk] = np.where(i >= 0, i + lo, -1)
    return merge_topk_vec(part_d.reshape(B, -1), part_i.reshape(B, -1), k)

"""LannsIndex — the end-to-end LANNS platform object (paper §5).

Composes the pieces exactly as the paper's offline framework does:

  1. ``fit``: learn ONE segmenter on a uniform subsample (§5.1) — shared by
     every shard, stored once.
  2. ``build``: two-level partition (hash shard → segment), then build an
     independent per-(shard, segment) engine **in parallel** (§5.2).  Engines:
     'hnsw' (the paper's choice) or 'scan' (TPU-native dense Pallas scan —
     DESIGN.md §2).  Builds are resumable: each partition artifact is written
     atomically with a manifest, so a preempted build restarts where it died
     (the paper's HDFS-temp-path fault-tolerance story, §5.3.1).
  3. ``query``: route queries (virtual spill), search only routed segments,
     segment-merge inside the shard, shard-merge at the broker with
     perShardTopK trimming (§5.3.2).

The distributed on-mesh serving path lives in repro/serve/retrieval.py; this
module is the offline/reference implementation that the paper benchmarks in
Tables 1-7 and that our benchmark harness mirrors.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Optional

import numpy as np

from repro.common.utils import Timer, next_pow2
from repro.core.hnsw import HNSWConfig, HNSWIndex
from repro.core.merge import merge_topk_vec, per_shard_topk
from repro.core.segmenter import SegmenterConfig
from repro.core.sharding import TwoLevelPartitioner
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class LannsConfig:
    """(n, m)-partitioning in the paper's notation: n shards x m segments.

    metric: 'l2' | 'ip' | 'cos' | 'mips'.  'mips' (beyond-paper) applies the
    augmented-vector reduction [Bachrach et al., RecSys'14]: corpus rows get
    an extra coordinate sqrt(M^2 - |x|^2) (queries get 0), turning max-inner-
    product into L2 NN — which is what hyperplane segmenters route well
    (raw-IP routing loses the norm component entirely).  Returned distances
    are converted back to inner products (negated, lower-is-better).
    """

    num_shards: int = 1
    num_segments: int = 8
    segmenter: str = "rh"  # 'rs' | 'rh' | 'apd'
    alpha: float = 0.15
    spill: str = "virtual"  # 'virtual' | 'physical'
    metric: str = "l2"
    engine: str = "hnsw"  # 'hnsw' | 'scan'
    hnsw_m: int = 16
    ef_construction: int = 100
    ef_search: int = 100
    topk_confidence: float = 0.95
    seed: int = 0
    segmenter_sample: int = 250_000

    def segmenter_config(self) -> SegmenterConfig:
        return SegmenterConfig(
            kind=self.segmenter,
            num_segments=self.num_segments,
            alpha=self.alpha,
            spill=self.spill,
            seed=self.seed,
            sample_size=self.segmenter_sample,
        )

    def hnsw_config(self) -> HNSWConfig:
        return HNSWConfig(
            M=self.hnsw_m,
            ef_construction=self.ef_construction,
            ef_search=self.ef_search,
            metric="l2" if self.metric == "mips" else self.metric,
            seed=self.seed,
        )


def _build_one_partition(args):
    """Worker: build one (shard, segment) engine.  Top-level for pickling."""
    (s, g, vectors, keys, engine, hnsw_cfg) = args
    t0 = time.perf_counter()
    if engine == "hnsw" and len(vectors) > 0:
        idx = HNSWIndex(hnsw_cfg, vectors.shape[1])
        idx.add_batch(vectors, keys)
        frozen = idx.freeze()
        payload = {
            "kind": "hnsw",
            "vectors": frozen.vectors,
            "levels": frozen.levels,
            "adj0": frozen.adj0,
            "entry": frozen.entry,
            "keys": frozen.keys,
            "level_nodes": frozen.level_nodes,
            "level_adj": frozen.level_adj,
            "level_loc": frozen.level_loc,
        }
    else:
        payload = {"kind": "scan", "vectors": vectors, "keys": keys}
    return s, g, payload, time.perf_counter() - t0


def _batched_scan_topk(queries: np.ndarray, vectors: np.ndarray, k: int, metric: str):
    """One fused distance+top-k call over a routed query batch.

    Goes through ``ops.distance_topk`` (Pallas kernel on TPU, blocked jnp
    scan elsewhere).  The batch is padded to the next power of two so the
    executor's per-(shard, segment) calls reuse a bounded set of jit traces
    instead of retracing for every routed-subset size.
    """
    B, D = queries.shape
    B_pad = next_pow2(B)
    qp = queries
    if B_pad != B:
        qp = np.zeros((B_pad, D), np.float32)
        qp[:B] = queries
    d, i = ops.distance_topk(qp, vectors, k, metric)
    return np.asarray(d)[:B], np.asarray(i)[:B].astype(np.int64)


class _Partition:
    """A built (shard, segment) engine."""

    def __init__(self, payload, config: LannsConfig):
        self.kind = payload["kind"]
        self.config = config
        self.keys = payload.get("keys")
        self.vectors = payload["vectors"]
        if self.kind == "hnsw":
            from repro.core.hnsw import FrozenHNSW

            self.frozen = FrozenHNSW(
                config=config.hnsw_config(),
                vectors=payload["vectors"],
                levels=payload["levels"],
                adj0=payload["adj0"],
                level_nodes=payload["level_nodes"],
                level_adj=payload["level_adj"],
                level_loc=payload["level_loc"],
                entry=int(payload["entry"]),
                keys=payload.get("keys"),
            )

    @property
    def size(self):
        return 0 if self.vectors is None else len(self.vectors)

    def search(self, queries: np.ndarray, k: int, ef: Optional[int] = None):
        if self.size == 0:
            B = queries.shape[0]
            return (
                np.full((B, k), np.inf, np.float32),
                np.full((B, k), -1, np.int64),
            )
        k_eff = min(k, self.size)
        if self.kind == "hnsw":
            d, i = self.frozen.search(queries, k_eff, ef=ef)
        else:
            metric = (
                "l2" if self.config.metric == "mips" else self.config.metric
            )
            d, i = _batched_scan_topk(queries, self.vectors, k_eff, metric)
            if self.keys is not None:
                i = np.where(i >= 0, self.keys[np.clip(i, 0, None)], -1)
        if k_eff < k:
            pad_d = np.full((queries.shape[0], k - k_eff), np.inf, np.float32)
            pad_i = np.full((queries.shape[0], k - k_eff), -1, np.int64)
            d = np.concatenate([d, pad_d], axis=1)
            i = np.concatenate([i.astype(np.int64), pad_i], axis=1)
        return d, i.astype(np.int64)


class LannsIndex:
    """End-to-end LANNS index: fit -> build -> query (+ save/load/resume)."""

    def __init__(self, config: LannsConfig):
        self.config = config
        self.partitioner = TwoLevelPartitioner(
            config.num_shards, config.segmenter_config()
        )
        self.partitions: dict[tuple, _Partition] = {}
        self.build_stats: dict = {}

    # -- build ---------------------------------------------------------------

    def fit(self, data: np.ndarray) -> "LannsIndex":
        with Timer() as t:
            self.partitioner.fit(data)
        self.build_stats["segmenter_fit_seconds"] = t.seconds
        return self

    def build(
        self,
        data: np.ndarray,
        keys: Optional[np.ndarray] = None,
        *,
        workers: int = 0,
        resume_dir: Optional[str] = None,
    ) -> "LannsIndex":
        """Partition + parallel per-partition index build.

        workers=0 builds in-process (deterministic single-thread); workers>0
        uses a process pool — one "executor" per partition, the paper's Spark
        model.  resume_dir enables checkpointed builds: finished partitions
        are persisted and skipped on restart.
        """
        cfg = self.config
        data = np.asarray(data, dtype=np.float32)
        if cfg.metric == "mips":
            # augmented-vector MIPS->L2 reduction; see LannsConfig docstring
            norms2 = np.einsum("nd,nd->n", data, data)
            self._mips_M2 = float(norms2.max())
            aug = np.sqrt(np.maximum(self._mips_M2 - norms2, 0.0))
            data = np.concatenate([data, aug[:, None]], axis=1)
        n = data.shape[0]
        if keys is None:
            keys = np.arange(n, dtype=np.int64)
        if not self.partitioner._fitted:
            self.fit(data)
        with Timer() as t_assign:
            assignment = self.partitioner.assign(data, keys)
        jobs = []
        per_partition_seconds = {}
        for s in range(cfg.num_shards):
            for g in range(cfg.num_segments):
                rows = assignment.rows[s][g]
                if resume_dir and self._partition_done(resume_dir, s, g):
                    self.partitions[(s, g)] = self._load_partition(resume_dir, s, g)
                    continue
                jobs.append(
                    (s, g, data[rows], keys[rows], cfg.engine, cfg.hnsw_config())
                )
        with Timer() as t_build:
            if workers and len(jobs) > 1:
                with ProcessPoolExecutor(max_workers=workers) as ex:
                    results = list(ex.map(_build_one_partition, jobs))
            else:
                results = [_build_one_partition(j) for j in jobs]
        for s, g, payload, secs in results:
            self.partitions[(s, g)] = _Partition(payload, cfg)
            per_partition_seconds[f"{s}/{g}"] = secs
            if resume_dir:
                self._save_partition(resume_dir, s, g, payload)
        self.build_stats.update(
            assign_seconds=t_assign.seconds,
            build_wall_seconds=t_build.seconds,
            per_partition_seconds=per_partition_seconds,
            partition_sizes=assignment.partition_sizes().tolist(),
            total_stored=assignment.total_stored,
            n_input=n,
            duplication_factor=assignment.total_stored / max(n, 1),
        )
        return self

    # -- query ---------------------------------------------------------------

    def query(
        self,
        queries: np.ndarray,
        topk: int,
        *,
        ef: Optional[int] = None,
        return_stats: bool = False,
    ):
        """Two-level partitioned search with perShardTopK (paper §5.3).

        Every query goes to every shard; within a shard it goes only to the
        segments its virtual-spill routing selects.  Returns (dists, ids)
        shaped (B, topk); optionally per-query routing stats.

        Batched executor: queries are grouped by routed segment, so each
        (shard, segment) partition runs ONE batched search over exactly its
        routed queries; candidates land in compact per-route slots (sized by
        the worst-case route count, not num_segments) and both merge levels
        run as single vectorized calls over all (query, shard) rows.
        """
        cfg = self.config
        queries = np.asarray(queries, dtype=np.float32)
        if cfg.metric == "mips":
            if not hasattr(self, "_mips_M2"):
                raise RuntimeError(
                    "metric='mips' index has no stored M^2 — build() it, or "
                    "load() one saved with mips_M2 in its manifest"
                )
            queries = np.concatenate(
                [queries, np.zeros((queries.shape[0], 1), np.float32)], axis=1
            )
        B = queries.shape[0]
        S = cfg.num_shards
        seg_mask = self.partitioner.route_queries(queries)  # (B, m)
        pstk = per_shard_topk(topk, S, cfg.topk_confidence)
        segments_visited = seg_mask.sum(axis=1)
        # slot[b, g]: position of segment g among query b's routed segments.
        slot = np.cumsum(seg_mask, axis=1) - 1
        max_routes = max(int(segments_visited.max()) if B else 0, 1)
        cand_d = np.full((B, S, max_routes, pstk), np.inf, np.float32)
        cand_i = np.full((B, S, max_routes, pstk), -1, np.int64)
        for g in range(cfg.num_segments):
            sel = np.nonzero(seg_mask[:, g])[0]
            if sel.size == 0:
                continue
            q_sel = queries[sel]
            sl = slot[sel, g]
            for s in range(S):
                part = self.partitions.get((s, g))
                if part is None or part.size == 0:
                    continue
                # the paper propagates the SHARD-level perShardTopK to the
                # segments (never a per-segment trim) — §5.3.2.
                d, i = part.search(q_sel, pstk, ef=ef)
                cand_d[sel, s, sl] = d
                cand_i[sel, s, sl] = i
        # level-1: segment merge inside each shard, all (query, shard) rows
        # in one vectorized call.
        shard_d, shard_i = merge_topk_vec(
            cand_d.reshape(B * S, max_routes * pstk),
            cand_i.reshape(B * S, max_routes * pstk),
            pstk,
        )
        # level-2: broker merge over shards.
        out_d, out_i = merge_topk_vec(
            shard_d.reshape(B, S * pstk), shard_i.reshape(B, S * pstk), topk
        )
        if cfg.metric == "mips":
            # convert augmented-L2 distances back to (negated) inner products:
            # d^2 = M^2 + |q|^2 - 2<q, x>  =>  -<q, x> = (d^2 - M^2 - |q|^2)/2
            qn = np.einsum("bd,bd->b", queries[:, :-1], queries[:, :-1])
            out_d = np.where(
                np.isfinite(out_d),
                (out_d - self._mips_M2 - qn[:, None]) / 2.0,
                np.inf,
            )
        if return_stats:
            return out_d, out_i, {
                "per_shard_topk": pstk,
                "mean_segments_visited": float(segments_visited.mean()),
                "max_segments_visited": int(segments_visited.max()),
            }
        return out_d, out_i

    # -- persistence (atomic, resumable) --------------------------------------

    @staticmethod
    def _partition_path(root, s, g):
        return os.path.join(root, f"shard{s:04d}_seg{g:04d}.npz")

    def _partition_done(self, root, s, g):
        return os.path.exists(self._partition_path(root, s, g))

    def _save_partition(self, root, s, g, payload):
        os.makedirs(root, exist_ok=True)
        path = self._partition_path(root, s, g)
        arrays = {"kind": np.array(payload["kind"])}
        for key, val in payload.items():
            if key == "kind" or val is None:
                continue
            if isinstance(val, list):
                for li, arr in enumerate(val):
                    arrays[f"{key}__{li}"] = arr
                arrays[f"{key}__len"] = np.array(len(val))
            else:
                arrays[key] = np.asarray(val)
        fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
        os.close(fd)
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)  # atomic publish

    def _load_partition(self, root, s, g):
        with np.load(self._partition_path(root, s, g), allow_pickle=False) as z:
            payload = {}
            lists: dict[str, dict[int, np.ndarray]] = {}
            for key in z.files:
                if "__" in key:
                    base, idx = key.rsplit("__", 1)
                    if idx == "len":
                        payload.setdefault(base, [None] * int(z[key]))
                    else:
                        lists.setdefault(base, {})[int(idx)] = z[key]
                elif key == "kind":
                    payload["kind"] = str(z[key])
                else:
                    payload[key] = z[key]
            for base, items in lists.items():
                payload.setdefault(base, [None] * len(items))
                for idx, arr in items.items():
                    payload[base][idx] = arr
        for key in ("level_nodes", "level_adj", "level_loc"):
            payload.setdefault(key, [])
        return _Partition(payload, self.config)

    def save(self, root: str):
        os.makedirs(root, exist_ok=True)
        for (s, g), part in self.partitions.items():
            if not self._partition_done(root, s, g):
                payload = {"kind": part.kind, "vectors": part.vectors, "keys": part.keys}
                if part.kind == "hnsw":
                    fr = part.frozen
                    payload.update(
                        levels=fr.levels, adj0=fr.adj0, entry=fr.entry,
                        level_nodes=fr.level_nodes, level_adj=fr.level_adj,
                        level_loc=fr.level_loc,
                    )
                self._save_partition(root, s, g, payload)
        seg = self.partitioner.segmenter
        tree = seg.tree_arrays()
        manifest = {
            "config": dataclasses.asdict(self.config),
            "partitions": sorted([f"{s}/{g}" for s, g in self.partitions]),
            "build_stats": {
                k: v for k, v in self.build_stats.items() if k != "per_partition_seconds"
            },
            # mips needs the corpus max-norm M^2 to convert augmented-L2
            # distances back to inner products at query time.
            "mips_M2": getattr(self, "_mips_M2", None),
        }
        with open(os.path.join(root, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2, default=str)
        if tree is not None:
            np.savez(
                os.path.join(root, "segmenter.npz"),
                hyperplanes=tree["hyperplanes"], split=tree["split"],
                lo=tree["lo"], hi=tree["hi"],
            )

    @classmethod
    def load(cls, root: str) -> "LannsIndex":
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        config = LannsConfig(**manifest["config"])
        index = cls(config)
        if manifest.get("mips_M2") is not None:
            index._mips_M2 = float(manifest["mips_M2"])
        seg_path = os.path.join(root, "segmenter.npz")
        if os.path.exists(seg_path):
            with np.load(seg_path) as z:
                seg = index.partitioner.segmenter
                seg.hyperplanes = z["hyperplanes"]
                seg.split = z["split"]
                seg.lo = z["lo"]
                seg.hi = z["hi"]
        index.partitioner._fitted = True
        for pstr in manifest["partitions"]:
            s, g = (int(v) for v in pstr.split("/"))
            index.partitions[(s, g)] = index._load_partition(root, s, g)
        index.build_stats = manifest.get("build_stats", {})
        return index

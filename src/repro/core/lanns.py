"""LannsIndex — the end-to-end LANNS platform object (paper §5).

Composes the pieces exactly as the paper's offline framework does:

  1. ``fit``: learn ONE segmenter on a uniform subsample (§5.1) — shared by
     every shard, stored once.
  2. ``build``: two-level partition (hash shard → segment), then build an
     independent per-(shard, segment) engine **in parallel** (§5.2).  Engines:
     'hnsw' (the paper's choice) or 'scan' (TPU-native dense Pallas scan —
     DESIGN.md §2).  Builds are resumable: each partition artifact is written
     atomically with a manifest, so a preempted build restarts where it died
     (the paper's HDFS-temp-path fault-tolerance story, §5.3.1).
  3. ``query``: route queries (virtual spill), search only routed segments,
     segment-merge inside the shard, shard-merge at the broker with
     perShardTopK trimming (§5.3.2).

The distributed on-mesh serving path lives in repro/serve/retrieval.py; this
module is the offline/reference implementation that the paper benchmarks in
Tables 1-7 and that our benchmark harness mirrors.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.common.utils import (
    Timer,
    next_pow2,
    next_pow2_quarter,
)
from repro.core.hnsw import DEFAULT_BUILD_CHUNK, HNSWConfig, HNSWIndex
from repro.core.merge import per_shard_topk
from repro.core.plan import (
    QueryPlanExecutor,
    choose_merge_path,
    knob_groups,
    query_stats,
)
from repro.core.segmenter import SegmenterConfig
from repro.core.sharding import TwoLevelPartitioner
from repro.kernels import ops

# Scale-safety contract (repro.analysis.scalecheck): paper-scale bounds —
# batches to 4096 queries, per-request topk <= 200, up to 4096 partitions
# of up to 2^25 pow2-padded rows each.
# lanns: dims[B<=4096, k<=200, P<=4096, n_pad<=33_554_432]

#: flattened ids (partition row offsets, adjacency entries) live on an
#: int32 device lattice; every `pi * n_pad + row` must stay below this
_INT32_MAX = np.iinfo(np.int32).max


@dataclasses.dataclass(frozen=True)
class LannsConfig:
    """(n, m)-partitioning in the paper's notation: n shards x m segments.

    metric: 'l2' | 'ip' | 'cos' | 'mips'.  'mips' (beyond-paper) applies the
    augmented-vector reduction [Bachrach et al., RecSys'14]: corpus rows get
    an extra coordinate sqrt(M^2 - |x|^2) (queries get 0), turning max-inner-
    product into L2 NN — which is what hyperplane segmenters route well
    (raw-IP routing loses the norm component entirely).  Returned distances
    are converted back to inner products (negated, lower-is-better).

    quantized: 'none' | 'q8' — 'q8' serves partitions from int8 codes with
    an exact fp32 re-rank, cutting the resident corpus ~4x with
    near-identical recall.  Composes with BOTH engines: 'scan' runs the
    two-stage int8 scan (candidates = ``rerank_factor * perShardTopK`` per
    routed lane), 'hnsw' runs the quantized beam (graph walk over int8
    codes, then the same shared exact re-rank stage).
    rerank_store: where the exact fp32 originals live for stage 2 —
    'host' (numpy / mmap-friendly), 'device', or 'auto' (host on CPU,
    device on TPU).
    """

    num_shards: int = 1
    num_segments: int = 8
    segmenter: str = "rh"  # 'rs' | 'rh' | 'apd'
    alpha: float = 0.15
    spill: str = "virtual"  # 'virtual' | 'physical'
    metric: str = "l2"
    engine: str = "hnsw"  # 'hnsw' | 'scan'
    hnsw_m: int = 16
    ef_construction: int = 100
    ef_search: int = 100
    topk_confidence: float = 0.95
    seed: int = 0
    segmenter_sample: int = 250_000
    quantized: str = "none"  # 'none' | 'q8'
    rerank_factor: int = 2
    rerank_store: str = "auto"  # 'auto' | 'host' | 'device'

    def segmenter_config(self) -> SegmenterConfig:
        return SegmenterConfig(
            kind=self.segmenter,
            num_segments=self.num_segments,
            alpha=self.alpha,
            spill=self.spill,
            seed=self.seed,
            sample_size=self.segmenter_sample,
        )

    def hnsw_config(self) -> HNSWConfig:
        return HNSWConfig(
            M=self.hnsw_m,
            ef_construction=self.ef_construction,
            ef_search=self.ef_search,
            metric="l2" if self.metric == "mips" else self.metric,
            seed=self.seed,
        )


def _build_one_partition(args):
    """Worker: build one (shard, segment) engine.  Top-level for pickling."""
    (s, g, vectors, keys, engine, hnsw_cfg, chunk) = args
    t0 = time.perf_counter()
    if engine == "hnsw" and len(vectors) > 0:
        idx = HNSWIndex(hnsw_cfg, vectors.shape[1])
        idx.add_batch(vectors, keys, chunk=chunk)
        frozen = idx.freeze()
        payload = {
            "kind": "hnsw",
            "vectors": frozen.vectors,
            "levels": frozen.levels,
            "adj0": frozen.adj0,
            "entry": frozen.entry,
            "keys": frozen.keys,
            "upper_adj": frozen.upper_adj,
        }
    else:
        payload = {"kind": "scan", "vectors": vectors, "keys": keys}
    return s, g, payload, time.perf_counter() - t0


def _summarize_seconds(secs: list) -> dict:
    """Compact build-cost summary persisted in manifests in place of the
    raw per-partition timing dict (which scales with partition count)."""
    if not secs:
        return {}
    return {
        "min": float(np.min(secs)),
        "median": float(np.median(secs)),
        "max": float(np.max(secs)),
        "total": float(np.sum(secs)),
        "count": len(secs),
    }


def _merge_seconds_summary(prior: dict, cur: dict) -> dict:
    """min/max/total/count merge exactly across build runs; the merged
    median is count-weighted (raw times are deliberately not persisted)."""
    if not prior or not prior.get("count"):
        return cur
    if not cur or not cur.get("count"):
        return prior
    n0, n1 = prior["count"], cur["count"]
    return {
        "min": min(prior["min"], cur["min"]),
        "median": (prior["median"] * n0 + cur["median"] * n1) / (n0 + n1),
        "max": max(prior["max"], cur["max"]),
        "total": prior["total"] + cur["total"],
        "count": n0 + n1,
    }


def _batched_scan_topk(
    queries: np.ndarray,
    vectors: np.ndarray,
    k: int,
    metric: str,
    n_valid: Optional[int] = None,
):
    """One fused distance+top-k call over a routed query batch.

    Goes through ``ops.distance_topk`` (Pallas kernel on TPU, blocked jnp
    scan elsewhere).  The batch is padded to the next power of two AND the
    corpus arrives padded to a shared pow2 size bucket (``n_valid`` real
    rows), so the executor's per-(shard, segment) calls reuse a bounded set
    of jit traces — O(log B x log N buckets) — instead of retracing for
    every (routed-subset size, partition size) pair.
    """
    B, D = queries.shape
    B_pad = next_pow2(B)
    qp = queries
    if B_pad != B:
        qp = np.zeros((B_pad, D), np.float32)
        qp[:B] = queries
    d, i = ops.distance_topk(qp, vectors, k, metric, n_valid=n_valid)  # lanns: noqa[LANNS033] -- k ranges over the finite per-request knob set (<= 200), capped by partition size; not corpus-dependent
    return np.asarray(d)[:B], np.asarray(i)[:B].astype(np.int64)  # lanns: noqa[LANNS003] -- the single designed host sync per routed scan batch


class _Partition:
    """A built (shard, segment) engine."""

    def __init__(self, payload, config: LannsConfig):
        self.kind = payload["kind"]
        self.config = config
        self.keys = payload.get("keys")
        self.vectors = payload["vectors"]
        self._scan_pad = None  # lazily bucketed scan corpus (pow2 rows)
        self.q8 = None
        if self.kind == "hnsw":
            from repro.core.hnsw import FrozenHNSW

            self.frozen = FrozenHNSW(
                config=config.hnsw_config(),
                vectors=payload["vectors"],
                levels=payload["levels"],
                adj0=payload["adj0"],
                upper_adj=payload["upper_adj"],
                entry=int(payload["entry"]),
                keys=payload.get("keys"),
            )
            if config.quantized == "q8" and self.size > 0:
                # quantized beam codes: frozen vectors are already
                # metric-prepped (cos rows normalized at build, mips rows
                # augmented), so encode as-is — 'ip' for cos avoids a
                # second normalization pass inside the codec.
                hm = config.hnsw_config().metric
                self.q8 = self._q8_from_payload(
                    payload, self.frozen.vectors, "l2" if hm == "l2" else "ip"
                )
        elif config.quantized == "q8" and self.size > 0:
            q8_metric = "l2" if config.metric == "mips" else config.metric
            self.q8 = self._q8_from_payload(payload, self.vectors, q8_metric)

    @staticmethod
    def _q8_from_payload(payload, vectors, q8_metric):
        from repro.quant.codec import Q8Corpus, quantize_q8

        if payload.get("q8_codes") is not None:
            return Q8Corpus(
                codes=payload["q8_codes"],
                scales=payload["q8_scales"],
                norms2=payload["q8_norms2"],
                metric=q8_metric,
            )
        # legacy fp32 artifact (or fresh build): quantization is
        # deterministic, so encoding here == encoding at save time.
        return quantize_q8(vectors, q8_metric)

    @property
    def size(self):
        return 0 if self.vectors is None else len(self.vectors)

    def scan_corpus(self):
        """Scan corpus padded to its quarter-pow2 size bucket (cached).

        Shared buckets mean ``distance_topk`` traces are reused ACROSS
        segments; padding rows are masked via n_valid, so results are
        bit-identical to scanning the raw corpus.  Quarter-pow2 steps (the
        same grid the HNSW lanes and q8 codes use) cap the padded-copy and
        padded-gemm waste at 25% while keeping the trace count logarithmic.
        """
        if self._scan_pad is None:
            n_pad = next_pow2_quarter(self.size)
            if n_pad == self.size:
                self._scan_pad = self.vectors
            else:
                pad = np.zeros((n_pad, self.vectors.shape[1]), np.float32)
                pad[: self.size] = self.vectors
                self._scan_pad = pad
                # drop the unpadded copy: the view keeps every other use
                # (save, re-rank stores) working, so the only extra resident
                # bytes are the <=25% padding rows.
                self.vectors = pad[: self.size]
        return self._scan_pad

    # lanns: hotpath
    def search(
        self,
        queries: np.ndarray,
        k: int,
        ef: Optional[int] = None,
        *,
        n_pad: Optional[int] = None,
        l_pad: Optional[int] = None,
        legacy: bool = False,
    ):
        if self.size == 0:
            B = queries.shape[0]
            return (
                np.full((B, k), np.inf, np.float32),
                np.full((B, k), -1, np.int64),
            )
        k_eff = min(k, self.size)
        if self.kind == "hnsw":
            if legacy:
                # pre-device-resident behaviour: re-upload the graph per call
                # and trace per routed-subset size (before/after benchmarks)
                d, i = self.frozen.search(
                    queries, k_eff, ef=ef, cached=False, pad_queries=False
                )
            else:
                # full k even when size < k: the beam's (inf, -1) slots are
                # exactly the padding below, and a uniform static k keeps one
                # beam_search trace shared across unevenly-sized partitions.
                d, i = self.frozen.search(
                    queries, k, ef=ef, n_pad=n_pad, l_pad=l_pad
                )
                k_eff = k
        else:
            metric = (
                "l2" if self.config.metric == "mips" else self.config.metric
            )
            d, i = _batched_scan_topk(
                queries, self.scan_corpus(), k_eff, metric,
                n_valid=self.size,
            )
            if self.keys is not None:
                i = np.where(i >= 0, self.keys[np.clip(i, 0, None)], -1)
        if k_eff < k:
            pad_d = np.full((queries.shape[0], k - k_eff), np.inf, np.float32)
            pad_i = np.full((queries.shape[0], k - k_eff), -1, np.int64)
            d = np.concatenate([d, pad_d], axis=1)
            i = np.concatenate([i.astype(np.int64), pad_i], axis=1)
        return d, i.astype(np.int64)


class LannsIndex:
    """End-to-end LANNS index: fit -> build -> query (+ save/load/resume)."""

    def __init__(self, config: LannsConfig):
        if config.quantized not in ("none", "q8"):
            raise ValueError(
                f"quantized={config.quantized!r} — expected 'none' or 'q8'"
            )
        if config.rerank_store not in ("auto", "host", "device"):
            raise ValueError(
                f"rerank_store={config.rerank_store!r} — expected 'auto', "
                "'host' or 'device'"
            )
        self.config = config
        self.partitioner = TwoLevelPartitioner(
            config.num_shards, config.segmenter_config()
        )
        self.partitions: dict[tuple, _Partition] = {}
        self.build_stats: dict = {}
        # lazily-built stacked HNSW device pytrees, keyed by quantized flag
        self._stack: dict[bool, Optional[dict]] = {}
        self._q8_exec = None  # lazily-built two-stage quantized executor
        self._exec = QueryPlanExecutor(self)  # the staged query executor
        # optional obs.Telemetry bundle; None (default) = untimed serving
        self.telemetry = None

    def attach_telemetry(self, telemetry) -> "LannsIndex":
        """Attach (or, with None, detach) an ``obs.Telemetry`` bundle.

        Attached, the staged executor times its route/candidates/rerank/
        merge boundaries into the bundle's registry and span sink, labeled
        by engine/quantized/merge_path/pow2 batch bucket.  Detached — the
        default — the executor reads no clock at all, so results are
        bit-identical either way (asserted in tests/test_obs.py) and the
        off path carries zero overhead.
        """
        self.telemetry = telemetry
        return self

    # -- stacked HNSW serving state -------------------------------------------

    def _invalidate_stack(self):
        self._stack = {}
        self._q8_exec = None

    def _q8_executor(self):
        """Two-stage quantized scan executor over every non-empty scan
        partition (device codes upload once, cached like the HNSW stack)."""
        if self._q8_exec is None:
            from repro.quant.twostage import (
                QuantizedScanExecutor,
                _Q8Partition,
            )

            metric = (
                "l2" if self.config.metric == "mips" else self.config.metric
            )
            parts = {
                sg: _Q8Partition(p.q8, p.vectors, p.keys, metric)
                for sg, p in sorted(self.partitions.items())
                if p.kind == "scan" and p.size > 0 and p.q8 is not None
            }
            self._q8_exec = QuantizedScanExecutor(
                parts,
                metric,
                self.config.rerank_factor,
                self.config.rerank_store,
            )
        return self._q8_exec

    def _hnsw_parts(self):
        """Servable HNSW partitions, sorted by (shard, segment).

        The single source of the eligibility rule — both dispatch modes
        (stacked / partition) and the shared pad computation use it, so they
        can never disagree on which partitions the HNSW paths serve.
        """
        return sorted(
            (sg, p) for sg, p in self.partitions.items()
            if p.kind == "hnsw" and p.size > 0
        )

    def _hnsw_stack(self, quantized: bool = False):
        """Flat device pytree over every non-empty HNSW partition.

        Partition rows concatenate into shared flat arrays — vectors
        (P*n_pad, d), adj0 (P*n_pad, 2M), upper_adj (l_pad, P*n_pad, M) —
        with partition p owning rows [p*n_pad, p*n_pad + size).  One
        ``beam_search_flat`` trace then serves any mix of (partition, query)
        lanes.  Built host-side and uploaded ONCE, then cached for the life
        of the partitions.  Returns {} when the index has no HNSW partitions.

        ``quantized=True`` builds the int8-code variant for the q8 beam:
        ``vectors`` holds the codes (a quarter of the fp32 bytes resident
        on device), an extra ``norms2`` leaf carries the dequantized
        squared norms, and host-side per-partition ``scales`` (P, d) +
        ``stores`` (the shared exact-rerank stores) ride along.  The two
        variants cache independently — a q8 index never uploads fp32
        vectors at all.
        """
        key = bool(quantized)
        if self._stack.get(key) is not None:
            return self._stack[key]
        items = self._hnsw_parts()
        if not items or (quantized and items[0][1].q8 is None):
            self._stack[key] = {}
            return self._stack[key]
        P = len(items)
        n_pad, l_pad = self._hnsw_pads(items)
        if P * n_pad > _INT32_MAX:
            # adjacency entries and beam lane offsets address the flat row
            # space in int32 — past 2^31 rows the ids would silently wrap
            raise OverflowError(
                f"flat HNSW stack spans {P * n_pad} rows (P={P} x "
                f"n_pad={n_pad}) — exceeds the int32 row lattice; shard "
                "the index across hosts instead"
            )
        dim = items[0][1].frozen.vectors.shape[1]
        m0 = items[0][1].frozen.adj0.shape[1]
        M = items[0][1].frozen.upper_adj.shape[2]
        adj0 = np.full((P * n_pad, m0), -1, np.int32)
        upper = np.full((l_pad, P * n_pad, M), -1, np.int32)
        entry = np.zeros((P,), np.int32)
        keys = np.full((P * n_pad,), -1, np.int64)
        if quantized:
            vecs = np.zeros((P * n_pad, dim), np.int8)
            norms2 = np.zeros((P * n_pad,), np.float32)
            scales = np.ones((P, dim), np.float32)
        else:
            vecs = np.zeros((P * n_pad, dim), np.float32)
        for pi, (_, p) in enumerate(items):
            fr = p.frozen
            n = fr.size
            off = pi * n_pad
            if quantized:
                vecs[off: off + n] = p.q8.codes
                norms2[off: off + n] = p.q8.norms2
                scales[pi] = p.q8.scales
            else:
                vecs[off: off + n] = fr.vectors
            adj0[off: off + n] = fr.adj0
            upper[: fr.num_upper_levels, off: off + n] = fr.upper_adj
            entry[pi] = fr.entry
            keys[off: off + n] = (
                fr.keys if fr.keys is not None else np.arange(n, dtype=np.int64)
            )
        arrs = {
            "vectors": jnp.asarray(vecs),
            "adj0": jnp.asarray(adj0),
            "upper_adj": jnp.asarray(upper),
        }
        stack = {
            "arrs": arrs,
            "entry": entry,  # per-partition local entry node (host)
            "keys": keys,
            "index": {sg: pi for pi, (sg, _) in enumerate(items)},
            "n_pad": n_pad,
            "l_pad": l_pad,
        }
        if quantized:
            from repro.quant.rerank import ExactStore, resolve_store_mode

            # the extra pytree leaf keys the quantized beam's own jit trace
            arrs["norms2"] = jnp.asarray(norms2)
            stack["scales"] = scales
            stack["stores"] = [
                ExactStore(p.frozen.vectors, p.frozen.keys)
                for _, p in items
            ]
            stack["store_mode"] = resolve_store_mode(
                self.config.rerank_store
            )
        self._stack[key] = stack
        return stack

    def _hnsw_pads(self, items=None):
        """Shared (n_pad, l_pad) corpus buckets over the servable partitions."""
        if items is None:
            items = self._hnsw_parts()
        if not items:
            return None, None
        return (
            next_pow2(max(p.size for _, p in items)),
            max(p.frozen.num_upper_levels for _, p in items),
        )

    # -- build ---------------------------------------------------------------

    def fit(self, data: np.ndarray) -> "LannsIndex":
        with Timer() as t:
            self.partitioner.fit(data)
        self.build_stats["segmenter_fit_seconds"] = t.seconds
        return self

    def build(
        self,
        data: np.ndarray,
        keys: Optional[np.ndarray] = None,
        *,
        workers: int = 0,
        resume_dir: Optional[str] = None,
        chunk: int = DEFAULT_BUILD_CHUNK,
    ) -> "LannsIndex":
        """Partition + parallel per-partition index build.

        workers=0 builds in-process (deterministic single-thread); workers>0
        uses a process pool — one "executor" per partition, the paper's Spark
        model.  resume_dir enables checkpointed builds: finished partitions
        are persisted and skipped on restart.  ``chunk`` is the HNSW
        wavefront batch size (throughput knob only: the built graph is
        bit-identical for any chunk >= 1 and any worker count).
        """
        cfg = self.config
        data = np.asarray(data, dtype=np.float32)
        if cfg.metric == "mips":
            # augmented-vector MIPS->L2 reduction; see LannsConfig docstring
            norms2 = np.einsum("nd,nd->n", data, data)
            self._mips_M2 = float(norms2.max())
            aug = np.sqrt(np.maximum(self._mips_M2 - norms2, 0.0))
            data = np.concatenate([data, aug[:, None]], axis=1)
        n = data.shape[0]
        if keys is None:
            keys = np.arange(n, dtype=np.int64)
        if not self.partitioner._fitted:
            self.fit(data)
        with Timer() as t_assign:
            assignment = self.partitioner.assign(data, keys)
        jobs = []
        per_partition_seconds = {}
        for s in range(cfg.num_shards):
            for g in range(cfg.num_segments):
                rows = assignment.rows[s][g]
                if resume_dir and self._partition_done(resume_dir, s, g):
                    self.partitions[(s, g)] = self._load_partition(resume_dir, s, g)
                    continue
                jobs.append(
                    (s, g, data[rows], keys[rows], cfg.engine,
                     cfg.hnsw_config(), chunk)
                )
        with Timer() as t_build:
            if workers and len(jobs) > 1:
                with ProcessPoolExecutor(max_workers=workers) as ex:
                    results = list(ex.map(_build_one_partition, jobs))
            else:
                results = [_build_one_partition(j) for j in jobs]
        for s, g, payload, secs in results:
            self.partitions[(s, g)] = _Partition(payload, cfg)
            per_partition_seconds[f"{s}/{g}"] = secs
            if resume_dir:
                self._save_partition(resume_dir, s, g, payload)
        self._invalidate_stack()
        summary = _summarize_seconds(list(per_partition_seconds.values()))
        if resume_dir:
            # resumed builds keep their build-cost provenance: fold the
            # previous runs' summary (persisted in the manifest) into this
            # run's — per-partition times themselves are not persisted.
            summary = _merge_seconds_summary(
                self._prior_seconds_summary(resume_dir), summary
            )
        self.build_stats.update(
            assign_seconds=t_assign.seconds,
            build_wall_seconds=t_build.seconds,
            per_partition_seconds=per_partition_seconds,
            per_partition_seconds_summary=summary,
            partition_sizes=assignment.partition_sizes().tolist(),
            total_stored=assignment.total_stored,
            n_input=n,
            duplication_factor=assignment.total_stored / max(n, 1),
            build_workers=workers,
            build_chunk=chunk,
        )
        return self

    @staticmethod
    def _prior_seconds_summary(resume_dir: str) -> dict:
        manifest_path = os.path.join(resume_dir, "manifest.json")
        if not os.path.exists(manifest_path):
            return {}
        try:
            with open(manifest_path) as f:
                manifest = json.load(f)
        except (OSError, ValueError):
            return {}
        stats = manifest.get("build_stats") or {}
        return stats.get("per_partition_seconds_summary") or {}

    # -- query ---------------------------------------------------------------

    def warm_traces(
        self,
        max_batch: int,
        topk: int,
        *,
        ef: Optional[int] = None,
        knobs=None,
    ) -> "LannsIndex":
        """Pre-compile the serving trace set for batches up to ``max_batch``.

        Online serving forms micro-batches of ANY size <= max_batch, and the
        executor pads routed per-segment subsets to pow2 buckets — so the
        first live traffic would otherwise pay one XLA compile per unseen
        (subset-bucket, corpus-bucket) pair, hundreds of ms each, exactly the
        latencies a p99 sweep measures.  The bucket grid makes the full set
        enumerable: one ``query`` per pow2 batch size warms routing + merge +
        the stacked-HNSW / q8 paths, and for fp32 scan partitions a direct
        per-partition sweep covers every (pow2 subset, corpus bucket) combo
        regardless of how routing happens to split the batch.

        Per-request knobs: ``topk`` (and for HNSW ``ef``) are STATIC jit
        args, so every distinct knob pair a mixed workload serves has its
        own trace set — pass the workload's mix as ``knobs`` (an iterable
        of ``(topk, ef)`` pairs; None entries mean the defaults above) and
        each pair's grid is warmed too.  Without this, the first batch
        containing an unseen knob group compiles mid-window — the exact
        first-traffic poisoning this method exists to prevent.

        Coverage caveat: the per-partition sweep is exhaustive only for the
        fp32 scan engine.  q8 and HNSW indexes get best-effort whole-batch
        warming — their per-subset buckets depend on how routing splits each
        dummy batch, so rare residual compiles remain possible on first
        live traffic (extend the sweep to those executors before gating
        their p99s).
        """
        parts = [p for p in self.partitions.values() if p.size > 0]
        if not parts or max_batch < 1:
            return self
        cfg = self.config
        dim = parts[0].vectors.shape[1]
        qdim = dim - 1 if cfg.metric == "mips" else dim
        rng = np.random.default_rng(0)
        # iterate pow2 buckets up to next_pow2(max_batch): a live batch of
        # max_batch pads to that bucket, so stopping at max_batch itself
        # would leave the TOP bucket cold for non-pow2 max_batch.
        b_top = next_pow2(max_batch)
        dummy = rng.standard_normal((b_top, qdim)).astype(np.float32)
        pairs = [(topk, ef)]
        for tk_k, ef_k in knobs or ():
            pair = (topk if tk_k is None else int(tk_k),
                    ef if ef_k is None else int(ef_k))
            if pair not in pairs:
                pairs.append(pair)
        for tk_w, ef_w in pairs:
            b = 1
            while b <= b_top:
                self.query(dummy[:b], tk_w, ef=ef_w)
                b *= 2
        if cfg.engine == "scan" and cfg.quantized == "none":
            full = dummy
            if cfg.metric == "mips":
                full = np.concatenate(
                    [dummy, np.zeros((len(dummy), 1), np.float32)], axis=1
                )
            for tk_w, ef_w in pairs:
                pstk = per_shard_topk(
                    tk_w, cfg.num_shards, cfg.topk_confidence
                )
                for p in parts:
                    b = 1
                    while b <= b_top:
                        p.search(full[:b], pstk, ef=ef_w)
                        b *= 2
        return self

    # lanns: hotpath
    def query(
        self,
        queries: np.ndarray,
        topk,
        *,
        ef=None,
        return_stats: bool = False,
        hnsw_mode: str = "stacked",  # 'stacked' | 'partition' | 'legacy'
    ):
        """Two-level partitioned search with perShardTopK (paper §5.3).

        Every query goes to every shard; within a shard it goes only to the
        segments its virtual-spill routing selects.  Execution is the staged
        plan pipeline in ``repro.core.plan``: route -> candidates (fp32
        scan | q8 scan | hnsw beam | q8 hnsw beam) -> exact re-rank for the
        quantized paths -> merge (dedup-free or two-level, decided in ONE
        place by ``choose_merge_path``).

        Per-request knobs: ``topk`` and ``ef`` accept scalars OR per-request
        arrays of shape (B,) — a formed micro-batch may mix them freely.
        The executor splits the batch into homogeneous (topk, ef) groups,
        runs each through the single-knob pipeline (inputs pad to the
        existing pow2 trace buckets, so no new trace shapes appear) and
        reassembles — bit-identical to issuing each group as its own query.
        ``ef`` entries <= 0 mean "index default".  With mixed ``topk`` the
        outputs are shaped (B, max(topk)); row r carries topk[r] results
        then (+inf, -1) padding.

        Returns (dists, ids); optionally per-query routing stats.

        HNSW partitions additionally run device-resident and trace-stable,
        selected by ``hnsw_mode``:

        * 'stacked' (default) — all partitions packed into one flat padded
          pytree, ONE vmapped ``beam_search_flat`` call per query batch (no
          per-partition Python loop or host<->device sync);
        * 'partition' — per-partition calls against cached device arrays
          padded to shared (n, L) buckets (bounded trace count);
        * 'legacy' — the pre-device-resident path: graph re-uploaded and
          beam_search retraced per routed-subset size (kept as the
          before/after benchmark baseline and a parity oracle).
        """
        if hnsw_mode not in ("stacked", "partition", "legacy"):
            raise ValueError(
                f"hnsw_mode={hnsw_mode!r} — expected 'stacked', 'partition' "
                "or 'legacy'"
            )
        cfg = self.config
        if (
            cfg.quantized == "q8"
            and cfg.engine == "hnsw"
            and hnsw_mode != "stacked"
        ):
            raise ValueError(
                "quantized='q8' with engine='hnsw' serves only "
                "hnsw_mode='stacked' (the flat quantized beam)"
            )
        queries = np.asarray(queries, dtype=np.float32)
        if cfg.metric == "mips":
            if not hasattr(self, "_mips_M2"):
                raise RuntimeError(
                    "metric='mips' index has no stored M^2 — build() it, or "
                    "load() one saved with mips_M2 in its manifest"
                )
            queries = np.concatenate(
                [queries, np.zeros((queries.shape[0], 1), np.float32)], axis=1
            )
        B = queries.shape[0]
        if cfg.engine != "hnsw":
            # ef is an HNSW beam knob — the scan engine ignores it, so
            # normalizing it away BEFORE grouping keeps a formed micro-batch
            # whole instead of fragmenting it into bit-identical groups.
            ef = None
        scalar, groups = knob_groups(topk, ef, B)
        if scalar:
            tk, efv, _ = groups[0]
            return self._query_group(
                queries, tk, efv, return_stats, hnsw_mode
            )
        # mixed knobs: one homogeneous sub-query per group, rows reassembled
        # in place.  Output width is the widest topk; narrower rows carry
        # (+inf, -1) padding past their own topk.
        k_max = max((tk for tk, _, _ in groups), default=0)
        out_d = np.full((B, k_max), np.inf, np.float32)
        out_i = np.full((B, k_max), -1, np.int64)
        group_stats = []
        for tk, efv, rows in groups:
            res = self._query_group(
                queries[rows], tk, efv, return_stats, hnsw_mode
            )
            if return_stats:
                d, i, st = res
                group_stats.append((tk, len(rows), st))
            else:
                d, i = res
            out_d[rows, :tk] = d
            out_i[rows, :tk] = i
        if not return_stats:
            return out_d, out_i
        return out_d, out_i, self._combine_group_stats(group_stats, B)

    def _query_group(self, queries, topk, ef, return_stats, hnsw_mode):
        """One homogeneous (topk, ef) group through the staged executor."""
        cfg = self.config
        pstk = per_shard_topk(topk, cfg.num_shards, cfg.topk_confidence)
        if queries.shape[0] == 0:
            # well-formed empty outputs; routing/merge would otherwise choke
            # on zero-length reductions (segments_visited.max()).
            out_d = np.full((0, topk), np.inf, np.float32)
            out_i = np.full((0, topk), -1, np.int64)
            if return_stats:
                return out_d, out_i, query_stats(
                    pstk, np.zeros((0,), np.int64), choose_merge_path(cfg)
                )
            return out_d, out_i
        out_d, out_i, plan = self._exec.execute(queries, topk, ef, hnsw_mode)
        if return_stats:
            return out_d, out_i, query_stats(
                pstk, plan.segments_visited, plan.merge_path
            )
        return out_d, out_i

    def _combine_group_stats(self, group_stats, B):
        """Fold per-group stats into one batch-level dict (same schema)."""
        if not group_stats:
            # B == 0 with array knobs: same merge-path report as the scalar
            # B == 0 path (the decision is configuration, not batch, state)
            return query_stats(
                0, np.zeros((0,), np.int64),
                choose_merge_path(self.config), knob_groups_count=0,
            )
        stats = dict(group_stats[-1][2])  # trace counters: process-wide
        paths = {st["merge_path"] for _, _, st in group_stats}
        stats["merge_path"] = paths.pop() if len(paths) == 1 else "mixed"
        stats["knob_groups"] = len(group_stats)
        stats["per_shard_topk"] = max(
            st["per_shard_topk"] for _, _, st in group_stats
        )
        stats["mean_segments_visited"] = (
            sum(st["mean_segments_visited"] * n for _, n, st in group_stats)
            / max(B, 1)
        )
        stats["max_segments_visited"] = max(
            st["max_segments_visited"] for _, _, st in group_stats
        )
        return stats

    # -- persistence (atomic, resumable) --------------------------------------

    @staticmethod
    def _partition_path(root, s, g):
        return os.path.join(root, f"shard{s:04d}_seg{g:04d}.npz")

    def _partition_done(self, root, s, g):
        return os.path.exists(self._partition_path(root, s, g))

    def _save_partition(self, root, s, g, payload):
        os.makedirs(root, exist_ok=True)
        path = self._partition_path(root, s, g)
        arrays = {"kind": np.array(payload["kind"])}
        for key, val in payload.items():
            if key == "kind" or val is None:
                continue
            if isinstance(val, list):
                for li, arr in enumerate(val):
                    arrays[f"{key}__{li}"] = arr
                arrays[f"{key}__len"] = np.array(len(val))
            else:
                arrays[key] = np.asarray(val)
        fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
        os.close(fd)
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)  # atomic publish

    def _load_partition(self, root, s, g):
        with np.load(self._partition_path(root, s, g), allow_pickle=False) as z:
            payload = {}
            lists: dict[str, dict[int, np.ndarray]] = {}
            for key in z.files:
                if "__" in key:
                    base, idx = key.rsplit("__", 1)
                    if idx == "len":
                        payload.setdefault(base, [None] * int(z[key]))
                    else:
                        lists.setdefault(base, {})[int(idx)] = z[key]
                elif key == "kind":
                    payload["kind"] = str(z[key])
                else:
                    payload[key] = z[key]
            for base, items in lists.items():
                payload.setdefault(base, [None] * len(items))
                for idx, arr in items.items():
                    payload[base][idx] = arr
        if payload.get("kind") == "hnsw" and "upper_adj" not in payload:
            # legacy artifact (pre-stacked): rebuild the (L, n, M) stack from
            # the ragged per-level lists it stored.
            from repro.core.hnsw import stack_upper_adj

            payload["upper_adj"] = stack_upper_adj(
                payload.get("level_nodes", []),
                payload.get("level_adj", []),
                payload["vectors"].shape[0],
                self.config.hnsw_config().M,
            )
        return _Partition(payload, self.config)

    def save(self, root: str):
        os.makedirs(root, exist_ok=True)
        for (s, g), part in self.partitions.items():
            if not self._partition_done(root, s, g):
                payload = {"kind": part.kind, "vectors": part.vectors, "keys": part.keys}
                if part.kind == "hnsw":
                    fr = part.frozen
                    payload.update(
                        levels=fr.levels, adj0=fr.adj0, entry=fr.entry,
                        upper_adj=fr.upper_adj,
                    )
                if part.q8 is not None:
                    # quantized payload: int8 codes + per-dim scales +
                    # per-vector norm corrections; the fp32 ``vectors``
                    # above double as the exact re-rank store.
                    payload.update(
                        q8_codes=part.q8.codes,
                        q8_scales=part.q8.scales,
                        q8_norms2=part.q8.norms2,
                    )
                self._save_partition(root, s, g, payload)
        seg = self.partitioner.segmenter
        tree = seg.tree_arrays()
        manifest = {
            # v2 adds the optional q8_* quantized arrays per partition (and
            # the quantized/rerank_* config knobs); v1 artifacts load
            # unchanged — absent fields fall back to fp32 behaviour.
            "format_version": 2,
            "config": dataclasses.asdict(self.config),
            "partitions": sorted([f"{s}/{g}" for s, g in self.partitions]),
            "build_stats": {
                k: v for k, v in self.build_stats.items() if k != "per_partition_seconds"
            },
            # mips needs the corpus max-norm M^2 to convert augmented-L2
            # distances back to inner products at query time.
            "mips_M2": getattr(self, "_mips_M2", None),
        }
        with open(os.path.join(root, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2, default=str)
        if tree is not None:
            np.savez(
                os.path.join(root, "segmenter.npz"),
                hyperplanes=tree["hyperplanes"], split=tree["split"],
                lo=tree["lo"], hi=tree["hi"],
            )

    @classmethod
    def load(cls, root: str) -> "LannsIndex":
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        version = int(manifest.get("format_version", 1))
        if version > 2:
            raise ValueError(
                f"artifact format_version={version} is newer than this "
                "build understands (max 2)"
            )
        config = LannsConfig(**manifest["config"])
        index = cls(config)
        if manifest.get("mips_M2") is not None:
            index._mips_M2 = float(manifest["mips_M2"])
        seg_path = os.path.join(root, "segmenter.npz")
        if os.path.exists(seg_path):
            with np.load(seg_path) as z:
                seg = index.partitioner.segmenter
                seg.hyperplanes = z["hyperplanes"]
                seg.split = z["split"]
                seg.lo = z["lo"]
                seg.hi = z["hi"]
        index.partitioner._fitted = True
        for pstr in manifest["partitions"]:
            s, g = (int(v) for v in pstr.split("/"))
            index.partitions[(s, g)] = index._load_partition(root, s, g)
        index.build_stats = manifest.get("build_stats", {})
        return index

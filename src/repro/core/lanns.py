"""LannsIndex — the end-to-end LANNS platform object (paper §5).

Composes the pieces exactly as the paper's offline framework does:

  1. ``fit``: learn ONE segmenter on a uniform subsample (§5.1) — shared by
     every shard, stored once.
  2. ``build``: two-level partition (hash shard → segment), then build an
     independent per-(shard, segment) engine **in parallel** (§5.2).  Engines:
     'hnsw' (the paper's choice) or 'scan' (TPU-native dense Pallas scan —
     DESIGN.md §2).  Builds are resumable: each partition artifact is written
     atomically with a manifest, so a preempted build restarts where it died
     (the paper's HDFS-temp-path fault-tolerance story, §5.3.1).
  3. ``query``: route queries (virtual spill), search only routed segments,
     segment-merge inside the shard, shard-merge at the broker with
     perShardTopK trimming (§5.3.2).

The distributed on-mesh serving path lives in repro/serve/retrieval.py; this
module is the offline/reference implementation that the paper benchmarks in
Tables 1-7 and that our benchmark harness mirrors.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from concurrent.futures import ProcessPoolExecutor
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.common.utils import (
    Timer,
    jit_cache_size,
    next_pow2,
    next_pow2_quarter,
)
from repro.core.hnsw import HNSWConfig, HNSWIndex
from repro.core.merge import (
    merge_topk_disjoint_np,
    merge_topk_vec,
    per_shard_topk,
)
from repro.core.segmenter import SegmenterConfig
from repro.core.sharding import TwoLevelPartitioner
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class LannsConfig:
    """(n, m)-partitioning in the paper's notation: n shards x m segments.

    metric: 'l2' | 'ip' | 'cos' | 'mips'.  'mips' (beyond-paper) applies the
    augmented-vector reduction [Bachrach et al., RecSys'14]: corpus rows get
    an extra coordinate sqrt(M^2 - |x|^2) (queries get 0), turning max-inner-
    product into L2 NN — which is what hyperplane segmenters route well
    (raw-IP routing loses the norm component entirely).  Returned distances
    are converted back to inner products (negated, lower-is-better).

    quantized: 'none' | 'q8' — 'q8' serves scan partitions through the
    two-stage path (int8 candidate scan + exact fp32 re-rank of
    ``rerank_factor * perShardTopK`` candidates per routed lane), cutting
    the resident scan corpus ~4x with near-identical recall.
    rerank_store: where the exact fp32 originals live for stage 2 —
    'host' (numpy / mmap-friendly), 'device', or 'auto' (host on CPU,
    device on TPU).
    """

    num_shards: int = 1
    num_segments: int = 8
    segmenter: str = "rh"  # 'rs' | 'rh' | 'apd'
    alpha: float = 0.15
    spill: str = "virtual"  # 'virtual' | 'physical'
    metric: str = "l2"
    engine: str = "hnsw"  # 'hnsw' | 'scan'
    hnsw_m: int = 16
    ef_construction: int = 100
    ef_search: int = 100
    topk_confidence: float = 0.95
    seed: int = 0
    segmenter_sample: int = 250_000
    quantized: str = "none"  # 'none' | 'q8'
    rerank_factor: int = 2
    rerank_store: str = "auto"  # 'auto' | 'host' | 'device'

    def segmenter_config(self) -> SegmenterConfig:
        return SegmenterConfig(
            kind=self.segmenter,
            num_segments=self.num_segments,
            alpha=self.alpha,
            spill=self.spill,
            seed=self.seed,
            sample_size=self.segmenter_sample,
        )

    def hnsw_config(self) -> HNSWConfig:
        return HNSWConfig(
            M=self.hnsw_m,
            ef_construction=self.ef_construction,
            ef_search=self.ef_search,
            metric="l2" if self.metric == "mips" else self.metric,
            seed=self.seed,
        )


def _build_one_partition(args):
    """Worker: build one (shard, segment) engine.  Top-level for pickling."""
    (s, g, vectors, keys, engine, hnsw_cfg) = args
    t0 = time.perf_counter()
    if engine == "hnsw" and len(vectors) > 0:
        idx = HNSWIndex(hnsw_cfg, vectors.shape[1])
        idx.add_batch(vectors, keys)
        frozen = idx.freeze()
        payload = {
            "kind": "hnsw",
            "vectors": frozen.vectors,
            "levels": frozen.levels,
            "adj0": frozen.adj0,
            "entry": frozen.entry,
            "keys": frozen.keys,
            "upper_adj": frozen.upper_adj,
        }
    else:
        payload = {"kind": "scan", "vectors": vectors, "keys": keys}
    return s, g, payload, time.perf_counter() - t0


def _batched_scan_topk(
    queries: np.ndarray,
    vectors: np.ndarray,
    k: int,
    metric: str,
    n_valid: Optional[int] = None,
):
    """One fused distance+top-k call over a routed query batch.

    Goes through ``ops.distance_topk`` (Pallas kernel on TPU, blocked jnp
    scan elsewhere).  The batch is padded to the next power of two AND the
    corpus arrives padded to a shared pow2 size bucket (``n_valid`` real
    rows), so the executor's per-(shard, segment) calls reuse a bounded set
    of jit traces — O(log B x log N buckets) — instead of retracing for
    every (routed-subset size, partition size) pair.
    """
    B, D = queries.shape
    B_pad = next_pow2(B)
    qp = queries
    if B_pad != B:
        qp = np.zeros((B_pad, D), np.float32)
        qp[:B] = queries
    d, i = ops.distance_topk(qp, vectors, k, metric, n_valid=n_valid)
    return np.asarray(d)[:B], np.asarray(i)[:B].astype(np.int64)


class _Partition:
    """A built (shard, segment) engine."""

    def __init__(self, payload, config: LannsConfig):
        self.kind = payload["kind"]
        self.config = config
        self.keys = payload.get("keys")
        self.vectors = payload["vectors"]
        self._scan_pad = None  # lazily bucketed scan corpus (pow2 rows)
        self.q8 = None
        if self.kind == "hnsw":
            from repro.core.hnsw import FrozenHNSW

            self.frozen = FrozenHNSW(
                config=config.hnsw_config(),
                vectors=payload["vectors"],
                levels=payload["levels"],
                adj0=payload["adj0"],
                upper_adj=payload["upper_adj"],
                entry=int(payload["entry"]),
                keys=payload.get("keys"),
            )
        elif config.quantized == "q8" and self.size > 0:
            from repro.quant.codec import Q8Corpus, quantize_q8

            q8_metric = "l2" if config.metric == "mips" else config.metric
            if payload.get("q8_codes") is not None:
                self.q8 = Q8Corpus(
                    codes=payload["q8_codes"],
                    scales=payload["q8_scales"],
                    norms2=payload["q8_norms2"],
                    metric=q8_metric,
                )
            else:
                # legacy fp32 artifact (or fresh build): quantization is
                # deterministic, so encoding here == encoding at save time.
                self.q8 = quantize_q8(self.vectors, q8_metric)

    @property
    def size(self):
        return 0 if self.vectors is None else len(self.vectors)

    def scan_corpus(self):
        """Scan corpus padded to its quarter-pow2 size bucket (cached).

        Shared buckets mean ``distance_topk`` traces are reused ACROSS
        segments; padding rows are masked via n_valid, so results are
        bit-identical to scanning the raw corpus.  Quarter-pow2 steps (the
        same grid the HNSW lanes and q8 codes use) cap the padded-copy and
        padded-gemm waste at 25% while keeping the trace count logarithmic.
        """
        if self._scan_pad is None:
            n_pad = next_pow2_quarter(self.size)
            if n_pad == self.size:
                self._scan_pad = self.vectors
            else:
                pad = np.zeros((n_pad, self.vectors.shape[1]), np.float32)
                pad[: self.size] = self.vectors
                self._scan_pad = pad
                # drop the unpadded copy: the view keeps every other use
                # (save, re-rank stores) working, so the only extra resident
                # bytes are the <=25% padding rows.
                self.vectors = pad[: self.size]
        return self._scan_pad

    def search(
        self,
        queries: np.ndarray,
        k: int,
        ef: Optional[int] = None,
        *,
        n_pad: Optional[int] = None,
        l_pad: Optional[int] = None,
        legacy: bool = False,
    ):
        if self.size == 0:
            B = queries.shape[0]
            return (
                np.full((B, k), np.inf, np.float32),
                np.full((B, k), -1, np.int64),
            )
        k_eff = min(k, self.size)
        if self.kind == "hnsw":
            if legacy:
                # pre-device-resident behaviour: re-upload the graph per call
                # and trace per routed-subset size (before/after benchmarks)
                d, i = self.frozen.search(
                    queries, k_eff, ef=ef, cached=False, pad_queries=False
                )
            else:
                # full k even when size < k: the beam's (inf, -1) slots are
                # exactly the padding below, and a uniform static k keeps one
                # beam_search trace shared across unevenly-sized partitions.
                d, i = self.frozen.search(
                    queries, k, ef=ef, n_pad=n_pad, l_pad=l_pad
                )
                k_eff = k
        else:
            metric = (
                "l2" if self.config.metric == "mips" else self.config.metric
            )
            d, i = _batched_scan_topk(
                queries, self.scan_corpus(), k_eff, metric,
                n_valid=self.size,
            )
            if self.keys is not None:
                i = np.where(i >= 0, self.keys[np.clip(i, 0, None)], -1)
        if k_eff < k:
            pad_d = np.full((queries.shape[0], k - k_eff), np.inf, np.float32)
            pad_i = np.full((queries.shape[0], k - k_eff), -1, np.int64)
            d = np.concatenate([d, pad_d], axis=1)
            i = np.concatenate([i.astype(np.int64), pad_i], axis=1)
        return d, i.astype(np.int64)


class LannsIndex:
    """End-to-end LANNS index: fit -> build -> query (+ save/load/resume)."""

    def __init__(self, config: LannsConfig):
        if config.quantized not in ("none", "q8"):
            raise ValueError(
                f"quantized={config.quantized!r} — expected 'none' or 'q8'"
            )
        if config.quantized == "q8" and config.engine != "scan":
            raise ValueError(
                "quantized='q8' requires engine='scan' (quantized HNSW "
                "beams are a ROADMAP follow-on)"
            )
        if config.rerank_store not in ("auto", "host", "device"):
            raise ValueError(
                f"rerank_store={config.rerank_store!r} — expected 'auto', "
                "'host' or 'device'"
            )
        self.config = config
        self.partitioner = TwoLevelPartitioner(
            config.num_shards, config.segmenter_config()
        )
        self.partitions: dict[tuple, _Partition] = {}
        self.build_stats: dict = {}
        self._stack = None  # lazily-built stacked HNSW device pytree
        self._q8_exec = None  # lazily-built two-stage quantized executor

    # -- stacked HNSW serving state -------------------------------------------

    def _invalidate_stack(self):
        self._stack = None
        self._q8_exec = None

    def _q8_executor(self):
        """Two-stage quantized scan executor over every non-empty scan
        partition (device codes upload once, cached like the HNSW stack)."""
        if self._q8_exec is None:
            from repro.quant.twostage import (
                QuantizedScanExecutor,
                _Q8Partition,
            )

            metric = (
                "l2" if self.config.metric == "mips" else self.config.metric
            )
            parts = {
                sg: _Q8Partition(p.q8, p.vectors, p.keys, metric)
                for sg, p in sorted(self.partitions.items())
                if p.kind == "scan" and p.size > 0 and p.q8 is not None
            }
            self._q8_exec = QuantizedScanExecutor(
                parts,
                metric,
                self.config.rerank_factor,
                self.config.rerank_store,
            )
        return self._q8_exec

    def _hnsw_parts(self):
        """Servable HNSW partitions, sorted by (shard, segment).

        The single source of the eligibility rule — both dispatch modes
        (stacked / partition) and the shared pad computation use it, so they
        can never disagree on which partitions the HNSW paths serve.
        """
        return sorted(
            (sg, p) for sg, p in self.partitions.items()
            if p.kind == "hnsw" and p.size > 0
        )

    def _hnsw_stack(self):
        """Flat device pytree over every non-empty HNSW partition.

        Partition rows concatenate into shared flat arrays — vectors
        (P*n_pad, d), adj0 (P*n_pad, 2M), upper_adj (l_pad, P*n_pad, M) —
        with partition p owning rows [p*n_pad, p*n_pad + size).  One
        ``beam_search_flat`` trace then serves any mix of (partition, query)
        lanes.  Built host-side and uploaded ONCE, then cached for the life
        of the partitions.  Returns {} when the index has no HNSW partitions.
        """
        if self._stack is not None:
            return self._stack
        items = self._hnsw_parts()
        if not items:
            self._stack = {}
            return self._stack
        P = len(items)
        n_pad, l_pad = self._hnsw_pads(items)
        dim = items[0][1].frozen.vectors.shape[1]
        m0 = items[0][1].frozen.adj0.shape[1]
        M = items[0][1].frozen.upper_adj.shape[2]
        vecs = np.zeros((P * n_pad, dim), np.float32)
        adj0 = np.full((P * n_pad, m0), -1, np.int32)
        upper = np.full((l_pad, P * n_pad, M), -1, np.int32)
        entry = np.zeros((P,), np.int32)
        keys = np.full((P * n_pad,), -1, np.int64)
        for pi, (_, p) in enumerate(items):
            fr = p.frozen
            n = fr.size
            off = pi * n_pad
            vecs[off: off + n] = fr.vectors
            adj0[off: off + n] = fr.adj0
            upper[: fr.num_upper_levels, off: off + n] = fr.upper_adj
            entry[pi] = fr.entry
            keys[off: off + n] = (
                fr.keys if fr.keys is not None else np.arange(n, dtype=np.int64)
            )
        self._stack = {
            "arrs": {
                "vectors": jnp.asarray(vecs),
                "adj0": jnp.asarray(adj0),
                "upper_adj": jnp.asarray(upper),
            },
            "entry": entry,  # per-partition local entry node (host)
            "keys": keys,
            "index": {sg: pi for pi, (sg, _) in enumerate(items)},
            "n_pad": n_pad,
            "l_pad": l_pad,
        }
        return self._stack

    def _hnsw_pads(self, items=None):
        """Shared (n_pad, l_pad) corpus buckets over the servable partitions."""
        if items is None:
            items = self._hnsw_parts()
        if not items:
            return None, None
        return (
            next_pow2(max(p.size for _, p in items)),
            max(p.frozen.num_upper_levels for _, p in items),
        )

    # -- build ---------------------------------------------------------------

    def fit(self, data: np.ndarray) -> "LannsIndex":
        with Timer() as t:
            self.partitioner.fit(data)
        self.build_stats["segmenter_fit_seconds"] = t.seconds
        return self

    def build(
        self,
        data: np.ndarray,
        keys: Optional[np.ndarray] = None,
        *,
        workers: int = 0,
        resume_dir: Optional[str] = None,
    ) -> "LannsIndex":
        """Partition + parallel per-partition index build.

        workers=0 builds in-process (deterministic single-thread); workers>0
        uses a process pool — one "executor" per partition, the paper's Spark
        model.  resume_dir enables checkpointed builds: finished partitions
        are persisted and skipped on restart.
        """
        cfg = self.config
        data = np.asarray(data, dtype=np.float32)
        if cfg.metric == "mips":
            # augmented-vector MIPS->L2 reduction; see LannsConfig docstring
            norms2 = np.einsum("nd,nd->n", data, data)
            self._mips_M2 = float(norms2.max())
            aug = np.sqrt(np.maximum(self._mips_M2 - norms2, 0.0))
            data = np.concatenate([data, aug[:, None]], axis=1)
        n = data.shape[0]
        if keys is None:
            keys = np.arange(n, dtype=np.int64)
        if not self.partitioner._fitted:
            self.fit(data)
        with Timer() as t_assign:
            assignment = self.partitioner.assign(data, keys)
        jobs = []
        per_partition_seconds = {}
        for s in range(cfg.num_shards):
            for g in range(cfg.num_segments):
                rows = assignment.rows[s][g]
                if resume_dir and self._partition_done(resume_dir, s, g):
                    self.partitions[(s, g)] = self._load_partition(resume_dir, s, g)
                    continue
                jobs.append(
                    (s, g, data[rows], keys[rows], cfg.engine, cfg.hnsw_config())
                )
        with Timer() as t_build:
            if workers and len(jobs) > 1:
                with ProcessPoolExecutor(max_workers=workers) as ex:
                    results = list(ex.map(_build_one_partition, jobs))
            else:
                results = [_build_one_partition(j) for j in jobs]
        for s, g, payload, secs in results:
            self.partitions[(s, g)] = _Partition(payload, cfg)
            per_partition_seconds[f"{s}/{g}"] = secs
            if resume_dir:
                self._save_partition(resume_dir, s, g, payload)
        self._invalidate_stack()
        self.build_stats.update(
            assign_seconds=t_assign.seconds,
            build_wall_seconds=t_build.seconds,
            per_partition_seconds=per_partition_seconds,
            partition_sizes=assignment.partition_sizes().tolist(),
            total_stored=assignment.total_stored,
            n_input=n,
            duplication_factor=assignment.total_stored / max(n, 1),
        )
        return self

    # -- query ---------------------------------------------------------------

    def warm_traces(
        self,
        max_batch: int,
        topk: int,
        *,
        ef: Optional[int] = None,
    ) -> "LannsIndex":
        """Pre-compile the serving trace set for batches up to ``max_batch``.

        Online serving forms micro-batches of ANY size <= max_batch, and the
        executor pads routed per-segment subsets to pow2 buckets — so the
        first live traffic would otherwise pay one XLA compile per unseen
        (subset-bucket, corpus-bucket) pair, hundreds of ms each, exactly the
        latencies a p99 sweep measures.  The bucket grid makes the full set
        enumerable: one ``query`` per pow2 batch size warms routing + merge +
        the stacked-HNSW / q8 paths, and for fp32 scan partitions a direct
        per-partition sweep covers every (pow2 subset, corpus bucket) combo
        regardless of how routing happens to split the batch.

        Coverage caveat: the per-partition sweep is exhaustive only for the
        fp32 scan engine.  q8 and HNSW indexes get best-effort whole-batch
        warming — their per-subset buckets depend on how routing splits each
        dummy batch, so rare residual compiles remain possible on first
        live traffic (extend the sweep to those executors before gating
        their p99s).
        """
        parts = [p for p in self.partitions.values() if p.size > 0]
        if not parts or max_batch < 1:
            return self
        cfg = self.config
        dim = parts[0].vectors.shape[1]
        qdim = dim - 1 if cfg.metric == "mips" else dim
        rng = np.random.default_rng(0)
        # iterate pow2 buckets up to next_pow2(max_batch): a live batch of
        # max_batch pads to that bucket, so stopping at max_batch itself
        # would leave the TOP bucket cold for non-pow2 max_batch.
        b_top = next_pow2(max_batch)
        dummy = rng.standard_normal((b_top, qdim)).astype(np.float32)
        b = 1
        while b <= b_top:
            self.query(dummy[:b], topk, ef=ef)
            b *= 2
        if cfg.engine == "scan" and cfg.quantized == "none":
            pstk = per_shard_topk(topk, cfg.num_shards, cfg.topk_confidence)
            full = dummy
            if cfg.metric == "mips":
                full = np.concatenate(
                    [dummy, np.zeros((len(dummy), 1), np.float32)], axis=1
                )
            for p in parts:
                b = 1
                while b <= b_top:
                    p.search(full[:b], pstk, ef=ef)
                    b *= 2
        return self

    def query(
        self,
        queries: np.ndarray,
        topk: int,
        *,
        ef: Optional[int] = None,
        return_stats: bool = False,
        hnsw_mode: str = "stacked",  # 'stacked' | 'partition' | 'legacy'
    ):
        """Two-level partitioned search with perShardTopK (paper §5.3).

        Every query goes to every shard; within a shard it goes only to the
        segments its virtual-spill routing selects.  Returns (dists, ids)
        shaped (B, topk); optionally per-query routing stats.

        Batched executor: queries are grouped by routed segment, so each
        (shard, segment) partition runs ONE batched search over exactly its
        routed queries; candidates land in compact per-route slots (sized by
        the worst-case route count, not num_segments) and both merge levels
        run as single vectorized calls over all (query, shard) rows.

        HNSW partitions additionally run device-resident and trace-stable,
        selected by ``hnsw_mode``:

        * 'stacked' (default) — all partitions packed into one flat padded
          pytree, ONE vmapped ``beam_search_flat`` call per query batch (no
          per-partition Python loop or host<->device sync);
        * 'partition' — per-partition calls against cached device arrays
          padded to shared (n, L) buckets (bounded trace count);
        * 'legacy' — the pre-device-resident path: graph re-uploaded and
          beam_search retraced per routed-subset size (kept as the
          before/after benchmark baseline and a parity oracle).
        """
        if hnsw_mode not in ("stacked", "partition", "legacy"):
            raise ValueError(
                f"hnsw_mode={hnsw_mode!r} — expected 'stacked', 'partition' "
                "or 'legacy'"
            )
        cfg = self.config
        queries = np.asarray(queries, dtype=np.float32)
        if cfg.metric == "mips":
            if not hasattr(self, "_mips_M2"):
                raise RuntimeError(
                    "metric='mips' index has no stored M^2 — build() it, or "
                    "load() one saved with mips_M2 in its manifest"
                )
            queries = np.concatenate(
                [queries, np.zeros((queries.shape[0], 1), np.float32)], axis=1
            )
        B = queries.shape[0]
        S = cfg.num_shards
        pstk = per_shard_topk(topk, S, cfg.topk_confidence)
        if B == 0:
            # well-formed empty outputs; routing/merge would otherwise choke
            # on zero-length reductions (segments_visited.max()).
            out_d = np.full((0, topk), np.inf, np.float32)
            out_i = np.full((0, topk), -1, np.int64)
            if return_stats:
                merge_path = (
                    "disjoint"
                    if cfg.engine == "scan" and cfg.spill == "virtual"
                    else "two_level"
                )
                return out_d, out_i, self._query_stats(
                    pstk, np.zeros((0,), np.int64), merge_path
                )
            return out_d, out_i
        seg_mask = self.partitioner.route_queries(queries)  # (B, m)
        segments_visited = seg_mask.sum(axis=1)
        # slot[b, g]: position of segment g among query b's routed segments.
        slot = np.cumsum(seg_mask, axis=1) - 1
        max_routes = max(int(segments_visited.max()), 1)
        # virtual spill stores each point in exactly ONE (shard, segment), so
        # scan-engine candidate ids are disjoint across lanes and the final
        # merge needs no dedup — one partial sort over every candidate
        # (merge_topk_disjoint_np) instead of the two-level lexsort merge.
        # fp32 scan joined the q8 two-stage path here after its deprecation
        # window (ROADMAP item; parity-tested in tests/test_lanns.py);
        # physical spill (duplicate ids) and the HNSW engine keep
        # merge_topk_vec.  q8 lanes additionally stay candidate-wide
        # (rerank_factor * pstk exactly-scored rows each).
        scan_virtual = cfg.engine == "scan" and cfg.spill == "virtual"
        q8_fast = cfg.quantized == "q8" and scan_virtual
        lane_w = pstk
        if q8_fast:
            lane_w = min(
                cfg.rerank_factor * pstk,
                max((p.size for p in self.partitions.values()), default=pstk),
            )
            lane_w = max(lane_w, pstk)
        cand_d = np.full((B, S, max_routes, lane_w), np.inf, np.float32)
        cand_i = np.full((B, S, max_routes, lane_w), -1, np.int64)
        # routed query subset per segment — shared by every shard's (s, g)
        # partition, so compute it once.
        sels = [np.nonzero(seg_mask[:, g])[0] for g in range(cfg.num_segments)]
        handled = self._query_hnsw_stacked(
            queries, sels, slot, cand_d, cand_i, pstk, ef
        ) if hnsw_mode == "stacked" else set()
        if cfg.quantized == "q8":
            handled |= self._q8_executor().run(
                queries, sels, slot, cand_d, cand_i, pstk,
                lane_width=lane_w,
            )
        n_pad = l_pad = None
        if hnsw_mode == "partition":
            n_pad, l_pad = self._hnsw_pads()
        for g in range(cfg.num_segments):
            sel = sels[g]
            if sel.size == 0:
                continue
            q_sel = queries[sel]
            sl = slot[sel, g]
            for s in range(S):
                if (s, g) in handled:
                    continue
                part = self.partitions.get((s, g))
                if part is None or part.size == 0:
                    continue
                # the paper propagates the SHARD-level perShardTopK to the
                # segments (never a per-segment trim) — §5.3.2.
                d, i = part.search(
                    q_sel, pstk, ef=ef, n_pad=n_pad, l_pad=l_pad,
                    legacy=(hnsw_mode == "legacy"),
                )
                cand_d[sel, s, sl, :pstk] = d
                cand_i[sel, s, sl, :pstk] = i
        use_disjoint = scan_virtual and (
            not q8_fast
            or handled >= {
                sg for sg, p in self.partitions.items() if p.size > 0
            }
        )
        if use_disjoint:
            # dedup-free merge over every candidate (a superset of what
            # perShardTopK trimming would forward, so recall can only
            # improve); physical spill (duplicate ids) takes the
            # merge_topk_vec branch below instead.
            out_d, out_i = merge_topk_disjoint_np(
                cand_d.reshape(B, S * max_routes * lane_w),
                cand_i.reshape(B, S * max_routes * lane_w),
                topk,
            )
        else:
            # level-1: segment merge inside each shard, all (query, shard)
            # rows in one vectorized call.
            shard_d, shard_i = merge_topk_vec(
                cand_d.reshape(B * S, max_routes * lane_w),
                cand_i.reshape(B * S, max_routes * lane_w),
                pstk,
            )
            # level-2: broker merge over shards.
            out_d, out_i = merge_topk_vec(
                shard_d.reshape(B, S * pstk), shard_i.reshape(B, S * pstk),
                topk,
            )
        if cfg.quantized == "q8" and cfg.metric in ("l2", "mips"):
            # the q8 executor's lane distances omit the per-query ||q||^2
            # constant (it cannot change any within-query ordering); restore
            # true squared distances with one (B, topk) add.
            qn8 = np.einsum("bd,bd->b", queries, queries)
            out_d = np.where(
                np.isfinite(out_d), out_d + qn8[:, None], out_d
            )
        if cfg.metric == "mips":
            # convert augmented-L2 distances back to (negated) inner products:
            # d^2 = M^2 + |q|^2 - 2<q, x>  =>  -<q, x> = (d^2 - M^2 - |q|^2)/2
            qn = np.einsum("bd,bd->b", queries[:, :-1], queries[:, :-1])
            out_d = np.where(
                np.isfinite(out_d),
                (out_d - self._mips_M2 - qn[:, None]) / 2.0,
                np.inf,
            )
        if return_stats:
            return out_d, out_i, self._query_stats(
                pstk, segments_visited,
                "disjoint" if use_disjoint else "two_level",
            )
        return out_d, out_i

    @staticmethod
    def _query_stats(pstk, segments_visited, merge_path="two_level"):
        """Routing/trace stats dict — one schema for empty and non-empty
        batches (dashboards index these keys unconditionally)."""
        from repro.core import hnsw as hnsw_mod

        from repro.kernels import ref as ref_mod
        from repro.quant import twostage as q8_mod

        empty = segments_visited.size == 0
        return {
            "per_shard_topk": pstk,
            # which final-merge implementation served the batch: 'disjoint'
            # (dedup-free partial sort; scan engine + virtual spill) or
            # 'two_level' (lexsort dedup merge).
            "merge_path": merge_path,
            "mean_segments_visited":
                0.0 if empty else float(segments_visited.mean()),
            "max_segments_visited":
                0 if empty else int(segments_visited.max()),
            # process-wide trace counts: serving dashboards watch these to
            # confirm the trace set stays bounded.
            "beam_traces": jit_cache_size(hnsw_mod.beam_search),
            "beam_traces_flat": jit_cache_size(hnsw_mod.beam_search_flat),
            "scan_traces": jit_cache_size(ref_mod.distance_topk_blocked),
            "scan_traces_q8": jit_cache_size(q8_mod._stage1_scores),
        }

    def _query_hnsw_stacked(self, queries, sels, slot, cand_d, cand_i, pstk, ef):
        """One ``beam_search_flat`` call covering every HNSW partition.

        Builds the sparse lane list of (partition, routed query) pairs —
        partition (s, g) searches the routed subset of segment g (identical
        across shards) — padded to a quarter-pow2 lane bucket so the call
        reuses a bounded trace set with <= 25% padding waste even under
        unbalanced segment routing.  Results scatter into the executor's
        compact per-route candidate slots.  Returns the set of
        (shard, segment) partitions served.
        """
        stack = self._hnsw_stack()
        if not stack:
            return set()
        from repro.core.hnsw import beam_search_flat

        hcfg = self.config.hnsw_config()
        q_eff = queries
        if hcfg.metric == "cos":
            q_eff = q_eff / np.maximum(
                np.linalg.norm(q_eff, axis=-1, keepdims=True), 1e-12
            )
        n_pad = stack["n_pad"]
        blocks = []  # (s, g, pi, lane_start, count)
        q_blocks, off_blocks, ep_blocks = [], [], []
        T = 0
        for (s, g), pi in stack["index"].items():
            sel = sels[g]
            if len(sel) == 0:
                continue
            blocks.append((s, g, pi, T, len(sel)))
            q_blocks.append(q_eff[sel])
            off_blocks.append(
                np.full(len(sel), pi * n_pad, np.int32)
            )
            ep_blocks.append(
                np.full(len(sel), stack["entry"][pi] + pi * n_pad, np.int32)
            )
            T += len(sel)
        handled = {(s, g) for (s, g) in stack["index"]}
        if T == 0:
            return handled
        T_pad = next_pow2_quarter(T)
        dim = queries.shape[1]
        Q = np.zeros((T_pad, dim), np.float32)
        OFF = np.zeros((T_pad,), np.int32)
        EP = np.zeros((T_pad,), np.int32)
        Q[:T] = np.concatenate(q_blocks)
        OFF[:T] = np.concatenate(off_blocks)
        EP[:T] = np.concatenate(ep_blocks)
        V = np.arange(T_pad) < T
        ef_eff = max(ef or hcfg.ef_search, pstk)
        d_all, i_all = beam_search_flat(
            stack["arrs"],
            jnp.asarray(Q),
            jnp.asarray(EP),
            jnp.asarray(OFF),
            jnp.asarray(V),
            k=pstk,
            ef=ef_eff,
            max_iters=ef_eff + 2 * hcfg.M,
            metric="l2" if hcfg.metric == "l2" else "ip",
        )
        # ONE host sync for all partitions (vs one np.asarray per (s, g))
        d_all, i_all = np.asarray(d_all), np.asarray(i_all)
        keys_flat = stack["keys"]
        for (s, g, pi, start, cnt) in blocks:
            sel = sels[g]
            d = d_all[start: start + cnt]
            i = i_all[start: start + cnt].astype(np.int64)
            i = np.where(i >= 0, keys_flat[np.clip(i, 0, None)], -1)
            sl = slot[sel, g]
            cand_d[sel, s, sl] = d
            cand_i[sel, s, sl] = i
        return handled

    # -- persistence (atomic, resumable) --------------------------------------

    @staticmethod
    def _partition_path(root, s, g):
        return os.path.join(root, f"shard{s:04d}_seg{g:04d}.npz")

    def _partition_done(self, root, s, g):
        return os.path.exists(self._partition_path(root, s, g))

    def _save_partition(self, root, s, g, payload):
        os.makedirs(root, exist_ok=True)
        path = self._partition_path(root, s, g)
        arrays = {"kind": np.array(payload["kind"])}
        for key, val in payload.items():
            if key == "kind" or val is None:
                continue
            if isinstance(val, list):
                for li, arr in enumerate(val):
                    arrays[f"{key}__{li}"] = arr
                arrays[f"{key}__len"] = np.array(len(val))
            else:
                arrays[key] = np.asarray(val)
        fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
        os.close(fd)
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)  # atomic publish

    def _load_partition(self, root, s, g):
        with np.load(self._partition_path(root, s, g), allow_pickle=False) as z:
            payload = {}
            lists: dict[str, dict[int, np.ndarray]] = {}
            for key in z.files:
                if "__" in key:
                    base, idx = key.rsplit("__", 1)
                    if idx == "len":
                        payload.setdefault(base, [None] * int(z[key]))
                    else:
                        lists.setdefault(base, {})[int(idx)] = z[key]
                elif key == "kind":
                    payload["kind"] = str(z[key])
                else:
                    payload[key] = z[key]
            for base, items in lists.items():
                payload.setdefault(base, [None] * len(items))
                for idx, arr in items.items():
                    payload[base][idx] = arr
        if payload.get("kind") == "hnsw" and "upper_adj" not in payload:
            # legacy artifact (pre-stacked): rebuild the (L, n, M) stack from
            # the ragged per-level lists it stored.
            from repro.core.hnsw import stack_upper_adj

            payload["upper_adj"] = stack_upper_adj(
                payload.get("level_nodes", []),
                payload.get("level_adj", []),
                payload["vectors"].shape[0],
                self.config.hnsw_config().M,
            )
        return _Partition(payload, self.config)

    def save(self, root: str):
        os.makedirs(root, exist_ok=True)
        for (s, g), part in self.partitions.items():
            if not self._partition_done(root, s, g):
                payload = {"kind": part.kind, "vectors": part.vectors, "keys": part.keys}
                if part.kind == "hnsw":
                    fr = part.frozen
                    payload.update(
                        levels=fr.levels, adj0=fr.adj0, entry=fr.entry,
                        upper_adj=fr.upper_adj,
                    )
                if part.q8 is not None:
                    # quantized payload: int8 codes + per-dim scales +
                    # per-vector norm corrections; the fp32 ``vectors``
                    # above double as the exact re-rank store.
                    payload.update(
                        q8_codes=part.q8.codes,
                        q8_scales=part.q8.scales,
                        q8_norms2=part.q8.norms2,
                    )
                self._save_partition(root, s, g, payload)
        seg = self.partitioner.segmenter
        tree = seg.tree_arrays()
        manifest = {
            # v2 adds the optional q8_* quantized arrays per partition (and
            # the quantized/rerank_* config knobs); v1 artifacts load
            # unchanged — absent fields fall back to fp32 behaviour.
            "format_version": 2,
            "config": dataclasses.asdict(self.config),
            "partitions": sorted([f"{s}/{g}" for s, g in self.partitions]),
            "build_stats": {
                k: v for k, v in self.build_stats.items() if k != "per_partition_seconds"
            },
            # mips needs the corpus max-norm M^2 to convert augmented-L2
            # distances back to inner products at query time.
            "mips_M2": getattr(self, "_mips_M2", None),
        }
        with open(os.path.join(root, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2, default=str)
        if tree is not None:
            np.savez(
                os.path.join(root, "segmenter.npz"),
                hyperplanes=tree["hyperplanes"], split=tree["split"],
                lo=tree["lo"], hi=tree["hi"],
            )

    @classmethod
    def load(cls, root: str) -> "LannsIndex":
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        version = int(manifest.get("format_version", 1))
        if version > 2:
            raise ValueError(
                f"artifact format_version={version} is newer than this "
                "build understands (max 2)"
            )
        config = LannsConfig(**manifest["config"])
        index = cls(config)
        if manifest.get("mips_M2") is not None:
            index._mips_M2 = float(manifest["mips_M2"])
        seg_path = os.path.join(root, "segmenter.npz")
        if os.path.exists(seg_path):
            with np.load(seg_path) as z:
                seg = index.partitioner.segmenter
                seg.hyperplanes = z["hyperplanes"]
                seg.split = z["split"]
                seg.lo = z["lo"]
                seg.hi = z["hi"]
        index.partitioner._fitted = True
        for pstr in manifest["partitions"]:
            s, g = (int(v) for v in pstr.split("/"))
            index.partitions[(s, g)] = index._load_partition(root, s, g)
        index.build_stats = manifest.get("build_stats", {})
        return index

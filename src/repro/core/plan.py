"""Composable query-plan executor: route -> candidates -> rerank -> merge.

The paper's serving path is a fixed pipeline (route queries, search each
routed partition, merge); ours composes it from pluggable stages so every
engine x precision x spill combination is a WIRING of shared pieces instead
of a hand-written branch inside ``LannsIndex.query``:

    route       virtual-spill segment routing + compact per-route slot
                layout + perShardTopK — produces a ``QueryPlan``.
    candidates  per-(shard, segment) candidate generation; one stage per
                engine x precision:
                  * fp32 scan   — fused distance+top-k per routed subset
                    (``_Partition.search``, Pallas kernel on TPU);
                  * q8 scan     — two-stage int8 scan + exact re-rank
                    (``quant.twostage.QuantizedScanExecutor``);
                  * fp32 hnsw   — ONE vmapped ``beam_search_flat`` call over
                    every (partition, routed query) lane of the flat
                    device-resident stack;
                  * q8 hnsw     — the same flat beam over int8 CODES
                    (per-dim scales folded into each lane's query; see
                    ``hnsw._make_row_dist``), then the shared exact re-rank.
    rerank      exact fp32 re-scoring of quantized candidates — the shared
                stage in ``quant/rerank.py``, invoked by both q8 paths.
    merge       THE merge-path decision (``choose_merge_path``) + the
                existing dedup-free ``merge_topk_disjoint_np`` or two-level
                ``merge_topk_vec`` merges, then metric finalization (q8
                ||q||^2 add-back, mips augmented-L2 -> inner-product).

Per-request knobs: a formed micro-batch may carry a DIFFERENT ``(topk, ef)``
per request.  ``knob_groups`` splits the batch into homogeneous groups; the
executor runs each group through the single-knob pipeline (whose inputs pad
to the existing pow2 trace buckets, so no new trace shapes appear) and
reassembles rows in place — bit-identical to issuing each group as its own
homogeneous query (asserted in tests/test_plan.py).

Every stage preserves the pre-refactor numerics exactly: the stage bodies
are the former ``LannsIndex.query`` blocks, moved — not rewritten.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.common.utils import jit_cache_size, next_pow2_quarter
from repro.core.merge import (
    merge_topk_disjoint_np,
    merge_topk_vec,
    per_shard_topk,
)

# Scale-safety contract for the beam-lane assembly (checked statically by
# repro.analysis.scalecheck at these bounds): up to 4096 partitions of up
# to 2^25 pow2-padded rows each, 2048-d vectors, <=16k routed lanes per
# batch, per-request topk <= 200.
# lanns: dims[n_pad<=33_554_432, pi<=4095, T<=16_384, dim<=2048, pstk<=200]

#: the flat HNSW row lattice (lane offsets, adjacency entries) is int32 on
#: device — every flattened id must stay below this
_INT32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# Per-request knob normalization / grouping
# ---------------------------------------------------------------------------


def knob_groups(topk, ef, B: int):
    """Normalize (topk, ef) — scalars or per-request arrays — into groups.

    Returns ``(scalar, groups)``:

    * ``scalar`` True: the whole batch shares one knob pair; ``groups`` is
      ``[(topk, ef, None)]`` and the executor runs the no-gather hot path
      (arrays whose entries are all equal collapse here, so a homogeneous
      array costs the same as a scalar).
    * ``scalar`` False: ``groups`` is ``[(topk, ef, rows)]`` sorted by
      ``(topk, ef)`` with ``rows`` ascending — deterministic, and each
      group is exactly a homogeneous sub-query.

    ``ef`` entries <= 0 (or None) mean "index default"; ``topk`` entries
    must be >= 1.
    """
    topk_arr = np.asarray(topk)
    ef_arr = None if ef is None else np.asarray(ef)
    mixed = topk_arr.ndim > 0 or (ef_arr is not None and ef_arr.ndim > 0)
    if not mixed:
        tk = int(topk_arr)
        if tk < 1:
            raise ValueError(f"topk={tk} must be >= 1")
        efv = None if ef is None else int(ef_arr)
        if efv is not None and efv <= 0:
            efv = None  # same contract as array entries: <= 0 == default
        return True, [(tk, efv, None)]
    tks = (
        np.broadcast_to(topk_arr, (B,)).astype(np.int64)
        if topk_arr.ndim == 0
        else topk_arr.astype(np.int64)
    )
    if tks.shape != (B,):
        raise ValueError(
            f"per-request topk has shape {tks.shape} — expected ({B},)"
        )
    if B and tks.min() < 1:
        raise ValueError("per-request topk entries must be >= 1")
    if ef_arr is None:
        efs = np.zeros((B,), np.int64)  # 0 == index default
    else:
        if ef_arr.ndim > 0 and ef_arr.shape != (B,):
            raise ValueError(
                f"per-request ef has shape {ef_arr.shape} — expected ({B},)"
            )
        efs = np.maximum(
            np.broadcast_to(ef_arr, (B,)).astype(np.int64), 0
        )
    groups = []
    for tk, efv in sorted(
        {(int(t), int(e)) for t, e in zip(tks, efs)}
    ):
        rows = np.nonzero((tks == tk) & (efs == efv))[0]
        groups.append((tk, efv if efv > 0 else None, rows))
    if len(groups) == 1:
        tk, efv, _ = groups[0]
        return True, [(tk, efv, None)]
    return False, groups


# ---------------------------------------------------------------------------
# Merge-path decision (the single source; deprecation-window endpoint)
# ---------------------------------------------------------------------------


def choose_merge_path(config, handled=None, partitions=None) -> str:
    """'disjoint' (dedup-free partial sort) vs 'two_level' (lexsort dedup).

    THE one decision point — every call-site (scan fp32/q8, physical spill,
    HNSW, the B == 0 early-out) routes through here instead of re-deriving
    the rule:

    * virtual spill stores each point in exactly ONE (shard, segment), so
      scan-engine candidate ids are disjoint across lanes and the final
      merge needs no dedup -> 'disjoint' (flipped for fp32 scan after its
      deprecation window; parity-tested in tests/test_lanns.py);
    * physical spill duplicates ids across segments -> 'two_level';
    * the HNSW engine (fp32 and q8 beams) keeps 'two_level': its lanes are
      pstk-trimmed, and the two-level merge is the historical contract its
      bit-identity tests pin down;
    * a q8 scan batch only takes 'disjoint' when the two-stage executor
      handled EVERY non-empty partition (its lanes are candidate-wide);
      pass ``handled``/``partitions`` to apply that refinement.
    """
    if config.engine != "scan" or config.spill != "virtual":
        return "two_level"
    if (
        config.quantized == "q8"
        and handled is not None
        and partitions is not None
    ):
        nonempty = {sg for sg, p in partitions.items() if p.size > 0}
        if not handled >= nonempty:
            return "two_level"
    return "disjoint"


def query_stats(pstk, segments_visited, merge_path="two_level",
                knob_groups_count=1):
    """Routing/trace stats dict — one schema for empty and non-empty
    batches (dashboards index these keys unconditionally)."""
    from repro.core import hnsw as hnsw_mod
    from repro.kernels import ref as ref_mod
    from repro.quant import twostage as q8_mod

    empty = segments_visited.size == 0
    return {
        "per_shard_topk": pstk,
        # which final-merge implementation served the batch: 'disjoint'
        # (dedup-free partial sort; scan engine + virtual spill) or
        # 'two_level' (lexsort dedup merge) — 'mixed' when knob groups of
        # one batch took different paths.
        "merge_path": merge_path,
        # how many homogeneous (topk, ef) groups the batch split into
        "knob_groups": knob_groups_count,
        "mean_segments_visited":
            0.0 if empty else float(segments_visited.mean()),
        "max_segments_visited":
            0 if empty else int(segments_visited.max()),
        # process-wide trace counts: serving dashboards watch these to
        # confirm the trace set stays bounded.
        "beam_traces": jit_cache_size(hnsw_mod.beam_search),
        "beam_traces_flat": jit_cache_size(hnsw_mod.beam_search_flat),
        "scan_traces": jit_cache_size(ref_mod.distance_topk_blocked),
        "scan_traces_q8": jit_cache_size(q8_mod._stage1_scores),
    }


# ---------------------------------------------------------------------------
# The plan object + staged executor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QueryPlan:
    """Routing result + per-request knobs flowing through the stages."""

    queries: np.ndarray  # (B, d) fp32, metric-prepped (mips-augmented)
    topk: int
    ef: Optional[int]
    hnsw_mode: str
    pstk: int
    lane_width: int  # candidate slots per (query, shard, route) lane
    seg_mask: np.ndarray  # (B, m) routed segments
    slot: np.ndarray  # (B, m) position of segment among the query's routes
    sels: list  # per-segment routed query subsets
    segments_visited: np.ndarray  # (B,)
    max_routes: int
    cand_d: np.ndarray  # (B, S, max_routes, lane_width)
    cand_i: np.ndarray
    handled: set = dataclasses.field(default_factory=set)
    merge_path: str = ""
    # telemetry (index.telemetry attached): the exact-re-rank share of the
    # candidates stage, accumulated by both q8 paths, and the final
    # route/candidates/rerank/merge wall-clock split.  Both stay at their
    # defaults when telemetry is detached — no clock is read at all.
    rerank_s: float = 0.0
    stage_s: Optional[dict] = None


class QueryPlanExecutor:
    """Runs ``QueryPlan``s against one ``LannsIndex``'s partitions.

    Stateless beyond the index reference — the cached device state (HNSW
    stacks, q8 executors) lives on the index, so invalidation stays in one
    place (``LannsIndex._invalidate_stack``).
    """

    def __init__(self, index):
        self.index = index

    # -- stage: route ------------------------------------------------------

    def plan(self, queries, topk, ef, hnsw_mode) -> QueryPlan:
        """Route the batch and lay out the compact candidate slots."""
        index = self.index
        cfg = index.config
        B = queries.shape[0]
        S = cfg.num_shards
        pstk = per_shard_topk(topk, S, cfg.topk_confidence)
        seg_mask = index.partitioner.route_queries(queries)  # (B, m)
        segments_visited = seg_mask.sum(axis=1)
        # slot[b, g]: position of segment g among query b's routed segments.
        slot = np.cumsum(seg_mask, axis=1) - 1
        max_routes = max(int(segments_visited.max()), 1)
        # q8 scan lanes stay candidate-wide (rerank_factor * pstk exactly-
        # scored rows each) so the dedup-free merge sees every candidate;
        # all other engines trim lanes to pstk.
        lane_w = pstk
        if cfg.quantized == "q8" and cfg.engine == "scan" \
                and cfg.spill == "virtual":
            lane_w = min(
                cfg.rerank_factor * pstk,
                max((p.size for p in index.partitions.values()),
                    default=pstk),
            )
            lane_w = max(lane_w, pstk)
        cand_d = np.full((B, S, max_routes, lane_w), np.inf, np.float32)
        cand_i = np.full((B, S, max_routes, lane_w), -1, np.int64)
        # routed query subset per segment — shared by every shard's (s, g)
        # partition, so compute it once.
        sels = [
            np.nonzero(seg_mask[:, g])[0] for g in range(cfg.num_segments)
        ]
        return QueryPlan(
            queries=queries, topk=topk, ef=ef, hnsw_mode=hnsw_mode,
            pstk=pstk, lane_width=lane_w, seg_mask=seg_mask, slot=slot,
            sels=sels, segments_visited=segments_visited,
            max_routes=max_routes, cand_d=cand_d, cand_i=cand_i,
        )

    # -- stage: candidates (engine x precision dispatch) -------------------

    def candidates(self, plan: QueryPlan) -> QueryPlan:
        """Fill the plan's candidate slots; every partition exactly once."""
        index = self.index
        cfg = index.config
        if plan.hnsw_mode == "stacked":
            if cfg.quantized == "q8":
                plan.handled |= self._candidates_hnsw_q8(plan)
            else:
                plan.handled |= self._candidates_hnsw_fp32(plan)
        if cfg.quantized == "q8" and cfg.engine == "scan":
            tel = getattr(index, "telemetry", None)
            acc = None if tel is None else [0.0]
            plan.handled |= index._q8_executor().run(
                plan.queries, plan.sels, plan.slot, plan.cand_d,
                plan.cand_i, plan.pstk, lane_width=plan.lane_width,
                rerank_s=acc, clock=None if tel is None else tel.clock,
            )
            if acc is not None:
                plan.rerank_s += acc[0]
        n_pad = l_pad = None
        if plan.hnsw_mode == "partition":
            n_pad, l_pad = index._hnsw_pads()
        for g in range(cfg.num_segments):
            sel = plan.sels[g]
            if sel.size == 0:
                continue
            q_sel = plan.queries[sel]
            sl = plan.slot[sel, g]
            for s in range(cfg.num_shards):
                if (s, g) in plan.handled:
                    continue
                part = index.partitions.get((s, g))
                if part is None or part.size == 0:
                    continue
                # the paper propagates the SHARD-level perShardTopK to the
                # segments (never a per-segment trim) — §5.3.2.
                d, i = part.search(
                    q_sel, plan.pstk, ef=plan.ef, n_pad=n_pad, l_pad=l_pad,
                    legacy=(plan.hnsw_mode == "legacy"),
                )
                plan.cand_d[sel, s, sl, : plan.pstk] = d
                plan.cand_i[sel, s, sl, : plan.pstk] = i
        return plan

    def _assemble_beam_lanes(self, plan: QueryPlan, stack, q_eff,
                             scales=None):
        """Sparse (partition, routed query) lane buffers for a flat beam.

        The lane layout shared by BOTH beam stages: partition (s, g)
        searches the routed subset of segment g (identical across shards),
        lanes pad to a quarter-pow2 bucket so the call reuses a bounded
        trace set with <= 25% padding waste even under unbalanced segment
        routing.  ``scales`` (P, d), when given, folds each partition's
        per-dim quantization scales into its lanes' queries (the q8 beam's
        dequantized-dot trick).  Returns ``(blocks, handled, Q, OFF, EP,
        V, T)`` — Q/OFF/EP/V are None when no lanes routed (T == 0).
        """
        n_pad = stack["n_pad"]
        blocks = []  # (s, g, pi, lane_start, count)
        q_blocks, off_blocks, ep_blocks = [], [], []
        T = 0
        # sorted(): the stack index is built in (shard, segment) order, but
        # lane layout must not DEPEND on dict insertion order — trace/layout
        # determinism is load-bearing (LANNS006), not incidental.
        for (s, g), pi in sorted(stack["index"].items()):
            sel = plan.sels[g]
            if len(sel) == 0:
                continue
            blocks.append((s, g, pi, T, len(sel)))
            q_blk = q_eff[sel]
            if scales is not None:
                q_blk = q_blk * scales[pi][None, :]
            q_blocks.append(q_blk)
            off = pi * n_pad
            if off + n_pad > _INT32_MAX:
                raise OverflowError(
                    f"beam lane offset {off} + n_pad {n_pad} exceeds the "
                    "int32 flat row lattice — shard the index"
                )
            off_blocks.append(np.full(len(sel), off, np.int32))
            ep_blocks.append(
                np.full(len(sel), stack["entry"][pi] + off, np.int32)
            )
            T += len(sel)
        handled = {(s, g) for (s, g) in stack["index"]}
        if T == 0:
            return blocks, handled, None, None, None, None, 0
        T_pad = next_pow2_quarter(T)
        dim = plan.queries.shape[1]
        Q = np.zeros((T_pad, dim), np.float32)
        OFF = np.zeros((T_pad,), np.int32)
        EP = np.zeros((T_pad,), np.int32)
        Q[:T] = np.concatenate(q_blocks)
        OFF[:T] = np.concatenate(off_blocks)
        EP[:T] = np.concatenate(ep_blocks)
        V = np.arange(T_pad) < T
        return blocks, handled, Q, OFF, EP, V, T

    @staticmethod
    def _cos_normalize(q_eff, hcfg):
        if hcfg.metric != "cos":
            return q_eff
        return q_eff / np.maximum(
            np.linalg.norm(q_eff, axis=-1, keepdims=True), 1e-12
        )

    def _candidates_hnsw_fp32(self, plan: QueryPlan) -> set:
        """One ``beam_search_flat`` call covering every HNSW partition.

        Results scatter into the plan's compact per-route candidate slots;
        returns the set of (shard, segment) partitions served.
        """
        index = self.index
        stack = index._hnsw_stack()
        if not stack:
            return set()
        from repro.core.hnsw import beam_search_flat

        hcfg = index.config.hnsw_config()
        pstk = plan.pstk
        q_eff = self._cos_normalize(plan.queries, hcfg)
        blocks, handled, Q, OFF, EP, V, T = self._assemble_beam_lanes(
            plan, stack, q_eff
        )
        if T == 0:
            return handled
        ef_eff = max(plan.ef or hcfg.ef_search, pstk)
        d_all, i_all = beam_search_flat(  # lanns: noqa[LANNS033] -- pstk ranges over the per-request knob set, finite by the knob_groups contract (not corpus-dependent)
            stack["arrs"],
            jnp.asarray(Q),
            jnp.asarray(EP),
            jnp.asarray(OFF),
            jnp.asarray(V),
            k=pstk,
            ef=ef_eff,
            max_iters=ef_eff + 2 * hcfg.M,
            metric="l2" if hcfg.metric == "l2" else "ip",
        )
        # ONE host sync for all partitions (vs one np.asarray per (s, g))
        d_all, i_all = np.asarray(d_all), np.asarray(i_all)  # lanns: noqa[LANNS003] -- the single designed host sync of the fp32 beam batch
        keys_flat = stack["keys"]
        for (s, g, _pi, start, cnt) in blocks:
            sel = plan.sels[g]
            d = d_all[start: start + cnt]
            i = i_all[start: start + cnt].astype(np.int64)
            i = np.where(i >= 0, keys_flat[np.clip(i, 0, None)], -1)
            sl = plan.slot[sel, g]
            plan.cand_d[sel, s, sl] = d
            plan.cand_i[sel, s, sl] = i
        return handled

    def _candidates_hnsw_q8(self, plan: QueryPlan) -> set:
        """Quantized HNSW beam + shared exact re-rank (AQR-style).

        Candidate generation runs the SAME flat beam as the fp32 stage but
        over the int8-code stack: each lane's query is pre-folded with its
        partition's per-dim scales, so every in-walk distance is a dot
        against the dequantized row at a quarter of the gather bytes.  The
        beam returns ``C = min(rerank_factor * pstk, ef)`` candidates per
        lane ranked by quantized distance; the shared re-rank stage
        (``quant/rerank.py``) re-scores them EXACTLY against the fp32
        originals, and the best ``pstk`` land in the plan slots — so the
        merged results carry no quantization error in their distances, only
        (bounded) candidate-selection error, exactly like the q8 scan.
        """
        index = self.index
        stack = index._hnsw_stack(quantized=True)
        if not stack:
            return set()
        from repro.core.hnsw import beam_search_flat
        from repro.quant.rerank import exact_candidate_distances

        cfg = index.config
        hcfg = cfg.hnsw_config()
        pstk = plan.pstk
        # beam walk + rerank both use the hnsw-internal metric ('cos' rows
        # were normalized at build, so their exact scores reduce to 'ip' —
        # matching the fp32 beam's returned distances)
        rmetric = "l2" if hcfg.metric == "l2" else "ip"
        q_eff = self._cos_normalize(plan.queries, hcfg)
        n_pad = stack["n_pad"]
        ef_eff = max(plan.ef or hcfg.ef_search, pstk)
        # candidate width: rerank up to rerank_factor * pstk of the beam's
        # ef entries — the beam's exploration budget stays the user's ef
        C = max(min(cfg.rerank_factor * pstk, ef_eff), pstk)
        blocks, handled, Q, OFF, EP, V, T = self._assemble_beam_lanes(
            plan, stack, q_eff, scales=stack["scales"]
        )
        if T == 0:
            return handled
        d_all, i_all = beam_search_flat(
            stack["arrs"],  # int8 codes + norms2: quantized walk
            jnp.asarray(Q),
            jnp.asarray(EP),
            jnp.asarray(OFF),
            jnp.asarray(V),
            k=C,
            ef=ef_eff,
            max_iters=ef_eff + 2 * hcfg.M,
            metric=rmetric,
        )
        i_all = np.asarray(i_all)  # lanns: noqa[LANNS003] -- the single designed host sync of the q8 beam batch (quantized d_all is discarded: re-ranked)
        stores = stack["stores"]
        store_mode = stack["store_mode"]
        tel = getattr(index, "telemetry", None)
        for (s, g, pi, start, cnt) in blocks:
            sel = plan.sels[g]
            store = stores[pi]
            rows = i_all[start: start + cnt]  # (b, C) flat rows, -1 padded
            invalid = rows < 0
            # int64 intermediate: `rows - pi * n_pad` in the rows' own int32
            # would wrap for partitions past the 2^31 boundary; the clip
            # result is < store.size, so the narrowing cast back is exact
            cand = np.clip(
                rows.astype(np.int64) - pi * n_pad, 0, store.size - 1
            ).astype(np.int32)
            t_rr = None if tel is None else tel.clock()
            ex = exact_candidate_distances(
                q_eff[sel], cand, store, rmetric,
                mode=store_mode, l_pad=next_pow2_quarter(cnt),
            )
            if t_rr is not None:
                plan.rerank_s += tel.clock() - t_rr
            ex = np.where(invalid, np.inf, ex)
            kk = min(pstk, C)
            if kk < C:
                loc = np.argpartition(ex, kk - 1, axis=1)[:, :kk]
                d_lane = np.take_along_axis(ex, loc, axis=1)
                cand_sel = np.take_along_axis(cand, loc, axis=1)
            else:
                d_lane = ex
                cand_sel = cand
            i_lane = np.where(
                np.isinf(d_lane), -1, store.keys[cand_sel]
            )
            sl = plan.slot[sel, g]
            plan.cand_d[sel, s, sl, :kk] = d_lane
            plan.cand_i[sel, s, sl, :kk] = i_lane
        return handled

    # -- stage: merge + metric finalization --------------------------------

    def merge(self, plan: QueryPlan):
        """Two-level (or dedup-free) merge + metric corrections."""
        index = self.index
        cfg = index.config
        B = plan.queries.shape[0]
        S = cfg.num_shards
        plan.merge_path = choose_merge_path(
            cfg, plan.handled, index.partitions
        )
        if plan.merge_path == "disjoint":
            # dedup-free merge over every candidate (a superset of what
            # perShardTopK trimming would forward, so recall can only
            # improve); physical spill (duplicate ids) takes the
            # merge_topk_vec branch below instead.
            out_d, out_i = merge_topk_disjoint_np(
                plan.cand_d.reshape(B, S * plan.max_routes * plan.lane_width),
                plan.cand_i.reshape(B, S * plan.max_routes * plan.lane_width),
                plan.topk,
            )
        else:
            # level-1: segment merge inside each shard, all (query, shard)
            # rows in one vectorized call.
            shard_d, shard_i = merge_topk_vec(
                plan.cand_d.reshape(B * S, plan.max_routes * plan.lane_width),
                plan.cand_i.reshape(B * S, plan.max_routes * plan.lane_width),
                plan.pstk,
            )
            # level-2: broker merge over shards.
            out_d, out_i = merge_topk_vec(
                shard_d.reshape(B, S * plan.pstk),
                shard_i.reshape(B, S * plan.pstk),
                plan.topk,
            )
        if cfg.quantized == "q8" and cfg.metric in ("l2", "mips"):
            # q8 lane distances omit the per-query ||q||^2 constant (it
            # cannot change any within-query ordering); restore true
            # squared distances with one (B, topk) add.
            qn8 = np.einsum("bd,bd->b", plan.queries, plan.queries)
            out_d = np.where(
                np.isfinite(out_d), out_d + qn8[:, None], out_d
            )
        if cfg.metric == "mips":
            # convert augmented-L2 distances back to (negated) inner
            # products: d^2 = M^2 + |q|^2 - 2<q, x>
            #   =>  -<q, x> = (d^2 - M^2 - |q|^2) / 2
            q_raw = plan.queries[:, :-1]
            qn = np.einsum("bd,bd->b", q_raw, q_raw)
            out_d = np.where(
                np.isfinite(out_d),
                (out_d - index._mips_M2 - qn[:, None]) / 2.0,
                np.inf,
            )
        return out_d, out_i

    # -- one homogeneous (single-knob) pass --------------------------------

    # lanns: hotpath
    def execute(self, queries, topk, ef, hnsw_mode):
        """route -> candidates (-> rerank) -> merge for ONE knob group.

        With ``index.telemetry`` attached (an ``obs.Telemetry``), the stage
        boundaries are timed and reported through ``telemetry.on_execute``
        (labeled by engine/quantized/merge_path/pow2 batch bucket) and the
        plan carries ``stage_s``; the exact-re-rank share accumulated by
        the q8 paths is subtracted out of the candidates stage.  Detached
        (the default), the untimed branch below runs — no clock reads, no
        telemetry calls — so instrumentation-off results are structurally
        bit-identical to -on (asserted in tests/test_obs.py).
        """
        tel = getattr(self.index, "telemetry", None)
        if tel is None:
            plan = self.plan(queries, topk, ef, hnsw_mode)
            self.candidates(plan)
            out_d, out_i = self.merge(plan)
            return out_d, out_i, plan
        clock = tel.clock
        t0 = clock()
        plan = self.plan(queries, topk, ef, hnsw_mode)
        t1 = clock()
        self.candidates(plan)
        t2 = clock()
        out_d, out_i = self.merge(plan)
        t3 = clock()
        plan.stage_s = {
            "route": t1 - t0,
            "candidates": max((t2 - t1) - plan.rerank_s, 0.0),
            "rerank": plan.rerank_s,
            "merge": t3 - t2,
        }
        cfg = self.index.config
        tel.on_execute(
            engine=cfg.engine, quantized=cfg.quantized,
            merge_path=plan.merge_path, batch=queries.shape[0],
            stage_s=plan.stage_s,
        )
        return out_d, out_i, plan

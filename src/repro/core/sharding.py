"""LANNS level-1 partitioning: hash sharding + the two-level partitioner.

Paper §4.1: "When a point is inserted, it is hashed to ONE particular shard
using the key of the data point. Since this partitioning does not exploit any
locality information, each query is routed to all shards."

§5.1: the segmenter is learned ONCE on a uniform subsample and shared across
all shards (shards are iid samples of the corpus under hash partitioning), so
the two-level partitioner composes `hash(key) % S` with one shared segmenter.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.common.utils import stable_hash_u64
from repro.core.segmenter import SegmenterConfig, make_segmenter


def hash_shard(keys: np.ndarray, num_shards: int, salt: int = 0x5AAD) -> np.ndarray:
    """Deterministic shard id per key (splitmix64 % S)."""
    return (stable_hash_u64(keys, salt=salt) % np.uint64(num_shards)).astype(np.int64)


@dataclasses.dataclass
class PartitionAssignment:
    """Result of two-level partitioning for a dataset.

    rows[s][g]  — int64 row indices of the input that land in (shard s,
                  segment g).  With physical spill a row may appear in several
                  segments of its shard (never in two shards).
    """

    num_shards: int
    num_segments: int
    rows: list  # list[list[np.ndarray]]

    def partition_sizes(self) -> np.ndarray:
        return np.array(
            [[len(self.rows[s][g]) for g in range(self.num_segments)]
             for s in range(self.num_shards)],
            dtype=np.int64,
        )

    @property
    def total_stored(self) -> int:
        return int(self.partition_sizes().sum())


class TwoLevelPartitioner:
    """shard = hash(key) % S;  segment(s) = shared learned segmenter."""

    def __init__(
        self,
        num_shards: int,
        segmenter_config: SegmenterConfig,
        salt: int = 0x5AAD,
    ):
        self.num_shards = num_shards
        self.segmenter_config = segmenter_config
        self.segmenter = make_segmenter(segmenter_config)
        self.salt = salt
        self._fitted = False

    def fit(self, data: np.ndarray) -> "TwoLevelPartitioner":
        """Learn the shared segmenter on a subsample of the full dataset."""
        self.segmenter.fit(data)
        self._fitted = True
        return self

    def assign(
        self, data: np.ndarray, keys: Optional[np.ndarray] = None
    ) -> PartitionAssignment:
        if not self._fitted:
            raise RuntimeError("call fit() first (pre-learned shared segmenter)")
        n = data.shape[0]
        if keys is None:
            keys = np.arange(n, dtype=np.uint64)
        shard = hash_shard(keys, self.num_shards, self.salt)
        seg_mask = self.segmenter.route_points(data, keys)  # (n, m) bool
        m = seg_mask.shape[1]
        rows: list[list[np.ndarray]] = []
        for s in range(self.num_shards):
            in_shard = shard == s
            per_seg = []
            for g in range(m):
                per_seg.append(np.nonzero(in_shard & seg_mask[:, g])[0])
            rows.append(per_seg)
        return PartitionAssignment(self.num_shards, m, rows)

    def route_queries(self, q: np.ndarray) -> np.ndarray:
        """(B, m) segment mask — identical for every shard (shared segmenter)."""
        return self.segmenter.route_queries(q)

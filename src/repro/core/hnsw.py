"""Hierarchical Navigable Small World (HNSW) index — array form.

LANNS (§3) uses HNSW [Malkov & Yashunin 2016] as the per-partition ANN engine.
This module implements HNSW faithfully in two halves that mirror the paper's
offline/online split:

* **Build** (offline — the paper builds inside Spark executors): a numpy
  implementation of Algorithms 1–4 of the HNSW paper (insert with greedy
  descent, ef_construction beam at each level, and the neighbor-selection
  heuristic).  Build is inherently sequential per index; LANNS gets its build
  parallelism *across* partitions (one HNSW per (shard, segment)), which is
  exactly what ``repro.core.lanns`` does.

* **Search** (online — the serving hot path): the frozen index is a set of
  fixed-shape int32 adjacency arrays, and search is a jit/vmap-compatible
  beam search written with ``jax.lax`` control flow so it runs under
  ``shard_map`` on a TPU mesh.  This is the TPU adaptation described in
  DESIGN.md §2: instead of pointer-chasing over a heap-allocated graph, we
  keep a top-``ef`` beam as dense (ids, dists, expanded) arrays and expand the
  best unexpanded node each iteration with a batched gather + MXU-friendly
  distance block.

Frozen layout
-------------
``vectors``      (n, d)  float32   — corpus (cosine-normalized if metric=cos)
``adj0``         (n, 2M) int32     — level-0 adjacency, -1 padded
``upper_adj``    (L, n, M) int32   — adjacency at levels 1..L, indexed by
                                     GLOBAL id (-1 rows for nodes absent at
                                     that level), so one fixed-shape stack
                                     replaces the ragged per-level lists
``entry``        int               — entry point (top-level node)

Trace stability (the serving contract): ``device_arrays`` pads ``n`` and
``L`` to caller-chosen buckets and caches the resulting device pytree on the
index, so (a) the graph uploads host->device ONCE per (n_pad, l_pad) bucket,
and (b) every partition padded to the same bucket reuses one ``beam_search``
trace.  ``beam_search_flat`` goes further and runs ALL partitions of an
index in a single vmapped call over flattened (partition, query) lanes —
the ``LannsIndex.query`` hot path; ``beam_search_stacked`` is the dense
(P, C) variant kept for the TPU dispatch comparison (ROADMAP).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class HNSWConfig:
    """Build/search parameters (HNSW paper notation).

    M:                max out-degree at levels >= 1 (level 0 uses 2M).
    ef_construction:  beam width during insertion.
    ef_search:        default beam width during search (>= k).
    metric:           'l2' (squared euclidean), 'ip' (inner product, maximize),
                      'cos' (cosine; vectors are L2-normalized at build/query).
    extend_candidates / keep_pruned: Algorithm 4 switches.
    """

    M: int = 16
    ef_construction: int = 100
    ef_search: int = 100
    metric: str = "l2"
    seed: int = 0
    extend_candidates: bool = False
    keep_pruned: bool = True
    max_level_cap: int = 12

    @property
    def m_l(self) -> float:
        return 1.0 / math.log(self.M)

    @property
    def m_max0(self) -> int:
        return 2 * self.M


def _normalize_rows(x: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, 1e-12)


def pairwise_dist(metric: str, q: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Distance from one query vector to rows of x.  Lower is better."""
    if metric == "l2":
        diff = x - q
        return np.einsum("nd,nd->n", diff, diff)
    # ip / cos: score = -<q, x> so "lower is better" stays uniform.
    return -(x @ q)


class HNSWIndex:
    """A single HNSW graph over one data partition."""

    def __init__(self, config: HNSWConfig, dim: int):
        self.config = config
        self.dim = dim
        self._vecs: list[np.ndarray] = []
        self._levels: list[int] = []
        # adjacency as python lists during build; frozen to arrays afterwards.
        self._adj: list[list[list[int]]] = []  # [level][node] -> [nbr ids]
        self.entry: int = -1
        self.max_level: int = -1
        self._rng = np.random.default_rng(config.seed)
        self._frozen = None
        self._vstack: Optional[np.ndarray] = None
        self._visited = np.zeros(0, dtype=np.int64)
        self._visit_gen = 0
        self.keys: Optional[np.ndarray] = None  # original (global) keys

    # ------------------------------------------------------------------
    # Build (numpy, Algorithms 1-4 of the HNSW paper)
    # ------------------------------------------------------------------

    @property
    def size(self) -> int:
        return len(self._vecs)

    def _dist(self, q: np.ndarray, ids) -> np.ndarray:
        ids = np.asarray(ids)
        vecs = self._vstack[ids]
        if self.config.metric == "l2":
            # true squared L2 via precomputed row norms (build hot path)
            return self._norms[ids] - 2.0 * (vecs @ q) + q @ q
        return -(vecs @ q)

    def _draw_level(self) -> int:
        u = self._rng.random()
        lvl = int(-math.log(max(u, 1e-12)) * self.config.m_l)
        return min(lvl, self.config.max_level_cap)

    def _search_layer(self, q, entry_points, ef, level):
        """Algorithm 2 — beam search at one level.  Returns (dists, ids) sorted."""
        cfg = self.config
        visited = self._visited
        self._visit_gen += 1
        gen = self._visit_gen
        adj = self._adj[level]

        eps = list(dict.fromkeys(entry_points))
        d0 = self._dist(q, eps)
        cand: list[tuple[float, int]] = []  # min-heap by dist
        best: list[tuple[float, int]] = []  # max-heap by -dist (the W set)
        for d, e in zip(d0, eps):
            visited[e] = gen
            heapq.heappush(cand, (float(d), e))
            heapq.heappush(best, (-float(d), e))
        while len(best) > ef:
            heapq.heappop(best)

        while cand:
            d_c, c = heapq.heappop(cand)
            d_worst = -best[0][0]
            if d_c > d_worst and len(best) >= ef:
                break
            nbrs = [u for u in adj[c] if visited[u] != gen]
            if not nbrs:
                continue
            for u in nbrs:
                visited[u] = gen
            dn = self._dist(q, nbrs)
            for d, u in zip(dn, nbrs):
                d = float(d)
                if len(best) < ef or d < -best[0][0]:
                    heapq.heappush(cand, (d, u))
                    heapq.heappush(best, (-d, u))
                    if len(best) > ef:
                        heapq.heappop(best)
        out = sorted((-nd, i) for nd, i in best)
        return [d for d, _ in out], [i for _, i in out]

    def _select_neighbors(self, q, cand_dists, cand_ids, m):
        """Algorithm 4 — heuristic neighbor selection with distance diversity.

        Vectorized: one (c, c) candidate-candidate distance matrix up front,
        then a cheap greedy pass using row slices of it (the per-candidate
        re-stacking this replaces dominated the build profile).
        """
        cfg = self.config
        cand_ids = np.asarray(cand_ids)
        cand_dists = np.asarray(cand_dists)
        order = np.argsort(cand_dists, kind="stable")
        ids = cand_ids[order]
        dists = cand_dists[order]
        c = len(ids)
        if c <= 1:
            return list(ids[:m])
        V = self._vstack[ids]  # (c, d)
        if cfg.metric == "l2":
            norms = np.einsum("cd,cd->c", V, V)
            D = norms[:, None] - 2.0 * (V @ V.T) + norms[None, :]
        else:
            D = -(V @ V.T)
        selected: list[int] = []  # positions into `ids`
        pruned: list[int] = []
        for i in range(c):
            if len(selected) >= m:
                break
            if not selected or dists[i] < D[i, selected].min():
                selected.append(i)
            elif cfg.keep_pruned:
                pruned.append(i)
        if cfg.keep_pruned and len(selected) < m:
            selected.extend(pruned[: m - len(selected)])
        return [int(ids[i]) for i in selected]

    def add_batch(self, vectors: np.ndarray, keys: Optional[np.ndarray] = None):
        """Insert vectors sequentially (HNSW build is order-dependent)."""
        cfg = self.config
        vectors = np.asarray(vectors, dtype=np.float32)
        if cfg.metric == "cos":
            vectors = _normalize_rows(vectors)
        n_new = vectors.shape[0]
        n_total = self.size + n_new
        self._visited = np.zeros(n_total, dtype=np.int64)
        self._visit_gen = 0
        # keep a contiguous copy for vectorized gathers during build
        if self.size:
            self._vstack = np.concatenate([np.stack(self._vecs), vectors])
        else:
            self._vstack = vectors
        self._norms = np.einsum("nd,nd->n", self._vstack, self._vstack)

        for r in range(n_new):
            x = vectors[r]
            i = self.size
            self._vecs.append(x)
            lvl = self._draw_level()
            self._levels.append(lvl)
            while len(self._adj) <= lvl:
                self._adj.append({})  # type: ignore[arg-type]
            # adjacency stored as dict level -> {node: list}; normalize lazily
            for l in range(lvl + 1):
                if isinstance(self._adj[l], dict):
                    self._adj[l][i] = []

            if self.entry < 0:
                self.entry = i
                self.max_level = lvl
                continue

            ep = [self.entry]
            # Phase 1: greedy descent through levels above lvl
            for l in range(self.max_level, lvl, -1):
                _, ids = self._search_layer(x, ep, 1, l)
                ep = ids[:1]
            # Phase 2: connect at each level from min(max_level, lvl) .. 0
            for l in range(min(self.max_level, lvl), -1, -1):
                m_max = cfg.m_max0 if l == 0 else cfg.M
                dists, ids = self._search_layer(x, ep, cfg.ef_construction, l)
                cand_ids, cand_d = ids, dists
                if cfg.extend_candidates:
                    ext = {u for c in ids for u in self._adj[l][c]}
                    ext -= set(ids)
                    if ext:
                        ext = list(ext)
                        cand_ids = ids + ext
                        cand_d = dists + list(self._dist(x, ext))
                sel = self._select_neighbors(x, cand_d, cand_ids, cfg.M)
                self._adj[l][i] = list(sel)
                for s in sel:
                    self._adj[l][s].append(i)
                    self._prune_node_dict(s, l, m_max)
                ep = ids
            if lvl > self.max_level:
                self.max_level = lvl
                self.entry = i
        if keys is not None:
            keys = np.asarray(keys)
            self.keys = keys if self.keys is None else np.concatenate([self.keys, keys])
        self._frozen = None
        return self

    def _prune_node_dict(self, node, level, m_max):
        adj = self._adj[level][node]
        if len(adj) <= m_max:
            return
        q = self._vecs[node]
        d = self._dist(q, adj)
        self._adj[level][node] = self._select_neighbors(q, list(d), list(adj), m_max)

    # ------------------------------------------------------------------
    # Freeze to arrays
    # ------------------------------------------------------------------

    def freeze(self) -> "FrozenHNSW":
        if self._frozen is not None:
            return self._frozen
        cfg = self.config
        n = self.size
        vecs = np.stack(self._vecs).astype(np.float32)
        levels = np.asarray(self._levels, dtype=np.int32)
        adj0 = np.full((n, cfg.m_max0), -1, dtype=np.int32)
        for i, nbrs in self._adj[0].items():
            k = min(len(nbrs), cfg.m_max0)
            adj0[i, :k] = nbrs[:k]
        n_upper = max(len(self._adj) - 1, 0)
        upper_adj = np.full((n_upper, n, cfg.M), -1, dtype=np.int32)
        for l in range(1, len(self._adj)):
            for i, nbrs in self._adj[l].items():
                nbrs = nbrs[: cfg.M]
                upper_adj[l - 1, i, : len(nbrs)] = nbrs
        self._frozen = FrozenHNSW(
            config=cfg,
            vectors=vecs,
            levels=levels,
            adj0=adj0,
            upper_adj=upper_adj,
            entry=self.entry,
            keys=self.keys,
        )
        return self._frozen

    # convenience: numpy reference search (exact same algorithm as build beam)
    def search_np(self, queries: np.ndarray, k: int, ef: Optional[int] = None):
        cfg = self.config
        ef = max(ef or cfg.ef_search, k)
        queries = np.asarray(queries, dtype=np.float32)
        if cfg.metric == "cos":
            queries = _normalize_rows(queries)
        self._visited = np.zeros(self.size, dtype=np.int64)
        self._visit_gen = 0
        self._vstack = np.stack(self._vecs)
        self._norms = np.einsum("nd,nd->n", self._vstack, self._vstack)
        out_d = np.full((len(queries), k), _INF, dtype=np.float32)
        out_i = np.full((len(queries), k), -1, dtype=np.int64)
        for qi, q in enumerate(queries):
            ep = [self.entry]
            for l in range(self.max_level, 0, -1):
                _, ids = self._search_layer(q, ep, 1, l)
                ep = ids[:1]
            dists, ids = self._search_layer(q, ep, ef, 0)
            m = min(k, len(ids))
            out_d[qi, :m] = dists[:m]
            out_i[qi, :m] = ids[:m]
        if self.keys is not None:
            valid = out_i >= 0
            out_i = np.where(valid, self.keys[np.clip(out_i, 0, None)], -1)
        return out_d, out_i


def stack_upper_adj(
    level_nodes: list, level_adj: list, n: int, M: int
) -> np.ndarray:
    """Convert the legacy ragged (level_nodes, level_adj) lists to the
    stacked (L, n, M) global-id adjacency (used when loading old artifacts)."""
    L = len(level_adj)
    upper = np.full((L, n, M), -1, dtype=np.int32)
    for l in range(L):
        ids = np.asarray(level_nodes[l], dtype=np.int64)
        a = np.asarray(level_adj[l], dtype=np.int32)
        m = min(a.shape[1], M) if a.size else 0
        if len(ids):
            upper[l, ids, :m] = a[:, :m]
    return upper


@dataclasses.dataclass
class FrozenHNSW:
    """Immutable array-form HNSW, ready for jit search / serialization."""

    config: HNSWConfig
    vectors: np.ndarray
    levels: np.ndarray
    adj0: np.ndarray
    upper_adj: np.ndarray  # (L, n, M) global-id adjacency, -1 padded
    entry: int
    keys: Optional[np.ndarray] = None

    def __post_init__(self):
        self._device_cache: dict = {}

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    @property
    def num_upper_levels(self) -> int:
        return self.upper_adj.shape[0]

    def device_arrays(self, n_pad: Optional[int] = None,
                      l_pad: Optional[int] = None, *, cached: bool = True):
        """The pytree consumed by ``beam_search`` (device-resident state).

        ``n_pad``/``l_pad`` pad the corpus rows / upper-level count to shared
        bucket sizes so beam_search traces are reused across partitions
        (padding rows are -1 adjacency = unreachable, zero vectors = never
        scored).  The pytree is built and uploaded ONCE per (n_pad, l_pad)
        bucket and cached on the index — serving must never re-ship the graph
        host->device per call.
        """
        n = self.size
        n_pad = n if n_pad is None else n_pad
        l_pad = self.num_upper_levels if l_pad is None else l_pad
        if n_pad < n or l_pad < self.num_upper_levels:
            raise ValueError(
                f"pad ({n_pad}, {l_pad}) smaller than index "
                f"({n}, {self.num_upper_levels})"
            )
        key = (n_pad, l_pad)
        if cached and key in self._device_cache:
            return self._device_cache[key]
        from repro.common.utils import pad_axis_to, pad_to

        vecs = pad_to(self.vectors, n_pad)
        adj0 = pad_to(self.adj0, n_pad, fill=-1)
        upper = pad_axis_to(self.upper_adj, 1, n_pad, fill=-1)
        upper = pad_to(upper, l_pad, fill=-1)
        arrs = {
            "vectors": jnp.asarray(vecs),
            "adj0": jnp.asarray(adj0),
            "upper_adj": jnp.asarray(upper),
            "entry": jnp.asarray(self.entry, dtype=jnp.int32),
        }
        if cached:
            self._device_cache[key] = arrs
        return arrs

    # lanns: dims[B<=4096, k<=200, n<=33_554_432]
    def search(  # lanns: hotpath
        self,
        queries,
        k: int,
        ef: Optional[int] = None,
        max_iters: int = 0,
        *,
        n_pad: Optional[int] = None,
        l_pad: Optional[int] = None,
        cached: bool = True,
        pad_queries: bool = True,
    ):
        """Batched jit beam search. Returns (dists (B,k), ids (B,k)).

        pad_queries=True pads the batch to its quarter-pow2 bucket (see
        ``next_pow2_quarter``: <= 25% padding, ~4 buckets per octave) so
        routed subsets of every size reuse a bounded set of traces.
        cached=False rebuilds the device pytree per call (the
        pre-device-resident behaviour; kept for before/after benchmarking).
        """
        cfg = self.config
        ef = max(ef or cfg.ef_search, k)
        if max_iters <= 0:
            max_iters = ef + 2 * cfg.M
        q = np.asarray(queries, dtype=np.float32)
        B = q.shape[0]
        if B == 0:
            return (np.full((0, k), _INF, np.float32),
                    np.full((0, k), -1, np.int64))
        if cfg.metric == "cos":
            q = q / np.maximum(
                np.linalg.norm(q, axis=-1, keepdims=True), 1e-12
            )
        valid = None
        if pad_queries:
            from repro.common.utils import next_pow2_quarter, pad_to

            B_pad = next_pow2_quarter(B)
            if B_pad != B:
                q = pad_to(q, B_pad)
                valid = jnp.asarray(np.arange(B_pad) < B)
        arrs = self.device_arrays(n_pad, l_pad, cached=cached)
        d, i = beam_search(  # lanns: noqa[LANNS033] -- k ranges over the finite per-request knob set (<= 200), not the corpus; bounded trace cardinality by the knob_groups contract
            arrs,
            jnp.asarray(q),
            valid,
            k=k,
            ef=ef,
            max_iters=max_iters,
            metric="l2" if cfg.metric == "l2" else "ip",
        )
        d, i = np.asarray(d)[:B], np.asarray(i)[:B]  # lanns: noqa[LANNS003] -- the single designed host sync of the beam batch
        if self.keys is not None:
            valid = i >= 0
            i = np.where(valid, self.keys[np.clip(i, 0, None)], -1)
        return d, i


# ---------------------------------------------------------------------------
# JAX search (serving hot path)
# ---------------------------------------------------------------------------


def _distance_rows(metric, q, x):
    """q (d,), x (m, d) -> (m,). Lower is better."""
    if metric == "l2":
        # ||q-x||^2 = ||x||^2 - 2<q,x> + ||q||^2 ; the ||q||^2 term is a
        # per-query constant and irrelevant for ranking but we keep it so the
        # returned distances are true squared distances (tests rely on it).
        return jnp.sum((x - q[None, :]) ** 2, axis=-1)
    return -(x @ q)


def _make_row_dist(arrs, metric):
    """Per-lane distance closure: (q, rows) -> (m,) scores, lower is better.

    fp32 mode (no ``norms2`` leaf in ``arrs``): gather fp32 rows, exact
    ``_distance_rows`` — the pre-existing path, op-for-op.

    Quantized mode (``arrs['norms2']`` present): ``vectors`` holds int8
    CODES and the caller pre-folds the partition's per-dim scales into each
    lane's query (``q_lane = q * scales[partition]``), so one fp32 cast-gemm
    per gather gives ``<q, x_hat>`` — the dot against the dequantized row —
    with no per-row scale gather.  'l2' scores are then
    ``||x_hat||^2 - 2<q, x_hat>``: the true squared distance to the
    dequantized point MINUS the per-query ||q||^2 constant, which cannot
    change any within-lane ordering (the beam only ever compares distances
    of one lane); the exact re-rank stage replaces these scores anyway.
    Presence of the extra pytree leaf changes the jit cache key, so fp32
    traces are never polluted.
    """
    vectors = arrs["vectors"]
    norms2 = arrs.get("norms2")
    if norms2 is None:
        return lambda q, rows: _distance_rows(metric, q, vectors[rows])

    def dist(q, rows):
        dots = vectors[rows].astype(jnp.float32) @ q
        if metric == "l2":
            return norms2[rows] - 2.0 * dots
        return -dots

    return dist


def _beam_search_lanes(arrs, queries, entry_rows, offsets, valid, *,
                       k, ef, max_iters, metric):
    """The beam-search core, in flat row space.

    Upper levels: greedy descent (while_loop) over the stacked (L, n, M)
    row-indexed adjacency — a padding level (all -1 rows) is a no-op walk, so
    partitions with fewer levels share the trace of the deepest one.  Level 0:
    best-first beam of width ``ef`` kept as dense arrays; each iteration
    expands the best unexpanded entry.  All ops are fixed-shape so the whole
    thing jits, vmaps over lanes, and shard_maps.  Expanded-set semantics: a
    node evicted from the beam may be re-inserted and re-expanded later; this
    wastes a little compute but never hurts correctness (matches the
    `visited`-free formulations of array HNSW).

    Each lane walks rows [off, off + n_partition) of the flat arrays:
    adjacency entries are partition-local, so every gathered neighbor id is
    shifted by the lane's ``off``.  A single partition is the off == 0
    special case.  An invalid lane (padding) seeds the walk with a -inf
    entry distance and an empty beam, so both loops exit immediately.

    ``arrs`` may carry a quantized corpus (int8 codes + ``norms2``; see
    ``_make_row_dist``) — the walk itself is precision-agnostic.
    """
    adj0 = arrs["adj0"]
    upper_adj = arrs["upper_adj"]
    num_upper_levels = upper_adj.shape[0]
    row_dist = _make_row_dist(arrs, metric)

    def one_lane(q, ep, off, v):
        def to_rows(nbrs):
            return jnp.where(nbrs >= 0, nbrs + off, -1)

        # ---- upper levels: greedy walk to a local minimum per level
        ep_d = row_dist(q, jnp.clip(ep, 0)[None])[0]
        ep_d = jnp.where(v, ep_d, -jnp.inf)
        ep = jnp.where(v, ep, -1)
        for l in range(num_upper_levels - 1, -1, -1):
            adj = upper_adj[l]

            def body(state):
                ep, ep_d, _ = state
                nbrs = to_rows(adj[jnp.clip(ep, 0)])
                valid_n = nbrs >= 0
                nd = row_dist(q, jnp.clip(nbrs, 0))
                nd = jnp.where(valid_n, nd, jnp.inf)
                j = jnp.argmin(nd)
                better = nd[j] < ep_d
                return (
                    jnp.where(better, nbrs[j], ep),
                    jnp.where(better, nd[j], ep_d),
                    better,
                )

            def cond(state):
                return state[2]

            ep, ep_d, _ = jax.lax.while_loop(cond, body, (ep, ep_d, jnp.bool_(True)))

        # ---- level 0 beam
        m0 = adj0.shape[1]
        beam_ids = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(ep)
        beam_d = jnp.full((ef,), jnp.inf, dtype=jnp.float32).at[0].set(ep_d)
        beam_exp = jnp.zeros((ef,), dtype=jnp.bool_)

        def cond(state):
            beam_ids, beam_d, beam_exp, it = state
            frontier = (~beam_exp) & (beam_ids >= 0)
            return jnp.any(frontier) & (it < max_iters)

        def body(state):
            beam_ids, beam_d, beam_exp, it = state
            pick_d = jnp.where((~beam_exp) & (beam_ids >= 0), beam_d, jnp.inf)
            b = jnp.argmin(pick_d)
            beam_exp = beam_exp.at[b].set(True)
            node = beam_ids[b]
            nbrs = to_rows(adj0[jnp.clip(node, 0)])
            valid_n = nbrs >= 0
            # dedup against current beam (m0 x ef comparison matrix)
            dup = jnp.any(nbrs[:, None] == beam_ids[None, :], axis=1)
            valid_n = valid_n & (~dup)
            nd = row_dist(q, jnp.clip(nbrs, 0))
            nd = jnp.where(valid_n, nd, jnp.inf)
            # merge (ef + m0) candidates, keep best ef
            all_ids = jnp.concatenate([beam_ids, jnp.where(valid_n, nbrs, -1)])
            all_d = jnp.concatenate([beam_d, nd])
            all_exp = jnp.concatenate([beam_exp, jnp.zeros((m0,), jnp.bool_)])
            neg_top, idx = jax.lax.top_k(-all_d, ef)
            return all_ids[idx], -neg_top, all_exp[idx], it + 1

        beam_ids, beam_d, beam_exp, _ = jax.lax.while_loop(
            cond, body, (beam_ids, beam_d, beam_exp, jnp.int32(0))
        )
        neg_top, idx = jax.lax.top_k(-beam_d, k)
        return -neg_top, beam_ids[idx]

    return jax.vmap(one_lane)(queries, entry_rows, offsets, valid)


def _beam_search_impl(arrs, queries, valid=None, *, k, ef, max_iters, metric):
    """Single-partition batched search: the zero-offset case of the core."""
    B = queries.shape[0]
    if valid is None:
        valid = jnp.ones((B,), dtype=jnp.bool_)
    entry_rows = jnp.broadcast_to(
        jnp.asarray(arrs["entry"], jnp.int32), (B,)
    )
    offsets = jnp.zeros((B,), jnp.int32)
    return _beam_search_lanes(
        {k_: arrs[k_] for k_ in ("vectors", "adj0", "upper_adj")},
        queries, entry_rows, offsets, valid,
        k=k, ef=ef, max_iters=max_iters, metric=metric,
    )


@partial(jax.jit, static_argnames=("k", "ef", "max_iters", "metric"))
def beam_search(arrs, queries, valid=None, *, k, ef, max_iters, metric):
    """Jit entry point: one partition, queries (B, d) -> ((B, k), (B, k)).
    ``valid`` (B,) marks real rows of a padded batch; padding rows exit
    immediately instead of walking the graph."""
    return _beam_search_impl(
        arrs, queries, valid, k=k, ef=ef, max_iters=max_iters, metric=metric
    )


@partial(jax.jit, static_argnames=("k", "ef", "max_iters", "metric"))
def beam_search_flat(arrs, queries, entry_rows, offsets, valid, *,
                     k, ef, max_iters, metric):
    """Multi-partition search over FLATTENED partition arrays.

    ``arrs`` holds every partition's rows concatenated: vectors (P*n, d),
    adj0 (P*n, 2M), upper_adj (L, P*n, M); adjacency entries stay partition-
    LOCAL.  Each lane of ``queries`` (T, d) carries its partition via
    ``offsets`` (T,) — the partition's first row in the flat arrays — and
    starts at ``entry_rows`` (T,) (the partition entry point, already
    offset).  Gathered neighbor ids are shifted by the lane's offset, so the
    whole walk runs in global row space and one vmapped call serves an
    arbitrary mix of (partition, query) pairs.

    vs the dense (P, C) ``beam_search_stacked``: lane count is the NUMBER OF
    ROUTED PAIRS (padded to a bucket), not partitions x the most-loaded
    partition's count — under unbalanced routing the dense form wastes up to
    ~2x lanes, and under vmap every padded lane runs the full loop.  Returns
    (dists (T, k), rows (T, k)) with rows in global (flat) space; map them
    through a flat key table host-side.

    Quantized corpora: pass int8 codes as ``vectors`` plus a ``norms2``
    leaf and pre-fold each lane's per-partition scales into its query row
    (``_make_row_dist``); the extra leaf keys a separate jit trace, so the
    fp32 path is untouched.
    """
    return _beam_search_lanes(
        arrs, queries, entry_rows, offsets, valid,
        k=k, ef=ef, max_iters=max_iters, metric=metric,
    )


@partial(jax.jit, static_argnames=("k", "ef", "max_iters", "metric"))
def beam_search_stacked(arrs, queries, valid=None, *, k, ef, max_iters, metric):
    """Multi-partition search: every leaf of ``arrs`` carries a leading
    partition axis (vectors (P, n, d), adj0 (P, n, 2M), upper_adj
    (P, L, n, M), entry (P,)) and queries is (P, C, d) — one vmapped
    ``beam_search`` serves all (shard, segment) partitions in a single call,
    with no per-partition Python dispatch or host<->device sync.  ``valid``
    (P, C) marks real query slots; padding slots short-circuit.
    """
    if valid is None:
        valid = jnp.ones(queries.shape[:-1], dtype=jnp.bool_)
    return jax.vmap(
        lambda a, q, v: _beam_search_impl(
            a, q, v, k=k, ef=ef, max_iters=max_iters, metric=metric
        )
    )(arrs, queries, valid)

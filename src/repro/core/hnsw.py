"""Hierarchical Navigable Small World (HNSW) index — array form.

LANNS (§3) uses HNSW [Malkov & Yashunin 2016] as the per-partition ANN engine.
This module implements HNSW faithfully in two halves that mirror the paper's
offline/online split:

* **Build** (offline — the paper builds inside Spark executors): a numpy
  implementation of Algorithms 1–4 of the HNSW paper (insert with greedy
  descent, ef_construction beam at each level, and the neighbor-selection
  heuristic).  Build is inherently sequential per index; LANNS gets its build
  parallelism *across* partitions (one HNSW per (shard, segment)), which is
  exactly what ``repro.core.lanns`` does.

* **Search** (online — the serving hot path): the frozen index is a set of
  fixed-shape int32 adjacency arrays, and search is a jit/vmap-compatible
  beam search written with ``jax.lax`` control flow so it runs under
  ``shard_map`` on a TPU mesh.  This is the TPU adaptation described in
  DESIGN.md §2: instead of pointer-chasing over a heap-allocated graph, we
  keep a top-``ef`` beam as dense (ids, dists, expanded) arrays and expand the
  best unexpanded node each iteration with a batched gather + MXU-friendly
  distance block.

Frozen layout
-------------
``vectors``      (n, d)  float32   — corpus (cosine-normalized if metric=cos)
``adj0``         (n, 2M) int32     — level-0 adjacency, -1 padded
``level_nodes``  list[(n_l,)]      — global ids present at level l >= 1
``level_adj``    list[(n_l, M)]    — adjacency at level l >= 1 (global ids)
``level_loc``    list[(n,)]        — global id -> local row at level l (-1 absent)
``entry``        int               — entry point (top-level node)
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class HNSWConfig:
    """Build/search parameters (HNSW paper notation).

    M:                max out-degree at levels >= 1 (level 0 uses 2M).
    ef_construction:  beam width during insertion.
    ef_search:        default beam width during search (>= k).
    metric:           'l2' (squared euclidean), 'ip' (inner product, maximize),
                      'cos' (cosine; vectors are L2-normalized at build/query).
    extend_candidates / keep_pruned: Algorithm 4 switches.
    """

    M: int = 16
    ef_construction: int = 100
    ef_search: int = 100
    metric: str = "l2"
    seed: int = 0
    extend_candidates: bool = False
    keep_pruned: bool = True
    max_level_cap: int = 12

    @property
    def m_l(self) -> float:
        return 1.0 / math.log(self.M)

    @property
    def m_max0(self) -> int:
        return 2 * self.M


def _normalize_rows(x: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, 1e-12)


def pairwise_dist(metric: str, q: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Distance from one query vector to rows of x.  Lower is better."""
    if metric == "l2":
        diff = x - q
        return np.einsum("nd,nd->n", diff, diff)
    # ip / cos: score = -<q, x> so "lower is better" stays uniform.
    return -(x @ q)


class HNSWIndex:
    """A single HNSW graph over one data partition."""

    def __init__(self, config: HNSWConfig, dim: int):
        self.config = config
        self.dim = dim
        self._vecs: list[np.ndarray] = []
        self._levels: list[int] = []
        # adjacency as python lists during build; frozen to arrays afterwards.
        self._adj: list[list[list[int]]] = []  # [level][node] -> [nbr ids]
        self.entry: int = -1
        self.max_level: int = -1
        self._rng = np.random.default_rng(config.seed)
        self._frozen = None
        self._vstack: Optional[np.ndarray] = None
        self._visited = np.zeros(0, dtype=np.int64)
        self._visit_gen = 0
        self.keys: Optional[np.ndarray] = None  # original (global) keys

    # ------------------------------------------------------------------
    # Build (numpy, Algorithms 1-4 of the HNSW paper)
    # ------------------------------------------------------------------

    @property
    def size(self) -> int:
        return len(self._vecs)

    def _dist(self, q: np.ndarray, ids) -> np.ndarray:
        ids = np.asarray(ids)
        vecs = self._vstack[ids]
        if self.config.metric == "l2":
            # true squared L2 via precomputed row norms (build hot path)
            return self._norms[ids] - 2.0 * (vecs @ q) + q @ q
        return -(vecs @ q)

    def _draw_level(self) -> int:
        u = self._rng.random()
        lvl = int(-math.log(max(u, 1e-12)) * self.config.m_l)
        return min(lvl, self.config.max_level_cap)

    def _search_layer(self, q, entry_points, ef, level):
        """Algorithm 2 — beam search at one level.  Returns (dists, ids) sorted."""
        cfg = self.config
        visited = self._visited
        self._visit_gen += 1
        gen = self._visit_gen
        adj = self._adj[level]

        eps = list(dict.fromkeys(entry_points))
        d0 = self._dist(q, eps)
        cand: list[tuple[float, int]] = []  # min-heap by dist
        best: list[tuple[float, int]] = []  # max-heap by -dist (the W set)
        for d, e in zip(d0, eps):
            visited[e] = gen
            heapq.heappush(cand, (float(d), e))
            heapq.heappush(best, (-float(d), e))
        while len(best) > ef:
            heapq.heappop(best)

        while cand:
            d_c, c = heapq.heappop(cand)
            d_worst = -best[0][0]
            if d_c > d_worst and len(best) >= ef:
                break
            nbrs = [u for u in adj[c] if visited[u] != gen]
            if not nbrs:
                continue
            for u in nbrs:
                visited[u] = gen
            dn = self._dist(q, nbrs)
            for d, u in zip(dn, nbrs):
                d = float(d)
                if len(best) < ef or d < -best[0][0]:
                    heapq.heappush(cand, (d, u))
                    heapq.heappush(best, (-d, u))
                    if len(best) > ef:
                        heapq.heappop(best)
        out = sorted((-nd, i) for nd, i in best)
        return [d for d, _ in out], [i for _, i in out]

    def _select_neighbors(self, q, cand_dists, cand_ids, m):
        """Algorithm 4 — heuristic neighbor selection with distance diversity.

        Vectorized: one (c, c) candidate-candidate distance matrix up front,
        then a cheap greedy pass using row slices of it (the per-candidate
        re-stacking this replaces dominated the build profile).
        """
        cfg = self.config
        cand_ids = np.asarray(cand_ids)
        cand_dists = np.asarray(cand_dists)
        order = np.argsort(cand_dists, kind="stable")
        ids = cand_ids[order]
        dists = cand_dists[order]
        c = len(ids)
        if c <= 1:
            return list(ids[:m])
        V = self._vstack[ids]  # (c, d)
        if cfg.metric == "l2":
            norms = np.einsum("cd,cd->c", V, V)
            D = norms[:, None] - 2.0 * (V @ V.T) + norms[None, :]
        else:
            D = -(V @ V.T)
        selected: list[int] = []  # positions into `ids`
        pruned: list[int] = []
        for i in range(c):
            if len(selected) >= m:
                break
            if not selected or dists[i] < D[i, selected].min():
                selected.append(i)
            elif cfg.keep_pruned:
                pruned.append(i)
        if cfg.keep_pruned and len(selected) < m:
            selected.extend(pruned[: m - len(selected)])
        return [int(ids[i]) for i in selected]

    def add_batch(self, vectors: np.ndarray, keys: Optional[np.ndarray] = None):
        """Insert vectors sequentially (HNSW build is order-dependent)."""
        cfg = self.config
        vectors = np.asarray(vectors, dtype=np.float32)
        if cfg.metric == "cos":
            vectors = _normalize_rows(vectors)
        n_new = vectors.shape[0]
        n_total = self.size + n_new
        self._visited = np.zeros(n_total, dtype=np.int64)
        self._visit_gen = 0
        # keep a contiguous copy for vectorized gathers during build
        if self.size:
            self._vstack = np.concatenate([np.stack(self._vecs), vectors])
        else:
            self._vstack = vectors
        self._norms = np.einsum("nd,nd->n", self._vstack, self._vstack)

        for r in range(n_new):
            x = vectors[r]
            i = self.size
            self._vecs.append(x)
            lvl = self._draw_level()
            self._levels.append(lvl)
            while len(self._adj) <= lvl:
                self._adj.append({})  # type: ignore[arg-type]
            # adjacency stored as dict level -> {node: list}; normalize lazily
            for l in range(lvl + 1):
                if isinstance(self._adj[l], dict):
                    self._adj[l][i] = []

            if self.entry < 0:
                self.entry = i
                self.max_level = lvl
                continue

            ep = [self.entry]
            # Phase 1: greedy descent through levels above lvl
            for l in range(self.max_level, lvl, -1):
                _, ids = self._search_layer(x, ep, 1, l)
                ep = ids[:1]
            # Phase 2: connect at each level from min(max_level, lvl) .. 0
            for l in range(min(self.max_level, lvl), -1, -1):
                m_max = cfg.m_max0 if l == 0 else cfg.M
                dists, ids = self._search_layer(x, ep, cfg.ef_construction, l)
                cand_ids, cand_d = ids, dists
                if cfg.extend_candidates:
                    ext = {u for c in ids for u in self._adj[l][c]}
                    ext -= set(ids)
                    if ext:
                        ext = list(ext)
                        cand_ids = ids + ext
                        cand_d = dists + list(self._dist(x, ext))
                sel = self._select_neighbors(x, cand_d, cand_ids, cfg.M)
                self._adj[l][i] = list(sel)
                for s in sel:
                    self._adj[l][s].append(i)
                    self._prune_node_dict(s, l, m_max)
                ep = ids
            if lvl > self.max_level:
                self.max_level = lvl
                self.entry = i
        if keys is not None:
            keys = np.asarray(keys)
            self.keys = keys if self.keys is None else np.concatenate([self.keys, keys])
        self._frozen = None
        return self

    def _prune_node_dict(self, node, level, m_max):
        adj = self._adj[level][node]
        if len(adj) <= m_max:
            return
        q = self._vecs[node]
        d = self._dist(q, adj)
        self._adj[level][node] = self._select_neighbors(q, list(d), list(adj), m_max)

    # ------------------------------------------------------------------
    # Freeze to arrays
    # ------------------------------------------------------------------

    def freeze(self) -> "FrozenHNSW":
        if self._frozen is not None:
            return self._frozen
        cfg = self.config
        n = self.size
        vecs = np.stack(self._vecs).astype(np.float32)
        levels = np.asarray(self._levels, dtype=np.int32)
        adj0 = np.full((n, cfg.m_max0), -1, dtype=np.int32)
        for i, nbrs in self._adj[0].items():
            k = min(len(nbrs), cfg.m_max0)
            adj0[i, :k] = nbrs[:k]
        level_nodes, level_adj, level_loc = [], [], []
        for l in range(1, len(self._adj)):
            ids = np.asarray(sorted(self._adj[l].keys()), dtype=np.int32)
            a = np.full((len(ids), cfg.M), -1, dtype=np.int32)
            loc = np.full(n, -1, dtype=np.int32)
            for r, i in enumerate(ids):
                nbrs = self._adj[l][i][: cfg.M]
                a[r, : len(nbrs)] = nbrs
                loc[i] = r
            level_nodes.append(ids)
            level_adj.append(a)
            level_loc.append(loc)
        self._frozen = FrozenHNSW(
            config=cfg,
            vectors=vecs,
            levels=levels,
            adj0=adj0,
            level_nodes=level_nodes,
            level_adj=level_adj,
            level_loc=level_loc,
            entry=self.entry,
            keys=self.keys,
        )
        return self._frozen

    # convenience: numpy reference search (exact same algorithm as build beam)
    def search_np(self, queries: np.ndarray, k: int, ef: Optional[int] = None):
        cfg = self.config
        ef = max(ef or cfg.ef_search, k)
        queries = np.asarray(queries, dtype=np.float32)
        if cfg.metric == "cos":
            queries = _normalize_rows(queries)
        self._visited = np.zeros(self.size, dtype=np.int64)
        self._visit_gen = 0
        self._vstack = np.stack(self._vecs)
        self._norms = np.einsum("nd,nd->n", self._vstack, self._vstack)
        out_d = np.full((len(queries), k), _INF, dtype=np.float32)
        out_i = np.full((len(queries), k), -1, dtype=np.int64)
        for qi, q in enumerate(queries):
            ep = [self.entry]
            for l in range(self.max_level, 0, -1):
                _, ids = self._search_layer(q, ep, 1, l)
                ep = ids[:1]
            dists, ids = self._search_layer(q, ep, ef, 0)
            m = min(k, len(ids))
            out_d[qi, :m] = dists[:m]
            out_i[qi, :m] = ids[:m]
        if self.keys is not None:
            valid = out_i >= 0
            out_i = np.where(valid, self.keys[np.clip(out_i, 0, None)], -1)
        return out_d, out_i


@dataclasses.dataclass
class FrozenHNSW:
    """Immutable array-form HNSW, ready for jit search / serialization."""

    config: HNSWConfig
    vectors: np.ndarray
    levels: np.ndarray
    adj0: np.ndarray
    level_nodes: list
    level_adj: list
    level_loc: list
    entry: int
    keys: Optional[np.ndarray] = None

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    def device_arrays(self):
        """The pytree consumed by ``beam_search`` (device-resident state)."""
        return {
            "vectors": jnp.asarray(self.vectors),
            "adj0": jnp.asarray(self.adj0),
            "level_adj": [jnp.asarray(a) for a in self.level_adj],
            "level_loc": [jnp.asarray(l) for l in self.level_loc],
            "entry": jnp.asarray(self.entry, dtype=jnp.int32),
        }

    def search(self, queries, k: int, ef: Optional[int] = None, max_iters: int = 0):
        """Batched jit beam search. Returns (dists (B,k), ids (B,k))."""
        cfg = self.config
        ef = max(ef or cfg.ef_search, k)
        if max_iters <= 0:
            max_iters = ef + 2 * cfg.M
        q = jnp.asarray(queries, dtype=jnp.float32)
        if cfg.metric == "cos":
            q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        arrs = self.device_arrays()
        d, i = beam_search(
            arrs,
            q,
            k=k,
            ef=ef,
            max_iters=max_iters,
            metric="l2" if cfg.metric == "l2" else "ip",
            num_upper_levels=len(self.level_adj),
        )
        d, i = np.asarray(d), np.asarray(i)
        if self.keys is not None:
            valid = i >= 0
            i = np.where(valid, self.keys[np.clip(i, 0, None)], -1)
        return d, i


# ---------------------------------------------------------------------------
# JAX search (serving hot path)
# ---------------------------------------------------------------------------


def _distance_rows(metric, q, x):
    """q (d,), x (m, d) -> (m,). Lower is better."""
    if metric == "l2":
        # ||q-x||^2 = ||x||^2 - 2<q,x> + ||q||^2 ; the ||q||^2 term is a
        # per-query constant and irrelevant for ranking but we keep it so the
        # returned distances are true squared distances (tests rely on it).
        return jnp.sum((x - q[None, :]) ** 2, axis=-1)
    return -(x @ q)


@partial(
    jax.jit,
    static_argnames=("k", "ef", "max_iters", "metric", "num_upper_levels"),
)
def beam_search(arrs, queries, *, k, ef, max_iters, metric, num_upper_levels):
    """Batched HNSW search over frozen arrays.

    Upper levels: greedy descent (while_loop).  Level 0: best-first beam of
    width ``ef`` kept as dense arrays; each iteration expands the best
    unexpanded entry.  All ops are fixed-shape so the whole thing jits and
    shard_maps.  Expanded-set semantics: a node evicted from the beam may be
    re-inserted and re-expanded later; this wastes a little compute but never
    hurts correctness (matches the `visited`-free formulations of array HNSW).
    """
    vectors = arrs["vectors"]
    adj0 = arrs["adj0"]
    entry = arrs["entry"]

    def one_query(q):
        # ---- upper levels: greedy walk to a local minimum per level
        ep = entry
        ep_d = _distance_rows(metric, q, vectors[ep[None]])[0]
        for l in range(num_upper_levels - 1, -1, -1):
            adj = arrs["level_adj"][l]
            loc = arrs["level_loc"][l]

            def body(state):
                ep, ep_d, _ = state
                row = loc[ep]
                nbrs = adj[row]
                valid = nbrs >= 0
                nd = _distance_rows(metric, q, vectors[jnp.clip(nbrs, 0)])
                nd = jnp.where(valid, nd, jnp.inf)
                j = jnp.argmin(nd)
                better = nd[j] < ep_d
                return (
                    jnp.where(better, nbrs[j], ep),
                    jnp.where(better, nd[j], ep_d),
                    better,
                )

            def cond(state):
                return state[2]

            ep, ep_d, _ = jax.lax.while_loop(cond, body, (ep, ep_d, jnp.bool_(True)))

        # ---- level 0 beam
        m0 = adj0.shape[1]
        beam_ids = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(ep)
        beam_d = jnp.full((ef,), jnp.inf, dtype=jnp.float32).at[0].set(ep_d)
        beam_exp = jnp.zeros((ef,), dtype=jnp.bool_)

        def cond(state):
            beam_ids, beam_d, beam_exp, it = state
            frontier = (~beam_exp) & (beam_ids >= 0)
            return jnp.any(frontier) & (it < max_iters)

        def body(state):
            beam_ids, beam_d, beam_exp, it = state
            pick_d = jnp.where((~beam_exp) & (beam_ids >= 0), beam_d, jnp.inf)
            b = jnp.argmin(pick_d)
            beam_exp = beam_exp.at[b].set(True)
            node = beam_ids[b]
            nbrs = adj0[jnp.clip(node, 0)]
            valid = nbrs >= 0
            # dedup against current beam (m0 x ef comparison matrix)
            dup = jnp.any(nbrs[:, None] == beam_ids[None, :], axis=1)
            valid = valid & (~dup)
            nd = _distance_rows(metric, q, vectors[jnp.clip(nbrs, 0)])
            nd = jnp.where(valid, nd, jnp.inf)
            # merge (ef + m0) candidates, keep best ef
            all_ids = jnp.concatenate([beam_ids, jnp.where(valid, nbrs, -1)])
            all_d = jnp.concatenate([beam_d, nd])
            all_exp = jnp.concatenate([beam_exp, jnp.zeros((m0,), jnp.bool_)])
            neg_top, idx = jax.lax.top_k(-all_d, ef)
            return all_ids[idx], -neg_top, all_exp[idx], it + 1

        beam_ids, beam_d, beam_exp, _ = jax.lax.while_loop(
            cond, body, (beam_ids, beam_d, beam_exp, jnp.int32(0))
        )
        neg_top, idx = jax.lax.top_k(-beam_d, k)
        return -neg_top, beam_ids[idx]

    return jax.vmap(one_query)(queries)

"""Hierarchical Navigable Small World (HNSW) index — array form.

LANNS (§3) uses HNSW [Malkov & Yashunin 2016] as the per-partition ANN engine.
This module implements HNSW faithfully in two halves that mirror the paper's
offline/online split:

* **Build** (offline — the paper builds inside Spark executors): a numpy
  implementation of Algorithms 1–4 of the HNSW paper (insert with greedy
  descent, ef_construction beam at each level, and the neighbor-selection
  heuristic).  The graph lives in preallocated flat int32 adjacency arrays
  with degree counters (amortized-doubling growth across ``add_batch``
  calls), and insertion runs in deterministic *wavefront chunks*: level
  draws are batched per call, and the phase-1 greedy descent of every
  level-0 point in a chunk runs as ONE vectorized batched walk against the
  frozen spine graph (only points with level >= 1 ever mutate the upper
  levels, so the descent of a level-0 run is a pure function of spine
  state — the order-dependent level-0 connect/prune phase stays sequential
  within the chunk, which makes the built graph bit-identical for a fixed
  seed regardless of chunk size or worker count).  Per-index build is still
  sequential where HNSW requires it; LANNS gets its build parallelism
  *across* partitions (one HNSW per (shard, segment)), which is exactly
  what ``repro.core.lanns`` does.  ``HNSWIndexLegacy`` keeps the
  pre-wavefront python-list/heapq builder as the before/after benchmark
  baseline and recall oracle.

* **Search** (online — the serving hot path): the frozen index is a set of
  fixed-shape int32 adjacency arrays, and search is a jit/vmap-compatible
  beam search written with ``jax.lax`` control flow so it runs under
  ``shard_map`` on a TPU mesh.  This is the TPU adaptation described in
  DESIGN.md §2: instead of pointer-chasing over a heap-allocated graph, we
  keep a top-``ef`` beam as dense (ids, dists, expanded) arrays and expand the
  best unexpanded node each iteration with a batched gather + MXU-friendly
  distance block.

Frozen layout
-------------
``vectors``      (n, d)  float32   — corpus (cosine-normalized if metric=cos)
``adj0``         (n, 2M) int32     — level-0 adjacency, -1 padded
``upper_adj``    (L, n, M) int32   — adjacency at levels 1..L, indexed by
                                     GLOBAL id (-1 rows for nodes absent at
                                     that level), so one fixed-shape stack
                                     replaces the ragged per-level lists
``entry``        int               — entry point (top-level node)

Trace stability (the serving contract): ``device_arrays`` pads ``n`` and
``L`` to caller-chosen buckets and caches the resulting device pytree on the
index, so (a) the graph uploads host->device ONCE per (n_pad, l_pad) bucket,
and (b) every partition padded to the same bucket reuses one ``beam_search``
trace.  ``beam_search_flat`` goes further and runs ALL partitions of an
index in a single vmapped call over flattened (partition, query) lanes —
the ``LannsIndex.query`` hot path; ``beam_search_stacked`` is the dense
(P, C) variant kept for the TPU dispatch comparison (ROADMAP).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class HNSWConfig:
    """Build/search parameters (HNSW paper notation).

    M:                max out-degree at levels >= 1 (level 0 uses 2M).
    ef_construction:  beam width during insertion.
    ef_search:        default beam width during search (>= k).
    metric:           'l2' (squared euclidean), 'ip' (inner product, maximize),
                      'cos' (cosine; vectors are L2-normalized at build/query).
    extend_candidates / keep_pruned: Algorithm 4 switches.
    """

    M: int = 16
    ef_construction: int = 100
    ef_search: int = 100
    metric: str = "l2"
    seed: int = 0
    extend_candidates: bool = False
    keep_pruned: bool = True
    max_level_cap: int = 12

    @property
    def m_l(self) -> float:
        return 1.0 / math.log(self.M)

    @property
    def m_max0(self) -> int:
        return 2 * self.M


def _normalize_rows(x: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, 1e-12)


def pairwise_dist(metric: str, q: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Distance from one query vector to rows of x.  Lower is better."""
    if metric == "l2":
        diff = x - q
        return np.einsum("nd,nd->n", diff, diff)
    # ip / cos: score = -<q, x> so "lower is better" stays uniform.
    return -(x @ q)


#: default wavefront chunk: the max number of consecutive level-0 points
#: whose phase-1 descent is batched into one vectorized walk.  Any value
#: yields the same graph (descent of a level-0 run is a pure function of the
#: frozen spine); 256 amortizes the numpy dispatch overhead without making
#: the (chunk, M, d) gather buffers large.
DEFAULT_BUILD_CHUNK = 256

#: best-first expansion batch: per beam round, up to this many candidate
#: nodes are popped together and their neighborhoods scored in one
#: vectorized block.  Deterministic (pops follow the same (dist, id) heap
#: order) and per-query local, so it never affects chunk/worker invariance;
#: it trades a few extra distance evaluations for ~B fewer numpy dispatches
#: per round, which dominates single-core build time.
_EXPAND_BATCH = 16

_MIN_CAP = 1024
_MIN_UPPER_CAP = 64


class HNSWIndex:
    """A single HNSW graph over one data partition (bulk wavefront builder).

    Storage is flat preallocated arrays with amortized-doubling growth, so
    repeated ``add_batch`` calls (the streaming-mutability precursor) are
    linear instead of re-concatenating the corpus per call:

    ``_vstack``  (cap, d) float32  corpus rows (cos rows pre-normalized)
    ``_adj0``    (cap, 2M) int32   level-0 adjacency, -1 beyond ``_deg0``
    ``_uadj[l]`` (cap_l, M) int32  level-(l+1) adjacency rows (slot-compact:
                                   only the ~n/M^(l+1) nodes present at that
                                   level own a row; ``_uslot[l]`` maps global
                                   id -> row, -1 when absent)

    Determinism contract: for a fixed config seed and insertion order, the
    built graph is bit-identical regardless of the wavefront ``chunk`` size
    and of how many process-pool workers build sibling partitions — and an
    ``add_batch(a); add_batch(b)`` sequence equals ``add_batch(a + b)``
    (level draws consume the generator stream element-wise).
    """

    def __init__(self, config: HNSWConfig, dim: int):
        self.config = config
        self.dim = dim
        self._n = 0
        self._cap = 0
        # adjacency rows carry slack beyond m_max (Vamana-style deferred
        # pruning): appends are plain writes until the row physically fills,
        # then one heuristic prune compacts it back to m_max.  freeze()
        # prunes any row still above m_max down to the frozen width.
        self._w0 = config.m_max0 + config.M
        self._wu = config.M + max(config.M // 2, 1)
        self._vstack = np.zeros((0, dim), dtype=np.float32)
        self._norms = np.zeros((0,), dtype=np.float32)
        self._levels = np.zeros((0,), dtype=np.int32)
        self._adj0 = np.zeros((0, self._w0), dtype=np.int32)
        self._deg0 = np.zeros((0,), dtype=np.int32)
        # upper levels (index ul = level - 1), slot-compact
        self._uslot: list[np.ndarray] = []  # (cap,) int32 global id -> row
        self._uadj: list[np.ndarray] = []   # (cap_l, M) int32 global ids
        self._udeg: list[np.ndarray] = []   # (cap_l,) int32
        self._ucount: list[int] = []        # rows in use per upper level
        self.entry: int = -1
        self.max_level: int = -1
        self._rng = np.random.default_rng(config.seed)
        self._frozen = None
        self._visited = np.zeros(0, dtype=np.int64)
        self._visit_gen = 0
        self.keys: Optional[np.ndarray] = None  # original (global) keys

    # ------------------------------------------------------------------
    # Storage growth (amortized doubling)
    # ------------------------------------------------------------------

    @property
    def size(self) -> int:
        return self._n

    def _ensure_capacity(self, n_total: int) -> None:
        if n_total <= self._cap:
            return
        cap = max(self._cap * 2, n_total, _MIN_CAP)
        n = self._n

        def grown(old, shape_tail, fill, dtype):
            new = np.full((cap, *shape_tail), fill, dtype=dtype)
            new[:n] = old[:n]
            return new

        self._vstack = grown(self._vstack, (self.dim,), 0.0, np.float32)
        self._norms = grown(self._norms, (), 0.0, np.float32)
        self._levels = grown(self._levels, (), 0, np.int32)
        self._adj0 = grown(self._adj0, (self._w0,), -1, np.int32)
        self._deg0 = grown(self._deg0, (), 0, np.int32)
        # visited stamps survive growth: new rows are 0 = never visited, and
        # the generation counter is never reset.  One sentinel slot rides at
        # index `cap`: -1 adjacency padding wraps onto it under
        # ``take(mode="wrap")`` and it is pre-stamped per search, so padding
        # is dropped by the same filter as visited nodes.
        visited = np.zeros(cap + 1, dtype=np.int64)
        visited[:n] = self._visited[:n]
        self._visited = visited
        self._uslot = [grown(s, (), -1, np.int32) for s in self._uslot]
        self._cap = cap

    def _register_upper(self, i: int, lvl: int) -> None:
        """Give node ``i`` an adjacency row at every level 1..lvl (creating
        levels that did not exist yet).  Slot order == insertion order."""
        wu = self._wu
        while len(self._uadj) < lvl:
            self._uslot.append(np.full(self._cap, -1, dtype=np.int32))
            self._uadj.append(
                np.full((_MIN_UPPER_CAP, wu), -1, dtype=np.int32)
            )
            self._udeg.append(np.zeros(_MIN_UPPER_CAP, dtype=np.int32))
            self._ucount.append(0)
        for ul in range(lvl):
            row = self._ucount[ul]
            if row == self._uadj[ul].shape[0]:
                cap_l = row * 2
                new_adj = np.full((cap_l, wu), -1, dtype=np.int32)
                new_adj[:row] = self._uadj[ul]
                self._uadj[ul] = new_adj
                new_deg = np.zeros(cap_l, dtype=np.int32)
                new_deg[:row] = self._udeg[ul]
                self._udeg[ul] = new_deg
            self._uslot[ul][i] = row
            self._ucount[ul] = row + 1

    # ------------------------------------------------------------------
    # Distance / adjacency primitives (build hot path)
    # ------------------------------------------------------------------

    def _dist(self, q: np.ndarray, ids: np.ndarray, q2: float) -> np.ndarray:
        """Distances from ``q`` (with precomputed ``q2 = <q, q>``) to rows
        ``ids``.  Lower is better; 'l2' returns true squared distances."""
        vecs = self._vstack[ids]
        if self.config.metric == "l2":
            return self._norms[ids] - 2.0 * (vecs @ q) + q2
        return -(vecs @ q)

    def _q2(self, q: np.ndarray) -> float:
        return float(q @ q) if self.config.metric == "l2" else 0.0

    # ------------------------------------------------------------------
    # Phase 1: vectorized wavefront greedy descent (spine levels)
    # ------------------------------------------------------------------

    def _descend(self, Q: np.ndarray, stops: np.ndarray, upper=None):
        """Greedy descent for a whole chunk in one batched walk.

        Lane ``c`` of ``Q`` walks levels ``max_level .. stops[c]+1``, moving
        to its best-improving neighbor until a local minimum, exactly like
        the serving path's upper-level loop (``_beam_search_lanes``).  Only
        nodes with level >= 1 ("spine" nodes) own upper-level adjacency and
        only spine insertions mutate it, so for a run of level-0 points this
        is a pure function of the frozen spine graph — the batched result is
        bit-identical to descending each point alone, whatever the chunk
        size.  Scores are rank-equivalent surrogates (l2 drops the constant
        ``<q, q>`` term); callers re-score entry points exactly.

        Returns ``(ep, ep_d)``: per-lane entry node and surrogate score.
        """
        C = Q.shape[0]
        ep = np.full(C, self.entry, dtype=np.int64)
        ve = self._vstack[self.entry]
        if self.config.metric == "l2":
            ep_d = self._norms[self.entry] - 2.0 * (Q @ ve)
        else:
            ep_d = -(Q @ ve)
        for level in range(self.max_level, 0, -1):
            act = np.flatnonzero(stops < level)
            if act.size == 0:
                continue
            ul = level - 1
            if upper is None:
                slot, adj = self._uslot[ul], self._uadj[ul]
            else:  # frozen upper adjacency: global-id indexed, no slots
                slot, adj = None, upper[ul]
            while act.size:
                rows = ep[act] if slot is None else slot[ep[act]]
                nbrs = adj[rows]  # (a, M) global ids, -1 padded
                safe = np.clip(nbrs, 0, None)
                dots = np.matmul(
                    self._vstack[safe], Q[act][:, :, None]
                )[:, :, 0]
                if self.config.metric == "l2":
                    dn = self._norms[safe] - 2.0 * dots
                else:
                    dn = -dots
                dn[nbrs < 0] = np.inf
                j = np.argmin(dn, axis=1)
                ar = np.arange(act.size)
                bd = dn[ar, j]
                better = bd < ep_d[act]
                if not better.any():
                    break
                moved = act[better]
                ep[moved] = nbrs[ar[better], j[better]]
                ep_d[moved] = bd[better]
                act = moved
        return ep, ep_d

    # ------------------------------------------------------------------
    # Algorithm 2 — beam search at one level (sequential, vectorized inner)
    # ------------------------------------------------------------------

    def _search_layer(self, q, entry_points, ef, level, adj0=None):
        """Best-first beam of width ``ef``.  Returns (dists, ids) ascending.

        Same W-set semantics as the classic heapq formulation, with two
        single-core throughput changes: per round, up to ``_EXPAND_BATCH``
        heap candidates are popped together (same (dist, id) pop order) and
        their joint neighborhood is visited-filtered + scored in ONE
        vectorized block, and once the beam is full only neighbors beating
        the current worst are pushed.
        """
        visited = self._visited
        self._visit_gen += 1
        gen = self._visit_gen
        q2 = self._q2(q)
        vstack = self._vstack
        norms = self._norms
        l2 = self.config.metric == "l2"
        heappush, heappop = heapq.heappush, heapq.heappop
        heapreplace = heapq.heapreplace
        if level == 0:
            adj, slot = (self._adj0 if adj0 is None else adj0), None
        else:
            ul = level - 1
            adj, slot = self._uadj[ul], self._uslot[ul]

        eps = np.asarray(entry_points, dtype=np.int64)
        if eps.size > 1:
            eps = np.unique(eps)
        if l2:
            d0 = norms[eps] - 2.0 * (vstack[eps] @ q) + q2
        else:
            d0 = -(vstack[eps] @ q)
        visited[eps] = gen
        visited[self._cap] = gen  # sentinel: -1 padding wraps onto it
        cand = list(zip(d0.tolist(), eps.tolist()))  # min-heap by dist
        heapq.heapify(cand)
        best = [(-d, e) for d, e in cand]  # max-heap by -dist (the W set)
        heapq.heapify(best)
        while len(best) > ef:
            heappop(best)
        full = len(best) >= ef
        d_worst = -best[0][0]
        batch = np.empty(_EXPAND_BATCH, dtype=np.int64)

        while cand:
            nb = 0
            while cand and nb < _EXPAND_BATCH:
                d_c = cand[0][0]
                if d_c > d_worst and full:
                    break
                batch[nb] = heappop(cand)[1]
                nb += 1
            if nb == 0:
                break
            rows = batch[:nb]
            nbrs = (adj[rows] if slot is None else adj[slot[rows]]).ravel()
            # -1 padding wraps to the pre-stamped sentinel slot, so one
            # filter drops both padding and already-visited nodes
            nbrs = nbrs[visited.take(nbrs, mode="wrap") != gen]
            if nbrs.size == 0:
                continue
            if nb > 1:  # batch rows can share neighbors: sorted dedup
                nbrs.sort()
                if nbrs[0] != nbrs[-1]:
                    keep = np.empty(nbrs.size, dtype=bool)
                    keep[0] = True
                    np.not_equal(nbrs[1:], nbrs[:-1], out=keep[1:])
                    nbrs = nbrs[keep]
                else:
                    nbrs = nbrs[:1]
            visited[nbrs] = gen
            vecs = np.take(vstack, nbrs, axis=0)
            if l2:
                dn = vecs @ q
                dn *= -2.0
                dn += np.take(norms, nbrs)
                dn += q2
            else:
                dn = vecs @ q
                dn *= -1.0
            if full:
                # only candidates beating the current worst can enter the
                # beam; the exact per-item check below still runs.
                keep = dn < d_worst
                nbrs = nbrs[keep]
                dn = dn[keep]
                if nbrs.size == 0:
                    continue
            if dn.size > 8:
                # process ascending: d_worst tightens fastest, and once one
                # neighbor misses the beam every later one must too — the
                # loop breaks instead of heap-churning through the tail.
                # (stable sort: ids are ascending after dedup, so ties are
                # deterministic.)
                o = np.argsort(dn, kind="stable")
                dn = dn[o]
                nbrs = nbrs[o]
                srt = True
            else:
                srt = False
            for d, u in zip(dn.tolist(), nbrs.tolist()):
                if not full:
                    heappush(cand, (d, u))
                    heappush(best, (-d, u))
                    if len(best) >= ef:
                        full = True
                        d_worst = -best[0][0]
                elif d < d_worst:
                    heappush(cand, (d, u))
                    heapreplace(best, (-d, u))
                    d_worst = -best[0][0]
                elif srt:
                    break
        out = sorted((-nd, i) for nd, i in best)
        return (
            np.asarray([d for d, _ in out], dtype=np.float64),
            np.asarray([i for _, i in out], dtype=np.int64),
        )

    # ------------------------------------------------------------------
    # Algorithm 4 — heuristic neighbor selection
    # ------------------------------------------------------------------

    def _select_neighbors(self, cand_dists, cand_ids, m):
        """Distance-diversity selection (Algorithm 4).

        One greedy pass over candidates sorted ascending, with the
        min-distance-to-selected vector materialized lazily in blocks: the
        pass usually fills its ``m`` slots within the first few dozen
        candidates, so pairwise distances are computed one examination
        window at a time (a (|selected|, block) rectangle each, plus a
        one-row refresh per in-block selection) instead of the full (c, c)
        matrix — and a window that runs dry continues into the next block
        carrying its selections, never restarting from scratch.  The
        acceptance sequence is identical to the textbook exhaustive pass.
        """
        cand_ids = np.asarray(cand_ids, dtype=np.int64)
        cand_dists = np.asarray(cand_dists)
        order = np.argsort(cand_dists, kind="stable")
        ids = cand_ids[order]
        c = ids.size
        if c <= 1:
            return ids[:m]
        dists = cand_dists[order]
        cfg = self.config
        l2 = cfg.metric == "l2"
        keep = cfg.keep_pruned
        V = self._vstack[ids]  # (c, d)
        norms = self._norms[ids] if l2 else None
        dl = dists.tolist()
        blk = max(4 * m, 64)
        selected: list[int] = []  # positions into `ids`
        pruned: list[int] = []
        lo = 0
        while lo < c and len(selected) < m:
            hi = min(lo + blk, c)
            Vb = V[lo:hi]
            if selected:
                G = V[selected] @ Vb.T  # (|selected|, hi - lo)
                if l2:
                    Db = (norms[selected][:, None] - 2.0 * G
                          + norms[lo:hi][None, :])
                else:
                    Db = -G
                mts = Db.min(axis=0)
            else:
                mts = np.full(hi - lo, np.inf)
            mtsl = mts.tolist()
            for i in range(lo, hi):
                if len(selected) >= m:
                    break
                j = i - lo
                if not selected or dl[i] < mtsl[j]:
                    selected.append(i)
                    if i + 1 < hi:
                        g = Vb[j + 1:] @ V[i]
                        if l2:
                            g *= -2.0
                            g += norms[i]
                            g += norms[i + 1: hi]
                        else:
                            np.negative(g, out=g)
                        np.minimum(mts[j + 1:], g, out=mts[j + 1:])
                        mtsl = mts.tolist()
                elif keep:
                    pruned.append(i)
            lo = hi
        if keep and len(selected) < m:
            selected.extend(pruned[: m - len(selected)])
        return ids[selected]

    # ------------------------------------------------------------------
    # Connect / prune (order-dependent, sequential within a chunk)
    # ------------------------------------------------------------------

    def _set_adjacency(self, i: int, level: int, sel: np.ndarray) -> None:
        if level == 0:
            self._adj0[i, : sel.size] = sel
            self._adj0[i, sel.size:] = -1
            self._deg0[i] = sel.size
            return
        ul = level - 1
        row = self._uslot[ul][i]
        self._uadj[ul][row, : sel.size] = sel
        self._uadj[ul][row, sel.size:] = -1
        self._udeg[ul][row] = sel.size

    def _add_reverse_edge(self, s: int, i: int, level: int) -> None:
        """Append ``i`` to s's adjacency; deferred heuristic prune.

        While the slack row has headroom the append is two scalar writes.
        Only when the row physically fills (m_max + slack entries) does the
        Algorithm-4 heuristic run, compacting back to m_max — amortizing
        the prune over ~slack appends instead of re-running it per edge on
        every saturated node (the dominant cost of the per-edge policy).
        """
        if level == 0:
            adj, deg, row, m_max = (
                self._adj0, self._deg0, s, self.config.m_max0
            )
        else:
            ul = level - 1
            row = self._uslot[ul][s]
            adj, deg, m_max = self._uadj[ul], self._udeg[ul], self.config.M
        d = deg[row]
        if d < adj.shape[1]:
            adj[row, d] = i
            deg[row] = d + 1
            return
        cand = np.empty(d + 1, dtype=np.int64)
        cand[:d] = adj[row, :d]
        cand[d] = i
        qv = self._vstack[s]
        dc = self._dist(qv, cand, float(self._norms[s]))
        sel = self._select_neighbors(dc, cand, m_max)
        adj[row, : sel.size] = sel
        adj[row, sel.size:] = -1
        deg[row] = sel.size

    def _candidates(self, q, dists, ids, level):
        """ef_construction beam results, optionally extended with the
        candidates' own neighbors (Algorithm 4's extendCandidates switch;
        np.unique order — deterministic)."""
        if not self.config.extend_candidates or ids.size == 0:
            return dists, ids
        if level == 0:
            rows = self._adj0[ids]
        else:
            ul = level - 1
            rows = self._uadj[ul][self._uslot[ul][ids]]
        ext = np.unique(rows[rows >= 0])
        ext = ext[~np.isin(ext, ids)]
        if ext.size == 0:
            return dists, ids
        d_ext = self._dist(q, ext, self._q2(q))
        return (
            np.concatenate([dists, d_ext.astype(dists.dtype)]),
            np.concatenate([ids, ext]),
        )

    def _connect(self, i: int, lvl: int, ep) -> None:
        """Phase 2 for node ``i``: ef_construction search + heuristic select
        + reverse edges with prune, at levels min(max_level, lvl) .. 0."""
        cfg = self.config
        x = self._vstack[i]
        for level in range(min(self.max_level, lvl), -1, -1):
            dists, ids = self._search_layer(x, ep, cfg.ef_construction, level)
            cand_d, cand_i = self._candidates(x, dists, ids, level)
            sel = self._select_neighbors(cand_d, cand_i, cfg.M)
            self._set_adjacency(i, level, sel)
            for s in sel.tolist():
                self._add_reverse_edge(s, i, level)
            ep = ids

    # ------------------------------------------------------------------
    # Bulk insert (the wavefront build loop)
    # ------------------------------------------------------------------

    # lanns: dims[n<=180_000_000, d<=2048, C<=65536]
    def add_batch(  # lanns: hotpath
        self,
        vectors: np.ndarray,
        keys: Optional[np.ndarray] = None,
        *,
        chunk: int = DEFAULT_BUILD_CHUNK,
    ):
        """Bulk-insert ``vectors`` (HNSW build is order-dependent).

        Points are consumed in wavefront chunks: a maximal run of up to
        ``chunk`` consecutive level-0 points gets its phase-1 greedy descent
        in ONE vectorized batched walk (``_descend``) against the frozen
        spine, then the order-dependent connect/prune phase runs
        sequentially point-by-point.  Spine points (level >= 1, a ~1/M
        fraction) are inserted fully sequentially since they mutate the
        upper levels the descent reads.  The built graph is bit-identical
        for any ``chunk`` >= 1 and across ``add_batch`` call splits.
        """
        cfg = self.config
        if chunk < 1:
            raise ValueError(f"chunk={chunk} — expected >= 1")
        vectors = np.asarray(vectors, dtype=np.float32)
        if cfg.metric == "cos":
            vectors = _normalize_rows(vectors)
        n_new = vectors.shape[0]
        if keys is not None:
            keys = np.asarray(keys)
            if keys.shape[0] != n_new:
                raise ValueError(
                    f"keys length {keys.shape[0]} != vectors {n_new}"
                )
            self.keys = (
                keys if self.keys is None
                else np.concatenate([self.keys, keys])
            )
        if n_new == 0:
            return self
        base = self._n
        self._ensure_capacity(base + n_new)
        self._n = base + n_new
        self._vstack[base: base + n_new] = vectors
        self._norms[base: base + n_new] = np.einsum(
            "nd,nd->n", vectors, vectors
        )
        # batched level draws: element-wise identical to per-point .random()
        # draws from the same generator state, so call-split boundaries do
        # not move the level sequence.
        u = self._rng.random(n_new)
        lvls = np.minimum(
            (-np.log(np.maximum(u, 1e-12)) * cfg.m_l).astype(np.int64),
            cfg.max_level_cap,
        ).astype(np.int32)
        self._levels[base: base + n_new] = lvls

        r = 0
        while r < n_new:
            i = base + r
            lvl = int(lvls[r])
            if self.entry < 0:
                # very first point: becomes the entry at its drawn level
                self._register_upper(i, lvl)
                self.entry = i
                self.max_level = lvl
                r += 1
                continue
            if lvl == 0:
                r_end = r + 1
                while (
                    r_end < n_new
                    and lvls[r_end] == 0
                    and r_end - r < chunk
                ):
                    r_end += 1
                eps, _ = self._descend(
                    vectors[r:r_end],
                    np.zeros(r_end - r, dtype=np.int32),
                )
                for j, ep in enumerate(eps.tolist()):
                    self._connect(base + r + j, 0, [ep])
                r = r_end
            else:
                self._register_upper(i, lvl)
                eps, _ = self._descend(
                    vectors[r: r + 1], np.asarray([lvl], dtype=np.int32)
                )
                self._connect(i, lvl, [int(eps[0])])
                if lvl > self.max_level:
                    self.max_level = lvl
                    self.entry = i
                r += 1
        self._frozen = None
        return self

    # ------------------------------------------------------------------
    # Freeze to arrays
    # ------------------------------------------------------------------

    def freeze(self) -> "FrozenHNSW":
        """Snapshot to frozen arrays; slack rows still above m_max get one
        final heuristic prune down to the frozen width.  Operates on copies
        — build state is untouched, so interleaving freeze() with further
        ``add_batch`` calls cannot perturb the graph."""
        if self._frozen is not None:
            return self._frozen
        cfg = self.config
        n = self._n
        m0 = cfg.m_max0
        M = cfg.M
        deg0 = self._deg0[:n]
        adj0 = np.full((n, m0), -1, dtype=np.int32)
        ok = np.flatnonzero(deg0 <= m0)
        adj0[ok] = self._adj0[ok, :m0]
        for s in np.flatnonzero(deg0 > m0).tolist():
            cand = self._adj0[s, : deg0[s]].astype(np.int64)
            dc = self._dist(self._vstack[s], cand, float(self._norms[s]))
            sel = self._select_neighbors(dc, cand, m0)
            adj0[s, : sel.size] = sel
        n_upper = len(self._uadj)
        upper_adj = np.full((n_upper, n, M), -1, dtype=np.int32)
        for ul in range(n_upper):
            slot = self._uslot[ul][:n]
            nodes = np.flatnonzero(slot >= 0)
            rows = slot[nodes]
            deg = self._udeg[ul][rows]
            src = self._uadj[ul][rows]
            sub = np.full((nodes.size, M), -1, dtype=np.int32)
            okm = deg <= M
            sub[okm] = src[okm, :M]
            for j in np.flatnonzero(~okm).tolist():
                s = int(nodes[j])
                cand = src[j, : deg[j]].astype(np.int64)
                dc = self._dist(self._vstack[s], cand, float(self._norms[s]))
                sel = self._select_neighbors(dc, cand, M)
                sub[j, : sel.size] = sel
            upper_adj[ul, nodes] = sub
        self._frozen = FrozenHNSW(
            config=cfg,
            vectors=self._vstack[:n].copy(),
            levels=self._levels[:n].copy(),
            adj0=adj0,
            upper_adj=upper_adj,
            entry=self.entry,
            keys=self.keys,
        )
        return self._frozen

    # convenience: numpy reference search (exact same algorithm as build
    # beam), over the FROZEN graph — the serving artifact — so its results
    # are comparable with the jax path bit-for-bit modulo tie-breaks.
    def search_np(self, queries: np.ndarray, k: int, ef: Optional[int] = None):
        cfg = self.config
        ef = max(ef or cfg.ef_search, k)
        queries = np.asarray(queries, dtype=np.float32)
        if cfg.metric == "cos":
            queries = _normalize_rows(queries)
        B = len(queries)
        out_d = np.full((B, k), _INF, dtype=np.float32)
        out_i = np.full((B, k), -1, dtype=np.int64)
        if self._n == 0 or B == 0:
            return out_d, out_i
        frozen = self.freeze()
        eps, _ = self._descend(
            queries, np.zeros(B, dtype=np.int32), upper=frozen.upper_adj
        )
        for qi, q in enumerate(queries):
            dists, ids = self._search_layer(
                q, [int(eps[qi])], ef, 0, adj0=frozen.adj0
            )
            m = min(k, len(ids))
            out_d[qi, :m] = dists[:m]
            out_i[qi, :m] = ids[:m]
        if self.keys is not None:
            valid = out_i >= 0
            out_i = np.where(valid, self.keys[np.clip(out_i, 0, None)], -1)
        return out_d, out_i


class HNSWIndexLegacy:
    """The pre-wavefront sequential builder (python dict adjacency + heapq).

    Kept as the before/after baseline for ``bench_build_query_scaling`` and
    as the recall oracle the bulk builder is accepted against (recall@100
    within 0.01 on the bench corpus).  One adjacency representation during
    build — ``_adj[level]`` is a dict node -> neighbor list — normalized to
    flat arrays once, at ``freeze``.
    """

    def __init__(self, config: HNSWConfig, dim: int):
        self.config = config
        self.dim = dim
        self._vecs: list[np.ndarray] = []
        self._levels: list[int] = []
        self._adj: list[dict[int, list[int]]] = []  # [level][node] -> nbrs
        self.entry: int = -1
        self.max_level: int = -1
        self._rng = np.random.default_rng(config.seed)
        self._frozen = None
        self._vstack: Optional[np.ndarray] = None
        self._visited = np.zeros(0, dtype=np.int64)
        self._visit_gen = 0
        self.keys: Optional[np.ndarray] = None

    @property
    def size(self) -> int:
        return len(self._vecs)

    def _dist(self, q, ids):
        ids = np.asarray(ids)
        vecs = self._vstack[ids]
        if self.config.metric == "l2":
            return self._norms[ids] - 2.0 * (vecs @ q) + q @ q
        return -(vecs @ q)

    def _draw_level(self) -> int:
        u = self._rng.random()
        lvl = int(-math.log(max(u, 1e-12)) * self.config.m_l)
        return min(lvl, self.config.max_level_cap)

    def _search_layer(self, q, entry_points, ef, level):
        visited = self._visited
        self._visit_gen += 1
        gen = self._visit_gen
        adj = self._adj[level]

        eps = list(dict.fromkeys(entry_points))
        d0 = self._dist(q, eps)
        cand: list[tuple[float, int]] = []
        best: list[tuple[float, int]] = []
        for d, e in zip(d0, eps):
            visited[e] = gen
            heapq.heappush(cand, (float(d), e))
            heapq.heappush(best, (-float(d), e))
        while len(best) > ef:
            heapq.heappop(best)
        while cand:
            d_c, c = heapq.heappop(cand)
            d_worst = -best[0][0]
            if d_c > d_worst and len(best) >= ef:
                break
            nbrs = [u for u in adj[c] if visited[u] != gen]
            if not nbrs:
                continue
            for u in nbrs:
                visited[u] = gen
            dn = self._dist(q, nbrs)
            for d, u in zip(dn, nbrs):
                d = float(d)
                if len(best) < ef or d < -best[0][0]:
                    heapq.heappush(cand, (d, u))
                    heapq.heappush(best, (-d, u))
                    if len(best) > ef:
                        heapq.heappop(best)
        out = sorted((-nd, i) for nd, i in best)
        return [d for d, _ in out], [i for _, i in out]

    def _select_neighbors(self, cand_dists, cand_ids, m):
        cfg = self.config
        cand_ids = np.asarray(cand_ids)
        cand_dists = np.asarray(cand_dists)
        order = np.argsort(cand_dists, kind="stable")
        ids = cand_ids[order]
        dists = cand_dists[order]
        c = len(ids)
        if c <= 1:
            return list(ids[:m])
        V = self._vstack[ids]
        if cfg.metric == "l2":
            norms = np.einsum("cd,cd->c", V, V)
            D = norms[:, None] - 2.0 * (V @ V.T) + norms[None, :]
        else:
            D = -(V @ V.T)
        selected: list[int] = []
        pruned: list[int] = []
        for i in range(c):
            if len(selected) >= m:
                break
            if not selected or dists[i] < D[i, selected].min():
                selected.append(i)
            elif cfg.keep_pruned:
                pruned.append(i)
        if cfg.keep_pruned and len(selected) < m:
            selected.extend(pruned[: m - len(selected)])
        return [int(ids[i]) for i in selected]

    def _prune_node(self, node, level, m_max):
        adj = self._adj[level][node]
        if len(adj) <= m_max:
            return
        q = self._vecs[node]
        d = self._dist(q, adj)
        self._adj[level][node] = self._select_neighbors(
            list(d), list(adj), m_max
        )

    def add_batch(self, vectors, keys=None):
        cfg = self.config
        vectors = np.asarray(vectors, dtype=np.float32)
        if cfg.metric == "cos":
            vectors = _normalize_rows(vectors)
        n_new = vectors.shape[0]
        n_total = self.size + n_new
        self._visited = np.zeros(n_total, dtype=np.int64)
        self._visit_gen = 0
        if self.size:
            self._vstack = np.concatenate([np.stack(self._vecs), vectors])
        else:
            self._vstack = vectors
        self._norms = np.einsum("nd,nd->n", self._vstack, self._vstack)

        for r in range(n_new):
            x = vectors[r]
            i = self.size
            self._vecs.append(x)
            lvl = self._draw_level()
            self._levels.append(lvl)
            while len(self._adj) <= lvl:
                self._adj.append({})
            for level in range(lvl + 1):
                self._adj[level][i] = []
            if self.entry < 0:
                self.entry = i
                self.max_level = lvl
                continue
            ep = [self.entry]
            for level in range(self.max_level, lvl, -1):
                _, ids = self._search_layer(x, ep, 1, level)
                ep = ids[:1]
            for level in range(min(self.max_level, lvl), -1, -1):
                m_max = cfg.m_max0 if level == 0 else cfg.M
                dists, ids = self._search_layer(
                    x, ep, cfg.ef_construction, level
                )
                sel = self._select_neighbors(dists, ids, cfg.M)
                self._adj[level][i] = list(sel)
                for s in sel:
                    self._adj[level][s].append(i)
                    self._prune_node(s, level, m_max)
                ep = ids
            if lvl > self.max_level:
                self.max_level = lvl
                self.entry = i
        if keys is not None:
            keys = np.asarray(keys)
            self.keys = (
                keys if self.keys is None
                else np.concatenate([self.keys, keys])
            )
        self._frozen = None
        return self

    def freeze(self) -> "FrozenHNSW":
        if self._frozen is not None:
            return self._frozen
        cfg = self.config
        n = self.size
        adj0 = np.full((n, cfg.m_max0), -1, dtype=np.int32)
        for i, nbrs in sorted(self._adj[0].items()):
            k = min(len(nbrs), cfg.m_max0)
            adj0[i, :k] = nbrs[:k]
        n_upper = max(len(self._adj) - 1, 0)
        upper_adj = np.full((n_upper, n, cfg.M), -1, dtype=np.int32)
        for level in range(1, len(self._adj)):
            for i, nbrs in sorted(self._adj[level].items()):
                nbrs = nbrs[: cfg.M]
                upper_adj[level - 1, i, : len(nbrs)] = nbrs
        self._frozen = FrozenHNSW(
            config=cfg,
            vectors=np.stack(self._vecs).astype(np.float32),
            levels=np.asarray(self._levels, dtype=np.int32),
            adj0=adj0,
            upper_adj=upper_adj,
            entry=self.entry,
            keys=self.keys,
        )
        return self._frozen


def stack_upper_adj(
    level_nodes: list, level_adj: list, n: int, M: int
) -> np.ndarray:
    """Convert the legacy ragged (level_nodes, level_adj) lists to the
    stacked (L, n, M) global-id adjacency (used when loading old artifacts)."""
    L = len(level_adj)
    upper = np.full((L, n, M), -1, dtype=np.int32)
    for l in range(L):
        ids = np.asarray(level_nodes[l], dtype=np.int64)
        a = np.asarray(level_adj[l], dtype=np.int32)
        m = min(a.shape[1], M) if a.size else 0
        if len(ids):
            upper[l, ids, :m] = a[:, :m]
    return upper


@dataclasses.dataclass
class FrozenHNSW:
    """Immutable array-form HNSW, ready for jit search / serialization."""

    config: HNSWConfig
    vectors: np.ndarray
    levels: np.ndarray
    adj0: np.ndarray
    upper_adj: np.ndarray  # (L, n, M) global-id adjacency, -1 padded
    entry: int
    keys: Optional[np.ndarray] = None

    def __post_init__(self):
        self._device_cache: dict = {}

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    @property
    def num_upper_levels(self) -> int:
        return self.upper_adj.shape[0]

    def device_arrays(self, n_pad: Optional[int] = None,
                      l_pad: Optional[int] = None, *, cached: bool = True):
        """The pytree consumed by ``beam_search`` (device-resident state).

        ``n_pad``/``l_pad`` pad the corpus rows / upper-level count to shared
        bucket sizes so beam_search traces are reused across partitions
        (padding rows are -1 adjacency = unreachable, zero vectors = never
        scored).  The pytree is built and uploaded ONCE per (n_pad, l_pad)
        bucket and cached on the index — serving must never re-ship the graph
        host->device per call.
        """
        n = self.size
        n_pad = n if n_pad is None else n_pad
        l_pad = self.num_upper_levels if l_pad is None else l_pad
        if n_pad < n or l_pad < self.num_upper_levels:
            raise ValueError(
                f"pad ({n_pad}, {l_pad}) smaller than index "
                f"({n}, {self.num_upper_levels})"
            )
        key = (n_pad, l_pad)
        if cached and key in self._device_cache:
            return self._device_cache[key]
        from repro.common.utils import pad_axis_to, pad_to

        vecs = pad_to(self.vectors, n_pad)
        adj0 = pad_to(self.adj0, n_pad, fill=-1)
        upper = pad_axis_to(self.upper_adj, 1, n_pad, fill=-1)
        upper = pad_to(upper, l_pad, fill=-1)
        arrs = {
            "vectors": jnp.asarray(vecs),
            "adj0": jnp.asarray(adj0),
            "upper_adj": jnp.asarray(upper),
            "entry": jnp.asarray(self.entry, dtype=jnp.int32),
        }
        if cached:
            self._device_cache[key] = arrs
        return arrs

    # lanns: dims[B<=4096, k<=200, n<=33_554_432]
    def search(  # lanns: hotpath
        self,
        queries,
        k: int,
        ef: Optional[int] = None,
        max_iters: int = 0,
        *,
        n_pad: Optional[int] = None,
        l_pad: Optional[int] = None,
        cached: bool = True,
        pad_queries: bool = True,
    ):
        """Batched jit beam search. Returns (dists (B,k), ids (B,k)).

        pad_queries=True pads the batch to its quarter-pow2 bucket (see
        ``next_pow2_quarter``: <= 25% padding, ~4 buckets per octave) so
        routed subsets of every size reuse a bounded set of traces.
        cached=False rebuilds the device pytree per call (the
        pre-device-resident behaviour; kept for before/after benchmarking).
        """
        cfg = self.config
        ef = max(ef or cfg.ef_search, k)
        if max_iters <= 0:
            max_iters = ef + 2 * cfg.M
        q = np.asarray(queries, dtype=np.float32)
        B = q.shape[0]
        if B == 0:
            return (np.full((0, k), _INF, np.float32),
                    np.full((0, k), -1, np.int64))
        if cfg.metric == "cos":
            q = q / np.maximum(
                np.linalg.norm(q, axis=-1, keepdims=True), 1e-12
            )
        valid = None
        if pad_queries:
            from repro.common.utils import next_pow2_quarter, pad_to

            B_pad = next_pow2_quarter(B)
            if B_pad != B:
                q = pad_to(q, B_pad)
                valid = jnp.asarray(np.arange(B_pad) < B)
        arrs = self.device_arrays(n_pad, l_pad, cached=cached)
        d, i = beam_search(  # lanns: noqa[LANNS033] -- k ranges over the finite per-request knob set (<= 200), not the corpus; bounded trace cardinality by the knob_groups contract
            arrs,
            jnp.asarray(q),
            valid,
            k=k,
            ef=ef,
            max_iters=max_iters,
            metric="l2" if cfg.metric == "l2" else "ip",
        )
        d, i = np.asarray(d)[:B], np.asarray(i)[:B]  # lanns: noqa[LANNS003] -- the single designed host sync of the beam batch
        if self.keys is not None:
            valid = i >= 0
            i = np.where(valid, self.keys[np.clip(i, 0, None)], -1)
        return d, i


# ---------------------------------------------------------------------------
# JAX search (serving hot path)
# ---------------------------------------------------------------------------


def _distance_rows(metric, q, x):
    """q (d,), x (m, d) -> (m,). Lower is better."""
    if metric == "l2":
        # ||q-x||^2 = ||x||^2 - 2<q,x> + ||q||^2 ; the ||q||^2 term is a
        # per-query constant and irrelevant for ranking but we keep it so the
        # returned distances are true squared distances (tests rely on it).
        return jnp.sum((x - q[None, :]) ** 2, axis=-1)
    return -(x @ q)


def _make_row_dist(arrs, metric):
    """Per-lane distance closure: (q, rows) -> (m,) scores, lower is better.

    fp32 mode (no ``norms2`` leaf in ``arrs``): gather fp32 rows, exact
    ``_distance_rows`` — the pre-existing path, op-for-op.

    Quantized mode (``arrs['norms2']`` present): ``vectors`` holds int8
    CODES and the caller pre-folds the partition's per-dim scales into each
    lane's query (``q_lane = q * scales[partition]``), so one fp32 cast-gemm
    per gather gives ``<q, x_hat>`` — the dot against the dequantized row —
    with no per-row scale gather.  'l2' scores are then
    ``||x_hat||^2 - 2<q, x_hat>``: the true squared distance to the
    dequantized point MINUS the per-query ||q||^2 constant, which cannot
    change any within-lane ordering (the beam only ever compares distances
    of one lane); the exact re-rank stage replaces these scores anyway.
    Presence of the extra pytree leaf changes the jit cache key, so fp32
    traces are never polluted.
    """
    vectors = arrs["vectors"]
    norms2 = arrs.get("norms2")
    if norms2 is None:
        return lambda q, rows: _distance_rows(metric, q, vectors[rows])

    def dist(q, rows):
        dots = vectors[rows].astype(jnp.float32) @ q
        if metric == "l2":
            return norms2[rows] - 2.0 * dots
        return -dots

    return dist


def _beam_search_lanes(arrs, queries, entry_rows, offsets, valid, *,
                       k, ef, max_iters, metric):
    """The beam-search core, in flat row space.

    Upper levels: greedy descent (while_loop) over the stacked (L, n, M)
    row-indexed adjacency — a padding level (all -1 rows) is a no-op walk, so
    partitions with fewer levels share the trace of the deepest one.  Level 0:
    best-first beam of width ``ef`` kept as dense arrays; each iteration
    expands the best unexpanded entry.  All ops are fixed-shape so the whole
    thing jits, vmaps over lanes, and shard_maps.  Expanded-set semantics: a
    node evicted from the beam may be re-inserted and re-expanded later; this
    wastes a little compute but never hurts correctness (matches the
    `visited`-free formulations of array HNSW).

    Each lane walks rows [off, off + n_partition) of the flat arrays:
    adjacency entries are partition-local, so every gathered neighbor id is
    shifted by the lane's ``off``.  A single partition is the off == 0
    special case.  An invalid lane (padding) seeds the walk with a -inf
    entry distance and an empty beam, so both loops exit immediately.

    ``arrs`` may carry a quantized corpus (int8 codes + ``norms2``; see
    ``_make_row_dist``) — the walk itself is precision-agnostic.
    """
    adj0 = arrs["adj0"]
    upper_adj = arrs["upper_adj"]
    num_upper_levels = upper_adj.shape[0]
    row_dist = _make_row_dist(arrs, metric)

    def one_lane(q, ep, off, v):
        def to_rows(nbrs):
            return jnp.where(nbrs >= 0, nbrs + off, -1)

        # ---- upper levels: greedy walk to a local minimum per level
        ep_d = row_dist(q, jnp.clip(ep, 0)[None])[0]
        ep_d = jnp.where(v, ep_d, -jnp.inf)
        ep = jnp.where(v, ep, -1)
        for l in range(num_upper_levels - 1, -1, -1):
            adj = upper_adj[l]

            def body(state):
                ep, ep_d, _ = state
                nbrs = to_rows(adj[jnp.clip(ep, 0)])
                valid_n = nbrs >= 0
                nd = row_dist(q, jnp.clip(nbrs, 0))
                nd = jnp.where(valid_n, nd, jnp.inf)
                j = jnp.argmin(nd)
                better = nd[j] < ep_d
                return (
                    jnp.where(better, nbrs[j], ep),
                    jnp.where(better, nd[j], ep_d),
                    better,
                )

            def cond(state):
                return state[2]

            ep, ep_d, _ = jax.lax.while_loop(cond, body, (ep, ep_d, jnp.bool_(True)))

        # ---- level 0 beam
        m0 = adj0.shape[1]
        beam_ids = jnp.full((ef,), -1, dtype=jnp.int32).at[0].set(ep)
        beam_d = jnp.full((ef,), jnp.inf, dtype=jnp.float32).at[0].set(ep_d)
        beam_exp = jnp.zeros((ef,), dtype=jnp.bool_)

        def cond(state):
            beam_ids, beam_d, beam_exp, it = state
            frontier = (~beam_exp) & (beam_ids >= 0)
            return jnp.any(frontier) & (it < max_iters)

        def body(state):
            beam_ids, beam_d, beam_exp, it = state
            pick_d = jnp.where((~beam_exp) & (beam_ids >= 0), beam_d, jnp.inf)
            b = jnp.argmin(pick_d)
            beam_exp = beam_exp.at[b].set(True)
            node = beam_ids[b]
            nbrs = to_rows(adj0[jnp.clip(node, 0)])
            valid_n = nbrs >= 0
            # dedup against current beam (m0 x ef comparison matrix)
            dup = jnp.any(nbrs[:, None] == beam_ids[None, :], axis=1)
            valid_n = valid_n & (~dup)
            nd = row_dist(q, jnp.clip(nbrs, 0))
            nd = jnp.where(valid_n, nd, jnp.inf)
            # merge (ef + m0) candidates, keep best ef
            all_ids = jnp.concatenate([beam_ids, jnp.where(valid_n, nbrs, -1)])
            all_d = jnp.concatenate([beam_d, nd])
            all_exp = jnp.concatenate([beam_exp, jnp.zeros((m0,), jnp.bool_)])
            neg_top, idx = jax.lax.top_k(-all_d, ef)
            return all_ids[idx], -neg_top, all_exp[idx], it + 1

        beam_ids, beam_d, beam_exp, _ = jax.lax.while_loop(
            cond, body, (beam_ids, beam_d, beam_exp, jnp.int32(0))
        )
        neg_top, idx = jax.lax.top_k(-beam_d, k)
        return -neg_top, beam_ids[idx]

    return jax.vmap(one_lane)(queries, entry_rows, offsets, valid)


def _beam_search_impl(arrs, queries, valid=None, *, k, ef, max_iters, metric):
    """Single-partition batched search: the zero-offset case of the core."""
    B = queries.shape[0]
    if valid is None:
        valid = jnp.ones((B,), dtype=jnp.bool_)
    entry_rows = jnp.broadcast_to(
        jnp.asarray(arrs["entry"], jnp.int32), (B,)
    )
    offsets = jnp.zeros((B,), jnp.int32)
    return _beam_search_lanes(
        {k_: arrs[k_] for k_ in ("vectors", "adj0", "upper_adj")},
        queries, entry_rows, offsets, valid,
        k=k, ef=ef, max_iters=max_iters, metric=metric,
    )


@partial(jax.jit, static_argnames=("k", "ef", "max_iters", "metric"))
def beam_search(arrs, queries, valid=None, *, k, ef, max_iters, metric):
    """Jit entry point: one partition, queries (B, d) -> ((B, k), (B, k)).
    ``valid`` (B,) marks real rows of a padded batch; padding rows exit
    immediately instead of walking the graph."""
    return _beam_search_impl(
        arrs, queries, valid, k=k, ef=ef, max_iters=max_iters, metric=metric
    )


@partial(jax.jit, static_argnames=("k", "ef", "max_iters", "metric"))
def beam_search_flat(arrs, queries, entry_rows, offsets, valid, *,
                     k, ef, max_iters, metric):
    """Multi-partition search over FLATTENED partition arrays.

    ``arrs`` holds every partition's rows concatenated: vectors (P*n, d),
    adj0 (P*n, 2M), upper_adj (L, P*n, M); adjacency entries stay partition-
    LOCAL.  Each lane of ``queries`` (T, d) carries its partition via
    ``offsets`` (T,) — the partition's first row in the flat arrays — and
    starts at ``entry_rows`` (T,) (the partition entry point, already
    offset).  Gathered neighbor ids are shifted by the lane's offset, so the
    whole walk runs in global row space and one vmapped call serves an
    arbitrary mix of (partition, query) pairs.

    vs the dense (P, C) ``beam_search_stacked``: lane count is the NUMBER OF
    ROUTED PAIRS (padded to a bucket), not partitions x the most-loaded
    partition's count — under unbalanced routing the dense form wastes up to
    ~2x lanes, and under vmap every padded lane runs the full loop.  Returns
    (dists (T, k), rows (T, k)) with rows in global (flat) space; map them
    through a flat key table host-side.

    Quantized corpora: pass int8 codes as ``vectors`` plus a ``norms2``
    leaf and pre-fold each lane's per-partition scales into its query row
    (``_make_row_dist``); the extra leaf keys a separate jit trace, so the
    fp32 path is untouched.
    """
    return _beam_search_lanes(
        arrs, queries, entry_rows, offsets, valid,
        k=k, ef=ef, max_iters=max_iters, metric=metric,
    )


@partial(jax.jit, static_argnames=("k", "ef", "max_iters", "metric"))
def beam_search_stacked(arrs, queries, valid=None, *, k, ef, max_iters, metric):
    """Multi-partition search: every leaf of ``arrs`` carries a leading
    partition axis (vectors (P, n, d), adj0 (P, n, 2M), upper_adj
    (P, L, n, M), entry (P,)) and queries is (P, C, d) — one vmapped
    ``beam_search`` serves all (shard, segment) partitions in a single call,
    with no per-partition Python dispatch or host<->device sync.  ``valid``
    (P, C) marks real query slots; padding slots short-circuit.
    """
    if valid is None:
        valid = jnp.ones(queries.shape[:-1], dtype=jnp.bool_)
    return jax.vmap(
        lambda a, q, v: _beam_search_impl(
            a, q, v, k=k, ef=ef, max_iters=max_iters, metric=metric
        )
    )(arrs, queries, valid)

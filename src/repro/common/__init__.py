from repro.common.utils import (
    Timer,
    pad_to,
    pad_axis_to,
    round_up,
    splitmix64,
    stable_hash_u64,
    tree_bytes,
    tree_count,
)

__all__ = [
    "Timer",
    "pad_to",
    "pad_axis_to",
    "round_up",
    "splitmix64",
    "stable_hash_u64",
    "tree_bytes",
    "tree_count",
]

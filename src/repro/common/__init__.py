from repro.common.utils import (
    Timer,
    next_pow2,
    pad_to,
    pad_axis_to,
    round_up,
    splitmix64,
    stable_hash_u64,
    tree_bytes,
    tree_count,
)

__all__ = [
    "Timer",
    "next_pow2",
    "pad_to",
    "pad_axis_to",
    "round_up",
    "splitmix64",
    "stable_hash_u64",
    "tree_bytes",
    "tree_count",
]

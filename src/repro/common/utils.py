"""Shared utilities: hashing, padding, timing, pytree accounting.

Everything here is dependency-light (numpy + jax only) and deterministic.
"""

from __future__ import annotations

import time
from typing import Iterable

import jax
import numpy as np


# ---------------------------------------------------------------------------
# Hashing — splitmix64 is the canonical cheap 64-bit mixer; we use it for the
# LANNS level-1 hash sharding ("when a point is inserted, it is hashed to ONE
# particular shard using the key of the data point", §4.1).  It must be (a)
# deterministic across hosts, (b) well mixed so shards are balanced, which the
# paper relies on ("the data distribution in our shards is uniform", §5.1).
# ---------------------------------------------------------------------------

_SM64_C1 = np.uint64(0xBF58476D1CE4E5B9)
_SM64_C2 = np.uint64(0x94D049BB133111EB)
_SM64_GAMMA = np.uint64(0x9E3779B97F4A7C15)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer over uint64 arrays."""
    x = np.asarray(x, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = x + _SM64_GAMMA
        z = (z ^ (z >> np.uint64(30))) * _SM64_C1
        z = (z ^ (z >> np.uint64(27))) * _SM64_C2
        z = z ^ (z >> np.uint64(31))
    return z


def stable_hash_u64(keys: np.ndarray, salt: int = 0) -> np.ndarray:
    """Deterministic 64-bit hash of integer keys (any integer dtype)."""
    k = np.asarray(keys).astype(np.uint64, copy=False)
    return splitmix64(k ^ np.uint64(salt))


# ---------------------------------------------------------------------------
# Shape helpers
# ---------------------------------------------------------------------------


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (and >= 1)."""
    return 1 << max(n - 1, 1).bit_length() if n & (n - 1) else max(n, 1)


def next_pow2_quarter(n: int) -> int:
    """Smallest v >= n on the quarter-pow2 grid {4,5,6,7} * 2^e (plus the
    exact small values 1..4).

    Shape-bucketing compromise: pow2 buckets waste up to 2x padded work,
    exact shapes retrace per size; quarter steps bound padding waste at 25%
    while keeping the trace count logarithmic."""
    n = max(int(n), 1)
    if n <= 4:
        return n
    step = 1 << ((n - 1).bit_length() - 3)
    return -(-n // step) * step


def pad_to(a: np.ndarray, n: int, fill=0) -> np.ndarray:
    """Pad axis 0 of ``a`` up to length ``n`` with ``fill``."""
    if a.shape[0] == n:
        return a
    if a.shape[0] > n:
        raise ValueError(f"cannot pad {a.shape[0]} down to {n}")
    pad_width = [(0, n - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad_width, constant_values=fill)


def pad_axis_to(a: np.ndarray, axis: int, n: int, fill=0) -> np.ndarray:
    if a.shape[axis] == n:
        return a
    pad_width = [(0, 0)] * a.ndim
    pad_width[axis] = (0, n - a.shape[axis])
    return np.pad(a, pad_width, constant_values=fill)


# ---------------------------------------------------------------------------
# Timing / accounting
# ---------------------------------------------------------------------------


def jit_cache_size(fn) -> int:
    """Compiled-trace count of a jitted function, -1 if unavailable.

    ``_cache_size`` is a private jax API (stable across 0.4.x but
    undocumented); serving stats must degrade, not crash, if it goes away.
    """
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


class Timer:
    """Context-manager wall timer. ``with Timer() as t: ...; t.seconds``."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False


def tree_count(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def batched(it: Iterable, n: int):
    """Yield lists of up to n items."""
    buf = []
    for x in it:
        buf.append(x)
        if len(buf) == n:
            yield buf
            buf = []
    if buf:
        yield buf

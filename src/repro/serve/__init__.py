"""Online serving substrate: LANNS retrieval on the mesh, KV-cache decode,
micro-batching front ends (sync + threaded async), and arrival-process load
generation for p99-vs-load sweeps."""

from repro.serve.controller import SLOController
from repro.serve.engine import AnnFrontend, AnnRequest, AsyncAnnFrontend
from repro.serve.loadgen import (
    LoadResult,
    arrival_gaps,
    measure_saturation_qps,
    run_controller_ab,
    run_load_point,
    sweep_load,
)

__all__ = [
    "AnnFrontend",
    "AnnRequest",
    "AsyncAnnFrontend",
    "LoadResult",
    "SLOController",
    "arrival_gaps",
    "measure_saturation_qps",
    "run_controller_ab",
    "run_load_point",
    "sweep_load",
]

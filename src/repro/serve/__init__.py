"""Online serving substrate: LANNS retrieval on the mesh, KV-cache decode."""

"""Arrival-process load generation over the async ANN front end.

The paper's headline serving numbers (Table 8: ~2.5K QPS/node with few-ms
p99, degrading as offered load approaches saturation) are statements about
latency UNDER A LIVE ARRIVAL PROCESS, not about closed-loop batch
throughput.  This module supplies that arrival process:

* ``poisson`` — open loop, exponential inter-arrival gaps at ``rate_qps``
  (memoryless arrivals, the standard web-traffic model and what Table 8's
  offered-load axis means);
* ``fixed`` — open loop, deterministic ``1/rate_qps`` gaps (isolates
  queueing effects from arrival burstiness);
* ``mmpp`` — open loop, two-state ON/OFF Markov-modulated Poisson: Poisson
  arrivals at ``rate_qps / mmpp_on_frac`` during exponentially-distributed
  ON periods, silence during OFF periods, mean rate ``rate_qps``.  The
  standard bursty-traffic model: same offered load as ``poisson`` but
  arrivals clump, so queues build during bursts and the p99 gap vs the
  matching Poisson point is pure burstiness effect;
* ``closed`` — ``concurrency`` synchronous clients, each submitting its
  next query the moment the previous one completes.  Offered load is
  implicit; the achieved QPS at high concurrency IS the saturation
  throughput, which anchors the open-loop sweep's load axis.

Open-loop generation is the honest protocol for percentiles: arrivals keep
coming while the system is slow, so queueing delay lands in the measured
latencies instead of silently throttling the generator (the coordinated-
omission trap of closed-loop measurement).

Gap sequences are pure functions of ``(process, rate, n, seed)`` —
``arrival_gaps`` is reproducible across runs and machines (seeding asserted
in tests/test_async_frontend.py); only the service times vary with the
host.  Every completed request carries end-to-end timestamps from
``AsyncAnnFrontend``, so a ``LoadResult`` reports p50/p95/p99 latency,
achieved QPS, and the formed-batch histogram per offered-load point.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.serve.controller import SLOController
from repro.serve.engine import AsyncAnnFrontend

PROCESSES = ("poisson", "fixed", "mmpp", "closed")


def arrival_gaps(
    process: str,
    rate_qps: float,
    n: int,
    seed: int = 0,
    *,
    mmpp_on_frac: float = 0.4,
    mmpp_cycle_s: float = 0.2,
) -> np.ndarray:
    """(n,) inter-arrival gaps in seconds; deterministic in ``seed``.

    ``mmpp`` knobs (ignored for other processes): ``mmpp_on_frac`` is the
    long-run fraction of time the source is ON (arrivals run at
    ``rate_qps / mmpp_on_frac`` while ON, so the mean rate stays
    ``rate_qps``); ``mmpp_cycle_s`` is the mean ON + mean OFF sojourn
    (exponential holding times — ``on_frac=1`` degenerates to plain
    Poisson).  Like the other open-loop processes, the sequence is a pure
    function of its arguments.
    """
    if process not in ("poisson", "fixed", "mmpp"):
        raise ValueError(
            f"process={process!r} has no gap sequence — expected 'poisson', "
            "'fixed' or 'mmpp' ('closed' is driven by completions, not a "
            "clock)"
        )
    if rate_qps <= 0:
        raise ValueError(f"rate_qps={rate_qps} must be > 0")
    if process == "fixed":
        return np.full(n, 1.0 / rate_qps)
    rng = np.random.default_rng(seed)
    if process == "poisson":
        return rng.exponential(1.0 / rate_qps, n)
    # mmpp: alternate exponential ON/OFF sojourns; arrivals are a Poisson
    # stream at lam_on inside ON windows.  A draw that crosses the window
    # edge is discarded and redrawn in the next ON window — valid by the
    # memorylessness of the exponential, and it keeps the generator a
    # simple forward walk.
    if not 0.0 < mmpp_on_frac <= 1.0:
        raise ValueError(f"mmpp_on_frac={mmpp_on_frac} must be in (0, 1]")
    if mmpp_cycle_s <= 0:
        raise ValueError(f"mmpp_cycle_s={mmpp_cycle_s} must be > 0")
    lam_on = rate_qps / mmpp_on_frac
    mean_on = mmpp_on_frac * mmpp_cycle_s
    mean_off = (1.0 - mmpp_on_frac) * mmpp_cycle_s
    gaps = np.empty(n, np.float64)
    t = last = 0.0
    on_end = rng.exponential(mean_on)
    i = 0
    while i < n:
        g = rng.exponential(1.0 / lam_on)
        if t + g <= on_end:
            t += g
            gaps[i] = t - last
            last = t
            i += 1
        else:
            t = on_end
            if mean_off > 0:
                t += rng.exponential(mean_off)
            on_end = t + rng.exponential(mean_on)
    return gaps


@dataclasses.dataclass
class LoadResult:
    """One offered-load point: what the bench JSON and the sweep report."""

    process: str
    offered_qps: float  # nan for closed loop (load is implicit)
    concurrency: int  # 0 for open loop
    duration_s: float  # submission window (drain time excluded)
    elapsed_s: float  # window + drain — the QPS denominator
    submitted: int
    completed: int
    cancelled: int
    p50_ms: float
    p95_ms: float
    p99_ms: float
    mean_ms: float
    max_ms: float
    mean_queue_ms: float  # batching/queueing share of the latency
    achieved_qps: float
    mean_batch: float
    batch_hist: dict[int, int]
    mean_exec_ms: float = float("nan")  # execution share (latency - queue)
    # per-stage percentiles from telemetry spans — {} without telemetry;
    # {stage: {p50_ms, p95_ms, p99_ms, mean_ms, n}} with (see
    # repro.obs.spans.stage_breakdown).
    stage_breakdown: dict = dataclasses.field(default_factory=dict)
    # SLO accounting (populated when the point ran with slo_ms set):
    # attainment is the fraction of completed requests within slo_ms,
    # degraded counts requests the controller served with a reduced ef.
    slo_ms: float = float("nan")
    slo_attainment: float = float("nan")
    degraded: int = 0
    controller_on: bool = False
    # mean recall@topk vs a ground-truth id table (open-loop points with
    # gt_ids only; nan otherwise)
    mean_recall: float = float("nan")

    def row(self) -> dict:
        """Strict-JSON-ready dict: batch_hist keys stringified, non-finite
        floats (closed-loop offered_qps, empty-percentile NaNs) -> null."""

        def _clean(v):
            if isinstance(v, float) and not math.isfinite(v):
                return None
            if isinstance(v, dict):
                return {k: _clean(x) for k, x in v.items()}
            return v

        out = {
            k: _clean(v) for k, v in dataclasses.asdict(self).items()
        }
        out["batch_hist"] = {str(k): v for k, v in sorted(
            self.batch_hist.items()
        )}
        return out


def _summarize(
    fe: AsyncAnnFrontend,
    *,
    process: str,
    offered_qps: float,
    concurrency: int,
    duration_s: float,
    elapsed_s: float,
    telemetry=None,
    span_since: int = 0,
    slo_ms: Optional[float] = None,
    controller_on: bool = False,
    gt_ids: Optional[np.ndarray] = None,
    n_pool: int = 0,
) -> LoadResult:
    done = [r for r in fe.completed if r.done]
    lat = np.array([r.latency_s for r in done], np.float64)
    queue = np.array([r.queue_s for r in done], np.float64)
    has = lat.size > 0
    slo_attainment = float("nan")
    if slo_ms is not None and has:
        slo_attainment = float(np.mean(lat <= slo_ms / 1e3))
    mean_recall = float("nan")
    if gt_ids is not None and n_pool > 0 and done:
        # open-loop points submit sequentially from one thread, so uid ==
        # arrival index == query-pool index mod n_pool (the caller skips
        # gt for closed loop, where per-client interleaving breaks this).
        per_req = [
            np.intersect1d(r.ids, gt_ids[r.uid % n_pool, : len(r.ids)]).size
            / max(len(r.ids), 1)
            for r in done
        ]
        mean_recall = float(np.mean(per_req))
    pct = (
        np.percentile(lat, (50, 95, 99)) if has else np.full(3, np.nan)
    )
    breakdown: dict = {}
    if telemetry is not None:
        # only this load point's executor spans: the sink is shared across
        # points, so filter by the seq watermark taken before submission.
        from repro.obs.spans import stage_breakdown

        plan_events = telemetry.spans.events(kind="plan", since=span_since)
        breakdown = stage_breakdown(
            plan_events, extra={"queue": queue.tolist()}
        )
    return LoadResult(
        process=process,
        offered_qps=float(offered_qps),
        concurrency=concurrency,
        duration_s=float(duration_s),
        elapsed_s=float(elapsed_s),
        submitted=fe.stats["submitted"],
        completed=len(done),
        cancelled=fe.stats["submitted"] - len(done),
        p50_ms=1e3 * float(pct[0]),
        p95_ms=1e3 * float(pct[1]),
        p99_ms=1e3 * float(pct[2]),
        mean_ms=1e3 * float(lat.mean()) if has else float("nan"),
        max_ms=1e3 * float(lat.max()) if has else float("nan"),
        mean_queue_ms=1e3 * float(queue.mean()) if has else float("nan"),
        achieved_qps=len(done) / max(elapsed_s, 1e-12),
        mean_batch=fe.mean_batch_size,
        batch_hist=dict(fe.batch_hist),
        mean_exec_ms=(
            1e3 * float((lat - queue).mean()) if has else float("nan")
        ),
        stage_breakdown=breakdown,
        slo_ms=float("nan") if slo_ms is None else float(slo_ms),
        slo_attainment=slo_attainment,
        degraded=sum(1 for r in done if r.degraded),
        controller_on=controller_on,
        mean_recall=mean_recall,
    )


def run_load_point(
    index,
    queries: np.ndarray,
    *,
    process: str = "poisson",
    rate_qps: Optional[float] = None,
    concurrency: int = 8,
    duration_s: float = 1.0,
    seed: int = 0,
    topk: int = 100,
    max_batch: int = 64,
    max_wait_ms: float = 2.0,
    ef: Optional[int] = None,
    collect_stats: bool = False,
    knob_mix: Optional[Sequence[tuple]] = None,
    telemetry=None,
    controller=None,
    deadline_ms: Optional[float] = None,
    slo_ms: Optional[float] = None,
    gt_ids: Optional[np.ndarray] = None,
) -> LoadResult:
    """Drive one offered-load point end to end and summarize it.

    Builds a fresh ``AsyncAnnFrontend`` (clean stats), submits arrivals for
    ``duration_s`` seconds under the chosen process, then drains — so every
    submitted query's completion (including queueing built up past
    saturation) is measured.  Queries cycle through ``queries`` rows.

    ``knob_mix`` generates a MIXED workload: a sequence of per-request
    ``(topk, ef)`` overrides (entries may be None -> the frontend default)
    that arrivals cycle through deterministically — arrival j carries
    ``knob_mix[j % len(knob_mix)]``, so the workload is reproducible and
    every formed micro-batch exercises the executor's knob-group path.

    ``telemetry`` (a ``repro.obs.Telemetry``) instruments the point: it is
    attached to ``index`` for the duration (previous attachment restored on
    exit), wired into the frontend, and the result gains a per-stage
    ``stage_breakdown`` computed from the executor spans this point
    produced (isolated via the span-sink seq watermark, so one shared
    telemetry can serve a whole sweep).

    ``controller`` (a fresh ``SLOController``) closes the loop for this
    point: the frontend binds it, its retune thread runs for the
    submission window, and degrade stays active through the drain.
    ``deadline_ms`` stamps every submitted request with that latency
    budget; ``slo_ms`` adds SLO-attainment accounting to the result
    (independent knobs: a controller-off point typically sets both
    ``deadline_ms`` and ``slo_ms`` to measure the baseline).  ``gt_ids``
    (n_pool, >= topk) enables mean recall@topk accounting for open-loop
    points — under degrade, recall is the other half of the A/B verdict.
    """
    if process not in PROCESSES:
        raise ValueError(f"process={process!r} — expected one of {PROCESSES}")
    fe = AsyncAnnFrontend(
        index, topk=topk, max_batch=max_batch, max_wait_ms=max_wait_ms,
        ef=ef, collect_stats=collect_stats, telemetry=telemetry,
        controller=controller,
    )
    span_since = 0
    prev_telemetry = getattr(index, "telemetry", None)
    if telemetry is not None:
        span_since = telemetry.spans.next_seq
        index.attach_telemetry(telemetry)
    n_pool = len(queries)

    def _submit(j: int):
        if knob_mix:
            tk, efv = knob_mix[j % len(knob_mix)]
            return fe.submit(
                queries[j % n_pool], topk=tk, ef=efv, deadline_ms=deadline_ms
            )
        return fe.submit(queries[j % n_pool], deadline_ms=deadline_ms)

    fe.start()
    if controller is not None:
        controller.start()
    t0 = time.perf_counter()
    try:
        if process == "closed":
            stop_at = t0 + duration_s

            def client(ci: int):
                qi = ci
                while time.perf_counter() < stop_at:
                    req = _submit(qi)
                    qi += concurrency
                    req.wait()

            threads = [
                threading.Thread(target=client, args=(ci,), daemon=True)
                for ci in range(concurrency)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            if rate_qps is None:
                raise ValueError(f"process={process!r} requires rate_qps")
            concurrency = 0
            # pre-draw the schedule (reproducible); recycle if the window
            # overruns the draw (only when achieved arrivals exceed 1.5x
            # the expected count).
            n_gaps = max(16, math.ceil(1.5 * rate_qps * duration_s))
            gaps = arrival_gaps(process, rate_qps, n_gaps, seed)
            deadline = t0 + duration_s
            t_next = t0 + gaps[0]
            gi, qi = 1, 0
            while True:
                now = time.perf_counter()
                if now >= deadline:
                    break
                if now >= t_next:
                    _submit(qi)
                    qi += 1
                    t_next += gaps[gi % len(gaps)]
                    gi += 1
                else:
                    time.sleep(min(t_next - now, 2e-3))
    finally:
        try:
            if controller is not None:
                # retune thread off first; degrade (frontend-driven) still
                # covers the drain batches below
                controller.stop()
        finally:
            fe.stop(drain=True)
            if telemetry is not None:
                index.attach_telemetry(prev_telemetry)
    elapsed = time.perf_counter() - t0
    return _summarize(
        fe,
        process=process,
        offered_qps=float("nan") if process == "closed" else rate_qps,
        concurrency=concurrency,
        duration_s=duration_s,
        elapsed_s=elapsed,
        telemetry=telemetry,
        span_since=span_since,
        slo_ms=slo_ms,
        controller_on=controller is not None,
        gt_ids=None if process == "closed" else gt_ids,
        n_pool=n_pool,
    )


def measure_saturation_qps(
    index,
    queries: np.ndarray,
    *,
    duration_s: float = 1.0,
    concurrency: Optional[int] = None,
    **kw,
) -> LoadResult:
    """Closed-loop saturation point: anchors the open-loop sweep's axis.

    With enough synchronous clients to keep full micro-batches forming
    (default 2x max_batch), the achieved QPS is the node's capacity; open-
    loop points are then swept as fractions of it.
    """
    mb = kw.get("max_batch", 64)
    return run_load_point(
        index, queries, process="closed",
        concurrency=concurrency or 2 * mb, duration_s=duration_s, **kw,
    )


def sweep_load(
    index,
    queries: np.ndarray,
    *,
    load_fracs: Sequence[float] = (0.25, 0.5, 0.75, 0.9, 1.1),
    process: str = "poisson",
    duration_s: float = 1.0,
    saturation: Optional[LoadResult] = None,
    seed: int = 0,
    **kw,
) -> tuple[LoadResult, list[LoadResult]]:
    """Measure saturation, then sweep offered load as fractions of it.

    Returns ``(saturation_point, open_loop_points)`` — the raw material of
    the paper's Table 8 (p99 vs offered load, including one point past
    saturation where queueing delay dominates).
    """
    if saturation is None:
        saturation = measure_saturation_qps(
            index, queries, duration_s=duration_s, **kw
        )
    points = [
        run_load_point(
            index, queries, process=process,
            rate_qps=max(frac * saturation.achieved_qps, 1.0),
            duration_s=duration_s, seed=seed + pi, **kw,
        )
        for pi, frac in enumerate(load_fracs)
    ]
    return saturation, points


def run_controller_ab(
    index,
    queries: np.ndarray,
    *,
    rate_qps: float,
    slo_ms: float,
    ef_ladder: Sequence[int],
    process: str = "mmpp",
    duration_s: float = 1.0,
    seed: int = 0,
    gt_ids: Optional[np.ndarray] = None,
    controller_kw: Optional[dict] = None,
    **kw,
) -> tuple[LoadResult, LoadResult, SLOController]:
    """Paired controller-off / controller-on load points (the ROADMAP's
    acceptance experiment: an MMPP burst at 0.9x saturation, on beats off
    on p99 without a recall cliff).

    Both points run the SAME seeded arrival schedule, knobs, and
    per-request ``deadline_ms = slo_ms``, so the only difference is the
    bound controller (fresh per call — a controller binds one frontend).
    Returns ``(off, on, controller)``; ``controller.snapshot()`` has the
    decision counters behind the ``on`` point.
    """
    off = run_load_point(
        index, queries, process=process, rate_qps=rate_qps,
        duration_s=duration_s, seed=seed, deadline_ms=slo_ms, slo_ms=slo_ms,
        gt_ids=gt_ids, **kw,
    )
    ctrl = SLOController(
        slo_ms=slo_ms, ef_ladder=ef_ladder, **(controller_kw or {})
    )
    on = run_load_point(
        index, queries, process=process, rate_qps=rate_qps,
        duration_s=duration_s, seed=seed, deadline_ms=slo_ms, slo_ms=slo_ms,
        gt_ids=gt_ids, controller=ctrl, **kw,
    )
    return off, on, ctrl

"""Distributed LANNS serving on the TPU mesh (paper §7, TPU-native form).

Topology (DESIGN.md §4): the corpus is sharded along the ``model`` mesh axis —
one LANNS *shard* per model-slice — and the query batch is sharded along the
``data`` axis.  The paper's broker is realized as a collective: each shard
computes its (segment-routed, locally merged) perShardTopK candidates and the
shard merge is an ``all_gather`` over ``model`` followed by a local top-k.
perShardTopK (Eq. 5-6) directly multiplies down the all-gather payload.

Segment routing on-device is MoE-style capacity dispatch: the virtual-spill
tree router yields a (B, m) segment mask; each segment takes up to ``capacity``
queries (gathered, padded), scans its own contiguous row-block with the fused
distance+top-k kernel, and results scatter back per query.  A query spilled to
s segments appears in s dispatch slots and its copies merge in the combine
step — exactly the paper's "merge within the shard" level.

Two scan modes:
  * routed  — capacity-dispatched per-segment scan (the LANNS win: each query
              touches ~(1+2a)^depth/m of the shard).
  * full    — every query scans the whole shard (brute-force baseline and the
              ground-truth path of §5.4).

Multi-pod: with mesh (pod, data, model), the default treats pods as index
replicas (queries sharded over pod x data; zero cross-pod collectives); set
``corpus_axes=("pod", "model")`` to instead shard the corpus over 2*16 shards
and merge with a two-stage hierarchical gather.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.utils import round_up
from repro.core.lanns import LannsConfig
from repro.core.merge import per_shard_topk
from repro.core.sharding import TwoLevelPartitioner


# ---------------------------------------------------------------------------
# On-device tree router (the jit twin of TreeSegmenter._route)
# ---------------------------------------------------------------------------


def route_queries_tree(tree: dict, q: jnp.ndarray, spill: bool = True) -> jnp.ndarray:
    """(B, d) queries -> (B, m) bool segment mask, fully vectorized.

    tree: dict of stacked heap-order arrays {hyperplanes (m-1, d), split, lo,
    hi} and static int ``depth``.  spill=True routes into the [lo, hi] band
    both ways (virtual spill / Figure 3); spill=False is the median split
    (used for point insertion parity tests).
    """
    depth = int(tree["depth"])
    H = tree["hyperplanes"]
    proj = q @ H.T  # (B, n_internal)
    B = q.shape[0]
    mask = jnp.ones((B, 1), dtype=bool)
    for lvl in range(depth):
        nodes = jnp.arange(2**lvl) + (2**lvl - 1)
        p = proj[:, nodes]  # (B, 2^lvl)
        if spill:
            gl = p <= tree["hi"][nodes][None, :]
            gr = p >= tree["lo"][nodes][None, :]
        else:
            gl = p < tree["split"][nodes][None, :]
            gr = ~gl
        mask = jnp.stack([mask & gl, mask & gr], axis=-1).reshape(B, 2 ** (lvl + 1))
    return mask


# ---------------------------------------------------------------------------
# Device-resident index
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DeviceIndex:
    """Stacked per-(shard, segment) corpus blocks, ready for the mesh.

    corpus  (S, m, N_seg, d) f32/bf16/int8 — padded row blocks (zeros invalid)
    ids     (S, m, N_seg)    i32 — global keys, -1 padding
    norms   (S, m, N_seg)    f32 — BUILD-TIME row norms (serving never
                                    re-derives them; §Perf v8)
    scale   (d,) f32 | None      — int8 per-dimension dequant scale (SQ8)
    tree    dict | None          — shared segmenter arrays (replicated)
    """

    corpus: np.ndarray
    ids: np.ndarray
    norms: np.ndarray
    tree: Optional[dict]
    config: LannsConfig
    scale: Optional[np.ndarray] = None

    @property
    def num_shards(self):
        return self.corpus.shape[0]

    @property
    def num_segments(self):
        return self.corpus.shape[1]


def build_device_index(
    data: np.ndarray,
    config: LannsConfig,
    keys: Optional[np.ndarray] = None,
    *,
    pad_multiple: int = 8,
    corpus_dtype: str = "float32",  # 'float32' | 'bfloat16' | 'int8'
) -> DeviceIndex:
    """Two-level partition the corpus and pack it into stacked device blocks.

    Physical spill duplicates rows into both children (paper Table 7's
    ~10-30% memory overhead shows up directly in N_seg).

    corpus_dtype='int8' applies symmetric per-dimension scalar quantization
    (FAISS SQ8 equivalent): 4x HBM saving over f32; norms are computed from
    the ORIGINAL f32 rows so the quantization error only perturbs the cross
    term of the distance.
    """
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    if keys is None:
        keys = np.arange(n, dtype=np.int64)
    part = TwoLevelPartitioner(config.num_shards, config.segmenter_config())
    part.fit(data)
    assignment = part.assign(data, keys)
    S, m = config.num_shards, config.num_segments
    sizes = assignment.partition_sizes()
    n_seg = round_up(max(int(sizes.max()), 1), pad_multiple)
    scale = None
    if corpus_dtype == "int8":
        scale = (np.abs(data).max(axis=0) / 127.0).astype(np.float32)
        scale = np.maximum(scale, 1e-12)
        store = np.zeros((S, m, n_seg, data.shape[1]), dtype=np.int8)
    else:
        store = np.zeros(
            (S, m, n_seg, data.shape[1]),
            dtype=jnp.dtype(corpus_dtype).type if corpus_dtype != "float32"
            else np.float32,
        )
    ids = np.full((S, m, n_seg), -1, dtype=np.int32)
    norms = np.zeros((S, m, n_seg), dtype=np.float32)
    for s in range(S):
        for g in range(m):
            rows = assignment.rows[s][g]
            block = data[rows]
            if corpus_dtype == "int8":
                store[s, g, : len(rows)] = np.clip(
                    np.round(block / scale[None, :]), -127, 127
                ).astype(np.int8)
            else:
                store[s, g, : len(rows)] = block.astype(store.dtype)
            ids[s, g, : len(rows)] = keys[rows]
            norms[s, g, : len(rows)] = np.einsum("nd,nd->n", block, block)
    seg = part.segmenter
    tree = seg.tree_arrays()
    if tree is not None:
        tree = {
            "hyperplanes": tree["hyperplanes"],
            "split": tree["split"],
            "lo": tree["lo"],
            "hi": tree["hi"],
            "depth": tree["depth"],
        }
    return DeviceIndex(
        corpus=store, ids=ids, norms=norms, tree=tree, config=config,
        scale=scale,
    )


# ---------------------------------------------------------------------------
# Local (per-shard) search — runs inside shard_map
# ---------------------------------------------------------------------------


def _segment_scan_topk(q_seg, x_seg, ids_seg, xn_seg, k, metric,
                       block_n=2048, scale=None):
    """Per-segment blocked scan.  q_seg (m, C, d); x_seg (m, N, d);
    ids_seg (m, N); xn_seg (m, N) BUILD-TIME row norms
    -> (m, C, k) dists/ids (global keys).

    The matmul runs in the corpus dtype (bf16 corpus => bf16 MXU matmul with
    f32 accumulation; int8 corpus dequantizes per block against ``scale``);
    only the running top-k merge stays f32.
    """

    def one(qg, xg, ig, xng):
        N, dim = xg.shape
        bn = min(block_n, N)
        nb = -(-N // bn)
        xp = jnp.pad(xg, ((0, nb * bn - N), (0, 0)))
        ip = jnp.pad(ig, (0, nb * bn - N), constant_values=-1)
        xnp_ = jnp.pad(xng, (0, nb * bn - N))
        compute_dtype = jnp.bfloat16 if xg.dtype == jnp.int8 else xg.dtype
        qc = qg.astype(compute_dtype)
        q_norm = jnp.sum(
            qc.astype(jnp.float32) * qc.astype(jnp.float32), -1, keepdims=True
        )

        def step(carry, blk):
            run_d, run_i = carry
            # dynamic-slice the corpus per block: scanning over a stacked
            # (nb, bn, d) xs materialized a full padded copy of the corpus.
            xb = jax.lax.dynamic_slice(xp, (blk * bn, 0), (bn, dim))
            ib = jax.lax.dynamic_slice(ip, (blk * bn,), (bn,))
            xn = jax.lax.dynamic_slice(xnp_, (blk * bn,), (bn,))
            if scale is not None:  # SQ8: dequant fuses into the matmul read
                xb = xb.astype(compute_dtype) * scale.astype(compute_dtype)
            elif xb.dtype != compute_dtype:
                xb = xb.astype(compute_dtype)
            qx = jax.lax.dot_general(
                qc, xb, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (C, bn) f32 accum from native-dtype reads
            s = (q_norm - 2.0 * qx + xn[None, :]) if metric == "l2" else -qx
            s = jnp.where((ib >= 0)[None, :], s, jnp.inf)
            # two-stage merge: block-local top-k FIRST (C, k), then a (C, 2k)
            # merge — concatenating the raw (C, bn) scores each block cost
            # ~10x the corpus bytes in merge traffic.  (approx_max_k was
            # tried here — on TPU it lowers to the single-pass PartialReduce
            # — but the CPU lowering falls back to a full sort, so the
            # measured estimate regressed; revisit on hardware. §Perf v7.)
            neg_b, idx_b = jax.lax.top_k(-s, min(k, bn))
            blk_i = ib[idx_b]
            blk_d = -neg_b
            if blk_d.shape[1] < k:
                pad = k - blk_d.shape[1]
                blk_d = jnp.pad(blk_d, ((0, 0), (0, pad)), constant_values=jnp.inf)
                blk_i = jnp.pad(blk_i, ((0, 0), (0, pad)), constant_values=-1)
            cat_d = jnp.concatenate([run_d, blk_d], 1)  # (C, 2k)
            cat_i = jnp.concatenate([run_i, blk_i], 1)
            neg, idx = jax.lax.top_k(-cat_d, k)
            return (-neg, jnp.take_along_axis(cat_i, idx, 1)), None

        C = qg.shape[0]
        init = (
            jnp.full((C, k), jnp.inf, jnp.float32),
            jnp.full((C, k), -1, jnp.int32),
        )
        (d, gi), _ = jax.lax.scan(step, init, jnp.arange(nb))
        return d, gi

    return jax.vmap(one)(q_seg, x_seg, ids_seg, xn_seg)


def _local_shard_search_routed(
    q, corpus, ids, norms, tree, *, k_local, metric, capacity, depth,
    block_n=2048, scale=None,
):
    """Segment-routed search of ONE shard.  q (B, d); corpus (m, N, d)."""
    B = q.shape[0]
    m = corpus.shape[0]
    mask = route_queries_tree(dict(tree, depth=depth), q, spill=True)  # (B, m)
    # capacity dispatch: first `capacity` routed queries per segment.
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=0) - 1  # (B, m) slot per seg
    keep = mask & (pos < capacity)
    overflow = jnp.sum(mask & ~keep)
    # gather query indices per segment: sort (B,) priorities per segment col
    prio = jnp.where(keep, jnp.arange(B, dtype=jnp.int32)[:, None], B)
    order = jnp.argsort(prio, axis=0)  # (B, m) — routed queries first
    sel = order[:capacity].T  # (m, C) query indices (B = invalid)
    valid_slot = jnp.take_along_axis(prio, order, axis=0)[:capacity].T < B  # (m, C)
    q_seg = q[jnp.clip(sel, 0, B - 1)]  # (m, C, d)
    d_seg, i_seg = _segment_scan_topk(
        q_seg, corpus, ids, norms, k_local, metric, block_n=block_n,
        scale=scale,
    )
    d_seg = jnp.where(valid_slot[..., None], d_seg, jnp.inf)
    i_seg = jnp.where(valid_slot[..., None], i_seg, -1)
    # combine: scatter back to (B, m, k_local) then merge the spilled copies.
    buf_d = jnp.full((B, m, k_local), jnp.inf, dtype=d_seg.dtype)
    buf_i = jnp.full((B, m, k_local), -1, dtype=i_seg.dtype)
    seg_idx = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[:, None], sel.shape)
    # invalid slots index out of range (B) and are dropped by the scatter.
    flat_q = jnp.where(valid_slot, sel, B).reshape(-1)
    flat_g = seg_idx.reshape(-1)
    buf_d = buf_d.at[flat_q, flat_g].set(d_seg.reshape(-1, k_local), mode="drop")
    buf_i = buf_i.at[flat_q, flat_g].set(i_seg.reshape(-1, k_local), mode="drop")
    # level-1 merge (inside the shard): across the <=m segment copies
    neg, idx = jax.lax.top_k(-buf_d.reshape(B, -1), k_local)
    out_i = jnp.take_along_axis(buf_i.reshape(B, -1), idx, axis=1)
    return -neg, out_i, overflow


def _local_shard_search_full(q, corpus, ids, norms, *, k_local, metric,
                             block_n=8192, scale=None):
    """Brute scan of the whole shard (ground truth / RS segmenter).

    Reuses the masked blocked scan (padding rows are ZERO vectors whose
    distance ||q||^2 can beat real neighbors — they must be masked BEFORE
    the top-k, which _segment_scan_topk does via its id mask)."""
    m, N, d = corpus.shape
    flat = corpus.reshape(1, m * N, d)
    flat_ids = ids.reshape(1, m * N)
    flat_norms = norms.reshape(1, m * N)
    dd, gi = _segment_scan_topk(
        q[None], flat, flat_ids, flat_norms, k_local, metric,
        block_n=block_n, scale=scale,
    )
    return dd[0], gi[0], jnp.int32(0)


# ---------------------------------------------------------------------------
# The distributed serve step
# ---------------------------------------------------------------------------


def make_serve_fn(
    mesh: Mesh,
    config: LannsConfig,
    *,
    topk: int,
    mode: str = "routed",  # 'routed' | 'full'
    capacity_factor: float = 1.5,
    batch_per_device: int = 64,
    use_per_shard_topk: bool = True,
    corpus_axes: tuple = ("model",),
    query_axes: tuple = ("data",),
    depth: int = 0,
    block_n: int = 2048,
):
    """Build the jit'd distributed serve step for a given mesh.

    Returns (serve_fn, in_shardings, out_shardings).  serve_fn signature:
      (queries (B_global, d), corpus (S, m, N, d), ids (S, m, N), tree...) ->
      (dists (B_global, topk), ids (B_global, topk), overflow count)
    """
    num_shards = 1
    for a in corpus_axes:
        num_shards *= mesh.shape[a]
    if num_shards != config.num_shards:
        raise ValueError(
            f"config.num_shards={config.num_shards} must equal mesh corpus "
            f"axes product {num_shards}"
        )
    pstk = per_shard_topk(topk, num_shards, config.topk_confidence) if (
        use_per_shard_topk
    ) else topk
    m = config.num_segments
    if depth <= 0:
        depth = int(np.log2(m))
    # expected routed queries/segment: B * (1+2a)^depth / m, plus slack.
    spill_mult = (1.0 + 2.0 * config.alpha) ** depth
    capacity = int(np.ceil(batch_per_device * spill_mult / m * capacity_factor))
    capacity = max(8, min(capacity, batch_per_device))
    metric = "ip" if config.metric in ("ip", "cos") else "l2"

    has_scale = False
    q_spec = P(query_axes, None)
    corpus_spec = P(corpus_axes, None, None, None)
    ids_spec = P(corpus_axes, None, None)
    out_spec = P(query_axes, None)

    def local_step(q, corpus, ids, norms, *extra):
        # inside shard_map: q (B_loc, d); corpus (1, m, N, d)
        corpus = corpus[0]
        ids_l = ids[0]
        norms_l = norms[0]
        scale = extra[-1] if has_scale else None
        tree_leaves = extra[:-1] if has_scale else extra
        if mode == "routed" and tree_leaves:
            tree = {
                "hyperplanes": tree_leaves[0],
                "split": tree_leaves[1],
                "lo": tree_leaves[2],
                "hi": tree_leaves[3],
            }
            d_l, i_l, ovf = _local_shard_search_routed(
                q, corpus, ids_l, norms_l, tree,
                k_local=pstk, metric=metric, capacity=capacity, depth=depth,
                block_n=block_n, scale=scale,
            )
        else:
            d_l, i_l, ovf = _local_shard_search_full(
                q, corpus, ids_l, norms_l, k_local=pstk, metric=metric,
                scale=scale,
            )
        # ---- level-2 merge: the broker as a collective --------------------
        # all_gather over the corpus axes; payload per query = pstk pairs
        # per shard, which is what Eq. (5)-(6) trims (vs topk without it).
        d_g, i_g = d_l, i_l
        for ax in reversed(corpus_axes):  # innermost axis gathered first
            d_g = jax.lax.all_gather(d_g, ax)
            i_g = jax.lax.all_gather(i_g, ax)
        d_g = d_g.reshape(num_shards, q.shape[0], pstk)
        i_g = i_g.reshape(num_shards, q.shape[0], pstk)
        cand_d = jnp.moveaxis(d_g, 0, 1).reshape(q.shape[0], num_shards * pstk)
        cand_i = jnp.moveaxis(i_g, 0, 1).reshape(q.shape[0], num_shards * pstk)
        neg, idx = jax.lax.top_k(-cand_d, topk)
        out_i = jnp.take_along_axis(cand_i, idx, axis=1)
        ovf = jax.lax.psum(ovf, corpus_axes + query_axes)  # global scalar
        return -neg, out_i, ovf

    from jax.experimental.shard_map import shard_map

    def serve_fn(queries, corpus, ids, norms, tree, scale=None):
        nonlocal has_scale
        has_scale = scale is not None
        if mode == "routed" and tree is not None:
            leaves = (tree["hyperplanes"], tree["split"], tree["lo"], tree["hi"])
        else:
            leaves = ()
        if has_scale:
            leaves = leaves + (scale,)
        norms_spec = P(corpus_axes, None, None)
        fn = shard_map(
            local_step,
            mesh=mesh,
            in_specs=(q_spec, corpus_spec, ids_spec, norms_spec)
            + tuple(P() for _ in leaves),
            out_specs=(out_spec, out_spec, P()),
            check_rep=False,
        )
        return fn(queries, corpus, ids, norms, *leaves)

    shardings = {
        "queries": NamedSharding(mesh, q_spec),
        "corpus": NamedSharding(mesh, corpus_spec),
        "ids": NamedSharding(mesh, ids_spec),
        "out": NamedSharding(mesh, out_spec),
        "per_shard_topk": pstk,
        "capacity": capacity,
    }
    return serve_fn, shardings

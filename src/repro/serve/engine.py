"""Serving engines: LM continuous batching + the ANN micro-batching front end.

``ServeEngine`` owns the jitted prefill/decode steps (shape-bucketed) and a
slot-based batch: requests occupy fixed cache slots, finished requests free
their slot for the next queued request (continuous batching a la Orca/vLLM,
reduced to the static-shape form that XLA wants: the decode step always runs
the full (slots, 1) batch, with inactive slots masked).

serve_step (what the dry-run lowers for decode cells) = one decode step for
the full slot batch against the full KV cache.

``AnnFrontend`` is the LANNS §7 online-serving front end: single-query
arrivals are micro-batched (up to ``max_batch`` queries or ``max_wait_ms``
of queueing, whichever first) and executed through the same batched
``LannsIndex.query`` executor the offline benchmarks measure.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import jit_cache_size, next_pow2
from repro.distributed.sharding_rules import NULL_CTX, ShardingCtx
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False


def make_prefill_fn(cfg: tf.TransformerConfig, ctx: ShardingCtx = NULL_CTX):
    """(params, tokens (B, S), cache) -> (next_token_logits (B, V), cache)."""

    def prefill(params, tokens, cache):
        logits, cache, _ = tf.apply(
            params, cfg, tokens, cache=cache, cache_offset=0, ctx=ctx
        )
        return logits[:, -1], cache

    return prefill


def make_bucketed_prefill_fn(cfg: tf.TransformerConfig, ctx: ShardingCtx = NULL_CTX):
    """Prefill over a length-bucketed prompt: tokens (B, S_bucket) is the
    prompt right-padded to a power-of-two bucket and ``last`` is the TRACED
    index of the final real token, so one trace serves every prompt length in
    the bucket.  Right padding is attention-valid under the causal mask: a
    pad token at position p > last cannot influence logits at ``last``, and
    pad rows written to the cache sit at positions >= the true length, which
    decode masks out (kv_pos <= q_pos) and then overwrites in place.
    """

    def prefill(params, tokens, cache, last):
        logits, cache, _ = tf.apply(
            params, cfg, tokens, cache=cache, cache_offset=0, ctx=ctx
        )
        return jax.lax.dynamic_slice_in_dim(logits, last, 1, axis=1)[:, 0], cache

    return prefill


def make_decode_fn(cfg: tf.TransformerConfig, ctx: ShardingCtx = NULL_CTX):
    """(params, token (B, 1), cache, offset) -> (logits (B, V), cache).

    One new token against a KV cache of length ``offset`` — the paper-kind
    serve_step for decode_32k / long_500k cells.
    """

    def decode(params, token, cache, offset):
        logits, cache, _ = tf.apply(
            params, cfg, token, cache=cache, cache_offset=offset, ctx=ctx
        )
        return logits[:, -1], cache

    return decode


class ServeEngine:
    """Host-side continuous batching over fixed cache slots."""

    def __init__(
        self,
        cfg: tf.TransformerConfig,
        params,
        *,
        slots: int = 8,
        max_seq: int = 512,
        cache_dtype=jnp.float32,
        ctx: ShardingCtx = NULL_CTX,
        greedy: bool = True,
        seed: int = 0,
        prefill_bucket_min: int = 16,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.cache = tf.make_cache(cfg, slots, max_seq, dtype=cache_dtype)
        self.offsets = np.zeros(slots, dtype=np.int64)  # per-slot position
        self.active: list[Optional[Request]] = [None] * slots
        self.queue: list[Request] = []
        self.greedy = greedy
        self.rng = np.random.default_rng(seed)
        self.prefill_bucket_min = prefill_bucket_min
        self._prefill = jax.jit(make_bucketed_prefill_fn(cfg, ctx))
        self._decode = jax.jit(make_decode_fn(cfg, ctx))
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "completed": 0,
                      "prefill_traces": 0}

    def submit(self, req: Request):
        self.queue.append(req)

    def _prompt_bucket(self, length: int) -> int:
        """Power-of-two length bucket, clamped to the cache extent, so the
        jitted prefill compiles O(log max_seq) traces instead of one per
        distinct prompt length."""
        return min(max(next_pow2(length), self.prefill_bucket_min),
                   max(self.max_seq, length))

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                # per-slot prefill: batch of 1 into this slot's cache rows,
                # prompt right-padded to its length bucket (causal-masked,
                # so pad positions never leak into the last real logits)
                L = len(req.prompt)
                S_pad = self._prompt_bucket(L)
                toks = np.zeros((1, S_pad), np.int32)
                toks[0, :L] = req.prompt
                slot_cache = jax.tree.map(lambda c: c[:, s: s + 1], self.cache)
                logits, slot_cache = self._prefill(
                    self.params, jnp.asarray(toks), slot_cache,
                    jnp.int32(L - 1),
                )
                self.cache = jax.tree.map(
                    lambda full, sl: full.at[:, s: s + 1].set(sl),
                    self.cache, slot_cache,
                )
                self.offsets[s] = len(req.prompt)
                tok = self._sample(np.asarray(logits)[0])
                req.tokens_out.append(int(tok))
                self.stats["prefill_tokens"] += len(req.prompt)
                self.stats["prefill_traces"] = jit_cache_size(self._prefill)

    def _sample(self, logits: np.ndarray) -> int:
        if self.greedy:
            return int(np.argmax(logits))
        p = np.exp(logits - logits.max())
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def step(self):
        """One engine tick: admit waiting requests, decode all active slots."""
        self._admit()
        if not any(self.active):
            return False
        last = np.zeros((self.slots, 1), dtype=np.int32)
        for s, req in enumerate(self.active):
            if req is not None and req.tokens_out:
                last[s, 0] = req.tokens_out[-1]
        # per-slot offsets: slots decode at their own cache positions
        offset = jnp.asarray(self.offsets, jnp.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(last), self.cache, offset
        )
        logits = np.asarray(logits)
        self.stats["decode_steps"] += 1
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.offsets[s] += 1
            tok = self._sample(logits[s])
            req.tokens_out.append(tok)
            if (
                len(req.tokens_out) >= req.max_new_tokens
                or self.offsets[s] >= self.max_seq - 1
            ):
                req.done = True
                self.stats["completed"] += 1
                self.active[s] = None
                self.offsets[s] = 0
        return True

    def run(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.stats


# ---------------------------------------------------------------------------
# ANN micro-batching front end (LANNS §7 online serving)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnnRequest:
    """One in-flight ANN query; results land in place when its batch runs."""

    uid: int
    query: np.ndarray  # (d,) float32
    t_submit: float
    dists: Optional[np.ndarray] = None  # (topk,) when done
    ids: Optional[np.ndarray] = None  # (topk,) when done

    @property
    def done(self) -> bool:
        return self.ids is not None


class AnnFrontend:
    """Micro-batching broker front end over a ``LannsIndex``-like object.

    Queries arrive one at a time (``submit``); the front end coalesces them
    and fires ONE batched ``index.query`` per micro-batch, when either
    ``max_batch`` queries are pending (throughput bound) or the oldest has
    queued for ``max_wait_ms`` (latency bound).  Amortizing the per-call
    routing/merge overhead over the batch is what makes the paper's
    single-node QPS claim reachable; see benchmarks/bench_online_qps.py.

    ``clock`` is injectable so tests can drive deadlines deterministically.
    """

    def __init__(
        self,
        index,
        *,
        topk: int = 100,
        max_batch: int = 64,
        max_wait_ms: float = 2.0,
        ef: Optional[int] = None,
        clock: Callable[[], float] = time.perf_counter,
        collect_stats: bool = False,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.index = index
        self.topk = topk
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.ef = ef
        self.clock = clock
        self.collect_stats = collect_stats
        self.pending: list[AnnRequest] = []
        self._uid = 0
        self.stats = {
            "submitted": 0, "completed": 0, "batches": 0,
            "full_batches": 0, "deadline_batches": 0, "forced_batches": 0,
            "segments_visited": 0.0,
        }
        # routing/trace stats of the most recent batch (collect_stats=True):
        # perShardTopK, segments visited, and the process-wide beam_search
        # trace counts — what an operator watches to confirm the serving
        # trace set stays bounded under live traffic.
        self.last_query_stats: Optional[dict] = None

    def submit(self, query: np.ndarray) -> AnnRequest:
        req = AnnRequest(self._uid, np.asarray(query, np.float32), self.clock())
        self._uid += 1
        self.pending.append(req)
        self.stats["submitted"] += 1
        return req

    def step(self) -> list[AnnRequest]:
        """Flush every due micro-batch; returns the completed requests."""
        done: list[AnnRequest] = []
        while len(self.pending) >= self.max_batch:
            done += self._execute(self.pending[: self.max_batch], "full_batches")
            self.pending = self.pending[self.max_batch:]
        if self.pending and (
            self.clock() - self.pending[0].t_submit >= self.max_wait_s
        ):
            done += self._execute(self.pending, "deadline_batches")
            self.pending = []
        return done

    def flush(self) -> list[AnnRequest]:
        """Drain everything pending regardless of deadlines (shutdown path)."""
        done: list[AnnRequest] = []
        while self.pending:
            batch = self.pending[: self.max_batch]
            self.pending = self.pending[self.max_batch:]
            done += self._execute(batch, "forced_batches")
        return done

    @property
    def mean_batch_size(self) -> float:
        return self.stats["completed"] / max(self.stats["batches"], 1)

    @property
    def mean_segments_visited(self) -> float:
        return self.stats["segments_visited"] / max(self.stats["completed"], 1)

    def _execute(self, batch: list[AnnRequest], kind: str) -> list[AnnRequest]:
        q = np.stack([r.query for r in batch])
        if self.collect_stats:
            d, i, qstats = self.index.query(
                q, self.topk, ef=self.ef, return_stats=True
            )
            self.last_query_stats = qstats
            self.stats["segments_visited"] += (
                qstats.get("mean_segments_visited", 0.0) * len(batch)
            )
        else:
            d, i = self.index.query(q, self.topk, ef=self.ef)
        d, i = np.asarray(d), np.asarray(i)
        for j, r in enumerate(batch):
            r.dists, r.ids = d[j], i[j]
        self.stats["batches"] += 1
        self.stats[kind] += 1
        self.stats["completed"] += len(batch)
        return list(batch)

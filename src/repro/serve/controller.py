"""Closed-loop SLO controller over the async ANN serving front end.

The paper's deployment story (§7: few-ms p99 at ~2.5K QPS/node) only holds
while the knobs — micro-batch deadline, batch size, HNSW ``ef`` — match the
offered load, and real traffic is bursty (the MMPP points in
serve/loadgen.py).  ``SLOController`` closes the loop that PR 8's telemetry
substrate was built to judge:

* **auto-tune** (a background thread, one tick per ``interval_s``): reads
  the ``batch`` spans the frontend's telemetry emitted since the last tick
  plus the live queue depth, and adapts ``max_wait_ms`` AIMD-style —
  tighten (multiplicative) when observed worst-case latency blows the SLO
  or the queue is deep, relax (multiplicative, capped at the configured
  base) when the system runs cold.  ``ef`` per Malkov & Yashunin is the
  accuracy/latency dial; ``max_wait_ms`` is the batching-delay dial — the
  controller moves the cheap dial continuously and the accuracy dial only
  per-request, only past deadline.
* **deadline-aware degrade** (called inline by the frontend at batch
  formation): a request already past its latency budget gets a reduced
  ``ef`` from a small descending ladder — one rung per whole budget
  already elapsed — instead of blowing the p99 for full-accuracy results
  nobody is waiting for.  Per-request ``(topk, ef)`` mixed batches (PR 5)
  mean a degraded request rides the same formed batch; the ladder is
  pre-compiled via ``LannsIndex.warm_traces(knobs=ctrl.warm_knobs())``, so
  a controller decision can NEVER trigger a jit compile on the serving
  path (asserted by the retrace-sentinel test in tests/test_controller.py).

The controller is pure policy over existing substrate: it calls only
``frontend.retune()`` (knob store under the frontend's own lock) and reads
only ``Telemetry`` signals.  It never raises from ``on_batch_formed`` by
construction — every policy input is validated in ``__init__`` — because
an exception there would crash the batcher thread and cancel every
in-flight request.

Concurrency contract (checked by ``repro.analysis`` LANNS010-013, stressed
by the nightly ``race_stress`` controller churn): every mutable field is
guarded by ``_lock`` per the ``_GUARDED_BY`` registry below.  The
controller NEVER holds ``_lock`` while calling into the frontend or
telemetry (both take their own locks), so the process-wide held-before
graph stays acyclic — ``_LOCK_ORDER`` records ``_lock`` as a leaf.
"""

from __future__ import annotations

import math
import threading
import time
from typing import Callable, Optional, Sequence

__all__ = ["SLOController"]


class SLOController:
    """Telemetry-driven auto-tune + deadline-aware ``ef`` degrade.

    Construct standalone, then hand it to the frontend —
    ``AsyncAnnFrontend(index, controller=ctrl, telemetry=tel)`` calls
    ``bind()`` — and ``start()`` the retune thread (optional: degrade
    works passively without it).  One controller binds ONE frontend.

    Parameters
    ----------
    slo_ms:
        The latency objective. Requests without an explicit per-request
        ``deadline_ms`` fall back to ``default_deadline_ms`` (which itself
        defaults to ``slo_ms``), and the retune tick compares observed
        worst-case latency against ``slo_ms``.
    ef_ladder:
        Strictly-descending ``ef`` rungs for degrade.  A request a whole
        budget late gets rung 0, two budgets late rung 1, ... clamped to
        the last rung.  Warm every rung: ``index.warm_traces(max_batch,
        topk, knobs=ctrl.warm_knobs())``.
    default_deadline_ms:
        Budget for requests that carry no ``deadline_ms``.  ``None``
        disables the fallback (only explicit deadlines degrade); the
        default mirrors ``slo_ms``.
    interval_s / min_wait_ms / tighten_factor / relax_factor / relax_margin:
        Retune cadence and AIMD shape: tighten multiplies ``max_wait_ms``
        by ``tighten_factor`` (floored at ``min_wait_ms``) when worst
        observed latency exceeds ``slo_ms`` or depth exceeds 2x
        ``max_batch``; relax multiplies by ``relax_factor`` (capped at the
        bind-time base) when worst latency sits under ``relax_margin *
        slo_ms`` and the queue is shallow.
    """

    _GUARDED_BY = {
        "frontend": "_lock",
        "telemetry": "_lock",
        "_thread": "_lock",
        "_stopping": "_lock",
        "_watermark": "_lock",
        "cur_wait_ms": "_lock",
        "_base_wait_ms": "_lock",
        "stats": "_lock",
    }
    # leaf lock: never held across frontend.retune()/telemetry calls
    _LOCK_ORDER = ("_lock",)

    def __init__(
        self,
        *,
        slo_ms: float,
        ef_ladder: Sequence[int] = (64, 32, 16),
        default_deadline_ms: object = "slo",
        interval_s: float = 0.05,
        min_wait_ms: float = 0.1,
        tighten_factor: float = 0.5,
        relax_factor: float = 1.5,
        relax_margin: float = 0.5,
        clock: Callable[[], float] = time.perf_counter,
    ):
        slo_ms = float(slo_ms)
        if not math.isfinite(slo_ms) or slo_ms <= 0:
            raise ValueError(f"slo_ms={slo_ms} must be finite and > 0")
        ladder = tuple(int(e) for e in ef_ladder)
        if not ladder:
            raise ValueError("ef_ladder must have at least one rung")
        if any(e < 1 for e in ladder):
            raise ValueError(f"ef_ladder={ladder} rungs must be >= 1")
        if any(a <= b for a, b in zip(ladder, ladder[1:])):
            raise ValueError(
                f"ef_ladder={ladder} must be strictly descending (rung i is "
                "the ef for a request i+1 budgets past deadline)"
            )
        if default_deadline_ms == "slo":
            default_deadline_ms = slo_ms
        elif default_deadline_ms is not None:
            default_deadline_ms = float(default_deadline_ms)
            if not math.isfinite(default_deadline_ms) or default_deadline_ms <= 0:
                raise ValueError(
                    f"default_deadline_ms={default_deadline_ms} must be "
                    "finite and > 0 (or None to degrade only explicit "
                    "deadlines)"
                )
        if interval_s <= 0:
            raise ValueError(f"interval_s={interval_s} must be > 0")
        if min_wait_ms <= 0:
            raise ValueError(f"min_wait_ms={min_wait_ms} must be > 0")
        if not 0.0 < tighten_factor < 1.0:
            raise ValueError(f"tighten_factor={tighten_factor} not in (0, 1)")
        if relax_factor <= 1.0:
            raise ValueError(f"relax_factor={relax_factor} must be > 1")
        if not 0.0 < relax_margin < 1.0:
            raise ValueError(f"relax_margin={relax_margin} not in (0, 1)")
        self.slo_ms = slo_ms
        self.ef_ladder = ladder
        self.default_deadline_ms = default_deadline_ms
        self.interval_s = float(interval_s)
        self.min_wait_ms = float(min_wait_ms)
        self.tighten_factor = float(tighten_factor)
        self.relax_factor = float(relax_factor)
        self.relax_margin = float(relax_margin)
        self.clock = clock
        self._lock = threading.Condition()
        self.frontend = None
        self.telemetry = None
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._watermark = 0  # span-sink seq consumed by the last tick
        self.cur_wait_ms = float("nan")  # set at bind()
        self._base_wait_ms = float("nan")
        self.stats = {
            "degraded": 0, "ticks": 0, "tighten": 0, "relax": 0, "hold": 0,
        }

    # -- wiring --------------------------------------------------------------

    def bind(self, frontend) -> "SLOController":
        """Attach to a frontend (called by ``AnnFrontend.__init__`` when the
        frontend is constructed with ``controller=``).  Captures the
        frontend's configured ``max_wait_ms`` as the relax ceiling and its
        telemetry bundle as the signal source."""
        with self._lock:
            if self.frontend is not None and self.frontend is not frontend:
                raise RuntimeError(
                    "SLOController is already bound to a frontend; build one "
                    "controller per frontend"
                )
            self.frontend = frontend
            self.telemetry = frontend.telemetry
            self._base_wait_ms = frontend.max_wait_s * 1e3
            self.cur_wait_ms = self._base_wait_ms
        return self

    def warm_knobs(self, topk: Optional[int] = None) -> list[tuple]:
        """``(topk, ef)`` pairs covering the degrade ladder, ready for
        ``LannsIndex.warm_traces(max_batch, topk, knobs=...)`` — warming
        them is what lets ``on_batch_formed`` switch ``ef`` mid-traffic
        without ever compiling."""
        return [(topk, ef) for ef in self.ef_ladder]

    # -- degrade (called inline by the frontend at batch formation) ----------

    def on_batch_formed(self, batch, now: float) -> Optional[list]:
        """Per-request ``ef`` overrides for a just-formed micro-batch.

        ``now`` is the frontend's batch-formation timestamp (its own
        ``clock`` domain, matching ``r.t_submit``).  Returns ``None`` when
        nothing degrades (the common case — zero allocation), else a list
        aligned with ``batch`` whose non-None entries replace that
        request's effective ``ef``.  A request's own explicit ``ef`` is
        only ever REDUCED, never raised.
        """
        ladder = self.ef_ladder
        n_rungs = len(ladder)
        default_budget = self.default_deadline_ms
        overrides: Optional[list] = None
        by_ef: dict[int, int] = {}
        for j, r in enumerate(batch):
            budget = r.deadline_ms if r.deadline_ms is not None else default_budget
            if budget is None:
                continue
            elapsed_ms = (now - r.t_submit) * 1e3
            if elapsed_ms < budget:
                continue
            rung = min(int(elapsed_ms // budget), n_rungs) - 1
            ef = ladder[rung]
            if r.ef is not None and r.ef <= ef:
                continue  # already cheaper than the rung: leave it
            if overrides is None:
                overrides = [None] * len(batch)
            overrides[j] = ef
            by_ef[ef] = by_ef.get(ef, 0) + 1
        if overrides is None:
            return None
        n = sum(by_ef.values())
        with self._lock:
            self.stats["degraded"] += n
            tel = self.telemetry
        if tel is not None:
            for ef, count in sorted(by_ef.items()):
                tel.on_degrade(ef, count)
        return overrides

    # -- auto-tune -----------------------------------------------------------

    def retune_once(self) -> str:
        """One controller tick; returns the decision taken.

        Signals: the worst end-to-end latency implied by the ``batch``
        spans emitted since the previous tick (``queue_max_s + exec_s`` —
        the slowest request of each formed batch), and the instantaneous
        queue depth.  The decision is computed under ``_lock`` but APPLIED
        outside it (``frontend.retune`` takes the frontend's lock;
        telemetry takes its leaf locks) — the lock graph stays acyclic.
        """
        with self._lock:
            fe = self.frontend
            tel = self.telemetry
            since = self._watermark
        if fe is None:
            return "unbound"
        worst_ms = float("nan")
        new_mark = since
        if tel is not None:
            events = tel.spans.events(kind="batch", since=since)
            new_mark = tel.spans.next_seq
            if events:
                worst_ms = 1e3 * max(
                    ev.get("queue_max_s", 0.0) + ev.get("exec_s", 0.0)
                    for ev in events
                )
        depth = fe.depth if hasattr(fe, "depth") else len(fe.pending)
        max_batch = fe.max_batch
        with self._lock:
            self._watermark = new_mark
            cur = self.cur_wait_ms
            base = self._base_wait_ms
            hot = (
                (math.isfinite(worst_ms) and worst_ms > self.slo_ms)
                or depth > 2 * max_batch
            )
            cold = (
                not math.isfinite(worst_ms)
                or worst_ms < self.relax_margin * self.slo_ms
            ) and depth <= max_batch
            if hot and cur > self.min_wait_ms:
                action = "tighten"
                new_wait = max(cur * self.tighten_factor, self.min_wait_ms)
            elif cold and cur < base:
                action = "relax"
                new_wait = min(cur * self.relax_factor, base)
            else:
                action = "hold"
                new_wait = cur
            self.cur_wait_ms = new_wait
            self.stats["ticks"] += 1
            self.stats[action] = self.stats.get(action, 0) + 1
        if new_wait != cur:
            fe.retune(max_wait_ms=new_wait)
        if tel is not None:
            tel.on_retune(
                action=action, max_wait_ms=new_wait, max_batch=max_batch,
                worst_ms=worst_ms, depth=depth,
            )
        return action

    def snapshot(self) -> dict:
        """Decision counters + current knob values (thread-safe copy)."""
        with self._lock:
            out = dict(self.stats)
            out["max_wait_ms"] = self.cur_wait_ms
        return out

    # -- lifecycle -----------------------------------------------------------

    @property
    def running(self) -> bool:
        with self._lock:
            return self._thread is not None

    def start(self) -> "SLOController":
        """Spawn the retune thread (one tick per ``interval_s``)."""
        with self._lock:
            if self.frontend is None:
                raise RuntimeError(
                    "bind() a frontend (AnnFrontend(..., controller=ctrl)) "
                    "before start()"
                )
            if self._thread is not None:
                raise RuntimeError("controller already started")
            self._stopping = False
            self._thread = threading.Thread(
                target=self._loop, name="slo-controller", daemon=True
            )
            self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = None) -> "SLOController":
        """Stop the retune thread; a no-op when not running.  Degrade keeps
        working after stop() — it is driven by the frontend, not this
        thread."""
        with self._lock:
            thread = self._thread
            if thread is None:
                return self
            self._stopping = True
            self._lock.notify_all()
        thread.join(timeout)
        if thread.is_alive():
            raise RuntimeError("controller thread did not stop in time")
        with self._lock:
            self._thread = None
        return self

    def __enter__(self) -> "SLOController":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    def _loop(self):
        while True:
            with self._lock:
                if self._stopping:
                    return
                self._lock.wait(self.interval_s)
                if self._stopping:
                    return
            self.retune_once()

"""Deterministic synthetic datasets for every substrate.

No internet access in this environment, so SIFT1M/GIST1M are mirrored by a
*clustered* generator whose local-neighborhood statistics are the property
that matters for ANN benchmarks (real descriptor datasets are strongly
clustered; iid gaussians are the known worst case for hyperplane segmenters
and would misrepresent the paper's RH/APD recall numbers in either direction).
Everything is seeded and reproducible.
"""

from __future__ import annotations

import numpy as np


def clustered_vectors(
    n: int,
    d: int,
    *,
    n_clusters: int = 64,
    cluster_std: float = 0.15,
    seed: int = 0,
    center_seed: int = None,
    spectrum_decay: float = 0.0,
    dtype=np.float32,
) -> np.ndarray:
    """Gaussian-mixture corpus: unit-norm centers + within-cluster noise.

    cluster_std controls the neighborhood structure: 0.15 gives SIFT-like
    cluster separation (most true neighbors share a cluster).

    ``center_seed`` pins the mixture centers independently of the sample
    noise — corpus and queries MUST share centers (same-distribution queries,
    as in SIFT1M); different centers put every query in no-man's land and
    make hyperplane routing look uniformly bad.

    ``spectrum_decay`` > 0 gives the coordinates a 1/i^decay eigenspectrum —
    real descriptor datasets (SIFT/GIST) are strongly anisotropic, which is
    exactly what makes the APD direction informative (+10 recall pts for APD
    at decay=1 in our calibration).
    """
    rng_c = np.random.default_rng(seed if center_seed is None else center_seed)
    rng = np.random.default_rng(seed)
    if spectrum_decay > 0:
        spec = 1.0 / np.arange(1, d + 1) ** spectrum_decay
        spec = spec / np.sqrt((spec**2).mean())
    else:
        spec = np.ones(d)
    centers = rng_c.standard_normal((n_clusters, d)).astype(np.float64) * spec
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = rng.integers(0, n_clusters, size=n)
    x = centers[assign] + cluster_std * rng.standard_normal((n, d)) * spec
    return x.astype(dtype)


def sift_like(n: int = 100_000, d: int = 128, n_queries: int = 1000, seed: int = 0):
    """(corpus, queries) pair mirroring the SIFT1M protocol at reduced scale.

    Queries are held-out draws from the SAME anisotropic mixture (shared
    centers); ~300 points/cluster so the top-100 neighborhood of a typical
    query sits inside one cluster, as at SIFT1M density."""
    nc = max(32, n // 300)
    kw = {"n_clusters": nc, "center_seed": seed, "spectrum_decay": 1.0}
    corpus = clustered_vectors(n, d, seed=seed, **kw)
    queries = clustered_vectors(n_queries, d, seed=seed + 1, **kw)
    return corpus, queries


# ---------------------------------------------------------------------------
# LM data
# ---------------------------------------------------------------------------


def token_batch(batch: int, seq_len: int, vocab: int, seed: int = 0):
    """(tokens, labels) int32 arrays — next-token LM batch."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, size=(batch, seq_len + 1), dtype=np.int64)
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)


# ---------------------------------------------------------------------------
# Graph data
# ---------------------------------------------------------------------------


def power_law_graph(
    n_nodes: int,
    n_edges: int,
    *,
    d_feat: int = 0,
    seed: int = 0,
    with_positions: bool = True,
):
    """Directed edge list with power-law-ish degree (preferential attachment
    approximated by degree-biased sampling), optional features/positions.

    Returns dict(edge_index (2, E) int32, positions (n, 3) f32?, features?).
    Self-loops removed; may contain parallel edges (as real web graphs do).
    """
    rng = np.random.default_rng(seed)
    # degree-biased endpoints: sample with probability ~ zipf rank weight
    ranks = np.arange(1, n_nodes + 1, dtype=np.float64)
    w = 1.0 / ranks**0.8
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int64)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int64)
    loops = src == dst
    dst[loops] = (dst[loops] + 1) % n_nodes
    out = {"edge_index": np.stack([src, dst]).astype(np.int32)}
    if with_positions:
        out["positions"] = rng.standard_normal((n_nodes, 3)).astype(np.float32)
    if d_feat:
        out["features"] = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    return out


def random_molecule_batch(
    batch: int, n_nodes: int = 30, n_edges: int = 64, seed: int = 0
):
    """Batched small molecules: atom types, 3D positions, radius-graph edges.

    Edges are the n_edges nearest pairs per molecule (symmetric-ish), padded
    to exactly n_edges with -1.  This is the `molecule` shape cell of the
    DimeNet config.
    """
    rng = np.random.default_rng(seed)
    z = rng.integers(1, 10, size=(batch, n_nodes), dtype=np.int32)
    pos = rng.standard_normal((batch, n_nodes, 3)).astype(np.float32) * 1.5
    edges = np.full((batch, 2, n_edges), -1, dtype=np.int32)
    for b in range(batch):
        d = np.linalg.norm(pos[b][:, None] - pos[b][None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        flat = np.argsort(d, axis=None)[: n_edges]
        src, dst = np.unravel_index(flat, d.shape)
        edges[b, 0, : len(src)] = src
        edges[b, 1, : len(dst)] = dst
    y = rng.standard_normal((batch,)).astype(np.float32)
    return {"z": z, "positions": pos, "edge_index": edges, "y": y}


# ---------------------------------------------------------------------------
# RecSys data
# ---------------------------------------------------------------------------


def criteo_like_batch(
    batch: int,
    *,
    n_sparse: int = 39,
    n_dense: int = 0,
    vocab_sizes=None,
    hist_len: int = 0,
    n_items: int = 0,
    seed: int = 0,
):
    """Click-log style batch: per-field categorical ids (+ optional dense
    features, behaviour history, candidate item) with a clicked label whose
    logit depends on a hidden linear model — so training losses actually
    decrease and smoke tests can assert learning."""
    rng = np.random.default_rng(seed)
    if vocab_sizes is None:
        vocab_sizes = [100_000] * n_sparse
    sparse = np.stack(
        [rng.integers(0, v, size=batch, dtype=np.int64) for v in vocab_sizes], axis=1
    ).astype(np.int32)
    out = {"sparse_ids": sparse}
    if n_dense:
        out["dense"] = rng.standard_normal((batch, n_dense)).astype(np.float32)
    if hist_len:
        out["history"] = rng.integers(
            0, max(n_items, 2), size=(batch, hist_len), dtype=np.int32
        )
        out["hist_len"] = rng.integers(1, hist_len + 1, size=batch, dtype=np.int32)
        out["target_item"] = rng.integers(0, max(n_items, 2), size=batch, dtype=np.int32)
    # hidden ground truth: logit from hashed field ids
    h = (sparse * (np.arange(sparse.shape[1]) + 1)[None, :]).sum(axis=1)
    logit = ((h % 97) / 97.0 - 0.5) * 4.0
    p = 1.0 / (1.0 + np.exp(-logit))
    out["label"] = (rng.random(batch) < p).astype(np.float32)
    return out

from repro.data.synthetic import (
    clustered_vectors,
    criteo_like_batch,
    power_law_graph,
    random_molecule_batch,
    sift_like,
    token_batch,
)

__all__ = [
    "clustered_vectors",
    "criteo_like_batch",
    "power_law_graph",
    "random_molecule_batch",
    "sift_like",
    "token_batch",
]

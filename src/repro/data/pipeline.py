"""Host data pipeline: sharded, deterministic, prefetching.

Each host materializes only its slice of the global batch (per-process data
parallelism); a background thread keeps ``prefetch`` batches ready so the
device step never waits on the generator (the standard single-controller
JAX input pattern).  Generators are pure functions of (seed, step) so any
host can reproduce any step after a restart — checkpoint resumption needs
no data-state file.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class ShardedBatchIterator:
    """Wraps batch_fn(seed, step) -> global-batch pytree; yields this host's
    slice, prefetched."""

    def __init__(
        self,
        batch_fn: Callable[[int, int], dict],
        *,
        seed: int = 0,
        start_step: int = 0,
        host_index: int = 0,
        num_hosts: int = 1,
        prefetch: int = 2,
        sharding: Optional[dict] = None,
    ):
        self.batch_fn = batch_fn
        self.seed = seed
        self.step = start_step
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _slice_host(self, batch: dict) -> dict:
        def sl(x):
            n = x.shape[0]
            per = n // self.num_hosts
            lo = self.host_index * per
            return x[lo: lo + per]

        return jax.tree.map(sl, batch)

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._slice_host(self.batch_fn(self.seed, step))
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        if self.sharding:
            batch = {
                k: jax.device_put(v, self.sharding.get(k)) if k in self.sharding
                else v
                for k, v in batch.items()
            }
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)

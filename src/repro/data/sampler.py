"""GNN neighbor sampler — the real fanout sampler the minibatch_lg cell needs.

GraphSAGE-style layered sampling over a CSR adjacency: for seed nodes, sample
``fanout[0]`` neighbors, then ``fanout[1]`` of each of those, etc.  Output is
a padded subgraph with static shapes (so the sampled-training step jits):
  nodes     (n_max,)   global ids, -1 padded (layer-0 seeds first)
  edge_index(2, e_max) LOCAL indices into ``nodes``, -1 padded
  seed_mask (n_max,)   True for the batch_nodes seeds (loss is computed there)

Sampling is vectorized numpy (no per-node python loop over the batch): one
randint block per layer.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # (n+1,)
    indices: np.ndarray  # (nnz,)

    @classmethod
    def from_edge_index(cls, edge_index: np.ndarray, n_nodes: int) -> "CSRGraph":
        src, dst = edge_index[0], edge_index[1]
        valid = (src >= 0) & (dst >= 0)
        src, dst = src[valid], dst[valid]
        order = np.argsort(src, kind="stable")
        indices = dst[order].astype(np.int64)
        indptr = np.searchsorted(src[order], np.arange(n_nodes + 1)).astype(np.int64)
        return cls(indptr=indptr, indices=indices)

    @property
    def n_nodes(self):
        return len(self.indptr) - 1

    def degree(self, nodes):
        return self.indptr[nodes + 1] - self.indptr[nodes]


def sample_neighbors(
    graph: CSRGraph, nodes: np.ndarray, fanout: int, rng: np.random.Generator
):
    """(len(nodes), fanout) sampled neighbor ids, -1 where degree == 0.
    Sampling with replacement (GraphSAGE default) — fully vectorized."""
    deg = graph.degree(nodes)
    r = rng.integers(0, 2**63 - 1, size=(len(nodes), fanout))
    safe_deg = np.maximum(deg, 1)
    offs = (r % safe_deg[:, None]).astype(np.int64)
    nbrs = graph.indices[graph.indptr[nodes][:, None] + offs]
    return np.where(deg[:, None] > 0, nbrs, -1)


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanout: tuple,
    *,
    rng: np.random.Generator,
    n_max: int,
    e_max: int,
):
    """Layered fanout sample -> padded local subgraph (see module doc)."""
    frontier = seeds.astype(np.int64)
    all_nodes = [frontier]
    src_list, dst_list = [], []
    for f in fanout:
        nbrs = sample_neighbors(graph, frontier, f, rng)  # (len, f)
        src = np.repeat(frontier, f)
        dst = nbrs.reshape(-1)
        ok = dst >= 0
        # message direction: neighbor -> frontier node
        src_list.append(dst[ok])
        dst_list.append(src[ok])
        frontier = np.unique(dst[ok])
        all_nodes.append(frontier)
    nodes = np.concatenate(all_nodes)
    # dedup, seeds first (stable)
    _, first_idx = np.unique(nodes, return_index=True)
    nodes = nodes[np.sort(first_idx)]
    if len(nodes) > n_max:
        nodes = nodes[:n_max]  # seeds are first, trim the outermost hop
    lookup = {int(g): i for i, g in enumerate(nodes)}
    src = np.concatenate(src_list) if src_list else np.zeros(0, np.int64)
    dst = np.concatenate(dst_list) if dst_list else np.zeros(0, np.int64)
    loc_src = np.fromiter((lookup.get(int(s), -1) for s in src), np.int64, len(src))
    loc_dst = np.fromiter((lookup.get(int(d), -1) for d in dst), np.int64, len(dst))
    ok = (loc_src >= 0) & (loc_dst >= 0)
    loc_src, loc_dst = loc_src[ok], loc_dst[ok]
    if len(loc_src) > e_max:
        loc_src, loc_dst = loc_src[:e_max], loc_dst[:e_max]
    out_nodes = np.full(n_max, -1, np.int64)
    out_nodes[: len(nodes)] = nodes
    edge_index = np.full((2, e_max), -1, np.int32)
    edge_index[0, : len(loc_src)] = loc_src
    edge_index[1, : len(loc_dst)] = loc_dst
    seed_mask = np.zeros(n_max, bool)
    seed_mask[: len(seeds)] = True
    node_mask = out_nodes >= 0
    return {
        "nodes": out_nodes,
        "edge_index": edge_index,
        "seed_mask": seed_mask,
        "node_mask": node_mask,
    }

"""Shared exact re-rank stage (fp32 originals -> exact candidate distances).

Every quantized candidate-generation path — the two-stage int8 scan
(``quant/twostage.py``) and the q8 HNSW beam (``core/plan.py``) — ends the
same way: a small per-lane candidate set must be re-scored against the
EXACT fp32 vectors so returned distances carry no quantization error.  This
module is that stage, lifted out of the scan executor so both engines (and
any future code path, e.g. PQ) share one implementation and one
host/device placement policy.

``ExactStore`` owns the fp32 originals (+ squared norms + key table) for one
partition; ``exact_candidate_distances`` scores a (b, C) candidate matrix
against it:

* ``mode='host'`` — density-adaptive numpy: when the candidate volume
  ``b * C`` rivals the store size N (the routed-batch regime), ONE dense
  BLAS gemm + a take_along_axis beats b*C row gathers; otherwise gather
  only the candidate rows.  Host placement keeps the originals
  mmap-friendly.
* ``mode='device'`` — a jitted gather + batched contraction against a
  lazily-uploaded device copy (lane counts padded by the caller so the
  trace set stays bounded).

Distance convention (``exact_from_dots``): lower is better; 'l2' OMITS the
per-query ||q||^2 constant (it cannot change any within-query ordering) —
the query executor adds it back once after its final merge, one (B, topk)
add instead of one per lane.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax.numpy as jnp
import numpy as np

import jax


def exact_from_dots(dots, n2, metric, xp=np):
    """Metric correction shared by every exact-rerank path (host dense, host
    gather, device gather): exact distance from raw <q, x> dots and ||x||^2.
    l2 omits the per-query ||q||^2 constant (see module docstring)."""
    if metric == "l2":
        return n2 - 2.0 * dots
    if metric == "cos":
        return -dots / xp.sqrt(xp.maximum(n2, 1e-24))
    return -dots  # ip


@partial(jax.jit, static_argnames=("metric",))
def _rerank_gather_dev(q, cand, vecs, norms2, metric):
    """Exact candidate distances from a device-resident fp32 store:
    gather only the candidate rows, one batched contraction."""
    g = jnp.take(vecs, cand, axis=0)  # (L, C, D)
    dots = jnp.einsum("lcd,ld->lc", g, q)
    return exact_from_dots(dots, jnp.take(norms2, cand), metric, xp=jnp)


class ExactStore:
    """fp32 originals + norms + keys for one partition's exact re-rank."""

    def __init__(self, vectors: np.ndarray, keys: Optional[np.ndarray] = None):
        self.vectors = np.asarray(vectors, np.float32)
        self.norms2 = np.einsum(
            "nd,nd->n", self.vectors, self.vectors
        ).astype(np.float32)
        self.keys = (
            np.asarray(keys, np.int64)
            if keys is not None
            else np.arange(len(self.vectors), dtype=np.int64)
        )
        self._dev_vecs = None
        self._dev_norms2 = None

    @property
    def size(self) -> int:
        return self.vectors.shape[0]

    def device(self):
        """Lazily-uploaded device copy (cached for the store's lifetime)."""
        if self._dev_vecs is None:
            self._dev_vecs = jnp.asarray(self.vectors)
            self._dev_norms2 = jnp.asarray(self.norms2)
        return self._dev_vecs, self._dev_norms2

    def nbytes(self) -> int:
        return int(self.vectors.nbytes) + int(self.norms2.nbytes)


def resolve_store_mode(rerank_store: str) -> str:
    """'auto' -> concrete placement: device on TPU, host elsewhere."""
    if rerank_store == "auto":
        return "device" if jax.default_backend() == "tpu" else "host"
    if rerank_store not in ("host", "device"):
        raise ValueError(
            f"rerank_store={rerank_store!r} — expected 'auto', 'host' "
            "or 'device'"
        )
    return rerank_store


# lanns: dims[b<=16_384, C<=1024, l_pad<=16_384]
def exact_candidate_distances(  # lanns: hotpath
    q: np.ndarray,
    cand: np.ndarray,
    store: ExactStore,
    metric: str,
    *,
    mode: str = "host",
    l_pad: Optional[int] = None,
) -> np.ndarray:
    """Exact distances (b, C) for candidate rows ``cand`` (b, C) of ``store``.

    ``q`` (b, d) must already be metric-prepped (normalized for 'cos',
    mips-augmented -> 'l2').  ``l_pad`` pads the device-mode lane count so
    the jitted gather reuses a bounded trace set; ignored for host mode.
    """
    b, C = cand.shape
    if mode == "device":
        vecs, n2 = store.device()
        qp = q
        cp = cand
        if l_pad is not None and l_pad != b:
            qp = np.zeros((l_pad, q.shape[1]), np.float32)
            qp[:b] = q
            cp = np.zeros((l_pad, C), np.int32)
            cp[:b] = cand
        ex = _rerank_gather_dev(  # lanns: noqa[LANNS033] -- callers pad l_pad on the quarter-pow2 grid (plan.py / twostage.py contract); this function never invents lane counts
            jnp.asarray(qp), jnp.asarray(cp), vecs, n2, metric  # lanns: noqa[LANNS033] -- same quarter-pow2 l_pad contract as the gather call above
        )
        return np.asarray(ex)[:b]  # lanns: noqa[LANNS003] -- the rerank stage's one designed sync (device mode)
    v, n2 = store.vectors, store.norms2
    if b * C >= store.size:  # dense regime: one BLAS gemm beats b*C gathers
        full = exact_from_dots(q @ v.T, n2[None, :], metric)
        return np.take_along_axis(full, cand, axis=1)
    g = np.take(v, cand.reshape(-1), axis=0).reshape(b, C, -1)
    dots = np.matmul(g, q[:, :, None])[:, :, 0]
    return exact_from_dots(dots, np.take(n2, cand), metric)

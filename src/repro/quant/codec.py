"""Symmetric per-dimension int8 codec + numpy reference scoring.

Quantization scheme
-------------------
Each corpus dimension d gets one fp32 scale ``s[d] = max_n |x[n, d]| / 127``
and rows are stored as ``codes[n, d] = round(x[n, d] / s[d])`` in [-127, 127]
— symmetric, so the int8 dot needs no zero-point cross terms.  Per-vector
fp32 ``norms2`` (the squared norm of the DEQUANTIZED row) ride along so l2
scores can be reconstructed from a single integer dot product; for 'cos' the
rows are normalized before encoding and scoring reduces to 'ip'.

Query-side: corpus scales fold into the query (``q * s``) and the folded
query is quantized per-query symmetric, so

    <q, x_hat>  ~=  q_scale[b] * <q_codes[b], codes[n]>     (int8 x int8)

with one fp32 rescale per (query, row).  This is exactly the contraction the
Pallas kernel (``repro.kernels.distance_topk_q8``) runs on the MXU; the
functions here are the numpy ground truth that its tests assert against.

Error: |x - dequantize(quantize(x))| <= s[d] / 2 per coordinate (round-to-
nearest, no clipping because s is derived from the per-dimension absmax).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# scales are clamped so all-zero dimensions quantize to 0 instead of NaN
EPS_SCALE = 1e-12

# The int8 x int8 contraction accumulates in int32 (numpy reference and the
# Pallas MXU kernel alike): the worst-case dot is d * 127 * 127, which must
# stay below 2^31 - 1.  Encoding refuses wider rows up front — a corpus that
# passes encode can never overflow the scoring accumulator, on any backend.
Q8_ACCUM_MAX_D = (2**31 - 1) // (127 * 127)  # = 133_144


def _check_accum_dim(d: int) -> None:
    if d > Q8_ACCUM_MAX_D:
        raise ValueError(
            f"d={d} exceeds Q8_ACCUM_MAX_D={Q8_ACCUM_MAX_D}: the int8 dot "
            "would overflow its int32 accumulator (d * 127^2 >= 2^31)"
        )


@dataclasses.dataclass
class Q8Corpus:
    """An int8-encoded corpus: codes + per-dim scales + per-vector norms.

    ``norms2[n] = ||codes[n] * scales||^2`` — the squared norm of the
    dequantized row, NOT of the original: l2 scores built from it are then
    exactly the distance to the dequantized point, which is what the
    candidate-generation stage ranks by.
    ``metric`` records what the codes were prepared for ('cos' rows are
    normalized before encoding; everything else stores rows as-is).
    """

    codes: np.ndarray  # (N, D) int8
    scales: np.ndarray  # (D,) fp32
    norms2: np.ndarray  # (N,) fp32
    metric: str = "l2"

    @property
    def size(self) -> int:
        return self.codes.shape[0]

    @property
    def dim(self) -> int:
        return self.codes.shape[1]


def _prep_rows(x: np.ndarray, metric: str) -> np.ndarray:
    x = np.asarray(x, dtype=np.float32)
    if metric == "cos":
        x = x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    return x


def quantize_q8(x: np.ndarray, metric: str = "l2") -> Q8Corpus:
    """Encode corpus rows to int8 with per-dimension symmetric scales."""
    if metric not in ("l2", "ip", "cos"):
        raise ValueError(f"metric={metric!r} — expected 'l2', 'ip' or 'cos'")
    x = _prep_rows(x, metric)
    _check_accum_dim(x.shape[1])
    if x.shape[0] == 0:
        return Q8Corpus(
            codes=np.zeros(x.shape, np.int8),
            scales=np.full((x.shape[1],), EPS_SCALE, np.float32),
            norms2=np.zeros((0,), np.float32),
            metric=metric,
        )
    scales = np.maximum(np.abs(x).max(axis=0) / 127.0, EPS_SCALE).astype(
        np.float32
    )
    codes = np.clip(np.rint(x / scales), -127, 127).astype(np.int8)
    deq = codes.astype(np.float32) * scales
    norms2 = np.einsum("nd,nd->n", deq, deq).astype(np.float32)
    return Q8Corpus(codes=codes, scales=scales, norms2=norms2, metric=metric)


def dequantize_q8(qc: Q8Corpus) -> np.ndarray:
    """Decode back to fp32 (the points stage-1 scoring actually ranks)."""
    return qc.codes.astype(np.float32) * qc.scales


def quantize_queries_q8(q: np.ndarray, scales: np.ndarray):
    """Fold corpus scales into queries and quantize per-query symmetric.

    Returns (q_codes (B, D) int8, q_scale (B,) fp32) such that
    ``q_scale[b] * <q_codes[b], codes[n]> ~= <q[b], dequantized x[n]>``.
    """
    q = np.asarray(q, dtype=np.float32)
    _check_accum_dim(q.shape[1])
    qf = q * np.asarray(scales, np.float32)[None, :]
    q_scale = np.maximum(
        np.abs(qf).max(axis=-1) / 127.0, EPS_SCALE
    ).astype(np.float32)
    q_codes = np.clip(np.rint(qf / q_scale[:, None]), -127, 127).astype(
        np.int8
    )
    return q_codes, q_scale


# lanns: dims[B<=4096, N<=33_554_432, D<=2048]
def q8_scores_np(q: np.ndarray, qc: Q8Corpus, metric: str = "l2"):
    """Reference stage-1 scores (B, N), lower is better.

    Mirrors the kernel contraction bit-for-bit at fp32: int32 dots, one fp32
    rescale, then the metric-specific correction.  For 'l2' the returned
    value is ``||q||^2 - 2 q_scale <q_c, x_c> + ||x_hat||^2`` — the (true)
    squared distance to the dequantized point up to query-quantization error.
    """
    q = np.asarray(q, dtype=np.float32)
    if metric == "cos":
        q = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    q_codes, q_scale = quantize_queries_q8(q, qc.scales)
    dots = q_codes.astype(np.int32) @ qc.codes.astype(np.int32).T  # exact
    qx = dots.astype(np.float32) * q_scale[:, None]
    if metric == "l2":
        qn = np.einsum("bd,bd->b", q, q)
        return qc.norms2[None, :] - 2.0 * qx + qn[:, None]
    return -qx  # ip / cos (cos is ip over pre-normalized inputs)


def distance_topk_q8_np(q: np.ndarray, qc: Q8Corpus, k: int, metric="l2"):
    """Reference top-k over the quantized scores (oracle for kernel tests)."""
    s = q8_scores_np(q, qc, metric)
    B, N = s.shape
    k_eff = min(k, N)
    idx = np.argsort(s, axis=1, kind="stable")[:, :k_eff]
    d = np.take_along_axis(s, idx, axis=1)
    if k_eff < k:
        d = np.concatenate(
            [d, np.full((B, k - k_eff), np.inf, np.float32)], axis=1
        )
        idx = np.concatenate(
            [idx, np.full((B, k - k_eff), -1, idx.dtype)], axis=1
        )
    return d.astype(np.float32), idx.astype(np.int32)


def q8_bytes_per_vector(qc: Q8Corpus) -> float:
    """Resident scan-corpus bytes per vector: codes + amortized scales +
    the per-vector fp32 norm correction.  The fp32 originals used by the
    exact re-rank stage are accounted separately (they can stay host-mmap)."""
    n = max(qc.size, 1)
    return (
        qc.codes.nbytes + qc.scales.nbytes + qc.norms2.nbytes
    ) / n

"""Quantized scan + exact re-rank subsystem (DESIGN: LoRANN/AQR-style).

The LANNS serving regime is bounded by corpus footprint and scan bandwidth
long before compute: fp32 corpora cap how many segments fit device-resident.
This package provides the standard fix — score a compact int8 corpus to
generate candidates, then re-rank a small candidate set against the exact
fp32 vectors — recovering full-precision recall at a fraction of the
resident bytes.

Pieces:

* ``codec``    — symmetric per-dimension int8 quantization (scale vector +
  per-vector norm correction), ``quantize_q8``/``dequantize_q8`` and numpy
  reference scoring;
* ``twostage`` — the CPU/TPU two-stage scan executor state used by the
  query-plan executor (stage-1 int8 scores, top-C candidate selection);
* ``rerank``   — the SHARED exact re-rank stage (``ExactStore`` +
  ``exact_candidate_distances``): both the two-stage scan and the
  quantized HNSW beam (``core/plan.py``) end their candidate generation
  here, so returned distances carry no quantization error;
* the fused Pallas int8 kernel lives in ``repro.kernels.distance_topk_q8``
  with its public wrapper ``repro.kernels.ops.distance_topk_q8``.
"""

from repro.quant.codec import (
    Q8Corpus,
    dequantize_q8,
    distance_topk_q8_np,
    q8_bytes_per_vector,
    q8_scores_np,
    quantize_q8,
    quantize_queries_q8,
)
from repro.quant.rerank import ExactStore, exact_candidate_distances

__all__ = [
    "ExactStore",
    "Q8Corpus",
    "dequantize_q8",
    "distance_topk_q8_np",
    "exact_candidate_distances",
    "q8_bytes_per_vector",
    "q8_scores_np",
    "quantize_q8",
    "quantize_queries_q8",
]

"""Two-stage (int8 scan -> exact re-rank) executor for the scan engine.

Stage 1 scores each routed (shard, segment)'s int8 corpus and keeps
``rerank_factor * perShardTopK`` candidates per (query, partition) lane;
stage 2 computes EXACT fp32 distances for just those candidates and the
executor merges the exact results.  Full-precision recall at a fraction of
the scan bytes: the resident scan corpus is int8 codes (+ 8 bytes/vector of
corrections), and the fp32 originals only serve candidate lookups.

Backend strategy (what actually runs where):

* stage-1 scoring is one jitted call per partition, dispatched async for
  every partition FIRST so XLA's pool computes later partitions while the
  host selects/re-ranks earlier ones.  On CPU the int8 dot is computed by
  casting codes to fp32 INSIDE the jit and running the oneDNN gemm —
  bit-exact to the int32 dot for D <= 1024 (products sum below 2^24) and
  measurably faster than the fp32 scan's gemm because the operand traffic
  halves.  On TPU / for D > 1024 it is a true int8->int32 ``dot_general``
  (the fused Pallas kernel in ``kernels/distance_topk_q8.py`` is the
  device-side equivalent that also fuses the top-k).
* candidate selection runs host-side via ``np.argpartition`` (O(N)
  introselect — measured ~3x cheaper than ``lax.top_k`` on CPU for the
  bench shapes) on a zero-copy dlpack view of the device scores.
* the exact re-rank is the SHARED stage in ``quant/rerank.py`` (also used
  by the q8 HNSW beam): density-adaptive host scoring (dense gemm when the
  candidate volume rivals the segment, row gathers otherwise) or a jitted
  device gather, selected by ``rerank_store``.

Shapes are bucketed exactly like the rest of the serving stack: corpora pad
to shared pow2 size buckets, lane counts to quarter-pow2 buckets, so the
jitted stage-1/stage-2 calls reuse a bounded trace set.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2_quarter
from repro.quant.codec import EPS_SCALE, Q8Corpus
from repro.quant.rerank import (
    ExactStore,
    exact_candidate_distances,
    resolve_store_mode,
)

# stage-1 fp32-cast gemm is exact (= the int32 dot) while every int8 product
# sum stays below 2^24: D * 127^2 <= 2^24  =>  D <= 1040.
_EXACT_CAST_MAX_D = 1024


@partial(jax.jit, static_argnames=("mult", "exact_cast"))
def _stage1_scores(q, codes, scale_bias, mult, exact_cast):
    """(L, Npad) quantized scores, lower is better.

    ``q`` is fp32 (pre-normalized by the caller for 'cos'); query
    quantization (scale folding + per-query symmetric int8) happens inside
    the jit.  ``scale_bias`` is (D + Npad,): the per-dim scales followed by
    a per-row bias that folds BOTH the metric correction and the padding
    mask — dequantized ||x||^2 with +inf padding for l2 (mult=-2), plain
    0/+inf for ip (mult=-1) — so no iota/where runs per call.
    """
    dim = q.shape[1]
    scales = scale_bias[:dim]
    bias = scale_bias[dim:]
    qf = q * scales[None, :]
    qsc = jnp.maximum(jnp.abs(qf).max(-1) / 127.0, EPS_SCALE)
    qcf = jnp.rint(qf / qsc[:, None])  # integer-valued fp32 in [-127, 127]
    if exact_cast:
        dots = jax.lax.dot_general(
            qcf, codes.astype(jnp.float32), (((1,), (1,)), ((), ()))
        )
    else:
        dots = jax.lax.dot_general(
            qcf.astype(jnp.int8), codes, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)
    return bias[None, :] + (mult * qsc)[:, None] * dots


class _Q8Partition:
    """Device/host state for one quantized (shard, segment) partition."""

    # Deployment envelope (repro.analysis.scalecheck): one segment of the
    # paper's q8 deployment point — 10M rows (12.5M after the quarter-pow2
    # pad) x 512d codes must fit a single 8 GiB device alongside headroom.
    # lanns: dims[n_pad<=12_500_000, dim<=512]
    def __init__(self, qc: Q8Corpus, vectors: np.ndarray, keys, metric: str):  # lanns: budget[device<=8GiB]
        self.n = qc.size
        # quarter-pow2 corpus buckets: stage-1 gemm cost and resident codes
        # scale with n_pad, so cap padding waste at 25% (vs up to 2x for
        # plain pow2) while the trace count stays logarithmic.
        self.n_pad = next_pow2_quarter(self.n)
        dim = qc.dim
        codes = np.zeros((self.n_pad, dim), np.int8)
        codes[: self.n] = qc.codes
        # scales ++ per-row bias folding the metric correction AND the
        # padding mask (l2 uses the dequantized norms, ip a zero bias; +inf
        # on padding rows) — only the served metric's vector stays resident.
        metric_k = "l2" if metric == "l2" else "ip"
        bias = np.full((self.n_pad,), np.inf, np.float32)
        bias[: self.n] = qc.norms2 if metric_k == "l2" else 0.0
        self.codes = jnp.asarray(codes)
        self.scale_bias = {
            metric_k: jnp.asarray(np.concatenate([qc.scales, bias])),
        }
        # exact store: fp32 originals stay host-side (numpy / mmap) unless
        # rerank_store='device' uploads them lazily.
        self.store = ExactStore(vectors, keys)
        self.metric = metric

    @property
    def keys(self):
        return self.store.keys

    def resident_bytes(self) -> int:
        """Scan-resident footprint: codes + scale/bias vectors."""
        return int(self.codes.nbytes) + sum(
            int(v.nbytes) for v in self.scale_bias.values()
        )


class QuantizedScanExecutor:
    """Runs the two-stage search for every quantized scan partition.

    Built once per index (device codes upload once, like the HNSW stack) and
    reused across query batches; ``run`` scatters per-lane exact results
    into the executor's compact route slots, mirroring the stacked-HNSW
    candidates stage in ``core/plan.py``.
    """

    def __init__(self, parts, metric: str, rerank_factor: int,
                 rerank_store: str):
        # parts: {(s, g): _Q8Partition}
        self.parts = parts
        self.metric = metric
        self.rerank_factor = max(int(rerank_factor), 1)
        self.rerank_store = resolve_store_mode(rerank_store)

    def resident_bytes(self) -> int:
        return sum(p.resident_bytes() for p in self.parts.values())

    def exact_store_bytes(self) -> int:
        return sum(p.store.nbytes() for p in self.parts.values())

    # -- the full two-stage pass ------------------------------------------

    # lanns: hotpath
    def run(self, queries, sels, slot, cand_d, cand_i, pstk, *,
            lane_width=None, rerank_s=None, clock=None):
        """Search every quantized partition; returns the handled set.

        ``queries`` are the raw fp32 queries (mips augmentation already
        applied by the caller; metric == 'l2' then).  Lane results land in
        ``cand_d``/``cand_i`` route slots of width ``lane_width``
        (default ``pstk``): the dedup-free merge path passes the full
        candidate width ``rerank_factor * pstk`` so lanes skip the
        per-lane trim and the merge sees every exactly-scored candidate.

        For metric 'l2' the scattered distances OMIT the per-query ||q||^2
        constant (it cannot change any within-query ordering); the caller
        adds it back after its merge — one (B, topk) add instead of one per
        lane.

        ``rerank_s`` (telemetry): a one-element list accumulator — the
        exact-re-rank wall clock of every partition is ADDED to
        ``rerank_s[0]``, read with ``clock`` (the telemetry clock).  Left
        at None (the default) no clock is read: the untimed path is
        byte-for-byte the pre-telemetry one.
        """
        handled = set(self.parts)
        W = pstk if lane_width is None else lane_width
        q_eff = np.asarray(queries, np.float32)
        if self.metric == "cos":
            q_eff = q_eff / np.maximum(
                np.linalg.norm(q_eff, axis=-1, keepdims=True), 1e-12
            )
        metric_k = "l2" if self.metric == "l2" else "ip"
        mult = -2.0 if metric_k == "l2" else -1.0
        # phase A: async-dispatch every partition's stage-1 scores; XLA's
        # pool computes later partitions while the host handles earlier ones
        staged = []
        # sorted(): dispatch order must not depend on dict insertion order —
        # it fixes both the XLA dispatch sequence and the scatter order
        # (LANNS006); parts is built sorted, so this is bit-identical.
        for (s, g), part in sorted(self.parts.items()):
            sel = sels[g]
            b = len(sel)
            if b == 0 or part.n == 0:
                continue
            l_pad = next_pow2_quarter(b)
            q_lane = q_eff[sel]
            qp = q_lane
            if l_pad != b:
                qp = np.zeros((l_pad, q_eff.shape[1]), np.float32)
                qp[:b] = q_lane
            fut = _stage1_scores(
                jnp.asarray(qp), part.codes, part.scale_bias[metric_k],  # lanns: noqa[LANNS004] -- per-partition ASYNC dispatch is the point: uploads overlap stage-1 compute
                mult, part.codes.shape[1] <= _EXACT_CAST_MAX_D,
            )
            staged.append(((s, g), part, sel, b, l_pad, q_lane, fut))
        # phase B: select -> exact re-rank -> scatter, one partition at a time
        host_shares_memory = jax.default_backend() == "cpu"
        for (s, g), part, sel, b, l_pad, q_lane, fut in staged:
            C = min(self.rerank_factor * pstk, part.n)
            # CPU jax shares buffers with numpy via dlpack (zero-copy view;
            # selection only reads it); accelerators need the device->host
            # copy — np.from_dlpack refuses non-CPU capsules.
            scores = (
                np.from_dlpack(fut) if host_shares_memory  # lanns: noqa[LANNS003] -- per-partition sync AFTER async dispatch of all partitions; zero-copy on CPU
                else np.asarray(fut)  # lanns: noqa[LANNS003] -- accelerator fallback of the same designed sync point
            )[:b]
            if C < scores.shape[1]:
                # padding rows score +inf, so the C smallest are always
                # real rows (C <= n == number of finite entries)
                cand = np.argpartition(scores, C, axis=1)[:, :C].astype(
                    np.int32
                )
            else:  # C == n == n_pad: every row is a candidate
                cand = np.broadcast_to(
                    np.arange(C, dtype=np.int32), (b, C)
                ).copy()
            t_rr = None if rerank_s is None else clock()
            ex = exact_candidate_distances(
                q_lane, cand, part.store, self.metric,
                mode=self.rerank_store, l_pad=l_pad,
            )
            if t_rr is not None:
                rerank_s[0] += clock() - t_rr
            kk = min(W, C)
            if kk < C:
                loc = np.argpartition(ex, kk - 1, axis=1)[:, :kk]
                d_lane = np.take_along_axis(ex, loc, axis=1)
                i_lane = part.keys[np.take_along_axis(cand, loc, axis=1)]
            else:
                d_lane = ex
                i_lane = part.keys[cand]
            sl = slot[sel, g]
            cand_d[sel, s, sl, :kk] = d_lane
            cand_i[sel, s, sl, :kk] = i_lane
        return handled

"""Sharded AdamW + schedules, dependency-free (no optax in this environment).

Optimizer state is a pytree mirroring params (m, v), so it inherits the
parameter PartitionSpecs; with ZeRO-1 the caller passes ``zero1_specs`` which
additionally shards replicated-parameter state along the data axis.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # 'cosine' | 'constant'


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params):
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"step": jnp.int32(0), "m": zeros(params), "v": zeros(params)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(cfg: AdamWConfig, params, grads, state,
                 decay_mask: Optional[Callable] = None):
    """One AdamW step.  Returns (new_params, new_state, metrics).

    decay_mask(path, leaf) -> bool: apply weight decay (default: only to
    >=2D weights — norms/biases/tables excluded like the usual LM recipe).
    """
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if (
        cfg.grad_clip > 0
    ) else 1.0
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    if decay_mask is None:
        decay_mask = lambda path, leaf: leaf.ndim >= 2

    flat_params = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat_params[0]]

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if decay_mask(path, p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state["m"], state["v"],
    )
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics

"""Train steps per model family: loss, grad, microbatch accumulation, update.

``make_lm_train_step`` (and siblings) return a pure function
    (params, opt_state, batch, rng) -> (params, opt_state, metrics)
suitable for jit with in/out shardings.  Microbatching runs a lax.scan over
microbatch slices accumulating f32 grads — this is what bounds activation
memory on the big dry-run cells (with cfg.remat bounding it further per
layer).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding_rules import NULL_CTX, ShardingCtx
from repro.train.optimizer import AdamWConfig, adamw_update


def cross_entropy_loss(logits, labels, *, z_loss: float = 0.0, mask=None):
    """Token CE with optional z-loss; logits (..., V) f32-upcast, labels int.

    The gold logit is extracted with an iota-select-reduce rather than
    take_along_axis: a gather along the vocab axis would force GSPMD to
    all-gather the (B, S, V) logits when vocab is model-sharded (measured:
    +21 GiB temp on smollm train_4k); the select+sum stays local per vocab
    shard and reduces with a psum.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(iota == labels[..., None], logits, 0.0), axis=-1
    )
    ce = lse - gold
    if z_loss:
        ce = ce + z_loss * lse**2
    if mask is not None:
        ce = ce * mask
        return ce.sum() / jnp.maximum(mask.sum(), 1.0)
    return ce.mean()


def bce_with_logits(logits, labels):
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def _accumulate_grads(loss_fn, params, batch, num_micro: int):
    """Scan over microbatches; returns (mean_loss, mean_grads, aux_mean).

    batch leaves must have leading dim divisible by num_micro.
    """
    if num_micro <= 1:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, grads, aux

    def reshape(x):
        return x.reshape(num_micro, x.shape[0] // num_micro, *x.shape[1:])

    micro = jax.tree.map(reshape, batch)

    def body(carry, mb):
        loss_acc, grad_acc, aux_acc = carry
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        grad_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
        )
        return (loss_acc + loss, grad_acc, aux_acc + aux), None

    zero_grads = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    (loss, grads, aux), _ = jax.lax.scan(
        body, (jnp.float32(0.0), zero_grads, jnp.float32(0.0)), micro
    )
    inv = 1.0 / num_micro
    return loss * inv, jax.tree.map(lambda g: g * inv, grads), aux * inv


def make_train_step(
    loss_fn: Callable,
    opt_cfg: AdamWConfig,
    *,
    num_micro: int = 1,
    decay_mask: Optional[Callable] = None,
):
    """Generic: loss_fn(params, batch) -> (loss, aux_scalar)."""

    def train_step(params, opt_state, batch):
        loss, grads, aux = _accumulate_grads(loss_fn, params, batch, num_micro)
        params, opt_state, metrics = adamw_update(
            opt_cfg, params, grads, opt_state, decay_mask
        )
        metrics = dict(metrics, loss=loss, aux_loss=aux)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# family-specific losses
# ---------------------------------------------------------------------------


def lm_loss_fn(cfg, ctx: ShardingCtx = NULL_CTX, z_loss: float = 1e-4):
    from repro.models import transformer as tf

    def loss_fn(params, batch):
        logits, _, aux = tf.apply(params, cfg, batch["tokens"], ctx=ctx)
        mask = batch.get("mask")
        ce = cross_entropy_loss(logits, batch["labels"], z_loss=z_loss, mask=mask)
        return ce + aux, aux

    return loss_fn


def dimenet_loss_fn(cfg, ctx: ShardingCtx = NULL_CTX):
    """Handles three batch layouts:
    * single graph:  positions (n, 3) — full-batch training;
    * batched:       positions (B, n, 3) — molecules, sampled subgraphs, OR
                     halo partitions of a huge graph (DistDGL-style data
                     parallelism: each lane owns one partition, grads psum);
      with y (B,) graph-level or y (B, n) node-level targets.
    """
    from repro.models import dimenet as dn

    def loss_fn(params, batch):
        if batch["positions"].ndim == 3:
            opt_keys = [
                k for k in ("z", "features", "node_mask") if k in batch
            ]

            def one(pos, ei, ti, to, *opts):
                kw = dict(zip(opt_keys, opts))
                node_pred, graph_pred = dn.apply(
                    params, cfg, positions=pos, edge_index=ei, t_in=ti,
                    t_out=to, z=kw.get("z"), node_feat=kw.get("features"),
                    node_mask=kw.get("node_mask"), ctx=ctx,
                )
                return node_pred[:, 0], graph_pred[0]

            node_preds, graph_preds = jax.vmap(one)(
                batch["positions"], batch["edge_index"], batch["t_in"],
                batch["t_out"], *[batch[k] for k in opt_keys],
            )
            y = batch["y"]
            if y.ndim == 2:  # node-level targets over partitions/subgraphs
                mask = batch.get("node_mask")
                mask = (
                    mask.astype(jnp.float32)
                    if mask is not None
                    else jnp.ones_like(y, jnp.float32)
                )
                loss = jnp.sum((node_preds - y) ** 2 * mask) / jnp.maximum(
                    mask.sum(), 1.0
                )
            else:
                loss = jnp.mean((graph_preds - y) ** 2)
        else:
            node_pred, _ = dn.apply(
                params, cfg,
                positions=batch["positions"], edge_index=batch["edge_index"],
                t_in=batch["t_in"], t_out=batch["t_out"],
                z=batch.get("z"), node_feat=batch.get("features"),
                node_mask=batch.get("node_mask"), ctx=ctx,
            )
            target = batch["y"]
            mask = batch.get("node_mask")
            se = (node_pred[:, 0] - target) ** 2
            if mask is not None:
                loss = jnp.sum(se * mask) / jnp.maximum(mask.sum(), 1.0)
            else:
                loss = jnp.mean(se)
        return loss, jnp.float32(0.0)

    return loss_fn


def recsys_loss_fn(arch: str, cfg, ctx: ShardingCtx = NULL_CTX):
    from repro.models import recsys as rs

    def loss_fn(params, batch):
        if arch == "autoint":
            logits = rs.autoint_apply(params, cfg, batch["sparse_ids"], ctx)
            loss = bce_with_logits(logits, batch["label"])
        elif arch == "xdeepfm":
            logits = rs.xdeepfm_apply(params, cfg, batch["sparse_ids"], ctx)
            loss = bce_with_logits(logits, batch["label"])
        elif arch == "din":
            logits = rs.din_apply(
                params, cfg, history=batch["history"], hist_len=batch["hist_len"],
                target_item=batch["target_item"], context_ids=batch["context_ids"],
                ctx=ctx,
            )
            loss = bce_with_logits(logits, batch["label"])
        elif arch == "sasrec":
            # paper objective: BCE(pos) + BCE(neg) with one sampled negative
            # per position (full 10M-item logits would be B*T*10M).
            labels = batch["next_items"]  # (B, T), -1 where padded
            if "neg_items" in batch:
                pos, neg = rs.sasrec_sampled_logits(
                    params, cfg, batch["item_seq"], jnp.clip(labels, 0),
                    batch["neg_items"], ctx,
                )
                mask = (labels >= 0).astype(jnp.float32)
                ls = jax.nn.softplus(-pos) + jax.nn.softplus(neg)
                loss = jnp.sum(ls * mask) / jnp.maximum(mask.sum(), 1.0)
            else:  # small-vocab eval path (smoke tests)
                logits = rs.sasrec_apply(params, cfg, batch["item_seq"], ctx)
                mask = (labels >= 0).astype(jnp.float32)
                loss = cross_entropy_loss(logits, jnp.clip(labels, 0), mask=mask)
        else:
            raise ValueError(arch)
        return loss, jnp.float32(0.0)

    return loss_fn

"""Fault-tolerant checkpointing: atomic, content-hashed, resumable, async.

The same machinery covers training state and LANNS build artifacts.
Guarantees:
  * atomicity  — write to temp + fsync + rename; a crash never leaves a
    half-written checkpoint visible;
  * integrity  — manifest stores a content hash per array file; restore
    verifies (detects torn writes on shared filesystems);
  * retention  — keep_last_n with monotonic step directories;
  * resumption — ``latest_step`` + ``restore`` rebuild (params, opt_state)
    exactly; restart-safe against partial saves (the paper's HDFS-temp-path
    pattern, §5.3.1, adapted to preemptible TPU jobs);
  * async      — a single background writer thread; ``wait()`` joins before
    the next save (bounded staleness of 1).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_names(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        names.append(
            "/".join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in path
            )
        )
    return names


class CheckpointManager:
    def __init__(self, root: str, keep_last_n: int = 3, async_write: bool = False):
        self.root = root
        self.keep_last_n = keep_last_n
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- write ----------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[dict] = None):
        """Snapshot ``tree`` at ``step``.  Host-blocking copy happens here;
        file IO happens inline or on the writer thread."""
        leaves, treedef = _flatten(tree)
        arrays = [np.asarray(x) for x in leaves]  # device -> host snapshot
        names = _leaf_names(tree)
        if self.async_write:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, names, extra), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, arrays, names, extra)

    def _write(self, step: int, arrays, names, extra):
        final_dir = os.path.join(self.root, f"step_{step:010d}")
        tmp_dir = tempfile.mkdtemp(dir=self.root, prefix=".tmp_")
        manifest = {"step": step, "arrays": [], "extra": extra or {}}
        try:
            npz_path = os.path.join(tmp_dir, "arrays.npz")
            np.savez(npz_path, **{f"a{i}": a for i, a in enumerate(arrays)})
            h = hashlib.sha256()
            with open(npz_path, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    h.update(chunk)
            for i, (a, n) in enumerate(zip(arrays, names)):
                manifest["arrays"].append(
                    {"key": f"a{i}", "name": n, "shape": list(a.shape),
                     "dtype": str(a.dtype)}
                )
            manifest["sha256"] = h.hexdigest()
            with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final_dir):
                shutil.rmtree(final_dir)
            os.replace(tmp_dir, final_dir)  # atomic publish
        except BaseException:
            shutil.rmtree(tmp_dir, ignore_errors=True)
            raise
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_last_n]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:010d}"), ignore_errors=True)

    # -- read -----------------------------------------------------------------

    def steps(self):
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.root, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, tree_like, verify: bool = True):
        """Restore into the structure of ``tree_like`` (shapes must match)."""
        d = os.path.join(self.root, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        npz_path = os.path.join(d, "arrays.npz")
        if verify:
            h = hashlib.sha256()
            with open(npz_path, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    h.update(chunk)
            if h.hexdigest() != manifest["sha256"]:
                raise IOError(
                    f"checkpoint {d} failed integrity check "
                    f"(torn write or corruption)"
                )
        leaves, treedef = _flatten(tree_like)
        with np.load(npz_path) as z:
            if len(manifest["arrays"]) != len(leaves):
                raise ValueError(
                    f"checkpoint has {len(manifest['arrays'])} leaves, "
                    f"expected {len(leaves)}"
                )
            new_leaves = []
            for meta, ref in zip(manifest["arrays"], leaves):
                a = z[meta["key"]]
                if list(a.shape) != list(np.shape(ref)):
                    raise ValueError(
                        f"leaf {meta['name']}: shape {a.shape} != {np.shape(ref)}"
                    )
                new_leaves.append(a)
        return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]

    def restore_latest(self, tree_like, verify: bool = True):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, tree_like, verify)
        return step, tree, extra

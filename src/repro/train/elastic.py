"""Elastic scaling + straggler mitigation plans (1000+ node operation).

On real pods, failures arrive as "host h went away".  Everything here is the
*deterministic control-plane logic* for that event — pure functions from
(cluster state, manifest) to placement/action, unit-testable on CPU:

* ``ShardPlacement``: shard -> host assignment as a pure function of
  (num_hosts, num_shards, generation).  LANNS shards are independent by
  construction (hash-partitioned, one index per shard), so re-placement is
  just "reload shard s artifacts on its new host" — no resharding of data.
* ``replan_on_failure``: drop failed hosts, rebalance with minimal movement
  (only shards that lived on dead hosts move), bump generation.
* ``EscalationPolicy``: mesh-size fallback for training — on loss of a data-
  parallel slice, shrink the data axis to the largest power-of-two that still
  fits and rescale per-device batch (gradient-equivalent; optimizer state is
  re-sharded by the same placement function).
* ``StragglerMonitor``: detects slow hosts from step-time EWMAs (the paper's
  Spark "time-out errors" §5.3.1 are exactly straggler cascades); emits
  speculative-duplicate assignments for the slowest shard like Spark
  speculative execution — in LANNS serving a duplicated shard is always safe
  (same answer, first responder wins).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardPlacement:
    num_hosts: int
    num_shards: int
    generation: int
    assignment: tuple  # shard -> host
    dead: tuple = ()  # hosts that have failed (cumulative)

    @classmethod
    def initial(cls, num_hosts: int, num_shards: int):
        # round-robin; deterministic
        return cls(
            num_hosts=num_hosts,
            num_shards=num_shards,
            generation=0,
            assignment=tuple(s % num_hosts for s in range(num_shards)),
        )

    def hosts_of(self, shard: int) -> int:
        return self.assignment[shard]

    def shards_of(self, host: int):
        return [s for s, h in enumerate(self.assignment) if h == host]

    def load(self) -> np.ndarray:
        counts = np.zeros(self.num_hosts, dtype=np.int64)
        for h in self.assignment:
            if h >= 0:
                counts[h] += 1
        return counts


def replan_on_failure(placement: ShardPlacement, failed_hosts) -> ShardPlacement:
    """Minimal-movement rebalance: only shards on failed hosts move, to the
    currently least-loaded surviving hosts.  Dead hosts accumulate across
    generations (a restarted host re-joins via a fresh placement epoch)."""
    failed = set(failed_hosts) | set(placement.dead)
    survivors = [h for h in range(placement.num_hosts) if h not in failed]
    if not survivors:
        raise RuntimeError("no surviving hosts")
    load = {h: 0 for h in survivors}
    for h in placement.assignment:
        if h in load:
            load[h] += 1
    new_assign = list(placement.assignment)
    for s, h in enumerate(placement.assignment):
        if h in failed:
            target = min(survivors, key=lambda x: (load[x], x))
            new_assign[s] = target
            load[target] += 1
    return ShardPlacement(
        num_hosts=placement.num_hosts,
        num_shards=placement.num_shards,
        generation=placement.generation + 1,
        assignment=tuple(new_assign),
        dead=tuple(sorted(failed)),
    )


@dataclasses.dataclass(frozen=True)
class MeshFallback:
    data: int
    model: int
    per_device_batch_scale: float


def escalation_plan(
    data_axis: int, model_axis: int, lost_devices: int
) -> Optional[MeshFallback]:
    """Shrink the data axis to the largest size whose mesh fits the surviving
    devices; model axis is preserved (TP groups must stay intact — losing one
    member kills the whole group, so lost devices round up to model-axis
    multiples)."""
    total = data_axis * model_axis
    lost_groups = -(-lost_devices // model_axis)
    surviving_groups = data_axis - lost_groups
    if surviving_groups <= 0:
        return None
    new_data = 1 << (surviving_groups.bit_length() - 1)  # floor pow2
    return MeshFallback(
        data=new_data,
        model=model_axis,
        per_device_batch_scale=data_axis / new_data,
    )


class StragglerMonitor:
    """EWMA step times per host; flags hosts slower than ratio x median."""

    def __init__(self, num_hosts: int, alpha: float = 0.2, ratio: float = 1.5,
                 min_samples: int = 5):
        self.ewma = np.zeros(num_hosts)
        self.count = np.zeros(num_hosts, dtype=np.int64)
        self.alpha = alpha
        self.ratio = ratio
        self.min_samples = min_samples

    def observe(self, host: int, step_seconds: float):
        if self.count[host] == 0:
            self.ewma[host] = step_seconds
        else:
            self.ewma[host] = (
                self.alpha * step_seconds + (1 - self.alpha) * self.ewma[host]
            )
        self.count[host] += 1

    def stragglers(self):
        ready = self.count >= self.min_samples
        if ready.sum() < 2:
            return []
        med = np.median(self.ewma[ready])
        return [
            int(h)
            for h in np.nonzero(ready & (self.ewma > self.ratio * med))[0]
        ]

    def speculative_duplicates(self, placement: ShardPlacement):
        """For each straggler, duplicate its shards onto the fastest host —
        serving-safe (idempotent reads); the broker takes the first answer."""
        stragglers = self.stragglers()
        if not stragglers:
            return {}
        ready = self.count >= self.min_samples
        fastest = int(np.argmin(np.where(ready, self.ewma, np.inf)))
        return {
            s: fastest
            for h in stragglers
            for s in placement.shards_of(h)
            if fastest != h
        }

"""Re-run the loop-aware HLO cost analysis over saved dry-run artifacts.

The dry-run persists each cell's post-SPMD HLO (gzip); this tool refreshes
the ``cost_loopaware`` block in the JSON records when the estimator changes —
no recompilation.

  PYTHONPATH=src python -m repro.launch.reanalyze [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.launch.hlo_cost import analyze


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="results/dryrun")
    args = p.parse_args(argv)
    n = 0
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        hlo_path = path[: -len(".json")] + ".hlo.gz"
        if not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            hlo = f.read()
        la = analyze(hlo)
        with open(path) as f:
            rec = json.load(f)
        rec["cost_loopaware"] = {
            "flops": la["flops"],
            "bytes": la["bytes"],
            "collective_bytes": la["collective_bytes"],
            "collective_total_bytes": la["collective_total_bytes"],
        }
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        n += 1
        print(f"reanalyzed {os.path.basename(path)}: "
              f"flops={la['flops']:.3e} bytes={la['bytes']:.3e} "
              f"coll={la['collective_total_bytes']:.3e}")
    print(f"{n} records updated")


if __name__ == "__main__":
    main()

"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device count is locked at first jax init, and smoke tests
must see 1 CPU device while the dry-run sees 512 forced host devices).
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions.

    ``axis_types`` / ``jax.sharding.AxisType`` only exist on newer jax; older
    releases (e.g. 0.4.x) default every axis to Auto anyway, so omitting the
    kwarg there is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: 'data' = batch/FSDP axis, 'model' = TP/EP/shard axis, 'pod' =
    cross-pod data parallelism (or extra corpus shards for LANNS serving).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 4, *, pod: int = 0):
    """Small mesh for CI-scale dry-run tests (requires forced host devices)."""
    if pod:
        return compat_make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat_make_mesh((data, model), ("data", "model"))

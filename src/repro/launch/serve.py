"""Serving driver: build a LANNS index and serve queries.

``python -m repro.launch.serve --corpus-size 20000 --dim 64 --mode offline``
runs the paper's offline pipeline (build -> query -> recall report);
``--mode online`` runs the batched serving loop with latency stats.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--corpus-size", type=int, default=20_000)
    p.add_argument("--dim", type=int, default=64)
    p.add_argument("--queries", type=int, default=500)
    p.add_argument("--topk", type=int, default=100)
    p.add_argument("--shards", type=int, default=2)
    p.add_argument("--segments", type=int, default=4)
    p.add_argument("--segmenter", default="apd", choices=["rs", "rh", "apd"])
    p.add_argument("--engine", default="scan", choices=["scan", "hnsw"])
    p.add_argument("--alpha", type=float, default=0.15)
    p.add_argument("--mode", default="offline", choices=["offline", "online"])
    p.add_argument("--index-dir", default=None)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from repro.core import (
        LannsConfig, LannsIndex, brute_force_topk, recall_table,
    )
    from repro.data.synthetic import clustered_vectors

    corpus = clustered_vectors(
        args.corpus_size, args.dim, n_clusters=max(64, args.corpus_size // 500),
        seed=args.seed,
    )
    queries = clustered_vectors(
        args.queries, args.dim, n_clusters=max(64, args.corpus_size // 500),
        seed=args.seed + 1,
    )
    cfg = LannsConfig(
        num_shards=args.shards, num_segments=args.segments,
        segmenter=args.segmenter, alpha=args.alpha, engine=args.engine,
    )
    print(f"building LANNS ({args.shards},{args.segments})-{args.segmenter} "
          f"over {args.corpus_size} x {args.dim} ...")
    t0 = time.time()
    idx = LannsIndex(cfg).build(corpus, resume_dir=args.index_dir)
    print(f"build: {time.time() - t0:.1f}s  "
          f"stats={ {k: v for k, v in idx.build_stats.items() if 'seconds' in k} }")

    if args.mode == "offline":
        t0 = time.time()
        d, i, stats = idx.query(queries, args.topk, return_stats=True)
        tq = time.time() - t0
        td, ti = brute_force_topk(queries, corpus, args.topk)
        print(f"query: {1e3 * tq / len(queries):.2f} ms/query  {stats}")
        print("recall:", {k: round(v, 4) for k, v in
                          recall_table(i, ti).items()})
    else:
        lat = []
        for s in range(0, len(queries), 32):
            t0 = time.perf_counter()
            idx.query(queries[s: s + 32], args.topk)
            lat.append(time.perf_counter() - t0)
        lat = np.array(lat[1:])
        print(
            f"online: {32 * len(lat) / lat.sum():.0f} QPS  "
            f"p50 {1e3 * np.percentile(lat, 50):.1f} ms/batch  "
            f"p99 {1e3 * np.percentile(lat, 99):.1f} ms/batch"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Training driver: ``python -m repro.launch.train --arch smollm-360m ...``.

Single-host (CPU) and mesh runs share this loop: data pipeline -> jit'd
train step -> checkpoint manager (+ resume), with straggler/step-time stats.
On CPU the arch's reduced config is the default so the driver is exercisable
end-to-end in CI; --full uses the published config (needs a real pod).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--num-micro", type=int, default=1)
    p.add_argument("--full", action="store_true", help="published config")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    from repro.configs import get_arch
    from repro.data.pipeline import ShardedBatchIterator
    from repro.data.synthetic import token_batch
    from repro.models import transformer as tf
    from repro.train.checkpoint import CheckpointManager
    from repro.train.optimizer import AdamWConfig, init_state
    from repro.train.train_step import lm_loss_fn, make_train_step

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for others")
    cfg = arch.model_config(reduced=not args.full)
    print(f"arch={args.arch} params={cfg.num_params():,} "
          f"(active {cfg.num_active_params():,})")

    key = jax.random.PRNGKey(args.seed)
    params = tf.init(key, cfg)
    opt_cfg = AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps,
    )
    opt_state = init_state(params)
    step_fn = jax.jit(
        make_train_step(lm_loss_fn(cfg), opt_cfg, num_micro=args.num_micro)
    )

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep_last_n=2, async_write=True)
        if args.resume:
            restored = mgr.restore_latest({"p": params, "o": opt_state})
            if restored:
                start_step, tree, extra = restored
                params, opt_state = tree["p"], tree["o"]
                print(f"resumed from step {start_step} (loss {extra.get('loss')})")

    def batch_fn(seed, step):
        toks, labels = token_batch(args.batch, args.seq, cfg.vocab,
                                   seed=seed * 1_000_003 + step)
        return {"tokens": toks, "labels": labels}

    it = ShardedBatchIterator(batch_fn, seed=args.seed, start_step=start_step)
    times = []
    loss = float("nan")
    try:
        for _ in range(start_step, args.steps):
            step, batch = next(it)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(
                params, opt_state,
                {k: jnp.asarray(v) for k, v in batch.items()},
            )
            loss = float(metrics["loss"])
            times.append(time.perf_counter() - t0)
            if step % args.log_every == 0:
                print(
                    f"step {step:5d} loss {loss:.4f} "
                    f"lr {float(metrics['lr']):.2e} "
                    f"gnorm {float(metrics['grad_norm']):.2f} "
                    f"{np.mean(times[-args.log_every:]) * 1e3:.0f} ms/step",
                    flush=True,
                )
            if mgr and step and step % args.ckpt_every == 0:
                mgr.save(step, {"p": params, "o": opt_state},
                         extra={"loss": loss})
    finally:
        it.close()
        if mgr:
            mgr.wait()
    print(f"done: final loss {loss:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
